"""GraphSAGE → trainable PQ index: the paper's technique on GNN embeddings.

GraphSAGE's original unsupervised use produces node embeddings consumed by
nearest-neighbor retrieval — exactly where the paper's index layer slots in.
This example trains GraphSAGE on a synthetic community graph, attaches the
GCD-rotated PQ index to the output embeddings, and measures neighbor-recall
through the compressed index vs the frozen-rotation baseline.

Run:  PYTHONPATH=src python examples/gnn_index.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import quant, search
from repro.quant import opq
from repro.data import graph as graph_lib
from repro.models import gnn
from repro.training import optimizer as opt_lib
from repro.training import train_state as ts


def _recall_excluding_self(pred_ids: np.ndarray, true_ids: np.ndarray,
                           rows: int) -> float:
    """Neighbor recall@10 where a node never counts itself as a hit (the
    query set is the corpus; searchers return self at rank ~0)."""
    hits = []
    for i in range(rows):
        pred = [p for p in pred_ids[i].tolist() if p != i and p >= 0][:10]
        hits.append(len(set(pred) & set(true_ids[i].tolist())) / 10)
    return float(np.mean(hits))


def main():
    g = graph_lib.synthetic_graph(0, num_nodes=2000, avg_degree=8, d_feat=32,
                                  num_classes=8)
    cfg = gnn.GraphSAGEConfig(name="sage", d_in=32, d_hidden=64,
                              num_classes=8, sample_sizes=(8, 4))
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.OptimizerConfig(lr=3e-3, total_steps=120, warmup_steps=10)
    state = ts.init_state(jax.random.PRNGKey(1), params, ocfg)
    step = jax.jit(ts.make_train_step(
        lambda p, h0, h1, h2, y: gnn.loss_minibatch(p, [h0, h1, h2], y, cfg),
        ocfg))

    for i in range(120):
        rng = np.random.RandomState(i)
        seeds = rng.randint(0, g.num_nodes, size=64)
        feats, labels = graph_lib.sample_blocks(g, seeds, cfg.sample_sizes, i)
        state, m = step(state, *feats, labels)
        if i % 30 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")

    # full-graph node embeddings (classifier input)
    src, dst = g.edge_list()
    h = jnp.asarray(g.feats)
    for l in range(cfg.num_layers):
        h_n = gnn._aggregate_edges(h, jnp.asarray(src), jnp.asarray(dst),
                                   g.num_nodes, cfg.aggregator)
        h = gnn._sage_layer(state.params[f"layer{l}"], h, h_n)
    print(f"node embeddings: {h.shape}")

    # index the embeddings with GCD rotation vs frozen — both the ground
    # truth and the compressed scan go through the repro.search registry
    cfg_pq = quant.PQConfig(8, 32)
    scfg = search.SearchConfig(subspaces=8, codewords=32, num_lists=1)
    probes = np.asarray(h[:200])
    exact_s = search.make("exact")
    ex_state = exact_s.build(jax.random.PRNGKey(3), h,
                             jnp.eye(h.shape[1], dtype=h.dtype), scfg)
    ex_ids = np.asarray(exact_s.search(ex_state, probes, k=11).ids)
    truth = np.stack([
        np.asarray([p for p in ex_ids[i].tolist() if p != i][:10])
        for i in range(200)
    ])
    flat_s = search.make("flat_adc")
    for solver in ("frozen", "gcd_greedy"):
        R, pqz, trace = opq.fit(
            jax.random.PRNGKey(3), h, cfg_pq, iters=15,
            rotation=solver, inner_steps=5, lr=2e-3)
        # serve the codebooks OPQ fit jointly with R (no refit), so the
        # printed distortion and recall measure the same quantizer
        state = flat_s.from_quantizer(R, pqz, h)
        res = flat_s.search(state, probes, k=11)
        rec = _recall_excluding_self(np.asarray(res.ids), truth, 200)
        print(f"{solver:12s} distortion {float(trace[-1]):.4f}  "
              f"neighbor recall@10 {rec:.3f}")


if __name__ == "__main__":
    main()
