"""End-to-end driver (deliverable b): train a ~100M-parameter two-tower
retrieval model with the paper's trainable PQ index for a few hundred steps.

Follows the paper's §3.2 protocol end to end:
  1. warm-up steps without the index layer;
  2. OPQ warm start of (R, codebooks) from a warm-up sample;
  3. joint training — codebooks by SGD (distortion loss), R by GCD
     (greedy matching, Algorithm 2), towers by Adam — with async
     checkpointing and auto-resume (kill it mid-run and start again!);
  4. final ADC-retrieval evaluation (p@k / r@k) vs the frozen-R baseline.

~100M params: 390k items × 256-dim table (≈100M) + tower MLPs.

Run:  PYTHONPATH=src python examples/train_twotower.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import rotations
from repro.core import index_layer as il
from repro.data import synthetic
from repro.models import recsys
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt_lib
from repro.training import train_state as ts


def build_cfg(item_vocab: int) -> recsys.TwoTowerConfig:
    return recsys.TwoTowerConfig(
        name="twotower-100m", item_vocab=item_vocab, embed_dim=256,
        tower_dims=(256, 128), hist_len=16, scoring="cosine",
        hinge_margin=0.1,
        index=il.IndexLayerConfig(dim=128, num_subspaces=16, num_codewords=64),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def evaluate(params, cfg, log, k=50, num_queries=32):
    hist, truth = log.eval_queries(7, num_queries, cfg.hist_len, k_truth=k)
    ids = jnp.arange(cfg.item_vocab)
    vecs, _ = recsys.item_tower(params, ids, cfg)
    vecs = vecs / jnp.maximum(jnp.linalg.norm(vecs, axis=-1, keepdims=True), 1e-6)
    codes = il.encode(params["index"], vecs)
    scores = recsys.twotower_retrieve_adc(params, hist, codes, cfg)
    top = np.asarray(jnp.argsort(-scores, axis=-1)[:, :k])
    hits = np.array([len(set(top[i]) & set(truth[i])) for i in range(len(top))])
    return hits.mean() / k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--warmup", type=int, default=80)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--items", type=int, default=390_000)
    ap.add_argument("--ckpt-dir", default="/tmp/twotower_ckpt")
    ap.add_argument("--rotation", default="gcd_greedy",
                    choices=[n for n in rotations.names()
                             if n != "subspace_gcd"],
                    help="rotation learner (repro.rotations registry spec)")
    args = ap.parse_args()

    cfg = build_cfg(args.items)
    from repro.models import param as plib
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(
        recsys.twotower_init(jax.random.PRNGKey(0), cfg)))
    print(f"model: {n_params/1e6:.1f}M parameters, {args.items} items")
    log = synthetic.ClickLog(0, cfg.item_vocab, dim=32)

    ocfg = opt_lib.OptimizerConfig(
        lr=2e-3, total_steps=args.steps + args.warmup, warmup_steps=20,
        rotation=rotations.RotationConfig.from_spec(args.rotation, lr=2e-3),
    )
    params = recsys.twotower_init(jax.random.PRNGKey(0), cfg)
    state = ts.init_state(jax.random.PRNGKey(1), params, ocfg)

    # resume if a checkpoint exists (fault tolerance demo)
    latest = ckpt.latest_step(args.ckpt_dir)
    start = 0
    if latest is not None:
        state, _ = ckpt.restore(args.ckpt_dir, latest, state)
        state = jax.device_put(state)
        start = latest
        print(f"resumed from step {latest}")

    warm_step = jax.jit(ts.make_train_step(
        lambda p, h, i: recsys.twotower_loss(p, h, i, cfg, use_index=False), ocfg))
    joint_step = jax.jit(ts.make_train_step(
        lambda p, h, i: recsys.twotower_loss(p, h, i, cfg, use_index=True), ocfg))

    t0 = time.time()
    for i in range(start, args.warmup + args.steps):
        hist, pos = log.batch(1000 + i, args.batch, cfg.hist_len)
        if i == args.warmup:
            # OPQ warm start of the index (paper protocol)
            sample, _ = recsys.item_tower(
                state.params, jnp.arange(2048) % cfg.item_vocab, cfg)
            state.params["index"] = il.warm_start(
                jax.random.PRNGKey(2), sample, cfg.index, opq_iters=30)
            print(f"[{i}] OPQ warm start done "
                  f"(distortion seeds the joint phase)")
        step_fn = warm_step if i < args.warmup else joint_step
        state, m = step_fn(state, hist, pos)
        if i % 25 == 0:
            phase = "warmup" if i < args.warmup else "joint"
            print(f"step {i:4d} [{phase}] loss={float(m['loss']):.4f} "
                  f"({(time.time()-t0)*1e3/max(i-start,1):.0f} ms/step)")
        if (i + 1) % 100 == 0:
            ckpt.save_async(args.ckpt_dir, i + 1, state)

    ckpt.wait_pending()
    p_at_k = evaluate(state.params, cfg, log)
    print(f"\nfinal ADC retrieval p@50 = {p_at_k:.4f} "
          f"(rotation learner: {args.rotation})")


if __name__ == "__main__":
    main()
