"""Quickstart: learn a rotation with Givens coordinate descent (paper §3.1).

Generates anisotropic SIFT-like vectors, then compares rotation learners on
fixed embeddings:
  * classic OPQ (SVD Procrustes)     — the baseline GCD replaces
  * GCD-G (greedy, paper Algorithm 1+2)
  * frozen identity rotation         — lower bound

and finishes by serving the GCD-rotated corpus through every backend of
the unified retrieval registry (repro.search): exact brute force, flat
ADC, and probed IVF — one API, three cost/quality points.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import rotations, search
from repro.data import synthetic
from repro.metrics import recall_at_k
from repro.quant import PQConfig, opq


def main():
    key = jax.random.PRNGKey(0)
    X = synthetic.sift_like(key, num=4096, dim=64)
    cfg = PQConfig(num_subspaces=8, num_codewords=32)
    print(f"data: {X.shape}, PQ D={cfg.num_subspaces} K={cfg.num_codewords}")

    R_best = None
    for solver, kw in [
        ("frozen", {}),
        ("procrustes", {}),
        ("gcd_greedy", dict(inner_steps=5, lr=2e-3)),
        ("gcd_steepest", dict(inner_steps=5, lr=2e-3)),
    ]:
        R, cb, trace = opq.alternating_minimization(
            jax.random.PRNGKey(1), X, cfg, iters=25, rotation=solver, **kw
        )
        tr = np.asarray(trace)
        ortho = float(rotations.orthogonality_error(R))
        print(f"{solver:14s} distortion {tr[0]:.4f} → {tr[-1]:.4f}   "
              f"‖RᵀR−I‖={ortho:.2e}")
        if solver == "gcd_greedy":
            R_best = R

    print("\nGCD matches OPQ without a single SVD — and it drops straight "
        "into an SGD loop (see examples/train_twotower.py).")

    # --- serve the learned rotation through the search registry
    Q = synthetic.sift_like(jax.random.PRNGKey(7), 64, 64)
    scfg = search.SearchConfig(num_lists=16, subspaces=cfg.num_subspaces,
                               codewords=cfg.num_codewords, nprobe=4)
    oracle = search.make("exact")
    oracle_state = oracle.build(jax.random.PRNGKey(8), X, R_best, scfg)
    truth = np.asarray(oracle.search(oracle_state, Q, k=10).ids)
    print("\nbackend       recall@10  scanned rows/query")
    for backend in search.names():
        searcher = search.make(backend)
        state = (oracle_state if backend == "exact" else
                 searcher.build(jax.random.PRNGKey(8), X, R_best, scfg))
        res = searcher.search(state, Q, k=10)
        rec = recall_at_k(np.asarray(res.ids), truth)
        print(f"{backend:12s}  {rec:9.3f}  {float(np.mean(np.asarray(res.scanned))):8.0f}")
    print("one Searcher API — exact is the oracle, flat_adc pays only "
          "quantization, ivf adds the probe trade-off (see repro.search).")


if __name__ == "__main__":
    main()
