"""Quickstart: learn a rotation with Givens coordinate descent (paper §3.1).

Generates anisotropic SIFT-like vectors, then compares rotation learners on
fixed embeddings:
  * classic OPQ (SVD Procrustes)     — the baseline GCD replaces
  * GCD-G (greedy, paper Algorithm 1+2)
  * frozen identity rotation         — lower bound

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import givens
from repro.data import synthetic
from repro.quant import PQConfig, opq


def main():
    key = jax.random.PRNGKey(0)
    X = synthetic.sift_like(key, num=4096, dim=64)
    cfg = PQConfig(num_subspaces=8, num_codewords=32)
    print(f"data: {X.shape}, PQ D={cfg.num_subspaces} K={cfg.num_codewords}")

    for solver, kw in [
        ("frozen", {}),
        ("procrustes", {}),
        ("gcd_greedy", dict(inner_steps=5, lr=2e-3)),
        ("gcd_steepest", dict(inner_steps=5, lr=2e-3)),
    ]:
        R, cb, trace = opq.alternating_minimization(
            jax.random.PRNGKey(1), X, cfg, iters=25, rotation=solver, **kw
        )
        tr = np.asarray(trace)
        ortho = float(givens.orthogonality_error(R))
        print(f"{solver:14s} distortion {tr[0]:.4f} → {tr[-1]:.4f}   "
              f"‖RᵀR−I‖={ortho:.2e}")

    print("\nGCD matches OPQ without a single SVD — and it drops straight "
          "into an SGD loop (see examples/train_twotower.py).")


if __name__ == "__main__":
    main()
