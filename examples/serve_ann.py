"""ANN serving example: GCD-learned rotation deployed as a live IVF-PQ index.

The serving path is the paper's T(X) = φ(XR)Rᵀ deployed at production shape
(repro.index):
  * offline: learn (R, codebooks) with GCD, then build an IVF-PQ index —
    k-means coarse lists over XR plus residual PQ codes in a block-aligned
    CSR layout (~16× compression at D=16 uint8 codes on 64-dim f32 vectors,
    before list padding);
  * online: per query batch, probe the top-``nprobe`` lists and scan only
    those (the Pallas ivf_adc kernel's job on TPU) — ~10–100× less scan
    work than the flat ADC path at matched recall;
  * continuously: after each GCD training step, ``refresh_rotation``
    absorbs the rotation delta into centroids+codebooks in O(n²) — the
    index stays servable between training steps with no corpus re-encode.

Run:  PYTHONPATH=src python examples/serve_ann.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import givens
from repro.data import synthetic
from repro.quant import PQConfig, opq
from repro.index import ivf, maintain, search
from repro.metrics import recall_at_k


def main():
    key = jax.random.PRNGKey(0)
    N, dim, D, K, L = 100_000, 64, 16, 256, 256
    corpus = synthetic.sift_like(key, N, dim)
    queries = synthetic.sift_like(jax.random.PRNGKey(1), 256, dim)

    print(f"corpus {N}×{dim} (f32: {N*dim*4/2**20:.0f} MiB)")
    t0 = time.time()
    R, cb, trace = opq.alternating_minimization(
        jax.random.PRNGKey(2), corpus[:8192], PQConfig(D, K), iters=15,
        rotation="gcd_greedy", inner_steps=5, lr=2e-3)
    print(f"rotation learned in {time.time()-t0:.1f}s "
          f"(distortion {float(trace[0]):.3f} → {float(trace[-1]):.3f})")

    # --- build the IVF-PQ index on the learned rotation
    cfg = ivf.IVFPQConfig(num_lists=L, pq=PQConfig(D, K), block_size=128)
    t0 = time.time()
    index = ivf.build(jax.random.PRNGKey(3), corpus, R, cfg, train_size=16384)
    code_mib = index.codes.shape[0] * D / 2**20  # uint8-equivalent payload
    print(f"index built in {time.time()-t0:.1f}s: {L} lists, "
          f"cap {index.capacity} rows, codes ≈{code_mib:.0f} MiB "
          f"({corpus.size*4/(index.capacity*D):.0f}× compression)")

    # --- serve query batches at a few nprobe settings
    exact = np.asarray(jnp.argsort(-(queries @ corpus.T), axis=1)[:, :10])
    max_blocks = index.max_list_blocks()  # hoisted: keep host sync out of loop
    for nprobe in (8, 32):
        res = search.search_fixed(index, queries, nprobe=nprobe, k=10,
                                  max_blocks=max_blocks, use_kernel=False)
        jax.block_until_ready(res.scores)
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(
                search.search_fixed(index, queries, nprobe=nprobe, k=10,
                                    max_blocks=max_blocks,
                                    use_kernel=False).scores)
        dt = (time.time() - t0) / 3
        print(f"nprobe={nprobe:3d}: served 256 queries in {dt*1e3:.1f} ms "
              f"({256/dt:.0f} qps), scanned {float(jnp.mean(res.scanned)):.0f}"
              f"/{index.capacity} rows/query, "
              f"recall@10 vs exact {recall_at_k(np.asarray(res.ids), exact):.3f}")

    # --- keep serving across a GCD training step: refresh, don't rebuild
    def distortion_loss(Rm):
        return index.quantizer.distortion(corpus[:8192] @ Rm)

    G = jax.grad(distortion_loss)(index.R)
    jax.block_until_ready(maintain.subspace_gcd_step(index, G, 2e-3)[0].R)
    t0 = time.time()  # timed second call: refresh cost, not jit compile
    index2, (pi, pj, theta) = maintain.subspace_gcd_step(index, G, 2e-3)
    jax.block_until_ready(index2.R)
    print(f"refresh_rotation after GCD step: {time.time()-t0:.3f}s, "
          f"orthogonality drift {float(givens.orthogonality_error(index2.R)):.2e}, "
          f"code mismatch vs full re-encode "
          f"{float(maintain.refresh_mismatch(index2, corpus))*100:.2f}%")
    res = search.search(index2, queries, nprobe=32, k=10, use_kernel=False)
    print(f"post-refresh recall@10 vs exact: "
          f"{recall_at_k(np.asarray(res.ids), exact):.3f}")


if __name__ == "__main__":
    main()
