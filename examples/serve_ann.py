"""ANN serving example: GCD-learned rotation deployed behind search.Engine.

The serving path is the paper's T(X) = φ(XR)Rᵀ deployed at production shape
through the unified retrieval subsystem (repro.search):
  * offline: learn (R, codebooks) with GCD, then ``search.make("ivf")``
    builds the IVF-PQ index — k-means coarse lists over XR plus residual
    PQ codes in a block-aligned CSR layout;
  * online: ``search.Engine`` serves ragged query batches — each batch is
    bucketized to a padded shape, compiled once per (bucket, k, nprobe),
    and repeated queries reuse their cached ADC LUTs;
  * continuously: after each GCD training step the learner's RotationDelta
    is fed to ``engine.refresh`` — centroids+codebooks absorb it in O(n²)
    and the index stays servable with zero recompiles and no corpus
    re-encode.

Run:  PYTHONPATH=src python examples/serve_ann.py
"""
import time

import jax
import numpy as np

from repro import rotations, search
from repro.data import synthetic
from repro.index import maintain
from repro.metrics import recall_at_k
from repro.quant import PQConfig, opq


def main():
    key = jax.random.PRNGKey(0)
    N, dim, D, K, L = 100_000, 64, 16, 256, 256
    corpus = synthetic.sift_like(key, N, dim)
    queries = synthetic.sift_like(jax.random.PRNGKey(1), 256, dim)

    print(f"corpus {N}×{dim} (f32: {N*dim*4/2**20:.0f} MiB)")
    t0 = time.time()
    R, cb, trace = opq.alternating_minimization(
        jax.random.PRNGKey(2), corpus[:8192], PQConfig(D, K), iters=15,
        rotation="gcd_greedy", inner_steps=5, lr=2e-3)
    print(f"rotation learned in {time.time()-t0:.1f}s "
          f"(distortion {float(trace[0]):.3f} → {float(trace[-1]):.3f})")

    # --- build the IVF backend on the learned rotation
    cfg = search.SearchConfig(num_lists=L, subspaces=D, codewords=K,
                              block_size=128, nprobe=32, train_size=16384)
    searcher = search.make("ivf")
    t0 = time.time()
    state = searcher.build(jax.random.PRNGKey(3), corpus, R, cfg)
    st = searcher.stats(state)
    print(f"index built in {time.time()-t0:.1f}s: {st['num_lists']} lists, "
          f"cap {st['capacity']} rows, codes ≈{st['memory_bytes']/2**20:.0f} MiB "
          f"({st['compression']:.0f}× compression)")

    # --- ground truth through the same registry: the exact backend
    exact = search.make("exact")
    exact_state = exact.build(key, corpus, R, cfg)
    truth = np.asarray(exact.search(exact_state, queries, k=10).ids)

    # --- serve ragged batches through the Engine at a few nprobe settings
    for nprobe in (8, 32):
        engine = search.Engine(searcher, state, k=10, nprobe=nprobe,
                               min_bucket=32)
        all_ids = []
        for lo, hi in ((0, 96), (96, 153), (153, 256), (0, 256)):
            all_ids.append(np.asarray(engine.search(queries[lo:hi]).ids))
        es = engine.stats()
        rec = recall_at_k(np.concatenate(all_ids[:3]), truth)
        print(f"nprobe={nprobe:3d}: {es['requests']} ragged batches "
              f"({es['queries']} queries) -> {es['compiles']} compiles, "
              f"LUT hit rate {es['lut_hit_rate']:.2f}, "
              f"p50 {es['latency_ms_p50']:.1f} ms, scanned "
              f"{es['scanned_rows_mean']:.0f}/{st['capacity']} rows/query, "
              f"recall@10 vs exact {rec:.3f}")

    # --- keep serving across a GCD training step: refresh, don't rebuild
    engine = search.Engine(searcher, state, k=10, nprobe=32, min_bucket=32)
    engine.search(queries)  # warm the executable cache

    def distortion_loss(Rm):
        return state.index.quantizer.distortion(corpus[:8192] @ Rm)

    G = jax.grad(distortion_loss)(state.index.R)
    learner = rotations.make("subspace_gcd", sub=state.index.quantizer.sub)
    _, delta = learner.update(learner.init_from(state.index.R), G, 2e-3,
                              jax.random.PRNGKey(4))
    # warm the refresh jit on a throwaway state: time refresh cost, not compile
    jax.block_until_ready(searcher.refresh(engine.state, delta).index.R)
    t0 = time.time()
    engine.refresh(delta)
    jax.block_until_ready(engine.state.index.R)
    dt = time.time() - t0
    res = engine.search(queries)
    es = engine.stats()
    print(f"engine.refresh after GCD step: {dt:.3f}s, orthogonality drift "
          f"{float(rotations.orthogonality_error(engine.state.index.R)):.2e}, "
          f"code mismatch vs full re-encode "
          f"{float(maintain.refresh_mismatch(engine.state.index, corpus))*100:.2f}%, "
          f"compiles after refresh: {es['compiles']} (unchanged)")
    print(f"post-refresh recall@10 vs exact: "
          f"{recall_at_k(np.asarray(res.ids), truth):.3f}")


if __name__ == "__main__":
    main()
