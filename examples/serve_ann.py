"""ANN serving example: build a PQ index with a GCD-learned rotation and
serve batched maximum-inner-product queries via ADC.

The serving path is exactly the paper's T(X) = φ(XR)Rᵀ deployed as an index:
  * offline: learn (R, codebooks) with GCD, encode the corpus to uint8 codes
    (32× compression at D=8 on 64-dim vectors vs f32);
  * online: per query batch, one LUT build (b·D·K dots) + ADC scan over the
    corpus (the Pallas adc_lookup kernel's job on TPU).

Run:  PYTHONPATH=src python examples/serve_ann.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import opq, pq
from repro.data import synthetic
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)
    N, dim, D, K = 100_000, 64, 16, 256
    corpus = synthetic.sift_like(key, N, dim)
    queries = synthetic.sift_like(jax.random.PRNGKey(1), 256, dim)

    print(f"corpus {N}×{dim} (f32: {N*dim*4/2**20:.0f} MiB)")
    t0 = time.time()
    R, cb, trace = opq.alternating_minimization(
        jax.random.PRNGKey(2), corpus[:8192], pq.PQConfig(D, K), iters=15,
        rotation_solver="gcd_greedy", inner_steps=5, lr=2e-3)
    print(f"index learned in {time.time()-t0:.1f}s "
          f"(distortion {float(trace[0]):.3f} → {float(trace[-1]):.3f})")

    codes = pq.assign(corpus @ R, cb).astype(jnp.uint8)
    print(f"codes: {codes.shape} uint8 ({codes.size/2**20:.0f} MiB — "
          f"{corpus.size*4/codes.size:.0f}× compression)")

    # --- serve a query batch
    @jax.jit
    def serve(q_batch):
        lut = pq.adc_lut(q_batch @ R, cb)          # (b, D, K)
        scores = ops.adc_lookup(lut, codes.astype(jnp.int32), use_kernel=False)
        return jax.lax.top_k(scores, 10)

    scores, top10 = serve(queries)
    jax.block_until_ready(top10)
    t0 = time.time()
    for _ in range(3):
        jax.block_until_ready(serve(queries))
    dt = (time.time() - t0) / 3
    print(f"served 256 queries × {N} items in {dt*1e3:.1f} ms "
          f"({256*N/dt/1e9:.2f} G score/s on CPU)")

    # recall@10 vs exact search
    exact = jnp.argsort(-(queries @ corpus.T), axis=1)[:, :10]
    rec = np.mean([
        len(set(np.asarray(top10[i]).tolist())
            & set(np.asarray(exact[i]).tolist())) / 10
        for i in range(256)
    ])
    print(f"recall@10 vs exact MIPS: {rec:.3f}")


if __name__ == "__main__":
    main()
