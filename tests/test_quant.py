"""Quantizer-conformance suite: every scheme behind the repro.quant protocol
must satisfy the same contract (ISSUE 2).

Parametrized over PQ, depth-2/3 RQ, and the residual quantizer of a built
IVF index. Checks per scheme:
  * encode/decode round trip: shapes, dtype bounds, distortion bounds;
  * ADC-vs-exact score parity through the shared kernel family (jnp oracle
    AND Pallas interpret path);
  * straight-through gradients: identity wrt X, finite, right shape;
  * within-subspace Givens rotation preserves codes (the refresh_rotation
    contract);
plus RQ-specific laws (depth monotonicity, level-major layout) and an
end-to-end depth-2 IVF check (build → search → refresh).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import churn, quant
from repro.core import givens
from repro.data import synthetic
from repro.index import ivf, maintain, search
from repro.training import train_state as ts

DIM, D, K = 32, 4, 16
CFG = quant.PQConfig(D, K)


def _data(seed=0, m=512):
    return synthetic.sift_like(jax.random.PRNGKey(seed), m, DIM)


@pytest.fixture(scope="module")
def quantizers():
    """name -> (quantizer, train data X). All protocol-conformant."""
    X = _data(0)
    pq, _ = quant.PQ.fit(jax.random.PRNGKey(1), X, CFG, iters=8)
    rq2, _ = quant.RQ.fit(jax.random.PRNGKey(1), X, CFG, 2, iters=8)
    rq3, _ = quant.RQ.fit(jax.random.PRNGKey(1), X, CFG, 3, iters=8)
    # the IVF residual quantizer, exactly as a built index carries it
    R = givens.random_rotation(jax.random.PRNGKey(2), DIM)
    index = ivf.build(
        jax.random.PRNGKey(3), X, R,
        ivf.IVFPQConfig(num_lists=8, pq=CFG, block_size=8, depth=2))
    XR = X @ R
    residuals = XR - index.coarse.centroids[index.coarse.assign(XR)]
    return {
        "pq": (pq, X),
        "rq2": (rq2, X),
        "rq3": (rq3, X),
        "ivf_residual": (index.quantizer, residuals),
    }


NAMES = ["pq", "rq2", "rq3", "ivf_residual"]


@pytest.mark.parametrize("name", NAMES)
def test_encode_decode_roundtrip(quantizers, name):
    q, X = quantizers[name]
    codes = q.encode(X)
    assert codes.shape == (X.shape[0], q.code_width)
    assert int(codes.min()) >= 0 and int(codes.max()) < q.num_codewords
    xhat = q.decode(codes)
    assert xhat.shape == X.shape
    # distortion beats the zero-codebook baseline and matches decode error
    d = float(q.distortion(X))
    zero = float(jnp.mean(jnp.sum(jnp.square(X), axis=-1)))
    err = float(jnp.mean(jnp.sum(jnp.square(X - xhat), axis=-1)))
    assert d < zero
    np.testing.assert_allclose(d, err, rtol=1e-5)
    # storage dtype round trip is lossless
    assert np.dtype(q.code_dtype) == (np.uint8 if K <= 256 else np.int32)
    np.testing.assert_array_equal(
        np.asarray(q.decode(codes.astype(q.code_dtype))), np.asarray(xhat))


@pytest.mark.parametrize("name", NAMES)
def test_adc_matches_exact_scores(quantizers, name):
    q, X = quantizers[name]
    codes = q.encode(X[:200])
    Q = _data(7, m=5)
    tables = q.adc_tables(Q)
    assert tables.shape == (5, q.code_width, q.num_codewords)
    want = Q @ q.decode(codes).T
    got_ref = quant.adc_score_tables(tables, codes, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # Pallas member of the kernel family (interpret mode off-TPU)
    got_kernel = quant.adc_score_tables(tables, codes, use_kernel=True)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(got_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", NAMES)
def test_encode_st_gradients(quantizers, name):
    q, X = quantizers[name]
    Xs = X[:64]
    w = jax.random.normal(jax.random.PRNGKey(9), (DIM,))
    # forward = hard quantization
    np.testing.assert_allclose(np.asarray(q.encode_st(Xs)),
                               np.asarray(q.decode(q.encode(Xs))), atol=1e-6)
    g = jax.grad(lambda x: jnp.sum(q.encode_st(x) @ w))(Xs)
    assert g.shape == Xs.shape
    assert np.all(np.isfinite(np.asarray(g)))
    # straight-through: dL/dx == broadcast of w
    np.testing.assert_allclose(np.asarray(g), np.tile(w, (64, 1)), atol=1e-5)


@pytest.mark.parametrize("name", NAMES)
def test_within_subspace_rotation_preserves_codes(quantizers, name):
    """The refresh_rotation contract: a disjoint within-subspace Givens
    product rotates codebooks so that codes of rotated data are unchanged."""
    q, X = quantizers[name]
    sub = q.sub
    # one disjoint pair inside each subspace: (d·sub, d·sub+1)
    pi = jnp.arange(D, dtype=jnp.int32) * sub
    pj = pi + 1
    theta = 0.05 * (1.0 + jnp.arange(D, dtype=jnp.float32))
    delta = givens.apply_pair_rotations(jnp.eye(DIM), pi, pj, theta)
    q_rot = q.rotate(pi, pj, theta)
    codes = np.asarray(q.encode(X))
    codes_rot = np.asarray(q_rot.encode(X @ delta))
    mismatch = np.mean(np.any(codes != codes_rot, axis=-1))
    assert mismatch <= 0.01  # exact up to fp-rounding ties


def test_rq_distortion_monotone_in_depth(quantizers):
    pq, X = quantizers["pq"]
    rq2, _ = quantizers["rq2"]
    rq3, _ = quantizers["rq3"]
    d1 = float(pq.distortion(X))
    d2 = float(rq2.distortion(X))
    d3 = float(rq3.distortion(X))
    assert d2 < d1 and d3 < d2, (d1, d2, d3)


def test_rq_level_major_layout(quantizers):
    """Column l·D+d is level l / subspace d, and decode sums the levels."""
    rq2, X = quantizers["rq2"]
    codes = rq2.encode(X[:50])
    lvl0 = quant.PQ(rq2.codebooks[0])
    np.testing.assert_array_equal(np.asarray(codes[:, :D]),
                                  np.asarray(lvl0.encode(X[:50])))
    dec = lvl0.decode(codes[:, :D]) \
        + quant.PQ(rq2.codebooks[1]).decode(codes[:, D:])
    np.testing.assert_allclose(np.asarray(rq2.decode(codes)),
                               np.asarray(dec), atol=1e-6)


def test_eq1_loss_trains_through_any_quantizer(quantizers):
    """training.train_state.eq1_loss: end-to-end Eq.(1) via encode_st yields
    finite grads for R, codebooks, and the input batch."""
    rq2, X = quantizers["rq2"]
    R0 = givens.random_rotation(jax.random.PRNGKey(11), DIM)
    Xs = X[:32]

    def loss(R, q, x):
        return ts.eq1_loss(q, R, x, lambda tx: -jnp.mean(jnp.sum(tx * x, -1)),
                           distortion_weight=0.5)

    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(R0, rq2, Xs)
    assert np.isfinite(float(val))
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g)))
    # codebook grads come from the distortion term (nonzero somewhere)
    assert float(jnp.max(jnp.abs(grads[1].codebooks))) > 0


# ---------------------------------------------------------------------------
# Depth-2 residual IVF index end to end (build → search → refresh)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rq_index():
    X = synthetic.sift_like(jax.random.PRNGKey(20), 2000, 16)
    R = givens.random_rotation(jax.random.PRNGKey(21), 16)
    cfg = ivf.IVFPQConfig(num_lists=8, pq=quant.PQConfig(4, 16),
                          block_size=8, depth=2)
    index = ivf.build(jax.random.PRNGKey(22), X, R, cfg)
    Q = synthetic.sift_like(jax.random.PRNGKey(23), 16, 16)
    return index, X, Q


def test_rq_index_full_probe_matches_flat(rq_index):
    index, _, Q = rq_index
    assert index.codes.shape[1] == 8  # M·D = 2·4 code columns
    res = search.search(index, Q, nprobe=index.num_lists, k=10,
                        use_kernel=False)
    flat_scores, flat_ids = search.flat_adc_scores(index, Q)
    want_scores, pos = jax.lax.top_k(flat_scores, 10)
    np.testing.assert_allclose(np.asarray(res.scores),
                               np.asarray(want_scores), rtol=1e-5, atol=1e-5)
    agree = np.mean(np.asarray(res.ids) == np.asarray(flat_ids[pos]))
    assert agree >= 0.95  # ids agree except on exact score ties


def test_rq_index_kernel_matches_ref(rq_index):
    index, _, Q = rq_index
    a = search.search(index, Q, nprobe=3, k=5, use_kernel=True)
    b = search.search(index, Q, nprobe=3, k=5, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_rq_index_refresh_rotation(rq_index):
    index, X, Q = rq_index
    G = jax.random.normal(jax.random.PRNGKey(24), (16, 16))
    refreshed, (pi, pj, theta) = maintain.subspace_gcd_step(index, G, 2e-3)
    assert float(jnp.max(jnp.abs(refreshed.R - index.R))) > 0
    assert float(givens.orthogonality_error(refreshed.R)) < 1e-4
    # both RQ levels rotated; codes survive a subspace step (≤1% fp ties)
    assert refreshed.quantizer.codebooks.shape == index.quantizer.codebooks.shape
    mismatch = float(maintain.refresh_mismatch(refreshed, X))
    assert mismatch <= 0.01
    # scores are rotation-invariant inner products
    a = search.search(index, Q, nprobe=index.num_lists, k=10, use_kernel=False)
    b = search.search(refreshed, Q, nprobe=index.num_lists, k=10,
                      use_kernel=False)
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=1e-4, atol=1e-4)


def test_rq_index_add_remove(rq_index):
    index, _, _ = rq_index
    idx2 = churn.tombstone_index(index, jnp.arange(40, dtype=jnp.int32))
    Xn = synthetic.sift_like(jax.random.PRNGKey(25), 30, 16)
    idx3 = churn.ingest_index(idx2, Xn,
                              jnp.arange(2000, 2030, dtype=jnp.int32))
    assert int(idx3.num_items()) == 2000 - 40 + 30
    assert idx3.codes.shape[1] == index.codes.shape[1]


def test_grouped_adc_batch_kernel_parity():
    """The KV-cache member of the kernel family, multi-level shapes included."""
    from repro.kernels import ops, ref
    for Dp in (4, 8):  # PQ-width and RQ-2-width columns
        lut = jax.random.normal(jax.random.PRNGKey(Dp), (3, 2, Dp, K))
        codes = jax.random.randint(jax.random.PRNGKey(Dp + 1), (3, 40, Dp),
                                   0, K)
        got = ops.adc_batch(lut, codes, use_kernel=True)
        want = ref.adc_batch_ref(lut, codes)
        assert got.shape == (3, 2, 40)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_quantizers_are_jit_traceable_pytrees(quantizers):
    for name in NAMES:
        q, X = quantizers[name]
        leaves, treedef = jax.tree_util.tree_flatten(q)
        assert all(hasattr(leaf, "shape") for leaf in leaves)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert type(rebuilt) is type(q)

        @jax.jit
        def enc(qz, x):
            return qz.encode(x)

        np.testing.assert_array_equal(np.asarray(enc(q, X[:8])),
                                      np.asarray(q.encode(X[:8])))
        assert isinstance(q, quant.Quantizer)
