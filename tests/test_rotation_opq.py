"""Rotation learning: GCD updates, Cayley, OPQ alternating minimization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cayley, givens, opq, pq, rotation
from repro.data import synthetic

# this module deliberately exercises the deprecated core shims; the
# explicit warning test below still sees them (pytest.warns bypasses filters)
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _convex_loss(key, n, m=64):
    X = jax.random.normal(key, (m, n))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    return lambda R: jnp.mean((X @ R) @ w)


@pytest.mark.parametrize("method", ["random", "greedy", "steepest"])
def test_gcd_descends_convex_loss(method):
    n = 16
    loss = _convex_loss(jax.random.PRNGKey(0), n)
    st = rotation.init(n)
    vals = [float(loss(st.R))]
    for t in range(30):
        G = jax.grad(loss)(st.R)
        st = rotation.update(st, G, 0.05, jax.random.PRNGKey(t), method=method)
        vals.append(float(loss(st.R)))
    assert vals[-1] < vals[0]
    assert float(givens.orthogonality_error(st.R)) < 1e-4


def test_gcd_greedy_descends_faster_than_random():
    """Paper: greedy picks steeper directions → faster early descent."""
    n = 32
    loss = _convex_loss(jax.random.PRNGKey(1), n)

    def run(method, steps=10):
        st = rotation.init(n)
        for t in range(steps):
            G = jax.grad(loss)(st.R)
            st = rotation.update(st, G, 0.05, jax.random.PRNGKey(100 + t),
                                 method=method)
        return float(loss(st.R))

    assert run("greedy") <= run("random") + 1e-4


@pytest.mark.parametrize("precond", ["adagrad", "adam"])
def test_gcd_preconditioners_run_and_descend(precond):
    n = 12
    loss = _convex_loss(jax.random.PRNGKey(2), n)
    st = rotation.init(n)
    l0 = float(loss(st.R))
    for t in range(25):
        G = jax.grad(loss)(st.R)
        st = rotation.update(st, G, 0.05, jax.random.PRNGKey(t),
                             method="greedy", preconditioner=precond)
    assert float(loss(st.R)) < l0
    assert float(givens.orthogonality_error(st.R)) < 1e-4


def test_orthogonality_exact_over_many_steps():
    """The paper's selling point: NO projection needed, R stays on SO(n)."""
    n = 24
    loss = _convex_loss(jax.random.PRNGKey(3), n)
    st = rotation.init(n)
    for t in range(200):
        G = jax.grad(loss)(st.R)
        st = rotation.update(st, G, 0.02, jax.random.PRNGKey(t), method="random")
    assert float(givens.orthogonality_error(st.R)) < 1e-3
    assert np.isclose(float(jnp.linalg.det(st.R)), 1.0, atol=1e-3)


def test_cayley_roundtrip_and_orthogonality():
    n = 16
    p = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (n, n))
    R = cayley.cayley(p)
    assert float(givens.orthogonality_error(R)) < 1e-4
    assert np.isclose(float(jnp.linalg.det(R)), 1.0, atol=1e-4)
    p2 = cayley.inverse_cayley(R)
    np.testing.assert_allclose(np.asarray(cayley.cayley(p2)), np.asarray(R),
                               atol=1e-4)


def test_procrustes_is_optimal():
    """SVD solve beats any Givens perturbation of itself on ‖XR−Y‖."""
    key = jax.random.PRNGKey(5)
    X = jax.random.normal(key, (64, 12))
    Y = jax.random.normal(jax.random.fold_in(key, 1), (64, 12))
    R = opq.procrustes_rotation(X, Y)

    def obj(Rm):
        return float(jnp.sum((X @ Rm - Y) ** 2))

    base = obj(R)
    for seed in range(5):
        rng = np.random.RandomState(seed)
        i, j = rng.choice(12, 2, replace=False)
        Rp = givens.apply_pair_rotations(
            R, jnp.array([i]), jnp.array([j]), jnp.array([0.05]))
        assert obj(Rp) >= base - 1e-4


def test_opq_gcd_converges_close_to_svd():
    """Fig 2a headline claim at test size."""
    X = synthetic.sift_like(jax.random.PRNGKey(6), 512, 32, num_clusters=8)
    cfg = pq.PQConfig(4, 8)
    _, _, tr_svd = opq.alternating_minimization(
        jax.random.PRNGKey(7), X, cfg, iters=12, rotation_solver="svd")
    _, _, tr_gcd = opq.alternating_minimization(
        jax.random.PRNGKey(7), X, cfg, iters=12, rotation_solver="gcd_greedy",
        inner_steps=5, lr=1e-2)  # swept lr for this n (EXPERIMENTS.md note)
    _, _, tr_frozen = opq.alternating_minimization(
        jax.random.PRNGKey(7), X, cfg, iters=12, rotation_solver="frozen")
    assert float(tr_gcd[-1]) < float(tr_frozen[-1])
    # GCD closes most of the frozen→SVD gap in only 12×5 tiny steps
    gap_closed = (float(tr_frozen[-1]) - float(tr_gcd[-1])) / (
        float(tr_frozen[-1]) - float(tr_svd[-1]))
    assert gap_closed > 0.6, gap_closed


def test_core_shims_emit_deprecation_warning():
    """ISSUE 4 satellite: the pre-registry core shims must announce their
    replacement (repro.rotations) on every entry point."""
    with pytest.warns(DeprecationWarning, match="repro.rotations"):
        rotation.init(8)
    with pytest.warns(DeprecationWarning, match="repro.rotations"):
        rotation.init_from(jnp.eye(8))
    with pytest.warns(DeprecationWarning, match="repro.rotations"):
        st = rotation.init(8)
        rotation.update(st, jnp.zeros((8, 8)), 0.01, jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning, match="repro.rotations"):
        _ = rotation.GCD
    with pytest.warns(DeprecationWarning, match="repro.rotations"):
        _ = cayley.cayley
    with pytest.warns(DeprecationWarning, match="repro.rotations"):
        _ = cayley.CayleySGD
