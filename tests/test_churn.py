"""repro.churn — streaming ingest, tombstone deletes, background compaction.

Coverage demanded by ISSUE 8:
  * staged adds are served by the very next query (flat side pass merged
    into every backend's top-k) and flush/compact preserve scores exactly;
  * hypothesis-driven interleavings of add/remove/refresh/flush/compact
    hold score parity against a from-scratch rebuild of the live rows and
    recall against the exact oracle after EVERY mutation sequence;
  * ChurnController sequences stage→flush→compact between Engine batches
    with zero recompiles and zero LUT invalidations in steady state;
  * maintain.add/remove are DeprecationWarning shims over the churn
    primitives (same results);
  * Engine.stats()["churn"] reports the controller's counters/gauges with
    PR 6's window-scoping conventions.
"""
import dataclasses
import time

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro import churn, rotations, search
from repro.data import synthetic
from repro.index import ivf as index_ivf
from repro.index import maintain

DIM, SUB, K, L, BS = 16, 4, 16, 8, 8
N, B = 1200, 8
CFG = search.SearchConfig(num_lists=L, subspaces=SUB, codewords=K,
                          block_size=BS, nprobe=L, tile_rows=256)


@pytest.fixture(scope="module")
def data():
    X = synthetic.sift_like(jax.random.PRNGKey(0), N, DIM)
    R = rotations.random_rotation(jax.random.PRNGKey(1), DIM)
    Q = synthetic.sift_like(jax.random.PRNGKey(2), B, DIM)
    return np.asarray(X), np.asarray(R), np.asarray(Q)


def _fresh_ivf(data, **attach_kw):
    X, R, _ = data
    index = index_ivf.build(jax.random.PRNGKey(3), jnp.asarray(X),
                            jnp.asarray(R), CFG.ivf_config(), train_size=512)
    return search.IVF.attach(index, nprobe=L, **attach_kw)


def _delta(R, key=0, lr=1e-3):
    G = jax.random.normal(jax.random.PRNGKey(100 + key), (DIM, DIM))
    learner = rotations.make("subspace_gcd", sub=DIM // SUB)
    _, delta = learner.update(learner.init_from(jnp.asarray(R)), G, lr,
                              jax.random.PRNGKey(key))
    return delta


def _result_map(res):
    """Per-query {id: score} dicts — packing-order-independent comparison."""
    ids = np.asarray(res.ids)
    scores = np.asarray(res.scores)
    return [{int(i): float(s) for i, s in zip(row_i, row_s) if i >= 0}
            for row_i, row_s in zip(ids, scores)]


def _assert_same_results(a, b, rtol=1e-5):
    for da, db in zip(_result_map(a), _result_map(b)):
        assert set(da) == set(db)
        for i in da:
            np.testing.assert_allclose(da[i], db[i], rtol=rtol, atol=1e-5)


# ---------------------------------------------------------------------------
# Staging buffer: adds visible to the next query, flush/compact exact
# ---------------------------------------------------------------------------


def test_staged_adds_served_immediately(data):
    X, R, Q = data
    state = churn.with_staging(_fresh_ivf(data), 64)
    Xn = np.asarray(synthetic.sift_like(jax.random.PRNGKey(7), 20, DIM))
    state = churn.stage(state, jnp.asarray(Xn), np.arange(N, N + 20))
    assert churn.staged_rows(state) == 20
    searcher = search.make("ivf")
    # the staged side pass serves EXACTLY what an eager pack would: compare
    # against churn.ingest_index folding the same rows into the CSR
    res = searcher.search(state, jnp.asarray(Xn), k=5, nprobe=L)
    eager = search.IVF.attach(
        churn.ingest_index(state.index, jnp.asarray(Xn),
                           jnp.arange(N, N + 20, dtype=jnp.int32)),
        nprobe=L)
    want = searcher.search(eager, jnp.asarray(Xn), k=5, nprobe=L)
    _assert_same_results(res, want)
    # staged self-recall == eager-pack self-recall (ADC error is the
    # quantizer's, never the staging lane's)
    res10 = searcher.search(state, jnp.asarray(Xn), k=10, nprobe=L)
    want10 = searcher.search(eager, jnp.asarray(Xn), k=10, nprobe=L)
    hits = [N + i in np.asarray(res10.ids)[i] for i in range(20)]
    want_hits = [N + i in np.asarray(want10.ids)[i] for i in range(20)]
    assert hits == want_hits
    # flat_adc serves the same staged rows through the same state
    res_flat = search.make("flat_adc").search(state, jnp.asarray(Xn), k=5)
    _assert_same_results(res, res_flat)

    # flush folds them into CSR holes without moving any score
    before = searcher.search(state, jnp.asarray(Q), k=10, nprobe=L)
    state2, moved = churn.flush(state)
    after = searcher.search(state2, jnp.asarray(Q), k=10, nprobe=L)
    assert moved + churn.staged_rows(state2) == 20
    _assert_same_results(before, after)


def test_with_staging_rejects_exact_states(data):
    X, R, _ = data
    ex = search.make("exact").build(jax.random.PRNGKey(3), jnp.asarray(X),
                                    jnp.asarray(R), CFG)
    with pytest.raises(TypeError, match="append buffers"):
        churn.with_staging(ex, 64)


def test_stage_overflow_raises(data):
    state = churn.with_staging(_fresh_ivf(data), 8)
    rng = np.random.default_rng(8)
    Xn = rng.standard_normal((9, DIM)).astype(np.float32)
    with pytest.raises(ValueError, match="staging buffer full"):
        churn.stage(state, jnp.asarray(Xn), np.arange(N, N + 9))
    bare = _fresh_ivf(data)
    with pytest.raises(ValueError, match="no staging buffer"):
        churn.stage(bare, jnp.asarray(Xn[:1]), np.array([N]))


def test_compact_is_bit_identical_to_fresh_rebuild(data):
    """compact() carries codes — a from-scratch ivf.pack of the same live
    rows (same quantizers) must serve the exact same {id: score} sets."""
    X, R, Q = data
    state = churn.with_staging(_fresh_ivf(data), 64)
    rng = np.random.default_rng(9)
    Xn = rng.standard_normal((30, DIM)).astype(np.float32)
    state = churn.stage(state, jnp.asarray(Xn), np.arange(N, N + 30))
    state = churn.tombstone(state, np.arange(0, 300))
    searcher = search.make("ivf")
    before = searcher.search(state, jnp.asarray(Q), k=10, nprobe=L)

    compacted = churn.compact(state)
    assert churn.staged_rows(compacted) == 0          # staged rows absorbed
    after = searcher.search(compacted, jnp.asarray(Q), k=10, nprobe=L)
    _assert_same_results(before, after)
    # shape discipline: steady-state compaction preserved every shape
    assert compacted.index.capacity == state.index.capacity
    assert compacted.max_blocks == state.max_blocks

    # fresh rebuild of the same live rows under the same quantizers
    idx = compacted.index
    live_X = np.concatenate([X[300:], Xn])
    live_ids = np.concatenate([np.arange(300, N), np.arange(N, N + 30)])
    XR = jnp.asarray(live_X) @ idx.R
    list_ids, codes = index_ivf.encode(XR, idx.coarse, idx.quantizer)
    rebuilt = index_ivf.pack(idx.R, idx.coarse, idx.quantizer, codes,
                             list_ids, live_ids.astype(np.int32),
                             block_size=BS)
    want = search.make("ivf").search(
        search.IVF.attach(rebuilt, nprobe=L), jnp.asarray(Q), k=10, nprobe=L)
    _assert_same_results(after, want)


# ---------------------------------------------------------------------------
# Hypothesis: interleaved mutations vs the exact oracle + fresh rebuild
# ---------------------------------------------------------------------------


@given(seq=st.lists(st.sampled_from(
    ["add", "remove", "refresh", "flush", "compact"]),
    min_size=1, max_size=7), seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=10)
def test_interleaved_mutations_hold_parity(seq, seed):
    """Any interleaving of add/remove/refresh/flush/compact leaves the
    churn state serving the same {id: score} sets as a from-scratch pack
    of its live rows, and recall@10 ≥ the fresh rebuild's vs brute force
    (the staged/flushed/compacted lanes never lose a live row)."""
    rng = np.random.default_rng(seed)
    Xh = rng.standard_normal((400, DIM)).astype(np.float32)
    R = np.asarray(rotations.random_rotation(jax.random.PRNGKey(1), DIM))
    Q = rng.standard_normal((4, DIM)).astype(np.float32)
    cfg = CFG.ivf_config()
    index = index_ivf.build(jax.random.PRNGKey(3), jnp.asarray(Xh),
                            jnp.asarray(R), cfg, train_size=256)
    searcher = search.make("ivf")
    state = churn.with_staging(search.IVF.attach(index, nprobe=L), 64)

    vecs = {i: Xh[i] for i in range(400)}     # the live-set model
    next_id = 400
    for op in seq:
        if op == "add":
            m = int(rng.integers(1, 12))
            Xn = rng.standard_normal((m, DIM)).astype(np.float32)
            ids = np.arange(next_id, next_id + m)
            if churn.free_slots(state) < m:
                state = churn.compact(state)
            state = churn.stage(state, jnp.asarray(Xn), ids)
            vecs.update({int(i): x for i, x in zip(ids, Xn)})
            next_id += m
        elif op == "remove" and len(vecs) > 20:
            dead = rng.choice(sorted(vecs), size=10, replace=False)
            state = churn.tombstone(state, dead.astype(np.int32))
            for i in dead:
                vecs.pop(int(i))
        elif op == "refresh":
            state = searcher.refresh(state, _delta(R, key=len(vecs)))
        elif op == "flush":
            state, _ = churn.flush(state)
        elif op == "compact":
            state = churn.compact(state)

    got = searcher.search(state, jnp.asarray(Q), k=10, nprobe=L)
    ids = np.asarray(got.ids)
    assert set(ids[ids >= 0].ravel().tolist()) <= set(vecs)

    # fresh rebuild of the live rows under the state's CURRENT quantizers
    idx = state.index
    live_ids = np.asarray(sorted(vecs), dtype=np.int32)
    live_X = np.stack([vecs[int(i)] for i in live_ids])
    XR = jnp.asarray(live_X) @ idx.R
    list_ids, codes = index_ivf.encode(XR, idx.coarse, idx.quantizer)
    rebuilt = index_ivf.pack(idx.R, idx.coarse, idx.quantizer, codes,
                             list_ids, live_ids, block_size=BS)
    want = searcher.search(search.IVF.attach(rebuilt, nprobe=L),
                           jnp.asarray(Q), k=10, nprobe=L)
    _assert_same_results(got, want, rtol=1e-4)


# ---------------------------------------------------------------------------
# ChurnController behind the Engine
# ---------------------------------------------------------------------------


def test_controller_zero_recompiles_in_steady_state(data):
    X, R, Q = data
    state = _fresh_ivf(data, fused_refresh=True)
    engine = search.Engine(search.make("ivf"), state, k=10, nprobe=L,
                           min_bucket=4)
    ctl = churn.ChurnController(engine, staging_rows=64, flush_at=0.5,
                                compact_at=0.1)
    engine.search(jnp.asarray(Q))             # compile once, WITH staging
    compiles = engine.stats()["compiles"]

    rng = np.random.default_rng(11)
    live = list(range(N))
    next_id = N
    for step in range(10):
        add = rng.standard_normal((12, DIM)).astype(np.float32)
        add_ids = np.arange(next_id, next_id + 12)
        next_id += 12
        dead = rng.choice(live, size=12, replace=False)
        live = [i for i in live if i not in set(dead.tolist())]
        live += add_ids.tolist()
        ctl.step(add=jnp.asarray(add), add_ids=add_ids, remove_ids=dead)
        engine.refresh(_delta(R, key=step))   # train-while-churning
        res = engine.search(jnp.asarray(Q))
        ids = np.asarray(res.ids)
        assert set(ids[ids >= 0].ravel().tolist()) <= set(live)

    st_ = engine.stats()
    assert st_["compiles"] == compiles         # ZERO recompiles under churn
    assert st_["lut_invalidations"] == 0       # fused refresh kept the LUTs
    ch = st_["churn"]
    assert ch["staged"] == 120 and ch["tombstoned"] == 120
    assert ch["grows"] == 0
    assert ch["flushes"] >= 1 and ch["compactions"] >= 1
    assert ch["flush_ms_p95"] >= 0.0
    assert ch["window"]["capacity"] == engine.history
    # the side pass keeps the scan-work metric honest: staged rows counted
    assert int(np.asarray(res.scanned)[0]) >= churn.staged_rows(ctl.state)


def test_controller_grows_when_corpus_grows(data):
    """Genuine growth (adds outpace deletes past capacity) recompiles ONCE
    and is counted — it is not steady-state churn."""
    X, R, Q = data
    engine = search.Engine(search.make("ivf"), _fresh_ivf(data), k=10,
                           nprobe=L, min_bucket=4)
    ctl = churn.ChurnController(engine, staging_rows=32, flush_at=0.25,
                                compact_at=0.05)
    engine.search(jnp.asarray(Q))
    rng = np.random.default_rng(12)
    next_id = N
    for _ in range(12):
        add = rng.standard_normal((24, DIM)).astype(np.float32)
        ctl.step(add=jnp.asarray(add),
                 add_ids=np.arange(next_id, next_id + 24))
        next_id += 24
    assert churn.live_rows(ctl.state) == N + 12 * 24
    assert engine.stats()["churn"]["grows"] >= 1


def test_engine_stats_churn_block_schema(data):
    """The churn block is always present (stable dashboard schema) and
    all-zero without a controller."""
    engine = search.Engine(search.make("ivf"), _fresh_ivf(data), k=10,
                           nprobe=L)
    ch = engine.stats()["churn"]
    for key in ("staged", "flushed", "tombstoned", "flushes", "compactions",
                "rebalances", "grows"):
        assert ch[key] == 0
    assert ch["staged_rows"] == 0 and ch["tombstoned_rows"] == 0
    assert ch["window"]["scope"] == "flush_ms aggregates"


# ---------------------------------------------------------------------------
# maintain.add/remove deprecation shims
# ---------------------------------------------------------------------------


def test_maintain_shims_warn_and_match(data):
    X, R, _ = data
    index = _fresh_ivf(data).index
    rng = np.random.default_rng(13)
    Xn = rng.standard_normal((16, DIM)).astype(np.float32)

    with pytest.warns(DeprecationWarning, match="churn.tombstone"):
        via_shim = maintain.remove(index, jnp.arange(50, dtype=jnp.int32))
    direct = churn.tombstone_index(index, jnp.arange(50, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(via_shim.ids),
                                  np.asarray(direct.ids))

    with pytest.warns(DeprecationWarning, match="churn.ingest_index"):
        added = maintain.add(via_shim, jnp.asarray(Xn),
                             jnp.arange(N, N + 16, dtype=jnp.int32))
    added_direct = churn.ingest_index(direct, jnp.asarray(Xn),
                                      jnp.arange(N, N + 16, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(added.ids),
                                  np.asarray(added_direct.ids))
    np.testing.assert_array_equal(np.asarray(added.codes),
                                  np.asarray(added_direct.codes))
    assert int(added.num_items()) == N - 50 + 16


# ---------------------------------------------------------------------------
# Sharded states (S = 1 in-process; multi-device churn parity runs in the
# churn benchmark's forced-host-device subprocess and in CI churn-smoke)
# ---------------------------------------------------------------------------


def test_sharded_churn_roundtrip(data):
    from repro.launch.mesh import make_data_mesh

    X, R, Q = data
    mesh = make_data_mesh()
    index = _fresh_ivf(data).index
    searcher = search.make("ivf_sharded")
    state = searcher.attach(index, mesh=mesh, nprobe=L)
    state = churn.with_staging(state, 32)
    Xn = np.asarray(synthetic.sift_like(jax.random.PRNGKey(14), 10, DIM))
    state = churn.stage(state, jnp.asarray(Xn), np.arange(N, N + 10))
    res = searcher.search(state, jnp.asarray(Xn), k=10, nprobe=L)
    staged_set = np.arange(N, N + 10)
    assert np.isin(staged_set, np.asarray(res.ids)).mean() >= 0.5

    state = churn.tombstone(state, np.arange(0, 100))
    before = searcher.search(state, jnp.asarray(Q), k=10, nprobe=L)
    assert not np.any(np.isin(np.asarray(before.ids), np.arange(100)))
    state, _ = churn.flush(state)
    state = churn.compact(state)
    after = searcher.search(state, jnp.asarray(Q), k=10, nprobe=L)
    _assert_same_results(before, after)
    state = churn.shard_rebalance(state)
    balanced = searcher.search(state, jnp.asarray(Q), k=10, nprobe=L)
    _assert_same_results(before, balanced)


def test_exact_stream_tombstone_updates_rows(data):
    X, R, Q = data
    state = search.make("exact_stream").build(
        jax.random.PRNGKey(3), jnp.asarray(X), jnp.asarray(R), CFG)
    rows_before = state.rows
    state2 = churn.tombstone(state, np.arange(0, 200))
    assert state2.rows == rows_before - 200
    assert dataclasses.is_dataclass(state2)
    res = search.make("exact_stream").search(state2, jnp.asarray(Q), k=10)
    assert not np.any(np.isin(np.asarray(res.ids), np.arange(200)))


# ---------------------------------------------------------------------------
# PR 10: background compaction + staleness re-encode
# ---------------------------------------------------------------------------


def test_background_compact_bit_identical_to_foreground(data):
    """A quiescent background pass must be BIT-identical to foreground
    ``churn.compact`` — same scores, same ids (the acceptance pin: moving
    the pack off-thread changes scheduling, never results)."""
    X, R, Q = data
    state = churn.with_staging(_fresh_ivf(data), 64)
    state = churn.tombstone(state, np.arange(0, N, 7, dtype=np.int32))
    eng = search.Engine(search.make("ivf"), state, k=10, nprobe=L)
    comp = churn.BackgroundCompactor(eng)
    fg = churn.compact(eng.state, include_staged=False)
    assert comp.submit()
    comp.join()
    assert comp.poll()
    searcher = search.make("ivf")
    res_bg = searcher.search(eng.state, jnp.asarray(Q), k=10, nprobe=L)
    res_fg = searcher.search(fg, jnp.asarray(Q), k=10, nprobe=L)
    assert bool(jnp.array_equal(res_bg.scores, res_fg.scores))
    assert bool(jnp.array_equal(res_bg.ids, res_fg.ids))
    comp.close()


def test_background_compactor_replays_mutations_since_submit(data):
    """Deletes and stages landing while the worker packs are not lost:
    deletes are replayed onto the compacted result at swap time, staged
    rows ride the CURRENT state's buffer (the worker packs CSR only)."""
    X, R, Q = data
    eng = search.Engine(search.make("ivf"),
                        churn.with_staging(_fresh_ivf(data), 64),
                        k=10, nprobe=L)
    comp = churn.BackgroundCompactor(eng, worker_delay_s=0.3)
    assert comp.submit()
    dead = np.arange(0, 40, dtype=np.int32)
    new_ids = np.asarray([N + 1, N + 2, N + 3, N + 4], dtype=np.int32)
    eng.state = churn.tombstone(eng.state, dead)
    eng.state = churn.stage(eng.state, jnp.asarray(X[:4]), new_ids)
    comp.join()
    assert comp.poll()
    assert eng.stats()["churn"]["bg_discarded"] == 0
    res = search.make("ivf").search(eng.state, jnp.asarray(Q), k=10,
                                    nprobe=L)
    served = set(np.asarray(res.ids).ravel().tolist())
    assert not served & set(dead.tolist())
    assert churn.staged_rows(eng.state) == 4   # the in-flight adds survived
    comp.close()


def test_background_compactor_discards_on_csr_move(data):
    """A flush while the worker packs moves the CSR — the stale result must
    be discarded at poll, never swapped in."""
    X, R, Q = data
    eng = search.Engine(search.make("ivf"),
                        churn.with_staging(_fresh_ivf(data), 64),
                        k=10, nprobe=L)
    eng.state = churn.tombstone(eng.state, np.arange(0, 64, dtype=np.int32))
    eng.state = churn.stage(eng.state, jnp.asarray(X[:4]),
                            np.asarray([N + 1, N + 2, N + 3, N + 4],
                                       dtype=np.int32))
    comp = churn.BackgroundCompactor(eng, worker_delay_s=0.3)
    assert comp.submit()
    eng.state, _ = churn.flush(eng.state)       # CSR holes refilled: moved
    comp.join()
    assert not comp.poll()
    st = eng.stats()["churn"]
    assert st["bg_discarded"] == 1 and st["bg_compactions"] == 0
    comp.close()


def test_background_compactor_poll_stress_no_double_swap(data):
    """Racing pollers against a deliberately slow worker, across rounds:
    exactly one swap per submit, one submit in flight at a time, no torn
    counters (Engine.stats stays readable throughout)."""
    import threading
    eng = search.Engine(search.make("ivf"),
                        churn.with_staging(_fresh_ivf(data), 64),
                        k=10, nprobe=L)
    comp = churn.BackgroundCompactor(eng, worker_delay_s=0.15)
    rounds = 3
    for r in range(rounds):
        eng.state = churn.tombstone(
            eng.state, np.arange(r * 20, r * 20 + 20, dtype=np.int32))
        assert comp.submit()
        assert not comp.submit()       # single pass in flight
        swaps: list[int] = []
        stop = threading.Event()

        def poller():
            while not stop.is_set():
                if comp.poll():
                    swaps.append(1)
                eng.stats()            # torn-stats probe
                # yield: a zero-sleep spin convoys the GIL/lock handoff
                # and can starve the worker indefinitely (unfair locks)
                time.sleep(0.002)

        threads = [threading.Thread(target=poller) for _ in range(4)]
        for t in threads:
            t.start()
        comp.join()
        deadline = time.time() + 10.0
        while not swaps and time.time() < deadline:
            time.sleep(0.005)
        stop.set()
        for t in threads:
            t.join()
        assert sum(swaps) == 1, swaps  # no double-swap, no lost swap
    st = eng.stats()["churn"]
    assert st["bg_compactions"] == rounds
    assert st["bg_discarded"] == 0
    assert churn.live_rows(eng.state) == N - rounds * 20
    comp.close()


def test_staleness_reencode_fixes_drifted_rows(data):
    """Cross-subspace refresh deltas drift stored codes off a fresh encode
    (``maintain.drifted_ids`` is the oracle); a compaction pass that
    re-encodes every stale row must drive the drifted set to empty."""
    X, R, Q = data
    eng = search.Engine(search.make("ivf"),
                        churn.with_staging(_fresh_ivf(data), 64),
                        k=10, nprobe=L)
    tracker = churn.StalenessTracker()
    tracker.record(np.arange(N))
    comp = churn.BackgroundCompactor(
        eng, tracker=tracker,
        reencode_fn=lambda ids: np.stack([X[int(i)] for i in ids]),
        reencode_rows=N)
    learner = rotations.make("gcd", method="greedy")
    for t in range(4):
        st = learner.init_from(jnp.asarray(eng.state.index.R,
                                           dtype=jnp.float32))
        G = jax.random.normal(jax.random.PRNGKey(t), (DIM, DIM))
        _, delta = learner.update(st, G, 5e-2, jax.random.PRNGKey(t))
        eng.refresh(delta)
        tracker.bump()
    assert maintain.drifted_ids(eng.state.index, jnp.asarray(X)).size > 0
    assert comp.submit()
    comp.join()
    assert comp.poll()
    assert maintain.drifted_ids(eng.state.index, jnp.asarray(X)).size == 0
    assert eng.stats()["churn"]["reencoded"] == N
    # every row was re-encoded at the current epoch: staleness repaid
    assert tracker.stalest(N).size == 0
    comp.close()


def test_staleness_tracker_orders_by_epoch():
    """stalest() returns the oldest-encoded rows first, deterministically,
    and never selects rows encoded at the current epoch."""
    tr = churn.StalenessTracker()
    tr.record([1, 2, 3])          # epoch 0
    tr.bump()
    tr.record([4, 5])             # epoch 1
    tr.bump()                     # now epoch 2
    assert list(tr.stalest(2)) == [1, 2]
    assert list(tr.stalest(10)) == [1, 2, 3, 4, 5]
    tr.record([1, 2, 3, 4, 5])    # all fresh at epoch 2
    assert tr.stalest(10).size == 0
    tr.forget([5])
    assert len(tr) == 4
    assert {int(k) for k in tr.histogram()} == {0}
