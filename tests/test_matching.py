"""GCD pair-selection: greedy vs exact oracle, disjointness properties."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import matching


def _rand_antisym(rng, n):
    A = rng.randn(n, n)
    return A - A.T


@given(n=st.sampled_from([4, 6, 8, 10, 12]), seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=20)
def test_greedy_is_disjoint_and_complete(n, seed):
    A = _rand_antisym(np.random.RandomState(seed), n)
    pi, pj = matching.greedy_matching(jnp.asarray(A))
    ids = np.concatenate([np.asarray(pi), np.asarray(pj)])
    assert len(set(ids.tolist())) == n  # perfect matching, disjoint
    assert np.all(np.asarray(pi) != np.asarray(pj))


@given(n=st.sampled_from([4, 6, 8, 10]), seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=15)
def test_greedy_le_twoopt_le_exact(n, seed):
    A = _rand_antisym(np.random.RandomState(seed), n)
    gpi, gpj = matching.greedy_matching(jnp.asarray(A))
    spi, spj = matching.steepest_matching(jnp.asarray(A))
    _, _, exact_w = matching.exact_matching_dp(A)
    gw = float(matching.matching_weight(A, gpi, gpj))
    sw = float(matching.matching_weight(A, spi, spj))
    assert gw <= sw + 1e-6          # 2-opt only improves
    assert sw <= exact_w + 1e-6     # exact is optimal
    # greedy achieves >= 1/2 of optimal (classic greedy matching bound)
    assert gw >= 0.5 * exact_w - 1e-6


@given(n=st.sampled_from([6, 8, 12, 16]), seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=15)
def test_random_matching_is_perfect(n, seed):
    pi, pj = matching.random_matching(jax.random.PRNGKey(seed), n)
    ids = np.concatenate([np.asarray(pi), np.asarray(pj)])
    assert len(set(ids.tolist())) == n


def test_greedy_takes_best_edge_first():
    n = 8
    A = np.zeros((n, n))
    A[2, 5] = 100.0
    A[5, 2] = -100.0
    A += 0.01 * _rand_antisym(np.random.RandomState(0), n)
    pi, pj = matching.greedy_matching(jnp.asarray(A))
    pairs = set(map(tuple, np.stack([np.asarray(pi), np.asarray(pj)], 1).tolist()))
    assert (2, 5) in pairs or (5, 2) in pairs


def test_overlapping_topk_picks_global_top():
    n = 6
    A = _rand_antisym(np.random.RandomState(1), n)
    pi, pj = matching.overlapping_topk(jnp.asarray(A), k=3)
    w = np.abs(A)
    iu = np.triu_indices(n, 1)
    top3 = sorted(w[iu], reverse=True)[:3]
    got = sorted(float(w[i, j]) for i, j in zip(np.asarray(pi), np.asarray(pj)))
    np.testing.assert_allclose(sorted(top3), got, rtol=1e-6)


@given(n=st.sampled_from([4, 8, 16, 32, 64]), seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=20)
def test_greedy_fast_exactly_matches_greedy(n, seed):
    """greedy_matching_fast is an EXACT reimplementation (same pairs, not
    just same weight) — the §Perf speedup must not change semantics."""
    A = _rand_antisym(np.random.RandomState(seed), n)
    p1 = matching.greedy_matching(jnp.asarray(A))
    p2 = matching.greedy_matching_fast(jnp.asarray(A))
    pairs1 = set(map(tuple, np.stack([np.asarray(x) for x in p1], 1).tolist()))
    pairs2 = set(map(tuple, np.stack([np.asarray(x) for x in p2], 1).tolist()))
    assert pairs1 == pairs2


def test_two_opt_monotone_improvement():
    rng = np.random.RandomState(7)
    A = jnp.asarray(_rand_antisym(rng, 16))
    pi, pj = matching.random_matching(jax.random.PRNGKey(0), 16)
    w0 = float(matching.matching_weight(A, pi, pj))
    pi2, pj2 = matching.two_opt_refine(A, pi, pj, sweeps=8)
    w1 = float(matching.matching_weight(A, pi2, pj2))
    assert w1 >= w0 - 1e-6
    ids = np.concatenate([np.asarray(pi2), np.asarray(pj2)])
    assert len(set(ids.tolist())) == 16  # still a perfect matching
