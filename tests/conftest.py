"""Test-suite bootstrap: make collection survive a missing ``hypothesis``.

The property-based tests (test_givens / test_pq / test_matching /
test_kernels) import ``hypothesis`` at module scope. On minimal images the
package is absent (it is a dev-only dependency — see requirements-dev.txt);
without this shim pytest dies at collection with ImportError and the entire
suite is lost. The shim installs a tiny stub module whose ``@given`` replaces
the test with a runtime ``pytest.skip``, so:

  * with hypothesis installed, the property tests run as written;
  * without it, they are reported as skipped and every example-based test in
    the same modules still runs.
"""
from __future__ import annotations

import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401  (real package present — nothing to do)
except ImportError:
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    def _strategy(*_a, **_k):  # placeholder for st.integers(...) etc.
        return None

    for _name in (
        "integers", "floats", "booleans", "sampled_from", "lists", "tuples",
        "composite", "just", "one_of", "text",
    ):
        setattr(st, _name, _strategy)

    def given(*_a, **_k):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper

        return deco

    class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
        def __init__(self, *_a, **_k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(_name, *_a, **_k):
            pass

        @staticmethod
        def load_profile(_name):
            pass

    class HealthCheck:
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

    def assume(_cond=True):
        return True

    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = HealthCheck
    hyp.assume = assume
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
