"""Conformance suite for the repro.rotations learner registry.

Every registered learner must satisfy the protocol contract:
  * exact orthogonality after K update steps (manifold invariant);
  * delta-vs-state consistency: apply(R_old, delta) == materialize(new_state);
  * vmapped stacked (L, n, n) updates (the per-layer KV-rotation path);
  * descent on a convex quadratic (frozen excepted — it must NOT move).

Plus the satellite regressions: the Cayley −1-eigenvalue guard and the
``reorthonormalize_every`` bf16 drift guard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rotations
from repro.core import givens
from repro.rotations import cayley as cayley_mod

N = 16
SUB_KW = {"subspace_gcd": {"sub": 4}}
ALL_SPECS = list(rotations.names())
DESCENT_SPECS = [s for s in ALL_SPECS
                 if s != "frozen" and not s.startswith("gcd_overlap")]


def _make(spec, **kw):
    return rotations.make(spec, **SUB_KW.get(spec, {}), **kw)


def _convex_loss(key, n, m=64):
    X = jax.random.normal(key, (m, n))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    return lambda R: jnp.mean((X @ R) @ w)


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_orthogonality_after_k_steps(spec):
    learner = _make(spec)
    loss = _convex_loss(jax.random.PRNGKey(0), N)
    st = learner.init(N)
    upd = jax.jit(learner.update)
    for t in range(12):
        G = jax.grad(loss)(learner.materialize(st))
        st, _ = upd(st, G, 0.05, jax.random.PRNGKey(t))
    R = learner.materialize(st)
    assert float(givens.orthogonality_error(R)) < 1e-4
    assert int(st.step) == 12


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_delta_vs_state_consistency(spec):
    """apply(R_old, delta) == materialize(new_state) — the trainer/index
    sync contract (index.maintain.refresh_delta relies on it)."""
    learner = _make(spec)
    loss = _convex_loss(jax.random.PRNGKey(1), N)
    # start away from identity so the contract is tested off the origin
    st = learner.init_from(givens.random_rotation(jax.random.PRNGKey(2), N))
    for t in range(3):
        R_old = learner.materialize(st)
        G = jax.grad(loss)(R_old)
        st, delta = jax.jit(learner.update)(st, G, 0.05, jax.random.PRNGKey(t))
        np.testing.assert_allclose(
            np.asarray(rotations.apply(R_old, delta)),
            np.asarray(learner.materialize(st)), atol=1e-5)


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_vmapped_stacked_updates(spec):
    """Stacked (L, n, n) rotations update under vmap — the per-layer
    KV-rotation path in training.optimizer."""
    L = 3
    learner = _make(spec)
    Rs = jnp.stack([givens.random_rotation(jax.random.PRNGKey(i), N)
                    for i in range(L)])
    Gs = jax.random.normal(jax.random.PRNGKey(7), (L, N, N))
    st = jax.vmap(learner.init_from)(Rs)

    def one(s, g, k):
        return learner.update(s, g, 0.05, k)

    st2, delta = jax.vmap(one)(st, Gs, jax.random.split(
        jax.random.PRNGKey(8), L))
    R2 = jax.vmap(learner.materialize)(st2)
    assert R2.shape == (L, N, N)
    for l in range(L):
        assert float(givens.orthogonality_error(R2[l])) < 1e-4
    # vmapped delta consistency
    applied = jax.vmap(lambda R, d: rotations.apply(R, d))(Rs, delta)
    np.testing.assert_allclose(np.asarray(applied), np.asarray(R2), atol=1e-5)


@pytest.mark.parametrize("spec", DESCENT_SPECS)
def test_descends_convex_quadratic(spec):
    learner = _make(spec)
    loss = _convex_loss(jax.random.PRNGKey(3), N)
    st = learner.init(N)
    lr = {"cayley_sgd": 0.02}.get(spec, 0.05)
    v0 = float(loss(learner.materialize(st)))
    upd = jax.jit(learner.update)
    for t in range(30):
        G = jax.grad(loss)(learner.materialize(st))
        st, _ = upd(st, G, lr, jax.random.PRNGKey(t))
    assert float(loss(learner.materialize(st))) < v0


def test_frozen_never_moves():
    learner = rotations.make("frozen")
    R0 = givens.random_rotation(jax.random.PRNGKey(4), N)
    st = learner.init_from(R0)
    G = jax.random.normal(jax.random.PRNGKey(5), (N, N))
    st, delta = learner.update(st, G, 0.5, jax.random.PRNGKey(6))
    np.testing.assert_array_equal(np.asarray(learner.materialize(st)),
                                  np.asarray(R0))
    assert delta.pi.shape == (0,)  # O(1) identity delta


def test_registry_aliases_and_unknown():
    assert isinstance(rotations.make("svd"), rotations.Procrustes)
    assert isinstance(rotations.make("cayley"), rotations.CayleySGD)
    assert rotations.make("gcd_steepest").method == "steepest"
    # explicit kwarg beats the spec-bound default
    assert rotations.make("gcd", method="random").method == "random"
    with pytest.raises(ValueError):
        rotations.make("does_not_exist")
    with pytest.raises(ValueError):
        rotations.make("subspace_gcd")  # sub is mandatory


def test_rotation_config_from_spec():
    rc = rotations.RotationConfig.from_spec("gcd_steepest", lr=2e-3)
    assert rc.learner == "gcd" and rc.method == "steepest" and rc.lr == 2e-3
    learner = rotations.from_config(rc)
    assert isinstance(learner, rotations.GCD) and learner.method == "steepest"
    assert isinstance(
        rotations.from_config(rotations.RotationConfig(learner="cayley")),
        rotations.CayleySGD)


def test_subspace_gcd_delta_stays_within_subspaces():
    sub = 4
    learner = rotations.make("subspace_gcd", sub=sub)
    st = learner.init_from(givens.random_rotation(jax.random.PRNGKey(9), N))
    G = jax.random.normal(jax.random.PRNGKey(10), (N, N))
    _, delta = learner.update(st, G, 0.05, jax.random.PRNGKey(11))
    cross = np.asarray(delta.pi) // sub != np.asarray(delta.pj) // sub
    assert np.all(np.abs(np.asarray(delta.theta)[cross]) == 0.0)


# --- satellite: Cayley −1-eigenvalue numerical guard -----------------------

def _rotation_with_eigenvalue_near(theta: float, n: int = 8) -> jnp.ndarray:
    """Block-diag rotation whose leading 2×2 plane turns by ``theta``
    (eigenvalues e^{±iθ} → −1 as θ → π)."""
    R = np.eye(n, dtype=np.float32)
    c, s = np.cos(theta), np.sin(theta)
    R[0, 0], R[0, 1], R[1, 0], R[1, 1] = c, -s, s, c
    return jnp.asarray(R)


def test_inverse_cayley_near_minus_one_eigenvalue_is_finite():
    """§1.1's instability: I + R is singular at a −1 eigenvalue. The guarded
    solve must stay finite and keep the round trip orthogonal."""
    # mildly near: the round trip must still be accurate
    R = _rotation_with_eigenvalue_near(np.pi - 1e-2)
    A = cayley_mod.inverse_cayley(R)
    assert bool(jnp.all(jnp.isfinite(A)))
    R2 = cayley_mod.cayley(A)
    np.testing.assert_allclose(np.asarray(R2), np.asarray(R), atol=1e-3)

    # exactly at the singularity: finite + orthogonal output (graceful
    # degradation — the unguarded solve returns inf/nan here)
    R_sing = _rotation_with_eigenvalue_near(np.pi)
    A_sing = cayley_mod.inverse_cayley(R_sing)
    assert bool(jnp.all(jnp.isfinite(A_sing)))
    assert bool(jnp.all(jnp.isfinite(cayley_mod.cayley(A_sing))))


def test_cayley_roundtrip_well_conditioned_unchanged():
    """The guard must not perturb the well-conditioned path."""
    p = 0.1 * jax.random.normal(jax.random.PRNGKey(12), (N, N))
    R = cayley_mod.cayley(p)
    assert float(givens.orthogonality_error(R)) < 1e-4
    p2 = cayley_mod.inverse_cayley(R)
    np.testing.assert_allclose(np.asarray(cayley_mod.cayley(p2)),
                               np.asarray(R), atol=1e-4)


# --- satellite: reorthonormalize_every drift guard --------------------------

def test_bf16_long_horizon_drift_guard():
    """Long-horizon GCD in bf16 drifts off SO(n); the periodic projection
    keeps the orthogonality error below tolerance."""
    n = 16
    loss = _convex_loss(jax.random.PRNGKey(13), n)

    def run(every, steps=200):
        learner = rotations.make("gcd", method="random",
                                 reorthonormalize_every=every)
        st = learner.init(n, dtype=jnp.bfloat16)
        upd = jax.jit(learner.update)
        for t in range(steps):
            G = jax.grad(loss)(learner.materialize(st).astype(jnp.float32))
            st, _ = upd(st, G, 0.05, jax.random.PRNGKey(t))
        R = learner.materialize(st).astype(jnp.float32)
        return float(givens.orthogonality_error(R))

    guarded = run(every=16)
    unguarded = run(every=0)
    assert guarded < 0.05, guarded
    assert guarded <= unguarded + 1e-6, (guarded, unguarded)


# --- satellite: fused Pallas score routing (kernels.ops.gcd_score) ----------

def test_gcd_score_kernel_routing_bit_parity():
    """``GCD.update`` with the score routed through the fused Pallas kernel
    (``score_kernel_min_n`` at/below n) must be BITWISE identical to the
    ``givens.directional_derivs`` reference path — same R, same delta —
    so the size threshold can never change a training trajectory."""
    for n in (16, 64):
        G = jax.random.normal(jax.random.PRNGKey(21), (n, n))
        ref = rotations.make("gcd", method="greedy", score_kernel_min_n=0)
        ker = rotations.make("gcd", method="greedy", score_kernel_min_n=n)
        s_ref, s_ker = ref.init(n), ker.init(n)
        upd_ref, upd_ker = jax.jit(ref.update), jax.jit(ker.update)
        for t in range(3):
            s_ref, d_ref = upd_ref(s_ref, G, 0.05, jax.random.PRNGKey(t))
            s_ker, d_ker = upd_ker(s_ker, G, 0.05, jax.random.PRNGKey(t))
        assert bool(jnp.array_equal(s_ref.R, s_ker.R))
        assert bool(jnp.array_equal(d_ref.pi, d_ker.pi))
        assert bool(jnp.array_equal(d_ref.pj, d_ker.pj))
        assert bool(jnp.array_equal(d_ref.theta, d_ker.theta))
