"""PQ-compressed KV cache: ADC attention vs dense-on-decoded oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_quant, pq


@pytest.fixture
def setup():
    cfg = kv_quant.KVQuantConfig(head_dim=16, num_subspaces=4, num_codewords=16)
    params = kv_quant.init(jax.random.PRNGKey(0), cfg)
    B, Hkv, S = 2, 2, 24
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, 16))
    return cfg, params, k, v


def test_encode_decode_shapes_and_dtypes(setup):
    cfg, params, k, v = setup
    ck, cv = kv_quant.encode_kv(params, k, v)
    assert ck.shape == (2, 2, 24, 4) and ck.dtype == jnp.uint8
    khat = kv_quant.decode_k(params, ck)
    assert khat.shape == k.shape


def test_adc_scores_match_decoded_dot(setup):
    cfg, params, k, v = setup
    ck, _ = kv_quant.encode_kv(params, k, v)
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 2, 16))
    s = kv_quant.adc_scores(params, q, ck)
    khat = kv_quant.decode_k(params, ck)
    ref = jnp.einsum("bhd,bhsd->bhs", q, khat)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref), atol=1e-4)


def test_weighted_value_sum_matches_decoded(setup):
    cfg, params, k, v = setup
    _, cv = kv_quant.encode_kv(params, k, v)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(4), (2, 2, 24)), -1)
    out = kv_quant.weighted_value_sum(params, w, cv)
    vhat = kv_quant.decode_v(params, cv)
    ref = jnp.einsum("bhs,bhsd->bhd", w, vhat)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_adc_decode_attention_gqa_vs_oracle(setup):
    cfg, params, k, v = setup
    ck, cv = kv_quant.encode_kv(params, k, v)
    B, H = 2, 4  # 2 q heads per kv head
    q = jax.random.normal(jax.random.PRNGKey(5), (B, H, 16))
    out = kv_quant.adc_decode_attention(params, q, ck, cv)
    khat = kv_quant.decode_k(params, ck)
    vhat = kv_quant.decode_v(params, cv)
    qg = q.reshape(B, 2, 2, 16)
    sc = jnp.einsum("bgrd,bgsd->bgrs", qg, khat) * 16 ** -0.5
    w = jax.nn.softmax(sc, -1)
    ref = jnp.einsum("bgrs,bgsd->bgrd", w, vhat).reshape(B, H, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_rotation_improves_anisotropic_distortion():
    """The paper's core claim transplanted to KV: a learned rotation lowers
    PQ distortion on anisotropic vectors vs identity rotation."""
    from repro.core import opq
    from repro.data import synthetic

    X = synthetic.sift_like(jax.random.PRNGKey(6), 1024, 16, num_clusters=4)
    cfg = pq.PQConfig(4, 8)
    R, cb, trace = opq.alternating_minimization(
        jax.random.PRNGKey(7), X, cfg, iters=10, rotation_solver="gcd_greedy",
        inner_steps=5, lr=2e-3)
    _, _, trace_frozen = opq.alternating_minimization(
        jax.random.PRNGKey(7), X, cfg, iters=10, rotation_solver="frozen")
    assert float(trace[-1]) < float(trace_frozen[-1])


def test_masked_attention_ignores_invalid_positions(setup):
    cfg, params, k, v = setup
    ck, cv = kv_quant.encode_kv(params, k, v)
    q = jax.random.normal(jax.random.PRNGKey(8), (2, 4, 16))
    mask_full = jnp.ones((2, 24), bool)
    mask_half = jnp.arange(24)[None, :] < 12
    out_half = kv_quant.adc_decode_attention(params, q, ck, cv,
                                             length_mask=mask_half)
    # corrupting masked-out codes must not change the result
    ck2 = ck.at[:, :, 12:].set(0)
    cv2 = cv.at[:, :, 12:].set(0)
    out_half2 = kv_quant.adc_decode_attention(params, q, ck2, cv2,
                                              length_mask=mask_half)
    np.testing.assert_allclose(np.asarray(out_half), np.asarray(out_half2),
                               atol=1e-5)
