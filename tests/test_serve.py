"""repro.serve: continuous batching, SLO-adaptive nprobe, namespace
isolation, and the churn-maintenance glue — all on a VirtualClock so
queueing behavior is deterministic."""
import jax
import numpy as np
import pytest

from repro import rotations, search, serve
from repro.data import synthetic
from repro.serve.queue import BatchQueue, make_ticket

DIM, SUB, K, L, BS = 16, 4, 16, 8, 8
N = 1500
CFG = search.SearchConfig(num_lists=L, subspaces=SUB, codewords=K,
                          block_size=BS, nprobe=4, fused_refresh=True)


@pytest.fixture(scope="module")
def corpus():
    s = search.make("ivf")
    out = {}
    for i, name in enumerate(("alpha", "beta")):
        X = synthetic.sift_like(jax.random.PRNGKey(10 * i), N, DIM)
        R = rotations.random_rotation(jax.random.PRNGKey(10 * i + 1), DIM)
        state = s.build(jax.random.PRNGKey(10 * i + 2), X, R, CFG)
        Q = np.asarray(synthetic.sift_like(
            jax.random.PRNGKey(10 * i + 3), 16, DIM))
        out[name] = (state, Q)
    return s, out


def _frontend(corpus, **kw):
    s, states = corpus
    clk = serve.VirtualClock()
    fe = serve.Frontend(slo_ms=kw.pop("slo_ms", 200.0),
                        clock=clk.now, advance=clk.advance,
                        lut_budget_rows=kw.pop("lut_budget_rows", 256))
    for name, (state, Q) in states.items():
        fe.create_namespace(name, s, state, k=10, warmup_queries=Q[:2],
                            **kw)
    return clk, fe, states


# -- queue semantics --------------------------------------------------------
def test_queue_deadline_flush():
    clk = serve.VirtualClock()
    q = BatchQueue(admission_ms=5.0, max_admit=4, clock=clk.now)
    q.push(make_ticket("a", None, k=10, nprobe=None, slo_ms=50,
                       arrival=clk.now()))
    assert not q.due()                       # window still open
    assert q.take() == []
    clk.advance(0.004)
    q.push(make_ticket("a", None, k=10, nprobe=None, slo_ms=50,
                       arrival=clk.now()))
    assert not q.due()
    clk.advance(0.0015)                      # oldest passes 5 ms
    assert q.due()
    batch = q.take()
    assert len(batch) == 2                   # both ride the same bucket
    assert batch[0].waited_ms >= 5.0 > batch[1].waited_ms
    assert q.depth == 0 and not q.due()


def test_queue_full_bucket_flushes_immediately():
    clk = serve.VirtualClock()
    q = BatchQueue(admission_ms=1e6, max_admit=3, clock=clk.now)
    for _ in range(7):
        q.push(make_ticket("a", None, k=10, nprobe=None, slo_ms=50,
                           arrival=clk.now()))
    assert q.due()                           # full despite infinite window
    assert len(q.take()) == 3
    assert len(q.take()) == 3
    assert q.take() == []                    # 1 left, window open again
    assert q.depth == 1


def test_queue_deadline_zero_degenerates_to_immediate():
    clk = serve.VirtualClock()
    q = BatchQueue(admission_ms=0.0, max_admit=8, clock=clk.now)
    q.push(make_ticket("a", None, k=10, nprobe=None, slo_ms=50,
                       arrival=clk.now()))
    assert q.due()                           # no batching delay at all
    assert len(q.take()) == 1


def test_queue_empty_drain():
    q = BatchQueue(clock=serve.VirtualClock().now)
    assert list(q.drain()) == []
    assert q.take() == []
    assert q.next_deadline() is None


# -- SLO controller ---------------------------------------------------------
def test_slo_controller_sheds_and_recovers():
    c = serve.SLOController(ladder=(2, 8, 32), safety=1.0, ewma=0.5)
    for rung, ms in ((2, 1.0), (8, 4.0), (32, 16.0)):
        c.observe(8, rung, ms)
    assert c.choose(100.0, 8) == 32          # ample budget → top rung
    assert c.choose(10.0, 8) == 8            # mid fits, top doesn't
    assert c.choose(2.0, 8) == 2
    assert c.choose(0.5, 8) == 2             # nothing fits → floor
    # backlog feedforward: 2 waves of queued work halve the usable budget
    assert c.choose(20.0, 8, backlog=8) == 8
    assert c.choose(40.0, 8, backlog=8) == 32
    assert c.floors == 1 and c.sheds >= 3
    # EWMA folds new evidence: top rung speeding up re-enables it
    for _ in range(8):
        c.observe(8, 32, 2.0)
    assert c.choose(10.0, 8) == 32


def test_slo_unknown_cell_falls_to_floor():
    c = serve.SLOController(ladder=(2, 8))
    assert c.choose(1e9, 16) == 2            # no EWMA yet → serve at floor


# -- serving through the frontend ------------------------------------------
def test_ragged_k_nprobe_mix_one_bucket_matches_direct(corpus):
    """One flush holding mixed k and nprobe serves every request exactly
    as a direct Engine call with the same parameters would."""
    s, states = corpus
    clk, fe, _ = _frontend(corpus, admission_ms=2.0, max_admit=8)
    state, Q = states["alpha"]
    want_engine = search.Engine(s, state, k=10)
    mix = [dict(k=3, nprobe=2), dict(k=10, nprobe=2), dict(k=3, nprobe=8),
           dict(k=7, nprobe=None), dict(k=10, nprobe=None)]
    tickets = [fe.submit("alpha", Q[i], **m) for i, m in enumerate(mix)]
    clk.advance(0.003)
    fe.poll()
    assert all(t.done for t in tickets)
    for i, (t, m) in enumerate(zip(tickets, mix)):
        want = want_engine.search(Q[i:i + 1], k=m["k"], nprobe=m["nprobe"])
        np.testing.assert_array_equal(np.asarray(t.result.ids),
                                      np.asarray(want.ids)[0])
        np.testing.assert_allclose(np.asarray(t.result.scores),
                                   np.asarray(want.scores)[0], atol=1e-4)
        assert t.result.ids.shape == (m["k"],)


def test_batch_composition_invariance(corpus):
    """A request's results don't depend on which co-riders shared its
    bucket (deterministic topk_merge + row-independent ADC)."""
    s, states = corpus
    state, Q = states["alpha"]
    clk, fe, _ = _frontend(corpus, admission_ms=1.0, max_admit=8)
    solo = fe.submit("alpha", Q[0], nprobe=4)
    clk.advance(0.002)
    fe.poll()
    clk2, fe2, _ = _frontend(corpus, admission_ms=1.0, max_admit=8)
    crowd = [fe2.submit("alpha", Q[i], nprobe=4) for i in (3, 0, 5, 7)]
    clk2.advance(0.002)
    fe2.poll()
    np.testing.assert_array_equal(np.asarray(solo.result.ids),
                                  np.asarray(crowd[1].result.ids))


def test_adaptive_nprobe_stays_on_precompiled_ladder(corpus):
    """SLO adaptation only ever serves ladder rungs, and switching rungs
    never compiles a new executable after warmup."""
    clk, fe, states = _frontend(corpus, admission_ms=1.0, max_admit=4,
                                nprobe_ladder=(2, 4, 8), slo_ms=500.0)
    ns = fe.namespaces.get("alpha")
    warm = ns.engine.stats()["compiles"]
    _, Q = states["alpha"]
    served = []
    for i in range(12):
        t = fe.submit("alpha", Q[i % len(Q)],
                      slo_ms=500.0 if i % 3 else 1e-6)  # force floor sheds
        clk.advance(0.002)
        fe.poll()
        assert t.done
        served.append(t.nprobe_served)
    assert set(served) <= {2, 4, 8}
    assert 2 in served and 8 in served        # both ends exercised
    assert ns.engine.stats()["compiles"] == warm
    assert ns.slo.sheds >= 1


def test_default_warmup_synthesized(corpus):
    """create_namespace without warmup_queries still pre-compiles every
    (bucket, rung) cell and seeds the SLO model — synthetic Gaussian rows
    at the state's rotation width; warmup_queries=() opts out."""
    s, states = corpus
    state, Q = states["alpha"]
    clk = serve.VirtualClock()
    fe = serve.Frontend(clock=clk.now, advance=clk.advance,
                        lut_budget_rows=256, slo_ms=200.0)
    ns = fe.create_namespace("auto", s, state, k=10, nprobe_ladder=(2, 8),
                             admission_ms=1.0, max_admit=4)
    assert ns.warm_compiles > 0
    assert ns.slo.stats()["cells"]            # EWMA seeded per (bucket,rung)
    warm = ns.engine.stats()["compiles"]
    t = fe.submit("auto", Q[0], slo_ms=1e9)
    clk.advance(0.002)
    fe.poll()
    assert t.done and t.nprobe_served == 8    # budget allows the top rung
    assert ns.engine.stats()["compiles"] == warm

    cold = fe.create_namespace("cold", s, state, k=10,
                               warmup_queries=())
    assert cold.warm_compiles == 0


def test_namespace_isolation_refresh(corpus):
    """A cross-subspace refresh on alpha invalidates ONLY alpha's LUT
    cache; beta's cache, epoch, and executables are untouched."""
    s, states = corpus
    clk, fe, _ = _frontend(corpus, admission_ms=0.0, max_admit=4)
    Qa, Qb = states["alpha"][1], states["beta"][1]
    for i in range(4):
        fe.submit("alpha", Qa[i]); fe.submit("beta", Qb[i])
        fe.poll()
    ea = fe.namespaces.get("alpha").engine
    eb = fe.namespaces.get("beta").engine
    sb0 = eb.stats()
    assert sb0["lut_cached_rows"] > 0
    # cross-subspace delta: fused refresh cannot keep LUTs through it
    G = jax.random.normal(jax.random.PRNGKey(5), (DIM, DIM))
    learner = rotations.make("gcd")
    _, delta = learner.update(learner.init_from(ea.state.index.R), G, 1e-3,
                              jax.random.PRNGKey(6))
    ea.refresh(delta)
    sa, sb = ea.stats(), eb.stats()
    assert sa["lut_invalidations"] == 1 and sa["lut_epoch"] == 1
    assert sb["lut_invalidations"] == 0 and sb["lut_epoch"] == 0
    assert sb["lut_cached_rows"] == sb0["lut_cached_rows"]
    # beta still serves on warm caches: no new compiles, all LUT hits
    t = fe.submit("beta", Qb[0])
    fe.poll()
    assert t.done
    sb2 = eb.stats()
    assert sb2["compiles"] == sb0["compiles"]
    assert sb2["lut_misses"] == sb["lut_misses"]


def test_lut_budget_split_and_evictions(corpus):
    """The global LUT budget splits evenly per namespace; a hot tenant
    churning distinct queries evicts only its own rows."""
    s, states = corpus
    clk, fe, _ = _frontend(corpus, admission_ms=0.0, max_admit=4,
                           lut_budget_rows=8)
    ea = fe.namespaces.get("alpha").engine
    eb = fe.namespaces.get("beta").engine
    assert ea.lut_cache_rows == 4 and eb.lut_cache_rows == 4
    _, Qb = states["beta"]
    for i in range(3):
        fe.submit("beta", Qb[i]); fe.poll()
    rows_b = eb.stats()["lut_cached_rows"]
    rng = np.random.default_rng(0)
    for _ in range(10):                      # alpha hammers distinct queries
        fe.submit("alpha", rng.standard_normal(DIM).astype(np.float32))
        fe.poll()
    assert ea.stats()["lut_evictions"] > 0
    assert ea.stats()["lut_cached_rows"] <= 4
    assert eb.stats()["lut_cached_rows"] == rows_b     # beta untouched
    assert eb.stats()["lut_evictions"] == 0


def test_namespace_lifecycle_resplit(corpus):
    s, states = corpus
    clk = serve.VirtualClock()
    fe = serve.Frontend(clock=clk.now, advance=clk.advance,
                        lut_budget_rows=100)
    state, Q = states["alpha"]
    fe.create_namespace("a", s, state, k=10)
    assert fe.namespaces.get("a").engine.lut_cache_rows == 100
    fe.create_namespace("b", s, state, k=10)
    assert fe.namespaces.get("a").engine.lut_cache_rows == 50
    fe.drop_namespace("b")
    assert fe.namespaces.get("a").engine.lut_cache_rows == 100
    with pytest.raises(KeyError, match="unknown namespace"):
        fe.namespaces.get("b")
    with pytest.raises(ValueError, match="already exists"):
        fe.create_namespace("a", s, state, k=10)


def test_churn_ticks_in_idle_slots(corpus):
    """Idle polls run churn maintenance; staged rows flush through ticks
    without recompiling, and stay searchable."""
    s, states = corpus
    clk, fe, _ = _frontend(corpus, admission_ms=1.0, max_admit=4,
                           churn={"staging_rows": 64, "flush_at": 0.25})
    ns = fe.namespaces.get("alpha")
    _, Q = states["alpha"]
    t = fe.submit("alpha", Q[0])
    clk.advance(0.002)
    fe.poll()
    assert t.done
    compiles = ns.engine.stats()["compiles"]
    # in-distribution adds at double magnitude: distinctive PQ codes, so
    # each new row is its own query's strong match
    new = 2.0 * np.asarray(synthetic.sift_like(
        jax.random.PRNGKey(9), 32, DIM))
    new_ids = np.arange(10_000, 10_032, dtype=np.int32)
    ns.churn.add(new, new_ids)               # 32/64 staged > flush_at
    before = fe.stats()["maintenance_ticks"]
    fe.poll()                                # idle → maintenance tick
    assert fe.stats()["maintenance_ticks"] == before + 1
    assert ns.engine.obs.counter("churn.flushes").value >= 1
    assert ns.engine.stats()["compiles"] == compiles
    # flushed rows are searchable (probe every list so only ADC ranks)
    t2 = fe.submit("alpha", new[0], nprobe=L)
    clk.advance(0.002)
    fe.poll()
    assert 10_000 in np.asarray(t2.result.ids)


def test_drain_and_ticket_errors(corpus):
    clk, fe, states = _frontend(corpus, admission_ms=1e6, max_admit=64)
    _, Q = states["alpha"]
    tickets = [fe.submit("alpha", Q[i]) for i in range(3)]
    assert fe.poll() == []                   # window open for a long time
    assert not tickets[0].done
    with pytest.raises(ValueError, match="still in flight"):
        _ = tickets[0].latency_ms
    done = fe.drain()                        # shutdown flush ignores window
    assert len(done) == 3 and all(t.done for t in tickets)
    with pytest.raises(ValueError, match="query row"):
        fe.submit("alpha", Q[:2])
    with pytest.raises(KeyError, match="unknown namespace"):
        fe.submit("nope", Q[0])
