"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates its REDUCED same-family config and runs one
forward/train step on CPU, asserting output shapes and no NaNs. The full
configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import gnn, recsys
from repro.models import transformer as tfm

LM_ARCHS = ["qwen1.5-4b", "olmo-1b", "nemotron-4-340b", "grok-1-314b",
            "llama4-maverick-400b-a17b"]


def _finite(x):
    return bool(jnp.all(jnp.isfinite(x)))


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_step(arch_id):
    cfg = configs.get(arch_id).make_smoke()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    loss = tfm.forward_train(params, tok, lab, cfg)
    assert loss.shape == () and _finite(loss)
    # rough sanity: loss near ln(vocab) at init
    assert 0.3 * np.log(cfg.vocab_size) < float(loss) < 3.5 * np.log(cfg.vocab_size)
    grads = jax.grad(lambda p: tfm.forward_train(p, tok, lab, cfg))(params)
    assert all(_finite(g) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_serve_paths(arch_id):
    cfg = configs.get(arch_id).make_smoke()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits, cache = tfm.serve_prefill(params, tok, cfg, max_len=24)
    assert logits.shape == (2, cfg.vocab_size) and _finite(logits)
    lg2, cache2 = tfm.serve_decode(params, jnp.argmax(logits, -1), cache, cfg)
    assert lg2.shape == (2, cfg.vocab_size) and _finite(lg2)
    assert int(cache2.length[0]) == 17


def test_lm_smoke_pq_cache_decode():
    """The long_500k path at smoke scale: PQ-compressed cache decode."""
    from repro.core.kv_quant import KVQuantConfig
    cfg = configs.get("olmo-1b").make_smoke()._replace(
        kv_quant=KVQuantConfig(head_dim=16, num_subspaces=4, num_codewords=16))
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits, cache = tfm.serve_prefill(params, tok, cfg, max_len=24)
    assert isinstance(cache, tfm.PQDecodeCache)
    assert cache.k_codes.dtype == jnp.uint8
    lg2, _ = tfm.serve_decode(params, jnp.argmax(logits, -1), cache, cfg)
    assert _finite(lg2)


def test_gnn_smoke_all_modes():
    arch = configs.get("graphsage-reddit")
    cfg = arch.make_smoke()
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    N, E = 60, 200
    feats = jax.random.normal(jax.random.PRNGKey(1), (N, cfg.d_in))
    src = jax.random.randint(jax.random.PRNGKey(2), (E,), 0, N)
    dst = jax.random.randint(jax.random.PRNGKey(3), (E,), 0, N)
    labels = jax.random.randint(jax.random.PRNGKey(4), (N,), 0, cfg.num_classes)
    mask = jnp.ones((N,), bool)
    loss = gnn.loss_full_batch(params, feats, src, dst, labels, mask, cfg)
    assert _finite(loss)
    # minibatch with real sampler
    from repro.data import graph as G
    g = G.synthetic_graph(0, 300, 6, cfg.d_in, num_classes=cfg.num_classes)
    fb, lb = G.sample_blocks(g, np.arange(16), cfg.sample_sizes, seed=1)
    assert _finite(gnn.loss_minibatch(params, fb, lb, cfg))
    # graph-batch (molecule) mode
    gids = jnp.repeat(jnp.arange(4), 15)
    glab = jax.random.randint(jax.random.PRNGKey(5), (4,), 0, cfg.num_classes)
    assert _finite(gnn.loss_graph_batch(params, feats, src, dst, gids, glab, 4, cfg))


@pytest.mark.parametrize("arch_id", ["wide-deep", "two-tower-retrieval",
                                     "mind", "din", "paper-twotower"])
def test_recsys_smoke_train_and_serve(arch_id):
    cfg = configs.get(arch_id).make_smoke()
    key = jax.random.PRNGKey(0)
    B = 16
    if isinstance(cfg, recsys.WideDeepConfig):
        params = recsys.widedeep_init(key, cfg)
        ids = jax.random.randint(key, (B, cfg.n_sparse), 0, cfg.vocab_per_field)
        y = jax.random.bernoulli(key, 0.4, (B,)).astype(jnp.float32)
        loss = recsys.widedeep_loss(params, ids, y, cfg)
        logits = recsys.widedeep_forward(params, ids, cfg)
        assert logits.shape == (B,) and _finite(logits)
    elif isinstance(cfg, recsys.TwoTowerConfig):
        params = recsys.twotower_init(key, cfg)
        hist = jax.random.randint(key, (B, cfg.hist_len), -1, cfg.item_vocab)
        pos = jax.random.randint(jax.random.fold_in(key, 1), (B,), 0, cfg.item_vocab)
        loss = recsys.twotower_loss(params, hist, pos, cfg)
        # retrieval paths
        from repro.core import index_layer as il
        v, _ = recsys.item_tower(params, jnp.arange(64), cfg)
        codes = il.encode(params["index"], v)
        s = recsys.twotower_retrieve_adc(params, hist[:2], codes, cfg)
        assert s.shape == (2, 64) and _finite(s)
    elif isinstance(cfg, recsys.MINDConfig):
        params = recsys.mind_init(key, cfg)
        hist = jax.random.randint(key, (B, cfg.hist_len), 0, cfg.item_vocab)
        pos = jax.random.randint(jax.random.fold_in(key, 1), (B,), 0, cfg.item_vocab)
        loss = recsys.mind_loss(params, hist, pos, cfg)
        ints = recsys.mind_interests(params, hist, cfg)
        assert ints.shape == (B, cfg.n_interests, cfg.embed_dim)
    elif isinstance(cfg, recsys.DINConfig):
        params = recsys.din_init(key, cfg)
        hist = jax.random.randint(key, (B, cfg.hist_len), 0, cfg.item_vocab)
        tgt = jax.random.randint(jax.random.fold_in(key, 1), (B,), 0, cfg.item_vocab)
        y = jax.random.bernoulli(key, 0.4, (B,)).astype(jnp.float32)
        loss = recsys.din_loss(params, hist, tgt, y, cfg)
    assert _finite(loss)


def test_registry_covers_grid():
    cells = configs.grid_cells()
    assert len(cells) == 40
    assert len(configs.ASSIGNED) == 10
    for aid in configs.ASSIGNED:
        arch = configs.get(aid)
        assert callable(arch.make_config) and callable(arch.make_smoke)
        assert len(arch.shapes) == 4
