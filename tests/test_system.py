"""End-to-end behaviour tests: the paper's full pipeline at test scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rotations
from repro.core import index_layer as il
from repro.core import givens, pq
from repro.data import synthetic
from repro.models import recsys
from repro.training import optimizer as opt_lib
from repro.training import train_state as ts


@pytest.fixture(scope="module")
def trained():
    """Paper §3.2 pipeline: warmup → OPQ warm start → joint training with GCD."""
    cfg = recsys.TwoTowerConfig(
        name="sys", item_vocab=512, embed_dim=16, tower_dims=(32, 16),
        hist_len=8, index=il.IndexLayerConfig(dim=16, num_subspaces=4,
                                              num_codewords=16),
    )
    log = synthetic.ClickLog(0, cfg.item_vocab, dim=16)
    ocfg = opt_lib.OptimizerConfig(
        lr=3e-3, total_steps=120, warmup_steps=10,
        rotation=rotations.RotationConfig(learner="gcd", method="greedy",
                                          lr=3e-3))
    params = recsys.twotower_init(jax.random.PRNGKey(0), cfg)
    state = ts.init_state(jax.random.PRNGKey(1), params, ocfg)

    warm = jax.jit(ts.make_train_step(
        lambda p, h, i: recsys.twotower_loss(p, h, i, cfg, use_index=False), ocfg))
    for i in range(40):
        h, pos = log.batch(100 + i, 32, cfg.hist_len)
        state, _ = warm(state, h, pos)

    v, _ = recsys.item_tower(state.params, jnp.arange(256), cfg)
    state.params["index"] = il.warm_start(jax.random.PRNGKey(2), v, cfg.index,
                                          opq_iters=20)
    joint = jax.jit(ts.make_train_step(
        lambda p, h, i: recsys.twotower_loss(p, h, i, cfg, use_index=True), ocfg))
    d0 = float(pq.distortion(v @ state.params["index"].R,
                             state.params["index"].codebooks))
    losses = []
    for i in range(80):
        h, pos = log.batch(500 + i, 32, cfg.hist_len)
        state, m = joint(state, h, pos)
        losses.append(float(m["loss"]))
    return cfg, log, state, d0, losses


def test_joint_training_reduces_loss(trained):
    _, _, _, _, losses = trained
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


def test_rotation_stays_orthogonal_through_training(trained):
    cfg, _, state, _, _ = trained
    R = state.params["index"].R
    assert float(givens.orthogonality_error(R)) < 1e-3
    assert not np.allclose(np.asarray(R), np.eye(cfg.index.dim), atol=1e-4), \
        "R must have moved away from the warm start"


def test_distortion_tracked_by_gcd(trained):
    """Eq. 1's second term: after joint training with GCD updates, the index
    distortion on FRESH item-tower outputs stays controlled (the frozen
    baseline drifts — that's the paper's Fig 3)."""
    cfg, _, state, d0, _ = trained
    v, _ = recsys.item_tower(state.params, jnp.arange(256), cfg)
    d1 = float(pq.distortion(v @ state.params["index"].R,
                             state.params["index"].codebooks))
    assert np.isfinite(d1)
    assert d1 < 5.0 * max(d0, 1e-3)


def test_serving_consistency(trained):
    """ADC retrieval scores == exact scores on decoded vectors."""
    cfg, log, state, _, _ = trained
    params = state.params
    ids = jnp.arange(128)
    v, _ = recsys.item_tower(params, ids, cfg)
    vn = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)
    codes = il.encode(params["index"], vn)
    hist, _ = log.batch(9, 4, cfg.hist_len)
    s_adc = recsys.twotower_retrieve_adc(params, hist, codes, cfg)
    u = recsys.user_tower(params, hist, cfg)
    u = u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)
    R, cb = params["index"].R, params["index"].codebooks
    decoded = pq.decode(codes, cb) @ R.T
    s_exact = u @ decoded.T
    np.testing.assert_allclose(np.asarray(s_adc), np.asarray(s_exact),
                               atol=1e-4, rtol=1e-3)


def test_gcd_beats_frozen_on_distortion_e2e():
    """The paper's headline end-to-end claim at test scale (short run)."""
    from benchmarks import fig3_table1_e2e
    res, checks = fig3_table1_e2e.run(steps=40, warmup=20, batch=32,
                                      verbose=False, item_vocab=512)
    assert checks["trainable_beats_frozen"], res
