"""IVF-PQ serving subsystem (repro.index + kernels/ivf_adc).

Coverage demanded by ISSUE 1:
  * search with nprobe = num_lists matches the flat ADC scan exactly;
  * the Pallas ivf_adc kernel (interpret mode) matches the jnp reference;
  * refresh_rotation matches a from-scratch re-encode (exact for
    within-subspace GCD steps, ≥99% for small full-matching steps);
plus CSR-layout invariants and add/remove maintenance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import churn
from repro.core import givens, matching, pq
from repro.data import synthetic
from repro.index import ivf, maintain, search
from repro.kernels import ops, ref

DIM, D, K, L, BS = 16, 4, 16, 8, 8
N = 2000


@pytest.fixture(scope="module")
def index_and_data():
    X = synthetic.sift_like(jax.random.PRNGKey(0), N, DIM)
    R = givens.random_rotation(jax.random.PRNGKey(1), DIM)
    cfg = ivf.IVFPQConfig(num_lists=L, pq=pq.PQConfig(D, K), block_size=BS)
    index = ivf.build(jax.random.PRNGKey(2), X, R, cfg)
    Q = synthetic.sift_like(jax.random.PRNGKey(3), 16, DIM)
    return index, X, Q


def test_pack_csr_invariants(index_and_data):
    index, X, _ = index_and_data
    offsets = np.asarray(index.list_offsets)
    ids = np.asarray(index.ids)
    assert offsets[0] == 0
    assert np.all(offsets % BS == 0)
    assert np.all(np.diff(offsets) >= 0)
    assert index.capacity == offsets[-1] + BS  # sentinel hole block
    assert np.all(ids[offsets[-1]:] == -1)
    live = ids[ids >= 0]
    assert sorted(live.tolist()) == list(range(N))  # every item exactly once
    # every live row's code matches a fresh encode of its vector
    XR = X @ index.R
    list_ids, codes = ivf.encode(XR, index.coarse, index.quantizer)
    rows = np.nonzero(ids >= 0)[0]
    np.testing.assert_array_equal(
        np.asarray(index.codes)[rows].astype(np.int32),
        np.asarray(codes)[ids[rows]],
    )
    # rows live in the list their vector was assigned to
    row_list = np.searchsorted(offsets, rows, side="right") - 1
    np.testing.assert_array_equal(row_list, np.asarray(list_ids)[ids[rows]])


def test_search_nprobe_full_matches_flat(index_and_data):
    index, _, Q = index_and_data
    res = search.search(index, Q, nprobe=L, k=10, use_kernel=False)
    flat_scores, flat_ids = search.flat_adc_scores(index, Q)
    want_scores, pos = jax.lax.top_k(flat_scores, 10)
    want_ids = flat_ids[pos]
    np.testing.assert_allclose(
        np.asarray(res.scores), np.asarray(want_scores), rtol=1e-5, atol=1e-5
    )
    # ids agree except possibly on exact score ties
    agree = np.mean(np.asarray(res.ids) == np.asarray(want_ids))
    assert agree >= 0.95
    assert np.all(np.asarray(res.scanned) == index.capacity - BS)


def test_search_kernel_matches_ref(index_and_data):
    index, _, Q = index_and_data
    a = search.search(index, Q, nprobe=3, k=5, use_kernel=True)
    b = search.search(index, Q, nprobe=3, k=5, use_kernel=False)
    np.testing.assert_allclose(
        np.asarray(a.scores), np.asarray(b.scores), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_ivf_adc_kernel_matches_ref():
    key = jax.random.PRNGKey(7)
    b, cap, bs, S = 5, 40 * 8, 8, 23
    lut = jax.random.normal(key, (b, D, K))
    codes = jax.random.randint(jax.random.PRNGKey(8), (cap, D), 0, K)
    bi = jax.random.randint(jax.random.PRNGKey(9), (S,), 0, cap // bs)
    bq = jax.random.randint(jax.random.PRNGKey(10), (S,), 0, b)
    got = ops.ivf_adc(lut, codes, bi, bq, block_size=bs, use_kernel=True)
    want = ref.ivf_adc_ref(lut, codes, bi, bq, block_size=bs)
    assert got.shape == (S, bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_refresh_subspace_step_is_exact(index_and_data):
    index, X, _ = index_and_data
    G = jax.random.normal(jax.random.PRNGKey(11), (DIM, DIM))
    refreshed, (pi, pj, theta) = maintain.subspace_gcd_step(index, G, 2e-3)
    # delta really moved the rotation, and stayed in SO(n)
    assert float(jnp.max(jnp.abs(refreshed.R - index.R))) > 0
    assert float(givens.orthogonality_error(refreshed.R)) < 1e-4
    sub = DIM // D
    w = np.asarray(pi) // sub == np.asarray(pj) // sub
    np.testing.assert_allclose(np.where(w, 0.0, np.asarray(theta)), 0.0)
    # codes match a full re-encode (fp ties aside) — acceptance: ≥ 99%
    mismatch = float(maintain.refresh_mismatch(refreshed, X))
    assert mismatch <= 0.01


def test_refresh_small_full_step_matches_rebuild(index_and_data):
    index, X, _ = index_and_data

    def loss(Rm):
        return pq.distortion(X @ Rm, index.codebooks)

    G = jax.grad(loss)(index.R)
    A = givens.directional_derivs(G, index.R)
    pi, pj = matching.greedy_matching_fast(A)
    theta = -2e-4 * A[pi, pj] / givens.SQRT2
    refreshed = maintain.refresh_rotation(index, pi, pj, theta)
    assert float(givens.orthogonality_error(refreshed.R)) < 1e-4
    mismatch = float(maintain.refresh_mismatch(refreshed, X))
    assert mismatch <= 0.01  # ≥ 99% of items keep their rebuild codes


def test_refresh_preserves_flat_recall(index_and_data):
    index, X, Q = index_and_data
    G = jax.random.normal(jax.random.PRNGKey(12), (DIM, DIM))
    refreshed, _ = maintain.subspace_gcd_step(index, G, 1e-3)
    a = search.search(index, Q, nprobe=L, k=10, use_kernel=False)
    b = search.search(refreshed, Q, nprobe=L, k=10, use_kernel=False)
    # scores are rotation-invariant inner products — refresh must not move them
    np.testing.assert_allclose(
        np.asarray(a.scores), np.asarray(b.scores), rtol=1e-4, atol=1e-4
    )


def test_search_k_exceeding_candidate_pool_pads(index_and_data):
    index, _, Q = index_and_data
    res = search.search(index, Q, nprobe=1, k=10_000, use_kernel=False)
    assert res.ids.shape == (Q.shape[0], 10_000)
    ids = np.asarray(res.ids)
    scores = np.asarray(res.scores)
    assert np.all(np.isfinite(scores[ids >= 0]))
    assert np.all(np.isneginf(scores[ids < 0]))
    # nprobe beyond num_lists clamps instead of crashing
    res2 = search.search(index, Q, nprobe=10 * L, k=5, use_kernel=False)
    assert res2.ids.shape == (Q.shape[0], 5)


def test_remove_tombstones_and_masks(index_and_data):
    index, _, Q = index_and_data
    dead = jnp.arange(50, dtype=jnp.int32)
    idx2 = churn.tombstone_index(index, dead)
    assert int(index.num_items()) - int(idx2.num_items()) == 50
    res = search.search(idx2, Q, nprobe=L, k=10, use_kernel=False)
    assert not np.any(np.isin(np.asarray(res.ids), np.asarray(dead)))


def test_add_fills_holes_then_repacks(index_and_data):
    index, _, _ = index_and_data
    idx2 = churn.tombstone_index(index, jnp.arange(100, dtype=jnp.int32))
    Xn = synthetic.sift_like(jax.random.PRNGKey(13), 60, DIM)
    new_ids = jnp.arange(N, N + 60, dtype=jnp.int32)
    idx3 = churn.ingest_index(idx2, Xn, new_ids)
    assert int(idx3.num_items()) == N - 100 + 60
    # new items are findable and correctly encoded
    XR = Xn @ idx3.R
    list_ids, codes = ivf.encode(XR, idx3.coarse, idx3.quantizer)
    ids_np = np.asarray(idx3.ids)
    for i in (0, 17, 59):
        rows = np.nonzero(ids_np == N + i)[0]
        assert len(rows) == 1
        np.testing.assert_array_equal(
            np.asarray(idx3.codes)[rows[0]].astype(np.int32),
            np.asarray(codes)[i],
        )
    # force the overflow/repack path: add more than the holes can absorb
    Xbig = synthetic.sift_like(jax.random.PRNGKey(14), 500, DIM)
    idx4 = churn.ingest_index(idx3, Xbig,
                              jnp.arange(10_000, 10_500, dtype=jnp.int32))
    assert int(idx4.num_items()) == int(idx3.num_items()) + 500
    offsets = np.asarray(idx4.list_offsets)
    assert np.all(offsets % BS == 0)


def test_index_is_jit_traceable_pytree(index_and_data):
    index, _, Q = index_and_data
    leaves, treedef = jax.tree_util.tree_flatten(index)
    assert all(hasattr(leaf, "shape") for leaf in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.block_size == index.block_size

    @jax.jit
    def serve(ix, qb):
        return search.search_fixed(
            ix, qb, nprobe=2, k=5,
            max_blocks=index.max_list_blocks(), use_kernel=False
        ).scores

    out = serve(index, Q)
    assert out.shape == (Q.shape[0], 5)
