"""repro.obs — registry/span/exporter/probe semantics + instrumentation.

Coverage demanded by ISSUE 6:
  * counter/gauge/distribution semantics: labels, lifetime vs window
    scoping, streaming percentiles;
  * span nesting (dotted paths) + exception safety (timing records with
    error=True, the stack unwinds, the exception propagates);
  * disabled-mode zero-side-effects: null singletons, no metric objects,
    no events, no sink writes;
  * JSONL event-log round-trip and BENCH_*.json write/append/validate
    round-trip (+ the validator rejecting malformed trajectories);
  * the vectorized recall_at_k against the original per-row set-loop
    reference (−1 padding semantics pinned);
  * the sampling RecallProbe catching an injected bad rotation through
    Engine.refresh while every latency metric stays green;
  * Engine under an ENABLED global registry: zero extra compiles, and
    stats() carrying the new p99/window keys.
"""
import json

import jax
import numpy as np
import pytest

from repro import obs, rotations, search
from repro.data import synthetic
from repro.index import maintain
from repro.metrics import recall_at_k
from repro.obs import registry as reg_mod


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------


def test_counter_gauge_lifetime_and_labels():
    reg = obs.Registry()
    reg.counter("hits").inc()
    reg.counter("hits").inc(4)
    assert reg.counter("hits").value == 5           # same object, lifetime
    reg.counter("hits", shard=0).inc()              # labels → distinct metric
    assert reg.counter("hits", shard=0).value == 1
    assert reg.counter("hits").value == 5
    reg.gauge("recall", k=10).set(0.9)
    reg.gauge("recall", k=10).set(0.7)              # last-write-wins
    g = reg.gauge("recall", k=10)
    assert g.value == 0.7 and g.updates == 2
    snap = reg.snapshot()
    assert snap["counters"]["hits"] == 5
    assert snap["counters"]["hits{shard=0}"] == 1
    assert snap["gauges"]["recall{k=10}"] == 0.7


def test_distribution_window_vs_lifetime_percentiles():
    reg = obs.Registry(window=100)
    d = reg.distribution("lat")
    for v in range(1, 1001):                        # 1..1000; window keeps
        d.observe(float(v))                         # only the last 100
    assert d.count == 1000                          # lifetime
    assert d.min == 1.0 and d.max == 1000.0         # lifetime extrema
    assert d.window_values() == [float(v) for v in range(901, 1001)]
    # percentiles are window-scoped: p50 of 901..1000, not of 1..1000
    assert d.percentile(50) == pytest.approx(950.5)
    assert d.percentile(0) == 901.0 and d.percentile(100) == 1000.0
    s = d.summary()
    assert s["count"] == 1000 and s["window"] == 100
    assert s["p99"] == pytest.approx(999.01)
    assert s["mean"] == pytest.approx(950.5)
    # empty distribution never divides by zero
    empty = reg.distribution("never")
    assert empty.percentile(99) == 0.0 and empty.summary()["mean"] == 0.0


def test_span_nesting_paths_and_sync():
    reg = obs.Registry()
    with reg.span("serve"):
        with reg.span("engine.search") as sp:
            sp.sync(jax.numpy.ones((4,)))           # concrete: blocks fine
    snap = reg.snapshot()
    assert "span.serve.ms" in snap["distributions"]
    assert "span.serve.engine.search.ms" in snap["distributions"]
    names = [e["name"] for e in reg.events("span")]
    assert names == ["serve.engine.search", "serve"]  # inner exits first
    with reg.span("engine.search"):                   # stack unwound: no
        pass                                          # stale "serve." prefix
    assert reg.events("span")[-1]["name"] == "engine.search"


def test_span_exception_safety():
    reg = obs.Registry()
    with pytest.raises(ValueError, match="boom"):
        with reg.span("outer"):
            with reg.span("inner"):
                raise ValueError("boom")
    evs = {e["name"]: e for e in reg.events("span")}
    assert evs["outer.inner"]["error"] is True        # both spans recorded,
    assert evs["outer"]["error"] is True              # both flagged
    assert reg._span_stack() == []                    # stack fully unwound
    with reg.span("after"):
        pass
    assert reg.events("span")[-1]["name"] == "after"


def test_disabled_registry_has_zero_side_effects():
    reg = obs.Registry(enabled=False)
    c = reg.counter("x")
    assert c is reg.gauge("y") is reg.distribution("z")  # shared null object
    c.inc(10)
    reg.gauge("y").set(1.0)
    reg.distribution("z").observe(5.0)
    reg.event("request", batch=8)
    sp = reg.span("s")
    assert sp is reg_mod._NULL_SPAN
    with sp as s:
        assert s.sync("v") == "v"                     # pass-through
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "distributions": {}}    # nothing materialized
    assert reg.events() == []
    assert reg.distribution("z").percentile(99) == 0.0


def test_global_override_toggles_instrumentation():
    assert not obs.enabled()                          # default: off
    obs.counter("ignored").inc()
    with obs.override(True) as reg:
        assert obs.enabled()
        obs.counter("seen").inc()
        assert reg.counter("seen").value == 1
    assert not obs.enabled()
    obs.default_registry().reset()


# ---------------------------------------------------------------------------
# Exporters: JSONL round-trip, text report, BENCH trajectory
# ---------------------------------------------------------------------------


def test_jsonl_sink_round_trip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    reg = obs.Registry()
    reg.add_sink(obs.JsonlSink(path))
    reg.event("request", batch=np.int64(8), latency_ms=np.float32(1.5))
    reg.event("refresh", drift=float("nan"), arr=np.arange(3))
    reg.reset()                                       # closes the sink
    evs = obs.read_jsonl(path)
    assert [e["kind"] for e in evs] == ["request", "refresh"]
    assert evs[0]["batch"] == 8                       # numpy → plain JSON
    assert evs[0]["latency_ms"] == 1.5
    assert evs[1]["drift"] is None                    # NaN can't round-trip
    assert evs[1]["arr"] == [0, 1, 2]
    # every line is strict JSON (a crash mid-run leaves parseable lines)
    for line in open(path):
        json.loads(line)


def test_text_report_lists_every_metric_kind():
    reg = obs.Registry()
    reg.counter("engine.requests").inc(3)
    reg.gauge("probe.recall_at_k", k=10).set(0.93)
    reg.distribution("engine.latency_ms").observe(2.0)
    rep = obs.text_report(reg)
    for needle in ("engine.requests", "probe.recall_at_k{k=10}",
                   "engine.latency_ms", "p99"):
        assert needle in rep
    assert obs.text_report(obs.Registry()) == "(no metrics recorded)"


def test_bench_write_append_validate_round_trip(tmp_path):
    out = str(tmp_path)
    path = obs.write_bench(out, "fast",
                           sections={"kernels": {"us": np.float32(3.5)}},
                           checks={"kernels/ok": np.bool_(True)},
                           config={"fast": True})
    assert path.endswith("BENCH_fast.json")
    assert obs.validate_bench(path) == []
    doc = obs.load_bench(path)
    assert doc["schema"] == obs.BENCH_SCHEMA and len(doc["runs"]) == 1
    run = doc["runs"][0]
    assert run["checks"]["kernels/ok"] is True        # coerced to real bool
    assert run["sections"]["kernels"]["us"] == 3.5
    assert {"backend", "device_count", "jax", "python"} <= set(run["host"])
    # second write APPENDS — a trajectory, not a snapshot
    obs.write_bench(out, "fast", sections={"kernels": {"us": 3.1}},
                    checks={"kernels/ok": True})
    doc = obs.load_bench(path)
    assert len(doc["runs"]) == 2
    assert obs.validate_bench(path) == []
    # non-finite section values serialize as null, never as bare NaN
    obs.write_bench(out, "nan", sections={"s": {"v": float("inf")}},
                    checks={})
    assert obs.load_bench(obs.bench_path(out, "nan"))["runs"][0][
        "sections"]["s"]["v"] is None


def test_bench_validator_rejects_malformed(tmp_path):
    out = str(tmp_path)
    path = obs.write_bench(out, "fast", sections={"a": {}}, checks={"ok": True})
    doc = obs.load_bench(path)
    doc["runs"][0]["checks"]["ok"] = "yes"            # non-bool check
    assert any("bool" in e for e in obs.validate_bench(doc))
    doc["runs"][0]["checks"]["ok"] = True
    doc["schema"] = "repro.bench/v0"
    assert any("schema" in e for e in obs.validate_bench(doc))
    assert obs.validate_bench({"schema": obs.BENCH_SCHEMA, "name": "x",
                               "runs": []}) != []     # empty trajectory
    # sections must be non-empty: a run that measured nothing is a bug
    bad = obs.load_bench(path)
    bad["runs"][0]["sections"] = {}
    assert any("sections" in e for e in obs.validate_bench(bad))
    # a raw-NaN file on disk fails the strict loader
    nan_file = tmp_path / "BENCH_raw.json"
    nan_file.write_text('{"schema": "repro.bench/v1", "name": "x", '
                        '"runs": [{"v": NaN}]}')
    assert any("unreadable" in e for e in obs.validate_bench(str(nan_file)))
    assert obs.bench.main(["--validate", str(nan_file)]) == 1
    assert obs.bench.main(["--validate", path]) == 0


# ---------------------------------------------------------------------------
# Vectorized recall_at_k vs the original per-row set-loop reference
# ---------------------------------------------------------------------------


def _recall_reference(pred_ids, true_ids, k=None):
    """The pre-vectorization implementation, verbatim (the semantic pin)."""
    pred_ids = np.asarray(pred_ids)
    true_ids = np.asarray(true_ids)
    k = k if k is not None else true_ids.shape[1]
    hits = []
    for i in range(pred_ids.shape[0]):
        pred = {p for p in pred_ids[i, :k].tolist() if p >= 0}
        hits.append(len(pred & set(true_ids[i, :k].tolist())) / k)
    return float(np.mean(hits))


@pytest.mark.parametrize("k", [1, 3, 10, None])
def test_recall_at_k_matches_set_loop_reference(k):
    rng = np.random.RandomState(0)
    m, width = 64, 10
    true = np.stack([rng.choice(1000, size=width, replace=False)
                     for _ in range(m)])
    # predictions: partial overlap with truth + −1 padding tails
    pred = np.stack([rng.choice(1000, size=width, replace=False)
                     for _ in range(m)])
    pred[:, :4] = true[:, :4][:, ::-1]                # guaranteed hits
    pred[rng.rand(m, width) < 0.3] = -1               # padding never counts
    got = recall_at_k(pred, true, k)
    assert got == pytest.approx(_recall_reference(pred, true, k))
    assert recall_at_k(true, true) == 1.0             # perfect prediction
    assert recall_at_k(np.full_like(true, -1), true) == 0.0   # all padding


# ---------------------------------------------------------------------------
# Instrumented subsystems
# ---------------------------------------------------------------------------

DIM, SUB = 16, 4
CFG = search.SearchConfig(num_lists=8, subspaces=SUB, codewords=64,
                          block_size=8, nprobe=4, tile_rows=256)


@pytest.fixture(scope="module")
def serving():
    X = synthetic.sift_like(jax.random.PRNGKey(0), 400, DIM)
    R = rotations.random_rotation(jax.random.PRNGKey(1), DIM)
    Q = synthetic.sift_like(jax.random.PRNGKey(2), 16, DIM)
    state = search.FlatADC.attach(
        search.make("ivf").build(jax.random.PRNGKey(3), X, R, CFG).index)
    return X, R, Q, state


def test_engine_stats_has_percentiles_and_window(serving):
    _, _, Q, state = serving
    engine = search.Engine(search.make("flat_adc"), state, k=10,
                           min_bucket=4, history=128)
    for b in (3, 7, 16, 5):
        engine.search(np.asarray(Q)[:b])
    st = engine.stats()
    assert st["requests"] == 4 and st["queries"] == 31
    assert st["latency_ms_p50"] > 0.0
    assert st["latency_ms_p99"] >= st["latency_ms_p95"] >= st["latency_ms_p50"]
    assert st["latency_ms_max"] >= st["latency_ms_p99"]
    assert st["window"] == {"size": 4, "capacity": 128,
                            "scope": "latency/scanned/pad aggregates"}
    assert st["window_requests"] == 4
    # pad waste: b=3→bucket 4, b=7→8, b=16→16, b=5→8
    assert st["pad_waste_mean"] == pytest.approx(
        np.mean([1 / 4, 1 / 8, 0.0, 3 / 8]))
    # requests compat view mirrors the event window, newest last
    reqs = engine.requests
    assert [r["batch"] for r in reqs] == [3, 7, 16, 5]
    assert set(reqs[0]) == {"batch", "bucket", "k", "nprobe", "latency_ms",
                            "scanned_rows", "lut_hits", "lut_misses",
                            "compiled"}
    assert reqs[0]["compiled"] and not reqs[-1]["compiled"]


def test_engine_zero_extra_compiles_with_obs_enabled(serving):
    """The acceptance gate: flipping the global registry ON changes no
    compile behavior — same executables, same compile count, and the
    refresh-health sync happens outside every traced function."""
    _, R, Q, state = serving
    Qnp = np.asarray(Q)

    def drive(engine):
        for b in (3, 7, 3, 16):
            engine.search(Qnp[:b])
        engine.refresh(_cross_subspace_delta(scale=1e-3))
        for b in (3, 7, 16):
            engine.search(Qnp[:b])
        return engine.stats()

    base = drive(search.Engine(search.make("flat_adc"), state, k=10,
                               min_bucket=4))
    with obs.override(True):
        inst = drive(search.Engine(search.make("flat_adc"), state, k=10,
                                   min_bucket=4))
        # refresh health DID record on the global registry…
        snap = obs.default_registry().snapshot()
        assert snap["gauges"]["refresh.orthogonality_drift"] < 1e-3
        assert snap["gauges"]["refresh.delta_norm"] > 0.0
    obs.default_registry().reset()
    # …and the serving behavior is bit-identical: zero extra compiles
    assert inst["compiles"] == base["compiles"] == 3
    assert inst["executables"] == base["executables"]
    assert inst["requests"] == base["requests"]


def _cross_subspace_delta(scale: float) -> rotations.GivensDelta:
    """Planes that straddle PQ subspace boundaries: the serving rotation
    absorbs them exactly, but ``maintain.rotate_components`` must drop them
    from the codebooks — at large angles that mismatch destroys recall."""
    sub = DIM // SUB
    pi = np.arange(0, DIM // 2)
    pj = pi + DIM // 2                       # always a different subspace
    assert not np.any(pi // sub == pj // sub)
    theta = np.full(pi.shape, scale, np.float32)
    return rotations.GivensDelta(pi=jax.numpy.asarray(pi),
                                 pj=jax.numpy.asarray(pj),
                                 theta=jax.numpy.asarray(theta))


def test_recall_probe_detects_injected_bad_rotation(serving):
    X, R, Q, state = serving
    probe = obs.RecallProbe.from_exact(X, R, np.asarray(Q), k=10, every=4)
    engine = search.Engine(search.make("flat_adc"), state, k=10,
                           min_bucket=4, probe=probe)
    engine.search(np.asarray(Q))             # first request → baseline probe
    base = probe.last
    assert base is not None and base > 0.5   # full-scan ADC: healthy recall
    assert engine.stats()["recall_probe"] == {"k": 10, "recall": base,
                                              "every": 4}
    # inject a BAD refresh: large cross-subspace planes the codebook
    # rotation cannot absorb
    engine.refresh(_cross_subspace_delta(scale=1.0))
    for _ in range(4):                       # sampling cadence: every 4th
        engine.search(np.asarray(Q)[:4])
    bad = probe.last
    assert probe.truth.shape == (16, 10)     # truth never re-derived
    assert bad < base - 0.2, f"probe missed the bad rotation: {base}->{bad}"


def test_recall_probe_sampling_cadence(serving):
    _, R, Q, state = serving
    probe = obs.RecallProbe(np.asarray(Q)[:4], np.zeros((4, 10), np.int64),
                            k=10, every=3)
    calls = []
    for i in range(7):
        probe.maybe_run(lambda q: (calls.append(i),
                                   np.zeros((4, 10), np.int64))[1])
    assert calls == [0, 3, 6]                # first call + every 3rd after


def test_refresh_health_reports_drift_and_norm():
    reg = obs.Registry()
    R = rotations.random_rotation(jax.random.PRNGKey(0), DIM)
    out = maintain.refresh_health(R, _cross_subspace_delta(1e-2),
                                  registry=reg)
    assert out["orthogonality_drift"] < 1e-4          # R is orthogonal
    assert out["delta_norm"] == pytest.approx(
        np.linalg.norm(np.full(DIM // 2, 1e-2)))
    snap = reg.snapshot()
    assert snap["gauges"]["refresh.orthogonality_drift"] == pytest.approx(
        out["orthogonality_drift"])
    assert snap["counters"]["refresh.count"] == 1
    assert reg.events("refresh")[0]["delta_norm"] == out["delta_norm"]
    # dense deltas take the Frobenius path
    dense = rotations.DenseDelta(dR=jax.numpy.eye(DIM) * 2.0)
    out2 = maintain.refresh_health(R, dense, registry=reg)
    assert out2["delta_norm"] == pytest.approx(2.0 * np.sqrt(DIM))


def test_kmeans_records_distortion_trace():
    from repro.quant.base import PQConfig
    from repro.quant.kmeans import kmeans

    X = synthetic.sift_like(jax.random.PRNGKey(0), 256, DIM)
    with obs.override(True):
        _, trace = kmeans(jax.random.PRNGKey(1), X, PQConfig(SUB, 16),
                          iters=6)
        reg = obs.default_registry()
        d = reg.distribution("kmeans.distortion", subspaces=SUB, codewords=16)
        assert d.count == 6
        assert d.window_values() == pytest.approx(
            np.asarray(trace, np.float64).tolist())
        ev = reg.events("kmeans_fit")[-1]
        assert ev["iters"] == 6 and len(ev["trace"]) == 6
        # Lloyd's never increases distortion
        assert ev["trace"][-1] <= ev["trace"][0]
        assert reg.gauge("kmeans.final_distortion", subspaces=SUB,
                         codewords=16).value == pytest.approx(ev["trace"][-1])
    obs.default_registry().reset()
