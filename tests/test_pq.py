"""Product quantization: k-means, STE, ADC exactness, hypothesis invariants."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import pq


def test_kmeans_distortion_monotone():
    X = jax.random.normal(jax.random.PRNGKey(0), (512, 32))
    cfg = pq.PQConfig(4, 16)
    _cb, trace = pq.kmeans(jax.random.PRNGKey(1), X, cfg, iters=10)
    t = np.asarray(trace)
    assert np.all(np.diff(t) <= 1e-5), "Lloyd iterations must not increase distortion"


@given(D=st.sampled_from([2, 4, 8]), K=st.sampled_from([4, 16]),
       seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=15)
def test_assign_decode_roundtrip_invariants(D, K, seed):
    n = D * 8
    X = jax.random.normal(jax.random.PRNGKey(seed), (64, n))
    cb = pq.kmeans_init(jax.random.PRNGKey(seed + 1), X, pq.PQConfig(D, K))
    codes = pq.assign(X, cb)
    assert codes.shape == (64, D)
    assert int(codes.min()) >= 0 and int(codes.max()) < K
    q = pq.decode(codes, cb)
    assert q.shape == X.shape
    # assignment is nearest: reassigning the reconstruction is a fixpoint
    codes2 = pq.assign(q, cb)
    assert np.array_equal(np.asarray(codes), np.asarray(codes2))


def test_quantize_is_projection():
    """φ(φ(x)) == φ(x): quantization is idempotent."""
    X = jax.random.normal(jax.random.PRNGKey(2), (128, 16))
    cb, _ = pq.kmeans(jax.random.PRNGKey(3), X, pq.PQConfig(4, 8), iters=5)
    q = pq.quantize(X, cb)
    np.testing.assert_allclose(np.asarray(pq.quantize(q, cb)), np.asarray(q),
                               atol=1e-6)


def test_ste_gradient_is_identity_wrt_x():
    X = jax.random.normal(jax.random.PRNGKey(4), (32, 16))
    cb, _ = pq.kmeans(jax.random.PRNGKey(5), X, pq.PQConfig(4, 8), iters=3)
    w = jax.random.normal(jax.random.PRNGKey(6), (16,))
    g = jax.grad(lambda x: jnp.sum(pq.quantize_ste(x, cb) @ w))(X)
    # straight-through: dL/dx == broadcast of w
    np.testing.assert_allclose(np.asarray(g), np.tile(w, (32, 1)), atol=1e-5)


def test_distortion_grad_trains_codebooks():
    X = jax.random.normal(jax.random.PRNGKey(7), (256, 16))
    cb = 0.01 * jax.random.normal(jax.random.PRNGKey(8), (4, 8, 4))
    d0 = float(pq.distortion(X, cb))
    for _ in range(50):
        g = jax.grad(lambda c: pq.distortion(X, c))(cb)
        cb = cb - 0.05 * g
    assert float(pq.distortion(X, cb)) < d0 * 0.8


@given(seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=10)
def test_adc_equals_exact_inner_product(seed):
    n, D, K = 32, 4, 16
    X = jax.random.normal(jax.random.PRNGKey(seed), (100, n))
    cb, _ = pq.kmeans(jax.random.PRNGKey(seed + 1), X, pq.PQConfig(D, K), iters=3)
    codes = pq.assign(X, cb)
    q = jax.random.normal(jax.random.PRNGKey(seed + 2), (3, n))
    lut = pq.adc_lut(q, cb)
    s_adc = pq.adc_score(lut, codes)
    s_exact = q @ pq.decode(codes, cb).T
    np.testing.assert_allclose(np.asarray(s_adc), np.asarray(s_exact),
                               atol=1e-4, rtol=1e-4)


def test_ema_update_moves_codebooks_toward_data():
    X = jnp.ones((64, 8)) * 3.0
    cb = jnp.zeros((2, 4, 4))
    codes = pq.assign(X, cb)
    cb2 = pq.codebook_ema_update(cb, X, codes, decay=0.5)
    # the assigned codeword moved halfway toward 3.0
    assert float(jnp.max(cb2)) > 1.0
