"""Givens-rotation math: invariants + hypothesis property tests."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import givens, matching

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("ci")


def _random_matching_np(rng, n):
    perm = rng.permutation(n)
    return jnp.asarray(perm[: n // 2]), jnp.asarray(perm[n // 2: 2 * (n // 2)])


@given(n=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 2**16))
def test_pair_rotation_preserves_orthogonality(n, seed):
    rng = np.random.RandomState(seed)
    R = givens.random_rotation(jax.random.PRNGKey(seed), n)
    pi, pj = _random_matching_np(rng, n)
    theta = jnp.asarray(rng.randn(n // 2))
    R2 = givens.apply_pair_rotations(R, pi, pj, theta)
    assert float(givens.orthogonality_error(R2)) < 1e-4


@given(n=st.sampled_from([4, 8, 16]), m=st.integers(1, 9),
       seed=st.integers(0, 2**16))
def test_pair_apply_equals_dense_matmul(n, m, seed):
    rng = np.random.RandomState(seed)
    X = jnp.asarray(rng.randn(m, n).astype(np.float32))
    pi, pj = _random_matching_np(rng, n)
    theta = jnp.asarray(rng.randn(n // 2).astype(np.float32))
    Rot = givens.rotation_from_pairs(pi, pj, theta, n)
    np.testing.assert_allclose(
        np.asarray(givens.apply_pair_rotations(X, pi, pj, theta)),
        np.asarray(X @ Rot), atol=1e-5)
    # det(Rot) == +1: product of commuting plane rotations is in SO(n)
    assert np.isclose(float(jnp.linalg.det(Rot)), 1.0, atol=1e-4)


@given(seed=st.integers(0, 2**16))
def test_transposed_apply_is_inverse(seed):
    n = 12
    rng = np.random.RandomState(seed)
    X = jnp.asarray(rng.randn(5, n).astype(np.float32))
    pi, pj = _random_matching_np(rng, n)
    theta = jnp.asarray(rng.randn(n // 2).astype(np.float32))
    Y = givens.apply_pair_rotations(X, pi, pj, theta)
    X2 = givens.apply_pair_rotations_transposed(Y, pi, pj, theta)
    np.testing.assert_allclose(np.asarray(X2), np.asarray(X), atol=1e-5)


def test_directional_derivative_matches_finite_difference():
    n, m = 16, 32
    key = jax.random.PRNGKey(0)
    R = givens.random_rotation(key, n)
    X = jax.random.normal(jax.random.PRNGKey(1), (m, n))
    w = jax.random.normal(jax.random.PRNGKey(2), (n,))

    def loss(Rm):
        return jnp.sum(jnp.tanh(X @ Rm) @ w)

    G = jax.grad(loss)(R)
    A = givens.directional_derivs(G, R)
    # eps must clear the f32 cancellation floor of the central difference
    # (loss ~ O(30), ulp noise / 2eps ≈ 4% at eps=1e-4) while keeping the
    # O(eps²) truncation term negligible — 3e-3 sits in the stable window.
    eps = 3e-3
    for (i, j) in [(0, 1), (2, 7), (10, 15)]:
        Rp = givens.apply_pair_rotations(
            R, jnp.array([i]), jnp.array([j]), jnp.array([eps]))
        Rm_ = givens.apply_pair_rotations(
            R, jnp.array([i]), jnp.array([j]), jnp.array([-eps]))
        fd = (loss(Rp) - loss(Rm_)) / (2 * eps)
        assert np.isclose(float(fd), float(A[i, j]), rtol=2e-2, atol=1e-3)


def test_directional_derivs_antisymmetric():
    key = jax.random.PRNGKey(3)
    G = jax.random.normal(key, (24, 24))
    R = givens.random_rotation(jax.random.PRNGKey(4), 24)
    A = givens.directional_derivs(G, R)
    np.testing.assert_allclose(np.asarray(A), -np.asarray(A).T, atol=1e-5)


def test_project_to_so_n():
    key = jax.random.PRNGKey(5)
    M = jax.random.normal(key, (10, 10))
    R = givens.project_to_so_n(M)
    assert float(givens.orthogonality_error(R)) < 1e-5
    assert np.isclose(float(jnp.linalg.det(R)), 1.0, atol=1e-4)
