"""Optimizer + train-state + checkpoint + grad-compression tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rotations
from repro.core import givens
from repro.data import synthetic
from repro.models import transformer as tfm
from repro.training import checkpoint as ckpt
from repro.training import grad_compress as gc
from repro.training import optimizer as opt
from repro.training import train_state as ts


def _tiny_cfg(**kw):
    return tfm.TransformerConfig(
        name="t", num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
        head_dim=8, d_ff=64, vocab_size=97, dtype=jnp.float32,
        param_dtype=jnp.float32, q_chunk=8, xent_chunk=16, **kw)


def test_adam_matches_reference_on_quadratic():
    cfg = opt.OptimizerConfig(lr=0.1, beta1=0.9, beta2=0.999, grad_clip=0.0,
                              warmup_steps=0, schedule="constant")
    params = {"w": jnp.ones((4,)) * 2.0}
    state = opt.init(params, cfg)
    # reference adam in numpy
    w = np.ones(4) * 2.0
    m = np.zeros(4)
    v = np.zeros(4)
    for t in range(1, 6):
        g = 2 * (w - 1.0)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g**2
        w = w - 0.1 * (m / (1 - 0.9**t)) / (np.sqrt(v / (1 - 0.999**t)) + 1e-8)
        grads = {"w": jnp.asarray(2 * (np.asarray(params["w"]) - 1.0))}
        params, state = opt.update(grads, state, params, cfg, jax.random.PRNGKey(t))
    np.testing.assert_allclose(np.asarray(params["w"]), w, rtol=1e-5)


def test_manifold_leaves_get_gcd_not_adam():
    cfg = opt.OptimizerConfig(
        lr=0.1, rotation=rotations.RotationConfig(learner="gcd",
                                                  method="greedy", lr=0.05))
    params = {"R": jnp.eye(8), "w": jnp.zeros((8,))}
    state = opt.init(params, cfg)
    G = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    grads = {"R": G, "w": jnp.ones((8,))}
    new_params, _ = opt.update(grads, state, params, cfg, jax.random.PRNGKey(1))
    # R stays exactly orthogonal (GCD), w moved by adam
    assert float(givens.orthogonality_error(new_params["R"])) < 1e-5
    assert not np.allclose(np.asarray(new_params["R"]), np.eye(8))
    assert not np.allclose(np.asarray(new_params["w"]), 0.0)


def test_frozen_method_keeps_rotation():
    cfg = opt.OptimizerConfig(rotation=rotations.RotationConfig(learner="frozen"))
    params = {"R": jnp.eye(6)}
    state = opt.init(params, cfg)
    grads = {"R": jax.random.normal(jax.random.PRNGKey(0), (6, 6))}
    new_params, _ = opt.update(grads, state, params, cfg, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(new_params["R"]), np.eye(6))


def test_adafactor_state_is_factored_and_converges():
    cfg = opt.OptimizerConfig(name="adafactor", lr=0.3, grad_clip=0.0,
                              warmup_steps=0, schedule="constant")
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
    state = opt.init(params, cfg)
    assert state.mu["w"].shape == (8,)
    assert state.nu["w"].shape == (16,)
    target = jnp.ones((8, 16))
    for t in range(60):
        g = 2 * (params["w"] - target)
        params, state = opt.update({"w": g}, state, params, cfg,
                                   jax.random.PRNGKey(t))
    assert float(jnp.abs(params["w"] - target).mean()) < 0.15


def test_accum_steps_equivalent_loss_and_grads():
    cfg = _tiny_cfg()
    p = tfm.init_params(jax.random.PRNGKey(0), cfg)
    tok, lab = synthetic.lm_batch(jax.random.PRNGKey(1), 8, 16, 97)
    outs = {}
    for A in (1, 2, 4):
        ocfg = opt.OptimizerConfig(
            accum_steps=A, lr=0.0, grad_clip=0.0,
            rotation=rotations.RotationConfig(learner="frozen"))
        step = jax.jit(ts.make_train_step(
            lambda pp, t, l: tfm.forward_train(pp, t, l, cfg), ocfg))
        st = ts.init_state(jax.random.PRNGKey(2), p, ocfg)
        _, m = step(st, tok, lab)
        outs[A] = (float(m["loss"]), float(m["grad_norm"]))
    for A in (2, 4):
        assert np.isclose(outs[A][0], outs[1][0], rtol=1e-5)
        assert np.isclose(outs[A][1], outs[1][1], rtol=1e-4)


def test_checkpoint_atomicity_and_keep_n():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": np.arange(10), "b": {"c": np.ones((3, 3))}}
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, tree, keep_n=2)
        assert ckpt.latest_step(d) == 4
        dirs = sorted(os.listdir(d))
        assert len(dirs) == 2  # keep_n respected
        # a partial (manifest-less) dir must be ignored
        os.makedirs(os.path.join(d, "step_0000000099"))
        assert ckpt.latest_step(d) == 4
        restored, man = ckpt.restore_latest(d, tree)
        np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])
        assert man["step"] == 4


def test_train_launcher_resume_exact():
    """Kill/restart mid-run resumes bit-exact (fault-tolerance contract)."""
    from repro.launch import train as train_mod
    with tempfile.TemporaryDirectory() as d:
        # run 6 steps straight
        state_a, hist_a = train_mod.train(
            "two-tower-retrieval", steps=6, batch=8, ckpt_dir=None,
            seed=3, log_every=100)
        # same 6-step job, crash after 3, then resume
        train_mod.train("two-tower-retrieval", steps=6, batch=8, ckpt_dir=d,
                        seed=3, ckpt_every=100, log_every=100, stop_after=3)
        state_b, hist_b = train_mod.train(
            "two-tower-retrieval", steps=6, batch=8, ckpt_dir=d, seed=3,
            ckpt_every=100, log_every=100)
        assert np.isclose(hist_a[-1], hist_b[-1], rtol=1e-4), (hist_a, hist_b)


def test_ef_compression_unbiased_over_time():
    rng = np.random.RandomState(0)
    g_true = jnp.asarray(rng.randn(256).astype(np.float32))
    err = jnp.zeros_like(g_true)
    acc_q = np.zeros(256)
    acc_t = np.zeros(256)
    for i in range(100):
        q, scale, err = gc.ef_quantize(g_true, err, axis_size=2)
        acc_q += np.asarray(q, np.float32) * float(scale) * 2
        acc_t += np.asarray(g_true)
    # error feedback: the long-run average matches full precision
    np.testing.assert_allclose(acc_q / 100, acc_t / 100, atol=1e-2)


# --- PR 10 satellites: prefetch determinism + delta emission ----------------


def test_pipeline_prefetch_bit_identical_and_resume():
    """The double-buffered prefetcher must not change the batch stream:
    prefetch on == off bitwise, and a pause/``state()``/``restore()``
    mid-stream (with a batch in flight) reproduces the uninterrupted
    stream exactly — the cursor is the whole checkpoint, never the
    buffer contents."""
    from repro.data import pipeline as pipe_lib

    def make(key):
        return jax.random.normal(key, (4, 8))

    sync = pipe_lib.Pipeline(make, seed=5, prefetch=False)
    want = [np.asarray(next(sync)) for _ in range(8)]

    pre = pipe_lib.Pipeline(make, seed=5, prefetch=True)
    got = [np.asarray(next(pre)) for _ in range(8)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert pre.prefetch_hits >= 6   # steady state: only the first can miss
    pre.close()
    sync.close()

    for prefetch in (False, True):
        p1 = pipe_lib.Pipeline(make, seed=5, prefetch=prefetch)
        for _ in range(3):
            next(p1)
        cursor = p1.state()
        p1.close()                   # in-flight batch 3 is dropped here
        p2 = pipe_lib.Pipeline(make, seed=0, prefetch=prefetch)
        p2.restore(cursor)
        rest = [np.asarray(next(p2)) for _ in range(5)]
        for w, g in zip(want[3:], rest):
            np.testing.assert_array_equal(w, g)
        p2.close()


def test_train_launcher_prefetch_resume_exact():
    """Launcher-level fault tolerance with prefetch on: crash-after-3 +
    resume matches the uninterrupted prefetch-OFF run — the pipeline
    cursor in the checkpoint is prefetch-agnostic."""
    from repro.launch import train as train_mod
    with tempfile.TemporaryDirectory() as d:
        _, hist_a = train_mod.train(
            "two-tower-retrieval", steps=6, batch=8, ckpt_dir=None,
            seed=3, log_every=100)
        train_mod.train("two-tower-retrieval", steps=6, batch=8, ckpt_dir=d,
                        seed=3, ckpt_every=100, log_every=100, stop_after=3,
                        prefetch=True)
        _, hist_b = train_mod.train(
            "two-tower-retrieval", steps=6, batch=8, ckpt_dir=d, seed=3,
            ckpt_every=100, log_every=100, prefetch=True)
        assert np.isclose(hist_a[-1], hist_b[-1], rtol=1e-4), (hist_a, hist_b)


def test_update_with_deltas_matches_update():
    """``update_with_deltas`` is the same optimizer step plus the manifold
    deltas (the trainer→live-index sync contract): params bitwise equal to
    ``update``, and the emitted delta applied to the old R reproduces the
    new R."""
    cfg = opt.OptimizerConfig(
        lr=0.1, rotation=rotations.RotationConfig(learner="gcd",
                                                  method="greedy", lr=0.05))
    params = {"R": jnp.eye(8), "w": jnp.zeros((8,))}
    state = opt.init(params, cfg)
    grads = {"R": jax.random.normal(jax.random.PRNGKey(0), (8, 8)),
             "w": jnp.ones((8,))}
    p1, s1 = opt.update(grads, state, params, cfg, jax.random.PRNGKey(1))
    p2, s2, deltas = opt.update_with_deltas(grads, state, params, cfg,
                                            jax.random.PRNGKey(1))
    assert bool(jnp.array_equal(p1["R"], p2["R"]))
    assert bool(jnp.array_equal(p1["w"], p2["w"]))
    assert set(deltas) == {"R"}
    np.testing.assert_allclose(np.asarray(deltas["R"].apply(params["R"])),
                               np.asarray(p2["R"]), atol=1e-6)


def test_train_step_emit_deltas_metric():
    """``make_train_step(emit_deltas=True)`` surfaces the per-step manifold
    delta under ``metrics["rotation_deltas"]`` and changes nothing else."""
    cfg = _tiny_cfg()
    p = tfm.init_params(jax.random.PRNGKey(0), cfg)
    tok, lab = synthetic.lm_batch(jax.random.PRNGKey(1), 8, 16, 97)
    ocfg = opt.OptimizerConfig(
        lr=1e-3, rotation=rotations.RotationConfig(learner="gcd",
                                                   method="greedy"))
    loss = lambda pp, t, l: tfm.forward_train(pp, t, l, cfg)  # noqa: E731
    st0 = ts.init_state(jax.random.PRNGKey(2), p, ocfg)
    _, m_plain = jax.jit(ts.make_train_step(loss, ocfg))(st0, tok, lab)
    st0 = ts.init_state(jax.random.PRNGKey(2), p, ocfg)
    st1, m_del = jax.jit(ts.make_train_step(loss, ocfg,
                                            emit_deltas=True))(st0, tok, lab)
    assert "rotation_deltas" not in m_plain
    assert np.isclose(float(m_plain["loss"]), float(m_del["loss"]))
    for key, delta in m_del["rotation_deltas"].items():
        assert isinstance(delta, rotations.GivensDelta), key
