"""repro.search — Searcher registry conformance + Engine serving tests.

Coverage demanded by ISSUE 4:
  * one shared conformance suite over every registered backend
    (build / search / refresh / stats);
  * backend parity: ``ivf`` at nprobe = num_lists returns the flat_adc
    top-k over the same codes, and ``exact`` beats both on recall@10;
  * the SearchResult padding contract when k exceeds the candidate pool
    (ids −1, scores −inf, recall_at_k ignores padding);
  * Engine: ragged batches match direct search, at most one compile per
    (bucket, k, nprobe), per-query LUT cache hits, live refresh between
    batches without recompiles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rotations, search
from repro.data import synthetic
from repro.metrics import recall_at_k

DIM, SUB, K, L, BS = 16, 4, 16, 8, 8
N, B = 2000, 16
CFG = search.SearchConfig(num_lists=L, subspaces=SUB, codewords=K,
                          block_size=BS, nprobe=4, tile_rows=256)


@pytest.fixture(scope="module")
def data():
    X = synthetic.sift_like(jax.random.PRNGKey(0), N, DIM)
    R = rotations.random_rotation(jax.random.PRNGKey(1), DIM)
    Q = synthetic.sift_like(jax.random.PRNGKey(2), B, DIM)
    truth = np.argsort(-np.asarray(Q @ X.T), axis=1)[:, :10]
    return X, R, Q, truth


@pytest.fixture(scope="module")
def states(data):
    """One state per backend; flat_adc attached to the ivf build so both
    serve the identical codes. The sharded twins attach the same artifacts
    on the local data mesh (S = 1 in-process; the 8-fake-device parity runs
    live in tests/test_distributed.py)."""
    from repro.launch.mesh import make_data_mesh

    X, R, Q, _ = data
    mesh = make_data_mesh()
    ivf_state = search.make("ivf").build(jax.random.PRNGKey(3), X, R, CFG)
    return {
        "exact": search.make("exact").build(jax.random.PRNGKey(3), X, R, CFG),
        "exact_stream": search.make("exact_stream").build(
            jax.random.PRNGKey(3), X, R, CFG),
        "flat_adc": search.FlatADC.attach(ivf_state.index),
        "ivf": ivf_state,
        "exact_sharded": search.make("exact_sharded", mesh=mesh).build(
            jax.random.PRNGKey(3), X, R, CFG),
        "flat_sharded": search.FlatSharded.attach(ivf_state.index, mesh=mesh),
        "ivf_sharded": search.IVFSharded.attach(ivf_state.index, mesh=mesh,
                                                nprobe=CFG.nprobe),
    }


def _delta(R, key=0, lr=1e-3):
    """A genuine subspace-GCD RotationDelta (what a training step emits)."""
    G = jax.random.normal(jax.random.PRNGKey(100 + key), (DIM, DIM))
    learner = rotations.make("subspace_gcd", sub=DIM // SUB)
    _, delta = learner.update(learner.init_from(R), G, lr,
                              jax.random.PRNGKey(key))
    return delta


# ---------------------------------------------------------------------------
# Shared conformance suite
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", search.names())
def test_conformance_build_and_search(backend, data, states):
    _, _, Q, _ = data
    searcher = search.make(backend)
    res = searcher.search(states[backend], Q, k=10)
    assert res.scores.shape == (B, 10) and res.ids.shape == (B, 10)
    assert res.scanned.shape == (B,)
    scores = np.asarray(res.scores)
    ids = np.asarray(res.ids)
    assert np.all(np.diff(scores, axis=1) <= 1e-6)        # descending
    assert np.all((ids >= -1) & (ids < N))
    assert np.all(np.isfinite(scores[ids >= 0]))
    assert np.all(np.asarray(res.scanned) > 0)


@pytest.mark.parametrize("backend", search.names())
def test_conformance_refresh(backend, data, states):
    _, R, Q, _ = data
    searcher = search.make(backend)
    state = states[backend]
    before = searcher.search(state, Q, k=10)

    # identity delta: a no-op refresh must not move results
    ident = searcher.refresh(state, rotations.identity_delta())
    after = searcher.search(ident, Q, k=10)
    np.testing.assert_allclose(np.asarray(before.scores),
                               np.asarray(after.scores), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))

    # a genuine learner delta: state stays servable, rotation really moved,
    # and scores (rotation-invariant inner products) stay put
    moved = searcher.refresh(state, _delta(R))
    res = searcher.search(moved, Q, k=10)
    np.testing.assert_allclose(np.asarray(before.scores),
                               np.asarray(res.scores), rtol=1e-4, atol=1e-4)
    new_R = moved.R if hasattr(moved, "R") else moved.index.R
    old_R = state.R if hasattr(state, "R") else state.index.R
    assert float(jnp.max(jnp.abs(new_R - old_R))) > 0
    assert float(rotations.orthogonality_error(new_R)) < 1e-4


@pytest.mark.parametrize("backend", search.names())
def test_conformance_stats(backend, states):
    st = search.make(backend).stats(states[backend])
    assert st["backend"] == backend
    assert st["rows"] == N
    assert st["scan_rows_per_query"] > 0
    assert st["memory_bytes"] > 0
    assert st["compression"] >= 1.0


def test_registry_make_and_aliases():
    assert set(search.names()) == {"exact", "exact_stream", "flat_adc",
                                   "ivf", "exact_sharded", "flat_sharded",
                                   "ivf_sharded"}
    assert isinstance(search.make("flat"), search.FlatADC)
    assert isinstance(search.make("bruteforce"), search.Exact)
    assert isinstance(search.make("streaming"), search.ExactStreaming)
    assert isinstance(search.make("exact_streaming"), search.ExactStreaming)
    assert isinstance(search.make("sharded"), search.IVFSharded)
    assert isinstance(search.make("flat_adc_sharded"), search.FlatSharded)
    with pytest.raises(ValueError, match="unknown search backend"):
        search.make("faiss")


# ---------------------------------------------------------------------------
# Backend parity (ISSUE 4 regression)
# ---------------------------------------------------------------------------


def test_ivf_full_probe_matches_flat_adc(data, states):
    _, _, Q, _ = data
    a = search.make("ivf").search(states["ivf"], Q, k=10, nprobe=L)
    b = search.make("flat_adc").search(states["flat_adc"], Q, k=10)
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=1e-5, atol=1e-5)
    # ids agree except possibly on exact score ties
    assert np.mean(np.asarray(a.ids) == np.asarray(b.ids)) >= 0.95
    # and the flat backend scans strictly more rows
    assert np.all(np.asarray(b.scanned) >= np.asarray(a.scanned))


def test_sharded_twins_match_replicated_backends(data, states):
    """Each ``*_sharded`` backend serves the same artifacts as its
    replicated twin, so scores/ids must agree (S = 1 here; the 8-device
    parity including cross-shard merge lives in test_distributed.py)."""
    _, _, Q, _ = data
    for sharded, single in (("exact_sharded", "exact"),
                            ("flat_sharded", "flat_adc"),
                            ("ivf_sharded", "ivf")):
        a = search.make(sharded).search(states[sharded], Q, k=10)
        b = search.make(single).search(states[single], Q, k=10)
        np.testing.assert_allclose(np.asarray(a.scores),
                                   np.asarray(b.scores), rtol=1e-5,
                                   atol=1e-5)
        assert np.mean(np.asarray(a.ids) == np.asarray(b.ids)) >= 0.95, sharded


def test_exact_beats_quantized_on_recall(data, states):
    _, _, Q, truth = data
    recalls = {}
    for backend in search.names():
        res = search.make(backend).search(states[backend], Q, k=10)
        recalls[backend] = recall_at_k(np.asarray(res.ids), truth)
    assert recalls["exact"] >= 0.999          # brute force IS the truth
    assert recalls["exact"] >= recalls["flat_adc"]
    assert recalls["exact"] >= recalls["ivf"]
    # probing can only lose candidates the flat scan keeps (tolerance for
    # chance overlap with the ground truth on what both get wrong)
    assert recalls["flat_adc"] >= recalls["ivf"] - 0.05


# ---------------------------------------------------------------------------
# Padding contract: k > candidate pool
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", search.names())
def test_padding_when_k_exceeds_candidates(backend):
    n_small, k = 12, 32
    X = synthetic.sift_like(jax.random.PRNGKey(5), n_small, DIM)
    R = rotations.random_rotation(jax.random.PRNGKey(6), DIM)
    Q = synthetic.sift_like(jax.random.PRNGKey(7), 4, DIM)
    cfg = CFG._replace(num_lists=2, codewords=8, nprobe=1, tile_rows=8)
    searcher = search.make(backend)
    state = searcher.build(jax.random.PRNGKey(8), X, R, cfg)
    res = searcher.search(state, Q, k=k)
    ids = np.asarray(res.ids)
    scores = np.asarray(res.scores)
    assert ids.shape == (4, k)
    assert np.all(ids[:, n_small:] == -1)          # pool is at most n_small
    assert np.all(np.isneginf(scores[ids < 0]))    # padding scores −inf
    assert np.all(np.isfinite(scores[ids >= 0]))
    # downstream recall ignores the padding rows entirely
    truth = np.argsort(-np.asarray(Q @ X.T), axis=1)[:, :10]
    rec = recall_at_k(ids, truth)
    assert 0.0 <= rec <= 1.0
    if backend == "exact":
        assert rec == 1.0


@pytest.mark.parametrize("backend", search.names())
def test_padding_when_deletes_shrink_pool_below_k(backend, data, states):
    """Live deletes can shrink the pool below k on ANY backend: the result
    must pad with (−1, −inf) past the live count — exactly the k > pool
    contract — and never surface a tombstoned id."""
    from repro import churn

    _, _, Q, _ = data
    k, live = 10, 6                       # tombstone down to live < k
    dead = np.arange(N - live, dtype=np.int32)
    state = churn.tombstone(states[backend], dead)
    # full probe on the ivf pair so "every survivor served" is scan-
    # complete (narrow probes may legitimately miss survivors' lists)
    kw = {"nprobe": L} if backend.startswith("ivf") else {}
    res = search.make(backend).search(state, Q, k=k, **kw)
    ids = np.asarray(res.ids)
    scores = np.asarray(res.scores)
    assert ids.shape == (B, k)
    assert not np.any(np.isin(ids, dead))              # no tombstone leaks
    assert np.all((ids == -1) | (ids >= N - live))
    assert np.all((ids == -1) == np.isneginf(scores))  # pad pairs up
    assert np.all(np.isfinite(scores[ids >= 0]))
    assert np.all((ids >= 0).sum(axis=1) == live)      # all survivors served


def test_direct_adcstate_construction_searches_exactly(data, states):
    """ADCState(index=...) without attach must derive the probe window from
    the index, not silently truncate probed lists to one block."""
    _, _, Q, _ = data
    searcher = search.make("ivf")
    bare = search.ADCState(index=states["ivf"].index, nprobe=L)
    want = searcher.search(states["ivf"], Q, k=10, nprobe=L)
    got = searcher.search(bare, Q, k=10)
    np.testing.assert_allclose(np.asarray(got.scores),
                               np.asarray(want.scores), rtol=1e-5, atol=1e-5)
    assert searcher.stats(bare)["max_blocks"] >= 1
    # and behind the Engine too: the state is normalized before it is ever
    # passed as a traced jit argument (regression: TracerArrayConversionError)
    engine = search.Engine(searcher, bare, k=10, nprobe=L, min_bucket=4)
    eres = engine.search(np.asarray(Q)[:8])
    np.testing.assert_allclose(np.asarray(eres.scores),
                               np.asarray(want.scores)[:8], rtol=1e-5,
                               atol=1e-5)


def test_shard_split_balances_sparse_ids(data, states):
    """shard_split partitions by id rank, so sparse/custom id spaces
    (build(ids=...), maintain.add) still split evenly instead of
    collapsing onto shard 0."""
    from repro.index import ivf as index_ivf

    X, R, _, _ = data
    sparse_ids = jnp.arange(N, dtype=jnp.int32) * 9973 + 5  # sparse, ragged
    index = index_ivf.build(jax.random.PRNGKey(3), X, R, CFG.ivf_config(),
                            ids=sparse_ids, train_size=512)
    parts = index_ivf.shard_split(index, 4)
    counts = [int(np.sum(np.asarray(p.ids) >= 0)) for p in parts]
    assert sum(counts) == N
    assert max(counts) - min(counts) <= 1, counts
    # and ids are preserved, not remapped
    got = np.sort(np.concatenate(
        [np.asarray(p.ids)[np.asarray(p.ids) >= 0] for p in parts]))
    np.testing.assert_array_equal(got, np.sort(np.asarray(sparse_ids)))


def test_direct_sharded_adcstate_prepared_path(data, states):
    """A directly-constructed ShardedADCState (max_blocks −1) must serve
    through search_prepared too, deriving the probe window like the
    replicated twin does."""
    _, _, Q, _ = data
    src = states["ivf_sharded"]
    bare = search.ShardedADCState(
        R=src.R, coarse=src.coarse, quantizer=src.quantizer,
        codes=src.codes, ids=src.ids, list_offsets=src.list_offsets,
        mesh=src.mesh, block_size=src.block_size, nprobe=L, axes=src.axes)
    assert bare.max_blocks == -1
    searcher = search.make("ivf_sharded")
    QR = searcher.rotate_queries(bare, Q)
    got = searcher.search_prepared(bare, QR, searcher.luts(bare, QR), k=10)
    want = searcher.search(states["ivf_sharded"], Q, k=10, nprobe=L)
    np.testing.assert_allclose(np.asarray(got.scores),
                               np.asarray(want.scores), rtol=1e-5, atol=1e-5)


def test_flat_single_list_build(data):
    """num_lists=1 (the pure flat scan quickstart/gnn use) builds/serves."""
    X, R, Q, truth = data
    cfg = CFG._replace(num_lists=1)
    searcher = search.make("flat_adc")
    state = searcher.build(jax.random.PRNGKey(9), X, R, cfg)
    res = searcher.search(state, Q, k=10)
    assert recall_at_k(np.asarray(res.ids), truth) > 0.1


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def test_engine_matches_direct_search(data, states):
    _, _, Q, _ = data
    searcher = search.make("ivf")
    engine = search.Engine(searcher, states["ivf"], k=10, nprobe=4,
                           min_bucket=4)
    for b in (3, 7, 16):
        got = engine.search(np.asarray(Q)[:b])
        want = searcher.search(states["ivf"], Q[:b], k=10, nprobe=4)
        np.testing.assert_allclose(np.asarray(got.scores),
                                   np.asarray(want.scores), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(got.ids),
                                      np.asarray(want.ids))


def test_engine_compiles_once_per_bucket_k_nprobe(data, states):
    _, _, Q, _ = data
    Qnp = np.asarray(Q)
    engine = search.Engine(search.make("ivf"), states["ivf"], k=10, nprobe=4,
                           min_bucket=4)
    for b in (3, 4, 7, 3):                 # buckets {4, 8}
        engine.search(Qnp[:b])
    assert engine.stats()["compiles"] == 2
    engine.search(Qnp[:3], k=5)            # new k -> one more
    engine.search(Qnp[:3], nprobe=L)       # new nprobe -> one more
    assert engine.stats()["compiles"] == 4
    for b in (3, 4, 7):                    # all warm now
        engine.search(Qnp[:b])
    engine.search(Qnp[:3], k=5)
    st = engine.stats()
    assert st["compiles"] == 4
    assert st["executables"] == 4
    assert st["requests"] == 10
    # oversized nprobe clamps to num_lists BEFORE keying the cache: both
    # requests share the nprobe=L executable compiled above
    engine.search(Qnp[:3], nprobe=10 * L)
    engine.search(Qnp[:3], nprobe=20 * L)
    st = engine.stats()
    assert st["compiles"] == 4
    assert engine.requests[-1]["nprobe"] == L   # records what was probed


def test_engine_lut_cache_hits_repeated_queries(data, states):
    _, _, Q, _ = data
    Qnp = np.asarray(Q)
    engine = search.Engine(search.make("flat_adc"), states["flat_adc"], k=10,
                           min_bucket=4)
    engine.search(Qnp[:8])
    st = engine.stats()
    assert st["lut_misses"] == 8 and st["lut_hits"] == 0
    engine.search(Qnp[:8])                 # same queries: all cached
    st = engine.stats()
    assert st["lut_hits"] == 8 and st["lut_misses"] == 8
    engine.search(Qnp[4:12])               # half cached
    st = engine.stats()
    assert st["lut_hits"] == 12 and st["lut_misses"] == 12
    # duplicate rows in one batch: counted per served row, computed once
    dup = np.stack([Qnp[14], Qnp[14], Qnp[14]])
    engine.search(dup)
    st = engine.stats()
    assert st["lut_hits"] == 12 and st["lut_misses"] == 15
    assert st["lut_cached_rows"] == 13      # one entry for the triplicate
    engine.search(dup)                     # now fully cached
    assert engine.stats()["lut_hits"] == 15


def test_engine_lut_eviction_under_pressure(data, states):
    """A full LRU must never evict rows the in-flight batch still needs:
    batches wider than the cache and steady-state hit/miss mixes both
    assemble (regression for read-after-evict KeyError)."""
    _, _, Q, _ = data
    Qnp = np.asarray(Q)
    engine = search.Engine(search.make("flat_adc"), states["flat_adc"], k=10,
                           min_bucket=4, lut_cache_rows=4)
    engine.search(Qnp[:8])                  # batch wider than the cache
    assert engine.stats()["lut_cached_rows"] == 4
    engine.search(Qnp[4:8])                 # hits on the survivors
    assert engine.stats()["lut_hits"] == 4
    engine.search(Qnp[2:7])                 # mixed: hits + evicting misses
    res = engine.search(Qnp)                # full batch, 4x the cache
    assert res.ids.shape == (B, 10)
    st = engine.stats()
    assert st["lut_cached_rows"] == 4
    assert st["lut_hits"] == 4 + 3 + 4      # 4,5,6 then 2,3,5,6 survivors


def test_engine_live_refresh_between_batches(data, states):
    _, R, Q, _ = data
    Qnp = np.asarray(Q)
    engine = search.Engine(search.make("ivf"), states["ivf"], k=10, nprobe=4,
                           min_bucket=4)
    before = engine.search(Qnp[:8])
    compiles = engine.stats()["compiles"]

    engine.refresh(_delta(R))
    after = engine.search(Qnp[:8])
    st = engine.stats()
    assert st["refreshes"] == 1
    assert st["compiles"] == compiles       # zero recompiles across refresh
    assert st["lut_misses"] == 16           # LUT cache invalidated (R moved)
    # scores are rotation-invariant; the refreshed engine still serves them
    np.testing.assert_allclose(np.asarray(before.scores),
                               np.asarray(after.scores), rtol=1e-4, atol=1e-4)


def test_engine_serves_sharded_backend(data, states):
    """The sharded family behind the Engine, unchanged: one compile per
    (bucket, k, nprobe), LUT cache live, refresh without recompiles."""
    _, R, Q, _ = data
    Qnp = np.asarray(Q)
    engine = search.Engine(search.make("ivf_sharded"), states["ivf_sharded"],
                           k=10, nprobe=4, min_bucket=4)
    for b in (3, 4, 7, 3):                 # buckets {4, 8}
        got = engine.search(Qnp[:b])
        want = search.make("ivf_sharded").search(
            states["ivf_sharded"], Q[:b], k=10, nprobe=4)
        np.testing.assert_allclose(np.asarray(got.scores),
                                   np.asarray(want.scores), rtol=1e-5,
                                   atol=1e-5)
    st = engine.stats()
    assert st["compiles"] == 2
    assert st["lut_misses"] > 0            # prepared path active
    compiles = st["compiles"]
    engine.refresh(_delta(R))
    after = engine.search(Qnp[:8])
    st = engine.stats()
    assert st["refreshes"] == 1
    assert st["compiles"] == compiles      # zero recompiles across refresh
    assert after.ids.shape == (8, 10)


def test_engine_plain_path_and_chunking(data, states):
    _, _, Q, _ = data
    engine = search.Engine(search.make("exact"), states["exact"], k=10,
                           min_bucket=4, max_bucket=8)
    res = engine.search(np.asarray(Q))      # B=16 > max_bucket: chunked
    assert res.ids.shape == (B, 10)
    st = engine.stats()
    assert st["requests"] == 2              # two max_bucket chunks
    assert st["lut_misses"] == 0            # exact has no LUT path
    assert st["searcher"]["backend"] == "exact"
    with pytest.raises(ValueError, match="empty query batch"):
        engine.search(np.zeros((0, DIM), np.float32))
    # nprobe on a backend that cannot honor it is an error, not a no-op
    with pytest.raises(ValueError, match="does not take nprobe"):
        engine.search(np.asarray(Q)[:4], nprobe=4)
    with pytest.raises(ValueError, match="does not take nprobe"):
        search.Engine(search.make("exact"), states["exact"], nprobe=4)


# ---------------------------------------------------------------------------
# PR 7: streaming exact scan, int8 LUTs, fused refresh (trace-counter checks)
# ---------------------------------------------------------------------------


def test_streaming_exact_matches_resident_exact(data, states):
    """The double-buffered host-streamed scan is the same oracle: scores
    bit-identical to the resident ``exact`` backend, through the Engine's
    eager (engine_jit=False) path included."""
    _, _, Q, _ = data
    want = search.make("exact").search(states["exact"], Q, k=10)
    got = search.make("exact_stream").search(states["exact_stream"], Q, k=10)
    np.testing.assert_array_equal(np.asarray(want.ids), np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(want.scores),
                                  np.asarray(got.scores))
    engine = search.Engine(search.make("exact_stream"),
                           states["exact_stream"], k=10, min_bucket=4)
    eres = engine.search(np.asarray(Q))
    np.testing.assert_array_equal(np.asarray(want.ids), np.asarray(eres.ids))
    # the host loop is never wrapped in an outer jit: zero Engine compiles
    assert engine.stats()["compiles"] == 0
    assert engine.stats()["searcher"]["streaming"] is True


def test_streaming_exact_fused_refresh_moves_no_tiles(data):
    """Fused mode: refresh touches only R — host tiles stay byte-identical
    and results stay exact (the delta cancels against the frozen corpus)."""
    X, R, Q, truth = data
    searcher = search.make("exact_stream")
    state = searcher.build(jax.random.PRNGKey(3), X, R,
                           CFG._replace(fused_refresh=True))
    tiles_before = [t.copy() for t in state.tiles]
    moved = searcher.refresh(state, _delta(R))
    for a, b in zip(tiles_before, moved.tiles):
        np.testing.assert_array_equal(a, b)      # zero corpus-side movement
    assert float(jnp.max(jnp.abs(moved.R - state.R))) > 0
    res = searcher.search(moved, Q, k=10)
    assert recall_at_k(np.asarray(res.ids), truth) >= 0.999
    assert searcher.stats(moved)["fused_refresh"] is True


@pytest.mark.parametrize("lut_dtype", ["int8", "uint8"])
def test_int8_luts_preserve_recall(data, states, lut_dtype):
    """Quantized ADC tables keep recall@10 within 0.01 of f32 on the same
    codes, for both the flat scan and the probed scan."""
    _, _, Q, truth = data
    index = states["ivf"].index
    for backend, attach_kw in (("flat_adc", {}), ("ivf", {"nprobe": L})):
        searcher = search.make(backend)
        f32 = searcher.attach(index, **attach_kw)
        q8 = searcher.attach(index, lut_dtype=lut_dtype, **attach_kw)
        r_f32 = searcher.search(f32, Q, k=10)
        r_q8 = searcher.search(q8, Q, k=10)
        rec_f32 = recall_at_k(np.asarray(r_f32.ids), truth)
        rec_q8 = recall_at_k(np.asarray(r_q8.ids), truth)
        assert rec_q8 >= rec_f32 - 0.01, (backend, lut_dtype)


def test_engine_lut_cache_keys_on_dtype(data, states):
    """Two Engines over the same index at different lut_dtypes must not
    alias cache entries: the key includes the dtype, so a dtype change is
    a miss, never a silently-wrong hit."""
    _, _, Q, _ = data
    Qnp = np.asarray(Q)
    searcher = search.make("flat_adc")
    state8 = searcher.attach(states["ivf"].index, lut_dtype="int8")
    engine = search.Engine(searcher, state8, k=10, min_bucket=4)
    engine.search(Qnp[:8])
    assert engine.stats()["lut_misses"] == 8
    engine.search(Qnp[:8])
    assert engine.stats()["lut_hits"] == 8
    # swap the state to f32 under the same Engine: same queries MISS
    engine.state = searcher.attach(states["ivf"].index)
    engine.search(Qnp[:8])
    st = engine.stats()
    assert st["lut_misses"] == 16 and st["lut_hits"] == 8
    key = engine._lut_key(Qnp[0])
    assert key[1] == "float32"                 # dtype is part of the key


def test_engine_fused_refresh_keeps_cache_and_executables(data, states):
    """The PR 7 acceptance trace: a fused within-subspace refresh costs the
    Engine zero recompiles AND zero LUT-cache invalidations — the epoch,
    the cached rows, and every executable survive; a cross-subspace delta
    still invalidates."""
    _, R, Q, _ = data
    Qnp = np.asarray(Q)
    searcher = search.make("flat_adc")
    state = searcher.attach(states["ivf"].index, lut_dtype="int8",
                            fused_refresh=True)
    engine = search.Engine(searcher, state, k=10, min_bucket=4)
    engine.search(Qnp[:8])
    compiles = engine.stats()["compiles"]
    assert engine.stats()["lut_invalidations"] == 0

    # subspace_gcd emits purely within-subspace pairs: LUTs provably valid
    engine.refresh(_delta(R))
    after = engine.search(Qnp[:8])
    st = engine.stats()
    assert st["refreshes"] == 1
    assert st["compiles"] == compiles          # zero recompiles
    assert st["lut_invalidations"] == 0        # zero cache rebuilds
    assert st["lut_hits"] == 8                 # the cached rows were REUSED
    assert st["lut_epoch"] == 0
    assert after.ids.shape == (8, 10)

    # a cross-subspace pair breaks the invariance proof: epoch advances
    cross = rotations.GivensDelta(pi=jnp.array([0]),
                                  pj=jnp.array([DIM - 1]),
                                  theta=jnp.array([1e-3]))
    engine.refresh(cross)
    engine.search(Qnp[:8])
    st = engine.stats()
    assert st["lut_invalidations"] == 1
    assert st["lut_epoch"] == 1
    assert st["lut_misses"] == 16
    assert st["compiles"] == compiles          # executables still survive


def test_fused_refresh_matches_eager_refresh(data):
    """Fused (query-side) and eager (corpus-side) refresh are the same
    math: after identical delta sequences the two states serve matching
    top-k on PQ and on depth-2 RQ."""
    X, R, Q, _ = data
    for depth in (1, 2):
        cfg = CFG._replace(depth=depth)
        searcher = search.make("flat_adc")
        eager = searcher.build(jax.random.PRNGKey(3), X, R, cfg)
        fused = searcher.build(jax.random.PRNGKey(3), X, R,
                               cfg._replace(fused_refresh=True))
        for i in range(3):
            d = _delta(R, key=i)
            eager = searcher.refresh(eager, d)
            fused = searcher.refresh(fused, d)
        r_e = searcher.search(eager, Q, k=10)
        r_f = searcher.search(fused, Q, k=10)
        np.testing.assert_allclose(np.asarray(r_e.scores),
                                   np.asarray(r_f.scores), rtol=1e-4,
                                   atol=1e-4)
        assert np.mean(np.asarray(r_e.ids) == np.asarray(r_f.ids)) >= 0.95


def test_sharded_fused_refresh_and_int8(data, states):
    """The sharded quantized twins inherit fused refresh + int8 LUTs: the
    frozen-index fused sharded state matches its REPLICATED fused twin
    after the same refresh (the shard merge only reorders candidates), and
    the invariance capability reports like the replicated one."""
    from repro.launch.mesh import make_data_mesh

    _, R, Q, _ = data
    mesh = make_data_mesh()
    index = states["ivf"].index
    searcher = search.make("flat_sharded")
    fused = searcher.attach(index, mesh=mesh, lut_dtype="int8",
                            fused_refresh=True)
    eager = searcher.attach(index, mesh=mesh)
    replicated = search.make("flat_adc").attach(index, lut_dtype="int8",
                                                fused_refresh=True)
    d = _delta(R)
    assert searcher.luts_refresh_invariant(fused, d) is True
    assert searcher.luts_refresh_invariant(eager, d) is False
    fused = searcher.refresh(fused, d)
    replicated = search.make("flat_adc").refresh(replicated, d)
    r_f = searcher.search(fused, Q, k=10)
    r_r = search.make("flat_adc").search(replicated, Q, k=10)
    np.testing.assert_allclose(np.asarray(r_r.scores),
                               np.asarray(r_f.scores), rtol=1e-5, atol=1e-5)
    assert np.mean(np.asarray(r_r.ids) == np.asarray(r_f.ids)) >= 0.95
    st = searcher.stats(fused)
    assert st["lut_dtype"] == "int8" and st["fused_refresh"] is True
