"""Distribution tests that need multiple devices: run in a subprocess with
8 fake CPU devices so the main pytest process keeps its single-device view
(the dry-run spec requires XLA_FLAGS never be set globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=420,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return json.loads(out.stdout.splitlines()[-1])


HEADER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_mesh_compat
""")


def test_sharded_embedding_lookup_matches_dense():
    res = _run(HEADER + textwrap.dedent("""
        from repro.models import embedding
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        V, dim = 64, 8
        table = jax.random.normal(jax.random.PRNGKey(0), (V, dim))
        ids = jax.random.randint(jax.random.PRNGKey(1), (10,), 0, V)
        want = np.asarray(embedding.lookup(table, ids))
        got = np.asarray(embedding.sharded_lookup(table, ids, mesh, "model"))
        print(json.dumps({"ok": bool(np.allclose(got, want, atol=1e-5))}))
    """))
    assert res["ok"]


def test_mini_dryrun_cell_compiles_on_8_devices():
    """The full dry-run pattern at 8 fake devices: lower + compile a train
    cell and parse roofline terms."""
    res = _run(HEADER + textwrap.dedent("""
        import repro.launch.mesh as mesh_lib
        mesh_lib.make_production_mesh = lambda multi_pod=False: make_mesh_compat(
            (2,2,2) if multi_pod else (2,4),
            ("pod","data","model") if multi_pod else ("data","model"))
        from repro.launch.dryrun import run_cell
        rec = run_cell("graphsage-reddit", "molecule", False, verbose=False)
        rec2 = run_cell("graphsage-reddit", "molecule", True, verbose=False)
        print(json.dumps({
            "ok": bool(rec["ok"] and rec2["ok"]),
            "err": (rec.get("error") or "") + (rec2.get("error") or ""),
            "has_terms": "compute_s" in rec.get("report", {}),
        }))
    """))
    assert res["ok"], res.get("err")
    assert res["has_terms"]


def test_ef_psum_int8_under_shard_map():
    res = _run(HEADER + textwrap.dedent("""
        from jax.sharding import PartitionSpec as P
        from repro.training import grad_compress as gc
        mesh = make_mesh_compat((8,), ("pod",))
        f = gc.make_compressed_crosspod_psum(mesh, "pod")
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))  # per-pod grads
        err = jnp.zeros((8, 64))
        summed, err2 = f(g, err)
        want = np.asarray(jnp.sum(g, axis=0))
        got = np.asarray(summed)
        rel = float(np.abs(got - want).max() / (np.abs(want).max() + 1e-9))
        print(json.dumps({"rel": rel, "err_shape": list(err2.shape)}))
    """))
    assert res["rel"] < 0.15  # int8 single-shot error; EF cleans it over steps
    assert res["err_shape"] == [8, 64]


def test_production_mesh_shapes():
    res = _run(HEADER + textwrap.dedent("""
        # make_mesh with 512 logical devices over 8 physical is not possible;
        # verify the mesh FUNCTION contract on the debug mesh instead and the
        # axis names on the real one via spec inspection.
        from repro.launch import mesh as mesh_lib
        import inspect
        src = inspect.getsource(mesh_lib.make_production_mesh)
        print(json.dumps({
            "single": "(16, 16)" in src, "multi": "(2, 16, 16)" in src,
            "axes": '"pod", "data", "model"' in src or "('pod', 'data', 'model')" in src,
        }))
    """))
    assert res["single"] and res["multi"] and res["axes"]
