"""Distribution tests that need multiple devices: run in a subprocess with
8 fake CPU devices so the main pytest process keeps its single-device view
(the dry-run spec requires XLA_FLAGS never be set globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=420,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return json.loads(out.stdout.splitlines()[-1])


HEADER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_mesh_compat
""")


def test_sharded_embedding_lookup_matches_dense():
    res = _run(HEADER + textwrap.dedent("""
        from repro.models import embedding
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        V, dim = 64, 8
        table = jax.random.normal(jax.random.PRNGKey(0), (V, dim))
        ids = jax.random.randint(jax.random.PRNGKey(1), (10,), 0, V)
        want = np.asarray(embedding.lookup(table, ids))
        got = np.asarray(embedding.sharded_lookup(table, ids, mesh, "model"))
        print(json.dumps({"ok": bool(np.allclose(got, want, atol=1e-5))}))
    """))
    assert res["ok"]


def test_mini_dryrun_cell_compiles_on_8_devices():
    """The full dry-run pattern at 8 fake devices: lower + compile a train
    cell and parse roofline terms."""
    res = _run(HEADER + textwrap.dedent("""
        import repro.launch.mesh as mesh_lib
        mesh_lib.make_production_mesh = lambda multi_pod=False: make_mesh_compat(
            (2,2,2) if multi_pod else (2,4),
            ("pod","data","model") if multi_pod else ("data","model"))
        from repro.launch.dryrun import run_cell
        rec = run_cell("graphsage-reddit", "molecule", False, verbose=False)
        rec2 = run_cell("graphsage-reddit", "molecule", True, verbose=False)
        print(json.dumps({
            "ok": bool(rec["ok"] and rec2["ok"]),
            "err": (rec.get("error") or "") + (rec2.get("error") or ""),
            "has_terms": "compute_s" in rec.get("report", {}),
        }))
    """))
    assert res["ok"], res.get("err")
    assert res["has_terms"]


def test_ef_psum_int8_under_shard_map():
    res = _run(HEADER + textwrap.dedent("""
        from jax.sharding import PartitionSpec as P
        from repro.training import grad_compress as gc
        mesh = make_mesh_compat((8,), ("pod",))
        f = gc.make_compressed_crosspod_psum(mesh, "pod")
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))  # per-pod grads
        err = jnp.zeros((8, 64))
        summed, err2 = f(g, err)
        want = np.asarray(jnp.sum(g, axis=0))
        got = np.asarray(summed)
        rel = float(np.abs(got - want).max() / (np.abs(want).max() + 1e-9))
        print(json.dumps({"rel": rel, "err_shape": list(err2.shape)}))
    """))
    assert res["rel"] < 0.15  # int8 single-shot error; EF cleans it over steps
    assert res["err_shape"] == [8, 64]


def test_sharded_backends_match_single_device():
    """Every ``*_sharded`` backend on an 8-fake-device mesh returns its
    single-device twin's results — same scores (bit-identical per-row ADC
    math; merge only reorders), same ids — including the k-exceeds-local-
    pool edge where each shard holds fewer than k rows."""
    res = _run(HEADER + textwrap.dedent("""
        from repro import rotations, search
        from repro.data import synthetic
        from repro.launch.mesh import make_data_mesh

        DIM, SUB, K, L, BS = 16, 4, 16, 8, 8
        N, B = 2000, 16
        CFG = search.SearchConfig(num_lists=L, subspaces=SUB, codewords=K,
                                  block_size=BS, nprobe=4, tile_rows=256)
        X = synthetic.sift_like(jax.random.PRNGKey(0), N, DIM)
        R = rotations.random_rotation(jax.random.PRNGKey(1), DIM)
        Q = synthetic.sift_like(jax.random.PRNGKey(2), B, DIM)
        mesh = make_data_mesh()
        out = {"devices": jax.device_count()}
        for sharded, single in (("exact_sharded", "exact"),
                                ("flat_sharded", "flat_adc"),
                                ("ivf_sharded", "ivf")):
            s = search.make(sharded, mesh=mesh)
            st = s.build(jax.random.PRNGKey(3), X, R, CFG)
            got = s.search(st, Q, k=10)
            ss = search.make(single)
            want = ss.search(ss.build(jax.random.PRNGKey(3), X, R, CFG),
                             Q, k=10)
            out[sharded] = dict(
                scores_close=bool(np.allclose(np.asarray(got.scores),
                                              np.asarray(want.scores),
                                              atol=1e-5)),
                id_agree=float(np.mean(np.asarray(got.ids)
                                       == np.asarray(want.ids))),
                shards=int(s.stats(st)["shards"]),
            )
            # k > per-shard pool: 50 rows over 8 shards, k = 16
            Xs = synthetic.sift_like(jax.random.PRNGKey(5), 50, DIM)
            small = s.build(jax.random.PRNGKey(8), Xs, R, CFG._replace(
                num_lists=2, codewords=8, nprobe=2, tile_rows=8))
            r = s.search(small, Xs[:4], k=16)
            ids = np.asarray(r.ids); sc = np.asarray(r.scores)
            out[sharded]["k_gt_pool"] = bool(
                ids.shape == (4, 16)
                and np.all(np.isneginf(sc[ids < 0]))
                and np.all(np.isfinite(sc[ids >= 0]))
                and np.all(np.diff(sc, axis=1) <= 1e-6))

        # ("pod", "data") mesh: the shard count must be the FULL product of
        # the row axes (2×4 = 8), not just the "data" extent — and the
        # stacked state must actually partition, not silently replicate
        pod_mesh = make_mesh_compat((2, 4), ("pod", "data"))
        s = search.make("ivf_sharded", mesh=pod_mesh)
        st = s.build(jax.random.PRNGKey(3), X, R, CFG)
        got = s.search(st, Q, k=10)
        ss = search.make("ivf")
        want = ss.search(ss.build(jax.random.PRNGKey(3), X, R, CFG), Q, k=10)
        shardings = {str(d) for d in st.codes.sharding.device_set}
        out["pod_data"] = dict(
            shards=int(s.stats(st)["shards"]),
            scores_close=bool(np.allclose(np.asarray(got.scores),
                                          np.asarray(want.scores),
                                          atol=1e-5)),
            devices_holding_codes=len(shardings),
            replicated=bool(st.codes.sharding.is_fully_replicated),
        )
        print(json.dumps(out))
    """))
    assert res["devices"] == 8
    for backend in ("exact_sharded", "flat_sharded", "ivf_sharded"):
        b = res[backend]
        assert b["shards"] == 8, (backend, b)
        assert b["scores_close"], (backend, b)
        assert b["id_agree"] >= 0.95, (backend, b)
        assert b["k_gt_pool"], (backend, b)
    assert res["pod_data"]["shards"] == 8, res["pod_data"]
    assert res["pod_data"]["scores_close"], res["pod_data"]
    assert not res["pod_data"]["replicated"], res["pod_data"]


def test_sharded_engine_refresh_without_recompile():
    """search.Engine over ivf_sharded on 8 devices: one compile per
    (bucket, k, nprobe) and a RotationDelta refresh that recompiles
    nothing while scores stay put (rotation-invariant inner products)."""
    res = _run(HEADER + textwrap.dedent("""
        from repro import rotations, search
        from repro.data import synthetic
        from repro.launch.mesh import make_data_mesh

        DIM, SUB, K, L, BS = 16, 4, 16, 8, 8
        N = 2000
        CFG = search.SearchConfig(num_lists=L, subspaces=SUB, codewords=K,
                                  block_size=BS, nprobe=4)
        X = synthetic.sift_like(jax.random.PRNGKey(0), N, DIM)
        R = rotations.random_rotation(jax.random.PRNGKey(1), DIM)
        Q = np.asarray(synthetic.sift_like(jax.random.PRNGKey(2), 16, DIM))
        s = search.make("ivf_sharded", mesh=make_data_mesh())
        state = s.build(jax.random.PRNGKey(3), X, R, CFG)
        engine = search.Engine(s, state, k=10, nprobe=4, min_bucket=4)
        for b in (3, 4, 7, 3):
            engine.search(Q[:b])
        compiles = engine.stats()["compiles"]
        before = engine.search(Q[:8])

        G = jax.random.normal(jax.random.PRNGKey(9), (DIM, DIM))
        learner = rotations.make("subspace_gcd", sub=DIM // SUB)
        _, delta = learner.update(learner.init_from(R), G, 1e-3,
                                  jax.random.PRNGKey(0))
        engine.refresh(delta)
        after = engine.search(Q[:8])
        st = engine.stats()
        print(json.dumps({
            "compiles_before": compiles,
            "compiles_after": st["compiles"],
            "refreshes": st["refreshes"],
            "scores_stable": bool(np.allclose(np.asarray(before.scores),
                                              np.asarray(after.scores),
                                              atol=1e-4)),
        }))
    """))
    assert res["compiles_before"] == 2          # buckets {4, 8}
    assert res["compiles_after"] == res["compiles_before"]
    assert res["refreshes"] == 1
    assert res["scores_stable"]


def test_sharded_kmeans_matches_single_device_fit():
    """quant.kmeans.kmeans_sharded (per-shard assign + psum accumulate)
    reaches the single-device fit's distortion — same Lloyd update, only
    the partial-sum order differs."""
    res = _run(HEADER + textwrap.dedent("""
        from repro.data import synthetic
        from repro.launch.mesh import make_data_mesh
        from repro.quant import kmeans as km

        X = synthetic.sift_like(jax.random.PRNGKey(0), 1027, 16)  # ragged
        cb1 = km.vq_kmeans(jax.random.PRNGKey(7), X, 16, iters=8)
        cb2 = km.vq_kmeans_sharded(jax.random.PRNGKey(7), X, 16,
                                   mesh=make_data_mesh(), iters=8)
        Xn = np.asarray(X)
        def distortion(cb):
            d = ((Xn[:, None, :] - np.asarray(cb)[None]) ** 2).sum(-1)
            return float(d.min(axis=1).mean())
        d1, d2 = distortion(cb1), distortion(cb2)
        print(json.dumps({"d_single": d1, "d_sharded": d2,
                          "shape_ok": np.asarray(cb2).shape == (16, 16)}))
    """))
    assert res["shape_ok"]
    assert res["d_sharded"] <= res["d_single"] * 1.05, res


def test_sharded_ingest_never_concatenates_corpus():
    """index.ivf.build_sharded consumes per-shard chunks (the host-sharded
    ingest path) and the attached state serves: recall in the same range
    as the replicated build trained on the same sample budget."""
    res = _run(HEADER + textwrap.dedent("""
        from repro import rotations, search
        from repro.data import synthetic
        from repro.index import ivf as index_ivf
        from repro.launch.mesh import make_data_mesh
        from repro.metrics import recall_at_k

        DIM, N = 16, 2000
        cfg = search.SearchConfig(num_lists=8, subspaces=4, codewords=16,
                                  block_size=8, nprobe=8)
        X = synthetic.sift_like(jax.random.PRNGKey(0), N, DIM)
        R = rotations.random_rotation(jax.random.PRNGKey(1), DIM)
        Q = synthetic.sift_like(jax.random.PRNGKey(2), 16, DIM)
        mesh = make_data_mesh()
        chunks = [np.asarray(X)[s::8] for s in range(8)]
        parts = index_ivf.build_sharded(
            jax.random.PRNGKey(3), chunks, R, cfg.ivf_config(),
            train_size=1024, mesh=mesh)
        state = search.attach_shards(parts, mesh=mesh, nprobe=8)
        res = search.make("ivf_sharded").search(state, Q, k=10)
        # chunk-local ids -> original row ids for the recall check
        order = np.concatenate([np.arange(N)[s::8] for s in range(8)])
        got = np.asarray(res.ids)
        remap = np.where(got >= 0, order[np.clip(got, 0, N - 1)], -1)
        truth = np.argsort(-np.asarray(Q @ X.T), axis=1)[:, :10]
        single = search.make("ivf").build(
            jax.random.PRNGKey(3), X, R,
            cfg._replace(train_size=1024))
        r_single = recall_at_k(
            np.asarray(search.make("ivf").search(
                single, Q, k=10, nprobe=8).ids), truth)
        # independently-fit per-chunk indexes do NOT share quantizers —
        # attach_shards must refuse them, not serve silently wrong scores
        rogue = index_ivf.build(jax.random.PRNGKey(11),
                                jnp.asarray(chunks[0]), R, cfg.ivf_config())
        try:
            search.attach_shards([rogue] + parts[1:], mesh=mesh)
            mismatch_raises = False
        except ValueError:
            mismatch_raises = True
        print(json.dumps({
            "recall": recall_at_k(remap, truth),
            "recall_single": r_single,
            "rows": int(search.make("ivf_sharded").stats(state)["rows"]),
            "mismatch_raises": mismatch_raises,
        }))
    """))
    assert res["rows"] == 2000
    assert res["mismatch_raises"]
    # different training sample (chunk heads vs corpus head) — same range
    assert res["recall"] >= res["recall_single"] - 0.15, res


def test_constrain_is_noop_outside_mesh_context():
    """sharding.rules.constrain must pass arrays through untouched when no
    mesh context is active (the compat.current_mesh probe returns None)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.sharding import rules as sh

    assert compat.current_mesh() is None
    x = jnp.arange(12.0).reshape(3, 4)
    y = sh.constrain(x, ("act_batch", None), sh.IVF_RULES)
    assert y is x                       # literally untouched, not a copy
    # and under jit the constraint is absent, not an error
    out = jax.jit(lambda a: sh.constrain(a, ("act_batch", None),
                                         sh.IVF_RULES) * 2.0)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0)


def test_current_mesh_probe_sees_context():
    """compat.current_mesh resolves the ambient mesh on this JAX version
    (public get_abstract_mesh first, legacy thread_resources fallback)."""
    from repro import compat
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    with mesh:
        seen = compat.current_mesh()
        assert seen is not None and not seen.empty
        assert set(dict(seen.shape)) == {"data", "model"}
    assert compat.current_mesh() is None


def test_ivf_sharded_rule_table_row_shards():
    """The ivf_sharded rule table maps corpus rows to ("pod", "data") and
    is registered for config lookup."""
    from repro.launch.mesh import make_mesh_compat
    from repro.sharding import rules as sh

    assert sh.RULE_REGISTRY["ivf_sharded"] is sh.IVF_SHARDED_RULES
    assert sh.IVF_SHARDED_RULES["ivf_rows"] == ("pod", "data")
    assert sh.IVF_SHARDED_RULES["ivf_cap"] == ("pod", "data")
    # resolves on a data-only mesh: absent axes are filtered, and the spec
    # actually partitions the leading (shard) axis
    mesh = make_mesh_compat((1,), ("data",))
    spec = sh.logical_to_spec(("ivf_rows", None, None),
                              sh.IVF_SHARDED_RULES, mesh, (1, 64, 4))
    assert spec[0] in ("data", ("data",))
    # the replicated table still replicates rows (migration contract)
    assert sh.IVF_RULES["ivf_cap"] is None


def test_sharded_backends_record_per_shard_metrics():
    """With repro.obs enabled, build/attach on an 8-fake-device mesh records
    one ``index.shard_rows`` gauge per shard plus the imbalance gauge, and
    every sharded ``stats()`` reports per-shard occupancy — the signals the
    ops story needs to catch a lopsided corpus before it skews latency."""
    res = _run(HEADER + textwrap.dedent("""
        from repro import obs, rotations, search
        from repro.data import synthetic
        from repro.launch.mesh import make_data_mesh

        DIM, N = 16, 2000
        CFG = search.SearchConfig(num_lists=8, subspaces=4, codewords=16,
                                  block_size=8, nprobe=4, tile_rows=256)
        X = synthetic.sift_like(jax.random.PRNGKey(0), N, DIM)
        R = rotations.random_rotation(jax.random.PRNGKey(1), DIM)
        mesh = make_data_mesh()
        obs.enable()
        exact = search.make("exact_sharded", mesh=mesh)
        ex_state = exact.build(jax.random.PRNGKey(3), X, R, CFG)
        ivf_state = search.make("ivf").build(jax.random.PRNGKey(3), X, R, CFG)
        search.IVFSharded.attach(ivf_state.index, mesh=mesh, nprobe=4)
        snap = obs.default_registry().snapshot()
        gauges = snap["gauges"]
        ex_rows = [gauges[f"index.shard_rows{{backend=exact_sharded,shard={s}}}"]
                   for s in range(8)]
        adc_rows = [gauges[f"index.shard_rows{{backend=adc_sharded,shard={s}}}"]
                    for s in range(8)]
        st = exact.stats(ex_state)
        layouts = obs.default_registry().events("shard_layout")
        print(json.dumps({
            "ex_rows": ex_rows,
            "adc_rows": adc_rows,
            "ex_imbalance": gauges["index.shard_imbalance{backend=exact_sharded}"],
            "adc_imbalance": gauges["index.shard_imbalance{backend=adc_sharded}"],
            "stats_rows": st["rows_per_shard"],
            "stats_imbalance": st["shard_imbalance"],
            "layout_backends": sorted(e["backend"] for e in layouts),
        }))
    """))
    assert sum(res["ex_rows"]) == 2000          # every row on exactly one shard
    assert sum(res["adc_rows"]) == 2000
    assert res["stats_rows"] == res["ex_rows"]
    assert res["ex_imbalance"] >= 1.0 and res["adc_imbalance"] >= 1.0
    assert res["ex_imbalance"] == res["stats_imbalance"]
    # imbalance stays sane on a near-even split: max/mean < 2
    assert res["ex_imbalance"] < 2.0, res
    assert res["layout_backends"] == ["adc_sharded", "exact_sharded"]


def test_production_mesh_shapes():
    res = _run(HEADER + textwrap.dedent("""
        # make_mesh with 512 logical devices over 8 physical is not possible;
        # verify the mesh FUNCTION contract on the debug mesh instead and the
        # axis names on the real one via spec inspection.
        from repro.launch import mesh as mesh_lib
        import inspect
        src = inspect.getsource(mesh_lib.make_production_mesh)
        print(json.dumps({
            "single": "(16, 16)" in src, "multi": "(2, 16, 16)" in src,
            "axes": '"pod", "data", "model"' in src or "('pod', 'data', 'model')" in src,
        }))
    """))
    assert res["single"] and res["multi"] and res["axes"]
