"""Pallas kernels: shape/dtype sweeps + hypothesis, allclose vs ref.py
oracles. interpret=True on CPU per the deliverable contract."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import givens
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n", [(8, 8), (64, 32), (100, 64), (257, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_givens_rotate_sweep(m, n, dtype):
    key = jax.random.PRNGKey(m * 1000 + n)
    X = jax.random.normal(key, (m, n)).astype(dtype)
    perm = np.random.RandomState(0).permutation(n)
    pi = jnp.asarray(perm[: n // 2])
    pj = jnp.asarray(perm[n // 2: 2 * (n // 2)])
    theta = jax.random.normal(jax.random.fold_in(key, 1), (n // 2,))
    got = ops.apply_pair_rotations(X, pi, pj, theta)
    want = givens.apply_pair_rotations(X, pi, pj, theta)
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("n", [32, 128, 384, 512])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gcd_score_sweep(n, dtype):
    key = jax.random.PRNGKey(n)
    G = jax.random.normal(key, (n, n)).astype(dtype)
    R = jax.random.normal(jax.random.fold_in(key, 1), (n, n)).astype(dtype)
    got = np.asarray(ops.gcd_score(G, R))
    want = np.asarray(ref.gcd_score_ref(G.astype(jnp.float32),
                                        R.astype(jnp.float32)))
    tol = 1e-3 * n if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, atol=tol, rtol=1e-2)
    np.testing.assert_allclose(got, -got.T, atol=1e-5)  # antisymmetric


@pytest.mark.parametrize("m,D,K,sub", [(17, 2, 4, 8), (300, 8, 16, 8),
                                       (1024, 4, 256, 16)])
def test_pq_assign_sweep(m, D, K, sub):
    key = jax.random.PRNGKey(m)
    X = jax.random.normal(key, (m, D * sub))
    cb = jax.random.normal(jax.random.fold_in(key, 1), (D, K, sub))
    got = np.asarray(ops.pq_assign(X, cb))
    want = np.asarray(ref.pq_assign_ref(X, cb))
    assert np.array_equal(got, want)


@given(N=st.integers(10, 600), D=st.sampled_from([2, 8]),
       K=st.sampled_from([4, 16]), b=st.integers(1, 5))
@settings(deadline=None, max_examples=12)
def test_adc_lookup_property(N, D, K, b):
    key = jax.random.PRNGKey(N)
    lut = jax.random.normal(key, (b, D, K))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (N, D), 0, K)
    got = np.asarray(ops.adc_lookup(lut, codes))
    want = np.asarray(ref.adc_lookup_ref(lut, codes))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@given(L=st.integers(1, 200), V=st.integers(10, 500),
       dim=st.sampled_from([8, 16]), B=st.integers(1, 20),
       weighted=st.booleans())
@settings(deadline=None, max_examples=12)
def test_embedding_bag_property(L, V, dim, B, weighted):
    rng = np.random.RandomState(L * 7 + V)
    table = jnp.asarray(rng.randn(V, dim).astype(np.float32))
    idx = jnp.asarray(rng.randint(-1, V, size=L).astype(np.int32))
    bags = jnp.asarray(np.sort(rng.randint(0, B, size=L)).astype(np.int32))
    w = jnp.asarray(rng.rand(L).astype(np.float32)) if weighted else None
    got = np.asarray(ops.embedding_bag(table, idx, bags, B, w))
    mask = np.asarray(idx) >= 0
    w_ref = np.where(mask, np.asarray(w) if w is not None else 1.0, 0.0)
    want = np.asarray(ref.embedding_bag_ref(
        table, jnp.maximum(idx, 0), bags, B, jnp.asarray(w_ref)))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_kernel_wrappers_jit_under_transforms():
    """Kernels must compose with jit+grad where gradients are defined."""
    X = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    pi = jnp.arange(8)
    pj = jnp.arange(8, 16)
    theta = 0.1 * jnp.ones((8,))

    # givens rotate is linear in X: grad = rotated cotangent
    def f(x):
        return jnp.sum(ops.apply_pair_rotations(x, pi, pj, theta) ** 2)

    g = jax.jit(jax.grad(f))(X)
    assert bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# PR 7: int8/uint8 LUT packs, rotation-fused LUT build, streaming merge
# ---------------------------------------------------------------------------


def _topk_ids(scores, k=10):
    return np.argsort(-np.asarray(scores), axis=-1)[..., :k]


@pytest.mark.parametrize("dtype", ["int8", "uint8"])
def test_quantize_luts_roundtrip_and_guard(dtype):
    lut = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16))
    qlut, scales = ops.quantize_luts(lut, dtype)
    assert qlut.dtype == jnp.dtype(dtype)
    assert scales.shape == (4, 8, 2)
    deq = ops.dequantize_luts(qlut, scales)
    # worst-case rounding error is half a quantization step per entry
    step = np.asarray(scales[..., 0])[..., None]
    assert np.all(np.abs(np.asarray(deq - lut)) <= 0.5001 * step + 1e-7)
    # a constant (zero-range) subspace must not divide by zero: the pack
    # dequantizes to the exact constant, not NaN
    const = lut.at[:, 3, :].set(0.0)
    qc, sc = ops.quantize_luts(const, dtype)
    deqc = np.asarray(ops.dequantize_luts(qc, sc))
    assert np.all(np.isfinite(deqc))
    np.testing.assert_allclose(deqc[:, 3, :], 0.0, atol=1e-7)


@pytest.mark.parametrize("dtype", ["int8", "uint8"])
@pytest.mark.parametrize("Dp", [4, 16])   # PQ-ish and RQ-2-ish code widths
def test_adc_lookup_int8_parity(dtype, Dp):
    """Quantized flat scan: kernel == ref on the same pack, and the top-k
    order stays monotone vs the f32 scores (same LUT, coarser steps)."""
    key = jax.random.PRNGKey(Dp)
    lut = jax.random.normal(key, (4, Dp, 16))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (256, Dp), 0, 16)
    qlut, scales = ops.quantize_luts(lut, dtype)
    got = np.asarray(ops.adc_lookup(qlut, codes, scales))
    want = np.asarray(ref.adc_lookup_ref(qlut, codes, scales))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    f32 = np.asarray(ops.adc_lookup(lut, codes))
    # quantization error bound: Dp columns × half-step each
    bound = Dp * 0.5001 * float(np.max(np.asarray(scales[..., 0]))) + 1e-5
    assert np.max(np.abs(got - f32)) <= bound
    # top-10 agreement within the error bound (monotone order preserved
    # wherever score gaps exceed the bound)
    agree = np.mean([len(set(a) & set(b)) / 10 for a, b in
                     zip(_topk_ids(got), _topk_ids(f32))])
    assert agree >= 0.8


@pytest.mark.parametrize("dtype", ["int8", "uint8"])
def test_ivf_adc_int8_parity(dtype):
    """Quantized probed scan: kernel == ref on the same pack."""
    key = jax.random.PRNGKey(3)
    b, D, K, bs, nblocks = 3, 8, 16, 8, 12
    lut = jax.random.normal(key, (b, D, K))
    codes = jax.random.randint(jax.random.fold_in(key, 1),
                               (bs * nblocks, D), 0, K)
    block_idx = jnp.arange(nblocks, dtype=jnp.int32)[::-1]
    block_query = jnp.asarray(np.resize(np.arange(b), nblocks), jnp.int32)
    qlut, scales = ops.quantize_luts(lut, dtype)
    got = np.asarray(ops.ivf_adc(qlut, codes, block_idx, block_query,
                                 scales, block_size=bs))
    want = np.asarray(ref.ivf_adc_ref(qlut, codes, block_idx, block_query,
                                      block_size=bs, scales=scales))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", ["int8", "uint8"])
def test_adc_batch_int8_parity(dtype):
    """Quantized grouped (KV-cache) scan: kernel == ref on the same pack."""
    key = jax.random.PRNGKey(5)
    g, r, Dp, K, S = 2, 3, 4, 16, 64
    lut = jax.random.normal(key, (g, r, Dp, K))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (g, S, Dp), 0, K)
    qlut, scales = ops.quantize_luts(lut, dtype)
    got = np.asarray(ops.adc_batch(qlut, codes, scales))
    want = np.asarray(ref.adc_batch_ref(qlut, codes, scales))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    f32 = np.asarray(ops.adc_batch(lut, codes))
    bound = Dp * 0.5001 * float(np.max(np.asarray(scales[..., 0]))) + 1e-5
    assert np.max(np.abs(got - f32)) <= bound


@pytest.mark.parametrize("b,n,D,K,sub", [(3, 16, 4, 8, 4),   # PQ identity
                                         (17, 32, 8, 16, 4)])
def test_fused_lut_pq_kernel_matches_ref(b, n, D, K, sub):
    key = jax.random.PRNGKey(b)
    Q = jax.random.normal(key, (b, n))
    qdelta = jax.random.normal(jax.random.fold_in(key, 1), (n, n))
    cb = jax.random.normal(jax.random.fold_in(key, 2), (D, K, sub))
    colmap = jnp.eye(D, dtype=jnp.float32)
    got = np.asarray(ops.fused_lut(Q, qdelta, cb, colmap))
    want = np.asarray(ref.fused_lut_ref(Q, qdelta, cb, colmap))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    # and the ref itself equals the unfused two-step build
    QL = np.asarray(Q @ qdelta).reshape(b, D, sub)
    direct = np.einsum("bds,dks->bdk", QL, np.asarray(cb))
    np.testing.assert_allclose(want, direct, atol=1e-4, rtol=1e-4)


def test_fused_lut_rq_colmap():
    """Depth-2 RQ level-major columns: column l·D+d reads query subspace d
    through the one-hot colmap — both levels score the same subspace."""
    key = jax.random.PRNGKey(9)
    b, n, D, K, M = 5, 16, 4, 8, 2
    sub = n // D
    Q = jax.random.normal(key, (b, n))
    qdelta = jax.random.normal(jax.random.fold_in(key, 1), (n, n))
    cb = jax.random.normal(jax.random.fold_in(key, 2), (M * D, K, sub))
    cols = np.arange(M * D)
    colmap = jnp.asarray(np.eye(D, dtype=np.float32)[cols % D])
    got = np.asarray(ops.fused_lut(Q, qdelta, cb, colmap))
    want = np.asarray(ref.fused_lut_ref(Q, qdelta, cb, colmap))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    QL = np.asarray(Q @ qdelta).reshape(b, D, sub)
    for p in range(M * D):
        direct = np.einsum("bs,ks->bk", QL[:, p % D], np.asarray(cb[p]))
        np.testing.assert_allclose(want[:, p], direct, atol=1e-4, rtol=1e-4)


def test_topk_merge_deterministic_ties():
    """Equal scores rank by ascending id, so the merged top-k is a pure
    function of the candidate SET — identical under any permutation of the
    candidate axis (the serve batch-composition determinism contract)."""
    rng = np.random.RandomState(7)
    b, C, k = 4, 24, 8
    # heavy ties: scores drawn from 4 distinct values
    scores = jnp.asarray(
        rng.choice([3.0, 2.0, 1.0, -np.inf], size=(b, C)).astype(np.float32))
    ids = jnp.asarray(rng.permutation(C).astype(np.int32)[None, :]
                      .repeat(b, axis=0))
    ids = jnp.where(jnp.isfinite(scores), ids, -1)   # padding contract
    want_s, want_i = ops.topk_merge(scores, ids, k)
    # within every tied score run, ids must come out ascending
    ws, wi = np.asarray(want_s), np.asarray(want_i)
    for r in range(b):
        for v in (3.0, 2.0, 1.0):
            run = wi[r][ws[r] == v]
            assert list(run) == sorted(run), (r, v, run)
    # permutation invariance: merging the same candidates in any order
    # yields the bit-identical result
    for trial in range(5):
        perm = rng.permutation(C)
        got_s, got_i = ops.topk_merge(scores[:, perm], ids[:, perm], k)
        np.testing.assert_array_equal(np.asarray(got_i), wi)
        np.testing.assert_array_equal(np.asarray(got_s), ws)
    # −inf slots surface only when the pool runs dry, always with id −1
    empty_s, empty_i = ops.topk_merge(
        jnp.full((2, 3), -jnp.inf), jnp.full((2, 3), -1, jnp.int32), k)
    assert np.all(np.asarray(empty_s) == -np.inf)
    assert np.all(np.asarray(empty_i) == -1)


def test_streaming_topk_ref_tile_order_invariance():
    """The streamed merge is bit-identical to a one-shot top-k over the
    concatenated scores, whatever order the tiles arrive in."""
    rng = np.random.RandomState(0)
    b, T, t, k = 4, 6, 32, 10
    scores = jnp.asarray(rng.randn(T, b, t).astype(np.float32))
    ids = jnp.asarray(
        np.arange(T * t, dtype=np.int32).reshape(T, t))
    ids = ids.at[-1, -5:].set(-1)                 # padding rows in last tile
    want_s, want_i = ref.streaming_topk_ref(scores, ids, k)
    flat = np.concatenate([np.asarray(scores[i]) for i in range(T)], axis=1)
    flat_ids = np.concatenate([np.asarray(ids[i]) for i in range(T)])
    flat[:, flat_ids < 0] = -np.inf
    order = np.argsort(-flat, axis=1)[:, :k]
    np.testing.assert_array_equal(np.asarray(want_i),
                                  flat_ids[order])
    np.testing.assert_array_equal(np.asarray(want_s),
                                  np.take_along_axis(flat, order, axis=1))
    # permute the tiles: same result set (ties broken by id order here
    # because all scores are distinct floats)
    perm = rng.permutation(T)
    got_s, got_i = ref.streaming_topk_ref(scores[perm], ids[perm], k)
    np.testing.assert_array_equal(np.sort(np.asarray(got_i)),
                                  np.sort(np.asarray(want_i)))
    np.testing.assert_allclose(np.sort(np.asarray(got_s)),
                               np.sort(np.asarray(want_s)))


# ---------------------------------------------------------------------------
# PR 8: in-kernel tombstone masks (repro.churn deletes)
# ---------------------------------------------------------------------------


@given(N=st.integers(10, 400), D=st.sampled_from([2, 8]),
       K=st.sampled_from([4, 16]), b=st.integers(1, 4),
       quantized=st.booleans())
@settings(deadline=None, max_examples=12)
def test_adc_lookup_mask_property(N, D, K, b, quantized):
    """Masked flat scan: kernel == ref, masked rows exactly −inf, live rows
    bit-equal to the unmasked scan (the mask must not perturb live scores)."""
    key = jax.random.PRNGKey(N * 31 + D)
    lut = jax.random.normal(key, (b, D, K))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (N, D), 0, K)
    ids = jnp.where(
        jax.random.bernoulli(jax.random.fold_in(key, 2), 0.3, (N,)),
        -1, jnp.arange(N, dtype=jnp.int32))
    scales = None
    if quantized:
        lut, scales = ops.quantize_luts(lut, "int8")
    got = np.asarray(ops.adc_lookup(lut, codes, scales, ids))
    want = np.asarray(ref.adc_lookup_ref(lut, codes, scales, ids))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    dead = np.asarray(ids) < 0
    assert np.all(np.isneginf(got[:, dead]))
    plain = np.asarray(ops.adc_lookup(lut, codes, scales))
    np.testing.assert_array_equal(got[:, ~dead], plain[:, ~dead])


@given(nblocks=st.integers(2, 16), bs=st.sampled_from([8, 16]),
       b=st.integers(1, 4), quantized=st.booleans())
@settings(deadline=None, max_examples=12)
def test_ivf_adc_mask_property(nblocks, bs, b, quantized):
    """Masked probed scan: the ids operand rides the same block_idx
    prefetch as the codes tile — kernel == ref, masked rows −inf, live
    rows bit-equal to the unmasked scan."""
    D, K = 4, 16
    key = jax.random.PRNGKey(nblocks * 17 + bs)
    lut = jax.random.normal(key, (b, D, K))
    cap = bs * nblocks
    codes = jax.random.randint(jax.random.fold_in(key, 1), (cap, D), 0, K)
    ids = jnp.where(
        jax.random.bernoulli(jax.random.fold_in(key, 2), 0.3, (cap,)),
        -1, jnp.arange(cap, dtype=jnp.int32))
    block_idx = jnp.asarray(
        np.random.RandomState(nblocks).permutation(nblocks), jnp.int32)
    block_query = jnp.asarray(np.resize(np.arange(b), nblocks), jnp.int32)
    scales = None
    if quantized:
        lut, scales = ops.quantize_luts(lut, "int8")
    got = np.asarray(ops.ivf_adc(lut, codes, block_idx, block_query,
                                 scales, ids, block_size=bs))
    want = np.asarray(ref.ivf_adc_ref(lut, codes, block_idx, block_query,
                                      block_size=bs, scales=scales, ids=ids))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    rows = (np.asarray(block_idx)[:, None] * bs + np.arange(bs))
    dead = np.asarray(ids)[rows] < 0
    assert np.all(np.isneginf(got[dead]))
    plain = np.asarray(ops.ivf_adc(lut, codes, block_idx, block_query,
                                   scales, block_size=bs))
    np.testing.assert_array_equal(got[~dead], plain[~dead])
