"""Pallas kernels: shape/dtype sweeps + hypothesis, allclose vs ref.py
oracles. interpret=True on CPU per the deliverable contract."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import givens
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n", [(8, 8), (64, 32), (100, 64), (257, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_givens_rotate_sweep(m, n, dtype):
    key = jax.random.PRNGKey(m * 1000 + n)
    X = jax.random.normal(key, (m, n)).astype(dtype)
    perm = np.random.RandomState(0).permutation(n)
    pi = jnp.asarray(perm[: n // 2])
    pj = jnp.asarray(perm[n // 2: 2 * (n // 2)])
    theta = jax.random.normal(jax.random.fold_in(key, 1), (n // 2,))
    got = ops.apply_pair_rotations(X, pi, pj, theta)
    want = givens.apply_pair_rotations(X, pi, pj, theta)
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("n", [32, 128, 384, 512])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gcd_score_sweep(n, dtype):
    key = jax.random.PRNGKey(n)
    G = jax.random.normal(key, (n, n)).astype(dtype)
    R = jax.random.normal(jax.random.fold_in(key, 1), (n, n)).astype(dtype)
    got = np.asarray(ops.gcd_score(G, R))
    want = np.asarray(ref.gcd_score_ref(G.astype(jnp.float32),
                                        R.astype(jnp.float32)))
    tol = 1e-3 * n if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, atol=tol, rtol=1e-2)
    np.testing.assert_allclose(got, -got.T, atol=1e-5)  # antisymmetric


@pytest.mark.parametrize("m,D,K,sub", [(17, 2, 4, 8), (300, 8, 16, 8),
                                       (1024, 4, 256, 16)])
def test_pq_assign_sweep(m, D, K, sub):
    key = jax.random.PRNGKey(m)
    X = jax.random.normal(key, (m, D * sub))
    cb = jax.random.normal(jax.random.fold_in(key, 1), (D, K, sub))
    got = np.asarray(ops.pq_assign(X, cb))
    want = np.asarray(ref.pq_assign_ref(X, cb))
    assert np.array_equal(got, want)


@given(N=st.integers(10, 600), D=st.sampled_from([2, 8]),
       K=st.sampled_from([4, 16]), b=st.integers(1, 5))
@settings(deadline=None, max_examples=12)
def test_adc_lookup_property(N, D, K, b):
    key = jax.random.PRNGKey(N)
    lut = jax.random.normal(key, (b, D, K))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (N, D), 0, K)
    got = np.asarray(ops.adc_lookup(lut, codes))
    want = np.asarray(ref.adc_lookup_ref(lut, codes))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@given(L=st.integers(1, 200), V=st.integers(10, 500),
       dim=st.sampled_from([8, 16]), B=st.integers(1, 20),
       weighted=st.booleans())
@settings(deadline=None, max_examples=12)
def test_embedding_bag_property(L, V, dim, B, weighted):
    rng = np.random.RandomState(L * 7 + V)
    table = jnp.asarray(rng.randn(V, dim).astype(np.float32))
    idx = jnp.asarray(rng.randint(-1, V, size=L).astype(np.int32))
    bags = jnp.asarray(np.sort(rng.randint(0, B, size=L)).astype(np.int32))
    w = jnp.asarray(rng.rand(L).astype(np.float32)) if weighted else None
    got = np.asarray(ops.embedding_bag(table, idx, bags, B, w))
    mask = np.asarray(idx) >= 0
    w_ref = np.where(mask, np.asarray(w) if w is not None else 1.0, 0.0)
    want = np.asarray(ref.embedding_bag_ref(
        table, jnp.maximum(idx, 0), bags, B, jnp.asarray(w_ref)))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_kernel_wrappers_jit_under_transforms():
    """Kernels must compose with jit+grad where gradients are defined."""
    X = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    pi = jnp.arange(8)
    pj = jnp.arange(8, 16)
    theta = 0.1 * jnp.ones((8,))

    # givens rotate is linear in X: grad = rotated cotangent
    def f(x):
        return jnp.sum(ops.apply_pair_rotations(x, pi, pj, theta) ** 2)

    g = jax.jit(jax.grad(f))(X)
    assert bool(jnp.all(jnp.isfinite(g)))
