import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
)

# ^ MUST be the very first lines, before any jax import — jax locks the
# device count on first init. REPRO_XLA_FLAGS exists only so tests can run a
# reduced-device dry-run in a subprocess.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape) cell this lowers + compiles the real
step function (train_step including optimizer+GCD, or the serve path) against
the production mesh — 16×16 single-pod and 2×16×16 multi-pod — and records:

  * memory_analysis()            (proves the cell fits 16 GiB/chip)
  * cost_analysis()              (per-device FLOPs / bytes for §Roofline)
  * parsed collective bytes      (the §Roofline third term)
  * sharding-rule warnings       (e.g. "20 heads % 16 → replicated")

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--both]
"""
import argparse
import json
import time
import traceback


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True) -> dict:
    import jax

    from repro import configs
    from repro.launch import cells as cells_lib
    from repro.launch import mesh as mesh_lib
    from repro.roofline import analysis
    from repro.sharding import rules as sh

    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name, "ok": False}
    try:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        n_chips = analysis.num_chips(mesh)
        sh.pop_warnings()
        cell = cells_lib.build_cell(arch_id, shape_name, mesh)
        rec["sharding_warnings"] = sorted(set(sh.pop_warnings()))
        with mesh:
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jitted.lower(*cell.abstract_inputs)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        report = analysis.analyze(
            compiled, lowered,
            model_flops_total=cell.meta.get("model_flops"),
            n_chips=n_chips,
            loop_trips=cell.meta.get("trips", 1.0),
        )
        mem = compiled.memory_analysis()
        rec.update(
            ok=True,
            kind=cell.meta.get("kind"),
            n_chips=n_chips,
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            report=report,
            fits_hbm=report["memory"]["peak_bytes"] <= analysis.CHIP_HBM_BYTES,
        )
        if verbose:
            print(f"[{arch_id} × {shape_name} × {mesh_name}] OK "
                  f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
            print(f"  memory_analysis: {mem}")
            print(f"  peak/device = {report['memory']['peak_bytes']/2**30:.2f} GiB "
                  f"(fits 16 GiB: {rec['fits_hbm']})")
            print(f"  cost_analysis: flops/dev={report['flops_per_device']:.3e} "
                  f"bytes/dev={report['bytes_per_device']:.3e} "
                  f"coll/dev={report['collective_bytes']:.3e}")
            print(f"  roofline: compute={report['compute_s']:.2e}s "
                  f"memory={report['memory_s']:.2e}s "
                  f"collective={report['collective_s']:.2e}s "
                  f"→ {report['dominant']}-bound "
                  f"(fraction {report['roofline_fraction']:.3f})")
    except Exception as e:  # noqa: BLE001 — failures ARE the result here
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{arch_id} × {shape_name} × {mesh_name}] FAIL: {rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        safe = f"{arch_id}__{shape_name}__{mesh_name}".replace("/", "_")
        with open(os.path.join(out_dir, safe + ".json"), "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--all", action="store_true", help="run every grid cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro import configs

    if args.all:
        cells = configs.grid_cells()
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both else [args.multi_pod]

    n_ok = n_fail = n_skip = 0
    for arch_id, shape_name in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            safe = f"{arch_id}__{shape_name}__{mesh_name}".replace("/", "_")
            path = os.path.join(args.out, safe + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        n_skip += 1
                        continue
            rec = run_cell(arch_id, shape_name, mp, out_dir=args.out)
            n_ok += int(rec["ok"])
            n_fail += int(not rec["ok"])
    print(f"\ndry-run done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
