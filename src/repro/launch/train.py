"""Fault-tolerant training launcher.

Wires together: config registry → synthetic pipeline → jit'd train step
(AdamW + GCD manifold updates) → async checkpointing → auto-resume.

Fault-tolerance contract (DESIGN.md §6):
  * checkpoints are atomic + manifest-gated; a crash mid-save is ignorable;
  * ``--resume`` (default) restores the newest complete checkpoint AND the
    data-pipeline cursor, so a restarted job replays no batch twice;
  * checkpoints are saved mesh-agnostic (host numpy) — a resume may use a
    different device count (elastic re-mesh: params are re-device_put with
    the new mesh's shardings);
  * a step watchdog flags stragglers: any step exceeding
    ``--watchdog-factor`` × median step time is logged with its step index
    (on a real fleet this signal feeds the pod-restart policy).

On this CPU container the launcher runs the smoke configs end-to-end; on a
TPU fleet the same entry point takes the full configs (--full).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch two-tower-retrieval \
      --steps 200 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs, rotations
from repro.data import pipeline as pipe_lib
from repro.data import synthetic
from repro.launch import mesh as mesh_lib
from repro.models import gnn, recsys
from repro.models import transformer as tfm
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt_lib
from repro.training import train_state as ts


def make_batch_fn(cfg, family: str, batch: int):
    """Family-specific synthetic batch maker: key -> tuple of arrays."""
    if family == "lm":
        def f(key):
            return synthetic.lm_batch(key, batch, 128, cfg.vocab_size)
        return f
    if family == "gnn":
        from repro.data import graph as graph_lib
        g = graph_lib.synthetic_graph(0, 2000, 8, cfg.d_in,
                                      num_classes=cfg.num_classes)

        def f(key):
            seed = int(jax.random.randint(key, (), 0, 1 << 30))
            rng = np.random.RandomState(seed)
            seeds = rng.randint(0, g.num_nodes, size=batch)
            feats, labels = graph_lib.sample_blocks(
                g, seeds, cfg.sample_sizes, seed)
            return (*feats, labels)
        return f
    # recsys
    if isinstance(cfg, recsys.WideDeepConfig):
        def f(key):
            return synthetic.ctr_batch(key, batch, cfg.n_sparse,
                                       cfg.vocab_per_field)
        return f
    if isinstance(cfg, (recsys.TwoTowerConfig, recsys.MINDConfig)):
        log = synthetic.ClickLog(0, cfg.item_vocab, dim=32)

        def f(key):
            seed = int(jax.random.randint(key, (), 0, 1 << 30))
            return log.batch(seed, batch, cfg.hist_len)
        return f
    if isinstance(cfg, recsys.DINConfig):
        def f(key):
            return synthetic.din_batch(key, batch, cfg.hist_len,
                                       cfg.item_vocab)
        return f
    raise TypeError(type(cfg))


def make_loss_fn(cfg, family: str):
    if family == "lm":
        return lambda p, tok, lab: tfm.forward_train(p, tok, lab, cfg)
    if family == "gnn":
        return lambda p, h0, h1, h2, lab: gnn.loss_minibatch(
            p, [h0, h1, h2], lab, cfg)
    if isinstance(cfg, recsys.WideDeepConfig):
        return lambda p, ids, lab: recsys.widedeep_loss(p, ids, lab, cfg)
    if isinstance(cfg, recsys.TwoTowerConfig):
        return lambda p, h, pos: recsys.twotower_loss(p, h, pos, cfg)
    if isinstance(cfg, recsys.MINDConfig):
        return lambda p, h, pos: recsys.mind_loss(p, h, pos, cfg)
    if isinstance(cfg, recsys.DINConfig):
        return lambda p, h, t, lab: recsys.din_loss(p, h, t, lab, cfg)
    raise TypeError(type(cfg))


def init_model(key, cfg, family):
    if family == "lm":
        return tfm.init_params(key, cfg)
    if family == "gnn":
        return gnn.init_params(key, cfg)
    if isinstance(cfg, recsys.WideDeepConfig):
        return recsys.widedeep_init(key, cfg)
    if isinstance(cfg, recsys.TwoTowerConfig):
        return recsys.twotower_init(key, cfg)
    if isinstance(cfg, recsys.MINDConfig):
        return recsys.mind_init(key, cfg)
    if isinstance(cfg, recsys.DINConfig):
        return recsys.din_init(key, cfg)
    raise TypeError(type(cfg))


def _rotation_health(params) -> float | None:
    """Max orthogonality error over the manifold (SO(n)) leaves — the
    trainer-side twin of ``maintain.refresh_health``'s drift gauge. One
    host sync per call; callers gate on ``obs.enabled()``."""
    errs = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        name = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
        if name in opt_lib.MANIFOLD_LEAVES and leaf.ndim >= 2 \
                and leaf.shape[-1] == leaf.shape[-2]:
            R = leaf.reshape(-1, leaf.shape[-1], leaf.shape[-1])
            errs.extend(float(rotations.orthogonality_error(r)) for r in R)
    return max(errs) if errs else None


def train(arch_id: str, steps: int, batch: int, ckpt_dir: str | None,
          resume: bool = True, full: bool = False, seed: int = 0,
          ckpt_every: int = 50, watchdog_factor: float = 5.0,
          rotation: str = "gcd_greedy", log_every: int = 10,
          stop_after: int | None = None, obs_log: str | None = None,
          prefetch: bool = False, live_loop=None):
    """``stop_after``: checkpoint and exit after that many steps — simulates
    a crash for the resume tests (the schedule still targets ``steps``, so a
    resumed run is bit-identical to an uninterrupted one).

    ``obs_log``: enable the global ``repro.obs`` registry with a JSONL
    event log at that path — per-step spans/metrics (time, loss, grad
    norm, rotation health every ``log_every``) stream there; the loop
    stays metric-free when observability is off.

    ``prefetch``: double-buffer the host pipeline — batch k+1 is generated
    on a worker thread while step k runs. Bit-identical stream (batches
    are pure functions of (seed, step)); checkpoints carry the cursor
    either way, so resume works mid-prefetch.

    ``live_loop``: a ``repro.pipeline.LiveIndexLoop`` to drive from this
    trainer — the step function is built with ``emit_deltas=True`` and the
    loop's ``on_step`` runs after each step (live-index refresh + the
    background compactor's poll stay off the device's critical path)."""
    if obs_log:
        obs.enable(jsonl=obs_log)
    reg = obs.default_registry()
    arch = configs.get(arch_id)
    cfg = arch.make_config() if full else arch.make_smoke()
    loss_fn = make_loss_fn(cfg, arch.family)
    batch_fn = make_batch_fn(cfg, arch.family, batch)

    ocfg = opt_lib.OptimizerConfig(
        lr=1e-3, total_steps=steps, warmup_steps=min(50, steps // 10 + 1),
        rotation=rotations.RotationConfig.from_spec(rotation),
    )
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg, arch.family)
    state = ts.init_state(jax.random.fold_in(key, 1), params, ocfg)
    pipe = pipe_lib.Pipeline(batch_fn, seed=seed, prefetch=prefetch,
                             registry=reg)

    # ---- auto-resume (elastic: arrays re-device_put on the current mesh) ----
    start_step = 0
    if ckpt_dir and resume:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            (restored, pipe_state), manifest = ckpt.restore(
                ckpt_dir, latest, (state, pipe.state()))
            state = jax.device_put(restored)
            pipe.restore(pipe_state)
            start_step = latest
            print(f"[train] resumed from step {latest}")

    step_fn = jax.jit(
        ts.make_train_step(loss_fn, ocfg,
                           emit_deltas=live_loop is not None),
        donate_argnums=(0,))

    times: list[float] = []
    metrics_hist = []
    for i in range(start_step, steps):
        t0 = time.time()
        with reg.span("train.step"):
            batch_data = next(pipe)
            state, metrics = step_fn(state, *batch_data)
            loss = float(metrics["loss"])   # blocks: the span covers compute
        if live_loop is not None:
            live_loop.on_step(metrics)
        dt = time.time() - t0
        times.append(dt)
        metrics_hist.append(loss)
        if obs.enabled():
            reg.distribution("train.step_ms").observe(dt * 1e3)
            reg.gauge("train.loss").set(loss)   # eq1 term included for
            reg.gauge("train.grad_norm").set(   # quantization-aware archs
                float(metrics["grad_norm"]))
        if len(times) > 8:
            med = statistics.median(times[-64:])
            if dt > watchdog_factor * med:
                print(f"[watchdog] step {i} straggled: {dt:.2f}s vs median "
                      f"{med:.2f}s — would trigger pod health-check")
                reg.counter("train.straggler_steps").inc()
        if i % log_every == 0:
            print(f"step {i:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if obs.enabled():
                health = _rotation_health(state.params)
                if health is not None:
                    reg.gauge("train.rotation_orthogonality").set(health)
                reg.event("train_step", step=i, loss=loss,
                          grad_norm=float(metrics["grad_norm"]),
                          step_ms=dt * 1e3, rotation_orthogonality=health)
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            ckpt.save_async(ckpt_dir, i + 1, (state, pipe.state()),
                            metadata={"arch": arch_id, "loss": loss})
        if stop_after is not None and (i + 1) >= stop_after:
            if ckpt_dir:
                ckpt.save(ckpt_dir, i + 1,
                          (jax.tree.map(np.asarray, state), pipe.state()),
                          metadata={"arch": arch_id, "crashed": True})
            print(f"[train] simulated crash after step {i + 1}")
            pipe.close()
            return state, metrics_hist
    if live_loop is not None:
        live_loop.drain()
    pipe.close()
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, (jax.tree.map(np.asarray, state),
                                    pipe.state()),
                  metadata={"arch": arch_id, "final": True})
        ckpt.wait_pending()
    return state, metrics_hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU fleets only)")
    ap.add_argument("--rotation", default="gcd_greedy",
                    choices=[n for n in rotations.names()
                             if n != "subspace_gcd"])
    ap.add_argument("--obs-log", default=None,
                    help="enable repro.obs and stream step events to this "
                         "JSONL file; a metrics report prints at exit")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffer host batch synthesis + device_put "
                         "on a worker thread (bit-identical stream)")
    args = ap.parse_args()
    _, hist = train(args.arch, args.steps, args.batch, args.ckpt_dir,
                    resume=not args.no_resume, full=args.full,
                    rotation=args.rotation, obs_log=args.obs_log,
                    prefetch=args.prefetch)
    print(f"final loss: {hist[-1]:.4f} (start {hist[0]:.4f})")
    if args.obs_log:
        print(obs.report())


if __name__ == "__main__":
    main()
