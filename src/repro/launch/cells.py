"""Dry-run cell builders: (architecture × shape × mesh) → a lowerable program.

For every grid cell this module produces:
  * ``fn``               — the step function (train_step with the FULL
                           optimizer incl. the GCD update, or a serve path)
  * ``abstract_inputs``  — ShapeDtypeStruct stand-ins (weak-type-correct,
                           shardable, zero allocation)
  * ``in_shardings`` / ``out_shardings`` — resolved from the arch's logical
                           rule table against the given mesh
  * ``meta``             — MODEL_FLOPS and cell bookkeeping for §Roofline.

Training cells lower the whole system (fwd + bwd + AdamW + GCD manifold
update); serve cells lower prefill/decode/scoring with donated caches.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import rotations
from repro.configs import base as cbase
from repro.configs import get as get_arch
from repro.models import gnn, param as param_lib, recsys
from repro.models import transformer as tfm
from repro.sharding import rules as sh
from repro.training import optimizer as opt_lib
from repro.training import train_state as ts

SDS = jax.ShapeDtypeStruct


class Cell(NamedTuple):
    fn: Any
    abstract_inputs: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    meta: dict


def _repl(mesh):
    return NamedSharding(mesh, P())


def _shard(mesh, logical, rules, shape, name="?"):
    return NamedSharding(mesh, sh.logical_to_spec(logical, rules, mesh, shape, name))


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# Abstract param/optimizer trees
# ---------------------------------------------------------------------------

def abstract_params(spec_tree, param_dtype):
    return param_lib.abstract_params(spec_tree, param_dtype)


def params_shardings(spec_tree, rules, mesh):
    logical = param_lib.logical_tree(spec_tree)
    shapes = jax.tree.map(lambda s: s.shape, spec_tree,
                          is_leaf=param_lib.is_spec)
    return jax.tree.map(
        lambda lg, shp: NamedSharding(
            mesh, sh.logical_to_spec(lg, rules, mesh, shp)),
        logical, shapes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def abstract_opt_state(aparams, ocfg: opt_lib.OptimizerConfig):
    adafactor = ocfg.name == "adafactor"

    def mu_leaf(a):
        if adafactor:
            return SDS(opt_lib.factored_shapes(a.shape)[0], jnp.float32)
        return SDS(a.shape, ocfg.moment_dtype)

    def nu_leaf(a):
        if adafactor:
            return SDS(opt_lib.factored_shapes(a.shape)[1], jnp.float32)
        return SDS(a.shape, ocfg.moment_dtype)

    mu = jax.tree.map(mu_leaf, aparams)
    nu = jax.tree.map(nu_leaf, aparams)
    # learner states for the manifold leaves, shape-inferred without
    # allocating (init_rot_states is pure shape arithmetic under eval_shape)
    rot = jax.eval_shape(
        lambda ap: opt_lib.init_rot_states(ap, ocfg), aparams)
    return opt_lib.OptState(mu=mu, nu=nu, rot=rot,
                            step=SDS((), jnp.int32))


def opt_shardings(spec_tree, rules, mesh, aparams, ocfg):
    adafactor = ocfg.name == "adafactor"
    logical = param_lib.logical_tree(spec_tree)
    is_lg = lambda x: (isinstance(x, tuple)
                       and all(isinstance(e, (str, type(None))) for e in x))

    def factored_sh(lg, shp, which):
        if len(shp) >= 2:
            lg2 = lg[:-1] if which == 0 else lg[:-2] + lg[-1:]
            shp2 = opt_lib.factored_shapes(shp)[which]
        else:
            lg2, shp2 = (lg, shp) if which == 0 else ((), ())
        return NamedSharding(mesh, sh.logical_to_spec(lg2, rules, mesh, shp2))

    shapes = jax.tree.map(lambda s: s.shape, spec_tree,
                          is_leaf=param_lib.is_spec)
    if adafactor:
        mu = jax.tree.map(lambda lg, s: factored_sh(lg, s, 0), logical,
                          shapes, is_leaf=is_lg)
        nu = jax.tree.map(lambda lg, s: factored_sh(lg, s, 1), logical,
                          shapes, is_leaf=is_lg)
    else:
        ps = params_shardings(spec_tree, rules, mesh)
        mu = nu = ps

    # rotation-learner states are tiny (n×n) — replicate every leaf
    abstract_rot = jax.eval_shape(
        lambda ap: opt_lib.init_rot_states(ap, ocfg), aparams)
    rot = jax.tree.map(lambda _: _repl(mesh), abstract_rot)
    return opt_lib.OptState(mu=mu, nu=nu, rot=rot, step=_repl(mesh))


def abstract_train_state(spec_tree, param_dtype, ocfg):
    ap = abstract_params(spec_tree, param_dtype)
    return ts.TrainState(
        params=ap,
        opt_state=abstract_opt_state(ap, ocfg),
        step=SDS((), jnp.int32),
        rng=SDS((2,), jnp.uint32),
    )


def train_state_shardings(spec_tree, rules, mesh, param_dtype, ocfg):
    ap = abstract_params(spec_tree, param_dtype)
    ps = params_shardings(spec_tree, rules, mesh)
    return ts.TrainState(
        params=ps,
        opt_state=opt_shardings(spec_tree, rules, mesh, ap, ocfg),
        step=_repl(mesh),
        rng=_repl(mesh),
    )


def _metrics_shardings(mesh):
    return {"loss": _repl(mesh), "grad_norm": _repl(mesh), "lr": _repl(mesh)}


def _opt_cfg_for(cfg) -> opt_lib.OptimizerConfig:
    """bf16 Adam moments for the ≥50B archs (memory math in DESIGN.md §6);
    microbatch accumulation factor comes from the arch config."""
    big, accum = False, 1
    if isinstance(cfg, tfm.TransformerConfig):
        big = tfm.num_params(cfg) > 50e9
        accum = cfg.train_accum_steps
    return opt_lib.OptimizerConfig(
        # ≥50B: Adafactor (factored 2nd moment, no 1st) — Adam's two
        # params-sized moments + their update copies cannot fit 16 GiB/chip
        # at 340B/256 chips (DESIGN.md §6). Adafactor's update-RMS clip
        # replaces global grad-norm clipping (grad_clip=0 avoids one more
        # params-sized pass).
        name="adafactor" if big else "adamw",
        grad_clip=0.0 if big else 1.0,
        lr=3e-4, moment_dtype=jnp.bfloat16 if big else jnp.float32,
        compute_dtype=jnp.bfloat16 if big else jnp.float32,
        accum_steps=accum,
        accum_dtype=jnp.bfloat16 if big else jnp.float32,
        rotation=rotations.RotationConfig(learner="gcd", method="greedy",
                                          lr=1e-3),
    )


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_train_cell(cfg: tfm.TransformerConfig, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    B = shape.params["global_batch"]
    S = shape.params["seq_len"]
    ocfg = _opt_cfg_for(cfg)
    spec_tree = tfm.param_specs(cfg)

    def loss_fn(params, tokens, labels):
        return tfm.forward_train(params, tokens, labels, cfg)

    pshard = params_shardings(spec_tree, rules, mesh)
    step = ts.make_train_step(loss_fn, ocfg, grad_shardings=pshard)
    astate = abstract_train_state(spec_tree, cfg.param_dtype, ocfg)
    sstate = train_state_shardings(spec_tree, rules, mesh, cfg.param_dtype, ocfg)
    tok = SDS((B, S), jnp.int32)
    tok_sh = _shard(mesh, ("act_batch", "act_seq"), rules, (B, S), "tokens")
    return Cell(
        fn=step,
        abstract_inputs=(astate, tok, tok),
        in_shardings=(sstate, tok_sh, tok_sh),
        out_shardings=(sstate, _metrics_shardings(mesh)),
        donate_argnums=(0,),
        meta={
            # 6N already covers fwd+bwd; full remat re-runs fwd (~8N/6N)
            "model_flops": tfm.model_flops_per_token(cfg) * B * S,
            "kind": "train",
            # cost_analysis counts while bodies once: dominant nest =
            # microbatch scan × layer scan (see roofline.analysis)
            "trips": float(ocfg.accum_steps * cfg.scan_len),
        },
    )


def _lm_cache_abstract(cfg: tfm.TransformerConfig, B: int, S: int):
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    if cfg.kv_quant is not None:
        D = cfg.kv_quant.num_subspaces
        z = SDS((L, B, Hkv, S, D), jnp.uint8)
        return tfm.PQDecodeCache(k_codes=z, v_codes=z,
                                 length=SDS((B,), jnp.int32))
    z = SDS((L, B, Hkv, S, hd), cfg.dtype)
    return tfm.DecodeCache(k=z, v=z, length=SDS((B,), jnp.int32))


def _lm_cache_shardings(cfg, B, S, mesh):
    rules = cfg.rule_table
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    e = cfg.kv_quant.num_subspaces if cfg.kv_quant is not None else hd
    spec = _shard(mesh, ("layers", "act_batch", None, "act_kv_seq", None),
                  rules, (L, B, Hkv, S, e), "kv_cache")
    length = _repl(mesh)
    if cfg.kv_quant is not None:
        return tfm.PQDecodeCache(k_codes=spec, v_codes=spec, length=length)
    return tfm.DecodeCache(k=spec, v=spec, length=length)


def _lm_decode_cell(cfg: tfm.TransformerConfig, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    B = shape.params["global_batch"]
    S = shape.params["seq_len"]
    spec_tree = tfm.param_specs(cfg)
    aparams = abstract_params(spec_tree, cfg.param_dtype)
    pshard = params_shardings(spec_tree, rules, mesh)

    def fn(params, token, cache):
        return tfm.serve_decode(params, token, cache, cfg)

    tok = SDS((B,), jnp.int32)
    tok_sh = _shard(mesh, ("act_batch",), rules, (B,), "token")
    acache = _lm_cache_abstract(cfg, B, S)
    scache = _lm_cache_shardings(cfg, B, S, mesh)
    logits_sh = _shard(mesh, ("act_batch", "act_vocab"), rules,
                       (B, cfg.vocab_size), "logits")
    # decode attention FLOPs: O(B·Hq·S·hd) per layer + projections
    attn_flops = (2.0 * B * cfg.num_heads * S * cfg.head_dim * 2  # qk + av
                  ) * cfg.num_layers
    return Cell(
        fn=fn,
        abstract_inputs=(aparams, tok, acache),
        in_shardings=(pshard, tok_sh, scache),
        out_shardings=(logits_sh, scache),
        donate_argnums=(2,),
        meta={"model_flops": tfm.model_flops_per_token(cfg) / 3.0 * B
              + attn_flops,
              "kind": "decode", "trips": float(cfg.scan_len)},
    )


def _lm_prefill_cell(cfg: tfm.TransformerConfig, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    B = shape.params["global_batch"]
    S = shape.params["seq_len"]
    spec_tree = tfm.param_specs(cfg)
    aparams = abstract_params(spec_tree, cfg.param_dtype)
    pshard = params_shardings(spec_tree, rules, mesh)

    def fn(params, tokens):
        return tfm.serve_prefill(params, tokens, cfg, max_len=S)

    tok = SDS((B, S), jnp.int32)
    tok_sh = _shard(mesh, ("act_batch", "act_seq"), rules, (B, S), "tokens")
    scache = _lm_cache_shardings(cfg, B, S, mesh)
    logits_sh = _shard(mesh, ("act_batch", "act_vocab"), rules,
                       (B, cfg.vocab_size), "logits")
    # causal attention flops: 2 matmuls × B·Hq·S²/2·hd × 2 ops
    attn = 2.0 * B * cfg.num_heads * (S * S / 2) * cfg.head_dim * 2 * cfg.num_layers
    return Cell(
        fn=fn,
        abstract_inputs=(aparams, tok),
        in_shardings=(pshard, tok_sh),
        out_shardings=((logits_sh, scache)),
        donate_argnums=(),
        meta={"model_flops": tfm.model_flops_per_token(cfg) / 3.0 * B * S + attn,
              "kind": "prefill", "trips": float(cfg.scan_len)},
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_full_cell(cfg: gnn.GraphSAGEConfig, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    n_dev = math.prod(mesh.shape.values())
    N = _pad_to(shape.params["n_nodes"], 2 * n_dev)
    E = _pad_to(shape.params["n_edges"], 2 * n_dev)
    F = shape.params["d_feat"]
    ocfg = _opt_cfg_for(cfg)
    spec_tree = gnn.param_specs(cfg)

    def loss_fn(params, feats, src, dst, labels, mask):
        return gnn.loss_full_batch(params, feats, src, dst, labels, mask, cfg)

    step = ts.make_train_step(
        loss_fn, ocfg, grad_shardings=params_shardings(spec_tree, rules, mesh))
    astate = abstract_train_state(spec_tree, cfg.param_dtype, ocfg)
    sstate = train_state_shardings(spec_tree, rules, mesh, cfg.param_dtype, ocfg)
    inputs = (
        SDS((N, F), jnp.float32), SDS((E,), jnp.int32), SDS((E,), jnp.int32),
        SDS((N,), jnp.int32), SDS((N,), jnp.bool_),
    )
    shards = (
        _shard(mesh, ("act_nodes", "act_feat"), rules, (N, F), "feats"),
        _shard(mesh, ("act_edges",), rules, (E,), "src"),
        _shard(mesh, ("act_edges",), rules, (E,), "dst"),
        _shard(mesh, ("act_nodes",), rules, (N,), "labels"),
        _shard(mesh, ("act_nodes",), rules, (N,), "mask"),
    )
    # SAGE flops: 2 layers × N × (2 matmuls d_in·d_h) × 3 (fwd+bwd)
    flops = 3.0 * 2.0 * N * (F * cfg.d_hidden + cfg.d_hidden**2) * 2
    return Cell(
        fn=step, abstract_inputs=(astate, *inputs),
        in_shardings=(sstate, *shards),
        out_shardings=(sstate, _metrics_shardings(mesh)),
        donate_argnums=(0,),
        meta={"model_flops": flops, "kind": "train"},
    )


def _gnn_minibatch_cell(cfg, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    B = shape.params["batch_nodes"]
    f1, f2 = shape.params["fanout"]
    F = shape.params["d_feat"]
    ocfg = _opt_cfg_for(cfg)
    spec_tree = gnn.param_specs(cfg)

    def loss_fn(params, h0, h1, h2, labels):
        return gnn.loss_minibatch(params, [h0, h1, h2], labels, cfg)

    step = ts.make_train_step(
        loss_fn, ocfg, grad_shardings=params_shardings(spec_tree, rules, mesh))
    astate = abstract_train_state(spec_tree, cfg.param_dtype, ocfg)
    sstate = train_state_shardings(spec_tree, rules, mesh, cfg.param_dtype, ocfg)
    inputs = (
        SDS((B, F), jnp.float32), SDS((B, f1, F), jnp.float32),
        SDS((B, f1, f2, F), jnp.float32), SDS((B,), jnp.int32),
    )
    bsh = lambda shp: _shard(mesh, ("act_nodes",) + (None,) * (len(shp) - 1),
                             rules, shp, "block")
    shards = tuple(bsh(i.shape) for i in inputs)
    flops = 3.0 * 2.0 * B * (1 + f1) * (F * cfg.d_hidden + cfg.d_hidden**2) * 2
    return Cell(
        fn=step, abstract_inputs=(astate, *inputs),
        in_shardings=(sstate, *shards),
        out_shardings=(sstate, _metrics_shardings(mesh)),
        donate_argnums=(0,),
        meta={"model_flops": flops, "kind": "train"},
    )


def _gnn_graph_batch_cell(cfg, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    G = shape.params["batch"]
    n, e = shape.params["n_nodes"], shape.params["n_edges"]
    F = shape.params["d_feat"]
    N, E = G * n, G * e
    ocfg = _opt_cfg_for(cfg)
    spec_tree = gnn.param_specs(cfg)

    def loss_fn(params, feats, src, dst, gids, labels):
        return gnn.loss_graph_batch(params, feats, src, dst, gids, labels, G, cfg)

    step = ts.make_train_step(
        loss_fn, ocfg, grad_shardings=params_shardings(spec_tree, rules, mesh))
    astate = abstract_train_state(spec_tree, cfg.param_dtype, ocfg)
    sstate = train_state_shardings(spec_tree, rules, mesh, cfg.param_dtype, ocfg)
    inputs = (
        SDS((N, F), jnp.float32), SDS((E,), jnp.int32), SDS((E,), jnp.int32),
        SDS((N,), jnp.int32), SDS((G,), jnp.int32),
    )
    shards = (
        _shard(mesh, ("act_nodes", "act_feat"), rules, (N, F), "feats"),
        _shard(mesh, ("act_edges",), rules, (E,), "src"),
        _shard(mesh, ("act_edges",), rules, (E,), "dst"),
        _shard(mesh, ("act_nodes",), rules, (N,), "gids"),
        _shard(mesh, ("act_nodes",), rules, (G,), "labels"),
    )
    flops = 3.0 * 2.0 * N * (F * cfg.d_hidden + cfg.d_hidden**2) * 2
    return Cell(
        fn=step, abstract_inputs=(astate, *inputs),
        in_shardings=(sstate, *shards),
        out_shardings=(sstate, _metrics_shardings(mesh)),
        donate_argnums=(0,),
        meta={"model_flops": flops, "kind": "train"},
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_inputs(cfg, B, mesh, rules):
    """(abstract inputs, shardings, loss_fn) for one arch's training batch."""
    bsh = lambda shp, nm: _shard(
        mesh, ("act_batch",) + (None,) * (len(shp) - 1), rules, shp, nm)
    if isinstance(cfg, recsys.WideDeepConfig):
        inputs = (SDS((B, cfg.n_sparse), jnp.int32), SDS((B,), jnp.float32))
        shards = (bsh((B, cfg.n_sparse), "ids"), bsh((B,), "labels"))

        def loss_fn(params, ids, labels):
            return recsys.widedeep_loss(params, ids, labels, cfg)

        init = recsys.widedeep_init
        specs = recsys.widedeep_specs(cfg)
    elif isinstance(cfg, recsys.TwoTowerConfig):
        inputs = (SDS((B, cfg.hist_len), jnp.int32), SDS((B,), jnp.int32))
        shards = (bsh((B, cfg.hist_len), "hist"), bsh((B,), "pos"))

        def loss_fn(params, hist, pos):
            return recsys.twotower_loss(params, hist, pos, cfg)

        init = recsys.twotower_init
        specs = recsys.twotower_specs(cfg)
        if cfg.index is not None:
            from repro.core import index_layer as il
            from repro.models.param import ParamSpec
            n, sub = cfg.index.dim, cfg.index.dim // cfg.index.num_subspaces
            specs["index"] = il.IndexLayerParams(
                R=ParamSpec((n, n), ("rot_in", "rot_out"), init="eye"),
                codebooks=ParamSpec(
                    (cfg.index.num_subspaces, cfg.index.num_codewords, sub),
                    ("pq_dim", "pq_code", "pq_sub"), scale=0.01),
            )
    elif isinstance(cfg, recsys.MINDConfig):
        inputs = (SDS((B, cfg.hist_len), jnp.int32), SDS((B,), jnp.int32))
        shards = (bsh((B, cfg.hist_len), "hist"), bsh((B,), "pos"))

        def loss_fn(params, hist, pos):
            return recsys.mind_loss(params, hist, pos, cfg)

        init = recsys.mind_init
        specs = recsys.mind_specs(cfg)
    elif isinstance(cfg, recsys.DINConfig):
        inputs = (SDS((B, cfg.hist_len), jnp.int32), SDS((B,), jnp.int32),
                  SDS((B,), jnp.float32))
        shards = (bsh((B, cfg.hist_len), "hist"), bsh((B,), "target"),
                  bsh((B,), "labels"))

        def loss_fn(params, hist, target, labels):
            return recsys.din_loss(params, hist, target, labels, cfg)

        init = recsys.din_init
        specs = recsys.din_specs(cfg)
    else:
        raise TypeError(type(cfg))
    return inputs, shards, loss_fn, specs


def _recsys_flops(cfg, B: int) -> float:
    if isinstance(cfg, recsys.WideDeepConfig):
        dims = (cfg.n_sparse * cfg.embed_dim, *cfg.mlp_dims, 1)
        return B * 2.0 * sum(a * b for a, b in zip(dims, dims[1:]))
    if isinstance(cfg, recsys.TwoTowerConfig):
        dims = (cfg.embed_dim, *cfg.tower_dims)
        return 2 * B * 2.0 * sum(a * b for a, b in zip(dims, dims[1:]))
    if isinstance(cfg, recsys.MINDConfig):
        e = cfg.embed_dim
        return B * 2.0 * (cfg.hist_len * e * e
                          + cfg.capsule_iters * cfg.hist_len * cfg.n_interests * e
                          + cfg.n_interests * 8 * e * e)
    if isinstance(cfg, recsys.DINConfig):
        e = cfg.embed_dim
        a = (4 * e, *cfg.attn_dims, 1)
        h = (2 * e, *cfg.mlp_dims, 1)
        return B * 2.0 * (cfg.hist_len * sum(x * y for x, y in zip(a, a[1:]))
                          + sum(x * y for x, y in zip(h, h[1:])))
    raise TypeError(type(cfg))


def _recsys_train_cell(cfg, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    B = shape.params["batch"]
    ocfg = _opt_cfg_for(cfg)
    inputs, shards, loss_fn, specs = _recsys_batch_inputs(cfg, B, mesh, rules)
    step = ts.make_train_step(
        loss_fn, ocfg, grad_shardings=params_shardings(specs, rules, mesh))
    astate = abstract_train_state(specs, cfg.param_dtype, ocfg)
    sstate = train_state_shardings(specs, rules, mesh, cfg.param_dtype, ocfg)
    return Cell(
        fn=step, abstract_inputs=(astate, *inputs),
        in_shardings=(sstate, *shards),
        out_shardings=(sstate, _metrics_shardings(mesh)),
        donate_argnums=(0,),
        meta={"model_flops": 3.0 * _recsys_flops(cfg, B), "kind": "train"},
    )


def _recsys_serve_cell(cfg, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    B = shape.params["batch"]
    inputs, shards, _loss, specs = _recsys_batch_inputs(cfg, B, mesh, rules)
    aparams = abstract_params(specs, cfg.param_dtype)
    pshard = params_shardings(specs, rules, mesh)
    out_sh = _shard(mesh, ("act_batch",), rules, (B,), "scores")

    if isinstance(cfg, recsys.WideDeepConfig):
        def fn(params, ids, _labels):
            return recsys.widedeep_forward(params, ids, cfg)
    elif isinstance(cfg, recsys.TwoTowerConfig):
        def fn(params, hist, item):
            u = recsys.user_tower(params, hist, cfg)
            v, _ = recsys.item_tower(params, item, cfg, apply_index=True)
            return jnp.sum(u * v, axis=-1)
    elif isinstance(cfg, recsys.MINDConfig):
        def fn(params, hist, item):
            u = recsys.mind_interests(params, hist, cfg)
            from repro.models import embedding
            v = embedding.lookup(params["item_table"], item).astype(u.dtype)
            return jnp.max(jnp.einsum("bie,be->bi", u, v), axis=-1)
    elif isinstance(cfg, recsys.DINConfig):
        def fn(params, hist, target, _labels):
            return recsys.din_forward(params, hist, target, cfg)
    else:
        raise TypeError(type(cfg))

    return Cell(
        fn=fn, abstract_inputs=(aparams, *inputs),
        in_shardings=(pshard, *shards),
        out_shardings=out_sh,
        donate_argnums=(),
        meta={"model_flops": _recsys_flops(cfg, B), "kind": "serve"},
    )


def _recsys_retrieval_cell(cfg, shape: cbase.Shape, mesh) -> Cell:
    rules = cfg.rule_table
    N = shape.params["n_candidates"]
    _inputs, _shards, _loss, specs = _recsys_batch_inputs(cfg, 8, mesh, rules)
    aparams = abstract_params(specs, cfg.param_dtype)
    pshard = params_shardings(specs, rules, mesh)
    cand_sh1 = _shard(mesh, ("act_cand",), rules, (N,), "cands")

    if isinstance(cfg, recsys.TwoTowerConfig):
        D = cfg.index.num_subspaces
        # Paper serving path: ADC over PQ codes of the 1M-item corpus.
        def fn(params, hist, codes):
            return recsys.twotower_retrieve_adc(params, hist, codes, cfg)

        inputs = (SDS((1, cfg.hist_len), jnp.int32), SDS((N, D), jnp.int32))
        shards = (_repl(mesh),
                  _shard(mesh, ("act_cand", None), rules, (N, D), "codes"))
        out_sh = _shard(mesh, (None, "act_cand"), rules, (1, N), "scores")
        flops = N * D * 2.0  # LUT gather-adds
    elif isinstance(cfg, recsys.MINDConfig):
        def fn(params, hist, cand_vecs):
            return recsys.mind_retrieve(params, hist, cand_vecs, cfg)

        inputs = (SDS((1, cfg.hist_len), jnp.int32),
                  SDS((N, cfg.embed_dim), jnp.float32))
        shards = (_repl(mesh),
                  _shard(mesh, ("act_cand", None), rules,
                         (N, cfg.embed_dim), "cand_vecs"))
        out_sh = _shard(mesh, (None, "act_cand"), rules, (1, N), "scores")
        flops = N * cfg.embed_dim * cfg.n_interests * 2.0
    elif isinstance(cfg, recsys.DINConfig):
        def fn(params, hist, cands):
            return recsys.din_score_candidates(params, hist, cands, cfg,
                                               chunk=31250)

        inputs = (SDS((cfg.hist_len,), jnp.int32), SDS((N,), jnp.int32))
        shards = (_repl(mesh), cand_sh1)
        out_sh = cand_sh1
        flops = _recsys_flops(cfg, N)
    elif isinstance(cfg, recsys.WideDeepConfig):
        def fn(params, ids):
            return recsys.widedeep_forward(params, ids, cfg)

        inputs = (SDS((N, cfg.n_sparse), jnp.int32),)
        shards = (_shard(mesh, ("act_cand", None), rules,
                         (N, cfg.n_sparse), "ids"),)
        out_sh = cand_sh1
        flops = _recsys_flops(cfg, N)
    else:
        raise TypeError(type(cfg))

    return Cell(
        fn=fn, abstract_inputs=(aparams, *inputs),
        in_shardings=(pshard, *shards),
        out_shardings=out_sh,
        donate_argnums=(),
        meta={"model_flops": flops, "kind": "retrieval"},
    )


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh) -> Cell:
    arch = get_arch(arch_id)
    shape = arch.shapes[shape_name]
    cfg = arch.config_for_shape(shape_name)

    if arch.family == "lm":
        if shape.kind == "train":
            return _lm_train_cell(cfg, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(cfg, shape, mesh)
        if shape.kind == "decode":
            return _lm_decode_cell(cfg, shape, mesh)
    elif arch.family == "gnn":
        if shape.kind == "gnn_full":
            return _gnn_full_cell(cfg, shape, mesh)
        if shape.kind == "gnn_minibatch":
            return _gnn_minibatch_cell(cfg, shape, mesh)
        if shape.kind == "gnn_graph_batch":
            return _gnn_graph_batch_cell(cfg, shape, mesh)
    elif arch.family == "recsys":
        if shape.kind == "recsys_train":
            return _recsys_train_cell(cfg, shape, mesh)
        if shape.kind == "recsys_serve":
            return _recsys_serve_cell(cfg, shape, mesh)
        if shape.kind == "recsys_retrieval":
            return _recsys_retrieval_cell(cfg, shape, mesh)
    raise ValueError(f"no builder for {arch_id}/{shape_name} ({shape.kind})")
