"""Production mesh definitions.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

  single-pod: (16, 16)      axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Hardware constants for the §Roofline terms (TPU v5e): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
CHIP_HBM_BYTES = 16 * 1024**3  # v5e: 16 GiB


def make_mesh_compat(shape, axes, **kwargs):
    """``jax.make_mesh`` across JAX API generations.

    Newer JAX requires explicit ``axis_types`` (``jax.sharding.AxisType``)
    for Auto axes; older releases (≤0.4.x) have neither the kwarg nor the
    enum. All mesh construction in this repo funnels through here so both
    generations work unmodified.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs.setdefault("axis_types", (axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names — smoke tests and
    benches run the same model code without 512 fake devices."""
    return make_mesh_compat((1, 1), ("data", "model"))


def num_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
