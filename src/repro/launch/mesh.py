"""Production mesh definitions.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

  single-pod: (16, 16)      axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Hardware constants (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
16 GiB HBM) are OWNED by ``repro.roofline.analysis`` — the launch layer
re-exports them for compatibility so the dry-run report and the roofline
table can never disagree on what a chip is.
"""
from __future__ import annotations

import jax

from repro.roofline.analysis import (  # noqa: F401  (compat re-exports)
    CHIP_HBM_BYTES,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    num_chips,
)


def make_mesh_compat(shape, axes, **kwargs):
    """``jax.make_mesh`` across JAX API generations.

    Newer JAX requires explicit ``axis_types`` (``jax.sharding.AxisType``)
    for Auto axes; older releases (≤0.4.x) have neither the kwarg nor the
    enum. All mesh construction in this repo funnels through here so both
    generations work unmodified.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs.setdefault("axis_types", (axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names — smoke tests and
    benches run the same model code without 512 fake devices."""
    return make_mesh_compat((1, 1), ("data", "model"))


def make_data_mesh(shards: int | None = None):
    """1-axis ``("data",)`` mesh over ``shards`` devices (default: all) —
    the serving mesh of the row-sharded searcher family
    (``repro.search`` ``*_sharded`` backends): the corpus partitions over
    "data" and each device scans only its local CSR shard."""
    n = jax.device_count() if shards is None else shards
    return make_mesh_compat((n,), ("data",))
