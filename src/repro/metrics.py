"""Retrieval quality metrics shared by examples, benchmarks, and tests."""
from __future__ import annotations

import numpy as np


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray,
                k: int | None = None) -> float:
    """Mean fraction of each row's true top-k found in the predicted top-k.

    ``pred_ids`` may contain −1 padding (repro.index returns it when fewer
    than k candidates survive); padding never counts as a hit. Rows of
    ``true_ids`` are assumed distinct within a row (they are top-k lists by
    construction), which makes the broadcast membership test below equal to
    the set-intersection definition |pred ∩ true| / k — the recall probe
    calls this on every sample tick, so it is one (m, k, k) comparison
    rather than a per-row Python set loop.
    """
    pred_ids = np.asarray(pred_ids)
    true_ids = np.asarray(true_ids)
    k = k if k is not None else true_ids.shape[1]
    pred = pred_ids[:, :k]
    true = true_ids[:, :k]
    # (m, k_true, k_pred): true id i matched by any non-padding prediction
    hit = (true[:, :, None] == pred[:, None, :]) & (pred[:, None, :] >= 0)
    return float(hit.any(axis=2).sum(axis=1).mean() / k)
