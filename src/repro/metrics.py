"""Retrieval quality metrics shared by examples, benchmarks, and tests."""
from __future__ import annotations

import numpy as np


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray,
                k: int | None = None) -> float:
    """Mean fraction of each row's true top-k found in the predicted top-k.

    ``pred_ids`` may contain −1 padding (repro.index returns it when fewer
    than k candidates survive); padding never counts as a hit.
    """
    pred_ids = np.asarray(pred_ids)
    true_ids = np.asarray(true_ids)
    k = k if k is not None else true_ids.shape[1]
    hits = []
    for i in range(pred_ids.shape[0]):
        pred = {p for p in pred_ids[i, :k].tolist() if p >= 0}
        hits.append(len(pred & set(true_ids[i, :k].tolist())) / k)
    return float(np.mean(hits))
