"""PQ — single-level product quantizer behind the ``Quantizer`` protocol.

A thin, jit-traceable pytree wrapper over the codebook substrate
(quant/codebook.py): splits an n-dim vector into D contiguous subvectors and
snaps each to the nearest of K codewords. ``code_width == D``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.quant import codebook as cb
from repro.quant import kmeans as km
from repro.quant.base import PQConfig


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class PQ:
    """Product quantizer. Single pytree leaf: ``codebooks (D, K, sub)``."""

    codebooks: jax.Array  # (D, K, sub)

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("codebooks"), self.codebooks),), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- static shape facts ------------------------------------------------
    @property
    def num_subspaces(self) -> int:
        return self.codebooks.shape[0]

    @property
    def num_codewords(self) -> int:
        return self.codebooks.shape[1]

    @property
    def sub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def dim(self) -> int:
        return self.codebooks.shape[0] * self.codebooks.shape[2]

    @property
    def code_width(self) -> int:
        return self.num_subspaces

    @property
    def code_dtype(self):
        return jnp.uint8 if self.num_codewords <= 256 else jnp.int32

    @property
    def config(self) -> PQConfig:
        return PQConfig(self.num_subspaces, self.num_codewords)

    # -- fitting -----------------------------------------------------------
    @classmethod
    def fit(cls, key: jax.Array, X: jax.Array, cfg: PQConfig,
            iters: int = 10) -> tuple["PQ", jax.Array]:
        """k-means per subspace; returns (PQ, distortion trace (iters,))."""
        codebooks, trace = km.kmeans(key, X, cfg, iters=iters)
        return cls(codebooks), trace

    def ema_update(self, X: jax.Array, codes: jax.Array,
                   decay: float = 0.99) -> "PQ":
        return PQ(km.codebook_ema_update(self.codebooks, X, codes, decay=decay))

    # -- Quantizer protocol ------------------------------------------------
    def encode(self, X: jax.Array) -> jax.Array:
        return cb.assign(X, self.codebooks)

    def decode(self, codes: jax.Array) -> jax.Array:
        return cb.decode(codes.astype(jnp.int32), self.codebooks)

    def encode_st(self, X: jax.Array) -> jax.Array:
        return cb.quantize_ste(X, self.codebooks)

    def adc_tables(self, Q: jax.Array) -> jax.Array:
        return cb.adc_lut(Q, self.codebooks)  # (b, D, K)

    def lut_operands(self) -> tuple[jax.Array, jax.Array]:
        """Operands for the rotation-fused LUT-build kernel
        (kernels/lut_build.py): flattened codebooks (Dp, K, sub) and the
        one-hot code-column → query-subspace map (Dp, D). For PQ the map is
        the identity (Dp == D)."""
        D = self.num_subspaces
        return self.codebooks, jnp.eye(D, dtype=jnp.float32)

    def distortion(self, X: jax.Array,
                   codes: jax.Array | None = None) -> jax.Array:
        if codes is not None:
            codes = codes.astype(jnp.int32)
        return cb.distortion(X, self.codebooks, codes)

    def rotate(self, pi: jax.Array, pj: jax.Array,
               theta: jax.Array) -> "PQ":
        """Rotated-space refresh; caller zeroes θ on cross-subspace pairs."""
        return PQ(cb.rotate_codebooks(self.codebooks, pi, pj, theta))
