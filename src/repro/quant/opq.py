"""OPQ — Optimized Product Quantization (Ge et al. 2013) and the paper's
fixed-embedding experiment harness (§3.1 / Fig 2), moved here from core/opq.py
so rotation-aware codebook fitting lives with the other quantizer fits.

The classic OPQ loop alternates
  (a) k-means on the rotated data XR   → codebooks, codes
  (b) Orthogonal Procrustes solve      → R = UVᵀ from SVD(Xᵀ·decode(codes))

The paper swaps step (b) for a few Givens coordinate-descent iterations
(GCD-R/G/S) or Cayley-SGD steps. ``alternating_minimization`` implements all
variants behind one ``rotation_solver`` switch so Fig 2a is a single sweep.
``fit`` wraps it into the protocol idiom: (R, quant.PQ, trace).

Rotation-solver machinery (core.rotation / core.cayley) is imported inside
the functions: repro.core's pq/opq modules are compatibility shims onto this
package, so module-level imports would cycle.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.quant import codebook as cb
from repro.quant import kmeans as km
from repro.quant.base import PQConfig
from repro.quant.pq import PQ


def procrustes_rotation(X: jax.Array, Y: jax.Array) -> jax.Array:
    """argmin_{R ∈ O(n)} ‖XR − Y‖_F = UVᵀ with XᵀY = USVᵀ (Schönemann 1966)."""
    M = X.T @ Y
    U, _, Vt = jnp.linalg.svd(M, full_matrices=False)
    return U @ Vt


class OPQState(NamedTuple):
    R: jax.Array
    codebooks: jax.Array
    rot_state: Any                     # rotation.RotationState (GCD solvers)
    cayley_params: jax.Array           # used by Cayley solver
    key: jax.Array


def _distortion_grad_wrt_R(X, R, codebooks):
    """∇_R (1/m)‖XR − φ(XR)‖² with codes frozen (the inner rotation objective)."""

    def loss(Rm):
        return cb.distortion(X @ Rm, codebooks)

    return jax.grad(loss)(R)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "iters", "rotation_solver", "inner_steps", "kmeans_iters"),
)
def alternating_minimization(
    key: jax.Array,
    X: jax.Array,
    cfg: PQConfig,
    iters: int = 30,
    rotation_solver: str = "svd",  # svd | gcd_random | gcd_greedy | gcd_steepest
    #                                | gcd_overlap_greedy | gcd_overlap_random
    #                                | cayley | frozen
    inner_steps: int = 5,
    lr: float = 1e-4,
    kmeans_iters: int = 1,
):
    """Fixed-embedding rotation learning (paper §3.1). Returns
    (final R, codebooks, distortion trace of length ``iters``)."""
    from repro.core import cayley as cayley_mod
    from repro.core import rotation

    n = X.shape[-1]
    k0, k1 = jax.random.split(key)
    cb0, _ = km.kmeans(k0, X @ jnp.eye(n, dtype=X.dtype), cfg, iters=kmeans_iters)
    state = OPQState(
        R=jnp.eye(n, dtype=X.dtype),
        codebooks=cb0,
        rot_state=rotation.init(n, dtype=X.dtype),
        cayley_params=cayley_mod.init(n, dtype=X.dtype),
        key=k1,
    )

    gcd_method = {
        "gcd_random": "random",
        "gcd_greedy": "greedy",
        "gcd_steepest": "steepest",
        "gcd_overlap_greedy": "overlap_greedy",
        "gcd_overlap_random": "overlap_random",
    }.get(rotation_solver)

    def body(state: OPQState, _):
        # (a) k-means refresh of codebooks on rotated data
        XR = X @ state.R
        codebooks = state.codebooks
        for _i in range(kmeans_iters):
            codebooks, _codes = km.kmeans_update(XR, codebooks)

        # (b) rotation update
        key, sub = jax.random.split(state.key)
        R, rot_state, cay = state.R, state.rot_state, state.cayley_params
        if rotation_solver == "svd":
            codes = cb.assign(X @ R, codebooks)
            target = cb.decode(codes, codebooks)
            R = procrustes_rotation(X, target)
        elif rotation_solver == "frozen":
            pass
        elif gcd_method is not None:
            rot_state = rot_state._replace(R=R)
            for _i in range(inner_steps):
                sub, sk = jax.random.split(sub)
                G = _distortion_grad_wrt_R(X, rot_state.R, codebooks)
                rot_state = rotation.update(
                    rot_state, G, lr, sk, method=gcd_method
                )
            R = rot_state.R
        elif rotation_solver == "cayley":
            def loss(p):
                return cb.distortion(X @ cayley_mod.cayley(p), codebooks)

            for _i in range(inner_steps):
                g = jax.grad(loss)(cay)
                cay = cay - lr * g
            R = cayley_mod.cayley(cay)
        else:
            raise ValueError(f"unknown rotation_solver {rotation_solver!r}")

        dist = cb.distortion(X @ R, codebooks)
        new_state = OPQState(R=R, codebooks=codebooks, rot_state=rot_state,
                             cayley_params=cay, key=key)
        return new_state, dist

    state, trace = jax.lax.scan(body, state, None, length=iters)
    return state.R, state.codebooks, trace


def opq(key, X, cfg: PQConfig, iters: int = 30, kmeans_iters: int = 1):
    """Classic OPQ (SVD rotation solver)."""
    return alternating_minimization(
        key, X, cfg, iters=iters, rotation_solver="svd", kmeans_iters=kmeans_iters
    )


def fit(key, X, cfg: PQConfig, *, iters: int = 30, rotation_solver: str = "svd",
        inner_steps: int = 5, lr: float = 1e-4,
        kmeans_iters: int = 1) -> tuple[jax.Array, PQ, jax.Array]:
    """Protocol-idiom entry point: returns (R, quant.PQ, distortion trace)."""
    R, codebooks, trace = alternating_minimization(
        key, X, cfg, iters=iters, rotation_solver=rotation_solver,
        inner_steps=inner_steps, lr=lr, kmeans_iters=kmeans_iters,
    )
    return R, PQ(codebooks), trace
