"""OPQ — Optimized Product Quantization (Ge et al. 2013) and the paper's
fixed-embedding experiment harness (§3.1 / Fig 2).

The classic OPQ loop alternates
  (a) k-means on the rotated data XR   → codebooks, codes
  (b) a rotation update toward argmin distortion

Step (b) is now any ``repro.rotations`` learner, selected by registry spec:
the classic SVD/Procrustes closed-form solve (learners exposing ``solve``),
gradient learners stepped ``inner_steps`` times per outer iteration (the
GCD family, Cayley-SGD), or the frozen control. ``alternating_minimization``
is therefore one sweepable harness for the whole Fig 2 comparison, and
``fit`` wraps it into the protocol idiom: (R, quant.PQ, trace).

Rotation-learner machinery is imported inside the functions: repro.core's
pq/opq modules are compatibility shims onto this package, so module-level
imports would cycle.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.quant import codebook as cb
from repro.quant import kmeans as km
from repro.quant.base import PQConfig
from repro.quant.pq import PQ


def procrustes_rotation(X: jax.Array, Y: jax.Array) -> jax.Array:
    """argmin_{R ∈ O(n)} ‖XR − Y‖_F = UVᵀ (re-exported convenience)."""
    from repro.rotations import procrustes as proc
    return proc.procrustes_rotation(X, Y)


class OPQState(NamedTuple):
    rot: Any                           # rotation-learner state (R inside)
    codebooks: jax.Array
    key: jax.Array


def _distortion_grad_wrt_R(X, R, codebooks):
    """∇_R (1/m)‖XR − φ(XR)‖² with codes frozen (the inner rotation objective)."""

    def loss(Rm):
        return cb.distortion(X @ Rm, codebooks)

    return jax.grad(loss)(R)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "iters", "rotation", "inner_steps", "kmeans_iters"),
)
def alternating_minimization(
    key: jax.Array,
    X: jax.Array,
    cfg: PQConfig,
    iters: int = 30,
    rotation: str = "procrustes",  # any repro.rotations registry spec
    inner_steps: int = 5,
    lr: float = 1e-4,
    kmeans_iters: int = 1,
):
    """Fixed-embedding rotation learning (paper §3.1). Returns
    (final R, codebooks, distortion trace of length ``iters``)."""
    from repro import rotations

    learner = rotations.make(rotation)
    closed_form = hasattr(learner, "solve")
    frozen = isinstance(learner, rotations.Frozen)

    n = X.shape[-1]
    k0, k1 = jax.random.split(key)
    cb0, _ = km.kmeans(k0, X @ jnp.eye(n, dtype=X.dtype), cfg, iters=kmeans_iters)
    state = OPQState(rot=learner.init(n, dtype=X.dtype), codebooks=cb0, key=k1)

    def body(state: OPQState, _):
        # (a) k-means refresh of codebooks on rotated data
        R = learner.materialize(state.rot)
        XR = X @ R
        codebooks = state.codebooks
        for _i in range(kmeans_iters):
            codebooks, _codes = km.kmeans_update(XR, codebooks)

        # (b) rotation update through the learner
        key, sub = jax.random.split(state.key)
        rot = state.rot
        if frozen:
            pass
        elif closed_form:
            codes = cb.assign(X @ R, codebooks)
            target = cb.decode(codes, codebooks)
            rot, _delta = learner.solve(rot, X, target)
        else:
            for _i in range(inner_steps):
                sub, sk = jax.random.split(sub)
                G = _distortion_grad_wrt_R(
                    X, learner.materialize(rot), codebooks)
                rot, _delta = learner.update(rot, G, lr, sk)

        dist = cb.distortion(X @ learner.materialize(rot), codebooks)
        return OPQState(rot=rot, codebooks=codebooks, key=key), dist

    state, trace = jax.lax.scan(body, state, None, length=iters)
    return learner.materialize(state.rot), state.codebooks, trace


def opq(key, X, cfg: PQConfig, iters: int = 30, kmeans_iters: int = 1):
    """Classic OPQ (SVD/Procrustes rotation solver)."""
    return alternating_minimization(
        key, X, cfg, iters=iters, rotation="procrustes",
        kmeans_iters=kmeans_iters
    )


def fit(key, X, cfg: PQConfig, *, iters: int = 30, rotation: str = "procrustes",
        inner_steps: int = 5, lr: float = 1e-4,
        kmeans_iters: int = 1) -> tuple[jax.Array, PQ, jax.Array]:
    """Protocol-idiom entry point: returns (R, quant.PQ, distortion trace)."""
    R, codebooks, trace = alternating_minimization(
        key, X, cfg, iters=iters, rotation=rotation,
        inner_steps=inner_steps, lr=lr, kmeans_iters=kmeans_iters,
    )
    return R, PQ(codebooks), trace
