"""Shared k-means machinery (extracted from core/pq.py and index/ivf.py).

One Lloyd's-iteration implementation serves every codebook fit in the repo:
per-subspace PQ codebooks, each level of a residual quantizer, and —
via ``vq_kmeans`` (a single-subspace special case) — the IVF coarse
quantizer's full-vector centroids. Streaming EMA updates (VQ-VAE style) live
here too as the alternative to gradient training of codebooks.

``kmeans_sharded`` is the distributed flavor: rows shard over a mesh axis,
each device assigns only its local rows, and the centroid accumulate is a
``psum`` — the same Lloyd update with a different summation order, so it
matches the single-device fit up to fp reordering (the distortion-parity
test in tests/test_distributed.py). This is what lets the sharded index
build (``index.ivf.build_sharded``) fit its coarse quantizer without ever
gathering the training rows onto one device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.quant.base import PQConfig
from repro.quant.codebook import assign, distortion, split


def kmeans_init(key: jax.Array, X: jax.Array, cfg: PQConfig) -> jax.Array:
    """Init codebooks by sampling K distinct rows per subspace."""
    m = X.shape[0]
    Xs = split(X, cfg.num_subspaces)  # (m, D, sub)
    idx = jax.random.choice(key, m, shape=(cfg.num_codewords,), replace=False)
    return jnp.transpose(Xs[idx], (1, 0, 2))  # (D, K, sub)


def kmeans_update(X: jax.Array, codebooks: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One Lloyd iteration over all D subspaces. Returns (codebooks, codes).

    Empty clusters keep their previous centroid.
    """
    D, K, _ = codebooks.shape
    codes = assign(X, codebooks)  # (m, D)
    Xs = split(X, D)  # (m, D, sub)

    def per_subspace(xd, cd):
        sums = jax.ops.segment_sum(xd, cd, num_segments=K)  # (K, sub)
        cnt = jax.ops.segment_sum(jnp.ones_like(cd, jnp.float32), cd, num_segments=K)
        return sums, cnt

    sums, cnt = jax.vmap(per_subspace, in_axes=(1, 1))(Xs, codes)  # (D, K, sub), (D, K)
    new = jnp.where(cnt[..., None] > 0, sums / jnp.maximum(cnt[..., None], 1.0), codebooks)
    return new, codes


@functools.partial(jax.jit, static_argnames=("cfg", "iters"))
def _kmeans_jit(key: jax.Array, X: jax.Array, cfg: PQConfig, iters: int):
    cb0 = kmeans_init(key, X, cfg)

    def body(cb, _):
        cb, codes = kmeans_update(X, cb)
        return cb, distortion(X, cb, codes)

    return jax.lax.scan(body, cb0, None, length=iters)


def kmeans(key: jax.Array, X: jax.Array, cfg: PQConfig, iters: int = 10):
    """Full k-means per subspace; returns (codebooks, distortion_trace).

    When the global ``repro.obs`` registry is enabled, each concrete fit
    records its per-iteration distortion trace (distribution
    ``kmeans.distortion`` + one ``kmeans_fit`` event carrying the whole
    trace) — the convergence signal behind every codebook in the repo.
    Calls traced under an outer jit skip the recording (tracers carry no
    values to record).
    """
    cb, trace = _kmeans_jit(key, X, cfg, iters)
    if obs.enabled() and not isinstance(trace, jax.core.Tracer):
        import numpy as np

        reg = obs.default_registry()
        t = np.asarray(trace, dtype=np.float64)
        dist = reg.distribution("kmeans.distortion",
                                subspaces=cfg.num_subspaces,
                                codewords=cfg.num_codewords)
        for v in t.tolist():
            dist.observe(v)
        reg.gauge("kmeans.final_distortion",
                  subspaces=cfg.num_subspaces,
                  codewords=cfg.num_codewords).set(float(t[-1]))
        reg.event("kmeans_fit", subspaces=cfg.num_subspaces,
                  codewords=cfg.num_codewords, iters=int(iters),
                  trace=t.tolist())
    return cb, trace


def vq_kmeans(key: jax.Array, X: jax.Array, num_centroids: int,
              iters: int = 10) -> jax.Array:
    """Full-vector k-means via the PQ machinery with a single subspace:
    PQConfig(1, L) codebooks (1, L, n) are exactly L centroids. Returns
    (L, n) centroids — the IVF coarse-quantizer fit."""
    cb, _ = kmeans(key, X, PQConfig(1, num_centroids), iters=iters)
    return cb[0]


# ---------------------------------------------------------------------------
# Sharded fit: per-shard assign + psum centroid accumulate under shard_map
# ---------------------------------------------------------------------------


def _sharded_lloyd_step(codebooks: jax.Array, Xs: jax.Array, w: jax.Array,
                        axis: str) -> jax.Array:
    """One Lloyd iteration over this shard's rows (runs inside shard_map).

    ``w`` is 1.0 for real rows, 0.0 for the padding that makes the row count
    divisible by the shard count — padded rows contribute nothing to either
    the sums or the counts. The cross-shard reduce is the two psums; the
    codebooks stay replicated (the same invariant the sharded searcher
    keeps: O(K) state replicated, O(N) state partitioned).
    """
    D, K, _ = codebooks.shape
    codes = assign(Xs, codebooks)                     # (m_local, D)
    Xss = split(Xs, D)                                # (m_local, D, sub)

    def per_subspace(xd, cd):
        sums = jax.ops.segment_sum(xd * w[:, None], cd, num_segments=K)
        cnt = jax.ops.segment_sum(w, cd, num_segments=K)
        return sums, cnt

    sums, cnt = jax.vmap(per_subspace, in_axes=(1, 1))(Xss, codes)
    sums = jax.lax.psum(sums, axis)
    cnt = jax.lax.psum(cnt, axis)
    return jnp.where(cnt[..., None] > 0,
                     sums / jnp.maximum(cnt[..., None], 1.0), codebooks)


def kmeans_sharded(key: jax.Array, X: jax.Array, cfg: PQConfig, *, mesh,
                   axis: str = "data", iters: int = 10) -> jax.Array:
    """Distributed ``kmeans``: rows of ``X`` shard over ``mesh``'s ``axis``.

    Init samples K rows exactly like the single-device fit (same key); each
    iteration assigns locally and accumulates centroids with a psum, so no
    device ever holds more than m/S training rows. Returns (D, K, sub)
    codebooks — numerically ≈ ``kmeans`` (identical update, shard-local
    partial sums reduce in a different order).
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat

    S = mesh.shape[axis]
    m = X.shape[0]
    pad = (-m) % S
    cb = kmeans_init(key, X, cfg)
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    w = jnp.concatenate([jnp.ones((m,), jnp.float32),
                         jnp.zeros((pad,), jnp.float32)])

    step = compat.shard_map(
        functools.partial(_sharded_lloyd_step, axis=axis),
        mesh=mesh,
        in_specs=(P(), P(axis), P(axis)),
        out_specs=P(),
        check_vma=False,
    )
    for _ in range(iters):
        cb = step(cb, Xp, w)
    return cb


def vq_kmeans_sharded(key: jax.Array, X: jax.Array, num_centroids: int, *,
                      mesh, axis: str = "data", iters: int = 10) -> jax.Array:
    """``vq_kmeans`` with the fit sharded over ``mesh``'s ``axis`` — the
    coarse-quantizer fit of the partitioned index build."""
    cb = kmeans_sharded(key, X, PQConfig(1, num_centroids),
                        mesh=mesh, axis=axis, iters=iters)
    return cb[0]


def codebook_ema_update(codebooks: jax.Array, X: jax.Array, codes: jax.Array,
                        decay: float = 0.99) -> jax.Array:
    """Streaming EMA codebook update (VQ-VAE style) — an alternative to
    gradient training of codebooks inside the end-to-end loop."""
    D, K, _ = codebooks.shape
    Xs = split(X, D)

    def per_subspace(xd, cd):
        sums = jax.ops.segment_sum(xd, cd, num_segments=K)
        cnt = jax.ops.segment_sum(jnp.ones_like(cd, jnp.float32), cd, num_segments=K)
        return sums, cnt

    sums, cnt = jax.vmap(per_subspace, in_axes=(1, 1))(Xs, codes)
    batch_mean = sums / jnp.maximum(cnt[..., None], 1.0)
    upd = decay * codebooks + (1.0 - decay) * batch_mean
    return jnp.where(cnt[..., None] > 0, upd, codebooks)
