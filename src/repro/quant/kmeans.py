"""Shared k-means machinery (extracted from core/pq.py and index/ivf.py).

One Lloyd's-iteration implementation serves every codebook fit in the repo:
per-subspace PQ codebooks, each level of a residual quantizer, and —
via ``vq_kmeans`` (a single-subspace special case) — the IVF coarse
quantizer's full-vector centroids. Streaming EMA updates (VQ-VAE style) live
here too as the alternative to gradient training of codebooks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.quant.base import PQConfig
from repro.quant.codebook import assign, distortion, split


def kmeans_init(key: jax.Array, X: jax.Array, cfg: PQConfig) -> jax.Array:
    """Init codebooks by sampling K distinct rows per subspace."""
    m = X.shape[0]
    Xs = split(X, cfg.num_subspaces)  # (m, D, sub)
    idx = jax.random.choice(key, m, shape=(cfg.num_codewords,), replace=False)
    return jnp.transpose(Xs[idx], (1, 0, 2))  # (D, K, sub)


def kmeans_update(X: jax.Array, codebooks: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One Lloyd iteration over all D subspaces. Returns (codebooks, codes).

    Empty clusters keep their previous centroid.
    """
    D, K, _ = codebooks.shape
    codes = assign(X, codebooks)  # (m, D)
    Xs = split(X, D)  # (m, D, sub)

    def per_subspace(xd, cd):
        sums = jax.ops.segment_sum(xd, cd, num_segments=K)  # (K, sub)
        cnt = jax.ops.segment_sum(jnp.ones_like(cd, jnp.float32), cd, num_segments=K)
        return sums, cnt

    sums, cnt = jax.vmap(per_subspace, in_axes=(1, 1))(Xs, codes)  # (D, K, sub), (D, K)
    new = jnp.where(cnt[..., None] > 0, sums / jnp.maximum(cnt[..., None], 1.0), codebooks)
    return new, codes


@functools.partial(jax.jit, static_argnames=("cfg", "iters"))
def kmeans(key: jax.Array, X: jax.Array, cfg: PQConfig, iters: int = 10):
    """Full k-means per subspace; returns (codebooks, distortion_trace)."""
    cb0 = kmeans_init(key, X, cfg)

    def body(cb, _):
        cb, codes = kmeans_update(X, cb)
        return cb, distortion(X, cb, codes)

    cb, trace = jax.lax.scan(body, cb0, None, length=iters)
    return cb, trace


def vq_kmeans(key: jax.Array, X: jax.Array, num_centroids: int,
              iters: int = 10) -> jax.Array:
    """Full-vector k-means via the PQ machinery with a single subspace:
    PQConfig(1, L) codebooks (1, L, n) are exactly L centroids. Returns
    (L, n) centroids — the IVF coarse-quantizer fit."""
    cb, _ = kmeans(key, X, PQConfig(1, num_centroids), iters=iters)
    return cb[0]


def codebook_ema_update(codebooks: jax.Array, X: jax.Array, codes: jax.Array,
                        decay: float = 0.99) -> jax.Array:
    """Streaming EMA codebook update (VQ-VAE style) — an alternative to
    gradient training of codebooks inside the end-to-end loop."""
    D, K, _ = codebooks.shape
    Xs = split(X, D)

    def per_subspace(xd, cd):
        sums = jax.ops.segment_sum(xd, cd, num_segments=K)
        cnt = jax.ops.segment_sum(jnp.ones_like(cd, jnp.float32), cd, num_segments=K)
        return sums, cnt

    sums, cnt = jax.vmap(per_subspace, in_axes=(1, 1))(Xs, codes)
    batch_mean = sums / jnp.maximum(cnt[..., None], 1.0)
    upd = decay * codebooks + (1.0 - decay) * batch_mean
    return jnp.where(cnt[..., None] > 0, upd, codebooks)
