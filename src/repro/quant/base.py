"""The `Quantizer` protocol — the one interface every quantization scheme in
this repo serves through (paper §2.1's pluggable φ).

The paper's transform T(X) = φ(XR)Rᵀ treats the quantizer φ as a component;
before this subsystem existed the repo had four divergent copies of
codebook/encode/ADC logic. Everything now speaks this protocol:

  * ``fit``        (classmethod) train codebooks from data
  * ``encode``     (m, n) -> (m, code_width) integer codes
  * ``decode``     codes -> (m, n) reconstruction (differentiable wrt codebooks)
  * ``encode_st``  straight-through φ: forward = decode(encode(X)),
                   backward = identity wrt X (Bengio et al. 2013)
  * ``adc_tables`` (b, n) queries -> (b, code_width, K) inner-product LUTs;
                   scores are Σ_c LUT[c, code_c] — the shape every kernel in
                   the shared ADC family (kernels/adc_common.py) consumes
  * ``distortion`` (1/m)‖X − φ(X)‖²_F — the paper's Eq.(1) second term
  * ``rotate``     absorb a product of disjoint Givens plane rotations into
                   the codebooks (what makes index.maintain.refresh_rotation
                   scheme-agnostic)

``code_width`` is the number of integer columns per item: D for PQ, M·D for
a depth-M residual quantizer. Multi-level schemes flatten their level axis
into ``code_width`` so the downstream ADC kernels are parameterized by
residual depth purely through that dimension — one kernel family serves PQ,
RQ, and the KV cache alike.

Implementations (PQ, RQ, VQ) are frozen-dataclass pytrees, so a Quantizer
can ride inside jit-traced structures (e.g. index.ivf.IVFPQIndex) and be
differentiated through (codebook leaves).
"""
from __future__ import annotations

from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


class PQConfig(NamedTuple):
    """Per-level product-quantizer shape: D subspaces × K codewords."""

    num_subspaces: int  # D
    num_codewords: int  # K

    def code_dtype(self):
        return jnp.uint8 if self.num_codewords <= 256 else jnp.int32


@runtime_checkable
class Quantizer(Protocol):
    """Structural interface — see module docstring for the contract."""

    @property
    def dim(self) -> int: ...               # input vector dimensionality n

    @property
    def code_width(self) -> int: ...        # integer columns per item

    @property
    def num_codewords(self) -> int: ...     # K (LUT last-dim)

    @property
    def code_dtype(self): ...               # storage dtype for codes

    def encode(self, X: jax.Array) -> jax.Array: ...

    def decode(self, codes: jax.Array) -> jax.Array: ...

    def encode_st(self, X: jax.Array) -> jax.Array: ...

    def adc_tables(self, Q: jax.Array) -> jax.Array: ...

    def distortion(self, X: jax.Array,
                   codes: jax.Array | None = None) -> jax.Array: ...

    def rotate(self, pi: jax.Array, pj: jax.Array,
               theta: jax.Array) -> "Quantizer": ...
