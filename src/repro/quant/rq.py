"""RQ — depth-M residual product quantizer (Transformed Residual
Quantization, Yuan & Liu 2015) behind the ``Quantizer`` protocol.

Level 0 product-quantizes the vector; each further level quantizes the
residual left by the levels before it. Reconstruction is the *sum* of the
level decodes, so for inner-product retrieval the ADC score stays a single
LUT sum over ``code_width = M·D`` columns:

    ⟨q, x̂⟩ = Σ_l ⟨q, decode_l(c_l)⟩ = Σ_{l,d} LUT[l·D+d, c_{l,d}]

i.e. an RQ looks to the shared ADC kernel family exactly like a PQ with M·D
subspaces — residual depth is a *shape parameter*, not a new kernel. At
equal K this trades M× code bytes for strictly lower distortion (each level
is fit on the previous level's error), tracing the recall/compression
frontier that benchmarks/ivf_recall_qps.py sweeps.

Codes are stored level-major: column l·D + d holds level l, subspace d.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.quant import codebook as cb
from repro.quant import kmeans as km
from repro.quant.base import PQConfig


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class RQ:
    """Residual quantizer. Single pytree leaf: ``codebooks (M, D, K, sub)``."""

    codebooks: jax.Array  # (M, D, K, sub)

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("codebooks"), self.codebooks),), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- static shape facts ------------------------------------------------
    @property
    def num_levels(self) -> int:
        return self.codebooks.shape[0]

    @property
    def num_subspaces(self) -> int:
        return self.codebooks.shape[1]

    @property
    def num_codewords(self) -> int:
        return self.codebooks.shape[2]

    @property
    def sub(self) -> int:
        return self.codebooks.shape[3]

    @property
    def dim(self) -> int:
        return self.num_subspaces * self.sub

    @property
    def code_width(self) -> int:
        return self.num_levels * self.num_subspaces

    @property
    def code_dtype(self):
        return jnp.uint8 if self.num_codewords <= 256 else jnp.int32

    @property
    def config(self) -> PQConfig:
        return PQConfig(self.num_subspaces, self.num_codewords)

    # -- fitting -----------------------------------------------------------
    @classmethod
    def fit(cls, key: jax.Array, X: jax.Array, cfg: PQConfig, depth: int,
            iters: int = 10) -> tuple["RQ", jax.Array]:
        """Greedy level-by-level fit: k-means each level on the residual the
        previous levels leave. Returns (RQ, (depth, iters) distortion trace
        — per-level traces are of the *residual* that level sees)."""
        res = X
        cbs, traces = [], []
        for lvl in range(depth):
            level_cb, tr = km.kmeans(jax.random.fold_in(key, lvl), res, cfg,
                                     iters=iters)
            res = res - cb.quantize(res, level_cb)
            cbs.append(level_cb)
            traces.append(tr)
        return cls(jnp.stack(cbs)), jnp.stack(traces)

    # -- Quantizer protocol ------------------------------------------------
    def encode(self, X: jax.Array) -> jax.Array:
        """(m, n) -> (m, M·D) int32, level-major (greedy residual encode)."""
        res = X
        cols = []
        for lvl in range(self.num_levels):
            codes_l = cb.assign(res, self.codebooks[lvl])
            res = res - cb.decode(codes_l, self.codebooks[lvl])
            cols.append(codes_l)
        return jnp.concatenate(cols, axis=-1)

    def decode(self, codes: jax.Array) -> jax.Array:
        """(m, M·D) -> (m, n): sum of per-level reconstructions."""
        D = self.num_subspaces
        codes = codes.astype(jnp.int32)
        out = cb.decode(codes[..., :D], self.codebooks[0])
        for lvl in range(1, self.num_levels):
            out = out + cb.decode(codes[..., lvl * D:(lvl + 1) * D],
                                  self.codebooks[lvl])
        return out

    def encode_st(self, X: jax.Array) -> jax.Array:
        q = self.decode(jax.lax.stop_gradient(self.encode(X)))
        return X + jax.lax.stop_gradient(q - X)

    def adc_tables(self, Q: jax.Array) -> jax.Array:
        """(b, n) -> (b, M·D, K): per-level LUTs flattened level-major so the
        shared kernels see one wide PQ."""
        luts = [cb.adc_lut(Q, self.codebooks[lvl])
                for lvl in range(self.num_levels)]
        return jnp.concatenate(luts, axis=1)

    def lut_operands(self) -> tuple[jax.Array, jax.Array]:
        """Operands for the rotation-fused LUT-build kernel: codebooks
        flattened level-major to (M·D, K, sub) — matching the code-column
        layout — and the one-hot column map sending column l·D+d to query
        subspace d (every level reads the same query slice)."""
        M, D, K, sub = self.codebooks.shape
        cols = jnp.arange(M * D)
        colmap = jnp.zeros((M * D, D), jnp.float32).at[cols, cols % D].set(1.0)
        return self.codebooks.reshape(M * D, K, sub), colmap

    def distortion(self, X: jax.Array,
                   codes: jax.Array | None = None) -> jax.Array:
        if codes is None:
            codes = jax.lax.stop_gradient(self.encode(X))
        q = self.decode(codes)
        return jnp.mean(jnp.sum(jnp.square(X - q), axis=-1))

    def rotate(self, pi: jax.Array, pj: jax.Array,
               theta: jax.Array) -> "RQ":
        """Within-subspace plane rotations commute with the residual
        recursion (residuals rotate with the data), so one call refreshes
        every level. Caller zeroes θ on cross-subspace pairs."""
        return RQ(cb.rotate_codebooks(self.codebooks, pi, pj, theta))
