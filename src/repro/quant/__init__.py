"""repro.quant — the unified quantizer subsystem.

Every quantization scheme in the repo (flat PQ, multi-level residual RQ, the
IVF coarse VQ, OPQ's rotation-aware fit, the per-head KV-cache PQ) serves
through one ``Quantizer`` protocol and one codebook/k-means substrate, and
scores through one shared Pallas ADC kernel family (repro.kernels):

  base      the Quantizer protocol + PQConfig
  codebook  per-subspace codebook primitives: assign/decode/STE/distortion,
            ADC tables, Givens codebook rotation (refresh_rotation's engine)
  kmeans    shared Lloyd's iterations, EMA updates, full-vector vq_kmeans
  pq        PQ    — single-level product quantizer (code_width = D)
  rq        RQ    — depth-M residual quantizer     (code_width = M·D)
  vq        VQ    — full-vector coarse quantizer    (code_width = 1)
  opq       OPQ alternating minimization (SVD / GCD / Cayley solvers)

Consumers: core.index_layer (training-path T(X) = φ(XR)Rᵀ via ``encode_st``),
core.kv_quant (per-head PQ on attention KV), index.* (VQ coarse + PQ/RQ
residual quantizer per IVF index), benchmarks/ivf_recall_qps.py (PQ-vs-RQ
recall/compression frontier). ``core.pq`` and ``core.opq`` remain as
compatibility shims onto this package — see README.md for the migration
table.
"""
from repro.quant import base, codebook, kmeans, opq  # noqa: F401
from repro.quant.base import PQConfig, Quantizer  # noqa: F401
from repro.quant.codebook import (  # noqa: F401
    adc_score_tables,
    rotate_codebooks,
)
from repro.quant.pq import PQ  # noqa: F401
from repro.quant.rq import RQ  # noqa: F401
from repro.quant.vq import VQ  # noqa: F401


def fit_quantizer(key, X, cfg: PQConfig, *, depth: int = 1, iters: int = 10):
    """Fit the residual family by depth: PQ at depth 1, RQ above. Returns
    (quantizer, distortion trace)."""
    if depth <= 1:
        return PQ.fit(key, X, cfg, iters=iters)
    return RQ.fit(key, X, cfg, depth, iters=iters)
