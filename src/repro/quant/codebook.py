"""Codebook substrate shared by every quantizer (moved here from core/pq.py).

Pure-jnp primitives over per-subspace codebooks ``(D, K, sub)`` — split/merge,
nearest-codeword assignment, decode, the straight-through estimator, the
distortion objective, and ADC lookup tables. Multi-level (residual) schemes
stack a leading level axis ``(M, D, K, sub)`` and flatten it into the
``code_width = M·D`` column axis before touching the shared kernels.

The non-differentiable argmin is bridged by the gradient straight-through
estimator (Bengio et al. 2013), exactly as in the paper / Zhang et al. 2021.

Codebooks: (D, K, sub) float. Codes: (m, D) int32 (uint8 in storage).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def split(X: jax.Array, D: int) -> jax.Array:
    """(..., n) -> (..., D, n/D)."""
    *lead, n = X.shape
    assert n % D == 0, f"n={n} not divisible by D={D}"
    return X.reshape(*lead, D, n // D)


def merge(Xs: jax.Array) -> jax.Array:
    """(..., D, sub) -> (..., D*sub)."""
    *lead, D, sub = Xs.shape
    return Xs.reshape(*lead, D * sub)


def assign(X: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Nearest codeword per subspace. (m, n) -> (m, D) int32.

    Uses ‖x−c‖² = ‖x‖² − 2⟨x,c⟩ + ‖c‖² with the ‖x‖² term dropped (constant
    in the argmin) — so the hot op is one einsum on the MXU.
    """
    D = codebooks.shape[0]
    Xs = split(X, D)  # (m, D, sub)
    dots = jnp.einsum("mds,dks->mdk", Xs, codebooks)
    cn = jnp.sum(jnp.square(codebooks), axis=-1)  # (D, K)
    d2 = cn[None, :, :] - 2.0 * dots
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def decode(codes: jax.Array, codebooks: jax.Array) -> jax.Array:
    """(m, D) codes -> (m, n) reconstruction (differentiable wrt codebooks)."""
    D = codebooks.shape[0]
    gathered = codebooks[jnp.arange(D)[None, :], codes]  # (m, D, sub)
    return merge(gathered)


def quantize(X: jax.Array, codebooks: jax.Array) -> jax.Array:
    """φ(X): hard quantization, no gradient bridging."""
    return decode(assign(X, codebooks), codebooks)


def quantize_ste(X: jax.Array, codebooks: jax.Array) -> jax.Array:
    """φ(X) with straight-through estimator: forward = quantized value,
    backward = identity wrt X (codebooks receive no grad through this path —
    they are trained by the distortion loss)."""
    q = decode(jax.lax.stop_gradient(assign(X, codebooks)), codebooks)
    return X + jax.lax.stop_gradient(q - X)


def distortion(X: jax.Array, codebooks: jax.Array,
               codes: jax.Array | None = None) -> jax.Array:
    """(1/m)‖X − φ(X)‖²_F — the paper's quantization-distortion metric/loss.

    Differentiable wrt both X and codebooks (assignment is stop-gradiented).
    """
    if codes is None:
        codes = jax.lax.stop_gradient(assign(X, codebooks))
    q = decode(codes, codebooks)
    return jnp.mean(jnp.sum(jnp.square(X - q), axis=-1))


def adc_lut(q: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Asymmetric-distance lookup table for a query batch.

    For inner-product / cosine retrieval the score of item with codes c is
    Σ_d LUT[d, c_d] with LUT[d, k] = ⟨q_d, C[d, k]⟩.  (b, n) -> (b, D, K).
    """
    D = codebooks.shape[0]
    qs = split(q, D)  # (b, D, sub)
    return jnp.einsum("bds,dks->bdk", qs, codebooks)


def adc_score(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Sum LUT entries over subspaces: (b, D, K) × (N, D) -> (b, N).

    Pure-jnp gather formulation — the small-N oracle. The serving paths go
    through ``adc_score_tables`` below (shared Pallas kernel family).
    """
    D = lut.shape[1]
    gathered = lut[:, jnp.arange(D)[None, :], codes]  # (b, N, D)
    return jnp.sum(gathered, axis=-1)


def adc_score_tables(tables: jax.Array, codes: jax.Array, *,
                     use_kernel: bool = True) -> jax.Array:
    """Score PQ/RQ codes against protocol-shaped ADC tables.

    ``tables (b, code_width, K)`` (any Quantizer.adc_tables output — residual
    depth is already flattened into ``code_width``) × ``codes
    (N, code_width)`` -> (b, N). Dispatches to the fused Pallas flat-scan
    kernel (kernels/adc_lookup.py) or its jnp oracle.
    """
    return kops.adc_lookup(tables, codes, use_kernel=use_kernel)


def rotate_codebooks(codebooks: jax.Array, pi: jax.Array, pj: jax.Array,
                     theta: jax.Array) -> jax.Array:
    """Absorb disjoint Givens plane rotations ∏ℓ R_{pi[ℓ],pj[ℓ]}(θℓ) of the
    *full* n-dim space into per-subspace codebooks.

    ``codebooks (..., D, K, sub)`` (optional leading level axes). In the
    full-dim layout, codeword slot k's column d·sub+t holds
    codebooks[..., d, k, t]; within-subspace pairs only mix columns inside
    one subspace slice, so one pair-rotation call refreshes all D (and all
    levels of) codebooks at once. Callers must zero θ for cross-subspace
    pairs — those cannot be absorbed into a product codebook (the zeroed
    rotation is the identity).
    """
    from repro.core import givens  # function-level: core imports quant shims

    *lead, D, K, sub = codebooks.shape
    cw = jnp.moveaxis(codebooks, -2, -3).reshape(-1, D * sub)  # (lead·K, n)
    cw = givens.apply_pair_rotations(cw, pi, pj, theta)
    return jnp.moveaxis(cw.reshape(*lead, K, D, sub), -2, -3)
