"""VQ — full-vector (single-subspace) quantizer: the IVF coarse quantizer.

A vector quantizer is a PQ with D = 1, so ``code_width == 1`` and the ADC
table degenerates to the plain centroid inner products Q·Cᵀ — exactly the
coarse term of the IVF score decomposition. Kept as its own protocol
implementation so index code reads ``index.coarse`` / ``index.quantizer``
symmetrically and ``refresh_rotation`` can rotate both the same way.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.quant import codebook as cb
from repro.quant import kmeans as km


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class VQ:
    """Vector quantizer. Single pytree leaf: ``centroids (L, n)``."""

    centroids: jax.Array  # (L, n)

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("centroids"), self.centroids),), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- static shape facts ------------------------------------------------
    @property
    def num_centroids(self) -> int:
        return self.centroids.shape[0]

    @property
    def num_codewords(self) -> int:
        return self.centroids.shape[0]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    @property
    def code_width(self) -> int:
        return 1

    @property
    def code_dtype(self):
        return jnp.uint8 if self.num_centroids <= 256 else jnp.int32

    # -- fitting -----------------------------------------------------------
    @classmethod
    def fit(cls, key: jax.Array, X: jax.Array, num_centroids: int,
            iters: int = 10) -> "VQ":
        return cls(km.vq_kmeans(key, X, num_centroids, iters=iters))

    # -- Quantizer protocol ------------------------------------------------
    def assign(self, X: jax.Array) -> jax.Array:
        """Nearest centroid: (m, n) -> (m,) int32 — the IVF list id."""
        return cb.assign(X, self.centroids[None, ...])[:, 0]

    def encode(self, X: jax.Array) -> jax.Array:
        return self.assign(X)[:, None]  # (m, 1)

    def decode(self, codes: jax.Array) -> jax.Array:
        return self.centroids[codes.astype(jnp.int32)[..., 0]]

    def encode_st(self, X: jax.Array) -> jax.Array:
        q = self.decode(jax.lax.stop_gradient(self.encode(X)))
        return X + jax.lax.stop_gradient(q - X)

    def adc_tables(self, Q: jax.Array) -> jax.Array:
        return (Q @ self.centroids.T)[:, None, :]  # (b, 1, L)

    def distortion(self, X: jax.Array,
                   codes: jax.Array | None = None) -> jax.Array:
        if codes is None:
            codes = jax.lax.stop_gradient(self.encode(X))
        q = self.decode(codes)
        return jnp.mean(jnp.sum(jnp.square(X - q), axis=-1))

    def rotate(self, pi: jax.Array, pj: jax.Array,
               theta: jax.Array) -> "VQ":
        """Centroids live in the rotated space; any disjoint plane product
        applies exactly (no subspace structure to respect)."""
        from repro.core import givens  # function-level: avoid import cycle

        return VQ(givens.apply_pair_rotations(self.centroids, pi, pj, theta))
