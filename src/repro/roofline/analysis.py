"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh) cell, all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
  collective = collective_bytes_per_device / link_bw       (~50 GB/s/link)

``cost_analysis()`` of an SPMD-partitioned module reports PER-DEVICE flops
and bytes (the module IS the per-device program). Collective bytes are not
in cost_analysis — ``collective_stats`` regex-parses the compiled HLO and
sums result-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (async -start forms included, -done skipped).
All-reduce is counted 2× (ring = reduce-scatter + all-gather).

The report also carries MODEL_FLOPS / HLO_FLOPs — the "useful compute"
ratio that exposes remat/dispatch waste.
"""
from __future__ import annotations

import re
from typing import Any

# Hardware constants for the roofline terms (TPU v5e) — the ONE source of
# truth. The launch layer (mesh policy, dry-run HBM check) re-exports these
# from here so the roofline table and the dry-run report can never disagree
# on what a chip is.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s per chip, bf16
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
CHIP_HBM_BYTES = 16 * 1024**3  # v5e: 16 GiB


def num_chips(mesh) -> int:
    """Total devices of a mesh — the per-device divisor of every roofline
    and capacity figure (dry-run report, sharded-index sizing)."""
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict[str, Any]:
    """Per-op-type byte totals + overall collective_bytes (per device)."""
    per_op: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op, _start = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(type_str)
        if op == "all-reduce":
            b *= 2  # ring all-reduce = reduce-scatter + all-gather
        per_op[op] = per_op.get(op, 0) + b
        counts[op] = counts.get(op, 0) + 1
    return {
        "bytes_by_op": per_op,
        "counts_by_op": counts,
        "collective_bytes": sum(per_op.values()),
    }


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
) -> dict[str, float]:
    compute = flops_per_device / PEAK_FLOPS_BF16
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        # fraction of the bound spent on useful compute — the roofline score
        "roofline_fraction": (compute / bound) if bound > 0 else 0.0,
    }


def analyze(compiled, lowered=None, model_flops_total: float | None = None,
            n_chips: int = 1, loop_trips: float = 1.0) -> dict[str, Any]:
    """Full per-cell report from a compiled executable.

    ``loop_trips``: XLA's cost_analysis counts each while-loop body ONCE, so
    scan-dominated programs under-report flops/bytes by the trip count
    (measured ~600× on the 96-layer × 16-microbatch train cell). The cell
    builder supplies the known trip product of the dominant loop nest
    (layers × microbatches); out-of-loop contributions are ≤ a few % for
    scan-dominated cells, so scaling the totals is a ≲10% approximation —
    recorded here rather than hidden.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # 0.4.x returns [dict], newer a dict
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0)) * loop_trips
    byts = float(cost.get("bytes accessed", 0.0)) * loop_trips
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    coll = {
        "bytes_by_op": coll["bytes_by_op"],
        "counts_by_op": coll["counts_by_op"],
        "collective_bytes": coll["collective_bytes"] * loop_trips,
    }
    mem = compiled.memory_analysis()
    out = {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "loop_trips": loop_trips,
        **coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
            "hbm_limit": CHIP_HBM_BYTES,
        },
        **roofline_terms(flops, byts, coll["collective_bytes"]),
    }
    if model_flops_total is not None and flops > 0:
        out["model_flops_total"] = model_flops_total
        out["model_flops_per_device"] = model_flops_total / n_chips
        out["useful_compute_ratio"] = (model_flops_total / n_chips) / flops
    return out

# ---------------------------------------------------------------------------
# kernel-level predicted-vs-measured (PR 7)
# ---------------------------------------------------------------------------

def kernel_predicted(flops: float, bytes_moved: float,
                     collective_bytes: float = 0.0) -> dict[str, Any]:
    """Roofline bound for a single kernel launch, in µs.

    Kernels (unlike train cells) are small enough to model their traffic in
    closed form, so the benchmark harness computes ``bytes_moved`` from the
    grid schedule (see :func:`adc_scan_traffic`) and books this prediction
    next to the measured wall-clock — the "predicted vs measured" entry every
    kernel section of ``benchmarks/kernels_micro.py`` must carry.
    """
    t = roofline_terms(flops, bytes_moved, collective_bytes)
    return {
        "predicted_us": max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6,
        "dominant": t["dominant"],
        "flops": flops,
        "bytes": bytes_moved,
    }


def adc_scan_traffic(b: int, Dp: int, K: int, steps: int, block: int,
                     lut_dtype: str = "float32", code_bytes: int = 1,
                     luts_per_step: int = 1) -> float:
    """Modeled HBM traffic (bytes) of one ADC scan launch.

    Per grid step the scan DMAs ``luts_per_step`` LUT rows (the whole
    (b, Dp, K) table for the flat scan, one query's row for the selected-block
    scan), one (block, Dp) code tile, and writes one (b, block) f32 score
    tile; ``steps`` is the number of scheduled grid steps. Integer LUT packs
    move 1 byte/entry plus the f32 (Dp, 2) scale/offset sidecar — the per-step
    LUT stream shrinks 4×, which is where the ≥2× total-bytes win of the int8
    pack comes from (codes are uint8 for K ≤ 256, so the corpus-side stream
    is already thin).
    """
    lut_entry = 4 if lut_dtype == "float32" else 1
    scales = 0 if lut_dtype == "float32" else Dp * 2 * 4
    lut_row = luts_per_step * (Dp * K * lut_entry + scales)
    codes_blk = block * Dp * code_bytes
    out_blk = b * block * 4
    return float(steps) * (lut_row + codes_blk + out_blk)


def fused_lut_traffic(b: int, n: int, Dp: int, K: int, sub: int) -> float:
    """Modeled HBM traffic (bytes) of one fused rotation-aware LUT build:
    queries (b, n) + delta product (n, n) + flat codebooks (Dp, K, sub) +
    one-hot column map (Dp, n) in, (b, Dp, K) f32 table out."""
    return 4.0 * (b * n + n * n + Dp * K * sub + Dp * n + b * Dp * K)
