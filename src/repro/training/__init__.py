"""Training substrate: optimizer (AdamW + GCD manifold routing), train state,
sharded checkpointing, error-feedback gradient compression."""
