"""Fault-tolerant checkpointing.

Design (DESIGN.md §6):
  * pytrees are flattened to path-keyed arrays and written as ``.npz``;
  * writes are atomic (tmp file + rename) and finalized by a ``manifest.json``
    whose presence marks the checkpoint complete — a crash mid-write leaves
    only an ignorable partial directory;
  * ``save_async`` runs the serialization on a background thread so the
    train loop never blocks on disk (compute/IO overlap);
  * checkpoints are saved *logically* (host numpy, unsharded), so a restore
    may use a different mesh — this is what makes restarts elastic: the
    launcher re-device_puts with whatever shardings the new mesh dictates;
  * ``keep_n`` old checkpoints are retained for straggler/corruption rollback.

At real fleet scale one would write per-host shards via tensorstore; the
layout here keeps the same manifest/atomicity contract on one host.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
         keep_n: int = 3) -> str:
    """Blocking save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": int(step),
        "time": time.time(),
        "keys": sorted(arrays.keys()),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _cleanup(ckpt_dir, keep_n)
    return final


_PENDING: list[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
               keep_n: int = 3) -> threading.Thread:
    """Non-blocking save: device arrays are fetched to host synchronously
    (cheap copy), serialization happens on a background thread."""
    host_tree = jax.tree.map(np.asarray, tree)
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree, metadata, keep_n),
        daemon=True,
    )
    t.start()
    _PENDING.append(t)
    return t


def wait_pending() -> None:
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _cleanup(ckpt_dir: str, keep_n: int) -> None:
    done = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    )
    for d in done[:-keep_n]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    """Newest COMPLETE checkpoint (manifest present), or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, d, "manifest.json")
        ):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like) -> Any:
    """Restore into the structure of ``like`` (a pytree template). Arrays are
    returned as host numpy; callers device_put with the CURRENT mesh's
    shardings (elastic re-mesh on resume)."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat[0]:
        key = _SEP.join(
            str(getattr(q, "key", getattr(q, "idx", getattr(q, "name", q))))
            for q in p
        )
        arr = data[key]
        if not hasattr(leaf, "shape"):  # python scalar leaf (e.g. pipeline step)
            leaves.append(type(leaf)(arr.item()))
            continue
        assert arr.shape == tuple(leaf.shape), (
            f"checkpoint mismatch at {key}: {arr.shape} vs {leaf.shape}"
        )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves), manifest


def restore_latest(ckpt_dir: str, like):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    return restore(ckpt_dir, step, like)
