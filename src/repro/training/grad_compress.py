"""Error-feedback int8 gradient compression for the cross-pod axis.

The inter-pod (DCN) links are the slowest hop in a multi-pod mesh; the
standard trick (1-bit Adam / EF-SGD lineage) is to quantize the cross-pod
all-reduce payload and carry the quantization error into the next step.

``ef_psum_int8`` quantizes to int8 with a *shared* scale (one scalar psum)
and pre-divides by the axis size so the integer sum cannot overflow int8 —
the payload of the big all-reduce is 1 byte/element instead of 4 (f32) or
2 (bf16). The local quantization residual is returned for error feedback:

    x      = g + err                      (apply feedback)
    q      = round(x / (s·n)) ∈ [−127,127/n]
    g_out  = psum(q) · s · n / n = psum(q)·s
    err'   = x − q·s·n                    (carry what was lost)

With error feedback the scheme is unbiased over time and converges at the
full-precision rate on smooth objectives (Karimireddy et al. 2019).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_quantize(x: jax.Array, err: jax.Array, axis_size: int):
    """Returns (q int8, scale f32 scalar, new_err). Shared-scale int8 with
    1/axis_size headroom so the integer psum stays in int8 range."""
    xf = x.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / (scale * axis_size)), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale * axis_size
    return q, scale, xf - deq


def ef_psum_int8(g: jax.Array, err: jax.Array, axis: str, axis_size: int):
    """Inside shard_map: all-reduce ``g`` over ``axis`` with an int8 payload.

    The scale must be identical on every participant, so it is psum-maxed
    first (a scalar — negligible traffic). Returns (g_summed, new_err).
    """
    xf = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / (scale * axis_size)), -127, 127).astype(jnp.int8)
    summed_q = jax.lax.psum(q, axis)           # 1-byte payload on the wire
    g_out = summed_q.astype(jnp.float32) * scale * axis_size
    new_err = xf - q.astype(jnp.float32) * scale * axis_size
    return g_out, new_err


def make_compressed_crosspod_psum(mesh, axis: str = "pod"):
    """Build a shard_map'd reducer f(g_stacked, err_stacked) -> (g_sum, err').

    ``g_stacked`` carries a leading pod axis of size n (one differing gradient
    per pod, sharded over ``axis``); the error-feedback buffer has the same
    layout and stays pod-local. The summed gradient comes back replicated.

    Used by the launcher when ``--grad-compress`` is on: the data/model-axis
    reductions stay full precision (fast ICI), only the pod-axis hop is
    compressed.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat

    n = mesh.shape[axis]

    def f(g, err):
        g_sum, err_new = ef_psum_int8(g[0], err[0], axis, n)
        return g_sum, err_new[None]

    return compat.shard_map(
        f, mesh=mesh,
        in_specs=(P(axis), P(axis)), out_specs=(P(), P(axis)),
        check_vma=False,
    )
