"""Train state + generic train-step builder.

``make_train_step(loss_fn, opt_cfg)`` turns any ``loss_fn(params, batch)``
into a jit-able ``(state, batch) → (state, metrics)`` step that:
  * differentiates the loss (rotations included — their grads feed the
    configured ``repro.rotations`` learner),
  * routes updates through training.optimizer (AdamW + manifold learner),
  * advances the RNG deterministically from the step counter.

End-to-end losses that train *through* a quantized index compose with
``eq1_loss`` below: the paper's Eq.(1) built from any ``repro.quant``
Quantizer via its straight-through ``encode_st`` (this is the route
core.index_layer.apply takes inside recsys.twotower_loss too).

The same step function is what launch/dryrun.py lowers for the training
cells, so the compiled artifact includes the full optimizer and the GCD
update — the roofline sees the real system, not just the forward pass.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.training import optimizer as opt_lib


def eq1_loss(quantizer, R: jax.Array, X: jax.Array,
             task_loss: Callable[[jax.Array], jax.Array],
             distortion_weight: float = 1.0) -> jax.Array:
    """Paper Eq.(1):  L_task(T(X)) + w·(1/m)‖XR − φ(XR)‖²  with
    T(X) = φ(XR)Rᵀ and φ any ``repro.quant`` Quantizer.

    The non-differentiable φ is bridged by ``Quantizer.encode_st`` (forward
    = quantized value, backward = identity wrt X), so ∂/∂X reaches the
    towers, ∂/∂codebooks comes from the distortion term, and ∂/∂R feeds the
    rotation learner's manifold update in training.optimizer.
    """
    XR = X @ R
    tx = quantizer.encode_st(XR) @ R.T
    return task_loss(tx) + distortion_weight * quantizer.distortion(XR)


class TrainState(NamedTuple):
    params: Any
    opt_state: opt_lib.OptState
    step: jax.Array
    rng: jax.Array


def init_state(key: jax.Array, params, opt_cfg: opt_lib.OptimizerConfig) -> TrainState:
    return TrainState(
        params=params,
        opt_state=opt_lib.init(params, opt_cfg),
        step=jnp.int32(0),
        rng=key,
    )


def make_train_step(
    loss_fn: Callable[..., jax.Array],
    opt_cfg: opt_lib.OptimizerConfig,
    grad_shardings=None,
    emit_deltas: bool = False,
) -> Callable:
    """loss_fn(params, *batch_arrays) -> scalar. Returns a pure step fn.

    ``emit_deltas=True`` adds ``metrics["rotation_deltas"]`` — the
    ``{path_key: RotationDelta}`` dict each manifold update applied, ready
    to replay onto a live index via ``Engine.refresh`` (the overlapped
    train-and-refresh loop in ``repro.pipeline``).

    ``opt_cfg.accum_steps > 1`` splits the global batch into microbatches
    scanned sequentially with f32 gradient accumulation — activation memory
    shrinks by the accumulation factor (the grads scan is NOT differentiated,
    so only one microbatch's activations are ever live).

    ``grad_shardings`` (a params-shaped tree of NamedShardings) pins each
    gradient leaf to its parameter's sharding — without it the SPMD
    partitioner is free to stage cotangent stacks through exotic tilings."""

    A = opt_cfg.accum_steps

    def _pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_shardings)

    def _grads(params, *batch):
        if A == 1:
            loss, g = jax.value_and_grad(loss_fn)(params, *batch)
            return loss, _pin(g)
        micro = jax.tree.map(
            lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch)
        gz = _pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, opt_cfg.accum_dtype), params))

        inv = 1.0 / A

        def scaled_loss(p, *mbatch):
            # fold the 1/A into the loss so no post-hoc params-sized
            # `g * inv` tree-map copy is needed
            return loss_fn(p, *mbatch) * inv

        def mb(carry, mbatch):
            lsum, gsum = carry
            l, g = jax.value_and_grad(scaled_loss)(params, *mbatch)
            g = _pin(g)
            gsum = _pin(jax.tree.map(
                lambda a, b: a + b.astype(opt_cfg.accum_dtype), gsum, g))
            return (lsum + l, gsum), None

        (loss, grads), _ = jax.lax.scan(mb, (jnp.float32(0.0), gz), micro)
        return loss, grads

    def train_step(state: TrainState, *batch) -> tuple[TrainState, dict]:
        loss, grads = _grads(state.params, *batch)
        key, sub = jax.random.split(state.rng)
        if emit_deltas:
            params, opt_state, deltas = opt_lib.update_with_deltas(
                grads, state.opt_state, state.params, opt_cfg, sub
            )
        else:
            params, opt_state = opt_lib.update(
                grads, state.opt_state, state.params, opt_cfg, sub
            )
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": opt_lib.global_norm(grads),
            "lr": opt_lib.schedule_lr(opt_cfg, state.step),
        }
        if emit_deltas:
            metrics["rotation_deltas"] = deltas
        return (
            TrainState(params=params, opt_state=opt_state,
                       step=state.step + 1, rng=key),
            metrics,
        )

    return train_step
