"""Optimizer with rotation-learner manifold routing.

Ordinary parameters get AdamW (configurable moment dtype — bf16 moments for
the ≥100B archs, see DESIGN.md §6). Any leaf whose name is in
``MANIFOLD_LEAVES`` ({'R', 'rot_k', 'rot_v'}) is an SO(n) rotation and is
routed through the ``repro.rotations`` learner configured by
``OptimizerConfig.rotation`` instead — GCD (the paper's Algorithm 2,
projection-free and exactly orthogonal at every step), Cayley-SGD,
SVD/Procrustes, or the frozen-R control, all swappable by registry spec.
Stacked rotations (leading layer axis, e.g. per-layer KV rotations
(L, hd, hd)) are vmapped over the learner's update.

This is the paper's headline integration claim: GCD "can be easily
integrated with standard neural network training algorithms" — and with the
learner protocol, so can every baseline it is compared against.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import rotations as rot_lib

MANIFOLD_LEAVES = ("R", "rot_k", "rot_v")


class OptimizerConfig(NamedTuple):
    name: str = "adamw"              # adamw | adafactor
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32  # bf16 halves optimizer memory at ≥100B
    compute_dtype: Any = jnp.float32  # bf16 halves the per-leaf update temps
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | constant
    # --- microbatch gradient accumulation (big-arch memory fit) ---
    accum_steps: int = 1
    accum_dtype: Any = jnp.float32
    # --- manifold (SO(n)) leaf settings: which rotation learner + its lr ---
    rotation: rot_lib.RotationConfig = rot_lib.RotationConfig()


class OptState(NamedTuple):
    mu: Any        # first moments (zeros for manifold leaves)
    nu: Any        # second moments
    rot: Any       # dict[path-key, learner state] for the manifold leaves
    step: jax.Array


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", str(last)))


def is_manifold_path(path) -> bool:
    return _leaf_name(path) in MANIFOLD_LEAVES


def path_key(path) -> str:
    """Stable string key for a param-tree path (OptState.rot dict key)."""
    return "/".join(_leaf_name((p,)) for p in path)


def _init_rot_leaf(learner, p):
    """Learner state for one manifold leaf (vmapped for stacked (L, n, n))."""
    if p.ndim == 3:
        return jax.vmap(learner.init_from)(p)
    return learner.init_from(p)


def init_rot_states(params, cfg: OptimizerConfig):
    """The ``OptState.rot`` dict: one learner state per manifold leaf."""
    learner = rot_lib.from_config(cfg.rotation)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return {
        path_key(path): _init_rot_leaf(learner, p)
        for path, p in flat if is_manifold_path(path)
    }


def factored_shapes(shape: tuple[int, ...]):
    """Adafactor factored second-moment shapes: (row-stat, col-stat).

    ≥2D: vr drops the last dim, vc drops the second-to-last.
    1D/0D: full-size v in the row slot, scalar placeholder in the col slot.
    """
    if len(shape) >= 2:
        return shape[:-1], shape[:-2] + shape[-1:]
    return shape, ()


def init(params, cfg: OptimizerConfig) -> OptState:
    adafactor = cfg.name == "adafactor"

    def mu_like(path, p):
        if adafactor:  # mu slot holds the factored ROW stat (f32, tiny)
            return jnp.zeros(factored_shapes(p.shape)[0], jnp.float32)
        return jnp.zeros(p.shape, cfg.moment_dtype)

    def nu_like(path, p):
        if adafactor:  # nu slot holds the factored COL stat
            return jnp.zeros(factored_shapes(p.shape)[1], jnp.float32)
        return jnp.zeros(p.shape, cfg.moment_dtype)

    mu = jax.tree_util.tree_map_with_path(mu_like, params)
    nu = jax.tree_util.tree_map_with_path(nu_like, params)
    return OptState(mu=mu, nu=nu, rot=init_rot_states(params, cfg),
                    step=jnp.int32(0))


def schedule_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        frac = jnp.clip(s / max(cfg.total_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    """Global L2 norm via per-leaf self-dot with f32 ACCUMULATION.

    ``jnp.sum(jnp.square(x))`` on a bf16 leaf upcasts the whole array to f32
    before reducing (jnp's half-precision sum semantics); under SPMD that
    materializes an f32 copy of every gradient leaf — measured ~8 GiB/device
    on the 340B arch. ``dot_general(x, x, preferred_element_type=f32)``
    accumulates in f32 without ever materializing an f32 operand."""

    def sq(x):
        # contract over ALL axes in place — a reshape(-1) first would break
        # the sharding and all-gather every leaf (measured: 2.9 TiB/device).
        dims = tuple(range(x.ndim))
        return jax.lax.dot_general(
            x, x, ((dims, dims), ((), ())),
            preferred_element_type=jnp.float32)

    return jnp.sqrt(sum(sq(x) for x in jax.tree.leaves(tree)))


def _f32_mean_sq_over(g: jax.Array, axis: int) -> jax.Array:
    """mean(g², axis) with f32 accumulation and NO f32 copy of g (bf16
    operands contracted against a ones vector — see layers._f32_sumsq)."""
    g2 = g * g  # bf16 elementwise
    ones = jnp.ones((g.shape[axis],), g.dtype)
    nd = g.ndim
    ax = axis % nd
    dims = (((ax,), (0,)), ((), ()))
    s = jax.lax.dot_general(g2, ones, dims, preferred_element_type=jnp.float32)
    return s / g.shape[ax]


def _adafactor_leaf(cfg: OptimizerConfig, lr, t, g, p, vr, vc):
    """Adafactor (Shazeer & Stern 2018), β1=0, factored second moment.

    The memory story at ≥300B: Adam keeps 2 params-sized moments plus their
    update-pipeline copies; Adafactor keeps O(rows+cols) f32 stats, so the
    optimizer adds ~nothing to the params+grads footprint. Update-RMS
    clipping (d=1) replaces global grad-norm clipping.
    """
    b2 = 1.0 - t ** -0.8  # Adafactor's schedule for the decay
    eps = 1e-30
    if p.ndim >= 2:
        r = _f32_mean_sq_over(g, -1)          # (rows...)
        c = _f32_mean_sq_over(g, -2)          # (..., cols)
        vr_n = b2 * vr + (1.0 - b2) * r
        vc_n = b2 * vc + (1.0 - b2) * c
        # v̂ = vr ⊗ vc / mean(vr): factored reconstruction
        denom = jnp.mean(vr_n, axis=-1, keepdims=True)
        rfac = jax.lax.rsqrt(vr_n / jnp.maximum(denom, eps) + eps)
        cfac = jax.lax.rsqrt(vc_n + eps)
        u = g.astype(cfg.compute_dtype) * (
            rfac[..., None] * cfac[..., None, :]).astype(cfg.compute_dtype)
    else:
        vr_n = b2 * vr + (1.0 - b2) * jnp.square(g.astype(jnp.float32))
        vc_n = vc
        u = g.astype(cfg.compute_dtype) * jax.lax.rsqrt(
            vr_n + eps).astype(cfg.compute_dtype)
    # update-RMS clip at d=1.0
    rms = jnp.sqrt(jnp.maximum(
        jax.lax.dot_general(u, u, ((tuple(range(u.ndim)),) * 2, ((), ())),
                            preferred_element_type=jnp.float32)
        * (1.0 / float(u.size)),  # float: u.size overflows int32 at ≥300B
        1e-30))
    scale = (lr / jnp.maximum(1.0, rms)).astype(cfg.compute_dtype)
    if cfg.weight_decay > 0:
        u = u + jnp.asarray(cfg.weight_decay, cfg.compute_dtype) * p.astype(cfg.compute_dtype)
    p_n = (p.astype(cfg.compute_dtype) - scale * u).astype(p.dtype)
    return p_n, vr_n, vc_n


def _update_impl(grads, state: OptState, params, cfg: OptimizerConfig,
                 key: jax.Array):
    """Shared update body → (new_params, new_state, rotation_deltas).

    ``rotation_deltas`` maps each manifold leaf's ``path_key`` to the
    ``RotationDelta`` the learner applied this step — the exact pytree a
    live index consumes through ``Engine.refresh``. ``update`` discards it
    (XLA dead-code-eliminates the unused outputs); ``update_with_deltas``
    returns it for the overlapped train-and-refresh loop."""
    step = state.step
    lr = schedule_lr(cfg, step)
    t = (step + 1).astype(jnp.float32)

    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) if cfg.grad_clip > 0 else 1.0
    b1c = 1.0 - cfg.beta1**t
    b2c = 1.0 - cfg.beta2**t

    flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
    keys = jax.random.split(key, max(len(flat_g), 1))
    key_for = {path: k for (path, _), k in zip(flat_g, keys)}

    learner = rot_lib.from_config(cfg.rotation)
    rot_n: dict[str, Any] = {}
    deltas: dict[str, Any] = {}
    cdt = cfg.compute_dtype

    def upd(path, g, p, mu, nu):
        g = g.astype(cdt) * clip.astype(cdt) if cfg.grad_clip > 0 else g.astype(cdt)
        if is_manifold_path(path):
            kk = key_for[path]
            # re-sync the learner state's R from the param leaf (source of
            # truth, e.g. after a partial checkpoint restore)
            st = state.rot[path_key(path)]

            def one_rot(s, G, k):
                return learner.update(s, G, cfg.rotation.lr, k)

            if p.ndim == 3:  # stacked per-layer rotations
                st = jax.vmap(learner.with_rotation)(st, p)
                ks = jax.random.split(kk, p.shape[0])
                st2, delta = jax.vmap(one_rot)(st, g, ks)
                p_n = jax.vmap(learner.materialize)(st2)
            else:
                st = learner.with_rotation(st, p)
                st2, delta = one_rot(st, g, kk)
                p_n = learner.materialize(st2)
            rot_n[path_key(path)] = st2
            deltas[path_key(path)] = delta
            return p_n.astype(p.dtype), mu, nu
        if cfg.name == "adafactor":
            return _adafactor_leaf(cfg, lr, t, g, p, mu, nu)
        one = jnp.asarray(1.0, cdt)
        mu_n = jnp.asarray(cfg.beta1, cdt) * mu.astype(cdt) + (one - cfg.beta1) * g
        nu_n = jnp.asarray(cfg.beta2, cdt) * nu.astype(cdt) + (one - cfg.beta2) * jnp.square(g)
        upd_v = (mu_n / b1c.astype(cdt)) / (jnp.sqrt(nu_n / b2c.astype(cdt))
                                            + jnp.asarray(cfg.eps, cdt))
        if cfg.weight_decay > 0:
            upd_v = upd_v + jnp.asarray(cfg.weight_decay, cdt) * p.astype(cdt)
        p_n = p.astype(cdt) - lr.astype(cdt) * upd_v
        return (p_n.astype(p.dtype), mu_n.astype(cfg.moment_dtype),
                nu_n.astype(cfg.moment_dtype))

    results = jax.tree_util.tree_map_with_path(
        upd, grads, params, state.mu, state.nu
    )
    # unzip the 3-tuples back into trees
    treedef = jax.tree.structure(params)
    flat = treedef.flatten_up_to(results)
    p_n = treedef.unflatten([r[0] for r in flat])
    mu_n = treedef.unflatten([r[1] for r in flat])
    nu_n = treedef.unflatten([r[2] for r in flat])
    return p_n, OptState(mu=mu_n, nu=nu_n, rot=rot_n, step=step + 1), deltas


@functools.partial(jax.jit, static_argnames=("cfg",))
def update(grads, state: OptState, params, cfg: OptimizerConfig, key: jax.Array):
    """Returns (new_params, new_state). Clips the global grad norm, then
    AdamW everywhere except the SO(n) leaves, which go through the
    configured ``repro.rotations`` learner (``cfg.rotation``)."""
    p_n, state_n, _deltas = _update_impl(grads, state, params, cfg, key)
    return p_n, state_n


@functools.partial(jax.jit, static_argnames=("cfg",))
def update_with_deltas(grads, state: OptState, params, cfg: OptimizerConfig,
                       key: jax.Array):
    """``update`` that also returns ``{path_key: RotationDelta}`` for the
    manifold leaves — feed these to a live index (``Engine.refresh``) to
    keep it aligned with the trainer's rotations at zero rebuild cost."""
    return _update_impl(grads, state, params, cfg, key)
