"""Logical-axis sharding rules (MaxText-style).

Every parameter and major activation in the model zoo is annotated with a
tuple of *logical* axis names. A per-architecture rule table maps logical
names to physical mesh axes; ``logical_to_spec`` resolves the tuple into a
``PartitionSpec``. This keeps the mesh layout (16×16 single-pod, 2×16×16
multi-pod) decoupled from model code, and lets the perf hillclimb swap
sharding strategies by editing one dict.

Conventions:
  * rule value None  → axis replicated
  * rule value str   → single mesh axis
  * rule value tuple → multiple mesh axes (e.g. batch over ("pod", "data"))
  * a logical axis absent from the table → replicated (safe default)

Rules are validated against tensor shapes at resolve time: a mesh axis is
dropped (replication) when it does not divide the dimension — with a warning
collected for the dry-run report, so "qwen has 20 heads, model axis is 16"
shows up as an explicit decision, not a crash.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

P = PartitionSpec

# ---------------------------------------------------------------------------
# Base rule tables
# ---------------------------------------------------------------------------

# Dense/GQA transformer LM. Weights ZeRO-shard their biggest dim over "data"
# and tensor-shard over "model"; activations shard batch over (pod, data) and
# the model-parallel dim over "model".
LM_BASE_RULES: dict[str, Any] = {
    # --- activations ---
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_seq": None,          # decode cache seq; decode cells flip to "model"
    "act_boundary_seq": None,    # saved layer boundaries; big-train rules
    #                              shard these over "model" (ZeRO-activations)
    "act_mlp": "model",
    "act_vocab": "model",
    "act_experts": "model",      # MoE dispatch buffer expert dim (EP)
    "act_capacity": "data",      # MoE dispatch buffer capacity dim
    "act_expert_mlp": "model",   # expert hidden dim (takes over when E < 16)
    "act_tokens": "data",        # flattened token dim in MoE dispatch
    # --- weights ---
    # ZeRO/FSDP axis; "pod" is filtered out automatically on the single-pod
    # mesh, so multi-pod runs ZeRO-shard across pods too.
    "w_embed": ("pod", "data"),
    "w_heads": "model",
    "w_kv_heads": None,          # GQA: kv heads usually < 16 → replicate
    "w_head_dim": None,
    "w_mlp": "model",
    "w_vocab": "model",
    "w_experts": "model",        # expert parallelism (EP)
    # When E doesn't divide the model axis (grok: 8 experts < 16), the
    # divisibility check drops the EP sharding and this rule tensor-shards
    # the expert hidden dim instead (dedup keeps whichever lands first).
    "w_expert_mlp": "model",
    "layers": None,              # scan axis: never sharded
}

GNN_BASE_RULES: dict[str, Any] = {
    "act_nodes": ("pod", "data"),
    "act_edges": ("pod", "data"),
    "act_feat": None,
    "act_hidden": None,
    "w_in": None,
    "w_out": "model",
    "layers": None,
}

RECSYS_BASE_RULES: dict[str, Any] = {
    "act_batch": ("pod", "data"),
    "act_feat": None,
    "act_hidden": "model",
    "act_cand": ("pod", "data"),   # candidate axis for bulk/retrieval scoring
    "vocab_rows": "model",         # embedding tables row-sharded
    "w_embed_dim": None,
    "w_in": None,
    "w_hidden": "model",
    "w_out": None,
    "fields": None,
    "layers": None,
}

# Paper's own two-tower (dim 512): tiny — replicate weights, shard batch.
PAPER_RULES: dict[str, Any] = dict(RECSYS_BASE_RULES)

# IVF-PQ serving (repro.index): queries are data-parallel; the flattened
# candidate axis (nprobe·blocks·block_size per query) is the big one and
# shards over "model", which splits the selected-list scan across devices.
# Index storage (centroids, codebooks, CSR codes/ids) is replicated by
# default — at 2 B/row/subspace a 100M-item index is ~3 GiB, well under
# chip HBM; the row-sharded variant below flips the corpus rows to
# ("pod", "data").
IVF_RULES: dict[str, Any] = {
    "act_batch": ("pod", "data"),
    "ivf_cand": "model",
    "ivf_cap": None,
    "ivf_lists": None,
    "ivf_rows": None,          # shard axis of a stacked per-shard index
}

# Row-sharded IVF (repro.search sharded backends): the corpus lives
# partitioned over the mesh's data axes end to end — each device owns one
# CSR shard (its own block-aligned lists over its local rows) and serves
# the fused scan locally; results merge with an all_gather + re-top-k.
# Capacity scales with the mesh: rows/device ≈ HBM / (2 B/row/subspace),
# so a ("pod", "data") = 32-way shard lifts the 100M-item ceiling to ~3B.
# Centroids, codebooks, and R stay replicated (they are O(n²), not O(N)).
IVF_SHARDED_RULES: dict[str, Any] = dict(IVF_RULES)
IVF_SHARDED_RULES.update({
    "ivf_cap": ("pod", "data"),
    "ivf_rows": ("pod", "data"),
})

# Rotation/PQ parameters are small and replicated everywhere.
for _t in (LM_BASE_RULES, GNN_BASE_RULES, RECSYS_BASE_RULES, PAPER_RULES):
    _t.update({"rot_in": None, "rot_out": None, "pq_sub": None,
               "pq_code": None, "pq_dim": None})


def merge(base, **overrides):
    out = dict(base)
    out.update(overrides)
    return out


# Named rule tables — configs reference these by key so the whole sharding
# strategy of an arch is one string (and the perf hillclimb is a dict edit).
RULE_REGISTRY: dict[str, dict[str, Any]] = {
    # Head-sharded tensor parallelism (heads % 16 == 0: nemotron, grok).
    "lm_base": LM_BASE_RULES,
    # Attention data-parallel, FFN/vocab/experts tensor-parallel — for archs
    # whose head count does not divide the model axis (qwen 20H, llama4 40H,
    # olmo 16H-kv16 small enough that TP overhead loses anyway).
    "lm_attn_dp": merge(LM_BASE_RULES, **{
        "w_heads": "data", "act_heads": None, "w_kv_heads": "data",
    }),
    # ≥300B training: the per-layer boundary stack saved for backward
    # dominates → shard the saved boundary's seq dim over "model"
    # (all-gathered on use; trades one fast-ICI collective per layer for
    # 16× boundary memory).
    "lm_base_bigtrain": merge(LM_BASE_RULES, **{
        "act_boundary_seq": "model",
    }),
    "lm_attn_dp_bigtrain": merge(LM_BASE_RULES, **{
        "w_heads": "data", "act_heads": None, "w_kv_heads": "data",
        "act_boundary_seq": "model",
    }),
    # Decode/prefill serving: the KV cache dominates memory → shard its seq
    # dim over "model" (context parallelism; XLA all-reduces the softmax
    # stats). Batch stays on (pod, data).
    # NB: weight STORAGE keeps tensor sharding ("model") even when the
    # attention math runs with full heads (act_heads None) — storing
    # attention weights on the (already-used) data axis left them 16×
    # under-sharded (measured +7 GiB/dev on nemotron decode).
    "lm_decode": merge(LM_BASE_RULES, **{
        "act_kv_seq": "model", "act_heads": None,
        "w_heads": "model", "w_kv_heads": "model",
    }),
    "lm_decode_attn_dp": merge(LM_BASE_RULES, **{
        "act_kv_seq": "model", "act_heads": None,
        "w_heads": "model", "w_kv_heads": "model",
    }),
    # Long-context decode (batch=1): the batch axis is given back, the KV
    # seq dim shards over BOTH data and model (524288 / 256 = 2048/device).
    "lm_long_ctx": merge(LM_BASE_RULES, **{
        "act_batch": None, "act_kv_seq": ("data", "model"),
        "act_heads": None, "w_heads": "model", "w_kv_heads": "model",
    }),
    "lm_long_ctx_attn_dp": merge(LM_BASE_RULES, **{
        "act_batch": None, "act_kv_seq": ("data", "model"),
        "act_heads": None, "w_heads": "model", "w_kv_heads": "model",
    }),
    "gnn": GNN_BASE_RULES,
    "recsys": RECSYS_BASE_RULES,
    "paper": PAPER_RULES,
    "ivf": IVF_RULES,
    "ivf_sharded": IVF_SHARDED_RULES,
}


def merge_rules(base: Mapping[str, Any], **overrides: Any) -> dict[str, Any]:
    out = dict(base)
    out.update(overrides)
    return out


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

_WARNINGS: list[str] = []


def pop_warnings() -> list[str]:
    out = list(_WARNINGS)
    _WARNINGS.clear()
    return out


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        size = 1
        for a in axis:
            size *= _mesh_axis_size(mesh, a)
        return size
    return mesh.shape[axis] if axis in mesh.shape else 1


def _present(mesh: Mesh, axis):
    """Filter out mesh axes that don't exist on this mesh (e.g. 'pod' on the
    single-pod mesh)."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.shape)
        return kept if kept else None
    return axis if axis in mesh.shape else None


def logical_to_spec(
    logical_axes: Sequence[str | None],
    rules: Mapping[str, Any],
    mesh: Mesh,
    shape: Sequence[int] | None = None,
    tensor_name: str = "?",
) -> PartitionSpec:
    """Resolve logical axis names to a PartitionSpec, dropping (with a
    recorded warning) any mesh axis that does not divide the dimension."""
    spec = []
    for d, name in enumerate(logical_axes):
        axis = _present(mesh, rules.get(name)) if name is not None else None
        if axis is not None and shape is not None:
            size = _mesh_axis_size(mesh, axis)
            if shape[d] % size != 0:
                _WARNINGS.append(
                    f"{tensor_name}: logical axis {name!r} dim {shape[d]} not"
                    f" divisible by mesh axes {axis} (size {size}) — replicated"
                )
                axis = None
        spec.append(axis)
    # PartitionSpec disallows duplicate mesh axes; keep first occurrence.
    seen: set[str] = set()
    clean = []
    for axis in spec:
        if axis is None:
            clean.append(None)
            continue
        ax_tuple = axis if isinstance(axis, tuple) else (axis,)
        kept = tuple(a for a in ax_tuple if a not in seen)
        seen.update(kept)
        clean.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return PartitionSpec(*clean)


def tree_specs(logical_tree, rules, mesh, shape_tree=None):
    """Map a pytree of logical-axis tuples (+ optional matching shapes tree)
    to a pytree of PartitionSpecs."""
    if shape_tree is None:
        return jax.tree.map(
            lambda lg: logical_to_spec(lg, rules, mesh),
            logical_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
    return jax.tree.map(
        lambda lg, shp: logical_to_spec(lg, rules, mesh, shp),
        logical_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(logical_tree, rules, mesh: Mesh, shape_tree=None):
    specs = tree_specs(logical_tree, rules, mesh, shape_tree)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def constrain(x, logical_axes, rules, mesh=None):
    """with_sharding_constraint by logical names (no-op when no mesh ctx)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(logical_axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh():
    """Ambient mesh context via the version-guarded ``compat`` probe."""
    from repro import compat

    return compat.current_mesh()
