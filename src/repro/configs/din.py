"""din [recsys]: embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn [arXiv:1706.06978; paper].

Pure ranker — the paper technique applies as embedding-table compression
only (DESIGN.md §Arch-applicability); retrieval_cand = bulk target-attention
scoring of 1M candidates for one user."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.recsys import DINConfig


def make_config() -> DINConfig:
    return DINConfig(
        name="din", item_vocab=1_000_000, embed_dim=18, hist_len=100,
        attn_dims=(80, 40), mlp_dims=(200, 80),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def make_smoke() -> DINConfig:
    return DINConfig(
        name="din-smoke", item_vocab=512, embed_dim=18, hist_len=16,
        attn_dims=(20, 10), mlp_dims=(32, 16),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


ARCH = base.ArchSpec(
    arch_id="din", family="recsys", make_config=make_config,
    make_smoke=make_smoke, shapes=base.RECSYS_SHAPES,
    notes="Target attention over 100-item history; BCE ranking loss.",
)
