"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304
— non-parametric LayerNorm [arXiv:2402.00838; hf]."""
import jax.numpy as jnp

from repro.configs import base
from repro.core.kv_quant import KVQuantConfig
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="olmo-1b", num_layers=16, d_model=2048, num_heads=16,
        num_kv_heads=16, head_dim=128, d_ff=8192, vocab_size=50304,
        activation="silu", use_glu=True, qkv_bias=False,
        norm="layernorm_nonparam", rules="lm_attn_dp",
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="olmo-1b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=256, vocab_size=257,
        activation="silu", use_glu=True, norm="layernorm_nonparam",
        dtype=jnp.float32, param_dtype=jnp.float32, q_chunk=16, xent_chunk=32,
    )


def adjust(cfg: TransformerConfig, shape_name: str) -> TransformerConfig:
    if shape_name == "train_4k":
        return cfg._replace(train_accum_steps=8, scan_groups=4)
    if shape_name in ("decode_32k", "prefill_32k"):
        return cfg._replace(rules="lm_decode_attn_dp")
    if shape_name == "long_500k":
        return cfg._replace(
            kv_quant=KVQuantConfig(head_dim=128, num_subspaces=16,
                                   num_codewords=256),
            rules="lm_long_ctx_attn_dp",
        )
    return cfg


ARCH = base.ArchSpec(
    arch_id="olmo-1b", family="lm", make_config=make_config,
    make_smoke=make_smoke, shapes=base.LM_SHAPES, adjust=adjust,
    notes="Non-parametric LN (no scale/bias); MHA kv=16.",
)
