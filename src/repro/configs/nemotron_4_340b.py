"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU, no GLU [arXiv:2402.16819; unverified].

96 heads / 16 = 6 → full head-sharded tensor parallelism ('lm_base'); KV
heads (8 < 16) replicate across the model axis (standard GQA TP). bf16 Adam
moments keep optimizer state inside 16 GB/chip at 256 chips (DESIGN.md §6).
"""
import jax.numpy as jnp

from repro.configs import base
from repro.core.kv_quant import KVQuantConfig
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="nemotron-4-340b", num_layers=96, d_model=18432, num_heads=96,
        num_kv_heads=8, head_dim=192, d_ff=73728, vocab_size=256000,
        activation="relu2", use_glu=False, qkv_bias=False, norm="rmsnorm",
        rules="lm_base", dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        q_chunk=256,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="nemotron-4-340b-smoke", num_layers=2, d_model=96, num_heads=8,
        num_kv_heads=2, head_dim=12, d_ff=384, vocab_size=500,
        activation="relu2", use_glu=False, norm="rmsnorm",
        dtype=jnp.float32, param_dtype=jnp.float32, q_chunk=16, xent_chunk=32,
    )


def adjust(cfg: TransformerConfig, shape_name: str) -> TransformerConfig:
    if shape_name == "train_4k":
        return cfg._replace(train_accum_steps=16, scan_groups=8, rules="lm_base_bigtrain")
    if shape_name in ("decode_32k", "prefill_32k"):
        return cfg._replace(rules="lm_decode")
    if shape_name == "long_500k":
        return cfg._replace(
            kv_quant=KVQuantConfig(head_dim=192, num_subspaces=24,
                                   num_codewords=256),
            rules="lm_long_ctx",
        )
    return cfg


ARCH = base.ArchSpec(
    arch_id="nemotron-4-340b", family="lm", make_config=make_config,
    make_smoke=make_smoke, shapes=base.LM_SHAPES, adjust=adjust,
    notes="Squared-ReLU non-GLU FFN; head-sharded TP; bf16 Adam moments.",
)
