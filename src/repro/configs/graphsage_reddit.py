"""graphsage-reddit [gnn]: n_layers=2 d_hidden=128 aggregator=mean
sample_sizes=25-10 [arXiv:1706.02216; paper].

d_in varies per shape cell (cora 1433 / reddit 602 / ogb 100 / molecule 64);
``adjust`` swaps it in. minibatch_lg uses the real CSR fanout sampler
(data/graph.py). The paper's index layer attaches to the output node
embeddings (GraphSAGE's unsupervised-retrieval use)."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.gnn import GraphSAGEConfig


def make_config() -> GraphSAGEConfig:
    return GraphSAGEConfig(
        name="graphsage-reddit", d_in=602, d_hidden=128, num_layers=2,
        num_classes=41, aggregator="mean", sample_sizes=(25, 10),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def make_smoke() -> GraphSAGEConfig:
    return GraphSAGEConfig(
        name="graphsage-smoke", d_in=32, d_hidden=16, num_layers=2,
        num_classes=7, aggregator="mean", sample_sizes=(5, 3),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def adjust(cfg: GraphSAGEConfig, shape_name: str) -> GraphSAGEConfig:
    d_feat = base.GNN_SHAPES[shape_name].params["d_feat"]
    upd = {"d_in": d_feat}
    if shape_name == "minibatch_lg":
        upd["sample_sizes"] = base.GNN_SHAPES[shape_name].params["fanout"]
    return cfg._replace(**upd)


ARCH = base.ArchSpec(
    arch_id="graphsage-reddit", family="gnn", make_config=make_config,
    make_smoke=make_smoke, shapes=base.GNN_SHAPES, adjust=adjust,
    notes="segment_sum message passing; real CSR sampler for minibatch_lg.",
)
