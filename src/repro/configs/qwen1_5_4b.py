"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

20 heads do not divide the 16-wide model axis → attention runs data-parallel
(rules 'lm_attn_dp'), FFN/vocab tensor-parallel (DESIGN.md §4).
"""
import jax.numpy as jnp

from repro.configs import base
from repro.core.kv_quant import KVQuantConfig
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-4b", num_layers=40, d_model=2560, num_heads=20,
        num_kv_heads=20, head_dim=128, d_ff=6912, vocab_size=151936,
        activation="silu", use_glu=True, qkv_bias=True, norm="rmsnorm",
        rope_theta=1_000_000.0, rules="lm_attn_dp",
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-4b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=160, vocab_size=269,
        activation="silu", use_glu=True, qkv_bias=True, norm="rmsnorm",
        dtype=jnp.float32, param_dtype=jnp.float32, q_chunk=16, xent_chunk=32,
    )


def adjust(cfg: TransformerConfig, shape_name: str) -> TransformerConfig:
    if shape_name == "train_4k":
        return cfg._replace(train_accum_steps=8, scan_groups=4)
    if shape_name in ("decode_32k", "prefill_32k"):
        return cfg._replace(rules="lm_decode_attn_dp")
    if shape_name == "long_500k":
        return cfg._replace(
            kv_quant=KVQuantConfig(head_dim=128, num_subspaces=16,
                                   num_codewords=256),
            rules="lm_long_ctx_attn_dp",
        )
    return cfg


ARCH = base.ArchSpec(
    arch_id="qwen1.5-4b", family="lm", make_config=make_config,
    make_smoke=make_smoke, shapes=base.LM_SHAPES, adjust=adjust,
    notes="QKV bias; MHA (kv=20); attention data-parallel (20 % 16 != 0).",
)
