"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1; unverified]."""
import jax.numpy as jnp

from repro.configs import base
from repro.core.kv_quant import KVQuantConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="grok-1-314b", num_layers=64, d_model=6144, num_heads=48,
        num_kv_heads=8, head_dim=128, d_ff=32768, vocab_size=131072,
        activation="gelu", use_glu=True, qkv_bias=False, norm="rmsnorm",
        moe=MoEConfig(num_experts=8, top_k=2), rules="lm_base",
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="grok-1-smoke", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, head_dim=8, d_ff=128, vocab_size=300,
        activation="gelu", use_glu=True, norm="rmsnorm",
        moe=MoEConfig(num_experts=4, top_k=2),
        dtype=jnp.float32, param_dtype=jnp.float32, q_chunk=16, xent_chunk=32,
    )


def adjust(cfg: TransformerConfig, shape_name: str) -> TransformerConfig:
    if shape_name == "train_4k":
        return cfg._replace(train_accum_steps=16, scan_groups=8, rules="lm_base_bigtrain")
    if shape_name == "prefill_32k":
        return cfg._replace(rules="lm_decode", moe_chunk=131072)
    if shape_name == "decode_32k":
        return cfg._replace(rules="lm_decode")
    if shape_name == "long_500k":
        return cfg._replace(
            kv_quant=KVQuantConfig(head_dim=128, num_subspaces=16,
                                   num_codewords=256),
            rules="lm_long_ctx",
        )
    return cfg


ARCH = base.ArchSpec(
    arch_id="grok-1-314b", family="lm", make_config=make_config,
    make_smoke=make_smoke, shapes=base.LM_SHAPES, adjust=adjust,
    notes="8-expert top-2 MoE (GeGLU experts); expert+head TP on model axis.",
)
