"""The paper's OWN experimental architecture (§3.2): two-tower retrieval
model, embedding size 512, cosine scoring, hinge margin 0.1, PQ index layer
with GCD-learned rotation on the item tower.

Not part of the assigned 40-cell grid — this is the faithful-reproduction
config used by the benchmarks (Fig 3 / Table 1) and examples."""
import jax.numpy as jnp

from repro.configs import base
from repro.core.index_layer import IndexLayerConfig
from repro.models.recsys import TwoTowerConfig


def make_config() -> TwoTowerConfig:
    return TwoTowerConfig(
        name="paper-twotower", item_vocab=1_541_673,  # paper's unique items
        embed_dim=512, tower_dims=(512, 512), hist_len=16, scoring="cosine",
        hinge_margin=0.1,
        index=IndexLayerConfig(dim=512, num_subspaces=64, num_codewords=256),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def make_smoke() -> TwoTowerConfig:
    return TwoTowerConfig(
        name="paper-twotower-smoke", item_vocab=4096, embed_dim=64,
        tower_dims=(64, 64), hist_len=8, scoring="cosine", hinge_margin=0.1,
        index=IndexLayerConfig(dim=64, num_subspaces=8, num_codewords=32),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


ARCH = base.ArchSpec(
    arch_id="paper-twotower", family="recsys", make_config=make_config,
    make_smoke=make_smoke, shapes=base.RECSYS_SHAPES,
    notes="Paper §3.2 faithful config (512-dim, hinge 0.1, OPQ warm start).",
)
