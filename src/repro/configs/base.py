"""Architecture registry scaffolding.

Every assigned architecture ships one module exposing an ``ArchSpec``:
  * ``make_config()``      — the FULL published config (dry-run only;
                             exercised via ShapeDtypeStruct, never allocated)
  * ``make_smoke()``       — a reduced same-family config for CPU smoke tests
  * ``shapes``             — the arch's own input-shape set (the 40-cell grid)
  * ``config_for_shape()`` — per-shape config adjustments (e.g. the paper's
                             PQ KV cache switches on for long_500k; decode
                             cells use long-context sharding rules)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple


class Shape(NamedTuple):
    kind: str            # train | prefill | decode | gnn_full | gnn_minibatch
    #                      | gnn_graph_batch | recsys_train | recsys_serve
    #                      | recsys_retrieval
    params: dict[str, Any]


class ArchSpec(NamedTuple):
    arch_id: str
    family: str          # lm | gnn | recsys
    make_config: Callable[[], Any]
    make_smoke: Callable[[], Any]
    shapes: dict[str, Shape]
    adjust: Callable[[Any, str], Any] | None = None  # (cfg, shape_name) -> cfg
    notes: str = ""

    def config_for_shape(self, shape_name: str):
        cfg = self.make_config()
        if self.adjust is not None:
            cfg = self.adjust(cfg, shape_name)
        return cfg


# The LM-family shape grid (same four shapes for all five LM archs).
LM_SHAPES = {
    "train_4k": Shape("train", {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": Shape("prefill", {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": Shape("decode", {"seq_len": 32768, "global_batch": 128}),
    # All five assigned LMs are full-attention; dense-cache 500k decode is
    # memory-infeasible (DESIGN.md §4) — this cell runs the paper technique:
    # PQ-compressed KV cache with learned GCD rotation, ADC attention.
    "long_500k": Shape("decode", {"seq_len": 524288, "global_batch": 1,
                                   "pq_cache": True}),
}

GNN_SHAPES = {
    "full_graph_sm": Shape("gnn_full", {"n_nodes": 2708, "n_edges": 10556,
                                        "d_feat": 1433}),
    "minibatch_lg": Shape("gnn_minibatch", {"n_nodes": 232965,
                                            "n_edges": 114615892,
                                            "batch_nodes": 1024,
                                            "fanout": (15, 10),
                                            "d_feat": 602}),
    "ogb_products": Shape("gnn_full", {"n_nodes": 2449029,
                                       "n_edges": 61859140, "d_feat": 100}),
    "molecule": Shape("gnn_graph_batch", {"n_nodes": 30, "n_edges": 64,
                                          "batch": 128, "d_feat": 64}),
}

RECSYS_SHAPES = {
    "train_batch": Shape("recsys_train", {"batch": 65536}),
    "serve_p99": Shape("recsys_serve", {"batch": 512}),
    "serve_bulk": Shape("recsys_serve", {"batch": 262144}),
    "retrieval_cand": Shape("recsys_retrieval", {"batch": 1,
                                                 "n_candidates": 1_000_000}),
}
