"""Architecture registry: ``get(arch_id)`` / ``REGISTRY`` / ``--arch`` ids."""
from __future__ import annotations

from repro.configs import (
    din,
    graphsage_reddit,
    grok_1_314b,
    llama4_maverick_400b,
    mind,
    nemotron_4_340b,
    olmo_1b,
    paper_twotower,
    qwen1_5_4b,
    two_tower_retrieval,
    wide_deep,
)
from repro.configs.base import ArchSpec, Shape

_MODULES = [
    qwen1_5_4b, olmo_1b, nemotron_4_340b, grok_1_314b, llama4_maverick_400b,
    graphsage_reddit, wide_deep, two_tower_retrieval, mind, din,
    paper_twotower,
]

REGISTRY: dict[str, ArchSpec] = {m.ARCH.arch_id: m.ARCH for m in _MODULES}

# The 10 assigned architectures (paper-twotower is extra, not in the grid).
ASSIGNED = [a for a in REGISTRY if a != "paper-twotower"]


def get(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[arch_id]


def grid_cells():
    """All (arch_id, shape_name) dry-run cells — the 40-cell grid."""
    cells = []
    for aid in ASSIGNED:
        for shape_name in REGISTRY[aid].shapes:
            cells.append((aid, shape_name))
    return cells
