"""mind [recsys]: embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030; unverified]."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.recsys import MINDConfig


def make_config() -> MINDConfig:
    return MINDConfig(
        name="mind", item_vocab=2_000_000, embed_dim=64, n_interests=4,
        capsule_iters=3, hist_len=50,
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def make_smoke() -> MINDConfig:
    return MINDConfig(
        name="mind-smoke", item_vocab=512, embed_dim=16, n_interests=2,
        capsule_iters=2, hist_len=8,
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


ARCH = base.ArchSpec(
    arch_id="mind", family="recsys", make_config=make_config,
    make_smoke=make_smoke, shapes=base.RECSYS_SHAPES,
    notes="Capsule B2I routing → 4 interests; retrieval = max over interests.",
)
