"""wide-deep [recsys]: n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat [arXiv:1606.07792; paper].

1M rows/field × 40 fields = 40M-row fused table, row-sharded over the model
axis. The paper's technique applies as PQ embedding-table compression (no ANN
stage in a pure ranker — DESIGN.md §Arch-applicability)."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.recsys import WideDeepConfig


def make_config() -> WideDeepConfig:
    return WideDeepConfig(
        name="wide-deep", n_sparse=40, vocab_per_field=1_000_000,
        embed_dim=32, mlp_dims=(1024, 512, 256),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def make_smoke() -> WideDeepConfig:
    return WideDeepConfig(
        name="wide-deep-smoke", n_sparse=6, vocab_per_field=128,
        embed_dim=8, mlp_dims=(32, 16),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


ARCH = base.ArchSpec(
    arch_id="wide-deep", family="recsys", make_config=make_config,
    make_smoke=make_smoke, shapes=base.RECSYS_SHAPES,
    notes="Fused 40M-row table; wide = per-id weight table.",
)
