"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, MoE 128 experts top-1 [hf:meta-llama/Llama-4-*; unverified].

Maverick interleaves dense and MoE layers (moe_every=2) — all-MoE at 128
experts would be ~770B, not 400B. 40 heads don't divide the model axis →
attention data-parallel; the 128 experts shard 16-way (8 experts/device).
The modality frontend ("early fusion") is out of scope: the backbone
consumes token/patch embeddings (input_specs stubs the frontend per spec).
"""
import jax.numpy as jnp

from repro.configs import base
from repro.core.kv_quant import KVQuantConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-maverick-400b-a17b", num_layers=48, d_model=5120,
        num_heads=40, num_kv_heads=8, head_dim=128, d_ff=8192,
        vocab_size=202048, activation="silu", use_glu=True, qkv_bias=False,
        norm="rmsnorm", moe=MoEConfig(num_experts=128, top_k=1),
        moe_every=2, rules="lm_attn_dp",
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def make_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-maverick-smoke", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=301,
        activation="silu", use_glu=True, norm="rmsnorm",
        moe=MoEConfig(num_experts=8, top_k=1), moe_every=2,
        dtype=jnp.float32, param_dtype=jnp.float32, q_chunk=16, xent_chunk=32,
    )


def adjust(cfg: TransformerConfig, shape_name: str) -> TransformerConfig:
    if shape_name == "train_4k":
        return cfg._replace(train_accum_steps=16, scan_groups=6, rules="lm_attn_dp_bigtrain")
    if shape_name == "prefill_32k":
        return cfg._replace(rules="lm_decode_attn_dp", moe_chunk=131072)
    if shape_name == "decode_32k":
        return cfg._replace(rules="lm_decode_attn_dp")
    if shape_name == "long_500k":
        return cfg._replace(
            kv_quant=KVQuantConfig(head_dim=128, num_subspaces=16,
                                   num_codewords=256),
            rules="lm_long_ctx_attn_dp",
        )
    return cfg


ARCH = base.ArchSpec(
    arch_id="llama4-maverick-400b-a17b", family="lm", make_config=make_config,
    make_smoke=make_smoke, shapes=base.LM_SHAPES, adjust=adjust,
    notes="Interleaved dense/MoE (every 2nd layer), 128e top-1, EP over model.",
)
