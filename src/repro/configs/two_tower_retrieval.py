"""two-tower-retrieval [recsys]: embed_dim=256 tower_mlp=1024-512-256
interaction=dot — sampled-softmax retrieval [RecSys'19 (YouTube); unverified].

THE paper architecture: the GCD-rotated PQ index layer sits on the item
tower (Fig 1); retrieval_cand scores 1M candidates via ADC over PQ codes."""
import jax.numpy as jnp

from repro.configs import base
from repro.core.index_layer import IndexLayerConfig
from repro.models.recsys import TwoTowerConfig


def make_config() -> TwoTowerConfig:
    return TwoTowerConfig(
        name="two-tower-retrieval", item_vocab=10_000_000, embed_dim=256,
        tower_dims=(1024, 512, 256), hist_len=50, scoring="cosine",
        hinge_margin=0.1,
        index=IndexLayerConfig(dim=256, num_subspaces=32, num_codewords=256),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


def make_smoke() -> TwoTowerConfig:
    return TwoTowerConfig(
        name="two-tower-smoke", item_vocab=2048, embed_dim=16,
        tower_dims=(32, 16), hist_len=8, scoring="cosine",
        index=IndexLayerConfig(dim=16, num_subspaces=4, num_codewords=16),
        dtype=jnp.float32, param_dtype=jnp.float32,
    )


ARCH = base.ArchSpec(
    arch_id="two-tower-retrieval", family="recsys", make_config=make_config,
    make_smoke=make_smoke, shapes=base.RECSYS_SHAPES,
    notes="Paper's own setting: index layer on item tower, ADC retrieval.",
)
