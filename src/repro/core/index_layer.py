"""The paper's trainable embedding-index layer:  T(X) = φ(X·R)·Rᵀ  (§2.1).

Sits at the top of the item tower of a two-tower retrieval model (Fig 1).
Forward rotates the batch into the PQ-friendly basis, product-quantizes with
a straight-through estimator, and rotates back, so downstream retrieval loss
sees (a differentiable surrogate of) exactly what the serving index returns.

Parameters:
  * ``rot``: RotationState — updated by GCD (never by the inner optimizer).
  * ``codebooks``: (D, K, sub) — trained by the distortion loss (plain SGD
    path) or by streaming EMA.

The total loss (Eq. 1) is  L_ret(T(X)) + (1/m)·‖XR − φ(XR)‖².
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import opq, pq


class IndexLayerConfig(NamedTuple):
    dim: int
    num_subspaces: int = 8
    num_codewords: int = 256
    distortion_weight: float = 1.0

    @property
    def pq_cfg(self) -> pq.PQConfig:
        return pq.PQConfig(self.num_subspaces, self.num_codewords)


class IndexLayerParams(NamedTuple):
    """R is a plain array so the whole tree is jax.grad-able; the GCD
    accumulator state (step counter, preconditioners) lives in the optimizer
    (training.optimizer treats any leaf named 'R'/'rot_*' as a manifold
    parameter and applies Algorithm 2 instead of Adam)."""

    R: jax.Array
    codebooks: jax.Array


def init(key: jax.Array, cfg: IndexLayerConfig, dtype=jnp.float32) -> IndexLayerParams:
    n, sub = cfg.dim, cfg.dim // cfg.num_subspaces
    cb = 0.01 * jax.random.normal(
        key, (cfg.num_subspaces, cfg.num_codewords, sub), dtype=dtype
    )
    return IndexLayerParams(R=jnp.eye(n, dtype=dtype), codebooks=cb)


def warm_start(
    key: jax.Array,
    X: jax.Array,
    cfg: IndexLayerConfig,
    opq_iters: int = 200,
    kmeans_iters: int = 1,
) -> IndexLayerParams:
    """Paper §3.2 setup: run OPQ on a warm-up sample to initialize R and the
    codebooks before joint training starts."""
    R, cb, _ = opq.opq(key, X, cfg.pq_cfg, iters=opq_iters, kmeans_iters=kmeans_iters)
    return IndexLayerParams(R=R, codebooks=cb)


def apply(params: IndexLayerParams, X: jax.Array) -> tuple[jax.Array, jax.Array]:
    """T(X) = φ(XR)Rᵀ with STE; returns (T(X), distortion scalar).

    Gradients: ∂/∂X flows straight-through φ and through both rotations;
    ∂/∂codebooks comes from the distortion term; ∂/∂R is consumed by the GCD
    update outside (the caller differentiates wrt ``params.R``).
    """
    R = params.R
    XR = X @ R
    q = pq.quantize_ste(XR, params.codebooks)
    out = q @ R.T
    dist = pq.distortion(XR, params.codebooks)
    return out, dist


def apply_no_ste(params: IndexLayerParams, X: jax.Array) -> jax.Array:
    """Serving-path forward: hard quantization, no gradient bridging."""
    R = params.R
    return pq.quantize(X @ R, params.codebooks) @ R.T


def encode(params: IndexLayerParams, X: jax.Array) -> jax.Array:
    """Index-build path: item codes (m, D) for the serving index."""
    return pq.assign(X @ params.R, params.codebooks)


def adc_scores(params: IndexLayerParams, queries: jax.Array,
               codes: jax.Array) -> jax.Array:
    """Serving-path ADC scoring: (b, n) queries × (N, D) codes -> (b, N).

    Inner-product scores in the rotated space equal scores in the original
    space because R is orthogonal: ⟨q, φ(xR)Rᵀ⟩ = ⟨qR, φ(xR)⟩.
    """
    lut = pq.adc_lut(queries @ params.R, params.codebooks)
    return pq.adc_score(lut, codes)
