"""The paper's trainable embedding-index layer:  T(X) = φ(X·R)·Rᵀ  (§2.1).

Sits at the top of the item tower of a two-tower retrieval model (Fig 1).
Forward rotates the batch into the PQ-friendly basis, product-quantizes with
a straight-through estimator, and rotates back, so downstream retrieval loss
sees (a differentiable surrogate of) exactly what the serving index returns.

φ is a ``repro.quant`` Quantizer (a ``quant.PQ`` view over the param
codebooks): the forward uses ``encode_st``, the loss term uses
``distortion``, and serving uses ``encode``/``adc_tables`` — the same
protocol every other quantizer consumer in the repo speaks.

Parameters:
  * ``R``: the rotation — updated by the configured ``repro.rotations``
    learner (``OptimizerConfig.rotation``), never by the inner optimizer.
  * ``codebooks``: (D, K, sub) — trained by the distortion loss (plain SGD
    path) or by streaming EMA. Kept as a raw array leaf so the optimizer's
    name-based manifold routing and launch/cells ParamSpecs see a flat tree;
    ``quantizer()`` wraps it in the protocol object on demand.

The total loss (Eq. 1) is  L_ret(T(X)) + (1/m)·‖XR − φ(XR)‖².
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import quant


class IndexLayerConfig(NamedTuple):
    dim: int
    num_subspaces: int = 8
    num_codewords: int = 256
    distortion_weight: float = 1.0

    @property
    def pq_cfg(self) -> quant.PQConfig:
        return quant.PQConfig(self.num_subspaces, self.num_codewords)


class IndexLayerParams(NamedTuple):
    """R is a plain array so the whole tree is jax.grad-able; the rotation
    learner's state (step counter, preconditioners) lives in the optimizer
    (training.optimizer treats any leaf named 'R'/'rot_*' as a manifold
    parameter and routes it through ``OptimizerConfig.rotation``'s learner
    instead of Adam)."""

    R: jax.Array
    codebooks: jax.Array


def quantizer(params: IndexLayerParams) -> quant.PQ:
    """The layer's φ as a protocol object (view over the codebook leaf)."""
    return quant.PQ(params.codebooks)


def init(key: jax.Array, cfg: IndexLayerConfig, dtype=jnp.float32) -> IndexLayerParams:
    n, sub = cfg.dim, cfg.dim // cfg.num_subspaces
    cb = 0.01 * jax.random.normal(
        key, (cfg.num_subspaces, cfg.num_codewords, sub), dtype=dtype
    )
    return IndexLayerParams(R=jnp.eye(n, dtype=dtype), codebooks=cb)


def warm_start(
    key: jax.Array,
    X: jax.Array,
    cfg: IndexLayerConfig,
    opq_iters: int = 200,
    kmeans_iters: int = 1,
) -> IndexLayerParams:
    """Paper §3.2 setup: run OPQ on a warm-up sample to initialize R and the
    codebooks before joint training starts."""
    R, pq_obj, _ = quant.opq.fit(key, X, cfg.pq_cfg, iters=opq_iters,
                                 kmeans_iters=kmeans_iters)
    return IndexLayerParams(R=R, codebooks=pq_obj.codebooks)


def apply(params: IndexLayerParams, X: jax.Array) -> tuple[jax.Array, jax.Array]:
    """T(X) = φ(XR)Rᵀ with STE; returns (T(X), distortion scalar).

    Gradients: ∂/∂X flows straight-through φ and through both rotations;
    ∂/∂codebooks comes from the distortion term; ∂/∂R is consumed by the GCD
    update outside (the caller differentiates wrt ``params.R``).
    """
    phi = quantizer(params)
    XR = X @ params.R
    out = phi.encode_st(XR) @ params.R.T
    dist = phi.distortion(XR)
    return out, dist


def apply_no_ste(params: IndexLayerParams, X: jax.Array) -> jax.Array:
    """Serving-path forward: hard quantization, no gradient bridging."""
    phi = quantizer(params)
    return phi.decode(phi.encode(X @ params.R)) @ params.R.T


def encode(params: IndexLayerParams, X: jax.Array) -> jax.Array:
    """Index-build path: item codes (m, D) for the serving index."""
    return quantizer(params).encode(X @ params.R)


def adc_scores(params: IndexLayerParams, queries: jax.Array,
               codes: jax.Array) -> jax.Array:
    """Serving-path ADC scoring: (b, n) queries × (N, D) codes -> (b, N).

    Inner-product scores in the rotated space equal scores in the original
    space because R is orthogonal: ⟨q, φ(xR)Rᵀ⟩ = ⟨qR, φ(xR)⟩. Scores go
    through the shared ADC kernel family (jnp oracle path off-TPU).
    """
    tables = quantizer(params).adc_tables(queries @ params.R)
    return quant.adc_score_tables(tables, codes, use_kernel=False)
