"""Compatibility shim — GCD rotation learning moved to ``repro.rotations``.

New code should go through the learner registry:

    learner = rotations.make("gcd", method="greedy", preconditioner="adam")
    state   = learner.init(n)                     # or init_from(R)
    state, delta = learner.update(state, G, lr, key)

The functional API below (``init`` / ``update`` / ``gcd_step``) is preserved
for existing callers; see README.md for the migration table. Imports of the
rotations package are deferred (it imports ``repro.core.givens``, so eager
module-level imports here would cycle).
"""
from __future__ import annotations

import functools
import warnings

import jax

_FORWARDED = {"GCD": "gcd", "GCDState": "gcd", "RotationState": "gcd",
              "METHODS": "gcd"}


def _warn(what: str) -> None:
    warnings.warn(
        f"repro.core.rotation.{what} is deprecated; use the repro.rotations "
        "learner registry (rotations.make('gcd', ...)) — see the README "
        "migration table", DeprecationWarning, stacklevel=3)


def __getattr__(name):
    if name in _FORWARDED:
        import importlib
        _warn(name)
        mod = importlib.import_module(f"repro.rotations.{_FORWARDED[name]}")
        return getattr(mod, "GCDState" if name == "RotationState" else name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@functools.lru_cache(maxsize=None)
def _learner(method: str, preconditioner: str, sweeps: int):
    from repro.rotations.gcd import GCD
    return GCD(method=method, preconditioner=preconditioner, sweeps=sweeps)


def init(n: int, dtype=None):
    _warn("init")
    import jax.numpy as jnp
    return _learner("greedy", "none", 16).init(n, dtype or jnp.float32)


def init_from(R: jax.Array):
    _warn("init_from")
    return _learner("greedy", "none", 16).init_from(R)


@functools.partial(
    jax.jit, static_argnames=("method", "preconditioner", "sweeps")
)
def _update_jit(state, G, lr, key, *, method, preconditioner, sweeps):
    new_state, _delta = _learner(method, preconditioner, sweeps).update(
        state, G, lr, key)
    return new_state


def update(
    state,
    G: jax.Array,
    lr,
    key: jax.Array,
    *,
    method: str = "greedy",
    preconditioner: str = "none",
    sweeps: int = 16,
):
    """One GCD step (old functional entry point; see rotations.GCD.update)."""
    _warn("update")
    return _update_jit(state, G, lr, key, method=method,
                       preconditioner=preconditioner, sweeps=sweeps)


def gcd_step(
    R: jax.Array,
    G: jax.Array,
    accum: jax.Array,
    accum2: jax.Array,
    step: jax.Array,
    lr,
    key: jax.Array,
    *,
    method: str = "greedy",
    preconditioner: str = "none",
    sweeps: int = 16,
):
    """Array-level GCD step (old optimizer hook). Returns (R, accum, accum2)."""
    _warn("gcd_step")
    from repro.rotations.gcd import GCDState
    state = GCDState(R=R, step=step, accum=accum, accum2=accum2)
    new_state, _delta = _learner(method, preconditioner, sweeps).update(
        state, G, lr, key)
    return new_state.R, new_state.accum, new_state.accum2


def apply_overlapping(R: jax.Array, pi: jax.Array, pj: jax.Array,
                      theta: jax.Array) -> jax.Array:
    """Sequential overlapping-pair apply (now a GivensDelta behavior)."""
    from repro.rotations import base
    return base.GivensDelta(pi=pi, pj=pj, theta=theta,
                            overlapping=True).apply(R)


def rotation_grad(loss_fn, R: jax.Array) -> jax.Array:
    """Convenience: ∇_R loss_fn(R)."""
    return jax.grad(loss_fn)(R)
