"""Trainable SO(n) rotation via Givens coordinate descent (paper Algorithm 2).

``GCDRotation`` owns the rotation matrix R and performs projection-free
manifold updates:

    G  = ∇_R L                      (ordinary backprop gradient)
    A  = GᵀR − RᵀG                  (directional derivatives, Prop. 1)
    (pi, pj) ← select n/2 disjoint pairs   (GCD-R / GCD-G / GCD-S)
    θℓ = −λ · A[iℓ, jℓ] / √2
    R  ← R · ∏ℓ R_{iℓ jℓ}(θℓ)       (commuting block update, O(n²))

R stays exactly orthogonal at every step (up to fp rounding) — no SVD, no
matrix exponential, no Cayley solve.

The optional diagonal preconditioners (adagrad / adam over the (n, n)
directional-derivative field) implement the paper's remark that GCD "can be
easily integrated with standard neural network training algorithms, such as
Adagrad and Adam".
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import givens, matching

METHODS = ("random", "greedy", "steepest", "overlap_greedy", "overlap_random")


class RotationState(NamedTuple):
    """State of the trainable rotation."""

    R: jax.Array              # (n, n) current rotation, in SO(n)
    step: jax.Array           # int32 step counter
    accum: jax.Array          # (n, n) preconditioner 1st accumulator (adagrad/adam-m)
    accum2: jax.Array         # (n, n) adam-v accumulator (unused for adagrad)


def init(n: int, dtype=jnp.float32) -> RotationState:
    return RotationState(
        R=jnp.eye(n, dtype=dtype),
        step=jnp.int32(0),
        accum=jnp.zeros((n, n), dtype=jnp.float32),
        accum2=jnp.zeros((n, n), dtype=jnp.float32),
    )


def init_from(R: jax.Array) -> RotationState:
    n = R.shape[0]
    return RotationState(
        R=R,
        step=jnp.int32(0),
        accum=jnp.zeros((n, n), dtype=jnp.float32),
        accum2=jnp.zeros((n, n), dtype=jnp.float32),
    )


def _precondition(state: RotationState, A: jax.Array, preconditioner: str,
                  beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8):
    """Optionally rescale the directional-derivative field elementwise."""
    if preconditioner == "none":
        return A, state.accum, state.accum2
    t = state.step.astype(jnp.float32) + 1.0
    if preconditioner == "adagrad":
        acc = state.accum + jnp.square(A)
        Ahat = A / (jnp.sqrt(acc) + eps)
        return Ahat, acc, state.accum2
    if preconditioner == "adam":
        m = beta1 * state.accum + (1.0 - beta1) * A
        v = beta2 * state.accum2 + (1.0 - beta2) * jnp.square(A)
        mhat = m / (1.0 - beta1**t)
        vhat = v / (1.0 - beta2**t)
        Ahat = mhat / (jnp.sqrt(vhat) + eps)
        return Ahat, m, v
    raise ValueError(f"unknown preconditioner {preconditioner!r}")


def gcd_step(
    R: jax.Array,
    G: jax.Array,
    accum: jax.Array,
    accum2: jax.Array,
    step: jax.Array,
    lr: float | jax.Array,
    key: jax.Array,
    *,
    method: str = "greedy",
    preconditioner: str = "none",
    sweeps: int = 16,
):
    """Functional core of Algorithm 2 — vmappable over stacked rotations
    (e.g. the per-layer KV rotations (L, hd, hd)). Returns
    (R_new, accum, accum2)."""
    n = R.shape[0]
    state = RotationState(R=R, step=step, accum=accum, accum2=accum2)
    A = givens.directional_derivs(G.astype(jnp.float32), R.astype(jnp.float32))
    Ahat, acc, acc2 = _precondition(state, A, preconditioner)

    if method == "random":
        pi, pj = matching.random_matching(key, n)
    elif method == "greedy":
        # exact-equivalent vectorized-rounds variant: ~12× faster at n=512
        # than the one-edge-at-a-time scan (see matching.greedy_matching_fast)
        pi, pj = matching.greedy_matching_fast(Ahat)
    elif method == "steepest":
        pi, pj = matching.steepest_matching(Ahat, sweeps=sweeps)
    elif method == "overlap_greedy":
        pi, pj = matching.overlapping_topk(Ahat)
    elif method == "overlap_random":
        pi, pj = matching.overlapping_random(key, n)
    else:
        raise ValueError(f"unknown GCD method {method!r}")

    theta = -jnp.asarray(lr, jnp.float32) * Ahat[pi, pj] / givens.SQRT2
    if method.startswith("overlap"):
        R_new = apply_overlapping(R, pi, pj, theta)
    else:
        R_new = givens.apply_pair_rotations(R, pi, pj, theta.astype(R.dtype))
    return R_new, acc, acc2


@functools.partial(
    jax.jit, static_argnames=("method", "preconditioner", "sweeps")
)
def update(
    state: RotationState,
    G: jax.Array,
    lr: float | jax.Array,
    key: jax.Array,
    *,
    method: str = "greedy",
    preconditioner: str = "none",
    sweeps: int = 16,
) -> RotationState:
    """One GCD step. ``G`` is the plain gradient ∇_R L (already psum'd in
    data-parallel training). The matching is computed from |A| and the step
    angle for pair ℓ is −lr · Â[iℓ, jℓ] / √2 (paper Algorithm 2, line 8)."""
    R_new, acc, acc2 = gcd_step(
        state.R, G, state.accum, state.accum2, state.step, lr, key,
        method=method, preconditioner=preconditioner, sweeps=sweeps,
    )
    return RotationState(R=R_new, step=state.step + 1, accum=acc, accum2=acc2)


def apply_overlapping(R: jax.Array, pi: jax.Array, pj: jax.Array,
                      theta: jax.Array) -> jax.Array:
    """Sequentially apply possibly-overlapping rotations (ablation only).

    Overlapping pairs do not commute, so this is a serial fori_loop — the
    paper's point is precisely that this is both slower and theoretically
    unsound; we keep it for the §3.1 ablation benchmarks.
    """

    def body(l, Rc):
        i, j, t = pi[l], pj[l], theta[l].astype(Rc.dtype)
        ci, cj = Rc[:, i], Rc[:, j]
        c, s = jnp.cos(t), jnp.sin(t)
        Rc = Rc.at[:, i].set(c * ci + s * cj)
        Rc = Rc.at[:, j].set(c * cj - s * ci)
        return Rc

    return jax.lax.fori_loop(0, pi.shape[0], body, R)


def rotation_grad(loss_fn, R: jax.Array) -> jax.Array:
    """Convenience: ∇_R loss_fn(R)."""
    return jax.grad(loss_fn)(R)
