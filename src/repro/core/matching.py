"""Coordinate-pair selection for Givens coordinate descent (paper §2.3).

Given the antisymmetric directional-derivative matrix ``A`` (from
``givens.directional_derivs``), select ``n//2`` disjoint axis pairs — a
perfect matching on the complete graph over the n coordinate axes — by one
of the paper's three strategies:

  * GCD-R  ``random_matching``   O(n)        shuffle + pair consecutively
  * GCD-G  ``greedy_matching``   O(n² log n) sort |A|, greedy disjoint scan
  * GCD-S  ``steepest_matching`` greedy + vectorized 2-opt refinement
           (TPU surrogate for the O(n³) serial blossom the paper itself
           brackets as impractical; see DESIGN.md §2). ``exact_matching_dp``
           is the exact bitmask-DP oracle for small n used in tests.

Also the paper's *overlapping* ablations (§3.1): top-k edge selection
without the disjointness constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def random_matching(key: jax.Array, n: int):
    """GCD-R: uniformly random perfect matching over {0..n-1}."""
    perm = jax.random.permutation(key, n)
    p = n // 2
    return perm[:p], perm[p : 2 * p]


@functools.partial(jax.jit, static_argnames=("max_edges",))
def greedy_matching(A: jax.Array, max_edges: int | None = None):
    """GCD-G (Algorithm 1): greedy bipartite matching on |A|.

    Sorts all i<j edges by |A_ij| descending and takes an edge whenever both
    endpoints are still free. On the complete graph this always completes a
    perfect matching after at most n²/2 inspected edges; the while_loop exits
    as soon as n//2 pairs are found.
    """
    n = A.shape[0]
    p = n // 2
    w = jnp.abs(A)
    ii = jnp.arange(n)
    upper = ii[:, None] < ii[None, :]
    flat = jnp.where(upper, w, -jnp.inf).reshape(-1)
    order = jnp.argsort(-flat)  # descending edge indices into n*n
    n_edges = order.shape[0] if max_edges is None else max_edges

    def cond(state):
        t, count, _, _, _ = state
        return (count < p) & (t < n_edges)

    def body(state):
        t, count, used, pi, pj = state
        e = order[t]
        i, j = e // n, e % n
        take = (~used[i]) & (~used[j])
        used = used.at[i].set(used[i] | take).at[j].set(used[j] | take)
        slot = jnp.where(take, count, p)  # p = scratch slot
        pi = pi.at[slot].set(jnp.where(take, i, pi[slot]))
        pj = pj.at[slot].set(jnp.where(take, j, pj[slot]))
        return t + 1, count + take.astype(jnp.int32), used, pi, pj

    state = (
        jnp.int32(0),
        jnp.int32(0),
        jnp.zeros((n,), dtype=bool),
        jnp.zeros((p + 1,), dtype=jnp.int32),
        jnp.zeros((p + 1,), dtype=jnp.int32),
    )
    _, _, _, pi, pj = jax.lax.while_loop(cond, body, state)
    return pi[:p], pj[:p]


@functools.partial(jax.jit, static_argnames=("edges_per_round",))
def greedy_matching_fast(A: jax.Array, edges_per_round: int | None = None):
    """Exact-equivalent GCD-G matching in vectorized ROUNDS (beyond-paper).

    ``greedy_matching`` scans the sorted edge list one edge at a time —
    up to n²/2 sequential while-loop steps (~160 ms at n=512 on CPU; the
    matching typically completes only near the end of the list because the
    LAST pair's edge can rank anywhere). This variant exploits a structural
    fact: restricting greedy to the currently-FREE nodes and re-sorting
    yields exactly the same matching (edges touching used nodes are skipped
    by greedy anyway, and relative order among free-free edges is
    unchanged). So each round (a) masks used nodes out of the score matrix,
    (b) re-sorts — fully vectorized, (c) scans only the top ``8n`` edges.
    Every round matches ≥1 pair (the best free-free edge is always taken),
    and empirically 1–3 rounds complete the matching: the serial scan
    shrinks from O(n²) to O(n) steps per round.
    """
    n = A.shape[0]
    p = n // 2
    m = min(edges_per_round or 8 * n, n * n)  # top_k k must fit n² edges
    w0 = jnp.abs(A)
    ii = jnp.arange(n)
    upper = ii[:, None] < ii[None, :]

    def cond(state):
        count, _, _, _ = state
        return count < p

    def round_body(state):
        count, used, pi, pj = state
        free = ~used
        mask = upper & free[:, None] & free[None, :]
        flat = jnp.where(mask, w0, -jnp.inf).reshape(-1)
        _, order = jax.lax.top_k(flat, m)  # vectorized global sort prefix

        def step(carry, e):
            count, used, pi, pj = carry
            i, j = e // n, e % n
            ok = (~used[i]) & (~used[j]) & (i != j)
            used = used.at[i].set(used[i] | ok).at[j].set(used[j] | ok)
            slot = jnp.where(ok, count, p)
            pi = pi.at[slot].set(jnp.where(ok, i, pi[slot]))
            pj = pj.at[slot].set(jnp.where(ok, j, pj[slot]))
            return (count + ok.astype(jnp.int32), used, pi, pj), None

        (count, used, pi, pj), _ = jax.lax.scan(step, (count, used, pi, pj), order)
        return count, used, pi, pj

    state = (
        jnp.int32(0), jnp.zeros((n,), bool),
        jnp.zeros((p + 1,), jnp.int32), jnp.zeros((p + 1,), jnp.int32),
    )
    count, used, pi, pj = jax.lax.while_loop(cond, round_body, state)
    return pi[:p], pj[:p]


@functools.partial(jax.jit, static_argnames=("sweeps",))
def two_opt_refine(A: jax.Array, pi: jax.Array, pj: jax.Array, sweeps: int = 16):
    """Vectorized 2-opt: repeatedly apply the single best pair-swap.

    For pairs (i₁,j₁), (i₂,j₂) consider rewirings (i₁,i₂),(j₁,j₂) and
    (i₁,j₂),(j₁,i₂); take the globally best improving swap each sweep.
    Monotonically increases total |A| weight, so the result dominates the
    greedy matching it starts from (our GCD-S surrogate).
    """
    w = jnp.abs(A)
    p = pi.shape[0]

    def sweep(_, state):
        pi, pj = state
        cur = w[pi, pj]  # (p,)
        pair_w = cur[:, None] + cur[None, :]
        alt1 = w[pi[:, None], pi[None, :]] + w[pj[:, None], pj[None, :]]
        alt2 = w[pi[:, None], pj[None, :]] + w[pj[:, None], pi[None, :]]
        gain = jnp.maximum(alt1, alt2) - pair_w
        eye = jnp.eye(p, dtype=bool)
        gain = jnp.where(eye, -jnp.inf, gain)
        idx = jnp.argmax(gain)
        a, b = idx // p, idx % p
        use1 = alt1[a, b] >= alt2[a, b]
        improving = gain[a, b] > 1e-12
        # new pair a: (pi[a], pi[b] or pj[b]); new pair b: (pj[a], pj[b] or pi[b])
        na_j = jnp.where(use1, pi[b], pj[b])
        nb_j = jnp.where(use1, pj[b], pi[b])
        new_pi = pi.at[b].set(pj[a])
        new_pj = pj.at[a].set(na_j).at[b].set(nb_j)
        pi = jnp.where(improving, new_pi, pi)
        pj = jnp.where(improving, new_pj, pj)
        return pi, pj

    pi, pj = jax.lax.fori_loop(0, sweeps, sweep, (pi, pj))
    return pi, pj


def steepest_matching(A: jax.Array, sweeps: int = 16):
    """GCD-S surrogate: greedy matching + 2-opt refinement (see DESIGN.md)."""
    pi, pj = greedy_matching(A)
    return two_opt_refine(A, pi, pj, sweeps=sweeps)


def overlapping_topk(A: jax.Array, k: int | None = None):
    """Paper §3.1 ablation: top-k |A| edges WITHOUT disjointness.

    Returned pairs may share axes, so they do not commute; callers must apply
    them sequentially (see rotation.apply_overlapping).
    """
    n = A.shape[0]
    k = n // 2 if k is None else k
    ii = jnp.arange(n)
    upper = ii[:, None] < ii[None, :]
    flat = jnp.where(upper, jnp.abs(A), -jnp.inf).reshape(-1)
    _, idx = jax.lax.top_k(flat, k)
    return idx // n, idx % n


def overlapping_random(key: jax.Array, n: int, k: int | None = None):
    """Random k edges (with possible overlap) — the GCD-R overlapping ablation."""
    k = n // 2 if k is None else k
    ki, kj = jax.random.split(key)
    i = jax.random.randint(ki, (k,), 0, n)
    # force j != i by sampling an offset in [1, n)
    off = jax.random.randint(kj, (k,), 1, n)
    j = (i + off) % n
    lo, hi = jnp.minimum(i, j), jnp.maximum(i, j)
    return lo, hi


def exact_matching_dp(A: np.ndarray):
    """Exact max-weight perfect matching via bitmask DP (test oracle, n ≤ 16).

    dp[mask] = best total |A|-weight perfectly matching the set bits of mask.
    O(2ⁿ·n²) — numpy/python only, never jitted.
    """
    w = np.abs(np.asarray(A))
    n = w.shape[0]
    assert n % 2 == 0 and n <= 16, "oracle is for small even n"
    full = (1 << n) - 1
    NEG = -np.inf
    dp = np.full(1 << n, NEG)
    choice = np.full((1 << n, 2), -1, dtype=np.int64)
    dp[0] = 0.0
    for mask in range(1 << n):
        if dp[mask] == NEG:
            continue
        # find first free axis
        i = 0
        while i < n and (mask >> i) & 1:
            i += 1
        if i == n:
            continue
        for j in range(i + 1, n):
            if (mask >> j) & 1:
                continue
            nm = mask | (1 << i) | (1 << j)
            val = dp[mask] + w[i, j]
            if val > dp[nm]:
                dp[nm] = val
                choice[nm] = (i, j)
    # backtrack
    pairs = []
    mask = full
    while mask:
        i, j = choice[mask]
        pairs.append((int(i), int(j)))
        mask &= ~((1 << int(i)) | (1 << int(j)))
    pairs = pairs[::-1]
    pi = np.array([a for a, _ in pairs], dtype=np.int32)
    pj = np.array([b for _, b in pairs], dtype=np.int32)
    return pi, pj, float(dp[full])


def matching_weight(A, pi, pj) -> jax.Array:
    """Total |A| weight of a matching — comparison metric in tests/benches."""
    return jnp.sum(jnp.abs(jnp.asarray(A)[pi, pj]))
