"""Givens rotation primitives for SO(n) coordinate descent.

The paper's Algorithm 2 needs three operations, all implemented here:

  1. ``directional_derivs(G, R)`` — the antisymmetric matrix
     ``A = GᵀR − RᵀG`` whose (i, j) entry is (up to 1/sqrt(2)) the
     directional derivative of the loss along the Givens generator
     ``R_ij(θ)`` at θ=0 (Proposition 1).
  2. ``apply_pair_rotations(X, pi, pj, theta)`` — right-multiply ``X`` by the
     product of n/2 *disjoint* (hence commuting) Givens rotations in O(n·m)
     instead of a dense matmul.
  3. ``rotation_from_pairs(...)`` — materialize the same product as a dense
     matrix (oracle for tests / small n).

Conventions: a Givens rotation ``R_ij(θ)`` is the identity with entries
``[i,i]=cosθ, [i,j]=−sinθ, [j,i]=sinθ, [j,j]=cosθ`` (Definition 2). Right
multiplication ``X · R_ij(θ)`` therefore mixes *columns* i and j of X:

    col_i' =  cosθ·col_i + sinθ·col_j
    col_j' = −sinθ·col_i + cosθ·col_j
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT2 = 1.4142135623730951


def directional_derivs(G: jax.Array, R: jax.Array) -> jax.Array:
    """A = GᵀR − RᵀG for G = ∇_R L. Antisymmetric (n, n).

    ``A[i, j] * (1/sqrt(2))`` is the normalized directional derivative
    ``d/dθ|₀ L(R·R_ij(θ))``. Note ``A = M − Mᵀ`` with ``M = GᵀR`` — a single
    matmul plus a transpose-subtract (fused in kernels/gcd_score on TPU).
    """
    M = G.T @ R
    return M - M.T


def directional_derivs_wrt_input(X: jax.Array, dLdX: jax.Array) -> jax.Array:
    """Proposition 1 form: derivative of L(X·R_ij(θ)) at θ=0.

    Returns ``∇L(X)ᵀX − Xᵀ∇L(X)`` (n, n) for X (m, n).
    """
    M = dLdX.T @ X
    return M - M.T


def apply_pair_rotations(
    X: jax.Array,
    pi: jax.Array,
    pj: jax.Array,
    theta: jax.Array,
) -> jax.Array:
    """Right-multiply X (..., n) by ∏_ℓ R_{pi[ℓ], pj[ℓ]}(theta[ℓ]).

    Pairs must be disjoint (a partial matching); columns not covered by any
    pair pass through unchanged. O(m·p) work for p pairs — no matmul.
    """
    c = jnp.cos(theta).astype(X.dtype)
    s = jnp.sin(theta).astype(X.dtype)
    xi = jnp.take(X, pi, axis=-1)
    xj = jnp.take(X, pj, axis=-1)
    yi = c * xi + s * xj
    yj = c * xj - s * xi
    X = X.at[..., pi].set(yi)
    X = X.at[..., pj].set(yj)
    return X


def apply_pair_rotations_transposed(
    X: jax.Array,
    pi: jax.Array,
    pj: jax.Array,
    theta: jax.Array,
) -> jax.Array:
    """Right-multiply X by (∏_ℓ R_{iℓ,jℓ}(θℓ))ᵀ = ∏_ℓ R_{iℓ,jℓ}(−θℓ)."""
    return apply_pair_rotations(X, pi, pj, -theta)


def rotation_from_pairs(
    pi: jax.Array, pj: jax.Array, theta: jax.Array, n: int, dtype=jnp.float32
) -> jax.Array:
    """Dense (n, n) matrix ∏_ℓ R_{pi[ℓ], pj[ℓ]}(theta[ℓ]) (disjoint pairs)."""
    return apply_pair_rotations(jnp.eye(n, dtype=dtype), pi, pj, theta)


def gather_pair_scores(A: jax.Array, pi: jax.Array, pj: jax.Array) -> jax.Array:
    """A[pi[ℓ], pj[ℓ]] for each pair ℓ (vector of signed scores)."""
    return A[pi, pj]


def orthogonality_error(R: jax.Array) -> jax.Array:
    """‖RᵀR − I‖_max — drift diagnostic; exactly 0 up to fp rounding for GCD."""
    n = R.shape[-1]
    return jnp.max(jnp.abs(R.T @ R - jnp.eye(n, dtype=R.dtype)))


def project_to_so_n(R: jax.Array) -> jax.Array:
    """SVD projection onto O(n) (det-corrected to SO(n)).

    Used only (a) to re-orthonormalize after very long runs if fp drift
    accumulates, and (b) by the OPQ/Procrustes baseline.
    """
    U, _, Vt = jnp.linalg.svd(R, full_matrices=False)
    Rp = U @ Vt
    det = jnp.linalg.det(Rp)
    # flip last column of U if det == -1 to land in SO(n)
    U = U.at[:, -1].multiply(jnp.sign(det))
    return U @ Vt


def random_rotation(key: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    """Haar-ish random element of SO(n) via QR of a Gaussian."""
    Z = jax.random.normal(key, (n, n), dtype=jnp.float32)
    Q, Rr = jnp.linalg.qr(Z)
    Q = Q * jnp.sign(jnp.diagonal(Rr))[None, :]
    det = jnp.linalg.det(Q)
    Q = Q.at[:, -1].multiply(jnp.sign(det))
    return Q.astype(dtype)
