"""Product quantization substrate (paper §2.1).

A product quantizer splits an n-dim vector into D contiguous subvectors of
size n/D and snaps each to the nearest of K codewords. Everything here is
pure jnp and differentiable where math allows; the non-differentiable argmin
is bridged by the gradient straight-through estimator (Bengio et al. 2013),
exactly as in the paper / Zhang et al. 2021.

Codebooks: (D, K, sub) float. Codes: (m, D) int32.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class PQConfig(NamedTuple):
    num_subspaces: int  # D
    num_codewords: int  # K

    def code_dtype(self):
        return jnp.uint8 if self.num_codewords <= 256 else jnp.int32


def split(X: jax.Array, D: int) -> jax.Array:
    """(..., n) -> (..., D, n/D)."""
    *lead, n = X.shape
    assert n % D == 0, f"n={n} not divisible by D={D}"
    return X.reshape(*lead, D, n // D)


def merge(Xs: jax.Array) -> jax.Array:
    """(..., D, sub) -> (..., D*sub)."""
    *lead, D, sub = Xs.shape
    return Xs.reshape(*lead, D * sub)


def assign(X: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Nearest codeword per subspace. (m, n) -> (m, D) int32.

    Uses ‖x−c‖² = ‖x‖² − 2⟨x,c⟩ + ‖c‖² with the ‖x‖² term dropped (constant
    in the argmin) — so the hot op is one einsum on the MXU.
    """
    D = codebooks.shape[0]
    Xs = split(X, D)  # (m, D, sub)
    dots = jnp.einsum("mds,dks->mdk", Xs, codebooks)
    cn = jnp.sum(jnp.square(codebooks), axis=-1)  # (D, K)
    d2 = cn[None, :, :] - 2.0 * dots
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def decode(codes: jax.Array, codebooks: jax.Array) -> jax.Array:
    """(m, D) codes -> (m, n) reconstruction (differentiable wrt codebooks)."""
    D = codebooks.shape[0]
    gathered = codebooks[jnp.arange(D)[None, :], codes]  # (m, D, sub)
    return merge(gathered)


def quantize(X: jax.Array, codebooks: jax.Array) -> jax.Array:
    """φ(X): hard quantization, no gradient bridging."""
    return decode(assign(X, codebooks), codebooks)


def quantize_ste(X: jax.Array, codebooks: jax.Array) -> jax.Array:
    """φ(X) with straight-through estimator: forward = quantized value,
    backward = identity wrt X (codebooks receive no grad through this path —
    they are trained by the distortion loss)."""
    q = decode(jax.lax.stop_gradient(assign(X, codebooks)), codebooks)
    return X + jax.lax.stop_gradient(q - X)


def distortion(X: jax.Array, codebooks: jax.Array,
               codes: jax.Array | None = None) -> jax.Array:
    """(1/m)‖X − φ(X)‖²_F — the paper's quantization-distortion metric/loss.

    Differentiable wrt both X and codebooks (assignment is stop-gradiented).
    """
    if codes is None:
        codes = jax.lax.stop_gradient(assign(X, codebooks))
    q = decode(codes, codebooks)
    return jnp.mean(jnp.sum(jnp.square(X - q), axis=-1))


def kmeans_init(key: jax.Array, X: jax.Array, cfg: PQConfig) -> jax.Array:
    """Init codebooks by sampling K distinct rows per subspace."""
    m = X.shape[0]
    Xs = split(X, cfg.num_subspaces)  # (m, D, sub)
    idx = jax.random.choice(key, m, shape=(cfg.num_codewords,), replace=False)
    return jnp.transpose(Xs[idx], (1, 0, 2))  # (D, K, sub)


def kmeans_update(X: jax.Array, codebooks: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One Lloyd iteration over all D subspaces. Returns (codebooks, codes).

    Empty clusters keep their previous centroid.
    """
    D, K, _ = codebooks.shape
    codes = assign(X, codebooks)  # (m, D)
    Xs = split(X, D)  # (m, D, sub)

    def per_subspace(xd, cd):
        sums = jax.ops.segment_sum(xd, cd, num_segments=K)  # (K, sub)
        cnt = jax.ops.segment_sum(jnp.ones_like(cd, jnp.float32), cd, num_segments=K)
        return sums, cnt

    sums, cnt = jax.vmap(per_subspace, in_axes=(1, 1))(Xs, codes)  # (D, K, sub), (D, K)
    new = jnp.where(cnt[..., None] > 0, sums / jnp.maximum(cnt[..., None], 1.0), codebooks)
    return new, codes


@functools.partial(jax.jit, static_argnames=("cfg", "iters"))
def kmeans(key: jax.Array, X: jax.Array, cfg: PQConfig, iters: int = 10):
    """Full k-means per subspace; returns (codebooks, distortion_trace)."""
    cb0 = kmeans_init(key, X, cfg)

    def body(cb, _):
        cb, codes = kmeans_update(X, cb)
        return cb, distortion(X, cb, codes)

    cb, trace = jax.lax.scan(body, cb0, None, length=iters)
    return cb, trace


def codebook_ema_update(codebooks: jax.Array, X: jax.Array, codes: jax.Array,
                        decay: float = 0.99) -> jax.Array:
    """Streaming EMA codebook update (VQ-VAE style) — an alternative to
    gradient training of codebooks inside the end-to-end loop."""
    D, K, _ = codebooks.shape
    Xs = split(X, D)

    def per_subspace(xd, cd):
        sums = jax.ops.segment_sum(xd, cd, num_segments=K)
        cnt = jax.ops.segment_sum(jnp.ones_like(cd, jnp.float32), cd, num_segments=K)
        return sums, cnt

    sums, cnt = jax.vmap(per_subspace, in_axes=(1, 1))(Xs, codes)
    batch_mean = sums / jnp.maximum(cnt[..., None], 1.0)
    upd = decay * codebooks + (1.0 - decay) * batch_mean
    return jnp.where(cnt[..., None] > 0, upd, codebooks)


def adc_lut(q: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Asymmetric-distance lookup table for a query batch.

    For inner-product / cosine retrieval the score of item with codes c is
    Σ_d LUT[d, c_d] with LUT[d, k] = ⟨q_d, C[d, k]⟩.  (b, n) -> (b, D, K).
    """
    D = codebooks.shape[0]
    qs = split(q, D)  # (b, D, sub)
    return jnp.einsum("bds,dks->bdk", qs, codebooks)


def adc_score(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Sum LUT entries over subspaces: (b, D, K) × (N, D) -> (b, N)."""
    D = lut.shape[1]
    gathered = lut[:, jnp.arange(D)[None, :], codes]  # (b, N, D)
    return jnp.sum(gathered, axis=-1)
