"""Compatibility shim — the PQ substrate moved to ``repro.quant``.

The functional product-quantization layer that used to live here is now the
shared codebook/k-means substrate of the unified quantizer subsystem:

  ===========================  =====================================
  old (core.pq)                new (repro.quant)
  ===========================  =====================================
  PQConfig                     quant.base.PQConfig
  split / merge                quant.codebook.split / merge
  assign / decode / quantize   quant.codebook.assign / decode / quantize
  quantize_ste                 quant.codebook.quantize_ste  (or PQ.encode_st)
  distortion                   quant.codebook.distortion    (or PQ.distortion)
  kmeans* / codebook_ema_*     quant.kmeans.*
  adc_lut / adc_score          quant.codebook.adc_lut / adc_score
  (object API)                 quant.PQ / quant.RQ / quant.VQ
  ===========================  =====================================

New code should import from ``repro.quant``; this module re-exports the old
names so existing call sites keep working.
"""
from repro.quant.base import PQConfig  # noqa: F401
from repro.quant.codebook import (  # noqa: F401
    adc_lut,
    adc_score,
    assign,
    decode,
    distortion,
    merge,
    quantize,
    quantize_ste,
    split,
)
from repro.quant.kmeans import (  # noqa: F401
    codebook_ema_update,
    kmeans,
    kmeans_init,
    kmeans_update,
)
