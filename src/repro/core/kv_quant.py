"""PQ-compressed KV cache with a learned (GCD) rotation — the paper's
embedding-index layer transplanted onto LM attention (beyond-paper feature,
see DESIGN.md §4).

Keys/values are quantized **per head vector** (head_dim-dim) with a per-layer
rotation R ∈ SO(head_dim) and per-layer codebooks — each of keys and values
is a ``quant.PQ`` instance (viewed over the ``cb_k``/``cb_v`` param leaves),
exactly the T(X)=φ(XR)Rᵀ structure of the paper. Decode-time attention never
dequantizes the cache into dense form:

  * scores:  q·k̂ᵀ = Σ_d LUT[d, code_d] — ADC through the shared kernel
             family's grouped member (kernels/adc_batch.py; one (batch,
             kv-head) pair per group, GQA rep queries per group)
  * output:  Σ_s w_s·v̂_s = Σ_{d,k} H[d,k]·C_v[d,k]  with the weight histogram
             H[d,k] = Σ_{s: code_s,d = k} w_s   (scatter-add + tiny matmul)

Memory: head_dim·2 bytes → D bytes per vector (e.g. 128·2B → 16B at D=16,
a 16× cut) — this is what makes the 500k-context decode cells feasible.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.kernels import ops as kops
from repro.kernels.common import INTERPRET


def _default_use_kernel(use_kernel: bool | None) -> bool:
    """Kernel dispatch default for the decode hot path: the Pallas member of
    the ADC family on real TPUs, its jnp oracle elsewhere — interpret mode
    loops the grid in Python and would cripple non-TPU decode. Pass an
    explicit bool to override (the parity tests force both paths)."""
    return (not INTERPRET) if use_kernel is None else use_kernel


class KVQuantConfig(NamedTuple):
    head_dim: int
    num_subspaces: int = 16
    num_codewords: int = 256

    @property
    def sub(self) -> int:
        return self.head_dim // self.num_subspaces

    @property
    def pq_cfg(self) -> quant.PQConfig:
        return quant.PQConfig(self.num_subspaces, self.num_codewords)


class KVQuantParams(NamedTuple):
    """Per-layer parameters (no leading layer axis; stack outside).

    Raw array leaves (models/transformer ParamSpecs and the optimizer's
    name-based manifold routing need a flat tree); ``quant_k``/``quant_v``
    wrap the codebooks in the Quantizer protocol on demand.
    """

    rot_k: jax.Array  # (hd, hd)
    rot_v: jax.Array  # (hd, hd)
    cb_k: jax.Array   # (D, K, sub)
    cb_v: jax.Array   # (D, K, sub)

    @property
    def quant_k(self) -> quant.PQ:
        return quant.PQ(self.cb_k)

    @property
    def quant_v(self) -> quant.PQ:
        return quant.PQ(self.cb_v)


def init(key: jax.Array, cfg: KVQuantConfig, dtype=jnp.float32) -> KVQuantParams:
    k1, k2 = jax.random.split(key)
    hd, D, K, sub = cfg.head_dim, cfg.num_subspaces, cfg.num_codewords, cfg.sub
    return KVQuantParams(
        rot_k=jnp.eye(hd, dtype=dtype),
        rot_v=jnp.eye(hd, dtype=dtype),
        cb_k=0.02 * jax.random.normal(k1, (D, K, sub), dtype=dtype),
        cb_v=0.02 * jax.random.normal(k2, (D, K, sub), dtype=dtype),
    )


def _flatten_heads(x: jax.Array) -> tuple[jax.Array, tuple]:
    """(..., hd) -> (prod(...), hd) plus the lead shape for unflattening."""
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def encode_kv(params: KVQuantParams, k: jax.Array, v: jax.Array):
    """Quantize key/value tensors (..., hd) -> codes (..., D) uint8/int32."""
    qk, qv = params.quant_k, params.quant_v
    kf, lead = _flatten_heads(k)
    vf, _ = _flatten_heads(v)
    ck = qk.encode(kf @ params.rot_k).astype(qk.code_dtype)
    cv = qv.encode(vf @ params.rot_v).astype(qv.code_dtype)
    return ck.reshape(*lead, qk.code_width), cv.reshape(*lead, qv.code_width)


def decode_k(params: KVQuantParams, codes: jax.Array) -> jax.Array:
    """Codes (..., D) -> dense keys (..., hd): k̂ = decode(c)·Rᵀ."""
    lead = codes.shape[:-1]
    flat = params.quant_k.decode(codes.reshape(-1, codes.shape[-1]))
    return (flat @ params.rot_k.T).reshape(*lead, params.rot_k.shape[0])


def decode_v(params: KVQuantParams, codes: jax.Array) -> jax.Array:
    lead = codes.shape[:-1]
    flat = params.quant_v.decode(codes.reshape(-1, codes.shape[-1]))
    return (flat @ params.rot_v.T).reshape(*lead, params.rot_v.shape[0])


def adc_scores_grouped(params: KVQuantParams, q: jax.Array, k_codes: jax.Array,
                       *, use_kernel: bool | None = None) -> jax.Array:
    """Grouped ADC scoring — the decode hot path.

    q (g, r, hd) queries vs k_codes (g, S, D): group g is one (batch,
    kv-head) pair, r its GQA query repetition. Builds one (r, D, K) LUT per
    group (LUT = adc_tables(qR)) and dispatches to the shared grouped kernel
    (kernels/adc_batch.py) or its scan-accumulated jnp oracle — codes are
    never broadcast over r, so the peak buffer stays O(g·r·S).
    Returns (g, r, S).
    """
    g, r, hd = q.shape
    lut = params.quant_k.adc_tables((q @ params.rot_k).reshape(g * r, hd))
    lut = lut.reshape(g, r, *lut.shape[1:])  # (g, r, D, K)
    return kops.adc_batch(lut, k_codes,
                          use_kernel=_default_use_kernel(use_kernel))


def adc_scores(params: KVQuantParams, q: jax.Array, k_codes: jax.Array,
               *, use_kernel: bool | None = None) -> jax.Array:
    """q (..., hd) vs key codes (..., S, D) -> scores (..., S).

    ⟨q, k̂⟩ = ⟨qR, decode(c)⟩ = Σ_d LUT[d, c_d] with LUT = adc_tables(qR).
    Leading axes of q and k_codes must broadcast-match (e.g. (B, H) each);
    each joint lead element becomes one single-query group of the grouped
    scorer. Size-1 broadcast axes materialize a code copy here — the GQA
    decode path calls ``adc_scores_grouped`` directly to share one code set
    across the rep queries instead.
    """
    hd = q.shape[-1]
    S, D = k_codes.shape[-2:]
    lead = jnp.broadcast_shapes(q.shape[:-1], k_codes.shape[:-2])
    qb = jnp.broadcast_to(q, (*lead, hd)).reshape(-1, 1, hd)
    cb = jnp.broadcast_to(k_codes, (*lead, S, D)).reshape(-1, S, D)
    out = adc_scores_grouped(params, qb, cb, use_kernel=use_kernel)
    return out.reshape(*lead, S)


def weighted_value_sum(params: KVQuantParams, w: jax.Array,
                       v_codes: jax.Array) -> jax.Array:
    """Σ_s w[..., s] · v̂[..., s, :] without dequantizing the cache.

    H[..., d, k] = Σ_{s: code=k} w_s  (histogram), out = Σ_{d,k} H·C_v[d,k]
    concatenated over d.  w: (..., S), v_codes: (..., S, D) -> (..., hd).
    """
    D, K, sub = params.cb_v.shape
    S = w.shape[-1]
    lead = w.shape[:-1]
    # scatter-add the weights into (D, K) histograms. GQA repetition: the
    # rep axis of w shares one set of codes — vmap with codes held constant
    # instead of broadcasting them (a materialized int32 broadcast costs
    # rep × S × D × 4 bytes: ~5 GiB at the 500k-context decode shape).
    code_lead = v_codes.shape[:-2]
    rep_shape = lead[len(code_lead):]       # extra axes w has beyond codes
    wf = w.reshape(-1, *rep_shape, S).reshape(
        -1, int(np.prod(rep_shape, dtype=int)) if rep_shape else 1, S)
    cf = v_codes.astype(jnp.int32).reshape(-1, S, D)

    def one_hist(wb, cb):  # wb (R, S), cb (S, D) -> (R, D, K)
        def per_rep(wr):
            return jax.vmap(
                lambda col: jax.ops.segment_sum(wr, col, num_segments=K),
                in_axes=1,
            )(cb)
        return jax.vmap(per_rep)(wb)

    hist = jax.vmap(one_hist)(wf, cf).reshape(*lead, D, K)
    parts = jnp.einsum("...dk,dks->...ds", hist, params.cb_v)  # (..., D, sub)
    out = parts.reshape(*parts.shape[:-2], D * sub)
    return out @ params.rot_v.T  # rotate back out of the PQ basis


def adc_decode_attention(
    params: KVQuantParams,
    q: jax.Array,          # (B, H, hd) single-step query
    k_codes: jax.Array,    # (B, H_kv, S, D)
    v_codes: jax.Array,    # (B, H_kv, S, D)
    length_mask: jax.Array | None = None,  # (B, S) bool, True = valid
    scale: float | None = None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """One decode step of attention entirely in the compressed domain.

    Supports GQA: H query heads read from H_kv cache heads (H % H_kv == 0).
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    H_kv, S, D = k_codes.shape[1:]
    rep = H // H_kv
    scale = (hd ** -0.5) if scale is None else scale
    # grouped scorer: one (batch, kv-head) pair per group, rep queries each —
    # codes are NOT broadcast over the rep axis.
    qg = q.reshape(B * H_kv, rep, hd)
    scores = adc_scores_grouped(
        params, qg, k_codes.reshape(B * H_kv, S, D), use_kernel=use_kernel
    ).reshape(B, H_kv, rep, S) * scale
    if length_mask is not None:
        scores = jnp.where(length_mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    # v_codes passed WITHOUT the rep axis: the histogram vmap shares one set
    # of codes across the rep heads (no broadcast materialization).
    out = weighted_value_sum(params, w, v_codes)  # (B, H_kv, rep, hd)
    return out.reshape(B, H, hd)


def kv_distortion(params: KVQuantParams, k: jax.Array, v: jax.Array) -> jax.Array:
    """Distortion loss on sampled K/V vectors — the Eq.(1) second term for the
    KV index; drives codebook SGD training and supplies ∇_R for GCD."""
    kf, _ = _flatten_heads(k)
    vf, _ = _flatten_heads(v)
    dk = params.quant_k.distortion(kf @ params.rot_k)
    dv = params.quant_v.distortion(vf @ params.rot_v)
    return dk + dv
