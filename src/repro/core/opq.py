"""Compatibility shim — OPQ alternating minimization moved to
``repro.quant.opq``; the rotation solvers live in the ``repro.rotations``
registry (see README.md migration table).

New code should call ``repro.quant.opq.alternating_minimization`` with a
``rotation=`` registry spec ("procrustes", "gcd_greedy", "cayley_sgd", ...)
or ``repro.quant.opq.fit`` (protocol idiom, returns (R, quant.PQ, trace)).
The wrappers below accept the pre-registry ``rotation_solver=`` keyword and
its legacy names ("svd", "cayley") unchanged.
"""
from repro.quant.opq import OPQState, opq, procrustes_rotation  # noqa: F401
from repro.quant import opq as _qopq


def alternating_minimization(key, X, cfg, iters: int = 30,
                             rotation_solver: str = "svd",
                             inner_steps: int = 5, lr: float = 1e-4,
                             kmeans_iters: int = 1):
    """Legacy wrapper (old signature preserved, positional calls included):
    ``rotation_solver`` → ``rotation``."""
    return _qopq.alternating_minimization(
        key, X, cfg, iters=iters, rotation=rotation_solver,
        inner_steps=inner_steps, lr=lr, kmeans_iters=kmeans_iters)


def fit(key, X, cfg, *, iters: int = 30, rotation_solver: str = "svd",
        inner_steps: int = 5, lr: float = 1e-4, kmeans_iters: int = 1):
    """Legacy keyword wrapper: ``rotation_solver`` → ``rotation``."""
    return _qopq.fit(key, X, cfg, iters=iters, rotation=rotation_solver,
                     inner_steps=inner_steps, lr=lr,
                     kmeans_iters=kmeans_iters)
