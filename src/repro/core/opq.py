"""Compatibility shim — OPQ alternating minimization moved to
``repro.quant.opq`` (rotation-aware codebook fitting lives with the other
quantizer fits; see README.md migration table).

New code should call ``repro.quant.opq.alternating_minimization`` (arrays) or
``repro.quant.opq.fit`` (protocol idiom, returns (R, quant.PQ, trace)).
"""
from repro.quant.opq import (  # noqa: F401
    OPQState,
    alternating_minimization,
    opq,
    procrustes_rotation,
)
