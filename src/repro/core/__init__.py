"""Core: the paper's contribution — GCD rotation learning + trainable PQ index.

Modules:
  givens       Givens rotation math (directional derivs, commuting pair apply)
  matching     GCD-R / GCD-G / GCD-S pair selection (+ exact DP test oracle)
  rotation     Trainable SO(n) rotation state & update (Algorithm 2)
  cayley       Cayley-transform baseline
  pq           compatibility shim → repro.quant (codebook/k-means substrate)
  opq          compatibility shim → repro.quant.opq (alternating min, Fig 2)
  index_layer  T(X) = φ(XR)Rᵀ trainable index layer (Fig 1), φ = quant.PQ
  kv_quant     PQ-compressed KV cache (per-head quant.PQ on LM attention)

Quantization itself lives in ``repro.quant`` (Quantizer protocol, PQ/RQ/VQ,
shared k-means); core keeps the rotation-learning math that is this paper's
contribution.
"""
from repro.core import (  # noqa: F401
    cayley,
    givens,
    index_layer,
    kv_quant,
    matching,
    opq,
    pq,
    rotation,
)
