"""Core: the paper's contribution — GCD rotation learning + trainable PQ index.

Modules:
  givens       Givens rotation math (directional derivs, commuting pair apply)
  matching     GCD-R / GCD-G / GCD-S pair selection (+ exact DP test oracle)
  rotation     Trainable SO(n) rotation state & update (Algorithm 2)
  cayley       Cayley-transform baseline
  pq           Product quantization (k-means, STE, ADC)
  opq          OPQ alternating minimization + fixed-embedding harness (Fig 2)
  index_layer  T(X) = φ(XR)Rᵀ trainable index layer (Fig 1)
  kv_quant     PQ-compressed KV cache (paper technique on LM attention)
"""
from repro.core import (  # noqa: F401
    cayley,
    givens,
    index_layer,
    kv_quant,
    matching,
    opq,
    pq,
    rotation,
)
