"""Core: the paper's low-level math — Givens primitives + trainable PQ index.

Modules:
  givens       Givens rotation math (directional derivs, commuting pair apply)
  matching     GCD-R / GCD-G / GCD-S pair selection (+ exact DP test oracle)
  rotation     compatibility shim → repro.rotations (GCD learner, Algorithm 2)
  cayley       compatibility shim → repro.rotations.cayley (guarded transforms)
  pq           compatibility shim → repro.quant (codebook/k-means substrate)
  opq          compatibility shim → repro.quant.opq (alternating min, Fig 2)
  index_layer  T(X) = φ(XR)Rᵀ trainable index layer (Fig 1), φ = quant.PQ
  kv_quant     PQ-compressed KV cache (per-head quant.PQ on LM attention)

Rotation *learning* lives in ``repro.rotations`` (RotationLearner protocol,
GCD/Cayley/Procrustes/frozen registry); quantization in ``repro.quant``
(Quantizer protocol, PQ/RQ/VQ, shared k-means). Core keeps the primitive
math both build on.
"""
from repro.core import (  # noqa: F401
    cayley,
    givens,
    index_layer,
    kv_quant,
    matching,
    opq,
    pq,
    rotation,
)
