"""Cayley-transform rotation baseline (paper §1.1, compared in §3).

R(A) = (I − A)(I + A)⁻¹ with A skew-symmetric, parameterized by the strict
lower triangle of an (n, n) matrix. Differentiable end-to-end, but every
evaluation costs an n×n linear solve that does not parallelize on
GPU/TPU — the paper's (and our) motivation for GCD. Numerically unstable
near rotations with −1 eigenvalues (noted in §1.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def skew_from_params(params: jax.Array) -> jax.Array:
    """Antisymmetrize: A = tril(params, -1) − tril(params, -1)ᵀ."""
    L = jnp.tril(params, -1)
    return L - L.T


def cayley(params: jax.Array) -> jax.Array:
    """R = (I − A)(I + A)⁻¹ ∈ SO(n). Solved as (I + A)ᵀ x = (I − A)ᵀ row-wise."""
    A = skew_from_params(params)
    n = A.shape[0]
    I = jnp.eye(n, dtype=A.dtype)
    # solve (I + A) R = (I − A)  =>  R = (I + A)^{-1} (I − A); both orderings
    # give an orthogonal matrix since (I−A) and (I+A)^{-1} commute.
    return jnp.linalg.solve(I + A, I - A)


def inverse_cayley(R: jax.Array) -> jax.Array:
    """A with cayley(A) == R (valid when I + R is invertible): A = (I−R)(I+R)⁻¹."""
    n = R.shape[0]
    I = jnp.eye(n, dtype=R.dtype)
    A = jnp.linalg.solve((I + R).T, (I - R).T).T
    return jnp.tril(A, -1)  # params form


def init(n: int, dtype=jnp.float32) -> jax.Array:
    """Identity rotation: A = 0."""
    return jnp.zeros((n, n), dtype=dtype)
