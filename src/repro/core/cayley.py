"""Compatibility shim — Cayley-transform math moved to ``repro.rotations.cayley``.

The transforms now carry a numerical guard against the −1-eigenvalue
instability the paper notes in §1.1 (``rotations.cayley.stable_solve``), and
the trainable baseline is the ``cayley_sgd`` learner in the rotation
registry (``rotations.make("cayley_sgd")``). See README.md for the
migration table.

Attribute access is lazy (PEP 562): ``repro.rotations`` imports
``repro.core.givens``, so an eager re-export here would cycle.
"""
import warnings

_NAMES = ("cayley", "init", "inverse_cayley", "skew_from_params",
          "stable_solve", "CayleySGD", "CayleyState")


def __getattr__(name):
    if name in _NAMES:
        warnings.warn(
            f"repro.core.cayley.{name} is deprecated; use "
            "repro.rotations.cayley (or rotations.make('cayley_sgd')) — see "
            "the README migration table", DeprecationWarning, stacklevel=2)
        from repro.rotations import cayley as _impl
        return getattr(_impl, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(_NAMES)
