"""Searcher protocol + SearchConfig — the ``repro.search`` core contract.

Every retrieval backend in the repo — exact brute force, flat ADC over
PQ/RQ codes, and the IVF probe/scan pipeline — serves through one
optax/quant-style protocol (mirroring the searcher abstraction of the
ScaNN codebase, Guo et al. 2020):

    searcher = search.make("ivf")
    state    = searcher.build(key, corpus, R, cfg)     # offline
    result   = searcher.search(state, Q, k=10)         # hot path, jit'd
    state    = searcher.refresh(state, delta)          # live GCD step
    facts    = searcher.stats(state)                   # host-side dict

``build`` consumes the *learned rotation* R (the paper's serving transform
T(X) = φ(XR)Rᵀ — every backend rotates queries by the same R before
scoring) and a shared ``SearchConfig``, so the same (key, corpus, R, cfg)
triple is comparable across backends — the registry sweep in
``benchmarks/ivf_recall_qps.py`` runs all of them on one harness.

``refresh`` consumes a ``rotations.RotationDelta`` — the same pytree a
``RotationLearner.update`` step returns — so training and serving share one
refresh path: the trainer's delta is fed both to its own state and to the
live searcher, and the served rotation tracks the trained one without a
corpus re-encode (see ``index.maintain``). The ADC backends require a
disjoint ``GivensDelta`` (dense Cayley/Procrustes factors do not factor
into per-subspace codebook rotations); ``exact`` absorbs any delta.

Every backend returns the same ``SearchResult`` pytree with a well-defined
padding contract: when ``k`` exceeds the surviving candidate count, tail
slots carry ``id = −1`` and ``score = −inf``, and ``metrics.recall_at_k``
never counts padding as a hit.

States are jit-traceable pytrees whose serving knobs (tile/probe window
sizes, kernel toggles) are static aux fields, so ``jax.jit`` specializes
per layout and a state can be swapped under a compiled executable as long
as its shapes are unchanged — which is exactly what ``refresh`` guarantees,
and what lets ``search.Engine`` keep its compile cache warm across live
rotation refreshes.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax

from repro import rotations
# SearchResult and the top-k/padding contract predate this package
# (repro.index.search, PR 1) and are re-exported as the one result type +
# padding behavior every backend shares.
from repro.index.search import (  # noqa: F401
    NEG_INF,
    SearchResult,
    topk_padded,
)


class SearchConfig(NamedTuple):
    """Backend-shared build parameters (each backend reads its slice).

    Quantized backends (``flat_adc``, ``ivf``) build an IVF-PQ/RQ index:
    ``subspaces``/``codewords``/``depth`` configure the residual quantizer,
    ``num_lists`` the coarse partition (``flat_adc`` defaults to scanning
    whatever partition it is given — 1 list makes it a pure flat scan),
    ``block_size`` the CSR/Pallas tile, ``train_size`` caps the k-means
    sample. ``nprobe`` is the ``ivf`` backend's default probe width (a
    per-call override exists). ``exact`` only reads ``tile_rows`` — the
    corpus tile of its streaming brute-force scan. ``use_kernel`` toggles
    the Pallas kernels (False = jnp reference path, the CPU/CI default).

    ``lut_dtype`` quantizes the ADC lookup tables the scan kernels stream
    ("float32" | "int8" | "uint8" — integer dtypes store per-subspace
    scales alongside and dequantize in VMEM, quartering LUT bytes moved).
    ``fused_refresh`` makes the ADC/exact backends absorb rotation deltas
    into the *query-side* transform only: corpus buffers are frozen at
    build time, ``refresh(delta)`` swaps one (n, n) matrix, and cached
    LUTs stay valid for within-subspace deltas (kernels/lut_build.py).
    """

    subspaces: int = 8
    codewords: int = 256
    depth: int = 1
    num_lists: int = 1
    nprobe: int = 8
    block_size: int = 128
    tile_rows: int = 4096
    train_size: int | None = None
    use_kernel: bool = False
    lut_dtype: str = "float32"
    fused_refresh: bool = False

    def ivf_config(self):
        """The ``IVFPQConfig`` slice consumed by the quantized backends."""
        from repro import quant
        from repro.index.ivf import IVFPQConfig
        return IVFPQConfig(
            num_lists=self.num_lists,
            pq=quant.PQConfig(self.subspaces, self.codewords),
            block_size=self.block_size,
            depth=self.depth,
            lut_dtype=self.lut_dtype,
        )


@runtime_checkable
class Searcher(Protocol):
    """The retrieval-backend protocol (see module docstring).

    Implementations are frozen dataclasses (hashable; safe to close over in
    jit) holding no per-corpus data — everything lives in the state pytree.
    Backends may expose extra capabilities the Engine sniffs for:
    ``rotate_queries``/``luts``/``search_prepared`` (ADC LUT caching) and
    per-call ``nprobe`` overrides (``ivf``).
    """

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> Any:
        """Offline: index ``corpus`` under the learned rotation ``R``."""
        ...

    def search(self, state: Any, Q: jax.Array, *, k: int = 10) -> SearchResult:
        """Top-``k`` by inner product for a (b, n) query batch."""
        ...

    def refresh(self, state: Any, delta: rotations.RotationDelta) -> Any:
        """Absorb a rotation-learner step into the servable state."""
        ...

    def stats(self, state: Any) -> dict:
        """Host-side serving facts (rows, scan work, memory, knobs)."""
        ...


