"""Flat-ADC searcher: full PQ/RQ scan via the shared ADC kernels.

Scores every CSR row of an IVF-PQ/RQ index (coarse term + residual LUT
sum, ``kernels/adc_lookup``) — the quantized-but-unprobed middle point of
the registry: exact's quality ceiling is its score quantization, ``ivf``'s
additional loss on top is probing. Built with ``num_lists=1`` it is a pure
flat ADC scan; built with (or attached to, via ``attach``) a multi-list
index it scans the identical codes the ``ivf`` backend probes, which is
what makes "recall@10 vs flat" a pure measure of ``nprobe`` — the
backend-parity regression in tests/test_search.py pins ``ivf`` at
``nprobe = num_lists`` to this backend's exact output.

``ADCState`` is shared with the ``ivf`` backend: same index pytree, same
static serving knobs, so one build can serve both backends and ``refresh``
(``maintain.refresh_delta`` — disjoint GivensDelta only) behaves
identically.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro import rotations
from repro.index import maintain
from repro.index import ivf as index_ivf
from repro.index import search as index_search
from repro.index.ivf import IVFPQIndex
from repro.search.base import SearchConfig, SearchResult, topk_padded


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ADCState:
    """Quantized-backend state: the servable index + static serving knobs.

    ``nprobe``/``max_blocks`` are read by the ``ivf`` backend only (the
    probe width default and the static probe-window size); ``flat_adc``
    scans everything. ``max_blocks = -1`` means "derive from the index at
    search time" — ``attach``/``build`` bake the concrete value so the
    serving hot path never host-syncs, but a directly-constructed
    ``ADCState(index=...)`` still searches exactly instead of silently
    truncating probed lists.
    """

    index: IVFPQIndex
    nprobe: int = dataclasses.field(default=8, metadata={"static": True})
    max_blocks: int = dataclasses.field(default=-1, metadata={"static": True})
    use_kernel: bool = dataclasses.field(
        default=False, metadata={"static": True})


def _adc_stats(name: str, state: ADCState) -> dict:
    index = state.index
    live = int(np.sum(np.asarray(index.ids) >= 0))
    code_bytes = int(index.codes.shape[1] * index.codes.dtype.itemsize)
    return dict(
        backend=name,
        rows=live,
        capacity=index.capacity,
        dim=index.dim,
        num_lists=index.num_lists,
        code_bytes_per_row=code_bytes,
        compression=float(index.dim * 4 / code_bytes),
        memory_bytes=int(index.codes.size * index.codes.dtype.itemsize),
        use_kernel=state.use_kernel,
    )


def _refresh(state: ADCState, delta: rotations.RotationDelta) -> ADCState:
    return dataclasses.replace(
        state, index=maintain.refresh_delta(state.index, delta))


def _rotate_queries(state: ADCState, Q: jax.Array) -> jax.Array:
    """Engine capability shared by both quantized backends: Q·R."""
    return Q @ state.index.R


def _luts(state: ADCState, QR: jax.Array) -> jax.Array:
    """Engine capability shared by both quantized backends: per-query ADC
    LUTs over the residual quantizer."""
    return state.index.quantizer.adc_tables(QR)


@functools.partial(jax.jit, static_argnames=("k",))
def _flat_search(state: ADCState, Q: jax.Array, k: int) -> SearchResult:
    QR = Q @ state.index.R
    lut = state.index.quantizer.adc_tables(QR)
    return _flat_topk(state, QR, lut, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _flat_prepared(state: ADCState, QR: jax.Array, lut: jax.Array,
                   k: int) -> SearchResult:
    return _flat_topk(state, QR, lut, k)


def _flat_topk(state: ADCState, QR: jax.Array, lut: jax.Array,
               k: int) -> SearchResult:
    scores, cand_ids = index_search.flat_adc_prepared(
        state.index, QR, lut, use_kernel=state.use_kernel)
    top_scores, top_ids = topk_padded(scores, cand_ids, k)
    scanned = jnp.full((QR.shape[0],), state.index.capacity, jnp.int32)
    return SearchResult(scores=top_scores, ids=top_ids, scanned=scanned)


@dataclasses.dataclass(frozen=True)
class FlatADC:
    """Registry backend ``"flat_adc"`` (see module docstring)."""

    name: ClassVar[str] = "flat_adc"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ADCState:
        index = index_ivf.build(key, corpus, R, cfg.ivf_config(),
                                train_size=cfg.train_size)
        return self.attach(index, use_kernel=cfg.use_kernel)

    @staticmethod
    def attach(index: IVFPQIndex, *, use_kernel: bool = False) -> ADCState:
        """State over an existing index — flat-scan the very codes another
        backend probes (the parity-test and benchmark-sharing entry)."""
        return ADCState(index=index, use_kernel=use_kernel,
                        max_blocks=index.max_list_blocks())

    @staticmethod
    def from_quantizer(R: jax.Array, quantizer, corpus: jax.Array, *,
                       block_size: int = 128,
                       use_kernel: bool = False) -> ADCState:
        """Serve a *pre-fit* quantizer (e.g. the PQ that OPQ's alternating
        minimization learned jointly with R) without refitting: the corpus
        is encoded as ``quantizer.encode(corpus @ R)`` under a single
        zero-centroid coarse list, so the served codes are exactly the
        quantizer's own."""
        from repro import quant
        XR = jnp.asarray(corpus) @ jnp.asarray(R).astype(corpus.dtype)
        coarse = quant.VQ(centroids=jnp.zeros((1, XR.shape[1]), XR.dtype))
        list_ids, codes = index_ivf.encode(XR, coarse, quantizer)
        ids = jnp.arange(XR.shape[0], dtype=jnp.int32)
        index = index_ivf.pack(R, coarse, quantizer, codes, list_ids, ids,
                               block_size=block_size)
        return FlatADC.attach(index, use_kernel=use_kernel)

    def search(self, state: ADCState, Q: jax.Array, *,
               k: int = 10) -> SearchResult:
        return _flat_search(state, Q, k)

    # -- Engine LUT-cache capabilities -------------------------------------
    def rotate_queries(self, state: ADCState, Q: jax.Array) -> jax.Array:
        return _rotate_queries(state, Q)

    def luts(self, state: ADCState, QR: jax.Array) -> jax.Array:
        return _luts(state, QR)

    def search_prepared(self, state: ADCState, QR: jax.Array,
                        lut: jax.Array, *, k: int = 10) -> SearchResult:
        return _flat_prepared(state, QR, lut, k)

    def refresh(self, state: ADCState,
                delta: rotations.RotationDelta) -> ADCState:
        return _refresh(state, delta)

    def stats(self, state: ADCState) -> dict:
        st = _adc_stats(self.name, state)
        st["scan_rows_per_query"] = st["capacity"]
        return st
