"""Flat-ADC searcher: full PQ/RQ scan via the shared ADC kernels.

Scores every CSR row of an IVF-PQ/RQ index (coarse term + residual LUT
sum, ``kernels/adc_lookup``) — the quantized-but-unprobed middle point of
the registry: exact's quality ceiling is its score quantization, ``ivf``'s
additional loss on top is probing. Built with ``num_lists=1`` it is a pure
flat ADC scan; built with (or attached to, via ``attach``) a multi-list
index it scans the identical codes the ``ivf`` backend probes, which is
what makes "recall@10 vs flat" a pure measure of ``nprobe`` — the
backend-parity regression in tests/test_search.py pins ``ivf`` at
``nprobe = num_lists`` to this backend's exact output.

``ADCState`` is shared with the ``ivf`` backend: same index pytree, same
static serving knobs, so one build can serve both backends and ``refresh``
(``maintain.refresh_delta`` — disjoint GivensDelta only) behaves
identically.

Fused refresh (``SearchConfig.fused_refresh``): the index pytree — R,
centroids, codebooks, codes — is **frozen at build time** and rotation
deltas accumulate on the query side only. The state carries three extra
matrices: ``rot = R₀·Δ`` (the live rotation, for stats/health), ``wacc``
(the within-subspace part W of the accumulated delta) and
``qdelta = Δ·Wᵀ`` (the composed query-side LUT transform). LUTs are then
built as ``adc_lut(q·R₀·qdelta, C₀)`` — exactly equal to the eager path's
``adc_lut(q·R₀·Δ, C₀ rotated by W)`` because Wᵀ is block-diagonal per
subspace — via the rotation-fused kernel (kernels/lut_build.py). The
payoff: ``refresh(delta)`` is three (n, n) matmuls, no corpus-side buffer
moves, and for *purely within-subspace* deltas (exactly what
``rotations.subspace_gcd`` emits) ``qdelta`` is provably invariant — the
Engine keeps its whole LUT cache (``luts_refresh_invariant``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro import rotations
from repro.churn import buffer as churn_buffer
from repro.index import maintain
from repro.index import ivf as index_ivf
from repro.index import search as index_search
from repro.index.ivf import IVFPQIndex
from repro.kernels import ops as kops
from repro.search.base import SearchConfig, SearchResult, topk_padded


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ADCState:
    """Quantized-backend state: the servable index + static serving knobs.

    ``nprobe``/``max_blocks`` are read by the ``ivf`` backend only (the
    probe width default and the static probe-window size); ``flat_adc``
    scans everything. ``max_blocks = -1`` means "derive from the index at
    search time" — ``attach``/``build`` bake the concrete value so the
    serving hot path never host-syncs, but a directly-constructed
    ``ADCState(index=...)`` still searches exactly instead of silently
    truncating probed lists.

    ``lut_dtype`` selects the ADC-table precision streamed by the scan
    kernels. ``rot``/``wacc``/``qdelta`` are the fused-refresh matrices
    (module docstring); all three are None in eager mode — fused-ness is
    part of the pytree structure, so jit specializes per mode.
    """

    index: IVFPQIndex
    nprobe: int = dataclasses.field(default=8, metadata={"static": True})
    max_blocks: int = dataclasses.field(default=-1, metadata={"static": True})
    use_kernel: bool = dataclasses.field(
        default=False, metadata={"static": True})
    lut_dtype: str = dataclasses.field(
        default="float32", metadata={"static": True})
    rot: jax.Array | None = None     # (n, n) live rotation R₀·Δ (fused)
    wacc: jax.Array | None = None    # (n, n) within-subspace product W
    qdelta: jax.Array | None = None  # (n, n) query-side LUT transform Δ·Wᵀ
    # live-churn append buffer (repro.churn): staged rows are scanned by a
    # flat-ADC side pass and merged into every search below. None until
    # ``churn.with_staging`` installs one; like fused-ness, its presence is
    # pytree structure, so install it before the first search.
    staging: churn_buffer.StagingBuffer | None = None


def _fused_state(state: ADCState) -> ADCState:
    """Initialize the fused-refresh matrices at the build rotation
    (Δ = W = I: rot = R₀, qdelta = I)."""
    n = state.index.R.shape[0]
    eye = jnp.eye(n, dtype=state.index.R.dtype)
    return dataclasses.replace(state, rot=state.index.R, wacc=eye,
                               qdelta=eye)


def _adc_stats(name: str, state: ADCState) -> dict:
    index = state.index
    live = int(np.sum(np.asarray(index.ids) >= 0))
    code_bytes = int(index.codes.shape[1] * index.codes.dtype.itemsize)
    return dict(
        backend=name,
        rows=live,
        capacity=index.capacity,
        dim=index.dim,
        num_lists=index.num_lists,
        code_bytes_per_row=code_bytes,
        compression=float(index.dim * 4 / code_bytes),
        memory_bytes=int(index.codes.size * index.codes.dtype.itemsize),
        use_kernel=state.use_kernel,
        lut_dtype=state.lut_dtype,
        fused_refresh=state.rot is not None,
    )


@functools.partial(jax.jit, static_argnames=("sub",))
def _fused_refresh_mats(R0, rot, wacc, pi, pj, theta, sub: int):
    """Advance the fused matrices by one disjoint GivensDelta: the full
    delta composes into rot, its within-subspace part into wacc, and the
    query-side transform is recomputed as qdelta = R₀ᵀ·rot·waccᵀ
    (= Δ·Wᵀ — it cannot be updated incrementally from itself because the
    new within part must commute past the accumulated cross part)."""
    rot = kops.apply_pair_rotations(rot, pi, pj, theta, use_kernel=False)
    within = (pi // sub) == (pj // sub)
    theta_w = jnp.where(within, theta, 0.0)
    wacc = kops.apply_pair_rotations(wacc, pi, pj, theta_w, use_kernel=False)
    qdelta = R0.T @ rot @ wacc.T
    return rot, wacc, qdelta


def _refresh(state: ADCState, delta: rotations.RotationDelta) -> ADCState:
    if state.rot is None:
        return dataclasses.replace(
            state, index=maintain.refresh_delta(state.index, delta))
    # fused: index pytree frozen, only the query-side matrices move
    maintain.check_refreshable(delta)
    rot, wacc, qdelta = _fused_refresh_mats(
        state.index.R, state.rot, state.wacc,
        delta.pi, delta.pj, delta.theta, state.index.quantizer.sub)
    return dataclasses.replace(state, rot=rot, wacc=wacc, qdelta=qdelta)


def _rotate_queries(state: ADCState, Q: jax.Array) -> jax.Array:
    """Engine capability shared by both quantized backends: Q·R.

    In fused mode the index rotation is frozen at R₀ and the coarse term is
    exactly invariant (⟨q·R₀Δ, c·Δ⟩ = ⟨q·R₀, c⟩), so R₀ is the correct —
    and cache-stable — query rotation in both modes."""
    return Q @ state.index.R


def _luts(state: ADCState, QR: jax.Array):
    """Engine capability shared by both quantized backends: per-query ADC
    LUT pack over the residual quantizer. In fused mode the accumulated
    query-side transform is applied inside the LUT-build kernel; with an
    integer ``lut_dtype`` the tables are quantized to (qlut, scales)."""
    if state.qdelta is not None:
        cb_flat, colmap = state.index.quantizer.lut_operands()
        lut = kops.fused_lut(QR, state.qdelta, cb_flat, colmap,
                             use_kernel=state.use_kernel)
    else:
        lut = state.index.quantizer.adc_tables(QR)
    if state.lut_dtype != "float32":
        return kops.quantize_luts(lut, state.lut_dtype)
    return lut


def _luts_refresh_invariant(state: ADCState,
                            delta: rotations.RotationDelta) -> bool:
    """True iff cached LUT packs stay exactly valid across
    ``refresh(state, delta)``: fused mode and a purely within-subspace
    disjoint GivensDelta (then qdelta' = qdelta — module docstring).
    Host-side, conservative: any doubt returns False."""
    if state.rot is None:
        return False
    if not isinstance(delta, rotations.GivensDelta) or delta.overlapping:
        return False
    sub = state.index.quantizer.sub
    pi = np.asarray(delta.pi)
    pj = np.asarray(delta.pj)
    return bool(np.all((pi // sub) == (pj // sub)))


@functools.partial(jax.jit, static_argnames=("k",))
def _flat_search(state: ADCState, Q: jax.Array, k: int) -> SearchResult:
    QR = _rotate_queries(state, Q)
    lut = _luts(state, QR)
    return _flat_topk(state, QR, lut, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _flat_prepared(state: ADCState, QR: jax.Array, lut,
                   k: int) -> SearchResult:
    return _flat_topk(state, QR, lut, k)


def _flat_topk(state: ADCState, QR: jax.Array, lut,
               k: int) -> SearchResult:
    scores, cand_ids = index_search.flat_adc_prepared(
        state.index, QR, lut, use_kernel=state.use_kernel)
    top_scores, top_ids = topk_padded(scores, cand_ids, k)
    scanned = jnp.full((QR.shape[0],), state.index.capacity, jnp.int32)
    res = SearchResult(scores=top_scores, ids=top_ids, scanned=scanned)
    if state.staging is not None:
        res = churn_buffer.merge_staged(
            res, state.staging, QR, lut, state.index.centroids, k,
            use_kernel=state.use_kernel)
    return res


@dataclasses.dataclass(frozen=True)
class FlatADC:
    """Registry backend ``"flat_adc"`` (see module docstring)."""

    name: ClassVar[str] = "flat_adc"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ADCState:
        index = index_ivf.build(key, corpus, R, cfg.ivf_config(),
                                train_size=cfg.train_size)
        return self.attach(index, use_kernel=cfg.use_kernel,
                           lut_dtype=cfg.lut_dtype,
                           fused_refresh=cfg.fused_refresh)

    @staticmethod
    def attach(index: IVFPQIndex, *, use_kernel: bool = False,
               lut_dtype: str = "float32",
               fused_refresh: bool = False) -> ADCState:
        """State over an existing index — flat-scan the very codes another
        backend probes (the parity-test and benchmark-sharing entry)."""
        state = ADCState(index=index, use_kernel=use_kernel,
                         max_blocks=index.max_list_blocks(),
                         lut_dtype=lut_dtype)
        return _fused_state(state) if fused_refresh else state

    @staticmethod
    def from_quantizer(R: jax.Array, quantizer, corpus: jax.Array, *,
                       block_size: int = 128,
                       use_kernel: bool = False) -> ADCState:
        """Serve a *pre-fit* quantizer (e.g. the PQ that OPQ's alternating
        minimization learned jointly with R) without refitting: the corpus
        is encoded as ``quantizer.encode(corpus @ R)`` under a single
        zero-centroid coarse list, so the served codes are exactly the
        quantizer's own."""
        from repro import quant
        XR = jnp.asarray(corpus) @ jnp.asarray(R).astype(corpus.dtype)
        coarse = quant.VQ(centroids=jnp.zeros((1, XR.shape[1]), XR.dtype))
        list_ids, codes = index_ivf.encode(XR, coarse, quantizer)
        ids = jnp.arange(XR.shape[0], dtype=jnp.int32)
        index = index_ivf.pack(R, coarse, quantizer, codes, list_ids, ids,
                               block_size=block_size)
        return FlatADC.attach(index, use_kernel=use_kernel)

    def search(self, state: ADCState, Q: jax.Array, *,
               k: int = 10) -> SearchResult:
        return _flat_search(state, Q, k)

    # -- Engine LUT-cache capabilities -------------------------------------
    def rotate_queries(self, state: ADCState, Q: jax.Array) -> jax.Array:
        return _rotate_queries(state, Q)

    def luts(self, state: ADCState, QR: jax.Array):
        return _luts(state, QR)

    def luts_refresh_invariant(self, state: ADCState,
                               delta: rotations.RotationDelta) -> bool:
        return _luts_refresh_invariant(state, delta)

    def search_prepared(self, state: ADCState, QR: jax.Array,
                        lut, *, k: int = 10) -> SearchResult:
        return _flat_prepared(state, QR, lut, k)

    def refresh(self, state: ADCState,
                delta: rotations.RotationDelta) -> ADCState:
        return _refresh(state, delta)

    def stats(self, state: ADCState) -> dict:
        st = _adc_stats(self.name, state)
        st["scan_rows_per_query"] = st["capacity"]
        return st
