"""repro.search — the unified retrieval subsystem (Searcher registry + Engine).

The paper's end-to-end value proposition is *serving*: T(X) = φ(XR)Rᵀ
deployed as a continuously-refreshed compressed index. This package is the
one front door for every retrieval call in the repo:

  base      Searcher protocol, SearchConfig, SearchResult (+ the shared
            top-k/padding contract: ids −1 / scores −inf past the pool)
  exact     tiled brute-force MIPS — the recall oracle
  flat      flat ADC over PQ/RQ codes (kernels/adc_lookup full scan)
  ivf       probe + fused selected-block Pallas scan (index/search.py)
  sharded   the row-sharded twins (``exact_sharded``/``flat_sharded``/
            ``ivf_sharded``): corpus partitioned over the mesh's "data"
            axis, per-shard local scans under shard_map, all_gather +
            re-top-k merge; R/centroids/codebooks stay replicated so a
            RotationDelta refresh is in-place and recompile-free
  registry  ``make`` / ``names`` — the backend string registry
  engine    ``Engine`` — batching front-end: bucketized ragged batches,
            per-(bucket, k, nprobe) compile cache, per-query ADC LUT
            cache, buffer donation, latency/scan-work stats, live
            rotation refresh between batches

Quick start::

    from repro import search
    searcher = search.make("ivf")                     # or "exact", "flat_adc"
    state = searcher.build(key, corpus, R, search.SearchConfig(
        num_lists=256, subspaces=16, codewords=256, nprobe=16))
    res = searcher.search(state, Q, k=10)             # res.scores, res.ids
    engine = search.Engine(searcher, state, k=10)     # ragged serving
    res = engine.search(Q_any_size)
    engine.refresh(delta)                             # after a GCD step

Consumers: ``examples/serve_ann.py`` (Engine serving loop),
``examples/quickstart.py`` / ``examples/gnn_index.py`` (registry recall
demos), ``benchmarks/ivf_recall_qps.py`` (backend sweep on one harness).
``index.search``'s free functions remain as the IVF mechanism layer this
package dispatches to. See README.md §Serving engine for the migration
table.
"""
from repro.search import (  # noqa: F401
    base,
    engine,
    exact,
    flat,
    ivf,
    registry,
    sharded,
)
from repro.search.base import (  # noqa: F401
    SearchConfig,
    Searcher,
    SearchResult,
    topk_padded,
)
from repro.search.engine import Engine  # noqa: F401
from repro.search.exact import (  # noqa: F401
    Exact,
    ExactState,
    ExactStreaming,
    StreamingExactState,
)
from repro.search.flat import ADCState, FlatADC  # noqa: F401
from repro.search.ivf import IVF  # noqa: F401
from repro.search.registry import make, names  # noqa: F401
from repro.search.sharded import (  # noqa: F401
    ExactSharded,
    FlatSharded,
    IVFSharded,
    ShardedADCState,
    ShardedExactState,
    attach_shards,
)
