"""search.Engine — the batching, compile-cached serving front-end.

Production query traffic is ragged: request batches arrive at arbitrary
sizes, and naive ``jax.jit`` recompiles the whole search pipeline for every
new batch shape. The Engine sits between callers and a Searcher and makes
the hot path shape-stable:

  * **bucketizing** — a (b, n) batch is zero-padded up to the next
    power-of-two bucket (≥ ``min_bucket``), so the universe of compiled
    shapes is logarithmic in the max batch size; results are sliced back
    to b rows before returning. Batches beyond ``max_bucket`` are chunked.
  * **compile cache** — one executable per (bucket, k, nprobe) triple,
    built on first use and reused forever after: ``stats()["compiles"]``
    counts actual traces, and the cache-hit test in tests/test_search.py
    pins "at most one compile per shape". A ``refresh`` swaps the state
    *under* the cached executables (same pytree structure, same statics —
    guaranteed by the refresh contract), so a live rotation update costs
    zero recompiles.
  * **per-query ADC LUT cache** — for quantized backends the (code_width,
    K) LUT is the per-query setup cost; hot/repeated queries reuse their
    cached LUT pack (keyed by raw query bytes + ``lut_dtype`` + the
    invalidation epoch, LRU-evicted) and only cache misses pay the LUT
    build. A refresh normally invalidates the cache (LUTs depend on R),
    but a backend that proves its LUTs exactly invariant across the delta
    (``luts_refresh_invariant`` — fused refresh + within-subspace
    rotations) keeps the whole cache warm; ``stats()["lut_invalidations"]``
    counts the actual clears. Served through the backend's
    ``search_prepared`` capability; backends without it (``exact``) take
    the plain path, and host-loop backends (``exact_stream``,
    ``engine_jit = False``) run eagerly without an outer jit.
  * **buffer donation** — on accelerator backends the padded query/LUT
    buffers are donated to the executable, so serving steady-state holds
    one in-flight copy instead of two (donation is skipped on CPU, where
    XLA would warn and ignore it).
  * **submit/collect split** — ``submit`` runs everything up to and
    including launching the compiled executable and returns WITHOUT
    blocking (JAX dispatch is async); ``collect`` blocks on the result and
    records the request metrics. ``search`` is exactly
    ``collect(submit(...))`` plus chunking, so direct callers see no
    change — but a serving loop (``repro.serve``) can overlap host-side
    admission/batching for the next bucket with device execution of the
    current one.
  * **serving observability** — every request lands in a private, always-on
    ``repro.obs.Registry`` (latency distribution with p50/p95/p99, scanned
    rows, bucket pad waste, LUT hit rate, compile counts) aggregated by
    ``stats()``; an attached ``obs.RecallProbe`` replays a pinned query set
    through the serving path every N requests and gauges live recall@k.
  * **live refresh** — ``engine.refresh(delta)`` absorbs a rotation-learner
    step between batches: training and serving share the one
    ``RotationDelta`` path end to end. When the global ``repro.obs``
    registry is enabled the refresh also records health gauges (delta
    norm, post-refresh orthogonality drift — ``maintain.refresh_health``).

Typical loop::

    engine = search.Engine(search.make("ivf"), state, k=10, nprobe=16)
    for batch in requests:
        res = engine.search(batch)          # ragged sizes welcome
    engine.refresh(delta)                    # after a GCD training step
    print(engine.stats())
"""
from __future__ import annotations

import collections
import inspect
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, rotations
from repro.search.base import SearchResult, Searcher


def _lut_to_host(lut):
    """Host copy of a LUT pack (plain (b, Dp, K) array or (qlut, scales)
    tuple — see index/search.py ``split_lut_pack``)."""
    if isinstance(lut, tuple):
        return tuple(np.asarray(p) for p in lut)
    return np.asarray(lut)


def _lut_row(lut_host, i: int):
    """Row ``i`` of a host LUT pack — the per-query cache value."""
    if isinstance(lut_host, tuple):
        return tuple(p[i] for p in lut_host)
    return lut_host[i]


def _stack_lut_rows(rows):
    """Reassemble cached per-query rows into a batch pack."""
    if isinstance(rows[0], tuple):
        return tuple(np.stack([r[j] for r in rows])
                     for j in range(len(rows[0])))
    return np.stack(rows)


def _pad_lut(lut, pad: int):
    """Zero-pad a LUT pack's query axis up to the bucket and land it on
    device (host rows assembled from the cache arrive as numpy)."""
    if isinstance(lut, tuple):
        return tuple(_pad_lut(p, pad) for p in lut)
    pads = ((0, pad),) + ((0, 0),) * (lut.ndim - 1)
    if isinstance(lut, np.ndarray):
        return jnp.asarray(np.pad(lut, pads))
    return jnp.pad(lut, pads)


class Pending(NamedTuple):
    """An in-flight ``Engine.submit`` — device work dispatched, not yet
    blocked on. Pass to ``Engine.collect`` exactly once; the request is
    only counted (latency, LUT hits, events) at collect time."""

    res: SearchResult          # sliced back to the request's b rows
    batch: int
    bucket: int
    k: int
    nprobe: int | None
    lut_hits: int
    lut_misses: int
    t0: float                  # perf_counter at submit — latency anchor
    compiled_before: int | float


class Engine:
    """Batching serving front-end over one Searcher + state (not thread-safe;
    one Engine per serving thread).

    ``lut_cache_rows`` bounds the LUT cache by *entries*, each a
    (code_width, K) f32 row on the host — 16 KiB at D=16/K=256 PQ, double
    at depth-2 RQ — so the default 8192 holds up to ~128–256 MiB per
    Engine at production configs. Size it to the host budget. The cache
    trades one synchronous device→host LUT copy per cold batch for free
    reuse on repeats; for purely streaming traffic with no repeated
    queries, set ``lut_cache_rows=0`` to disable it (and the prepared
    path) and serve fully on-device.

    ``probe`` (an ``obs.RecallProbe``) is replayed through ``search()``
    every ``probe.every`` requests; probe traffic takes the normal serving
    path and is counted in the request metrics like any other caller.
    """

    def __init__(self, searcher: Searcher, state: Any, *, k: int = 10,
                 nprobe: int | None = None, min_bucket: int = 8,
                 max_bucket: int = 4096, lut_cache_rows: int = 8192,
                 donate: bool | None = None, history: int = 512,
                 probe: obs.RecallProbe | None = None):
        self.searcher = searcher
        if hasattr(searcher, "prepare_state"):
            # bake derived statics now: inside the compiled executables the
            # state arrives as a traced pytree and cannot be host-synced
            state = searcher.prepare_state(state)
        self.state = state
        self.k = k
        self.nprobe = nprobe
        self.min_bucket = max(1, min_bucket)
        self.max_bucket = max(self.min_bucket, max_bucket)
        self.lut_cache_rows = lut_cache_rows
        self.history = history
        self.donate = (jax.default_backend() != "cpu"
                       if donate is None else donate)

        self._takes_nprobe = "nprobe" in inspect.signature(
            searcher.search).parameters
        if nprobe is not None and not self._takes_nprobe:
            raise ValueError(
                f"{type(searcher).__name__} does not take nprobe — an "
                "nprobe setting on this Engine would be silently ignored")
        # backends whose search is a host-side loop (exact_stream) opt out
        # of jit wrapping — their executables are the per-tile jits inside
        self._jit = bool(getattr(searcher, "engine_jit", True))
        self._prepared_ok = self._jit and lut_cache_rows > 0 and all(
            hasattr(searcher, m)
            for m in ("rotate_queries", "luts", "search_prepared"))
        self._compiled: dict[tuple, Any] = {}
        # per-query LUT rows (or (qlut, scales) row tuples), keyed by
        # (raw query bytes, lut_dtype, epoch) — the epoch advances whenever
        # a refresh actually invalidates LUTs, so stale entries can never
        # alias a fresh query even if a clear is ever skipped
        self._luts: collections.OrderedDict[tuple, Any] = \
            collections.OrderedDict()
        self._epoch = 0

        # private always-on registry: the source of truth behind ``stats()``
        # and the ``requests`` compat view (window = ``history`` requests)
        self.obs = obs.Registry(enabled=True, window=max(1, history))
        self._latency = self.obs.distribution("engine.latency_ms")
        self._scanned = self.obs.distribution("engine.scanned_rows")
        self._pad_waste = self.obs.distribution("engine.pad_waste")
        self._counters = {
            name: self.obs.counter(f"engine.{name}")
            for name in ("requests", "queries", "compiles", "refreshes",
                         "lut_hits", "lut_misses", "lut_invalidations",
                         "lut_evictions")}
        self.probe = probe
        self._in_probe = False

    # -- shape bucketing ---------------------------------------------------
    def _bucket(self, b: int) -> int:
        bucket = self.min_bucket
        while bucket < b:
            bucket *= 2
        # chunking guarantees b <= max_bucket, so the clamp still covers b
        # when max_bucket is not itself a power of two
        return min(bucket, self.max_bucket)

    # -- compile cache -----------------------------------------------------
    def _nprobe_key(self, nprobe: int | None) -> int | None:
        """The *effective* probe width: clamped by the backend where it can
        be (ivf caps at num_lists), so oversized requests share one
        executable and request records log what was actually probed."""
        if not self._takes_nprobe:
            if nprobe is not None:
                raise ValueError(
                    f"{type(self.searcher).__name__} does not take nprobe")
            return None
        npb = self.nprobe if nprobe is None else nprobe
        if npb is not None and npb < 1:
            raise ValueError(f"nprobe must be >= 1, got {npb}")
        if hasattr(self.searcher, "effective_nprobe"):
            npb = self.searcher.effective_nprobe(self.state, npb)
        return npb

    def _plain_fn(self, bucket: int, k: int, nprobe: int | None):
        key = ("plain", bucket, k, nprobe)
        if key not in self._compiled:
            searcher = self.searcher
            kw = {} if nprobe is None else {"nprobe": nprobe}
            if not self._jit:
                # eager backend (engine_jit=False): the host-side search
                # loop runs as-is — no outer trace, no donation, and no
                # compile tick (the backend jits its own inner steps)
                self._compiled[key] = \
                    lambda state, Q: searcher.search(state, Q, k=k, **kw)
                return self._compiled[key]
            compiles = self._counters["compiles"]

            def fn(state, Q):
                compiles.inc()  # traced once per key
                return searcher.search(state, Q, k=k, **kw)

            self._compiled[key] = jax.jit(
                fn, donate_argnums=(1,) if self.donate else ())
        return self._compiled[key]

    def _prepared_fn(self, bucket: int, k: int, nprobe: int | None):
        key = ("prepared", bucket, k, nprobe)
        if key not in self._compiled:
            searcher = self.searcher
            kw = {} if nprobe is None else {"nprobe": nprobe}
            compiles = self._counters["compiles"]

            def fn(state, QR, lut):
                compiles.inc()  # traced once per key
                return searcher.search_prepared(state, QR, lut, k=k, **kw)

            self._compiled[key] = jax.jit(
                fn, donate_argnums=(1, 2) if self.donate else ())
        return self._compiled[key]

    # -- per-query LUT cache -----------------------------------------------
    def _lut_key(self, row: np.ndarray) -> tuple:
        """Cache key for one query row: raw bytes + the LUT precision knob
        + the invalidation epoch. ``lut_dtype`` is in the key because the
        cached rows ARE dtype-specific (an int8 (qlut, scales) row is not a
        f32 row); the epoch is bumped by non-invariant refreshes."""
        return (row.tobytes(),
                getattr(self.state, "lut_dtype", "float32"),
                self._epoch)

    def _gather_luts(self, Qnp: np.ndarray,
                     QR: jax.Array) -> tuple[Any, int, int]:
        """LUT rows for every query, cached by raw query bytes (+ dtype,
        epoch). ``QR`` is the already-rotated batch (rows sliced for the
        misses, so the rotation runs once per request). Returns (lut pack
        (b, Dp, K) or ((b, Dp, K) qlut, (b, Dp, 2) scales), hits, misses)
        — both counted per served row; duplicate rows inside one batch pay
        the LUT build only once."""
        keys = [self._lut_key(row) for row in Qnp]
        hits = 0
        need, seen = [], set()
        for i, kb in enumerate(keys):
            if kb in self._luts:
                hits += 1
                self._luts.move_to_end(kb)  # MRU now: never evicted below
            elif kb not in seen:
                seen.add(kb)
                need.append(i)
        misses = len(keys) - hits
        if misses == len(keys) and len(need) == len(keys):
            # all-miss, all-distinct: serve the device LUTs directly (skip
            # the host round-trip); the host copy below only feeds the cache
            lut_dev = self.searcher.luts(self.state, QR)
            lut_host = _lut_to_host(lut_dev)
            for i, kb in enumerate(keys):
                self._luts[kb] = _lut_row(lut_host, i)
            self._evict()
            return lut_dev, hits, misses
        if need:
            lut_m = _lut_to_host(self.searcher.luts(
                self.state, QR[np.asarray(need)]))
            for j, i in enumerate(need):
                self._luts[keys[i]] = _lut_row(lut_m, j)
        # read every row BEFORE evicting: a batch wider than the cache (or
        # one whose misses push out nothing-but-this-batch entries) must
        # still assemble — eviction only trims for the NEXT request
        rows = _stack_lut_rows([self._luts[kb] for kb in keys])
        self._evict()
        return rows, hits, misses

    def _evict(self) -> None:
        """Trim to the capacity cap (LRU-first), counting every eviction —
        ``lut_evictions`` is how a multi-tenant front-end sees one hot
        namespace churning its budget (repro.serve sizes each tenant's cap
        so that churn can never spill into another tenant's cache)."""
        while len(self._luts) > self.lut_cache_rows:
            self._luts.popitem(last=False)
            self._counters["lut_evictions"].inc()

    # -- serving -----------------------------------------------------------
    def submit(self, Q: jax.Array, *, k: int | None = None,
               nprobe: int | None = None) -> Pending:
        """Dispatch one (b, n) batch (1 ≤ b ≤ ``max_bucket``) WITHOUT
        blocking: bucketize, resolve LUTs, launch the compiled executable,
        and return a ``Pending`` the caller hands to ``collect``. Device
        work proceeds asynchronously in the meantime, so a serving loop can
        keep admitting/batching the next bucket while this one runs."""
        b = Q.shape[0]
        if b == 0:
            raise ValueError("empty query batch")
        if b > self.max_bucket:
            raise ValueError(
                f"submit is bounded by max_bucket={self.max_bucket} "
                f"(got {b}); search() chunks oversized batches")
        k = self.k if k is None else k
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        npb = self._nprobe_key(nprobe)
        bucket = self._bucket(b)
        pad = bucket - b
        compiled_before = self._counters["compiles"].value
        t0 = time.perf_counter()

        lut_hits = lut_misses = 0
        if self._prepared_ok:
            # the LUT cache keys on raw query bytes — the one place the
            # batch must visit the host (dtype preserved, matching the
            # plain path and direct searcher calls); rotation reads the
            # original array, so a device-resident Q is not re-uploaded
            Qnp = np.asarray(Q)
            QR = self.searcher.rotate_queries(self.state, Q)
            lut, lut_hits, lut_misses = self._gather_luts(Qnp, QR)
            QR = jnp.pad(QR, ((0, pad), (0, 0)))
            # pack-aware: cached host rows and all-miss device packs
            # both pad up to the bucket and land on device
            lut = _pad_lut(lut, pad)
            res = self._prepared_fn(bucket, k, npb)(self.state, QR, lut)
        else:
            # plain path: never leaves the device
            Qp = jnp.pad(jnp.asarray(Q), ((0, pad), (0, 0)))
            res = self._plain_fn(bucket, k, npb)(self.state, Qp)

        res = SearchResult(scores=res.scores[:b], ids=res.ids[:b],
                           scanned=res.scanned[:b])
        return Pending(res=res, batch=b, bucket=bucket, k=k, nprobe=npb,
                       lut_hits=lut_hits, lut_misses=lut_misses, t0=t0,
                       compiled_before=compiled_before)

    def collect(self, pending: Pending) -> SearchResult:
        """Block on a ``submit``'s device work and account the request:
        latency covers submit → result-ready (exactly what the fused
        ``search`` span used to measure), LUT hits/misses and the request
        event land here. Call once per Pending."""
        res = pending.res
        leaves = [x for x in jax.tree_util.tree_leaves(res)
                  if not isinstance(x, jax.core.Tracer)]
        if leaves:
            jax.block_until_ready(leaves)
        latency_ms = (time.perf_counter() - pending.t0) * 1e3

        scanned_rows = float(np.mean(np.asarray(res.scanned)))
        self._counters["requests"].inc()
        self._counters["queries"].inc(pending.batch)
        self._counters["lut_hits"].inc(pending.lut_hits)
        self._counters["lut_misses"].inc(pending.lut_misses)
        self._latency.observe(latency_ms)
        self._scanned.observe(scanned_rows)
        self._pad_waste.observe(
            (pending.bucket - pending.batch) / pending.bucket)
        self.obs.event(
            "request", batch=pending.batch, bucket=pending.bucket,
            k=pending.k, nprobe=pending.nprobe, latency_ms=latency_ms,
            scanned_rows=scanned_rows, lut_hits=pending.lut_hits,
            lut_misses=pending.lut_misses,
            compiled=(self._counters["compiles"].value
                      > pending.compiled_before))

        if self.probe is not None and not self._in_probe:
            self._in_probe = True
            try:
                self.probe.maybe_run(
                    lambda pq: self.search(pq, k=self.probe.k))
            finally:
                self._in_probe = False
        return res

    def search(self, Q: jax.Array, *, k: int | None = None,
               nprobe: int | None = None) -> SearchResult:
        """Serve one (b, n) query batch (any b ≥ 1) at top-``k`` —
        ``collect(submit(...))``, chunking batches beyond ``max_bucket``."""
        b = Q.shape[0]
        if b == 0:
            raise ValueError("empty query batch")
        if b > self.max_bucket:  # chunk oversized requests
            parts = [self.collect(self.submit(Q[i:i + self.max_bucket],
                                              k=k, nprobe=nprobe))
                     for i in range(0, b, self.max_bucket)]
            return SearchResult(
                scores=jnp.concatenate([p.scores for p in parts]),
                ids=jnp.concatenate([p.ids for p in parts]),
                scanned=jnp.concatenate([p.scanned for p in parts]))
        return self.collect(self.submit(Q, k=k, nprobe=nprobe))

    # -- live rotation refresh --------------------------------------------
    def refresh(self, delta: rotations.RotationDelta) -> None:
        """Absorb a rotation-learner step between batches. Cached LUTs are
        invalidated (they depend on R) — UNLESS the backend proves them
        exactly invariant across this delta (fused refresh + purely
        within-subspace rotations: ``luts_refresh_invariant``), in which
        case the whole cache and its epoch survive. Compiled executables
        survive either way (the state pytree's structure and statics are
        refresh-invariant)."""
        R = self._live_rot()
        if R is not None:
            n = int(R.shape[-1])
            pi = getattr(delta, "pi", None)
            if pi is not None and pi.size and int(
                    jnp.maximum(pi.max(), delta.pj.max())) >= n:
                # out-of-range pair indices would one-hot to zero rows and
                # silently corrupt R — a trainer/index dimension mismatch
                raise ValueError(
                    f"refresh: delta rotates pairs up to index "
                    f"{int(jnp.maximum(pi.max(), delta.pj.max()))} but the "
                    f"live rotation is {n}x{n} — the trainer's manifold "
                    f"leaf and this index have different dimensions")
            dR = getattr(delta, "dR", None)
            if dR is not None and dR.shape[-1] != n:
                raise ValueError(
                    f"refresh: dense delta is {dR.shape[-1]}x"
                    f"{dR.shape[-1]} but the live rotation is {n}x{n}")
        keep = (hasattr(self.searcher, "luts_refresh_invariant")
                and self.searcher.luts_refresh_invariant(self.state, delta))
        with self.obs.span("engine.refresh") as sp:
            self.state = self.searcher.refresh(self.state, delta)
            sp.sync(self.state)
        if not keep:
            self._luts.clear()
            self._epoch += 1
            self._counters["lut_invalidations"].inc()
        self._counters["refreshes"].inc()
        if obs.enabled():
            # refresh health (delta norm + orthogonality drift) on the
            # global registry — a host sync on the (n, n) rotation, so only
            # when someone is watching
            from repro.index import maintain

            # the LIVE rotation lives at state.rot (fused quantized modes —
            # state.R / state.index.R are frozen at R₀ there), else state.R
            # (exact/flat/sharded) or state.index.R (the replicated ivf
            # backend wraps an IVFPQIndex)
            R = self._live_rot()
            if R is not None:
                maintain.refresh_health(R, delta)

    def _live_rot(self):
        """The live rotation the current backend scores through (see the
        per-backend comment in ``refresh``), or None."""
        R = getattr(self.state, "rot", None)
        if R is None:
            R = getattr(self.state, "R", None)
        if R is None:
            R = getattr(getattr(self.state, "index", None), "R", None)
        return R

    # -- observability -----------------------------------------------------
    @property
    def requests(self) -> list[dict]:
        """Compat view: the retained per-request records (newest last, at
        most ``history``), reconstructed from the registry's event window."""
        return [{k: v for k, v in rec.items() if k not in ("kind", "t")}
                for rec in self.obs.events("request")]

    def stats(self) -> dict:
        """Aggregate serving stats + the backend's static facts.

        Two scopes, in one place: **lifetime totals** — every counter key
        (``requests``, ``queries``, ``compiles``, ``executables``,
        ``refreshes``, ``lut_hits``, ``lut_misses``, and the
        ``lut_hit_rate`` derived from them) counts since Engine
        construction and never resets. **Window-scoped** — every latency /
        scanned-rows / pad-waste aggregate (mean, p50, p95, p99, max)
        covers only the retained request window: the last
        ``window["size"]`` requests, bounded by ``window["capacity"]``
        (the ``history`` constructor arg). The ``window`` dict makes the
        scope machine-readable so dashboards don't have to guess."""
        lat = self._latency.summary()
        c = {name: m.value for name, m in self._counters.items()}
        looked = c["lut_hits"] + c["lut_misses"]
        out = dict(
            requests=c["requests"],
            queries=c["queries"],
            compiles=c["compiles"],
            executables=len(self._compiled),
            refreshes=c["refreshes"],
            lut_hits=c["lut_hits"],
            lut_misses=c["lut_misses"],
            lut_hit_rate=(c["lut_hits"] / looked if looked else 0.0),
            lut_cached_rows=len(self._luts),
            lut_evictions=c["lut_evictions"],
            lut_invalidations=c["lut_invalidations"],
            lut_epoch=self._epoch,
            window=dict(size=lat.get("window", 0),
                        capacity=self.history,
                        scope="latency/scanned/pad aggregates"),
            window_requests=lat.get("window", 0),
            latency_ms_mean=lat.get("mean", 0.0),
            latency_ms_p50=lat.get("p50", 0.0),
            latency_ms_p95=lat.get("p95", 0.0),
            latency_ms_p99=lat.get("p99", 0.0),
            latency_ms_max=(max(self._latency.window_values())
                            if lat.get("window") else 0.0),
            scanned_rows_mean=self._scanned.summary().get("mean", 0.0),
            pad_waste_mean=self._pad_waste.summary().get("mean", 0.0),
            searcher=self.searcher.stats(self.state),
        )
        if self.probe is not None:
            out["recall_probe"] = dict(k=self.probe.k,
                                       recall=self.probe.last,
                                       every=self.probe.every)
        out["churn"] = self._churn_stats()
        return out

    def _churn_stats(self) -> dict:
        """The live-churn block of ``stats()``: read off this Engine's own
        registry, where an attached ``churn.ChurnController`` records its
        counters/gauges/spans. Always present (all-zero without a
        controller) so dashboards have a stable schema; same two scopes as
        above — counters are lifetime, ``flush_ms`` aggregates cover the
        retained window described by the ``window`` dict."""
        flush_ms = self.obs.distribution("churn.flush_ms")
        summ = flush_ms.summary()
        return dict(
            staged_rows=self.obs.gauge("churn.staged_rows").value,
            tombstoned_rows=self.obs.gauge("churn.tombstoned_rows").value,
            staged=self.obs.counter("churn.staged").value,
            flushed=self.obs.counter("churn.flushed").value,
            tombstoned=self.obs.counter("churn.tombstoned").value,
            flushes=self.obs.counter("churn.flushes").value,
            compactions=self.obs.counter("churn.compactions").value,
            rebalances=self.obs.counter("churn.rebalances").value,
            grows=self.obs.counter("churn.grows").value,
            flush_ms_p95=flush_ms.percentile(95.0),
            # background compaction (BackgroundCompactor; zero without one)
            bg_submitted=self.obs.counter("churn.bg_submitted").value,
            bg_compactions=self.obs.counter("churn.bg_compactions").value,
            bg_discarded=self.obs.counter("churn.bg_discarded").value,
            flushes_deferred=self.obs.counter("churn.flushes_deferred").value,
            reencoded=self.obs.counter("churn.reencoded").value,
            compact_hidden_ms_total=self.obs.distribution(
                "churn.compact_hidden_ms").total,
            window=dict(size=summ.get("window", 0),
                        capacity=self.history,
                        scope="flush_ms aggregates"),
        )
