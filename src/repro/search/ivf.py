"""IVF searcher: probe top-``nprobe`` lists, scan only their tiles.

The production backend — wraps the ``index/search.py`` probe/scan pipeline
(coarse probe → per-query ADC LUT → fused Pallas selected-block scan →
masked top-k) behind the Searcher protocol. Scan work per query is
≈ ``nprobe/num_lists`` of the corpus; ``nprobe`` is the only serving knob
and can be overridden per call (the Engine keys its compile cache on it).

Shares ``ADCState`` with the ``flat_adc`` backend: ``attach`` the same
index to both and ``nprobe = num_lists`` reproduces the flat scan exactly
(the registry's internal consistency check). ``refresh`` absorbs a
disjoint GivensDelta via ``maintain.refresh_delta`` — centroids, codebooks
and R rotate in O(n²); codes and the CSR layout (hence ``max_blocks`` and
every compiled executable) are untouched.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax

from repro import rotations
from repro.churn import buffer as churn_buffer
from repro.index import ivf as index_ivf
from repro.index import search as index_search
from repro.index.ivf import IVFPQIndex
from repro.search import flat
from repro.search.base import SearchConfig, SearchResult
from repro.search.flat import ADCState, _adc_stats, _refresh


@dataclasses.dataclass(frozen=True)
class IVF:
    """Registry backend ``"ivf"`` (see module docstring)."""

    name: ClassVar[str] = "ivf"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ADCState:
        index = index_ivf.build(key, corpus, R, cfg.ivf_config(),
                                train_size=cfg.train_size)
        return self.attach(index, nprobe=cfg.nprobe,
                           use_kernel=cfg.use_kernel,
                           lut_dtype=cfg.lut_dtype,
                           fused_refresh=cfg.fused_refresh)

    @staticmethod
    def attach(index: IVFPQIndex, *, nprobe: int = 8,
               use_kernel: bool = False, lut_dtype: str = "float32",
               fused_refresh: bool = False) -> ADCState:
        """State over an existing index (captures the static probe window)."""
        state = ADCState(index=index,
                         nprobe=min(nprobe, index.num_lists),
                         max_blocks=index.max_list_blocks(),
                         use_kernel=use_kernel, lut_dtype=lut_dtype)
        return flat._fused_state(state) if fused_refresh else state

    def effective_nprobe(self, state: ADCState, nprobe: int | None) -> int:
        """The probe width actually served: the request's (or the state's
        default), capped at num_lists. Also an Engine capability — the
        compile cache keys on the clamped value so oversized requests
        share one executable."""
        return min(state.nprobe if nprobe is None else nprobe,
                   state.index.num_lists)

    @staticmethod
    def _max_blocks(state: ADCState) -> int:
        """The static probe window: baked by ``attach``, or derived from the
        index (one host sync) for a directly-constructed state."""
        if state.max_blocks >= 1:
            return state.max_blocks
        return state.index.max_list_blocks()

    def prepare_state(self, state: ADCState) -> ADCState:
        """Bake derived statics into the state so it can be passed as a
        *traced* jit argument (the Engine does this once up front — the
        ``max_blocks`` fallback host-syncs on concrete offsets, which a
        tracer cannot satisfy)."""
        if state.max_blocks >= 1:
            return state
        return dataclasses.replace(
            state, max_blocks=state.index.max_list_blocks())

    def search(self, state: ADCState, Q: jax.Array, *, k: int = 10,
               nprobe: int | None = None) -> SearchResult:
        if state.qdelta is not None or state.staging is not None:
            # fused mode (LUT build must route through the accumulated
            # query-side transform) and live churn (staged rows merge after
            # the main scan) both go via the prepared path
            QR = flat._rotate_queries(state, Q)
            return self.search_prepared(state, QR, flat._luts(state, QR),
                                        k=k, nprobe=nprobe)
        return index_search.search_fixed(
            state.index, Q, nprobe=self.effective_nprobe(state, nprobe), k=k,
            max_blocks=self._max_blocks(state), use_kernel=state.use_kernel,
            lut_dtype=state.lut_dtype)

    # -- Engine LUT-cache capabilities -------------------------------------
    def rotate_queries(self, state: ADCState, Q: jax.Array) -> jax.Array:
        return flat._rotate_queries(state, Q)

    def luts(self, state: ADCState, QR: jax.Array):
        return flat._luts(state, QR)

    def luts_refresh_invariant(self, state: ADCState,
                               delta: rotations.RotationDelta) -> bool:
        return flat._luts_refresh_invariant(state, delta)

    def search_prepared(self, state: ADCState, QR: jax.Array,
                        lut, *, k: int = 10,
                        nprobe: int | None = None) -> SearchResult:
        res = index_search.search_prepared(
            state.index, QR, lut, nprobe=self.effective_nprobe(state, nprobe),
            k=k, max_blocks=self._max_blocks(state),
            use_kernel=state.use_kernel)
        if state.staging is not None:
            # live churn: staged rows ride a flat-ADC side pass over the
            # same LUT pack and merge into the probed top-k
            res = churn_buffer.merge_staged(
                res, state.staging, QR, lut, state.index.centroids, k,
                use_kernel=state.use_kernel)
        return res

    def refresh(self, state: ADCState,
                delta: rotations.RotationDelta) -> ADCState:
        return _refresh(state, delta)

    def stats(self, state: ADCState) -> dict:
        st = _adc_stats(self.name, state)
        mb = self._max_blocks(state)
        st["nprobe"] = state.nprobe
        st["max_blocks"] = mb
        st["scan_rows_per_query"] = min(
            state.nprobe * mb * state.index.block_size, st["capacity"])
        return st
