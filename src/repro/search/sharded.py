"""Row-sharded searcher family: the corpus partitioned over the device mesh.

The replicated backends cap corpus size at one chip's HBM and throughput at
one chip's bandwidth. This module is the distributed half of the registry —
every replicated backend gets a ``*_sharded`` twin that keeps the paper's
serving transform and the SearchResult contract while the corpus lives
partitioned over the mesh's "data" axis end to end (the GPU-scale ANN
recipe of Wieschollek et al.: partition the database, search partitions in
parallel, merge per-partition top-k):

  exact_sharded    per-shard tiled brute-force scan over local rows
  flat_sharded     per-shard flat ADC scan over the local CSR codes
  ivf_sharded      per-shard probe + fused selected-block scan — every
                   device probes the same top-``nprobe`` lists of the
                   SHARED coarse quantizer but scans only its local lists

All three run the existing single-device scan as the shard-local body of a
``compat.shard_map``: per-shard arrays (rotated corpus / CSR codes, ids,
list offsets) are stacked on a leading shard axis and partitioned with the
``ivf_sharded`` rule table (sharding/rules.py — corpus rows over
("pod", "data")), while R, the coarse centroids, and the residual
codebooks stay replicated (O(n²) vs O(N) state). Each shard emits a padded
local top-k honoring the −inf/−1 contract — including when k exceeds its
local pool — and the static-shape merge is an ``all_gather`` of the
(b, k) runs + re-top-k (``kernels.ops.topk_merge``), so the collective
payload is O(b·k·shards), independent of corpus size.

Parity: built (or ``attach``-ed) from the same artifacts, a sharded
backend returns bit-identical scores to its replicated twin — per-row
scores are computed by the same kernel on the same codes, and the merge
only reorders candidates (tests/test_distributed.py pins all three on an
8-fake-device mesh). ``refresh`` broadcasts the (small, replicated)
RotationDelta and updates R/coarse/codebooks in place — per-shard CSR
state, pytree structure, and statics are untouched, so a live rotation
refresh costs zero recompiles and zero cross-device traffic
(``maintain.rotate_components``).

The registry serves them like any other backend::

    mesh = launch.mesh.make_data_mesh()            # ("data",) over all devices
    searcher = search.make("ivf_sharded", mesh=mesh)
    state = searcher.build(key, corpus, R, cfg)    # corpus rows partitioned
    engine = search.Engine(searcher, state, k=10, nprobe=16)

and ``search.Engine`` needs no changes: the LUT cache keys on replicated
quantities, the compile cache on (bucket, k, nprobe), and chunked/ragged
batches flow through the shard_map'd executables unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat, obs, quant, rotations
from repro.churn import buffer as churn_buffer
from repro.index import ivf as index_ivf
from repro.index import maintain
from repro.index import search as index_search
from repro.index.ivf import IVFPQIndex
from repro.kernels import ops as kops
from repro.search import exact as exact_mod
from repro.search import flat as flat_mod
from repro.search.base import SearchConfig, SearchResult, topk_padded
from repro.sharding import rules as sh


AxisSpec = str | tuple[str, ...]


def resolve_mesh(mesh: Mesh | None = None,
                 axis: AxisSpec = "auto") -> Mesh:
    """The serving mesh: an explicit one, else the ambient mesh context (if
    it has a shard axis), else a fresh 1-axis mesh over every device.

    The ambient mesh must be a concrete ``Mesh`` — shard placement needs
    real devices, and new JAX's ``use_mesh`` context reports an
    AbstractMesh (no device list), which cannot place index shards.
    """
    if mesh is not None:
        return mesh
    ambient = compat.current_mesh()
    if (isinstance(ambient, Mesh)
            and getattr(ambient, "devices", None) is not None):
        try:
            resolve_axes(ambient, axis)
            return ambient
        except ValueError:
            pass
    from repro.launch.mesh import make_data_mesh

    return make_data_mesh()


def resolve_axes(mesh: Mesh, axis: AxisSpec = "auto") -> tuple[str, ...]:
    """The mesh axes the corpus rows shard over.

    ``"auto"`` takes the row-sharded rule table's axes present on this
    mesh (``IVF_SHARDED_RULES["ivf_rows"] == ("pod", "data")`` → both on a
    multi-pod mesh, just ``("data",)`` on a data-only one), so the shard
    count is the FULL product of the row axes — a (2, 16) pod×data mesh
    shards 32 ways, it does not silently replicate over "pod"."""
    if axis == "auto" or axis is None:
        rule = sh.IVF_SHARDED_RULES["ivf_rows"]
        kept = tuple(a for a in rule if a in mesh.shape)
        if not kept:
            raise ValueError(
                f"mesh {dict(mesh.shape)} has none of the row-shard axes "
                f"{rule}")
        return kept
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    for a in axes:
        if a not in mesh.shape:
            raise ValueError(
                f"mesh {dict(mesh.shape)} has no {a!r} axis to shard over")
    return axes


def _num_shards(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _place_sharded(arr: jax.Array, mesh: Mesh,
                   axes: tuple[str, ...]) -> jax.Array:
    """Partition a stacked (S, ...) per-shard array over the mesh: leading
    (shard) axis over the resolved row axes — the placement half of the
    ``ivf_sharded`` rule table, with S = the axis-size product by
    construction so the spec never silently drops to replication."""
    spec = P(axes if len(axes) > 1 else axes[0],
             *([None] * (arr.ndim - 1)))
    return jax.device_put(arr, NamedSharding(mesh, spec))


def _replicated_specs(tree) -> object:
    """A matching tree of replicated PartitionSpecs for a pytree argument."""
    return jax.tree.map(lambda _: P(), tree)


def _shard_spec(axes: tuple[str, ...]) -> P:
    """in_spec for a stacked (S, ...) per-shard array: leading dim over the
    resolved row axes."""
    return P(axes if len(axes) > 1 else axes[0])


def _merge_local_topk(scores: jax.Array, ids: jax.Array, k: int,
                      axes: tuple[str, ...]) -> tuple[jax.Array, jax.Array]:
    """Inside shard_map: concatenate every shard's padded (b, k) run and
    re-top-k. Static shapes — (b, S·k) — whatever the per-shard pools.
    The ``jax.named_scope`` labels the gather+merge stage in the HLO, so an
    XLA profile (``obs.Registry.trace``) separates collective time from
    scan time at zero runtime cost."""
    with jax.named_scope("obs.gather_merge"):
        g_scores = jax.lax.all_gather(scores, axes, axis=1, tiled=True)
        g_ids = jax.lax.all_gather(ids, axes, axis=1, tiled=True)
        return kops.topk_merge(g_scores, g_ids, k)


def _record_shard_gauges(backend: str, ids: np.ndarray) -> None:
    """Per-shard row gauges + the imbalance gauge on the global registry
    (``ids`` is the stacked (S, rows_s) id array, −1 = hole/padding). Host
    data is already in hand at build/attach time, so this costs nothing on
    the query path; gated on ``obs.enabled()`` by the callers."""
    reg = obs.default_registry()
    rows = (np.asarray(ids) >= 0).sum(axis=1)
    for s, r in enumerate(rows.tolist()):
        reg.gauge("index.shard_rows", backend=backend, shard=s).set(r)
    imbalance = float(rows.max()) / max(float(rows.mean()), 1.0)
    reg.gauge("index.shard_imbalance", backend=backend).set(imbalance)
    reg.event("shard_layout", backend=backend, shards=int(rows.size),
              rows=[int(r) for r in rows], imbalance=imbalance)


def _shard_rows_stats(ids: np.ndarray) -> dict:
    """The per-shard occupancy facts every sharded ``stats()`` reports."""
    rows = (np.asarray(ids) >= 0).sum(axis=1)
    return dict(
        rows_per_shard=[int(r) for r in rows],
        shard_imbalance=float(rows.max()) / max(float(rows.mean()), 1.0),
    )


# ---------------------------------------------------------------------------
# exact_sharded
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedExactState:
    """Rotated corpus stacked per shard; R replicated. ``mesh``/``axes``
    are static aux data, so jit specializes per mesh layout and a refresh
    (same shapes, same statics) never invalidates a compiled executable."""

    R: jax.Array        # (n, n) serving rotation, replicated
    XR: jax.Array       # (S, rows_s, n) rotated corpus, zero-padded
    ids: jax.Array      # (S, rows_s) int32 global item ids, −1 = padding
    mesh: Mesh = dataclasses.field(metadata={"static": True})
    tile_rows: int = dataclasses.field(default=4096,
                                       metadata={"static": True})
    axes: tuple[str, ...] = dataclasses.field(default=("data",),
                                              metadata={"static": True})
    R0: jax.Array | None = None  # frozen build rotation (fused refresh)


@functools.partial(jax.jit, static_argnames=("k",))
def _exact_sharded_search(state: ShardedExactState, Q: jax.Array,
                          k: int) -> SearchResult:
    axes = state.axes
    # fused mode scores with the frozen R₀ (delta cancels against the frozen
    # shards — see search/exact.py); resolved here so the shard-local body
    # is mode-agnostic
    Rq = exact_mod._query_rotation(state)

    def local(R, XR_s, ids_s, Q):
        lstate = exact_mod.ExactState(R=R, XR=XR_s[0], ids=ids_s[0],
                                      tile_rows=state.tile_rows)
        with jax.named_scope("obs.shard_scan"):
            res = exact_mod._exact_search_impl(lstate, Q, k)
        scores, ids = _merge_local_topk(res.scores, res.ids, k, axes)
        return SearchResult(scores=scores, ids=ids,
                            scanned=jax.lax.psum(res.scanned, axes))

    f = compat.shard_map(
        local, mesh=state.mesh,
        in_specs=(P(), _shard_spec(axes), _shard_spec(axes), P()),
        out_specs=SearchResult(scores=P(), ids=P(), scanned=P()),
        check_vma=False,
    )
    return f(Rq, state.XR, state.ids, Q)


@dataclasses.dataclass(frozen=True)
class ExactSharded:
    """Registry backend ``"exact_sharded"`` (see module docstring)."""

    name: ClassVar[str] = "exact_sharded"
    mesh: Mesh | None = None
    axis: AxisSpec = "auto"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ShardedExactState:
        del key  # deterministic build
        mesh = resolve_mesh(self.mesh, self.axis)
        axes = resolve_axes(mesh, self.axis)
        S = _num_shards(mesh, axes)
        R = jnp.asarray(R)
        XR = jnp.asarray(corpus) @ R.astype(corpus.dtype)
        n_rows, n = XR.shape
        rows_s = max(-(-n_rows // S), 1)
        tile = max(1, min(cfg.tile_rows, rows_s))
        rows_s = -(-rows_s // tile) * tile          # whole tiles per shard
        pad = rows_s * S - n_rows
        ids = jnp.concatenate([
            jnp.arange(n_rows, dtype=jnp.int32),
            jnp.full((pad,), -1, jnp.int32),
        ]).reshape(S, rows_s)
        XR = jnp.pad(XR, ((0, pad), (0, 0))).reshape(S, rows_s, n)
        if obs.enabled():
            _record_shard_gauges(self.name, np.asarray(ids))
        return ShardedExactState(
            R=R, XR=_place_sharded(XR, mesh, axes),
            ids=_place_sharded(ids, mesh, axes),
            mesh=mesh, tile_rows=tile, axes=axes,
            R0=R if cfg.fused_refresh else None)

    def search(self, state: ShardedExactState, Q: jax.Array, *,
               k: int = 10) -> SearchResult:
        return _exact_sharded_search(state, Q, k)

    def refresh(self, state: ShardedExactState,
                delta: rotations.RotationDelta) -> ShardedExactState:
        if state.R0 is not None:
            # fused: the frozen shards cancel the delta exactly — no
            # cross-device XR re-materialization, only R tracks the trainer
            return dataclasses.replace(
                state, R=rotations.apply(state.R, delta))
        return dataclasses.replace(
            state,
            R=rotations.apply(state.R, delta),
            XR=rotations.apply(state.XR, delta),
        )

    def stats(self, state: ShardedExactState) -> dict:
        ids = np.asarray(state.ids)
        rows = int(np.sum(ids >= 0))
        S = ids.shape[0]
        return dict(
            backend=self.name,
            rows=rows,
            capacity=int(ids.size),
            dim=int(state.XR.shape[-1]),
            shards=S,
            tile_rows=state.tile_rows,
            scan_rows_per_query=rows,
            scan_rows_per_query_per_device=rows / S,
            memory_bytes=int(state.XR.size * state.XR.dtype.itemsize),
            memory_bytes_per_device=int(
                state.XR.size * state.XR.dtype.itemsize) // S,
            compression=1.0,
            fused_refresh=state.R0 is not None,
            **_shard_rows_stats(ids),
        )


# ---------------------------------------------------------------------------
# flat_sharded / ivf_sharded — the quantized family over stacked CSR shards
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedADCState:
    """Quantized sharded state: shared quantizers + stacked per-shard CSRs.

    R/coarse/quantizer are the replicated O(n²) components a refresh
    rotates; codes/ids/list_offsets hold one block-aligned CSR per shard
    (padded to a common capacity with hole rows so they stack). ``nprobe``
    and ``max_blocks`` (the MAX over shards' longest lists — every shard
    runs the same program) mirror ``ADCState``'s statics.
    """

    R: jax.Array              # (n, n) replicated
    coarse: quant.VQ          # shared coarse quantizer (L centroids)
    quantizer: quant.Quantizer  # shared residual quantizer
    codes: jax.Array          # (S, cap_s, Dp) per-shard CSR codes
    ids: jax.Array            # (S, cap_s) int32 global ids, −1 = hole
    list_offsets: jax.Array   # (S, L+1) per-shard list offsets
    mesh: Mesh = dataclasses.field(metadata={"static": True})
    block_size: int = dataclasses.field(default=128,
                                        metadata={"static": True})
    nprobe: int = dataclasses.field(default=8, metadata={"static": True})
    max_blocks: int = dataclasses.field(default=-1,
                                        metadata={"static": True})
    use_kernel: bool = dataclasses.field(default=False,
                                         metadata={"static": True})
    axes: tuple[str, ...] = dataclasses.field(default=("data",),
                                              metadata={"static": True})
    lut_dtype: str = dataclasses.field(default="float32",
                                       metadata={"static": True})
    rot: jax.Array | None = None     # fused refresh: live rotation R₀·Δ
    wacc: jax.Array | None = None    # fused refresh: within-subspace W
    qdelta: jax.Array | None = None  # fused refresh: query transform Δ·Wᵀ
    # live-churn append buffers, one per shard stacked on the leading axis
    # and partitioned like the CSR; each shard's side pass runs inside the
    # shard_map local body (repro.churn). None until churn.with_staging.
    staging: churn_buffer.StagingBuffer | None = None

    @property
    def num_shards(self) -> int:
        return self.codes.shape[0]

    @property
    def num_lists(self) -> int:
        return self.list_offsets.shape[1] - 1


def _fused_sharded_state(state: ShardedADCState) -> ShardedADCState:
    """Initialize the fused-refresh matrices at the build rotation
    (Δ = W = I: rot = R₀, qdelta = I — mirrors ``flat._fused_state``)."""
    n = state.R.shape[0]
    eye = jnp.eye(n, dtype=state.R.dtype)
    return dataclasses.replace(state, rot=state.R, wacc=eye, qdelta=eye)


def attach_shards(parts: list[IVFPQIndex], *, mesh: Mesh | None = None,
                  axis: AxisSpec = "auto", nprobe: int = 8,
                  use_kernel: bool = False, lut_dtype: str = "float32",
                  fused_refresh: bool = False) -> ShardedADCState:
    """Stack per-shard indexes (``ivf.shard_split`` or ``ivf.build_sharded``
    output) into one servable sharded state.

    All parts must share R / coarse / quantizer / block_size — checked
    below, because serving decodes every shard against shard 0's
    quantizers and a mismatch would be silently wrong, not loud. Shorter
    shards pad to the max capacity with hole rows appended AFTER their
    sentinel block — unreferenced by any offset, id −1, so both the flat
    scan (masked) and the probe scan (never scheduled) ignore them.

    Assembly is host-side (one stacked array per field before placement),
    so the attach step needs the whole index in host memory even though
    serving state is partitioned — fine up to host RAM (codes are the
    compressed 2 B-ish/row payload, not the f32 corpus). Past that, feed
    per-shard chunks through ``ivf.build_sharded`` so no step ever holds
    more than a chunk, and on a real multi-host pod attach per-host
    shard lists (single-host process assumption here matches the repo's
    forced-host-device test rig).
    """
    mesh = resolve_mesh(mesh, axis)
    axes = resolve_axes(mesh, axis)
    S = _num_shards(mesh, axes)
    if len(parts) != S:
        raise ValueError(
            f"{len(parts)} index shards for a {S}-way {axes!r} mesh axis")
    head = parts[0]
    # the shared components must be IDENTICAL across shards — serving
    # decodes every shard's codes against shard 0's quantizers, so a list
    # of independently-fit per-chunk indexes would return well-formed but
    # silently wrong scores. Fail loudly instead (use ``shard_split`` or
    # ``build_sharded``, which share one fit by construction).
    for i, p in enumerate(parts[1:], start=1):
        if (p.block_size != head.block_size
                or not np.array_equal(np.asarray(p.R), np.asarray(head.R))
                or not np.array_equal(np.asarray(p.coarse.centroids),
                                      np.asarray(head.coarse.centroids))
                or not np.array_equal(np.asarray(p.quantizer.codebooks),
                                      np.asarray(head.quantizer.codebooks))):
            raise ValueError(
                f"index shard {i} does not share shard 0's R/coarse/"
                "quantizer/block_size — sharded serving requires one fit "
                "across all shards (ivf.shard_split / ivf.build_sharded)")
    cap = max(p.capacity for p in parts)
    codes, ids = [], []
    for p in parts:
        extra = cap - p.capacity
        codes.append(np.pad(np.asarray(p.codes), ((0, extra), (0, 0))))
        ids.append(np.pad(np.asarray(p.ids), (0, extra),
                          constant_values=-1))
    if obs.enabled():
        # one ShardedADCState serves both flat_sharded and ivf_sharded
        _record_shard_gauges("adc_sharded", np.stack(ids))
    state = ShardedADCState(
        R=head.R, coarse=head.coarse, quantizer=head.quantizer,
        codes=_place_sharded(jnp.asarray(np.stack(codes)), mesh, axes),
        ids=_place_sharded(jnp.asarray(np.stack(ids)), mesh, axes),
        list_offsets=_place_sharded(
            jnp.asarray(np.stack([np.asarray(p.list_offsets)
                                  for p in parts])), mesh, axes),
        mesh=mesh, block_size=head.block_size,
        nprobe=min(nprobe, head.num_lists),
        max_blocks=max(max(p.max_list_blocks() for p in parts), 1),
        use_kernel=use_kernel, axes=axes, lut_dtype=lut_dtype,
    )
    return _fused_sharded_state(state) if fused_refresh else state


def _local_index(R, coarse, quantizer, codes_s, ids_s, offs_s,
                 block_size: int) -> IVFPQIndex:
    """This shard's single-device index view (inside shard_map: the leading
    shard axis arrives as a size-1 block)."""
    return IVFPQIndex(R=R, coarse=coarse, quantizer=quantizer,
                      codes=codes_s[0], ids=ids_s[0],
                      list_offsets=offs_s[0], block_size=block_size)


def _sharded_scan(state: ShardedADCState, QR: jax.Array, lut,
                  local_body):
    """Run ``local_body(local_index, QR, lut) -> SearchResult`` on every
    shard and merge (body already emits a padded local top-k). With a
    staging buffer attached, each shard's staged rows ride its local
    flat-ADC side pass and fold into its run before the cross-shard merge
    — staged rows never cross devices."""
    axes = state.axes
    stg = state.staging
    extra = () if stg is None else (stg.codes, stg.ids, stg.lists)
    extra_specs = () if stg is None else (_shard_spec(axes),) * 3

    def local(R, coarse, quantizer, codes, ids, offs, QR, lut, *stg_parts):
        idx = _local_index(R, coarse, quantizer, codes, ids, offs,
                           state.block_size)
        with jax.named_scope("obs.shard_scan"):
            res = local_body(idx, QR, lut)
            if stg_parts:
                buf = churn_buffer.StagingBuffer(
                    codes=stg_parts[0][0], ids=stg_parts[1][0],
                    lists=stg_parts[2][0])
                res = churn_buffer.merge_staged(
                    res, buf, QR, lut, coarse.centroids,
                    res.scores.shape[1], use_kernel=state.use_kernel)
        scores, out_ids = _merge_local_topk(
            res.scores, res.ids, res.scores.shape[1], axes)
        return SearchResult(scores=scores, ids=out_ids,
                            scanned=jax.lax.psum(res.scanned, axes))

    f = compat.shard_map(
        local, mesh=state.mesh,
        in_specs=(P(), _replicated_specs(state.coarse),
                  _replicated_specs(state.quantizer),
                  _shard_spec(axes), _shard_spec(axes), _shard_spec(axes),
                  P(), _replicated_specs(lut), *extra_specs),
        out_specs=SearchResult(scores=P(), ids=P(), scanned=P()),
        check_vma=False,
    )
    return f(state.R, state.coarse, state.quantizer, state.codes, state.ids,
             state.list_offsets, QR, lut, *extra)


def _flat_local_body(k: int, use_kernel: bool):
    def body(idx: IVFPQIndex, QR, lut) -> SearchResult:
        scores, cand_ids = index_search.flat_adc_prepared(
            idx, QR, lut, use_kernel=use_kernel)
        top_scores, top_ids = topk_padded(scores, cand_ids, k)
        scanned = jnp.full((QR.shape[0],), idx.capacity, jnp.int32)
        return SearchResult(scores=top_scores, ids=top_ids, scanned=scanned)

    return body


def _ivf_local_body(k: int, nprobe: int, max_blocks: int, use_kernel: bool):
    def body(idx: IVFPQIndex, QR, lut) -> SearchResult:
        # every shard probes the same lists of the shared coarse quantizer
        # (the probe is replicated work, O(b·L)) but scans only its local
        # CSR blocks — the O(rows) term is what divides by the shard count
        return index_search._search_core(
            idx, QR, lut, nprobe=nprobe, k=k, max_blocks=max_blocks,
            use_kernel=use_kernel)

    return body


@functools.partial(jax.jit, static_argnames=("k",))
def _flat_sharded_prepared(state: ShardedADCState, QR: jax.Array,
                           lut, k: int) -> SearchResult:
    return _sharded_scan(state, QR, lut,
                         _flat_local_body(k, state.use_kernel))


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _ivf_sharded_prepared(state: ShardedADCState, QR: jax.Array,
                          lut, k: int,
                          nprobe: int) -> SearchResult:
    return _sharded_scan(
        state, QR, lut,
        _ivf_local_body(k, nprobe, state.max_blocks, state.use_kernel))


def _sharded_refresh(state: ShardedADCState,
                     delta: rotations.RotationDelta) -> ShardedADCState:
    """Broadcast the (small, replicated) delta: rotate R/coarse/codebooks
    in place, leave every shard's CSR untouched — structure and statics are
    refresh-invariant, so compiled executables survive. In fused mode even
    R/coarse/codebooks are frozen and only the three query-side matrices
    advance (see ``flat._fused_refresh_mats``)."""
    maintain.check_refreshable(delta)
    if state.rot is not None:
        rot, wacc, qdelta = flat_mod._fused_refresh_mats(
            state.R, state.rot, state.wacc,
            delta.pi, delta.pj, delta.theta, state.quantizer.sub)
        return dataclasses.replace(state, rot=rot, wacc=wacc, qdelta=qdelta)
    R, coarse, quantizer = maintain.rotate_components(
        state.R, state.coarse, state.quantizer,
        delta.pi, delta.pj, delta.theta)
    return dataclasses.replace(state, R=R, coarse=coarse,
                               quantizer=quantizer)


def _sharded_luts_refresh_invariant(state: ShardedADCState,
                                    delta: rotations.RotationDelta) -> bool:
    """Sharded twin of ``flat._luts_refresh_invariant`` — same criterion
    (fused mode + purely within-subspace disjoint GivensDelta), reading the
    shared quantizer directly off the sharded state."""
    if state.rot is None:
        return False
    if not isinstance(delta, rotations.GivensDelta) or delta.overlapping:
        return False
    sub = state.quantizer.sub
    pi = np.asarray(delta.pi)
    pj = np.asarray(delta.pj)
    return bool(np.all((pi // sub) == (pj // sub)))


def _sharded_adc_stats(name: str, state: ShardedADCState) -> dict:
    ids = np.asarray(state.ids)
    live = int(np.sum(ids >= 0))
    S = state.num_shards
    code_bytes = int(state.codes.shape[-1] * state.codes.dtype.itemsize)
    mem = int(state.codes.size * state.codes.dtype.itemsize)
    return dict(
        backend=name,
        rows=live,
        capacity=int(ids.size),
        dim=int(state.coarse.dim),
        shards=S,
        num_lists=state.num_lists,
        code_bytes_per_row=code_bytes,
        compression=float(state.coarse.dim * 4 / code_bytes),
        memory_bytes=mem,
        memory_bytes_per_device=mem // S,
        use_kernel=state.use_kernel,
        lut_dtype=state.lut_dtype,
        fused_refresh=state.rot is not None,
        **_shard_rows_stats(ids),
    )


def _shard_existing(index: IVFPQIndex, mesh: Mesh | None, axis: AxisSpec, *,
                    nprobe: int, use_kernel: bool,
                    lut_dtype: str = "float32",
                    fused_refresh: bool = False) -> ShardedADCState:
    mesh = resolve_mesh(mesh, axis)
    axes = resolve_axes(mesh, axis)
    parts = index_ivf.shard_split(index, _num_shards(mesh, axes))
    return attach_shards(parts, mesh=mesh, axis=axes, nprobe=nprobe,
                         use_kernel=use_kernel, lut_dtype=lut_dtype,
                         fused_refresh=fused_refresh)


# Engine LUT-cache capabilities, shared by both sharded ADC backends (the
# replicated pair shares these the same way — see flat.py):
def _rotate_queries(state: ShardedADCState, Q: jax.Array) -> jax.Array:
    # fused mode freezes R at R₀ and the coarse term is exactly invariant,
    # so Q @ state.R is the correct query rotation in both modes
    return Q @ state.R


def _luts(state: ShardedADCState, QR: jax.Array):
    """Per-query ADC LUT pack over the shared residual quantizer — fused
    LUT-build and integer quantization mirror ``flat._luts``; the pack is
    replicated, so the shard_map in_specs tree-map over it."""
    if state.qdelta is not None:
        cb_flat, colmap = state.quantizer.lut_operands()
        lut = kops.fused_lut(QR, state.qdelta, cb_flat, colmap,
                             use_kernel=state.use_kernel)
    else:
        lut = state.quantizer.adc_tables(QR)
    if state.lut_dtype != "float32":
        return kops.quantize_luts(lut, state.lut_dtype)
    return lut


@dataclasses.dataclass(frozen=True)
class FlatSharded:
    """Registry backend ``"flat_sharded"`` (see module docstring)."""

    name: ClassVar[str] = "flat_sharded"
    mesh: Mesh | None = None
    axis: AxisSpec = "auto"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ShardedADCState:
        index = index_ivf.build(key, corpus, R, cfg.ivf_config(),
                                train_size=cfg.train_size)
        return self.attach(index, mesh=self.mesh, axis=self.axis,
                           use_kernel=cfg.use_kernel,
                           lut_dtype=cfg.lut_dtype,
                           fused_refresh=cfg.fused_refresh)

    @staticmethod
    def attach(index: IVFPQIndex, *, mesh: Mesh | None = None,
               axis: AxisSpec = "auto", nprobe: int = 8,
               use_kernel: bool = False, lut_dtype: str = "float32",
               fused_refresh: bool = False) -> ShardedADCState:
        """Shard an existing replicated index across the mesh — the very
        codes the single-device backends serve, redistributed (the parity
        and migration entry point)."""
        return _shard_existing(index, mesh, axis, nprobe=nprobe,
                               use_kernel=use_kernel, lut_dtype=lut_dtype,
                               fused_refresh=fused_refresh)

    def search(self, state: ShardedADCState, Q: jax.Array, *,
               k: int = 10) -> SearchResult:
        QR = _rotate_queries(state, Q)
        return _flat_sharded_prepared(state, QR, _luts(state, QR), k)

    # -- Engine LUT-cache capabilities -------------------------------------
    def rotate_queries(self, state: ShardedADCState,
                       Q: jax.Array) -> jax.Array:
        return _rotate_queries(state, Q)

    def luts(self, state: ShardedADCState, QR: jax.Array):
        return _luts(state, QR)

    def luts_refresh_invariant(self, state: ShardedADCState,
                               delta: rotations.RotationDelta) -> bool:
        return _sharded_luts_refresh_invariant(state, delta)

    def search_prepared(self, state: ShardedADCState, QR: jax.Array,
                        lut, *, k: int = 10) -> SearchResult:
        return _flat_sharded_prepared(state, QR, lut, k)

    def refresh(self, state: ShardedADCState,
                delta: rotations.RotationDelta) -> ShardedADCState:
        return _sharded_refresh(state, delta)

    def stats(self, state: ShardedADCState) -> dict:
        st = _sharded_adc_stats(self.name, state)
        st["scan_rows_per_query"] = st["capacity"]
        st["scan_rows_per_query_per_device"] = (st["capacity"]
                                                / state.num_shards)
        return st


@dataclasses.dataclass(frozen=True)
class IVFSharded:
    """Registry backend ``"ivf_sharded"`` (see module docstring)."""

    name: ClassVar[str] = "ivf_sharded"
    mesh: Mesh | None = None
    axis: AxisSpec = "auto"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ShardedADCState:
        index = index_ivf.build(key, corpus, R, cfg.ivf_config(),
                                train_size=cfg.train_size)
        return self.attach(index, mesh=self.mesh, axis=self.axis,
                           nprobe=cfg.nprobe, use_kernel=cfg.use_kernel,
                           lut_dtype=cfg.lut_dtype,
                           fused_refresh=cfg.fused_refresh)

    @staticmethod
    def attach(index: IVFPQIndex, *, mesh: Mesh | None = None,
               axis: AxisSpec = "auto", nprobe: int = 8,
               use_kernel: bool = False, lut_dtype: str = "float32",
               fused_refresh: bool = False) -> ShardedADCState:
        """Shard an existing replicated index across the mesh (see
        ``FlatSharded.attach`` — one state serves both sharded ADC
        backends, like ``ADCState`` does for the replicated pair)."""
        return _shard_existing(index, mesh, axis, nprobe=nprobe,
                               use_kernel=use_kernel, lut_dtype=lut_dtype,
                               fused_refresh=fused_refresh)

    def effective_nprobe(self, state: ShardedADCState,
                         nprobe: int | None) -> int:
        """Engine capability: the probe width actually served (clamped at
        the shared coarse quantizer's list count)."""
        return min(state.nprobe if nprobe is None else nprobe,
                   state.num_lists)

    def prepare_state(self, state: ShardedADCState) -> ShardedADCState:
        """Engine capability: bake the probe window for a directly-
        constructed state (``attach_shards`` already did — one host sync
        over the stacked offsets otherwise)."""
        if state.max_blocks >= 1:
            return state
        lens = np.diff(np.asarray(state.list_offsets), axis=1)
        return dataclasses.replace(
            state, max_blocks=max(int(lens.max()) // state.block_size, 1))

    def search(self, state: ShardedADCState, Q: jax.Array, *, k: int = 10,
               nprobe: int | None = None) -> SearchResult:
        state = self.prepare_state(state)
        QR = _rotate_queries(state, Q)
        return _ivf_sharded_prepared(state, QR, _luts(state, QR), k,
                                     self.effective_nprobe(state, nprobe))

    # -- Engine LUT-cache capabilities -------------------------------------
    def rotate_queries(self, state: ShardedADCState,
                       Q: jax.Array) -> jax.Array:
        return _rotate_queries(state, Q)

    def luts(self, state: ShardedADCState, QR: jax.Array):
        return _luts(state, QR)

    def luts_refresh_invariant(self, state: ShardedADCState,
                               delta: rotations.RotationDelta) -> bool:
        return _sharded_luts_refresh_invariant(state, delta)

    def search_prepared(self, state: ShardedADCState, QR: jax.Array,
                        lut, *, k: int = 10,
                        nprobe: int | None = None) -> SearchResult:
        # prepare_state is a no-op on an attach_shards state (max_blocks
        # baked as a STATIC, concrete even under a jit trace); the host
        # sync only fires for a directly-constructed concrete state, same
        # as the replicated twin's _max_blocks fallback
        state = self.prepare_state(state)
        return _ivf_sharded_prepared(state, QR, lut, k,
                                     self.effective_nprobe(state, nprobe))

    def refresh(self, state: ShardedADCState,
                delta: rotations.RotationDelta) -> ShardedADCState:
        return _sharded_refresh(state, delta)

    def stats(self, state: ShardedADCState) -> dict:
        st = _sharded_adc_stats(self.name, state)
        st["nprobe"] = state.nprobe
        st["max_blocks"] = state.max_blocks
        per_shard = min(state.nprobe * state.max_blocks * state.block_size,
                        int(state.codes.shape[1]))
        st["scan_rows_per_query"] = per_shard * state.num_shards
        st["scan_rows_per_query_per_device"] = per_shard
        return st
