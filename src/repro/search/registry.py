"""The searcher registry — single source of truth for retrieval backends.

Before this package the repo's three query paths were three disconnected
idioms: free functions in ``index/search.py`` (IVF), ``flat_adc_scores``
(flat ADC), and hand-rolled ``Q @ corpus.T`` scans duplicated across
examples and benchmarks. Now every retrieval call resolves a spec string:

    search.make("exact")        # tiled brute force — the recall oracle
    search.make("exact_stream") # same oracle, corpus streamed from host RAM
    search.make("flat_adc")     # PQ/RQ full scan via kernels/adc_lookup
    search.make("ivf")          # probe + fused selected-block Pallas scan

plus the row-sharded twins — same transform, same SearchResult contract,
corpus partitioned over the mesh's "data" axis with an all_gather +
re-top-k merge (``search/sharded.py``):

    search.make("exact_sharded", mesh=mesh)
    search.make("flat_sharded", mesh=mesh)
    search.make("ivf_sharded", mesh=mesh)

``names()`` is what benchmarks sweep (``benchmarks/ivf_recall_qps.py``
runs all backends on one harness); aliases keep informal spellings
working without double-counting in sweeps.
"""
from __future__ import annotations

from repro.search import base, exact, flat, ivf, sharded

_REGISTRY: dict[str, type] = {
    "exact": exact.Exact,
    "exact_stream": exact.ExactStreaming,
    "flat_adc": flat.FlatADC,
    "ivf": ivf.IVF,
    "exact_sharded": sharded.ExactSharded,
    "flat_sharded": sharded.FlatSharded,
    "ivf_sharded": sharded.IVFSharded,
}

_ALIASES = {
    "flat": "flat_adc",
    "brute_force": "exact",
    "bruteforce": "exact",
    "exact_streaming": "exact_stream",
    "streaming": "exact_stream",
    "flat_adc_sharded": "flat_sharded",
    "sharded": "ivf_sharded",
}


def names() -> tuple[str, ...]:
    """Canonical registered backends — what benchmarks sweep. Aliases are
    excluded (they resolve through ``make`` but never double-count)."""
    return tuple(_REGISTRY)


def canonical(spec: str) -> str:
    return _ALIASES.get(spec, spec)


def make(spec: str, **kwargs) -> base.Searcher:
    """Build a searcher from a registry spec. ``kwargs`` go to the backend's
    constructor (backends are currently parameter-free frozen dataclasses —
    per-corpus data lives in the state, serving knobs in SearchConfig)."""
    cls = _REGISTRY.get(canonical(spec))
    if cls is None:
        raise ValueError(
            f"unknown search backend {spec!r}; registered: {names()}")
    return cls(**kwargs)
