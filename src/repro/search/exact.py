"""Exact brute-force searchers: tiled streaming MIPS over the rotated corpus.

The ground-truth backends of the registry — no quantization, no probing,
every query scores every live row. The corpus is stored *rotated*
(XR = X·R) so the backend serves the same transform as the compressed
ones: search computes (Q·R)·(X·R)ᵀ, which equals Q·Xᵀ exactly because R is
orthogonal — making this the recall oracle the quantized backends are
measured against.

Two backends share one tile-merge body (``_merge_tile``, oracle:
``kernels.ref.streaming_topk_ref``):

``exact`` keeps the padded corpus resident on device and scans fixed
(tile_rows, n) tiles with a running top-k merge (a ``lax.scan``), so peak
memory is O(b·(k + tile_rows)) instead of the O(b·N) of the naive
``Q @ corpus.T`` materialization — at N = 10⁷ and b = 256 the full score
matrix would be 10 GiB; a 4096-row tile is 4 MiB.

``exact_stream`` keeps the corpus tiles in **host** memory and
double-buffers them through the device: while tile t scores, tile t+1's
H2C copy is already in flight (``jax.device_put`` is async), so the oracle
scales past HBM at the cost of PCIe/DMA bandwidth. The per-tile merge step
is a single jitted function; the host loop is not traceable, so the
backend opts out of the Engine's jit wrap (``engine_jit = False``).

``refresh`` semantics: in the default (eager) mode it right-multiplies R
*and* the stored rotated corpus by the delta. Scores are invariant
(rotations preserve inner products), so a refresh provably never moves
results — the conformance suite checks that. Under
``SearchConfig.fused_refresh`` the corpus is frozen at build rotation R₀
and only R tracks the trainer: because ⟨q·R₀Δ, x·R₀Δ⟩ = ⟨q·R₀, x·R₀⟩ the
delta cancels against the frozen corpus exactly, so search scores queries
with R₀ and ``refresh`` is one (n, n) matmul — corpus-side buffers are
never touched (the roofline win benchmarks/kernels_micro.py pins).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro import rotations
from repro.search.base import NEG_INF, SearchConfig, SearchResult


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExactState:
    """Rotated corpus padded to whole tiles; ``tile_rows`` is static so jit
    specializes on the tile shape (padding rows carry id −1).

    ``R0`` is the frozen build rotation of fused-refresh mode (None = eager
    mode). When present, XR stays at X·R₀ forever and search rotates
    queries by R₀ — exact because the live delta cancels (module docstring);
    R keeps tracking the trained rotation for stats/health."""

    R: jax.Array        # (n, n) serving rotation (tracks the trainer)
    XR: jax.Array       # (T·tile_rows, n) rotated corpus, zero-padded
    ids: jax.Array      # (T·tile_rows,) int32 item ids, −1 = padding
    tile_rows: int = dataclasses.field(default=4096, metadata={"static": True})
    R0: jax.Array | None = None  # frozen build rotation (fused mode)


def _merge_tile(carry, s, ids, k: int):
    """Fold one (b, t) score tile into the (b, k) running top-k carry.

    Rows with id −1 are padding and score −inf before the merge — the one
    merge body shared by the resident scan, the streaming scan, and (via
    kernels.ref.streaming_topk_ref) the tile-order-invariance oracle."""
    best_s, best_i = carry
    s = jnp.where(ids[None, :] >= 0, s, NEG_INF)
    cat_s = jnp.concatenate([best_s, s], axis=1)
    cat_i = jnp.concatenate(
        [best_i, jnp.broadcast_to(ids[None, :], s.shape)], axis=1)
    top_s, pos = jax.lax.top_k(cat_s, k)
    top_i = jnp.take_along_axis(cat_i, pos, axis=1)
    top_i = jnp.where(jnp.isfinite(top_s), top_i, -1)
    return top_s, top_i


def _query_rotation(state) -> jax.Array:
    """R₀ when the state is fused-frozen, else the live R."""
    R0 = getattr(state, "R0", None)
    return state.R if R0 is None else R0


def _exact_search_impl(state: ExactState, Q: jax.Array,
                       k: int) -> SearchResult:
    """The tiled scan body, un-jit'd — also the per-shard local scan of the
    ``exact_sharded`` backend (called inside shard_map)."""
    R = _query_rotation(state)
    QR = Q @ R.astype(Q.dtype)
    n = state.XR.shape[1]
    tiles = state.XR.reshape(-1, state.tile_rows, n)
    tile_ids = state.ids.reshape(-1, state.tile_rows)
    b = Q.shape[0]

    def merge(carry, tile):
        xr, ids = tile
        s = QR @ xr.T                                   # (b, tile_rows)
        return _merge_tile(carry, s, ids, k), None

    init = (jnp.full((b, k), NEG_INF, QR.dtype),
            jnp.full((b, k), -1, jnp.int32))
    (scores, ids), _ = jax.lax.scan(merge, init, (tiles, tile_ids))
    scanned = jnp.full((b,), jnp.sum(state.ids >= 0), dtype=jnp.int32)
    return SearchResult(scores=scores, ids=ids, scanned=scanned)


_exact_search = functools.partial(jax.jit, static_argnames=("k",))(
    _exact_search_impl)


def _pad_to_tiles(XR: jax.Array, tile: int) -> tuple[jax.Array, jax.Array]:
    n_rows = XR.shape[0]
    pad = (-n_rows) % tile
    ids = jnp.concatenate([
        jnp.arange(n_rows, dtype=jnp.int32),
        jnp.full((pad,), -1, jnp.int32),
    ])
    return jnp.pad(XR, ((0, pad), (0, 0))), ids


@dataclasses.dataclass(frozen=True)
class Exact:
    """Registry backend ``"exact"`` (see module docstring)."""

    name: ClassVar[str] = "exact"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ExactState:
        del key  # deterministic build
        R = jnp.asarray(R)
        XR = jnp.asarray(corpus) @ R.astype(corpus.dtype)
        tile = max(1, min(cfg.tile_rows, XR.shape[0]))
        XR, ids = _pad_to_tiles(XR, tile)
        return ExactState(R=R, XR=XR, ids=ids, tile_rows=tile,
                          R0=R if cfg.fused_refresh else None)

    def search(self, state: ExactState, Q: jax.Array, *,
               k: int = 10) -> SearchResult:
        return _exact_search(state, Q, k)

    def refresh(self, state: ExactState,
                delta: rotations.RotationDelta) -> ExactState:
        if state.R0 is not None:
            # fused: the frozen corpus cancels the delta exactly — only the
            # trainer-tracking R moves, XR is never re-materialized
            return dataclasses.replace(
                state, R=rotations.apply(state.R, delta))
        return dataclasses.replace(
            state,
            R=rotations.apply(state.R, delta),
            XR=rotations.apply(state.XR, delta),
        )

    def stats(self, state: ExactState) -> dict:
        rows = int(np.sum(np.asarray(state.ids) >= 0))
        return dict(
            backend=self.name,
            rows=rows,
            capacity=int(state.ids.shape[0]),
            dim=int(state.XR.shape[1]),
            tile_rows=state.tile_rows,
            scan_rows_per_query=rows,
            memory_bytes=int(state.XR.size * state.XR.dtype.itemsize),
            compression=1.0,
            fused_refresh=state.R0 is not None,
        )


@dataclasses.dataclass(frozen=True)
class StreamingExactState:
    """Host-resident corpus tiles (NOT a jax pytree — the tile list lives in
    host RAM and is streamed through the device per search)."""

    R: jax.Array                 # (n, n) serving rotation (device)
    tiles: tuple                 # T × (tile_rows, n) np.ndarray, zero-padded
    tile_ids: tuple              # T × (tile_rows,) np.int32, −1 = padding
    tile_rows: int
    rows: int                    # live row count
    R0: jax.Array | None = None  # frozen build rotation (fused mode)


@functools.partial(jax.jit, static_argnames=("k",), donate_argnums=(3,))
def _stream_step(QR: jax.Array, xr: jax.Array, ids: jax.Array, carry,
                 k: int):
    """Score one device-resident tile and fold it into the carry (the
    carry buffer is donated — the merge runs in place)."""
    s = QR @ xr.T.astype(QR.dtype)
    return _merge_tile(carry, s, ids, k)


@dataclasses.dataclass(frozen=True)
class ExactStreaming:
    """Registry backend ``"exact_stream"`` — the out-of-HBM recall oracle.

    Same scores as ``exact`` (bit-identical merge: the tile-order-invariance
    test pins it against ``streaming_topk_ref``), but the corpus lives in
    host memory and tiles are double-buffered through the device: the next
    tile's async ``device_put`` is issued *before* the current tile's merge
    step, so transfer overlaps compute. The host loop is untraceable, so
    ``engine_jit = False`` tells the Engine to call search eagerly.
    """

    name: ClassVar[str] = "exact_stream"
    engine_jit: ClassVar[bool] = False

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> StreamingExactState:
        del key  # deterministic build
        R = jnp.asarray(R)
        corpus = np.asarray(corpus)
        n_rows, n = corpus.shape
        tile = max(1, min(cfg.tile_rows, n_rows))
        Rh = np.asarray(R, dtype=corpus.dtype)
        tiles, tile_ids = [], []
        # rotate per tile so the full corpus never materializes on device
        for start in range(0, n_rows, tile):
            chunk = corpus[start:start + tile]
            xr = np.asarray(
                jnp.asarray(chunk) @ jnp.asarray(Rh))
            ids = np.arange(start, start + chunk.shape[0], dtype=np.int32)
            if chunk.shape[0] < tile:
                pad = tile - chunk.shape[0]
                xr = np.pad(xr, ((0, pad), (0, 0)))
                ids = np.concatenate([ids, np.full(pad, -1, np.int32)])
            tiles.append(xr)
            tile_ids.append(ids)
        return StreamingExactState(
            R=R, tiles=tuple(tiles), tile_ids=tuple(tile_ids),
            tile_rows=tile, rows=n_rows,
            R0=R if cfg.fused_refresh else None)

    def search(self, state: StreamingExactState, Q: jax.Array, *,
               k: int = 10) -> SearchResult:
        R = _query_rotation(state)
        QR = jnp.asarray(Q) @ R.astype(Q.dtype)
        b = QR.shape[0]
        carry = (jnp.full((b, k), NEG_INF, QR.dtype),
                 jnp.full((b, k), -1, jnp.int32))
        T = len(state.tiles)
        # double buffer: slot t's compute overlaps slot t+1's H2D copy
        buf = (jax.device_put(state.tiles[0]),
               jax.device_put(state.tile_ids[0]))
        for t in range(T):
            nxt = None
            if t + 1 < T:
                nxt = (jax.device_put(state.tiles[t + 1]),
                       jax.device_put(state.tile_ids[t + 1]))
            carry = _stream_step(QR, buf[0], buf[1], carry, k)
            buf = nxt
        scores, ids = carry
        scanned = jnp.full((b,), state.rows, dtype=jnp.int32)
        return SearchResult(scores=scores, ids=ids, scanned=scanned)

    def refresh(self, state: StreamingExactState,
                delta: rotations.RotationDelta) -> StreamingExactState:
        R = rotations.apply(state.R, delta)
        if state.R0 is not None:
            # fused: frozen host tiles cancel the delta — nothing streams
            return dataclasses.replace(state, R=R)
        # eager: re-rotate tile by tile through the device (the expensive
        # path fused_refresh exists to avoid)
        tiles = tuple(
            np.asarray(rotations.apply(jnp.asarray(t), delta))
            for t in state.tiles)
        return dataclasses.replace(state, R=R, tiles=tiles)

    def stats(self, state: StreamingExactState) -> dict:
        n = state.tiles[0].shape[1] if state.tiles else 0
        host_bytes = sum(t.nbytes for t in state.tiles)
        return dict(
            backend=self.name,
            rows=state.rows,
            capacity=state.tile_rows * len(state.tiles),
            dim=n,
            tile_rows=state.tile_rows,
            scan_rows_per_query=state.rows,
            memory_bytes=host_bytes,
            device_bytes=2 * state.tile_rows * n * 4,  # double buffer
            compression=1.0,
            streaming=True,
            fused_refresh=state.R0 is not None,
        )
