"""Exact brute-force searcher: tiled streaming MIPS over the rotated corpus.

The ground-truth backend of the registry — no quantization, no probing,
every query scores every live row. The corpus is stored *rotated*
(XR = X·R) so the backend serves the same transform as the compressed
ones: search computes (Q·R)·(X·R)ᵀ, which equals Q·Xᵀ exactly because R is
orthogonal — making this the recall oracle the quantized backends are
measured against.

The scan streams over fixed (tile_rows, n) corpus tiles with a running
top-k merge (a ``lax.scan``), so peak memory is O(b·(k + tile_rows))
instead of the O(b·N) of the naive ``Q @ corpus.T``
materialization the examples used to hand-roll — at N = 10⁷ and b = 256
the full score matrix would be 10 GiB; a 4096-row tile is 4 MiB.

``refresh`` right-multiplies R *and* the stored rotated corpus by the
delta. Scores are invariant (rotations preserve inner products), so a
refresh provably never moves this backend's results — the conformance
suite checks that — but the served transform stays bit-consistent with the
trainer, and dense Cayley/Procrustes deltas are absorbed just as well as
Givens ones (unlike the ADC backends, which need the Givens factorization).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro import rotations
from repro.search.base import NEG_INF, SearchConfig, SearchResult


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExactState:
    """Rotated corpus padded to whole tiles; ``tile_rows`` is static so jit
    specializes on the tile shape (padding rows carry id −1)."""

    R: jax.Array        # (n, n) serving rotation
    XR: jax.Array       # (T·tile_rows, n) rotated corpus, zero-padded
    ids: jax.Array      # (T·tile_rows,) int32 item ids, −1 = padding
    tile_rows: int = dataclasses.field(default=4096, metadata={"static": True})


def _exact_search_impl(state: ExactState, Q: jax.Array,
                       k: int) -> SearchResult:
    """The tiled scan body, un-jit'd — also the per-shard local scan of the
    ``exact_sharded`` backend (called inside shard_map)."""
    QR = Q @ state.R.astype(Q.dtype)
    n = state.XR.shape[1]
    tiles = state.XR.reshape(-1, state.tile_rows, n)
    tile_ids = state.ids.reshape(-1, state.tile_rows)
    b = Q.shape[0]

    def merge(carry, tile):
        best_s, best_i = carry
        xr, ids = tile
        s = QR @ xr.T                                   # (b, tile_rows)
        s = jnp.where(ids[None, :] >= 0, s, NEG_INF)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(ids[None, :], s.shape)], axis=1)
        top_s, pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        top_i = jnp.where(jnp.isfinite(top_s), top_i, -1)
        return (top_s, top_i), None

    init = (jnp.full((b, k), NEG_INF, QR.dtype),
            jnp.full((b, k), -1, jnp.int32))
    (scores, ids), _ = jax.lax.scan(merge, init, (tiles, tile_ids))
    scanned = jnp.full((b,), jnp.sum(state.ids >= 0), dtype=jnp.int32)
    return SearchResult(scores=scores, ids=ids, scanned=scanned)


_exact_search = functools.partial(jax.jit, static_argnames=("k",))(
    _exact_search_impl)


@dataclasses.dataclass(frozen=True)
class Exact:
    """Registry backend ``"exact"`` (see module docstring)."""

    name: ClassVar[str] = "exact"

    def build(self, key: jax.Array, corpus: jax.Array, R: jax.Array,
              cfg: SearchConfig) -> ExactState:
        del key  # deterministic build
        R = jnp.asarray(R)
        XR = jnp.asarray(corpus) @ R.astype(corpus.dtype)
        n_rows = XR.shape[0]
        tile = max(1, min(cfg.tile_rows, n_rows))
        pad = (-n_rows) % tile
        ids = jnp.concatenate([
            jnp.arange(n_rows, dtype=jnp.int32),
            jnp.full((pad,), -1, jnp.int32),
        ])
        XR = jnp.pad(XR, ((0, pad), (0, 0)))
        return ExactState(R=R, XR=XR, ids=ids, tile_rows=tile)

    def search(self, state: ExactState, Q: jax.Array, *,
               k: int = 10) -> SearchResult:
        return _exact_search(state, Q, k)

    def refresh(self, state: ExactState,
                delta: rotations.RotationDelta) -> ExactState:
        return dataclasses.replace(
            state,
            R=rotations.apply(state.R, delta),
            XR=rotations.apply(state.XR, delta),
        )

    def stats(self, state: ExactState) -> dict:
        rows = int(np.sum(np.asarray(state.ids) >= 0))
        return dict(
            backend=self.name,
            rows=rows,
            capacity=int(state.ids.shape[0]),
            dim=int(state.XR.shape[1]),
            tile_rows=state.tile_rows,
            scan_rows_per_query=rows,
            memory_bytes=int(state.XR.size * state.XR.dtype.itemsize),
            compression=1.0,
        )
