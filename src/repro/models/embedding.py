"""Embedding-table substrate for the recsys family.

JAX has neither ``nn.EmbeddingBag`` nor a sharded embedding primitive; both
are built here (kernel_taxonomy §RecSys note — "this IS part of the system"):

  * ``bag_lookup``       EmbeddingBag(sum/mean) = take + segment_sum
                         (Pallas scalar-prefetch kernel on the hot path)
  * ``sharded_lookup``   row-sharded table lookup under shard_map: each shard
                         masks the ids it owns, gathers locally, and psums —
                         O(B·dim) collective instead of all-gathering the
                         (possibly multi-GB) table.

The naive path (``jnp.take`` on a sharded table, XLA inserts the all-gather)
is kept on purpose: it is the §Perf hillclimb baseline for the recsys cells.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels import ops as kops


def lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain gather; under pjit XLA typically all-gathers a sharded table."""
    return jnp.take(table, ids, axis=0)


def bag_lookup(table: jax.Array, ids: jax.Array, *, combiner: str = "mean",
               use_kernel: bool = False) -> jax.Array:
    """EmbeddingBag over the last axis of ids: (..., L) -> (..., dim).

    ids < 0 are padding. ``use_kernel=True`` routes through the Pallas
    scalar-prefetch kernel (single-host path).
    """
    lead = ids.shape[:-1]
    L = ids.shape[-1]
    flat = ids.reshape(-1, L)
    B = flat.shape[0]
    valid = flat >= 0
    if use_kernel:
        bag_ids = jnp.repeat(jnp.arange(B, dtype=jnp.int32), L)
        out = kops.embedding_bag(
            table, flat.reshape(-1).astype(jnp.int32), bag_ids, B
        )
    else:
        rows = jnp.take(table, jnp.maximum(flat, 0), axis=0)
        rows = jnp.where(valid[..., None], rows, 0.0)
        out = jnp.sum(rows, axis=1)
    if combiner == "mean":
        cnt = jnp.maximum(jnp.sum(valid, axis=1), 1)
        out = out / cnt[:, None].astype(out.dtype)
    return out.reshape(*lead, table.shape[-1])


def sharded_lookup(table: jax.Array, ids: jax.Array, mesh, axis: str = "model",
                   table_spec: P | None = None) -> jax.Array:
    """Row-sharded lookup: table (V, dim) sharded on rows over ``axis``;
    ids replicated (or batch-sharded). Returns embeddings with ids' sharding.

    Each shard owns rows [lo, hi); out-of-range ids contribute 0 and the psum
    reassembles the full rows — total collective traffic is one (B, dim)
    psum instead of a (V, dim) all-gather.
    """
    V, dim = table.shape
    n_shards = mesh.shape[axis]
    table_spec = table_spec if table_spec is not None else P(axis, None)
    ids_spec = P()  # replicated ids inside the region

    def local(table_l, ids_l):
        shard = jax.lax.axis_index(axis)
        rows_per = V // n_shards
        lo = shard * rows_per
        local_ids = ids_l - lo
        ok = (local_ids >= 0) & (local_ids < rows_per) & (ids_l >= 0)
        safe = jnp.clip(local_ids, 0, rows_per - 1)
        out = jnp.take(table_l, safe, axis=0)
        out = jnp.where(ok[..., None], out, 0.0)
        return jax.lax.psum(out, axis)

    return compat.shard_map(
        local, mesh=mesh, in_specs=(table_spec, ids_spec), out_specs=P(),
        check_vma=False,
    )(table, ids)
