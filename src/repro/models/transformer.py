"""Decoder-only transformer LM substrate: dense / GQA / MoE variants.

One config covers all five assigned LM architectures (qwen1.5-4b, olmo-1b,
nemotron-4-340b, grok-1, llama4-maverick). Layers are scanned (params carry a
leading layer axis) and rematerialized, so the HLO stays compact at 96 layers
and activation memory is O(1) in depth.

``moe_every=2`` (llama4-style interleaving) scans over two-layer super-blocks
— sublayer "a" dense, sublayer "b" MoE — because lax.scan needs homogeneous
per-step params.

Paths:
  forward_train   tokens → mean xent loss (+ MoE aux, + optional KV-PQ
                  distortion term — the paper's Eq. 1 second term applied to
                  the KV stream)
  serve_prefill   tokens → last-token logits + KV cache (dense or PQ codes)
  serve_decode    one token in, one token out, cache updated in place
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kv_quant
from repro.models import layers, moe as moe_lib, param
from repro.models.param import ParamSpec
from repro.sharding import rules as sh


class TransformerConfig(NamedTuple):
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "silu"
    use_glu: bool = True
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    rope_theta: float = 10000.0
    moe: moe_lib.MoEConfig | None = None
    moe_every: int = 1               # 2 → dense/MoE interleave (llama4)
    kv_quant: kv_quant.KVQuantConfig | None = None
    train_kv_quant: bool = False     # add KV distortion term to the train loss
    dtype: Any = jnp.bfloat16        # activation dtype
    param_dtype: Any = jnp.bfloat16
    q_chunk: int = 256
    xent_chunk: int = 8192
    moe_chunk: int = 0               # >0: serve-path MoE processed in token
    #                                  chunks (bounds the E·C dispatch buffers
    #                                  at 1M-token prefill)
    remat: bool = True
    scan_groups: int = 1             # two-level layer scan: only every
    #                                  (scan_len/scan_groups)-th boundary is
    #                                  saved in bwd (sqrt-remat, ~+1/G fwd)
    train_accum_steps: int = 1       # microbatch accumulation (memory fit)
    rules: str = "lm_base"           # key into sharding rule registry

    @property
    def rule_table(self) -> dict[str, Any]:
        return sh.RULE_REGISTRY[self.rules]

    @property
    def interleaved(self) -> bool:
        return self.moe is not None and self.moe_every == 2

    @property
    def scan_len(self) -> int:
        return self.num_layers // (2 if self.interleaved else 1)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _sublayer_specs(cfg: TransformerConfig, L: int, moe_on: bool):
    d = cfg.d_model
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    f = cfg.d_ff
    attn = {
        "wq": ParamSpec((L, d, Hq * hd), ("layers", "w_embed", "w_heads")),
        "wk": ParamSpec((L, d, Hkv * hd), ("layers", "w_embed", "w_kv_heads")),
        "wv": ParamSpec((L, d, Hkv * hd), ("layers", "w_embed", "w_kv_heads")),
        "wo": ParamSpec((L, Hq * hd, d), ("layers", "w_heads", "w_embed")),
    }
    if cfg.qkv_bias:
        attn["bq"] = ParamSpec((L, Hq * hd), ("layers", "w_heads"), init="zeros")
        attn["bk"] = ParamSpec((L, Hkv * hd), ("layers", "w_kv_heads"), init="zeros")
        attn["bv"] = ParamSpec((L, Hkv * hd), ("layers", "w_kv_heads"), init="zeros")

    if moe_on:
        E = cfg.moe.num_experts
        ffn = {
            "router": ParamSpec((L, d, E), ("layers", "w_embed", None)),
            "wi": ParamSpec((L, E, d, f), ("layers", "w_experts", "w_embed", "w_expert_mlp")),
            "wo": ParamSpec((L, E, f, d), ("layers", "w_experts", "w_expert_mlp", "w_embed")),
        }
        if cfg.use_glu:
            ffn["wg"] = ParamSpec((L, E, d, f), ("layers", "w_experts", "w_embed", "w_expert_mlp"))
    else:
        ffn = {
            "wi": ParamSpec((L, d, f), ("layers", "w_embed", "w_mlp")),
            "wo": ParamSpec((L, f, d), ("layers", "w_mlp", "w_embed")),
        }
        if cfg.use_glu:
            ffn["wg"] = ParamSpec((L, d, f), ("layers", "w_embed", "w_mlp"))

    out = {"attn": attn, "ffn": ffn}
    if cfg.norm == "rmsnorm":
        out["ln1"] = ParamSpec((L, d), ("layers", None), init="ones")
        out["ln2"] = ParamSpec((L, d), ("layers", None), init="ones")
    return out


def param_specs(cfg: TransformerConfig):
    d, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    hd = cfg.head_dim
    if cfg.interleaved:
        Lb = L // 2
        layers_spec = {
            "a": _sublayer_specs(cfg, Lb, moe_on=False),
            "b": _sublayer_specs(cfg, Lb, moe_on=True),
        }
    else:
        layers_spec = _sublayer_specs(cfg, L, moe_on=cfg.moe is not None)

    specs = {
        "embed": ParamSpec((V, d), ("w_vocab", "w_embed"), scale=1.0),
        "head": ParamSpec((V, d), ("w_vocab", "w_embed")),
        "layers": layers_spec,
    }
    if cfg.norm == "rmsnorm":
        specs["ln_f"] = ParamSpec((d,), (None,), init="ones")

    if cfg.kv_quant is not None:
        kq = cfg.kv_quant
        D, K, sub = kq.num_subspaces, kq.num_codewords, kq.sub
        specs["kvq"] = {
            "rot_k": ParamSpec((L, hd, hd), ("layers", "rot_in", "rot_out"), init="eye"),
            "rot_v": ParamSpec((L, hd, hd), ("layers", "rot_in", "rot_out"), init="eye"),
            "cb_k": ParamSpec((L, D, K, sub), ("layers", "pq_dim", "pq_code", "pq_sub"), scale=0.02),
            "cb_v": ParamSpec((L, D, K, sub), ("layers", "pq_dim", "pq_code", "pq_sub"), scale=0.02),
        }
    return specs


def init_params(key: jax.Array, cfg: TransformerConfig):
    return param.init_params(key, param_specs(cfg), cfg.param_dtype)


def _kvq_scan_tree(params, cfg: TransformerConfig):
    """KV-quant params reshaped for the layer scan: leading scan_len (and a
    sublayer pair axis when interleaved). None when disabled."""
    if "kvq" not in params:
        return None
    kvq = params["kvq"]
    if cfg.interleaved:
        Lb = cfg.scan_len
        return jax.tree.map(lambda a: a.reshape(Lb, 2, *a.shape[1:]), kvq)
    return kvq


def _kvq_params(kvq_leaf_tree) -> kv_quant.KVQuantParams | None:
    if kvq_leaf_tree is None:
        return None
    return kv_quant.KVQuantParams(
        rot_k=kvq_leaf_tree["rot_k"], rot_v=kvq_leaf_tree["rot_v"],
        cb_k=kvq_leaf_tree["cb_k"], cb_v=kvq_leaf_tree["cb_v"],
    )


def _kvq_sub(kvq_tree, i):
    if kvq_tree is None:
        return None
    return jax.tree.map(lambda a: a[i], kvq_tree)


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _qkv(lp, h, cfg: TransformerConfig, positions):
    B, S, d = h.shape
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = h @ lp["attn"]["wq"].astype(h.dtype)
    k = h @ lp["attn"]["wk"].astype(h.dtype)
    v = h @ lp["attn"]["wv"].astype(h.dtype)
    if cfg.qkv_bias:
        q = q + lp["attn"]["bq"].astype(h.dtype)
        k = k + lp["attn"]["bk"].astype(h.dtype)
        v = v + lp["attn"]["bv"].astype(h.dtype)
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(lp, h, cfg: TransformerConfig, moe_on: bool):
    """Dense MLP or MoE on (B, S, d). Returns (out, aux_loss)."""
    rt = cfg.rule_table
    if moe_on:
        B, S, d = h.shape
        T = B * S

        def run_moe(tokens):
            return moe_lib.moe_block(
                tokens,
                lp["ffn"]["router"].astype(jnp.float32),
                lp["ffn"]["wi"],
                lp["ffn"].get("wg"),
                lp["ffn"]["wo"],
                cfg.moe,
                activation=cfg.activation,
                rule_table=rt,
            )

        flat = h.reshape(T, d)
        if cfg.moe_chunk and T > cfg.moe_chunk:
            assert T % cfg.moe_chunk == 0
            nc = T // cfg.moe_chunk
            out, aux = jax.lax.map(run_moe, flat.reshape(nc, cfg.moe_chunk, d))
            out = out.reshape(T, d)
            aux = jnp.mean(aux)
        else:
            out, aux = run_moe(flat)
        return out.reshape(B, S, d), aux
    hh = h @ lp["ffn"]["wi"].astype(h.dtype)
    hh = sh.constrain(hh, ("act_batch", "act_seq", "act_mlp"), rt)
    if cfg.use_glu:
        g = h @ lp["ffn"]["wg"].astype(h.dtype)
        hh = layers.activate(hh, cfg.activation) * g
    else:
        hh = layers.activate(hh, cfg.activation)
    out = hh @ lp["ffn"]["wo"].astype(h.dtype)
    return out, jnp.float32(0.0)


def _norm(lp, name, x, cfg: TransformerConfig):
    scale = lp[name] if cfg.norm == "rmsnorm" else None
    return layers.apply_norm(x, scale, cfg.norm)


def _layer_train(x, lp, cfg: TransformerConfig, positions, kvq_l, moe_on):
    """Full-sequence layer forward. Returns (x, (aux, kv_dist))."""
    rt = cfg.rule_table
    h = _norm(lp, "ln1", x, cfg)
    q, k, v = _qkv(lp, h, cfg, positions)
    q = sh.constrain(q, ("act_batch", "act_seq", "act_heads", None), rt)
    att = layers.blockwise_attention(q, k, v, q_chunk=cfg.q_chunk)
    B, S = x.shape[:2]
    att = att.reshape(B, S, cfg.num_heads * cfg.head_dim)
    x = x + att @ lp["attn"]["wo"].astype(x.dtype)
    h2 = _norm(lp, "ln2", x, cfg)
    y, aux = _ffn(lp, h2, cfg, moe_on)
    x = x + y
    # act_boundary_seq: None normally; "model" under *_bigtrain rules so the
    # residual saved for backward is stored seq-sharded (ZeRO-activations).
    x = sh.constrain(x, ("act_batch", "act_boundary_seq", "act_embed"), rt)

    kv_dist = jnp.float32(0.0)
    if cfg.train_kv_quant and kvq_l is not None:
        # Distortion term on a subsample of this layer's K/V vectors — the
        # paper's Eq. (1) second term for the KV index.
        kvp = _kvq_params(kvq_l)
        ks = k[:, : min(64, S)].reshape(-1, cfg.head_dim)
        vs = v[:, : min(64, S)].reshape(-1, cfg.head_dim)
        kv_dist = kv_quant.kv_distortion(kvp, ks, vs)
    return x, (aux, kv_dist)


def _constrain_grouped(grouped, params, cfg: TransformerConfig):
    """Apply logical shardings (with the extra leading group axis) to the
    (G, per, ...) reshaped scan inputs."""
    rt = cfg.rule_table
    spec_tree = param_specs(cfg)
    logical_layers = param.logical_tree(spec_tree["layers"])
    kvq_logical = (param.logical_tree(spec_tree["kvq"])
                   if "kvq" in spec_tree else None)
    if cfg.interleaved and kvq_logical is not None:
        # kvq leaves gained a sublayer-pair axis in _kvq_scan_tree
        kvq_logical = jax.tree.map(
            lambda lg: (lg[0], None) + tuple(lg[1:]), kvq_logical,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x))
    logical = (logical_layers, kvq_logical)

    def is_logical(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)

    arrs, treedef = jax.tree.flatten(grouped)
    lgs = jax.tree.leaves(logical, is_leaf=is_logical)
    assert len(arrs) == len(lgs), (len(arrs), len(lgs))
    pinned = [sh.constrain(a, ("layers",) + tuple(lg), rt)
              for a, lg in zip(arrs, lgs)]
    return jax.tree.unflatten(treedef, pinned)


def _maybe_remat(fn, cfg: TransformerConfig):
    """Full remat (save layer inputs only). The tempting
    dots_with_no_batch_dims_saveable policy saves every projection output —
    per-token matmuls have no dot batch dims — which stacked f32 copies of
    (L, B, S, d) across the layer scan (measured +200 GiB/dev at the 4k
    train shape). Recomputing the layer costs ~1 extra fwd pass and keeps
    only the bf16 boundary per layer."""
    if not cfg.remat:
        return fn
    return jax.checkpoint(fn, static_argnums=(2, 5))


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------

def forward_train(params, tokens: jax.Array, labels: jax.Array,
                  cfg: TransformerConfig) -> jax.Array:
    """tokens/labels (B, S) int32 → scalar loss."""
    rt = cfg.rule_table
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = sh.constrain(x, ("act_batch", "act_seq", "act_embed"), rt)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    fn = _maybe_remat(_layer_train, cfg)

    def body(carry, scanned):
        x, aux_t, dist_t = carry
        lp, kvq_l = scanned
        if cfg.interleaved:
            x, (a1, d1) = fn(x, lp["a"], cfg, positions, _kvq_sub(kvq_l, 0), False)
            x, (a2, d2) = fn(x, lp["b"], cfg, positions, _kvq_sub(kvq_l, 1), True)
            aux, dist = a1 + a2, d1 + d2
        else:
            x, (aux, dist) = fn(x, lp, cfg, positions, kvq_l, cfg.moe is not None)
        return (x, aux_t + aux, dist_t + dist), None

    scanned = (params["layers"], _kvq_scan_tree(params, cfg))
    carry0 = (x, jnp.float32(0.0), jnp.float32(0.0))
    G = cfg.scan_groups
    if G > 1:
        assert cfg.scan_len % G == 0
        per = cfg.scan_len // G
        grouped = jax.tree.map(
            lambda a: a.reshape(G, per, *a.shape[1:]), scanned)
        # Re-pin shardings after the grouping reshape: without this the SPMD
        # partitioner invents (32, 8)-style tilings for the grouped weights
        # and buys them back with f32 full-rematerialization temporaries
        # (measured ~7 GiB/dev on nemotron).
        grouped = _constrain_grouped(grouped, params, cfg)

        @jax.checkpoint
        def run_group(carry, group_xs):
            carry, _ = jax.lax.scan(body, carry, group_xs)
            return carry, None

        (x, aux, dist), _ = jax.lax.scan(run_group, carry0, grouped)
    else:
        (x, aux, dist), _ = jax.lax.scan(body, carry0, scanned)
    x = _final_norm(params, x, cfg)
    loss = layers.softmax_xent_chunked(
        x.reshape(B * S, cfg.d_model), params["head"], labels.reshape(-1),
        chunk=cfg.xent_chunk,
    )
    total = loss + 0.01 * aux / cfg.num_layers
    if cfg.train_kv_quant and "kvq" in params:
        total = total + 0.1 * dist / cfg.num_layers
    return total


def _final_norm(params, x, cfg: TransformerConfig):
    scale = params["ln_f"] if cfg.norm == "rmsnorm" else None
    return layers.apply_norm(x, scale, cfg.norm)


# ---------------------------------------------------------------------------
# Serving: prefill + decode (dense cache or PQ-compressed cache)
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    k: jax.Array       # (L, B, Hkv, S, hd)
    v: jax.Array
    length: jax.Array  # (B,) int32 — number of valid positions


class PQDecodeCache(NamedTuple):
    k_codes: jax.Array  # (L, B, Hkv, S, D) uint8
    v_codes: jax.Array
    length: jax.Array


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               quantized: bool | None = None):
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    quantized = (cfg.kv_quant is not None) if quantized is None else quantized
    if quantized:
        D = cfg.kv_quant.num_subspaces
        z = jnp.zeros((L, batch, Hkv, max_len, D), jnp.uint8)
        return PQDecodeCache(k_codes=z, v_codes=z, length=jnp.zeros((batch,), jnp.int32))
    z = jnp.zeros((L, batch, Hkv, max_len, hd), cfg.dtype)
    return DecodeCache(k=z, v=z, length=jnp.zeros((batch,), jnp.int32))


def _write_cache(cache_layer: jax.Array, new: jax.Array, length: jax.Array):
    """cache (B, Hkv, S, e) ← new (B, Hkv, e) at per-batch position length."""

    def upd(c, n, pos):  # c (Hkv, S, e), n (Hkv, e)
        return jax.lax.dynamic_update_slice_in_dim(c, n[:, None], pos, axis=1)

    return jax.vmap(upd)(cache_layer, new, length)


def _decode_sublayer(x, lp, cfg: TransformerConfig, pos, kvq_l, moe_on,
                     kc, vc, quantized: bool, rt):
    B = x.shape[0]
    Hq, hd = cfg.num_heads, cfg.head_dim
    h = _norm(lp, "ln1", x[:, None], cfg)  # (B, 1, d)
    q, k, v = _qkv(lp, h, cfg, pos[:, None])
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    if quantized:
        kvp = _kvq_params(kvq_l)
        ck, cv = kv_quant.encode_kv(kvp, k, v)
        kc = _write_cache(kc, ck, pos)
        vc = _write_cache(vc, cv, pos)
        kc = sh.constrain(kc, ("act_batch", None, "act_kv_seq", None), rt)
        vc = sh.constrain(vc, ("act_batch", None, "act_kv_seq", None), rt)
        mask = jnp.arange(kc.shape[2])[None] <= pos[:, None]
        att = kv_quant.adc_decode_attention(kvp, q, kc, vc, length_mask=mask)
    else:
        kc = _write_cache(kc, k, pos)
        vc = _write_cache(vc, v, pos)
        kc = sh.constrain(kc, ("act_batch", None, "act_kv_seq", None), rt)
        vc = sh.constrain(vc, ("act_batch", None, "act_kv_seq", None), rt)
        att = layers.decode_attention(q, kc, vc, pos + 1)
    x = x + att.reshape(B, Hq * hd) @ lp["attn"]["wo"].astype(x.dtype)
    h2 = _norm(lp, "ln2", x[:, None], cfg)
    y, _aux = _ffn(lp, h2, cfg, moe_on)
    return x + y[:, 0], kc, vc


def serve_decode(params, token: jax.Array, cache, cfg: TransformerConfig):
    """One decode step. token (B,) int32 → (logits (B, V), new cache).

    The cache rides in the scan CARRY and is updated in place with
    dynamic_update_slice — emitting per-layer caches as scan ys doubles the
    cache footprint (input stack + output stack both live; measured 62 vs
    ~10 GiB/device on nemotron decode_32k)."""
    rt = cfg.rule_table
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)  # (B, d)
    pos = cache.length  # (B,)
    quantized = isinstance(cache, PQDecodeCache)
    k_all, v_all = (cache.k_codes, cache.v_codes) if quantized else (cache.k, cache.v)
    n_sub = 2 if cfg.interleaved else 1

    def body(carry, scanned):
        x, k_all, v_all = carry
        lp, kvq_l, li = scanned  # li = layer index of sublayer "a"

        def run(x, k_all, v_all, lp_s, kvq_s, moe_on, idx):
            kc, vc = k_all[idx], v_all[idx]
            x, kc, vc = _decode_sublayer(
                x, lp_s, cfg, pos, kvq_s, moe_on, kc, vc, quantized, rt)
            k_all = jax.lax.dynamic_update_slice_in_dim(
                k_all, kc[None], idx, axis=0)
            v_all = jax.lax.dynamic_update_slice_in_dim(
                v_all, vc[None], idx, axis=0)
            return x, k_all, v_all

        if cfg.interleaved:
            x, k_all, v_all = run(x, k_all, v_all, lp["a"],
                                  _kvq_sub(kvq_l, 0), False, li)
            x, k_all, v_all = run(x, k_all, v_all, lp["b"],
                                  _kvq_sub(kvq_l, 1), True, li + 1)
        else:
            x, k_all, v_all = run(x, k_all, v_all, lp, kvq_l,
                                  cfg.moe is not None, li)
        return (x, k_all, v_all), None

    layer_ids = jnp.arange(cfg.scan_len, dtype=jnp.int32) * n_sub
    scanned = (params["layers"], _kvq_scan_tree(params, cfg), layer_ids)
    (x, new_k, new_v), _ = jax.lax.scan(body, (x, k_all, v_all), scanned)
    x = _final_norm(params, x[:, None], cfg)[:, 0]
    logits = (x.astype(jnp.float32) @ params["head"].astype(jnp.float32).T)
    logits = sh.constrain(logits, ("act_batch", "act_vocab"), rt)
    if quantized:
        new_cache = PQDecodeCache(new_k, new_v, cache.length + 1)
    else:
        new_cache = DecodeCache(new_k, new_v, cache.length + 1)
    return logits, new_cache


def _prefill_sublayer(x, lp, cfg, positions, kvq_l, moe_on, quantized, rt):
    B, S = x.shape[:2]
    h = _norm(lp, "ln1", x, cfg)
    q, k, v = _qkv(lp, h, cfg, positions)
    q = sh.constrain(q, ("act_batch", "act_seq", "act_heads", None), rt)
    att = layers.blockwise_attention(q, k, v, q_chunk=cfg.q_chunk)
    att = att.reshape(B, S, cfg.num_heads * cfg.head_dim)
    x = x + att @ lp["attn"]["wo"].astype(x.dtype)
    h2 = _norm(lp, "ln2", x, cfg)
    y, _aux = _ffn(lp, h2, cfg, moe_on)
    x = x + y
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, S, hd)
    vt = v.transpose(0, 2, 1, 3)
    if quantized:
        kvp = _kvq_params(kvq_l)
        ck, cv = kv_quant.encode_kv(kvp, kt, vt)
        return x, ck, cv
    return x, kt, vt


def serve_prefill(params, tokens: jax.Array, cfg: TransformerConfig,
                  max_len: int | None = None):
    """tokens (B, S) → (last-token logits, populated cache of size max_len)."""
    rt = cfg.rule_table
    B, S = tokens.shape
    max_len = max_len or S
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    quantized = cfg.kv_quant is not None

    def body(x, scanned):
        lp, kvq_l = scanned
        if cfg.interleaved:
            x, k0, v0 = _prefill_sublayer(
                x, lp["a"], cfg, positions, _kvq_sub(kvq_l, 0), False, quantized, rt)
            x, k1, v1 = _prefill_sublayer(
                x, lp["b"], cfg, positions, _kvq_sub(kvq_l, 1), True, quantized, rt)
            return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
        x, kt, vt = _prefill_sublayer(
            x, lp, cfg, positions, kvq_l, cfg.moe is not None, quantized, rt)
        return x, (kt, vt)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], _kvq_scan_tree(params, cfg)))
    if cfg.interleaved:
        ks = ks.reshape(cfg.num_layers, *ks.shape[2:])
        vs = vs.reshape(cfg.num_layers, *vs.shape[2:])
    x = _final_norm(params, x, cfg)
    logits = x[:, -1].astype(jnp.float32) @ params["head"].astype(jnp.float32).T

    pad = max_len - S
    pad_width = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
    if quantized:
        cache = PQDecodeCache(
            k_codes=jnp.pad(ks, pad_width), v_codes=jnp.pad(vs, pad_width),
            length=jnp.full((B,), S, jnp.int32),
        )
    else:
        cache = DecodeCache(
            k=jnp.pad(ks, pad_width), v=jnp.pad(vs, pad_width),
            length=jnp.full((B,), S, jnp.int32),
        )
    return logits, cache


def model_flops_per_token(cfg: TransformerConfig) -> float:
    """6·N_active — the §Roofline MODEL_FLOPS numerator per token."""
    d, f, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    attn = d * (Hq + 2 * Hkv) * hd + Hq * hd * d
    n_mats = 3 if cfg.use_glu else 2
    dense_ffn = n_mats * d * f
    moe_ffn = (cfg.moe.top_k * n_mats * d * f) if cfg.moe is not None else 0.0
    if cfg.interleaved:
        ffn_total = (L // 2) * dense_ffn + (L // 2) * moe_ffn
    elif cfg.moe is not None:
        ffn_total = L * moe_ffn
    else:
        ffn_total = L * dense_ffn
    head = cfg.vocab_size * d
    return 6.0 * (L * attn + ffn_total + head)


def num_params(cfg: TransformerConfig) -> int:
    return param.count_params(param_specs(cfg))
