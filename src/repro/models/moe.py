"""Mixture-of-Experts with sort-based capacity dispatch (TPU-native).

GShard's einsum dispatch materializes a (tokens, E, capacity) one-hot — at
our production shapes (65k tokens/device, 128 experts) that is tens of GB, so
we use the sort-based formulation instead (DESIGN.md §3):

  1. top-k routing → (token, expert) assignment list of length T·k
  2. stable argsort by expert id → expert-contiguous order
  3. position-within-expert via running counts; entries beyond the per-expert
     capacity C drop to an overflow row (token keeps its residual path)
  4. scatter into a dense (E, C, d) buffer → batched expert GEMMs on the MXU
  5. gather back, weight by router gate, combine.

Memory is O(T·k·d + E·C·d); no (T, E, C) tensor ever exists. The (E, C, d)
buffer is sharded over the "model" mesh axis (expert parallelism) via a
sharding constraint — XLA inserts the token→expert all-to-all.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.sharding import rules as sh


class MoEConfig(NamedTuple):
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor of 8


def moe_block(
    x: jax.Array,          # (T, d) tokens
    router_w: jax.Array,   # (d, E)
    wi: jax.Array,         # (E, d, f)
    wg: jax.Array | None,  # (E, d, f) for GLU variants
    wo: jax.Array,         # (E, f, d)
    cfg: MoEConfig,
    *,
    activation: str,
    rule_table: dict[str, Any],
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (T, d), aux load-balancing loss)."""
    from repro.models import layers

    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = capacity(T, cfg)

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # Switch-style auxiliary load-balance loss.
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E

    flat_ids = ids.reshape(-1)                      # (T·k,)
    flat_tok = jnp.repeat(jnp.arange(T), k)         # (T·k,)

    order = jnp.argsort(flat_ids, stable=True)
    inv = jnp.argsort(order)                        # unsort permutation
    s_ids = flat_ids[order]
    s_tok = flat_tok[order]

    counts = jax.ops.segment_sum(
        jnp.ones_like(s_ids, jnp.int32), s_ids, num_segments=E)
    starts = jnp.cumsum(counts) - counts            # exclusive prefix

    # --- dispatch: GATHER-only (a scatter here makes the SPMD partitioner
    # materialize (T·k, d)-sized u32 index grids — measured +10 GiB/dev).
    # slot[e, c] = sorted position of the c-th token routed to expert e.
    slot = starts[:, None] + jnp.arange(C)[None, :]             # (E, C)
    valid = jnp.arange(C)[None, :] < counts[:, None]
    tok_idx = jnp.take(s_tok, jnp.clip(slot, 0, T * k - 1), axis=0)
    # 2D-index gather (no flatten+reshape: merging the expert and capacity
    # dims defeats their shardings and replicates the (E·C, d) buffer)
    xe = x[tok_idx]                                             # (E, C, d)
    xe = xe * valid[..., None].astype(xe.dtype)
    xe = sh.constrain(xe, ("act_experts", "act_capacity", None), rule_table)

    h = jnp.einsum("ecd,edf->ecf", xe, wi.astype(xe.dtype))
    h = sh.constrain(h, ("act_experts", "act_capacity", "act_expert_mlp"), rule_table)
    if wg is not None:
        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
        h = layers.activate(h, activation) * g
    else:
        h = layers.activate(h, activation)
    ye = jnp.einsum("ecf,efd->ecd", h, wo.astype(h.dtype))
    ye = sh.constrain(ye, ("act_experts", "act_capacity", None), rule_table)

    # --- combine: gather back by (expert, position), unsort, weight, sum
    # over the k choices — again no scatter (the unsort is a gather by the
    # inverse permutation; the k-sum is a reshape-reduce).
    pos_sorted = jnp.arange(T * k) - starts[s_ids]  # position within expert
    keep = pos_sorted < C
    # 2D gather ye[e, c] — reshaping ye to (E·C, d) first merges a
    # replicated dim with a sharded dim and XLA replicates the whole thing
    # (measured 90 GiB/dev at the 1M-token prefill shape).
    val_sorted = ye[s_ids, jnp.clip(pos_sorted, 0, C - 1)]
    val_sorted = val_sorted * keep[:, None].astype(val_sorted.dtype)
    # token-stream intermediates must stay token-sharded — replicated
    # (T·k, d) copies cost GiBs/device at 65k tokens (measured on grok).
    val_sorted = sh.constrain(val_sorted, ("act_tokens", None), rule_table)
    y_tk = jnp.take(val_sorted, inv, axis=0)        # (T·k, d) in (t, j) order
    y_tk = sh.constrain(y_tk, ("act_tokens", None), rule_table)
    y_tk = y_tk.reshape(T, k, d) * gates[..., None].astype(val_sorted.dtype)
    out = jnp.sum(y_tk, axis=1)
    out = sh.constrain(out, ("act_tokens", None), rule_table)
    return out.astype(x.dtype), aux
