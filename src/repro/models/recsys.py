"""RecSys model zoo: Wide&Deep, Two-Tower retrieval, MIND, DIN.

These four assigned architectures share the embedding substrate in
``models/embedding.py`` (huge row-sharded tables, EmbeddingBag) and differ in
their interaction op:

  wide-deep    concat + deep MLP ∥ wide linear          (ranking, BCE)
  two-tower    dot(user MLP, item MLP), in-batch softmax (retrieval — the
               paper's own setting; index layer on the item tower)
  mind         capsule dynamic routing → 4 interests, label-aware attention
  din          target attention over user history → MLP  (ranking, BCE)

Retrieval-scoring cells (1 query × 10⁶ candidates) run both the dense-matmul
baseline and the paper's ADC path over PQ codes.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import index_layer as il
from repro.core import pq
from repro.models import embedding, param
from repro.models.param import ParamSpec
from repro.sharding import rules as sh


def _mlp_specs(dims: tuple[int, ...], prefix: str = "mlp"):
    specs = {}
    for i in range(len(dims) - 1):
        specs[f"{prefix}{i}_w"] = ParamSpec((dims[i], dims[i + 1]), ("w_in", "w_hidden"))
        specs[f"{prefix}{i}_b"] = ParamSpec((dims[i + 1],), ("w_hidden",), init="zeros")
    return specs


def _mlp_apply(params, x, dims: tuple[int, ...], prefix: str = "mlp",
               final_act: bool = False):
    n = len(dims) - 1
    for i in range(n):
        x = x @ params[f"{prefix}{i}_w"].astype(x.dtype) + params[f"{prefix}{i}_b"].astype(x.dtype)
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ===========================================================================
# Wide & Deep
# ===========================================================================

class WideDeepConfig(NamedTuple):
    name: str = "wide-deep"
    n_sparse: int = 40
    vocab_per_field: int = 1_000_000
    embed_dim: int = 32
    mlp_dims: tuple[int, ...] = (1024, 512, 256)
    psum_lookup: bool = False        # shard_map masked-psum lookup instead of
    #                                  the XLA all-gather gather (§Perf)
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    rules: str = "recsys"

    @property
    def rule_table(self):
        return sh.RULE_REGISTRY[self.rules]

    @property
    def total_vocab(self) -> int:
        return self.n_sparse * self.vocab_per_field


def widedeep_specs(cfg: WideDeepConfig):
    d_in = cfg.n_sparse * cfg.embed_dim
    dims = (d_in, *cfg.mlp_dims, 1)
    return {
        # one fused table; field f owns rows [f·V, (f+1)·V)
        "table": ParamSpec((cfg.total_vocab, cfg.embed_dim), ("vocab_rows", "w_embed_dim"), scale=0.01),
        "wide": ParamSpec((cfg.total_vocab, 1), ("vocab_rows", None), scale=0.01),
        **_mlp_specs(dims),
    }


def widedeep_init(key, cfg: WideDeepConfig):
    return param.init_params(key, widedeep_specs(cfg), cfg.param_dtype)


def widedeep_forward(params, sparse_ids: jax.Array, cfg: WideDeepConfig) -> jax.Array:
    """sparse_ids (B, n_sparse) field-local ids -> logits (B,)."""
    rt = cfg.rule_table
    B, F = sparse_ids.shape
    offsets = (jnp.arange(F) * cfg.vocab_per_field)[None, :]
    gids = sparse_ids + offsets
    if cfg.psum_lookup:
        mesh = sh._current_mesh()
        lookup = lambda t, i: embedding.sharded_lookup(t, i, mesh, "model")
    else:
        lookup = embedding.lookup
    emb = lookup(params["table"], gids)                      # (B, F, e)
    emb = sh.constrain(emb, ("act_batch", "fields", None), rt)
    deep_in = emb.reshape(B, F * cfg.embed_dim).astype(cfg.dtype)
    d_in = F * cfg.embed_dim
    deep = _mlp_apply(params, deep_in, (d_in, *cfg.mlp_dims, 1))[:, 0]
    wide = jnp.sum(lookup(params["wide"], gids)[..., 0], axis=-1)
    return deep + wide.astype(deep.dtype)


def widedeep_loss(params, sparse_ids, labels, cfg: WideDeepConfig) -> jax.Array:
    logits = widedeep_forward(params, sparse_ids, cfg).astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ===========================================================================
# Two-tower retrieval (the paper's own setting)
# ===========================================================================

class TwoTowerConfig(NamedTuple):
    name: str = "two-tower-retrieval"
    item_vocab: int = 10_000_000
    embed_dim: int = 256
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    hist_len: int = 50
    scoring: str = "cosine"           # cosine | dot
    hinge_margin: float = 0.1
    index: il.IndexLayerConfig | None = None  # paper's index layer on item tower
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    rules: str = "recsys"

    @property
    def rule_table(self):
        return sh.RULE_REGISTRY[self.rules]

    @property
    def out_dim(self) -> int:
        return self.tower_dims[-1]


def twotower_specs(cfg: TwoTowerConfig):
    e = cfg.embed_dim
    specs = {
        "item_table": ParamSpec((cfg.item_vocab, e), ("vocab_rows", "w_embed_dim"), scale=0.01),
        **_mlp_specs((e, *cfg.tower_dims), prefix="user"),
        **_mlp_specs((e, *cfg.tower_dims), prefix="item"),
    }
    return specs


def twotower_init(key, cfg: TwoTowerConfig):
    params = param.init_params(key, twotower_specs(cfg), cfg.param_dtype)
    if cfg.index is not None:
        params["index"] = il.init(jax.random.fold_in(key, 1), cfg.index,
                                  dtype=cfg.param_dtype)
    return params


def user_tower(params, hist_ids: jax.Array, cfg: TwoTowerConfig) -> jax.Array:
    """hist_ids (B, L) (−1 padded) -> (B, out)."""
    pooled = embedding.bag_lookup(params["item_table"], hist_ids, combiner="mean")
    u = _mlp_apply(params, pooled.astype(cfg.dtype), (cfg.embed_dim, *cfg.tower_dims), prefix="user")
    return u


def item_tower(params, item_ids: jax.Array, cfg: TwoTowerConfig,
               apply_index: bool = False):
    """item_ids (B,) -> (B, out)[, distortion]."""
    emb = embedding.lookup(params["item_table"], item_ids)
    v = _mlp_apply(params, emb.astype(cfg.dtype), (cfg.embed_dim, *cfg.tower_dims), prefix="item")
    if apply_index and "index" in params:
        v, dist = il.apply(params["index"], v)
        return v, dist
    return v, jnp.float32(0.0)


def _score(u, v, scoring: str):
    if scoring == "cosine":
        u = u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)
        v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)
    return u @ v.T


def twotower_loss(params, hist_ids, pos_item_ids, cfg: TwoTowerConfig,
                  use_index: bool = True) -> jax.Array:
    """In-batch hinge loss (paper §3.2: cosine scoring, margin 0.1) +
    distortion term when the index layer is attached (Eq. 1)."""
    rt = cfg.rule_table
    u = user_tower(params, hist_ids, cfg)
    v, dist = item_tower(params, pos_item_ids, cfg, apply_index=use_index)
    u = sh.constrain(u, ("act_batch", None), rt)
    v = sh.constrain(v, ("act_batch", None), rt)
    scores = _score(u, v, cfg.scoring).astype(jnp.float32)  # (B, B)
    B = scores.shape[0]
    # (B, B) at B=65536 is 17 GB — shard rows over data, cols over model.
    scores = sh.constrain(scores, ("act_batch", "act_hidden"), rt)
    pos = jnp.diagonal(scores)
    hinge = jnp.maximum(0.0, cfg.hinge_margin + scores - pos[:, None])
    # mask the diagonal via iota compare (jnp.eye(65536) would materialize
    # 17 GB; B*(B-1) as a python int overflows int32 at this batch size)
    ii = jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
    hinge = jnp.where(ii == jj, 0.0, hinge)
    loss = jnp.sum(hinge) * (1.0 / (float(B) * (B - 1.0)))
    if use_index and "index" in params:
        loss = loss + cfg.index.distortion_weight * dist
    return loss


def twotower_retrieve_dense(params, hist_ids, cand_vecs, cfg: TwoTowerConfig):
    """Dense baseline: (1|B, L) history vs (N, out) candidate tower outputs."""
    u = user_tower(params, hist_ids, cfg)
    return _score(u, cand_vecs, cfg.scoring)


def twotower_retrieve_adc(params, hist_ids, cand_codes, cfg: TwoTowerConfig):
    """Paper serving path: ADC over PQ codes of the candidate corpus."""
    u = user_tower(params, hist_ids, cfg)
    if cfg.scoring == "cosine":
        u = u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)
    return il.adc_scores(params["index"], u, cand_codes)


# ===========================================================================
# MIND — multi-interest capsule routing
# ===========================================================================

class MINDConfig(NamedTuple):
    name: str = "mind"
    item_vocab: int = 2_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    rules: str = "recsys"

    @property
    def rule_table(self):
        return sh.RULE_REGISTRY[self.rules]


def mind_specs(cfg: MINDConfig):
    e = cfg.embed_dim
    return {
        "item_table": ParamSpec((cfg.item_vocab, e), ("vocab_rows", "w_embed_dim"), scale=0.01),
        "bilinear": ParamSpec((e, e), ("w_in", "w_hidden")),  # S matrix (B2I routing)
        **_mlp_specs((e, 4 * e, e), prefix="interest"),       # per-interest transform
    }


def mind_init(key, cfg: MINDConfig):
    return param.init_params(key, mind_specs(cfg), cfg.param_dtype)


def _squash(s: jax.Array) -> jax.Array:
    n2 = jnp.sum(jnp.square(s), axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * s / jnp.sqrt(n2 + 1e-9)


def mind_interests(params, hist_ids: jax.Array, cfg: MINDConfig) -> jax.Array:
    """Dynamic routing (B2I): hist (B, L) -> interests (B, I, e)."""
    B, L = hist_ids.shape
    I = cfg.n_interests
    valid = (hist_ids >= 0)
    e = embedding.lookup(params["item_table"], jnp.maximum(hist_ids, 0))
    e = jnp.where(valid[..., None], e, 0.0).astype(cfg.dtype)   # (B, L, e)
    eS = e @ params["bilinear"].astype(e.dtype)                 # behavior→interest space
    b = jnp.zeros((B, L, I), jnp.float32)                       # routing logits

    def routing_iter(b, _):
        w = jax.nn.softmax(b, axis=-1)                          # over interests
        w = jnp.where(valid[..., None], w, 0.0)
        s = jnp.einsum("bli,ble->bie", w, eS.astype(jnp.float32))
        u = _squash(s)                                          # (B, I, e)
        b_new = b + jnp.einsum("ble,bie->bli", eS.astype(jnp.float32), u)
        return b_new, u

    b, us = jax.lax.scan(routing_iter, b, None, length=cfg.capsule_iters)
    u = us[-1]
    u = _mlp_apply(params, u.astype(cfg.dtype), (cfg.embed_dim, 4 * cfg.embed_dim, cfg.embed_dim), prefix="interest")
    return u  # (B, I, e)


def mind_loss(params, hist_ids, pos_item_ids, cfg: MINDConfig) -> jax.Array:
    """Label-aware attention + in-batch sampled softmax."""
    u = mind_interests(params, hist_ids, cfg)                  # (B, I, e)
    v = embedding.lookup(params["item_table"], pos_item_ids).astype(cfg.dtype)  # (B, e)
    att = jnp.einsum("bie,ce->bic", u, v).astype(jnp.float32)  # (B, I, B)
    # label-aware: weight interests by (softmax over I of pow(score, 2))
    scores = jnp.max(att, axis=1)                              # (B, B) max over interests
    logp = jax.nn.log_softmax(scores, axis=-1)
    return -jnp.mean(jnp.diagonal(logp))


def mind_retrieve(params, hist_ids, cand_vecs, cfg: MINDConfig) -> jax.Array:
    """(B, L) × (N, e) -> (B, N): max over interests of dot scores."""
    u = mind_interests(params, hist_ids, cfg)
    return jnp.max(jnp.einsum("bie,ne->bin", u, cand_vecs.astype(u.dtype)), axis=1)


# ===========================================================================
# DIN — deep interest network (target attention)
# ===========================================================================

class DINConfig(NamedTuple):
    name: str = "din"
    item_vocab: int = 1_000_000
    embed_dim: int = 18
    hist_len: int = 100
    attn_dims: tuple[int, ...] = (80, 40)
    mlp_dims: tuple[int, ...] = (200, 80)
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    rules: str = "recsys"

    @property
    def rule_table(self):
        return sh.RULE_REGISTRY[self.rules]


def din_specs(cfg: DINConfig):
    e = cfg.embed_dim
    return {
        "item_table": ParamSpec((cfg.item_vocab, e), ("vocab_rows", "w_embed_dim"), scale=0.01),
        **_mlp_specs((4 * e, *cfg.attn_dims, 1), prefix="attn"),
        **_mlp_specs((2 * e, *cfg.mlp_dims, 1), prefix="head"),
    }


def din_init(key, cfg: DINConfig):
    return param.init_params(key, din_specs(cfg), cfg.param_dtype)


def din_forward(params, hist_ids: jax.Array, target_ids: jax.Array,
                cfg: DINConfig) -> jax.Array:
    """hist (B, L), target (B,) -> logits (B,). Target attention: the
    attention MLP sees [h, t, h−t, h⊙t] per history item (DIN eq. 3)."""
    e = cfg.embed_dim
    valid = hist_ids >= 0
    h = embedding.lookup(params["item_table"], jnp.maximum(hist_ids, 0)).astype(cfg.dtype)
    h = jnp.where(valid[..., None], h, 0.0)                    # (B, L, e)
    t = embedding.lookup(params["item_table"], target_ids).astype(cfg.dtype)  # (B, e)
    tt = jnp.broadcast_to(t[:, None], h.shape)
    attn_in = jnp.concatenate([h, tt, h - tt, h * tt], axis=-1)  # (B, L, 4e)
    w = _mlp_apply(params, attn_in, (4 * e, *cfg.attn_dims, 1), prefix="attn")[..., 0]
    w = jnp.where(valid, w, -1e30)
    w = jax.nn.softmax(w.astype(jnp.float32), axis=-1).astype(cfg.dtype)
    pooled = jnp.einsum("bl,ble->be", w, h)
    head_in = jnp.concatenate([pooled, t], axis=-1)
    return _mlp_apply(params, head_in, (2 * e, *cfg.mlp_dims, 1), prefix="head")[:, 0]


def din_loss(params, hist_ids, target_ids, labels, cfg: DINConfig) -> jax.Array:
    logits = din_forward(params, hist_ids, target_ids, cfg).astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def din_score_candidates(params, hist_ids: jax.Array, cand_ids: jax.Array,
                         cfg: DINConfig, chunk: int = 8192) -> jax.Array:
    """Bulk target-attention scoring of N candidates for ONE user:
    hist (L,), cand (N,) -> (N,). Chunked over candidates (no N×L blowup
    beyond chunk×L)."""
    N = cand_ids.shape[0]
    nc = N // chunk
    hist_b = jnp.broadcast_to(hist_ids[None], (chunk, hist_ids.shape[0]))

    def one(chunk_ids):
        return din_forward(params, hist_b, chunk_ids, cfg)

    out = jax.lax.map(one, cand_ids[: nc * chunk].reshape(nc, chunk))
    return out.reshape(-1)
