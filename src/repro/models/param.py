"""Declarative parameter specs with logical sharding axes.

A model declares its parameters as a pytree of ``ParamSpec``; from that one
tree we derive (a) initialized arrays, (b) the logical-axis tree consumed by
sharding.rules, (c) ShapeDtypeStructs for the dry-run (no allocation).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | eye
    scale: float | None = None  # None → 1/sqrt(fan_in)
    dtype: Any = None           # None → model param_dtype


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(key, spec: ParamSpec, default_dtype):
    dtype = spec.dtype or default_dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "eye":
        assert len(spec.shape) >= 2 and spec.shape[-1] == spec.shape[-2]
        eye = jnp.eye(spec.shape[-1], dtype=dtype)
        return jnp.broadcast_to(eye, spec.shape)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    return scale * jax.random.normal(key, spec.shape, dtype=jnp.float32).astype(dtype)


def init_params(key: jax.Array, spec_tree, param_dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s, param_dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def logical_tree(spec_tree):
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=is_spec)


def abstract_params(spec_tree, param_dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype),
        spec_tree,
        is_leaf=is_spec,
    )


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)
