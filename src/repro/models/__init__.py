"""Model zoo substrate: transformers (dense/MoE/GQA), GNNs, recsys models.

All models follow the same conventions:
  * parameters are plain pytrees built from ``param.ParamSpec`` trees, with a
    parallel tree of logical sharding axes (see sharding.rules);
  * forward functions are pure and jit/pjit friendly (lax control flow only);
  * every family exposes ``init_params``, a training forward returning a
    scalar loss, and (where the family serves) prefill/decode/score paths.
"""
