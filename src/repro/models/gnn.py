"""GraphSAGE (Hamilton et al. 2017) substrate.

Message passing is built on ``jax.ops.segment_sum``/``segment_max`` over an
edge-index (src → dst) scatter — JAX has no sparse SpMM beyond BCOO, so this
IS the system's GNN kernel layer (kernel_taxonomy §GNN, SpMM regime).

Two execution modes:
  * ``full_batch_forward`` — whole-graph propagation from an edge list
    (full_graph_sm / ogb_products cells);
  * ``minibatch_forward`` — seed nodes + dense fanout neighbor arrays from
    the real CSR sampler in data/graph.py (minibatch_lg cell), GraphSAGE's
    original training mode.

The paper's index layer attaches to the output node embeddings (GraphSAGE's
unsupervised use feeds ANN retrieval) — see examples/gnn_index.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import param
from repro.models.param import ParamSpec
from repro.sharding import rules as sh


class GraphSAGEConfig(NamedTuple):
    name: str
    d_in: int
    d_hidden: int = 128
    num_layers: int = 2
    num_classes: int = 41
    aggregator: str = "mean"          # mean | max
    sample_sizes: tuple[int, ...] = (25, 10)
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    rules: str = "gnn"

    @property
    def rule_table(self):
        return sh.RULE_REGISTRY[self.rules]


def param_specs(cfg: GraphSAGEConfig):
    specs = {}
    d_prev = cfg.d_in
    for l in range(cfg.num_layers):
        specs[f"layer{l}"] = {
            "w_self": ParamSpec((d_prev, cfg.d_hidden), ("w_in", "w_out")),
            "w_neigh": ParamSpec((d_prev, cfg.d_hidden), ("w_in", "w_out")),
            "b": ParamSpec((cfg.d_hidden,), ("w_out",), init="zeros"),
        }
        d_prev = cfg.d_hidden
    specs["classifier"] = ParamSpec((cfg.d_hidden, cfg.num_classes), ("w_in", None))
    return specs


def init_params(key: jax.Array, cfg: GraphSAGEConfig):
    return param.init_params(key, param_specs(cfg), cfg.param_dtype)


def _aggregate_edges(h: jax.Array, src: jax.Array, dst: jax.Array,
                     num_nodes: int, aggregator: str) -> jax.Array:
    """Scatter messages h[src] into dst buckets. h (N, d) -> (N, d)."""
    msgs = jnp.take(h, src, axis=0)
    if aggregator == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)
        deg = jax.ops.segment_sum(
            jnp.ones_like(dst, h.dtype), dst, num_segments=num_nodes
        )
        return s / jnp.maximum(deg, 1.0)[:, None]
    if aggregator == "max":
        return jax.ops.segment_max(msgs, dst, num_segments=num_nodes)
    raise ValueError(aggregator)


def _sage_layer(lp, h_self: jax.Array, h_neigh: jax.Array) -> jax.Array:
    out = h_self @ lp["w_self"].astype(h_self.dtype)
    out = out + h_neigh @ lp["w_neigh"].astype(h_neigh.dtype)
    out = jax.nn.relu(out + lp["b"].astype(out.dtype))
    # L2-normalize as in the paper (Algorithm 1, line 7)
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)


def full_batch_forward(params, feats: jax.Array, src: jax.Array,
                       dst: jax.Array, cfg: GraphSAGEConfig) -> jax.Array:
    """feats (N, F), edge endpoints (E,) each -> logits (N, C)."""
    rt = cfg.rule_table
    N = feats.shape[0]
    h = feats.astype(cfg.dtype)
    for l in range(cfg.num_layers):
        h_n = _aggregate_edges(h, src, dst, N, cfg.aggregator)
        h = _sage_layer(params[f"layer{l}"], h, h_n)
        h = sh.constrain(h, ("act_nodes", "act_hidden"), rt)
    return h @ params["classifier"].astype(h.dtype)


def node_embeddings_minibatch(params, feats_by_hop, cfg: GraphSAGEConfig):
    """Minibatch forward from dense fanout arrays (GraphSAGE Algorithm 2).

    ``feats_by_hop``: list of (B, f1, ..., f_h, F) feature arrays, hop 0 =
    seeds (B, F), hop 1 = (B, f1, F), ... produced by data.graph.sample_blocks.
    Returns (B, d_hidden) embeddings of the seed nodes.
    """
    agg = jnp.mean if cfg.aggregator == "mean" else (
        lambda x, axis: jnp.max(x, axis=axis))
    h = [f.astype(cfg.dtype) for f in feats_by_hop]
    for l in range(cfg.num_layers):
        nxt = []
        for hop in range(len(h) - 1):
            h_neigh = agg(h[hop + 1], axis=-2)
            nxt.append(_sage_layer(params[f"layer{l}"], h[hop], h_neigh))
        h = nxt
    return h[0]


def minibatch_forward(params, feats_by_hop, cfg: GraphSAGEConfig) -> jax.Array:
    return node_embeddings_minibatch(params, feats_by_hop, cfg) @ params[
        "classifier"
    ].astype(cfg.dtype)


def loss_full_batch(params, feats, src, dst, labels, mask, cfg) -> jax.Array:
    """Masked node-classification cross-entropy (full-graph training)."""
    logits = full_batch_forward(params, feats, src, dst, cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_minibatch(params, feats_by_hop, labels, cfg) -> jax.Array:
    logits = minibatch_forward(params, feats_by_hop, cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def loss_graph_batch(params, feats, src, dst, graph_ids, labels, num_graphs,
                     cfg: GraphSAGEConfig) -> jax.Array:
    """Graph-level classification on a disjoint union of small graphs (the
    'molecule' cell): propagate on the union, mean-pool nodes per graph via
    segment_sum, classify each graph."""
    N = feats.shape[0]
    h = feats.astype(cfg.dtype)
    for l in range(cfg.num_layers):
        h_n = _aggregate_edges(h, src, dst, N, cfg.aggregator)
        h = _sage_layer(params[f"layer{l}"], h, h_n)
    pooled = jax.ops.segment_sum(h, graph_ids, num_segments=num_graphs)
    cnt = jax.ops.segment_sum(jnp.ones((N,), h.dtype), graph_ids,
                              num_segments=num_graphs)
    pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
    logits = (pooled @ params["classifier"].astype(h.dtype)).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
