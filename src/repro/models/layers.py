"""Shared transformer layer primitives: norms, RoPE, activations, attention.

Attention is implemented blockwise (scan over query chunks, f32 softmax per
chunk) so the (B, H, S, S) score tensor is never materialized — the pure-JAX
equivalent of flash attention, sized so the per-chunk score tile stays within
a few GB/device at the production shapes (see DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps fully-masked rows finite


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def _f32_sumsq_lastdim(x: jax.Array) -> jax.Array:
    """Σ x² over the last axis with f32 ACCUMULATION but bf16 operands.

    An ``x.astype(f32)`` here is the first consumer of every saved layer
    boundary; XLA hoists that convert out of the backward while-loop and
    materializes an f32 copy of the whole (L, B, S, d) residual stack
    (measured ~5.6 GiB/device on the 340B arch). A batched self-dot with
    preferred_element_type keeps the stats in f32 without any f32 copy of x.
    """
    nb = x.ndim - 1
    dims = (((nb,), (nb,)), (tuple(range(nb)), tuple(range(nb))))
    return jax.lax.dot_general(x, x, dims, preferred_element_type=jnp.float32)


def _f32_sum_lastdim(x: jax.Array) -> jax.Array:
    ones = jnp.ones((x.shape[-1],), x.dtype)
    nb = x.ndim - 1
    dims = (((nb,), (0,)), ((), ()))
    return jax.lax.dot_general(x, ones, dims,
                               preferred_element_type=jnp.float32)


def rms_norm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    d = x.shape[-1]
    ss = _f32_sumsq_lastdim(x)[..., None] / d          # f32 stats
    inv = jax.lax.rsqrt(ss + eps).astype(x.dtype)      # bf16 apply
    out = x * inv
    if scale is not None:
        out = out * scale.astype(x.dtype)
    return out


def nonparam_layer_norm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale, no bias). f32 stats,
    model-dtype application (see _f32_sumsq_lastdim)."""
    d = x.shape[-1]
    mu = (_f32_sum_lastdim(x) / d)[..., None]
    ss = (_f32_sumsq_lastdim(x) / d)[..., None]
    var = jnp.maximum(ss - jnp.square(mu), 0.0)
    inv = jax.lax.rsqrt(var + eps)
    return ((x - mu.astype(x.dtype)) * inv.astype(x.dtype))


def apply_norm(x: jax.Array, scale: jax.Array | None, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, scale)
    if kind == "layernorm_nonparam":
        return nonparam_layer_norm(x)
    raise ValueError(f"unknown norm {kind!r}")


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":  # squared ReLU (Nemotron-4 / Primer)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise causal attention (training / prefill)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("q_chunk", "causal"))
def blockwise_attention(
    q: jax.Array,  # (B, S, Hq, hd)
    k: jax.Array,  # (B, S, Hkv, hd)
    v: jax.Array,  # (B, S, Hkv, hd)
    *,
    q_chunk: int = 256,
    causal: bool = True,
) -> jax.Array:
    """Causal GQA attention, scanned over query chunks with f32 softmax.

    Peak temp is (B, Hkv, rep, q_chunk, S) f32 per chunk instead of the full
    (B, H, S, S) score tensor.
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    scale = hd**-0.5
    q_chunk = min(q_chunk, S)
    assert S % q_chunk == 0, f"seq {S} must be divisible by q_chunk {q_chunk}"
    nq = S // q_chunk

    qg = q.reshape(B, S, Hkv, rep, hd).transpose(0, 2, 3, 1, 4)  # (B,Hkv,rep,S,hd)
    kg = k.transpose(0, 2, 1, 3)  # (B, Hkv, S, hd)
    vg = v.transpose(0, 2, 1, 3)
    qg = qg.reshape(B, Hkv, rep, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    kv_pos = jnp.arange(S)

    # jax.checkpoint per chunk: without it the q-chunk scan stacks each
    # chunk's softmax residuals, resurrecting the full (B,H,S,S) tensor in
    # the backward pass (measured: 213 GiB/dev at the 4k-train shape). With
    # it, the bwd recomputes scores chunk-by-chunk — flash-attention
    # semantics in pure JAX.
    @jax.checkpoint
    def one_chunk(ci, qc):
        # qc: (B, Hkv, rep, q_chunk, hd)
        scores = jnp.einsum(
            "bhrqd,bhsd->bhrqs", qc.astype(jnp.float32), kg.astype(jnp.float32)
        ) * scale
        if causal:
            q_pos = ci * q_chunk + jnp.arange(q_chunk)
            mask = q_pos[:, None] >= kv_pos[None, :]  # (q_chunk, S)
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhrqs,bhsd->bhrqd", w, vg.astype(jnp.float32))
        return out.astype(q.dtype)

    outs = jax.lax.map(lambda args: one_chunk(*args), (jnp.arange(nq), qg))
    # (nq, B, Hkv, rep, q_chunk, hd) -> (B, S, Hq, hd)
    outs = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, rep, S, hd)
    return outs.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, hd)


def decode_attention(
    q: jax.Array,        # (B, Hq, hd) single step
    k_cache: jax.Array,  # (B, Hkv, S, hd)
    v_cache: jax.Array,  # (B, Hkv, S, hd)
    length: jax.Array,   # (B,) valid prefix length (new token already written)
) -> jax.Array:
    B, Hq, hd = q.shape
    Hkv = k_cache.shape[1]
    rep = Hq // Hkv
    S = k_cache.shape[2]
    scale = hd**-0.5
    qg = q.reshape(B, Hkv, rep, hd)
    scores = jnp.einsum(
        "bhrd,bhsd->bhrs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(S)[None, :] < length[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrs,bhsd->bhrd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (vocab can be huge and sharded)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def softmax_xent_chunked(
    x: jax.Array,        # (T, d) final hidden states
    head: jax.Array,     # (V, d) output projection (logits = x @ headᵀ)
    labels: jax.Array,   # (T,) int32
    *,
    chunk: int = 8192,
) -> jax.Array:
    """Mean cross-entropy without materializing all (T, V) logits at once.

    Scans over token chunks; each chunk's logits are recomputed in the
    backward pass (jax.checkpoint), so peak memory is O(chunk · V).
    """
    T, d = x.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, f"tokens {T} must be divisible by chunk {chunk}"
    nc = T // chunk
    xc = x.reshape(nc, chunk, d)
    lc = labels.reshape(nc, chunk)

    @jax.checkpoint
    def chunk_loss(xi, li):
        # The logits matmul runs in the model dtype and is cast to f32 AFTER:
        # (a) an .astype(f32) copy of the head forces an involuntary SPMD
        # rematerialization per chunk; (b) preferred_element_type=f32 is
        # worse — its transpose rule makes the residual-stream cotangent
        # f32, poisoning the whole backward into f32 weight-stack copies
        # (measured +8 GiB/dev on nemotron). The f32 cast after the dot
        # keeps logsumexp numerics in f32 while its transpose returns the
        # cotangent to the model dtype at the boundary.
        logits = jax.lax.dot_general(
            xi, head, (((1,), (1,)), ((), ()))).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[:, None], axis=-1)[:, 0]
        return jnp.sum(lse - gold)

    def body(carry, inp):
        xi, li = inp
        return carry + chunk_loss(xi, li), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc))
    return total / T
