"""ChurnController: sequence stage → flush → compact between Engine batches.

The policy layer over ``churn.ops``: owns WHEN the primitives run so the
serving loop just interleaves ``engine.search`` with ``controller.step``.
The controller's one structural move happens at construction — it installs
the staging buffer (``ops.with_staging``) BEFORE the first search, so every
executable the Engine compiles is traced with staging attached and the
whole add/delete/flush/compact cycle after that is shape-preserving:
zero recompiles in steady state. ``engine.state`` is swapped wholesale
after each mutation (the Engine re-reads it per batch).

Thresholds:

  flush_at     staging occupancy fraction that triggers a flush after the
               mutations of a ``step`` (keeps the side pass small).
  compact_at   tombstone fraction of live capacity that triggers a
               compaction (reclaims dead blocks before they dominate scan
               work). Compaction also absorbs staged rows that flushes
               could not place (their lists had no holes).
  imbalance_threshold
               max/mean shard-occupancy ratio beyond which a sharded state
               is rebalanced (``ops.shard_rebalance`` — the live
               generalization of ``ivf.shard_split``).

Instrumented through ``repro.obs`` on the Engine's own always-on registry,
so ``Engine.stats()`` reports the churn block next to its serving counters:
counters ``churn.staged/flushed/tombstoned/flushes/compactions/rebalances/
grows``, gauges ``churn.staged_rows/tombstoned_rows``, and the
``churn.flush_ms`` distribution + ``churn.compact``/``churn.flush`` spans.

The tombstone tally is the controller's own bookkeeping: once flipped to
−1, a tombstoned row is indistinguishable from a build-time padding hole,
so the gauge counts deletes since the last compaction (live-row delta per
``remove``), resetting to zero when compaction reclaims them.

With a ``BackgroundCompactor`` attached (``compactor=``), ``compact()``
becomes a non-blocking submit and each ``step`` polls for a finished pass
to swap in — the repack leaves the critical path entirely. Flushes are
deferred while a pass is in flight (a flush moves the CSR, which would
invalidate the worker's snapshot); the one blocking fallback is an ``add``
into a full buffer, which joins the worker before flushing.
"""
from __future__ import annotations

from repro import obs
from repro.churn import ops


class ChurnController:
    """Drive live churn on a ``search.Engine`` (see module docstring)."""

    def __init__(self, engine, *, staging_rows: int = 1024,
                 flush_at: float = 0.5, compact_at: float = 0.25,
                 imbalance_threshold: float = 1.25, compactor=None):
        self.engine = engine
        self.flush_at = float(flush_at)
        self.compact_at = float(compact_at)
        self.imbalance_threshold = float(imbalance_threshold)
        self.obs = getattr(engine, "obs", None) or obs.default_registry()
        self.compactor = compactor
        self._tombstoned_at_submit = 0
        self._tombstoned = 0
        # install staging NOW, before the first search compiles — the
        # buffer is pytree structure, so this is the one structural change
        # the controller ever makes
        if getattr(engine.state, "staging", None) is None:
            engine.state = ops.with_staging(engine.state, staging_rows)
        self._gauges()

    # -- metric plumbing ---------------------------------------------------
    def _count(self, name: str, n: int = 1) -> None:
        self.obs.counter(f"churn.{name}").inc(n)

    def _gauges(self) -> None:
        self.obs.gauge("churn.staged_rows").set(ops.staged_rows(self.state))
        self.obs.gauge("churn.tombstoned_rows").set(self._tombstoned)

    @property
    def state(self):
        return self.engine.state

    # -- mutations ---------------------------------------------------------
    def add(self, X_new, new_ids) -> None:
        """Stage new rows; they are served by the very next query. Flushes
        (and compacts, if flushing cannot free enough slots) first when the
        buffer cannot hold the batch."""
        n = len(new_ids)
        if ops.free_slots(self.state) < n:
            if self.compactor is not None and self.compactor.in_flight:
                # the one blocking fallback: a full buffer needs a flush,
                # and a flush would invalidate the in-flight snapshot
                self.compactor.join()
                self.poll_background()
            self.flush()
        if ops.free_slots(self.state) < n:
            self._compact_sync()
        self.engine.state = ops.stage(self.state, X_new, new_ids)
        self._count("staged", n)
        self._gauges()

    def remove(self, remove_ids) -> None:
        """Tombstone rows by id — O(1), visible to the next query."""
        before = ops.live_rows(self.state)
        self.engine.state = ops.tombstone(self.state, remove_ids)
        dead = before - ops.live_rows(self.state)
        self._tombstoned += dead
        self._count("tombstoned", dead)
        self._gauges()

    # -- maintenance -------------------------------------------------------
    def flush(self) -> int:
        """Fold staged rows into CSR holes (shape-preserving). Deferred
        (returns 0) while a background compaction is in flight — a flush
        moves the CSR and would force the worker's result to be
        discarded."""
        if self.compactor is not None and self.compactor.in_flight:
            self._count("flushes_deferred")
            return 0
        with self.obs.span("churn.flush") as sp:
            new_state, moved = ops.flush(self.state)
            sp.sync(new_state.index.ids if hasattr(new_state, "index")
                    else new_state.ids)
        self.engine.state = new_state
        self.obs.distribution("churn.flush_ms").observe(sp.elapsed_ms)
        self._count("flushes")
        self._count("flushed", moved)
        self._gauges()
        return moved

    def compact(self) -> None:
        """Repack the live (+ staged) rows, reclaiming tombstoned blocks.
        With a ``BackgroundCompactor`` attached this is a non-blocking
        submit (the swap lands on a later ``step``/``poll_background``);
        without one it runs synchronously on the calling thread.
        Steady-state compactions preserve every shape; genuine growth
        (capacity or probe window) is counted via ``churn.grows`` — it
        recompiles once, legitimately."""
        if self.compactor is not None:
            if self.compactor.submit():
                self._tombstoned_at_submit = self._tombstoned
                self._count("bg_submitted")
            return
        self._compact_sync()

    def _compact_sync(self) -> None:
        st = self.state
        cap_before = (st.index.capacity if hasattr(st, "index")
                      else int(st.codes.shape[1]))
        mb_before = st.max_blocks
        with self.obs.span("churn.compact") as sp:
            new_state = ops.compact(st)
            sp.sync(new_state.ids if not hasattr(new_state, "index")
                    else new_state.index.ids)
        self.engine.state = new_state
        cap_after = (new_state.index.capacity
                     if hasattr(new_state, "index")
                     else int(new_state.codes.shape[1]))
        if cap_after != cap_before or new_state.max_blocks != mb_before:
            self._count("grows")
        self._count("compactions")
        self._tombstoned = 0
        self._gauges()

    def poll_background(self) -> bool:
        """Swap in a finished background compaction, if one is ready.
        Deletes that landed since the submit were replayed by the
        compactor, so only they remain tombstoned after the swap."""
        if self.compactor is None:
            return False
        if not self.compactor.poll():
            return False
        self._tombstoned = max(
            0, self._tombstoned - self._tombstoned_at_submit)
        self._count("compactions")
        self._gauges()
        return True

    def maybe_rebalance(self) -> bool:
        """Sharded states only: rebalance when max/mean shard occupancy
        exceeds the threshold. Returns whether a rebalance ran."""
        st = self.state
        if not hasattr(st, "list_offsets") or not hasattr(st, "mesh"):
            return False
        import numpy as np

        rows = (np.asarray(st.ids) >= 0).sum(axis=1).astype(np.float64)
        if st.staging is not None:
            rows += (np.asarray(st.staging.ids) >= 0).sum(axis=1)
        imbalance = float(rows.max()) / max(float(rows.mean()), 1.0)
        if imbalance <= self.imbalance_threshold:
            return False
        with self.obs.span("churn.rebalance"):
            self.engine.state = ops.shard_rebalance(st)
        self._count("rebalances")
        self._tombstoned = 0   # rebalance repacks, reclaiming tombstones too
        self._gauges()
        return True

    # -- the per-batch policy ----------------------------------------------
    def step(self, *, add=None, add_ids=None, remove_ids=None) -> None:
        """One churn tick between query batches: apply this tick's deletes
        and adds, then run whatever maintenance the thresholds call for."""
        self.poll_background()
        if remove_ids is not None and len(remove_ids):
            self.remove(remove_ids)
        if add is not None and len(add_ids):
            self.add(add, add_ids)
        st = self.state
        cap = st.staging.ids.size if st.staging is not None else 0
        if cap and ops.staged_rows(st) >= self.flush_at * cap:
            self.flush()
        total_cap = (st.index.capacity if hasattr(st, "index")
                     else int(st.codes.shape[1]) * st.codes.shape[0])
        if self._tombstoned >= self.compact_at * max(total_cap, 1):
            self.compact()
        self.maybe_rebalance()
