"""Host-side churn primitives: stage, flush, tombstone, compact, rebalance.

The mutation half of ``repro.churn``. Every primitive here takes a servable
state, returns a new one, and is shape-preserving wherever the serving hot
path can see it:

  * ``tombstone`` — O(1) deletes: flip ids to −1 (CSR, staging, and exact
    corpora alike); the in-kernel mask makes the rows score −inf on the
    very next query. Never reshapes anything.
  * ``stage`` — encode new rows against the state's frozen quantizers and
    park them in free staging slots (``churn.buffer``); raises when the
    buffer is full so the caller can flush/compact first.
  * ``flush`` — fold staged rows into the CSR holes of their target lists.
    Holes only: list offsets, shapes, and statics are untouched, and rows
    that don't fit (their list has no holes) simply stay staged.
  * ``compact`` — host-side repack of the live (+ optionally staged) rows,
    reclaiming tombstoned blocks. Capacity is padded back up to the
    original whenever the live set still fits, so steady-state compaction
    (adds ≈ deletes) swaps arrays of identical shape under the compiled
    executables — zero recompiles. Genuine growth returns bigger arrays
    (and possibly a bigger ``max_blocks`` static): a legitimate, counted
    recompile, not steady state.
  * ``shard_rebalance`` — the sharded generalization of
    ``index/ivf.py::shard_split``: gather every live row, re-partition by
    id rank, repack per shard. Codes are carried, never re-encoded, so
    scores are bit-identical to a fresh rebuild of the same rows.

States are dispatched by shape ("duck typing"), not by class: this module
sits below ``repro.search`` (whose modules import ``churn.buffer``) and
must not import it back. The sharded placement helper is therefore inlined
here — same spec as ``search/sharded.py::_place_sharded``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.churn import buffer as churn_buffer
from repro.churn.buffer import StagingBuffer
from repro.index import ivf as index_ivf
from repro.index.ivf import IVFPQIndex


# ---------------------------------------------------------------------------
# State dispatch
# ---------------------------------------------------------------------------


def _kind(state) -> str:
    """Which churn family a state belongs to, by structure (no
    ``repro.search`` imports — see module docstring)."""
    if isinstance(state, IVFPQIndex):
        return "index"
    if hasattr(state, "index"):                    # search.flat.ADCState
        return "adc"
    if hasattr(state, "tiles"):                    # StreamingExactState
        return "exact_stream"
    if hasattr(state, "list_offsets"):             # ShardedADCState
        return "sharded_adc"
    if hasattr(state, "XR"):                       # Exact(/Sharded)State
        return "sharded_exact" if state.XR.ndim == 3 else "exact"
    raise TypeError(
        f"{type(state).__name__} is not a churn-capable state (expected an "
        "IVFPQIndex, an ADC/exact searcher state, or a sharded twin)")


def _place(arr: jax.Array, mesh, axes: tuple[str, ...]) -> jax.Array:
    """Partition a stacked (S, ...) per-shard array: leading axis over the
    resolved row axes (the ``search/sharded.py::_place_sharded`` spec)."""
    spec = P(axes if len(axes) > 1 else axes[0], *([None] * (arr.ndim - 1)))
    return jax.device_put(arr, NamedSharding(mesh, spec))


def _place_buffer(buf: StagingBuffer, mesh, axes) -> StagingBuffer:
    return StagingBuffer(codes=_place(buf.codes, mesh, axes),
                         ids=_place(buf.ids, mesh, axes),
                         lists=_place(buf.lists, mesh, axes))


def _row_lists(offsets: np.ndarray, capacity: int,
               num_lists: int) -> np.ndarray:
    """Coarse-list id of every CSR row (sentinel/pad rows clamp into the
    last list; they are holes, so the value never matters)."""
    rl = np.searchsorted(offsets, np.arange(capacity), side="right") - 1
    return np.clip(rl, 0, num_lists - 1).astype(np.int32)


def _repack_bound(live: int, num_lists: int, block_size: int) -> int:
    """Upper bound on ``pack()``'s capacity for ``live`` rows however they
    distribute over lists: per-list rounding wastes < one block per list,
    plus the sentinel block — a block multiple by construction."""
    bound = live + num_lists * (block_size - 1) + block_size
    return math.ceil(bound / block_size) * block_size


# ---------------------------------------------------------------------------
# Occupancy facts (host-side, for controllers/tests)
# ---------------------------------------------------------------------------


def staged_rows(state) -> int:
    """Live rows currently staged (0 when no buffer is attached)."""
    stg = getattr(state, "staging", None)
    if stg is None:
        return 0
    return int(np.sum(np.asarray(stg.ids) >= 0))


def free_slots(state) -> int:
    """Free staging slots across all shards."""
    stg = getattr(state, "staging", None)
    if stg is None:
        return 0
    return int(np.sum(np.asarray(stg.ids) < 0))


def live_rows(state) -> int:
    """Total live (servable) rows: CSR/corpus plus staged."""
    kind = _kind(state)
    if kind == "index":
        return int(np.sum(np.asarray(state.ids) >= 0))
    if kind == "adc":
        return int(np.sum(np.asarray(state.index.ids) >= 0)) \
            + staged_rows(state)
    if kind == "exact_stream":
        return sum(int(np.sum(np.asarray(t) >= 0)) for t in state.tile_ids)
    # sharded_adc / exact / sharded_exact all carry a stacked/flat ids array
    return int(np.sum(np.asarray(state.ids) >= 0)) + staged_rows(state)


# ---------------------------------------------------------------------------
# Staging
# ---------------------------------------------------------------------------


def with_staging(state, capacity: int, *, window_slack: int | None = None):
    """Attach an (empty) append buffer of ``capacity`` rows — per shard for
    sharded states. Do this ONCE, before the first search: the buffer is
    part of the pytree structure, so installing it later invalidates
    compiled executables (installing it first means they are traced with
    staging from the start and churn never recompiles them).

    ``window_slack`` extra blocks are added to the static ``max_blocks``
    probe window (default: the buffer capacity in blocks) so lists that
    grow when staged rows are compacted in stay fully scanned without a
    recompile — out-of-range window tiles redirect to the sentinel hole
    block, so slack costs only masked scan work.

    Capacity also gains worst-case block-rounding headroom (≤ one block
    per list, holes past the last list offset — pure reserve, never
    scanned or flushed into): a ``compact()`` of the same live row count
    can round per-list padding differently, and without the reserve a
    one-block drift would grow the arrays and recompile. With it,
    steady-state compaction is shape-preserving by construction.
    """
    kind = _kind(state)
    if kind == "adc":
        if state.staging is not None:
            return state
        idx = state.index
        slack = (math.ceil(capacity / idx.block_size)
                 if window_slack is None else window_slack)
        buf = churn_buffer.empty(capacity, idx.codes.shape[1],
                                 idx.codes.dtype)
        mb = (state.max_blocks if state.max_blocks >= 1
              else idx.max_list_blocks())
        idx = _pad_capacity(idx, _repack_bound(
            int(np.sum(np.asarray(idx.ids) >= 0)), idx.num_lists,
            idx.block_size))
        return dataclasses.replace(state, index=idx, staging=buf,
                                   max_blocks=mb + slack)
    if kind == "sharded_adc":
        if state.staging is not None:
            return state
        slack = (math.ceil(capacity / state.block_size)
                 if window_slack is None else window_slack)
        buf = churn_buffer.empty(capacity, state.codes.shape[-1],
                                 state.codes.dtype,
                                 shards=state.codes.shape[0])
        mb = state.max_blocks
        if mb < 1:
            lens = np.diff(np.asarray(state.list_offsets), axis=1)
            mb = max(int(lens.max()) // state.block_size, 1)
        ids_np = np.asarray(state.ids)
        num_lists = np.asarray(state.list_offsets).shape[1] - 1
        cap = max(int(state.codes.shape[1]), _repack_bound(
            int((ids_np >= 0).sum(axis=1).max()), num_lists,
            state.block_size))
        extra = cap - int(state.codes.shape[1])
        mesh, axes = state.mesh, state.axes
        out = dataclasses.replace(
            state, staging=_place_buffer(buf, mesh, axes),
            max_blocks=mb + slack)
        if extra:
            codes = np.pad(np.asarray(state.codes),
                           ((0, 0), (0, extra), (0, 0)))
            ids = np.pad(ids_np, ((0, 0), (0, extra)), constant_values=-1)
            out = dataclasses.replace(
                out, codes=_place(jnp.asarray(codes), mesh, axes),
                ids=_place(jnp.asarray(ids), mesh, axes))
        return out
    raise TypeError(
        "append buffers require a quantized (ADC) state — exact backends "
        "store raw vectors and take no staged codes")


def stage(state, X_new: jax.Array, new_ids):
    """Encode raw rows against the state's quantizers and park them in free
    staging slots (most-free shard first on sharded states, so the side
    passes stay balanced). Raises ``ValueError`` when the buffer cannot
    hold them — ``flush``/``compact`` first (ChurnController does).

    Encoding uses the state's stored rotation — the frozen R₀ under fused
    refresh, exactly like the frozen codebooks the CSR codes live in, so
    staged and resident rows score through one LUT pack."""
    kind = _kind(state)
    stg = getattr(state, "staging", None)
    if stg is None:
        raise ValueError(
            "state has no staging buffer — churn.with_staging(state, cap) "
            "first (before the first search, to keep executables warm)")
    new_ids = np.asarray(new_ids, dtype=np.int32)
    m = int(new_ids.shape[0])

    if kind == "adc":
        idx = state.index
        XR = jnp.asarray(X_new) @ idx.R.astype(X_new.dtype)
        list_ids, codes = index_ivf.encode(XR, idx.coarse, idx.quantizer)
        s_codes = np.asarray(stg.codes).copy()
        s_ids = np.asarray(stg.ids).copy()
        s_lists = np.asarray(stg.lists).copy()
        free = np.nonzero(s_ids < 0)[0]
        if free.size < m:
            raise ValueError(
                f"staging buffer full: {m} new rows, {free.size} free slots "
                "— flush() or compact() first")
        sl = free[:m]
        s_codes[sl] = np.asarray(codes)
        s_ids[sl] = new_ids
        s_lists[sl] = np.asarray(list_ids, dtype=np.int32)
        return dataclasses.replace(state, staging=StagingBuffer(
            codes=jnp.asarray(s_codes), ids=jnp.asarray(s_ids),
            lists=jnp.asarray(s_lists)))

    if kind == "sharded_adc":
        XR = jnp.asarray(X_new) @ state.R.astype(X_new.dtype)
        list_ids, codes = index_ivf.encode(XR, state.coarse, state.quantizer)
        codes = np.asarray(codes)
        list_ids = np.asarray(list_ids, dtype=np.int32)
        s_codes = np.asarray(stg.codes).copy()
        s_ids = np.asarray(stg.ids).copy()
        s_lists = np.asarray(stg.lists).copy()
        free = [list(np.nonzero(s_ids[s] < 0)[0]) for s in
                range(s_ids.shape[0])]
        if sum(len(f) for f in free) < m:
            raise ValueError(
                f"staging buffers full: {m} new rows, "
                f"{sum(len(f) for f in free)} free slots across shards — "
                "flush() or compact() first")
        for r in range(m):
            s = max(range(len(free)), key=lambda j: len(free[j]))
            slot = free[s].pop(0)
            s_codes[s, slot] = codes[r]
            s_ids[s, slot] = new_ids[r]
            s_lists[s, slot] = list_ids[r]
        return dataclasses.replace(state, staging=_place_buffer(
            StagingBuffer(codes=jnp.asarray(s_codes),
                          ids=jnp.asarray(s_ids),
                          lists=jnp.asarray(s_lists)),
            state.mesh, state.axes))

    raise TypeError("stage() needs a quantized state with a staging buffer")


def _flush_into(ids_np: np.ndarray, codes_np: np.ndarray,
                offsets: np.ndarray, s_codes: np.ndarray,
                s_ids: np.ndarray, s_lists: np.ndarray) -> int:
    """Fill CSR holes from staged rows, in place on host copies; staged
    rows that fit free their slot (id −1). Returns the rows moved. The
    sentinel block sits past ``offsets[-1]`` and is never a target."""
    moved = 0
    staged = np.nonzero(s_ids >= 0)[0]
    for l in np.unique(s_lists[staged]) if staged.size else ():
        take = staged[s_lists[staged] == l]
        seg = slice(int(offsets[l]), int(offsets[l + 1]))
        holes = np.nonzero(ids_np[seg] < 0)[0] + int(offsets[l])
        fit = min(holes.size, take.size)
        if fit:
            ids_np[holes[:fit]] = s_ids[take[:fit]]
            codes_np[holes[:fit]] = s_codes[take[:fit]]
            s_ids[take[:fit]] = -1
            moved += fit
    return moved


def flush(state):
    """Fold staged rows into the block-aligned CSR without touching
    offsets, shapes, or other shards: holes only. Rows whose target list
    has no free hole stay staged (compact() absorbs them with a repack).
    Returns ``(new_state, rows_moved)``."""
    kind = _kind(state)
    stg = getattr(state, "staging", None)
    if stg is None:
        return state, 0

    if kind == "adc":
        idx = state.index
        ids_np = np.asarray(idx.ids).copy()
        codes_np = np.asarray(idx.codes).copy()
        s_codes = np.asarray(stg.codes).copy()
        s_ids = np.asarray(stg.ids).copy()
        s_lists = np.asarray(stg.lists)
        moved = _flush_into(ids_np, codes_np, np.asarray(idx.list_offsets),
                            s_codes, s_ids, s_lists)
        if not moved:
            return state, 0
        return dataclasses.replace(
            state,
            index=dataclasses.replace(idx, codes=jnp.asarray(codes_np),
                                      ids=jnp.asarray(ids_np)),
            staging=dataclasses.replace(stg, ids=jnp.asarray(s_ids)),
        ), moved

    if kind == "sharded_adc":
        ids_np = np.asarray(state.ids).copy()
        codes_np = np.asarray(state.codes).copy()
        offs = np.asarray(state.list_offsets)
        s_codes = np.asarray(stg.codes).copy()
        s_ids = np.asarray(stg.ids).copy()
        s_lists = np.asarray(stg.lists)
        moved = 0
        for s in range(ids_np.shape[0]):   # each shard flushes locally
            moved += _flush_into(ids_np[s], codes_np[s], offs[s],
                                 s_codes[s], s_ids[s], s_lists[s])
        if not moved:
            return state, 0
        mesh, axes = state.mesh, state.axes
        return dataclasses.replace(
            state,
            codes=_place(jnp.asarray(codes_np), mesh, axes),
            ids=_place(jnp.asarray(ids_np), mesh, axes),
            staging=dataclasses.replace(
                stg, ids=_place(jnp.asarray(s_ids), mesh, axes)),
        ), moved

    raise TypeError("flush() needs a quantized state with a staging buffer")


# ---------------------------------------------------------------------------
# Tombstones
# ---------------------------------------------------------------------------


def tombstone_index(index: IVFPQIndex, remove_ids) -> IVFPQIndex:
    """Tombstone items of a bare index by id: their rows become holes
    (id −1) that score −inf in-kernel and are reused by later flushes.
    Shape-preserving and jit-able."""
    rids = jnp.asarray(remove_ids).astype(index.ids.dtype)
    dead = jnp.isin(index.ids, rids)
    return dataclasses.replace(index, ids=jnp.where(dead, -1, index.ids))


def _tombstone_ids(ids: jax.Array, rids: jax.Array) -> jax.Array:
    return jnp.where(jnp.isin(ids, rids.astype(ids.dtype)), -1, ids)


def tombstone(state, remove_ids):
    """O(1) delete on ANY backend state: flip matching ids (resident and
    staged) to −1. Nothing is reshaped, no executable is invalidated — the
    rows just stop scoring, everywhere, on the next query."""
    rids = jnp.asarray(remove_ids)
    kind = _kind(state)
    if kind == "index":
        return tombstone_index(state, rids)
    if kind == "adc":
        stg = state.staging
        if stg is not None:
            stg = dataclasses.replace(stg,
                                      ids=_tombstone_ids(stg.ids, rids))
        return dataclasses.replace(
            state, index=tombstone_index(state.index, rids), staging=stg)
    if kind == "sharded_adc":
        mesh, axes = state.mesh, state.axes
        stg = state.staging
        if stg is not None:
            stg = dataclasses.replace(
                stg, ids=_place(_tombstone_ids(stg.ids, rids), mesh, axes))
        return dataclasses.replace(
            state, ids=_place(_tombstone_ids(state.ids, rids), mesh, axes),
            staging=stg)
    if kind == "exact":
        return dataclasses.replace(state,
                                   ids=_tombstone_ids(state.ids, rids))
    if kind == "sharded_exact":
        return dataclasses.replace(
            state, ids=_place(_tombstone_ids(state.ids, rids),
                              state.mesh, state.axes))
    # exact_stream: host-resident tile id tuples + a live-row count field
    rh = np.asarray(rids)
    tile_ids = tuple(
        np.where(np.isin(t, rh), -1, t).astype(np.int32)
        for t in state.tile_ids)
    rows = sum(int(np.sum(t >= 0)) for t in tile_ids)
    return dataclasses.replace(state, tile_ids=tile_ids, rows=rows)


# ---------------------------------------------------------------------------
# Compaction & rebalance
# ---------------------------------------------------------------------------


def _pad_capacity(index: IVFPQIndex, cap: int) -> IVFPQIndex:
    """Append hole rows so the index reaches ``cap`` total rows. Both
    capacities are block multiples, so the trailing block stays all-hole
    and ``sentinel_block`` (capacity//bs − 1) remains a valid redirect
    target."""
    cur = index.capacity
    if cur >= cap:
        return index
    extra = cap - cur
    codes = np.pad(np.asarray(index.codes), ((0, extra), (0, 0)))
    ids = np.pad(np.asarray(index.ids), (0, extra), constant_values=-1)
    return dataclasses.replace(index, codes=jnp.asarray(codes),
                               ids=jnp.asarray(ids))


def _gather_live(index_ids, index_codes, offsets, num_lists):
    """(codes, list_ids, ids) of the live CSR rows — pack() operands."""
    ids = np.asarray(index_ids)
    codes = np.asarray(index_codes)
    offs = np.asarray(offsets)
    live = ids >= 0
    rl = _row_lists(offs, ids.shape[0], num_lists)
    return codes[live], rl[live], ids[live]


def _drain_staged(stg: StagingBuffer | None, shard: int | None = None):
    """(codes, list_ids, ids) of the live staged rows (empty triple when
    no buffer). ``shard`` selects one stacked row."""
    if stg is None:
        return None
    s_codes = np.asarray(stg.codes if shard is None else stg.codes[shard])
    s_ids = np.asarray(stg.ids if shard is None else stg.ids[shard])
    s_lists = np.asarray(stg.lists if shard is None else stg.lists[shard])
    live = s_ids >= 0
    return s_codes[live], s_lists[live].astype(np.int32), s_ids[live]


def _empty_like(stg: StagingBuffer) -> StagingBuffer:
    return dataclasses.replace(stg, ids=jnp.full_like(stg.ids, -1))


def _reencode_rows(codes: np.ndarray, lists: np.ndarray, ids: np.ndarray,
                   reencode, R, coarse, quantizer):
    """Re-encode the gathered rows named by ``reencode=(ids, vectors)``
    against the CURRENT rotation/quantizers (the staleness pass: rows
    encoded many refreshes ago drift from the codebooks the LUTs are built
    on). ``vectors`` are the raw, unrotated embeddings aligned with the id
    list; ids not live in this gather (tombstoned/rebalanced away) are
    skipped. Returns (codes, lists, rows_reencoded) — in-place on copies.
    """
    rid = np.asarray(reencode[0]).astype(np.int64)
    if rid.size == 0:
        return codes, lists, 0
    pos = {int(v): k for k, v in enumerate(ids)}
    keep = np.asarray([j for j, r in enumerate(rid) if int(r) in pos],
                      dtype=np.int64)
    if keep.size == 0:
        return codes, lists, 0
    sel = np.asarray([pos[int(rid[j])] for j in keep], dtype=np.int64)
    X = jnp.asarray(np.asarray(reencode[1])[keep])
    XR = X @ R.astype(X.dtype)
    new_lists, new_codes = index_ivf.encode(XR, coarse, quantizer)
    codes = codes.copy()
    lists = lists.copy()
    codes[sel] = np.asarray(new_codes)
    lists[sel] = np.asarray(new_lists, dtype=np.int32)
    return codes, lists, int(keep.size)


def compact(state, *, include_staged: bool = True, reencode=None):
    """Reclaim tombstoned blocks: repack the live rows (draining the
    staging buffer too, by default) into fresh block-aligned CSR order.
    Codes are carried, never re-encoded — scores are bit-identical to a
    fresh rebuild of the same rows under the same quantizers.

    ``reencode=(ids, vectors)`` folds a staleness pass into the repack:
    those live rows are re-encoded from their raw ``vectors`` against the
    state's CURRENT rotation/quantizers (and re-homed to their new coarse
    list) instead of carrying their frozen codes. With ``reencode=None``
    the repack stays bit-identical.

    Shape discipline: capacity is padded back to the pre-compact value
    whenever the live set fits (the steady-churn case — pure shape-
    preserving array swap, zero recompiles); a genuinely grown corpus
    returns larger arrays, and a list grown past the static probe window
    raises ``max_blocks`` — both are counted as growth by the controller
    and recompile once.
    """
    kind = _kind(state)
    if kind == "index":
        c, l, i = _gather_live(state.ids, state.codes, state.list_offsets,
                               state.num_lists)
        if reencode is not None:
            c, l, _ = _reencode_rows(c, l, i, reencode, state.R,
                                     state.coarse, state.quantizer)
        new = index_ivf.pack(state.R, state.coarse, state.quantizer,
                             c, l, i, block_size=state.block_size)
        return _pad_capacity(new, state.capacity)

    if kind == "adc":
        idx = state.index
        c, l, i = _gather_live(idx.ids, idx.codes, idx.list_offsets,
                               idx.num_lists)
        parts = [(c, l, i)]
        stg = state.staging
        if include_staged and stg is not None:
            parts.append(_drain_staged(stg))
            stg = _empty_like(stg)
        c = np.concatenate([p[0] for p in parts])
        l = np.concatenate([p[1] for p in parts])
        i = np.concatenate([p[2] for p in parts])
        if reencode is not None:
            c, l, _ = _reencode_rows(c, l, i, reencode, idx.R,
                                     idx.coarse, idx.quantizer)
        new = index_ivf.pack(idx.R, idx.coarse, idx.quantizer, c, l, i,
                             block_size=idx.block_size)
        new = _pad_capacity(new, idx.capacity)
        mb = state.max_blocks
        if mb >= 1:
            mb = max(mb, new.max_list_blocks())
        return dataclasses.replace(state, index=new, staging=stg,
                                   max_blocks=mb)

    if kind == "sharded_adc":
        return _compact_sharded(state, include_staged=include_staged,
                                rebalance=False, reencode=reencode)
    raise TypeError("compact() needs a quantized (ADC or index) state")


def shard_rebalance(state, *, include_staged: bool = True):
    """Move rows between shards when occupancy has drifted: gather every
    live (+ staged) row, re-partition by id rank (``ivf.shard_split``'s
    rule — dense whatever the id space), repack per shard. Codes carried →
    bit-identical scores; shapes padded back to the common pre-call
    capacity when the rows still fit, so a rebalance is recompile-free in
    steady state."""
    if _kind(state) != "sharded_adc":
        raise TypeError("shard_rebalance() needs a sharded ADC state")
    return _compact_sharded(state, include_staged=include_staged,
                            rebalance=True)


def _compact_sharded(state, *, include_staged: bool, rebalance: bool,
                     reencode=None):
    """Shared body: per-shard repack (compact) or global rank re-partition
    + per-shard repack (rebalance)."""
    S = state.codes.shape[0]
    offs = np.asarray(state.list_offsets)
    num_lists = offs.shape[1] - 1
    stg = state.staging

    # live rows per shard (+ that shard's staged rows)
    per_shard = []
    for s in range(S):
        c, l, i = _gather_live(np.asarray(state.ids)[s],
                               np.asarray(state.codes)[s], offs[s],
                               num_lists)
        if include_staged and stg is not None:
            sc, sl, si = _drain_staged(stg, shard=s)
            c = np.concatenate([c, sc])
            l = np.concatenate([l, sl])
            i = np.concatenate([i, si])
        if reencode is not None:
            c, l, _ = _reencode_rows(c, l, i, reencode, state.R,
                                     state.coarse, state.quantizer)
        per_shard.append((c, l, i))

    if rebalance:
        all_c = np.concatenate([p[0] for p in per_shard])
        all_l = np.concatenate([p[1] for p in per_shard])
        all_i = np.concatenate([p[2] for p in per_shard])
        # id-rank partition, exactly as ivf.shard_split
        rank = np.empty(all_i.size, dtype=np.int64)
        rank[np.argsort(all_i, kind="stable")] = np.arange(all_i.size)
        shard_of = (rank * S) // max(all_i.size, 1)
        per_shard = [(all_c[shard_of == s], all_l[shard_of == s],
                      all_i[shard_of == s]) for s in range(S)]

    parts = [index_ivf.pack(state.R, state.coarse, state.quantizer,
                            c, l, i, block_size=state.block_size)
             for c, l, i in per_shard]
    cap = max(max(p.capacity for p in parts), int(state.codes.shape[1]))
    codes = np.stack([np.pad(np.asarray(p.codes),
                             ((0, cap - p.capacity), (0, 0)))
                      for p in parts])
    ids = np.stack([np.pad(np.asarray(p.ids), (0, cap - p.capacity),
                           constant_values=-1) for p in parts])
    offsets = np.stack([np.asarray(p.list_offsets) for p in parts])
    mb = state.max_blocks
    if mb >= 1:
        mb = max(mb, max(p.max_list_blocks() for p in parts))
    mesh, axes = state.mesh, state.axes
    if include_staged and stg is not None:
        stg = _place_buffer(_empty_like(stg), mesh, axes)
    return dataclasses.replace(
        state,
        codes=_place(jnp.asarray(codes), mesh, axes),
        ids=_place(jnp.asarray(ids), mesh, axes),
        list_offsets=_place(jnp.asarray(offsets), mesh, axes),
        staging=stg, max_blocks=mb)


# ---------------------------------------------------------------------------
# Bare-index ingest (the maintain.add path, rehomed)
# ---------------------------------------------------------------------------


def ingest_index(index: IVFPQIndex, X_new: jax.Array,
                 new_ids) -> IVFPQIndex:
    """Eager insert into a bare index: encode against the current
    centroids/codebooks, fill each target list's holes, and fall back to a
    full block-aligned repack when a list overflows (host-side, like
    ``ivf.build``). This is the one-shot/offline path; live serving should
    stage + flush instead (``maintain.add`` now shims here with a
    DeprecationWarning)."""
    XR = X_new @ index.R
    list_ids, codes_new = index_ivf.encode(XR, index.coarse, index.quantizer)

    list_ids_np = np.asarray(list_ids)
    codes_np = np.asarray(codes_new)
    new_ids_np = np.asarray(new_ids, dtype=np.int32)
    ids_np = np.asarray(index.ids).copy()
    all_codes_np = np.asarray(index.codes).copy()
    offsets = np.asarray(index.list_offsets)

    overflow = []
    for l in np.unique(list_ids_np):
        take = np.nonzero(list_ids_np == l)[0]
        seg = slice(int(offsets[l]), int(offsets[l + 1]))
        holes = np.nonzero(ids_np[seg] < 0)[0] + offsets[l]
        fit = min(len(holes), len(take))
        ids_np[holes[:fit]] = new_ids_np[take[:fit]]
        all_codes_np[holes[:fit]] = codes_np[take[:fit]]
        overflow.extend(take[fit:].tolist())

    if not overflow:
        return dataclasses.replace(
            index,
            codes=jnp.asarray(all_codes_np),
            ids=jnp.asarray(ids_np),
        )

    # Some list overflowed its padding: repack everything (existing live
    # rows keep their codes — no re-encode — only the layout is rebuilt).
    live = ids_np >= 0
    row_list = _row_lists(offsets, len(ids_np), index.num_lists)
    ov = np.asarray(overflow)
    return index_ivf.pack(
        index.R, index.coarse, index.quantizer,
        np.concatenate([all_codes_np[live], codes_np[ov]]),
        np.concatenate([row_list[live], list_ids_np[ov]]),
        np.concatenate([ids_np[live], new_ids_np[ov]]),
        block_size=index.block_size,
    )
