"""Background compaction: repack the NEXT index state off the critical path.

``ChurnController.compact`` runs ``ops.compact`` synchronously between
Engine batches — at full corpus scale that host-side repack is the p99 of
the serving/training loop. ``BackgroundCompactor`` moves it to a worker
thread double-buffering the next state while the current one serves, and
swaps at the Engine's existing refresh point (a wholesale ``engine.state``
assignment, same as ``Engine.refresh`` — the Engine re-reads state per
batch, so the swap is one reference write on the poll thread).

Correctness under concurrent mutation — the worker compacts a SNAPSHOT,
so by swap time the live state may have moved. The reconcile rules:

  * ``tombstone`` since submit → replayed onto the compacted arrays by id
    (set difference of live CSR ids, snapshot vs current). O(deads).
  * ``stage`` since submit → staged rows live in the staging buffer, which
    the swap takes from the CURRENT state (the worker compacts with
    ``include_staged=False``), so they keep serving uninterrupted.
  * ``refresh`` since submit → refresh carries codes and only moves
    R/coarse/quantizer, which the swap also takes from the CURRENT state;
    compacted codes stay valid (they are the snapshot's codes, reordered).
  * ``flush``/``compact``/``rebalance`` since submit → the CSR itself moved
    under the worker; the result is DISCARDED (validity check: unchanged
    list offsets + current live ids ⊆ snapshot live ids). The controller
    defers flushes while a compaction is in flight precisely so discards
    stay rare.

Because codes are carried, a background compaction that raced nothing is
bit-identical to a foreground ``ops.compact`` of the same input — pinned
in tests/test_churn.py.

Staleness re-encode rides along: given a ``StalenessTracker`` and a
``reencode_fn(ids) -> raw vectors``, each pass re-encodes the stalest rows
against the snapshot's current quantizers (``ops.compact(reencode=...)``),
so index freshness is maintained inside maintenance the index was already
doing — never as extra critical-path work.

Threading discipline: the worker runs pure compute and touches NO obs
registry and NO engine state — it returns ``(state, elapsed_s)`` through a
Future. All registry writes and the swap happen in ``poll()`` on the
caller's thread, under one lock (no torn stats, no double swap — stressed
in tests/test_churn.py with an artificially delayed worker via
``worker_delay_s``).
"""
from __future__ import annotations

import concurrent.futures
import threading
import time
import dataclasses

import jax
import numpy as np

from repro import obs
from repro.churn import ops


def _csr_ids(state) -> np.ndarray:
    """The CSR id array (flat or stacked) — staging excluded."""
    if hasattr(state, "index"):
        return np.asarray(state.index.ids)
    return np.asarray(state.ids)


def _csr_offsets(state) -> np.ndarray:
    if hasattr(state, "index"):
        return np.asarray(state.index.list_offsets)
    return np.asarray(state.list_offsets)


def _live_set(ids: np.ndarray) -> set:
    return set(int(i) for i in ids.ravel() if i >= 0)


class BackgroundCompactor:
    """Double-buffered ``ops.compact`` with an Engine-swap reconcile.

    ``engine`` is anything with a ``.state`` attribute (``search.Engine``
    or a bare holder). ``tracker``/``reencode_fn``/``reencode_rows`` wire
    the staleness pass; ``worker_delay_s`` artificially delays the worker
    (stress tests). Single poll-thread convention: ``submit``/``poll`` may
    be called from any one thread at a time (they lock), the worker never
    writes shared state.
    """

    def __init__(self, engine, *, tracker=None, reencode_fn=None,
                 reencode_rows: int = 256, include_staged: bool = False,
                 worker_delay_s: float = 0.0, registry=None):
        self.engine = engine
        self.tracker = tracker
        self.reencode_fn = reencode_fn
        self.reencode_rows = int(reencode_rows)
        self.include_staged = bool(include_staged)
        self.worker_delay_s = float(worker_delay_s)
        self.obs = (registry if registry is not None
                    else getattr(engine, "obs", None) or
                    obs.default_registry())
        self._lock = threading.Lock()
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="churn-compact")
        self._future: concurrent.futures.Future | None = None
        self._snap_live: set = set()
        self._snap_offsets: np.ndarray | None = None
        self._snap_epoch = 0
        self._reencode_ids: np.ndarray | None = None

    # -- worker body: pure compute, no registry/engine writes ---------------
    def _work(self, snapshot, reencode):
        if self.worker_delay_s > 0:
            time.sleep(self.worker_delay_s)
        t0 = time.perf_counter()
        new = ops.compact(snapshot, include_staged=self.include_staged,
                          reencode=reencode)
        jax.block_until_ready(_csr_ids(new))
        return new, time.perf_counter() - t0

    @property
    def in_flight(self) -> bool:
        with self._lock:
            return self._future is not None

    def submit(self) -> bool:
        """Snapshot the current state and start compacting it in the
        background. Returns False (no-op) when a pass is already in
        flight."""
        with self._lock:
            if self._future is not None:
                return False
            snapshot = self.engine.state
            self._snap_offsets = _csr_offsets(snapshot).copy()
            self._snap_live = _live_set(_csr_ids(snapshot))
            reencode = None
            self._reencode_ids = None
            if self.tracker is not None and self.reencode_fn is not None \
                    and self.reencode_rows > 0:
                rid = self.tracker.stalest(self.reencode_rows)
                if rid.size:
                    reencode = (rid, np.asarray(self.reencode_fn(rid)))
                    self._reencode_ids = rid
            self._snap_epoch = (self.tracker.epoch
                                if self.tracker is not None else 0)
            self._future = self._pool.submit(self._work, snapshot, reencode)
            return True

    def poll(self) -> bool:
        """Consume a finished pass: validate, reconcile, swap. Returns True
        when a swap happened. Never blocks on an unfinished worker. All
        metric writes happen here, on the caller's thread."""
        with self._lock:
            fut = self._future
            if fut is None or not fut.done():
                return False
            self._future = None          # double-swap guard: consumed once
            compacted, elapsed = fut.result()
            current = self.engine.state

            cur_ids = _csr_ids(current)
            cur_live = _live_set(cur_ids)
            valid = (np.array_equal(_csr_offsets(current),
                                    self._snap_offsets)
                     and cur_live <= self._snap_live)
            if not valid:
                # the CSR moved under the worker (flush/compact/rebalance):
                # the snapshot's repack no longer describes the live rows
                self.obs.counter("churn.bg_discarded").inc()
                return False

            # replay deletes that landed since the snapshot
            dead = self._snap_live - cur_live
            swapped = self._swap(current, compacted, dead)
            self.engine.state = swapped

            if self.tracker is not None:
                if self._reencode_ids is not None:
                    self.tracker.record(self._reencode_ids,
                                        epoch=self._snap_epoch)
                    self.obs.counter("churn.reencoded").inc(
                        int(self._reencode_ids.size))
                if dead:
                    self.tracker.forget(np.fromiter(
                        dead, dtype=np.int64, count=len(dead)))
                self.tracker.histogram(self.obs)
            self.obs.counter("churn.bg_compactions").inc()
            self.obs.distribution("churn.bg_compact_ms").observe(
                elapsed * 1e3)
            # the whole worker wall time was hidden behind the caller's
            # step loop — the overlap win train_e2e pins
            self.obs.distribution("churn.compact_hidden_ms").observe(
                elapsed * 1e3)
            return True

    def _swap(self, current, compacted, dead: set):
        """Compose the post-swap state: CSR layout from the compacted
        snapshot (deletes replayed), everything a refresh moves
        (R/coarse/quantizer/rot state) and the staging buffer from the
        CURRENT state."""
        dead_arr = (np.fromiter(dead, dtype=np.int64, count=len(dead))
                    if dead else None)

        def replay(ids_arr):
            if dead_arr is None:
                return ids_arr
            ids_np = np.asarray(ids_arr)
            out = np.where(np.isin(ids_np, dead_arr), -1, ids_np)
            return jax.numpy.asarray(out)

        if hasattr(current, "index"):        # flat/ivf ADC state
            comp_idx = compacted.index
            new_idx = dataclasses.replace(
                current.index,
                codes=comp_idx.codes,
                ids=replay(comp_idx.ids),
                list_offsets=comp_idx.list_offsets)
            return dataclasses.replace(
                current, index=new_idx, max_blocks=compacted.max_blocks)
        if hasattr(current, "mesh"):         # sharded ADC state
            ids = replay(compacted.ids)
            ids = ops._place(ids, current.mesh, current.axes)
            return dataclasses.replace(
                current, codes=compacted.codes, ids=ids,
                list_offsets=compacted.list_offsets,
                max_blocks=compacted.max_blocks)
        # bare IVFPQIndex
        return dataclasses.replace(
            current, codes=compacted.codes, ids=replay(compacted.ids),
            list_offsets=compacted.list_offsets)

    def join(self, timeout: float | None = None) -> None:
        """Block until the in-flight worker (if any) finishes — it still
        needs a ``poll()`` to swap."""
        with self._lock:
            fut = self._future
        if fut is not None:
            concurrent.futures.wait([fut], timeout=timeout)

    def close(self) -> None:
        self._pool.shutdown(wait=True, cancel_futures=True)
