"""Per-row staleness tracking: rotation epoch at encode time.

``stage`` encodes against the state's frozen quantizers, and the non-fused
refresh path drops cross-subspace angles when absorbing a delta into the
codebooks (``maintain.refresh_delta``) — so every refresh leaves each row's
stored code a little further from what a fresh encode under the current
rotation would produce (``maintain.refresh_mismatch`` measures the drift,
~1% of codes per full-matching step). Rebuilding everything per refresh
would defeat the paper's cheap-update claim; instead this tracker records
the rotation epoch each row was last encoded at, and each compaction pass
re-encodes only the STALEST rows (``ops.compact(..., reencode=...)``),
amortizing freshness over the maintenance the index was already doing.

Host-side and O(rows) in plain numpy — never inside a jit trace. The
single-writer convention matches the rest of ``repro.churn``: the poll /
training thread owns all mutations; the background compaction worker only
reads a snapshot taken under the compactor's lock.
"""
from __future__ import annotations

import numpy as np


class StalenessTracker:
    """Maps row id → rotation epoch at last encode (see module docstring)."""

    def __init__(self, ids=None, epoch: int = 0):
        self.epoch = int(epoch)
        self._encoded_at: dict[int, int] = {}
        if ids is not None:
            self.record(ids)

    def bump(self, n: int = 1) -> int:
        """A rotation delta landed: everything already encoded is now one
        epoch staler. Returns the new epoch."""
        self.epoch += int(n)
        return self.epoch

    def record(self, ids, epoch: int | None = None) -> None:
        """Rows were (re-)encoded at ``epoch`` (default: the current one)."""
        at = self.epoch if epoch is None else int(epoch)
        for i in np.asarray(ids, dtype=np.int64).ravel():
            if i >= 0:
                self._encoded_at[int(i)] = at

    def forget(self, ids) -> None:
        """Rows were tombstoned — stop tracking them."""
        for i in np.asarray(ids, dtype=np.int64).ravel():
            self._encoded_at.pop(int(i), None)

    def staleness_of(self, row_id: int) -> int:
        """Epochs since this row was encoded (0 = fresh/untracked)."""
        at = self._encoded_at.get(int(row_id))
        return 0 if at is None else self.epoch - at

    def stalest(self, k: int, *, min_staleness: int = 1) -> np.ndarray:
        """Ids of the ≤k stalest rows at least ``min_staleness`` epochs old
        — the re-encode batch for the next compaction pass. Ties broken by
        id for determinism."""
        cands = [(self.epoch - at, -i) for i, at in self._encoded_at.items()
                 if self.epoch - at >= min_staleness]
        if not cands:
            return np.empty(0, dtype=np.int64)
        cands.sort(reverse=True)
        return np.asarray([-neg for _, neg in cands[:k]], dtype=np.int64)

    def histogram(self, registry=None) -> dict[int, int]:
        """``{staleness: row count}``; optionally recorded onto an obs
        registry as the ``churn.staleness`` distribution (one observe per
        tracked row would be O(rows) — the bucketed counts are gauges)."""
        hist: dict[int, int] = {}
        for at in self._encoded_at.values():
            s = self.epoch - at
            hist[s] = hist.get(s, 0) + 1
        if registry is not None:
            for s, n in hist.items():
                registry.gauge("churn.staleness_rows", staleness=s).set(n)
            registry.gauge("churn.staleness_max").set(
                max(hist) if hist else 0)
        return hist

    def __len__(self) -> int:
        return len(self._encoded_at)
