"""repro.churn: live mutations for servable indexes.

Streaming ingest, tombstone deletes, and background compaction over the
search stack's states — built so the serving hot path never changes shape:

  * ``buffer``     — fixed-capacity append buffers + the flat-ADC side
                     pass that serves staged rows (device-side).
  * ``ops``        — the mutation primitives: with_staging / stage /
                     flush / tombstone / compact / shard_rebalance
                     (host-side, state-in state-out).
  * ``controller`` — ``ChurnController``: sequences stage→flush→compact
                     between Engine batches, instrumented via repro.obs.
  * ``compactor``  — ``BackgroundCompactor``: double-buffers the next
                     compacted state in a worker thread and swaps it at the
                     Engine refresh point (deletes replayed, staging and
                     rotation state taken live) — the repack off the
                     critical path.
  * ``staleness``  — ``StalenessTracker``: rotation epoch at encode time
                     per row, so each compaction pass re-encodes only the
                     stalest rows (``ops.compact(reencode=...)``).

Deletes are O(1) id flips honored inside the Pallas scan kernels; adds are
visible to the next query via the staging side pass; compaction repacks at
preserved shapes in steady state, so sustained churn costs zero recompiles.
"""
from repro.churn.buffer import (StagingBuffer, empty, merge_staged,
                                staged_topk)
from repro.churn.compactor import BackgroundCompactor
from repro.churn.controller import ChurnController
from repro.churn.ops import (compact, flush, free_slots, ingest_index,
                             live_rows, shard_rebalance, stage, staged_rows,
                             tombstone, tombstone_index, with_staging)
from repro.churn.staleness import StalenessTracker

__all__ = [
    "StagingBuffer", "empty", "merge_staged", "staged_topk",
    "ChurnController", "BackgroundCompactor", "StalenessTracker",
    "with_staging", "stage", "flush", "tombstone", "compact",
    "shard_rebalance", "tombstone_index", "ingest_index",
    "staged_rows", "free_slots", "live_rows",
]
