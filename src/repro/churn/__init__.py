"""repro.churn: live mutations for servable indexes.

Streaming ingest, tombstone deletes, and background compaction over the
search stack's states — built so the serving hot path never changes shape:

  * ``buffer``     — fixed-capacity append buffers + the flat-ADC side
                     pass that serves staged rows (device-side).
  * ``ops``        — the mutation primitives: with_staging / stage /
                     flush / tombstone / compact / shard_rebalance
                     (host-side, state-in state-out).
  * ``controller`` — ``ChurnController``: sequences stage→flush→compact
                     between Engine batches, instrumented via repro.obs.

Deletes are O(1) id flips honored inside the Pallas scan kernels; adds are
visible to the next query via the staging side pass; compaction repacks at
preserved shapes in steady state, so sustained churn costs zero recompiles.
"""
from repro.churn.buffer import (StagingBuffer, empty, merge_staged,
                                staged_topk)
from repro.churn.controller import ChurnController
from repro.churn.ops import (compact, flush, free_slots, ingest_index,
                             live_rows, shard_rebalance, stage, staged_rows,
                             tombstone, tombstone_index, with_staging)

__all__ = [
    "StagingBuffer", "empty", "merge_staged", "staged_topk",
    "ChurnController",
    "with_staging", "stage", "flush", "tombstone", "compact",
    "shard_rebalance", "tombstone_index", "ingest_index",
    "staged_rows", "free_slots", "live_rows",
]
