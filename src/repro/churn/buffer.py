"""Append buffers: the device-side staging lane of the churn subsystem.

A live index cannot afford a CSR repack per insert — ``ivf.pack`` is a
host-side relayout of the whole codes array. Instead, new rows land in a
fixed-capacity ``StagingBuffer``: already rotated + residual-encoded (so
they score through the exact same LUTs as the main CSR), tagged with their
target coarse list, and scanned by a small flat-ADC side pass whose padded
top-k merges into the main scan's result via ``kernels.ops.topk_merge`` —
the same −inf/−1 contract as the cross-shard merge. ``churn.ops.flush``
later folds staged rows into CSR holes; until then they are served from
here, so an add is visible to the very next query.

The buffer is a pytree with FIXED shapes: staging, serving, and flushing
never change the array shapes the compiled executables were traced with
(free slots carry id −1 and score −inf through the same in-kernel tombstone
mask as CSR holes), which is what keeps the Engine's compile cache warm
through sustained churn. Sharded states stack one buffer per shard on a
leading axis and each shard's side pass runs inside the shard_map local
body — staged rows never cross devices until a rebalance.

No ``repro.search`` imports here: this module sits below the searcher layer
(search/flat.py, search/ivf.py and search/sharded.py all call into it), so
it only speaks the ``index.search`` result/padding vocabulary.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.index import search as index_search
from repro.kernels import ops as kops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StagingBuffer:
    """Fixed-capacity append buffer (one per index, or per shard stacked on
    a leading axis). A slot is free iff its id is −1; ``lists`` holds each
    staged row's coarse-list assignment so the side pass can add the same
    coarse term ⟨q·R, c_l⟩ the main scan adds per block."""

    codes: jax.Array  # (cap_b, Dp) residual codes — or (S, cap_b, Dp)
    ids: jax.Array    # (cap_b,) int32 item ids, −1 = free — or (S, cap_b)
    lists: jax.Array  # (cap_b,) int32 target coarse list — or (S, cap_b)

    @property
    def capacity(self) -> int:
        """Slots per buffer (per shard for stacked buffers)."""
        return self.ids.shape[-1]


def empty(capacity: int, code_width: int, code_dtype, *,
          shards: int | None = None) -> StagingBuffer:
    """An all-free buffer matching an index's code layout. ``shards``
    stacks one buffer per shard on a leading axis (placement is the
    caller's job — ``churn.ops.with_staging`` partitions it like the CSR)."""
    lead = () if shards is None else (shards,)
    return StagingBuffer(
        codes=jnp.zeros(lead + (capacity, code_width),
                        dtype=jnp.dtype(code_dtype)),
        ids=jnp.full(lead + (capacity,), -1, jnp.int32),
        lists=jnp.zeros(lead + (capacity,), jnp.int32),
    )


def staged_topk(buf: StagingBuffer, QR: jax.Array, lut, centroids, k: int, *,
                use_kernel: bool = False) -> tuple[jax.Array, jax.Array]:
    """The flat-ADC side pass: score every staged row under the SAME LUT
    pack the main scan streams (staged rows are encoded against the same
    frozen quantizers, so one LUT build serves both lanes) and return a
    padded (b, k) top-k. Free slots mask to −inf inside the tile body via
    the ids operand — the buffer scans at fixed shape whatever its fill."""
    lut, scales = index_search.split_lut_pack(lut)
    res = kops.adc_lookup(lut, buf.codes, scales, buf.ids,
                          use_kernel=use_kernel)          # (b, cap_b)
    coarse = QR @ centroids.T                             # (b, L)
    scores = res + jnp.take(coarse, buf.lists, axis=1)
    return index_search.topk_padded(scores, buf.ids, k)


def merge_staged(res: index_search.SearchResult, buf: StagingBuffer,
                 QR: jax.Array, lut, centroids, k: int, *,
                 use_kernel: bool = False) -> index_search.SearchResult:
    """Fold the staging side pass into a main-scan result: concatenate the
    two padded top-k runs and re-top-k (``kernels.ops.topk_merge`` — the
    one merge the sharded searchers already use). ``scanned`` grows by the
    live staged rows, keeping the scan-work metric honest."""
    s, i = staged_topk(buf, QR, lut, centroids, k, use_kernel=use_kernel)
    scores, ids = kops.topk_merge(
        jnp.concatenate([res.scores, s], axis=1),
        jnp.concatenate([res.ids, i], axis=1), k)
    scanned = res.scanned + jnp.sum(buf.ids >= 0).astype(res.scanned.dtype)
    return index_search.SearchResult(scores=scores, ids=ids, scanned=scanned)
