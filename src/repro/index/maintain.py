"""Index maintenance: incremental add/remove and GCD rotation refresh.

The refresh is the capability unique to this paper's method. A GCD training
step updates the rotation by a short product of *disjoint* Givens rotations,
R ← R·Δ with Δ = ∏ℓ R_{iℓjℓ}(θℓ). Under that delta every quantity the index
stores transforms by right-multiplication in the rotated space:

    x·R' = (x·R)·Δ      centroids' = centroids·Δ      residuals' = residuals·Δ

and because rotations preserve distances, the coarse list assignment is
EXACTLY invariant — no item migrates between lists. The residual
quantizer's codebooks live per-subspace, so the part of Δ whose pairs fall
inside one subspace rotates the codewords exactly (codes unchanged, zero
error); pairs that straddle two subspaces cannot be absorbed into a product
codebook and are dropped to zeroth order — for GCD's small per-step angles
(θ = −λ·A/√2) this perturbs codes only for items near Voronoi boundaries.
The refresh is scheme-agnostic: it calls ``Quantizer.rotate`` (and
``VQ.rotate`` for the coarse centroids), so any quantizer exposing
codebooks — PQ, depth-M RQ, future schemes — refreshes the same way
(within-subspace rotations commute with the residual recursion, so one call
refreshes every RQ level). Net effect: ``refresh_rotation`` is O(n²) on the
rotation + O(L·n + M·D·K·n) on centroids/codebooks — independent of corpus
size — versus the O(N·n·K) full re-encode, and matches the rebuild's codes
on ≥99% of items per step (the acceptance test in tests/test_ivf.py; exact
when the matching is restricted to within-subspace pairs).

Mutations have moved to ``repro.churn`` (staging buffers, in-kernel
tombstones, background compaction); the ``add``/``remove`` here are
deprecated shims over ``churn.ingest_index``/``churn.tombstone_index``.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, rotations
from repro.core import givens
from repro.index import ivf
from repro.index.ivf import IVFPQIndex


def refresh_health(R: jax.Array,
                   delta: rotations.RotationDelta | None = None, *,
                   registry: obs.Registry | None = None) -> dict:
    """Host-side refresh health: the live signal for the paper's
    train-while-serving story. Records two gauges on ``registry`` (default:
    the global ``repro.obs`` registry):

      * ``refresh.orthogonality_drift`` — ‖RᵀR − I‖ of the *post-refresh*
        serving rotation: repeated float32 delta products slowly leave
        SO(n), and drift here degrades every stored code at once.
      * ``refresh.delta_norm`` — ‖θ‖ of the applied GivensDelta (Frobenius
        norm over leaves for dense deltas): a spiking delta norm is a
        runaway rotation learner, visible before recall moves.

    One (n, n) host sync — call it per refresh, not per query. Tracer
    inputs (accidentally called under a trace) are skipped, not crashed
    on. Returns the measured dict either way ``repro.obs`` is toggled.
    """
    if isinstance(R, jax.core.Tracer):
        return {}
    reg = registry if registry is not None else obs.default_registry()
    drift = float(rotations.orthogonality_error(jnp.asarray(R)))
    norm = None
    if delta is not None and not any(
            isinstance(x, jax.core.Tracer)
            for x in jax.tree_util.tree_leaves(delta)):
        if isinstance(delta, rotations.GivensDelta):
            norm = float(np.linalg.norm(np.asarray(delta.theta)))
        else:
            norm = float(np.sqrt(sum(
                float(np.sum(np.square(np.asarray(leaf))))
                for leaf in jax.tree_util.tree_leaves(delta))))
        reg.gauge("refresh.delta_norm").set(norm)
    reg.gauge("refresh.orthogonality_drift").set(drift)
    reg.counter("refresh.count").inc()
    reg.event("refresh", orthogonality_drift=drift, delta_norm=norm)
    return dict(orthogonality_drift=drift, delta_norm=norm)


def remove(index: IVFPQIndex, remove_ids: jax.Array) -> IVFPQIndex:
    """Deprecated alias for ``repro.churn.tombstone_index`` (kept for one
    release so existing callers keep working — same semantics: tombstone
    ids in place, shape-preserving). New code should use ``repro.churn``:
    ``tombstone`` handles every backend state, not just bare indexes."""
    warnings.warn(
        "maintain.remove is deprecated — use repro.churn.tombstone (any "
        "searcher state) or churn.tombstone_index (bare index)",
        DeprecationWarning, stacklevel=2)
    from repro import churn

    return churn.tombstone_index(index, jnp.asarray(remove_ids))


def add(index: IVFPQIndex, X_new: jax.Array, new_ids: jax.Array) -> IVFPQIndex:
    """Deprecated alias for ``repro.churn.ingest_index`` (the eager
    hole-fill + repack-on-overflow insert). Live serving should stage
    through a ``churn.ChurnController`` / ``churn.stage`` instead: staged
    adds are visible to the next query without ever repacking the CSR
    under the compiled executables."""
    warnings.warn(
        "maintain.add is deprecated — use repro.churn.ingest_index "
        "(offline) or churn.stage/ChurnController.add (live serving)",
        DeprecationWarning, stacklevel=2)
    from repro import churn

    return churn.ingest_index(index, X_new, new_ids)


def rotate_components(R: jax.Array, coarse, quantizer, pi: jax.Array,
                      pj: jax.Array, theta: jax.Array):
    """The corpus-independent piece of a rotation refresh: rotate R, the
    coarse centroids, and the residual codebooks by a disjoint plane
    product. Codes never enter — which is exactly why the row-sharded
    searchers (``search/sharded.py``) refresh by updating these three
    replicated components in place while every device's CSR shard stays
    untouched (zero recompiles, zero cross-device traffic).

    Cross-subspace pairs apply to R and the coarse centroids exactly and
    are dropped (θ→0) for the residual quantizer's product codebooks —
    within-subspace pairs only mix columns inside one subspace slice, so
    ``Quantizer.rotate`` absorbs them exactly (all levels at once for RQ).
    """
    sub = quantizer.sub
    R_new = givens.apply_pair_rotations(R, pi, pj, theta)
    coarse_new = coarse.rotate(pi, pj, theta)
    within = (pi // sub) == (pj // sub)
    theta_w = jnp.where(within, theta, 0.0)
    quantizer_new = quantizer.rotate(pi, pj, theta_w)
    return R_new, coarse_new, quantizer_new


def check_refreshable(delta: rotations.RotationDelta) -> rotations.GivensDelta:
    """The ADC-backend refresh precondition: a disjoint GivensDelta. Dense
    Cayley/Procrustes deltas do not factor into per-subspace codebook
    rotations — re-encode (ivf.build) instead."""
    if not isinstance(delta, rotations.GivensDelta):
        raise TypeError(
            f"refresh needs a GivensDelta (got {type(delta).__name__}):"
            " dense Cayley/Procrustes deltas do not factor into per-subspace"
            " codebook rotations — re-encode (ivf.build) instead")
    if delta.overlapping:
        raise ValueError("refresh requires a disjoint (commuting) delta")
    return delta


@jax.jit
def refresh_rotation(index: IVFPQIndex, pi: jax.Array, pj: jax.Array,
                     theta: jax.Array) -> IVFPQIndex:
    """Absorb a GCD step R ← R·∏ℓ R_{pi[ℓ],pj[ℓ]}(theta[ℓ]) into the live
    index without touching the stored codes (see module docstring).

    Pairs must be disjoint (a GCD matching). Scheme-agnostic: any ``quant``
    object implementing ``rotate`` (PQ, RQ, ...) refreshes here — the
    component rotation itself is ``rotate_components``.
    """
    R_new, coarse_new, quantizer_new = rotate_components(
        index.R, index.coarse, index.quantizer, pi, pj, theta)
    return dataclasses.replace(
        index, R=R_new, coarse=coarse_new, quantizer=quantizer_new
    )


@jax.jit
def refresh_delta(index: IVFPQIndex,
                  delta: rotations.GivensDelta) -> IVFPQIndex:
    """``refresh_rotation`` for a learner-produced RotationDelta — the index
    side of the trainer/index sync contract: feed the same delta that
    ``RotationLearner.update`` returned and the served rotation matches the
    trainer's ``materialize`` exactly. Only Givens deltas factor into
    per-subspace codebook rotations (``check_refreshable``)."""
    check_refreshable(delta)
    return refresh_rotation(index, delta.pi, delta.pj, delta.theta)


@jax.jit
def subspace_gcd_step(index: IVFPQIndex, G: jax.Array, lr: float | jax.Array):
    """Serving-aware GCD step via the ``subspace_gcd`` rotation learner
    (``repro.rotations.SubspaceGCD`` — the matching is restricted to
    within-subspace planes, so the delta is block-diagonal over the PQ
    subspaces and the refresh absorbs it EXACTLY; codes provably unchanged).

    Returns (refreshed index, (pi, pj, theta)) — apply the same triple (or
    the learner's own delta) to the trainer's rotation state to stay in
    sync.
    """
    learner = rotations.make("subspace_gcd", sub=index.quantizer.sub)
    state = learner.init_from(index.R.astype(jnp.float32))
    _state, delta = learner.update(
        state, G, lr, jax.random.PRNGKey(0))  # greedy matching: key unused
    return refresh_delta(index, delta), (delta.pi, delta.pj, delta.theta)


def refresh_mismatch(refreshed: IVFPQIndex, X: jax.Array) -> jax.Array:
    """Diagnostic: fraction of items whose stored codes differ from a full
    re-encode of raw vectors ``X`` (ordered by original item id) against the
    refreshed index — 0.0 when the GCD matching stayed within subspaces.
    (Stored codes are carried over by refresh_rotation, so this is exactly
    the refresh-vs-rebuild disagreement.)"""
    XR = X @ refreshed.R
    _, codes_rebuild = ivf.encode(XR, refreshed.coarse, refreshed.quantizer)
    live = refreshed.ids >= 0
    stored = refreshed.codes
    rebuilt = codes_rebuild[jnp.maximum(refreshed.ids, 0)]
    mismatch = jnp.any(stored != rebuilt, axis=-1) & live
    return jnp.sum(mismatch) / jnp.maximum(jnp.sum(live), 1)


def drifted_ids(index: IVFPQIndex, X: jax.Array) -> np.ndarray:
    """Item ids whose stored codes disagree with a fresh encode of their
    raw vectors against the index's CURRENT rotation/quantizers — the
    ground-truth stale set ``refresh_mismatch`` reports the fraction of.
    The staleness machinery (``churn.StalenessTracker`` + the compactor's
    re-encode pass) approximates this set from epochs alone, without the
    full re-encode this oracle pays for; tests/benchmarks use this to
    check how well the approximation tracks reality."""
    XR = X @ index.R
    _, codes_rebuild = ivf.encode(XR, index.coarse, index.quantizer)
    ids = np.asarray(index.ids)
    live = ids >= 0
    rebuilt = np.asarray(codes_rebuild)[np.maximum(ids, 0)]
    mism = np.any(np.asarray(index.codes) != rebuilt, axis=-1) & live
    return np.unique(ids[mism])
