"""repro.index — IVF serving layer over the GCD rotation machinery.

Turns the paper's T(X) = φ(XR)Rᵀ into a production-shaped ANN index, with
φ drawn from the unified quantizer subsystem (repro.quant):

  ivf       build: quant.VQ coarse quantizer over rotated vectors +
            residual quant.PQ (depth 1) or quant.RQ (depth M), packed into
            a block-aligned CSR pytree (IVFPQIndex); the partitioned
            variants — ``shard_split`` (repartition a built index) and
            ``build_sharded`` (host-sharded chunk ingest; the corpus never
            concatenates) — feed the row-sharded searchers
  search    batched query engine: probe top-nprobe lists, per-query
            Quantizer.adc_tables LUTs, fused Pallas selected-block ADC scan
            (kernels/ivf_adc.py — depth rides in the LUT column dim)
  maintain  incremental add/remove and refresh_rotation — absorb a GCD
            training step into a live index without re-encoding the corpus
            (scheme-agnostic via Quantizer.rotate; ``rotate_components``
            is the corpus-independent core the sharded refresh reuses)

This package is the IVF *mechanism* layer; the serving front door is
``repro.search`` — a Searcher registry (``exact`` / ``flat_adc`` / ``ivf``)
plus a batching ``Engine`` — and new retrieval code should go through it::

    from repro import search
    searcher = search.make("ivf")
    state = searcher.build(key, X, R, search.SearchConfig(num_lists=256,
                                                          subspaces=16))
    res = searcher.search(state, Q, k=10)            # res.scores, res.ids
    state = searcher.refresh(state, delta)           # after a GCD step

The free functions below remain supported (the ``ivf``/``flat_adc``
backends dispatch to them)::

    from repro import quant
    from repro.index import ivf, search, maintain
    cfg = ivf.IVFPQConfig(num_lists=256, pq=quant.PQConfig(16, 256), depth=2)
    index = ivf.build(key, X, R, cfg)
    res = search.search(index, Q, nprobe=16, k=10)   # res.scores, res.ids
    index = maintain.refresh_rotation(index, pi, pj, theta)  # after a GCD step

See README.md §Index serving for the layout and the recall/nprobe
trade-off, and §Serving engine for the registry/Engine migration table.
"""
from repro.index import ivf, maintain, search  # noqa: F401
from repro.index.ivf import IVFPQConfig, IVFPQIndex  # noqa: F401
from repro.index.search import SearchResult  # noqa: F401
