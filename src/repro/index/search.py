"""Batched IVF-PQ query engine.

Per query batch (b, n):

  1. rotate:       QR = Q·R  (the paper's serving transform)
  2. probe:        coarse scores QR·Cᵀ, keep the top-``nprobe`` lists
  3. LUT build:    ``index.quantizer.adc_tables(QR)`` — one
                   (code_width, K) table per query against the *residual*
                   quantizer, shared by all probes. Residual depth is
                   already flattened into code_width, so PQ and RQ feed
                   the same kernel.
  4. scan:         selected list blocks scored by the fused Pallas kernel
                   (kernels/ivf_adc.py) or its jnp oracle; the coarse term
                   ⟨q·R, c_l⟩ is added per block group outside the kernel
  5. top-k:        over nprobe·max_blocks·block_size masked candidates

Because every list is padded to whole ``block_size`` tiles (ivf.pack), the
probe window of each (query, list) pair is a fixed ``max_blocks`` tiles:
shorter lists redirect their out-of-range tiles to the index's all-hole
sentinel block, whose ids are −1 and therefore score −inf. Scan work per
query is nprobe·max_blocks·block_size rows versus the corpus size for the
flat scan — the recall/work trade-off is entirely in ``nprobe``.

Device sharding: under an active mesh the candidate axis is annotated with
the ``ivf`` rule table (sharding/rules.py) so XLA splits list scanning over
the "model" axis while the query batch stays data-parallel. The row-sharded
deployment (``search/sharded.py``) instead runs ``_search_core`` as the
shard-local body of a shard_map — each device probes the shared centroids
but scans only its own CSR shard, and per-shard top-k runs merge
cross-device.

This module is the IVF *mechanism*; the serving front door is
``repro.search`` (Searcher registry + batching Engine), whose ``ivf`` and
``flat_adc`` backends dispatch here. The ``*_prepared`` variants take the
rotated queries and ADC LUTs as explicit operands so the Engine can cache
per-query LUTs across requests.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.index.ivf import IVFPQIndex
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.sharding import rules as sh

NEG_INF = -jnp.inf


class SearchResult(NamedTuple):
    scores: jax.Array   # (b, k) approximate inner products, descending
    ids: jax.Array      # (b, k) item ids (−1 where fewer than k candidates)
    scanned: jax.Array  # (b,) CSR rows scanned per query (scan-work metric)


def topk_padded(scores: jax.Array, cand_ids: jax.Array,
                k: int) -> tuple[jax.Array, jax.Array]:
    """The one top-k + padding contract every retrieval path shares.

    ``cand_ids`` is (C,) or (b, C); masked candidates must already score
    −inf. Returns (b, k) scores/ids padded with (−inf, −1) when k > C or
    when fewer than k finite candidates survive. The core lives in
    ``kernels.ref.topk_merge_ref`` (also the cross-shard merge of the
    sharded searchers) so the contract has exactly one implementation.
    """
    b, C = scores.shape
    if cand_ids.ndim == 1:
        cand_ids = jnp.broadcast_to(cand_ids[None, :], (b, C))
    return kref.topk_merge_ref(scores, cand_ids, k)


def build_luts(quantizer, QR: jax.Array, lut_dtype: str = "float32"):
    """Build ADC tables for rotated queries, optionally quantized.

    Returns the **LUT pack** convention every scan path downstream accepts:
    a plain (b, Dp, K) float32 array for ``lut_dtype="float32"``, or a
    ``(qlut, scales)`` tuple from ``kernels.quantize_luts`` for
    int8/uint8 — a pytree, so packs flow through jit, shard_map, and the
    Engine's LUT cache unchanged.
    """
    lut = quantizer.adc_tables(QR)
    if lut_dtype == "float32":
        return lut
    return kops.quantize_luts(lut, lut_dtype)


def split_lut_pack(lut):
    """LUT pack -> (lut, scales | None) for the kernel call sites."""
    if isinstance(lut, tuple):
        qlut, scales = lut
        return qlut, scales
    return lut, None


def probe(index: IVFPQIndex, QR: jax.Array,
          nprobe: int) -> tuple[jax.Array, jax.Array]:
    """Top-``nprobe`` lists per rotated query: ((b, p) lists, (b, p) coarse
    scores ⟨q·R, c_l⟩ — the additive coarse term of the final score)."""
    coarse = QR @ index.centroids.T  # (b, L)
    cscores, lists = jax.lax.top_k(coarse, nprobe)
    return lists, cscores


def candidate_blocks(index: IVFPQIndex, lists: jax.Array,
                     max_blocks: int) -> tuple[jax.Array, jax.Array]:
    """Tile schedule for the probed lists.

    Returns (block_idx (b, p, B) int32 tile indices into the CSR codes
    array, valid (b, p, B) bool). Out-of-range tiles of short lists point at
    the sentinel hole block (still masked via ids, but ``valid`` lets the
    scan-work metric count only real tiles).
    """
    bs = index.block_size
    starts = index.list_offsets[lists] // bs                      # (b, p)
    nblocks = (index.list_offsets[lists + 1]
               - index.list_offsets[lists]) // bs                 # (b, p)
    k = jnp.arange(max_blocks, dtype=jnp.int32)
    blk = starts[..., None] + k
    valid = k < nblocks[..., None]
    return jnp.where(valid, blk, index.sentinel_block).astype(jnp.int32), valid


def _search_core(index: IVFPQIndex, QR: jax.Array, lut, *,
                 nprobe: int, k: int, max_blocks: int,
                 use_kernel: bool) -> SearchResult:
    """Probe + scan + top-k over already-rotated queries and built LUTs.
    ``lut`` is a LUT pack (plain f32 array or (qlut, scales))."""
    b = QR.shape[0]
    bs = index.block_size
    QR = sh.constrain(QR, ("act_batch", None), sh.IVF_RULES)

    lists, cscores = probe(index, QR, nprobe)

    blk, valid = candidate_blocks(index, lists, max_blocks)    # (b, p, B)
    S = b * nprobe * max_blocks
    block_idx = blk.reshape(S)
    block_query = jnp.repeat(
        jnp.arange(b, dtype=jnp.int32), nprobe * max_blocks
    )

    lut, scales = split_lut_pack(lut)
    # holes/tombstones (id < 0) are masked to −inf inside the tile body;
    # adding the finite coarse term afterwards cannot resurrect them
    res_scores = kops.ivf_adc(
        lut, index.codes, block_idx, block_query, scales, index.ids,
        block_size=bs, use_kernel=use_kernel,
    ).reshape(b, nprobe, max_blocks, bs)
    scores = res_scores + cscores[:, :, None, None]            # + coarse term

    rows = blk[..., None] * bs + jnp.arange(bs)                # (b, p, B, bs)
    cand_ids = index.ids[rows]
    scores = sh.constrain(
        scores.reshape(b, -1), ("act_batch", "ivf_cand"), sh.IVF_RULES
    )

    # k can exceed the candidate pool (small nprobe, large k): the shared
    # contract clamps the top_k and pads back out to (b, k) with (−inf, −1)
    top_scores, top_ids = topk_padded(scores, cand_ids.reshape(b, -1), k)
    scanned = jnp.sum(valid.reshape(b, -1), axis=1) * bs
    return SearchResult(scores=top_scores, ids=top_ids, scanned=scanned)


@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "k", "max_blocks", "use_kernel", "lut_dtype"),
)
def search_fixed(index: IVFPQIndex, Q: jax.Array, *, nprobe: int, k: int = 10,
                 max_blocks: int, use_kernel: bool = True,
                 lut_dtype: str = "float32") -> SearchResult:
    """Jit-friendly core: ``max_blocks`` (the per-list probe window in tiles,
    ≥ index.max_list_blocks() for exactness) is passed statically."""
    # constrain before the LUT build so the (b, Dp, K) tables inherit the
    # act_batch annotation at their producer under an active mesh
    QR = sh.constrain(Q @ index.R, ("act_batch", None), sh.IVF_RULES)
    lut = build_luts(index.quantizer, QR, lut_dtype)           # (b, Dp, K)
    return _search_core(index, QR, lut, nprobe=nprobe, k=k,
                        max_blocks=max_blocks, use_kernel=use_kernel)


@functools.partial(
    jax.jit, static_argnames=("nprobe", "k", "max_blocks", "use_kernel")
)
def search_prepared(index: IVFPQIndex, QR: jax.Array, lut, *,
                    nprobe: int, k: int = 10, max_blocks: int,
                    use_kernel: bool = True) -> SearchResult:
    """``search_fixed`` with the rotate + LUT-build steps hoisted out: the
    caller supplies ``QR = Q·R`` and a LUT pack (``build_luts`` output).
    The ``search.Engine`` uses this to reuse cached per-query LUTs."""
    return _search_core(index, QR, lut, nprobe=nprobe, k=k,
                        max_blocks=max_blocks, use_kernel=use_kernel)


def search(index: IVFPQIndex, Q: jax.Array, *, nprobe: int, k: int = 10,
           use_kernel: bool = True, lut_dtype: str = "float32") -> SearchResult:
    """Batched ANN search: (b, n) queries -> top-k (scores, ids, scanned).

    Convenience wrapper that reads the probe-window size off the concrete
    index (one host sync) and dispatches to the jit'd ``search_fixed``.
    """
    nprobe = min(nprobe, index.num_lists)
    return search_fixed(
        index, Q, nprobe=nprobe, k=k,
        max_blocks=index.max_list_blocks(), use_kernel=use_kernel,
        lut_dtype=lut_dtype,
    )


def flat_adc_scores(index: IVFPQIndex, Q: jax.Array, *,
                    use_kernel: bool = False,
                    lut_dtype: str = "float32") -> tuple[jax.Array, jax.Array]:
    """Flat baseline over the same quantized representation: score every CSR
    row (coarse term + residual ADC). Returns ((b, cap) scores with holes at
    −inf, (cap,) ids) — the exactness oracle for nprobe = num_lists and the
    scan-work baseline for the recall/QPS benchmark."""
    QR = Q @ index.R
    lut = build_luts(index.quantizer, QR, lut_dtype)
    return flat_adc_prepared(index, QR, lut, use_kernel=use_kernel)


def flat_adc_prepared(index: IVFPQIndex, QR: jax.Array, lut, *,
                      use_kernel: bool = False) -> tuple[jax.Array, jax.Array]:
    """``flat_adc_scores`` with rotate + LUT-build hoisted out (Engine LUT
    cache entry point, mirroring ``search_prepared``). ``lut`` is a LUT
    pack."""
    lut, scales = split_lut_pack(lut)
    # holes/tombstones (id < 0) are masked to −inf inside the tile body
    res = kops.adc_lookup(lut, index.codes, scales, index.ids,
                          use_kernel=use_kernel)  # (b, cap)
    # coarse term per row: row r belongs to list l iff offsets[l] ≤ r < offsets[l+1]
    row_list = jnp.searchsorted(
        index.list_offsets, jnp.arange(index.capacity), side="right"
    ) - 1
    row_list = jnp.clip(row_list, 0, index.num_lists - 1).astype(jnp.int32)
    coarse = QR @ index.centroids.T                                 # (b, L)
    scores = res + coarse[:, row_list]
    return scores, index.ids
