"""IVF index build: coarse quantizer + residual PQ/RQ in a CSR pytree.

The paper deploys T(X) = φ(XR)Rᵀ as an ANN index; a flat ADC scan touches
every item per query. This module adds the standard production refinement
(cf. Transformed Residual Quantization, arXiv:1512.06925): a ``quant.VQ``
coarse quantizer over the *rotated* vectors partitions the corpus into
``num_lists`` inverted lists, and a residual quantizer (``quant.PQ`` at
depth 1, ``quant.RQ`` above) encodes the **residual** x·R − c(x) instead of
the raw vector. Scores then decompose exactly as

    ⟨q·R, x·R⟩ ≈ ⟨q·R, c_l⟩  +  Σ_d LUT[d, code_d]      (coarse + residual)

so a query only scans the ``nprobe`` lists with the best coarse term. Both
quantizers are protocol objects from ``repro.quant``: the index is agnostic
to the residual scheme — codes are ``code_width`` integer columns and LUTs
are (b, code_width, K), whatever the depth.

Memory layout (the whole index is one jit-traceable pytree):

  * ``codes (cap, Dp)`` / ``ids (cap,)`` — all lists concatenated, CSR style
    (Dp = quantizer.code_width: D for PQ, M·D for depth-M RQ).
  * ``list_offsets (L+1,)`` — row ranges; every offset is a multiple of
    ``block_size`` so a list is an integer number of kernel tiles and the
    Pallas scan (kernels/ivf_adc.py) can DMA list blocks straight from HBM
    by block index — no gathers.
  * holes (padding rows and tombstones from ``maintain.remove``) carry
    ``id = −1`` and are masked out at score time; one all-hole sentinel
    block sits at the end of the array as the target for out-of-range
    block indices of shorter-than-max lists.

Rotations enter twice: ``build`` consumes the GCD-learned R, and
``maintain.refresh_rotation`` keeps the index servable across further GCD
steps without touching the stored codes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from typing import NamedTuple

from repro import quant


class IVFPQConfig(NamedTuple):
    """Static build parameters.

    ``num_lists``: coarse cells L (scan work per query ≈ nprobe/L of corpus).
    ``pq``: residual quantizer per-level config (D subspaces × K codewords).
    ``depth``: residual levels M — 1 builds a ``quant.PQ``, >1 a ``quant.RQ``
    (M·D code bytes/item for strictly lower distortion).
    ``block_size``: CSR alignment = Pallas tile rows; lists are padded to a
    multiple of it.
    ``lut_dtype``: ADC-table precision streamed by the scan kernels
    ("float32" | "int8" | "uint8"; integer dtypes carry per-subspace scales
    and dequantize in VMEM — 4× less LUT HBM traffic per tile).
    """

    num_lists: int
    pq: quant.PQConfig
    block_size: int = 128
    depth: int = 1
    lut_dtype: str = "float32"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFPQIndex:
    """Servable IVF index. Array/quantizer fields are pytree children;
    ``block_size`` is static aux data so jit specializes on the tile shape."""

    R: jax.Array              # (n, n) GCD-learned rotation
    coarse: quant.VQ          # coarse quantizer (L centroids, rotated space)
    quantizer: quant.Quantizer  # residual quantizer (quant.PQ or quant.RQ)
    codes: jax.Array          # (cap, Dp) residual codes, CSR by list
    #                           (uint8 when K ≤ 256, else int32 — see pack)
    ids: jax.Array            # (cap,) int32 item ids, −1 = hole/tombstone
    list_offsets: jax.Array   # (L+1,) int32, multiples of block_size
    block_size: int = 128

    def tree_flatten(self):
        children = (self.R, self.coarse, self.quantizer, self.codes,
                    self.ids, self.list_offsets)
        return children, self.block_size

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, block_size=aux)

    # -- compatibility views ----------------------------------------------
    @property
    def centroids(self) -> jax.Array:
        """(L, n) coarse centroids (the old pre-quant array field)."""
        return self.coarse.centroids

    @property
    def codebooks(self) -> jax.Array:
        """Residual codebooks: (D, K, sub) for PQ, (M, D, K, sub) for RQ."""
        return self.quantizer.codebooks

    # -- static shape facts ------------------------------------------------
    @property
    def num_lists(self) -> int:
        return self.coarse.num_centroids

    @property
    def dim(self) -> int:
        return self.coarse.dim

    @property
    def capacity(self) -> int:
        """Total CSR rows, including padding and the sentinel hole block."""
        return self.codes.shape[0]

    @property
    def sentinel_block(self) -> int:
        """Block index of the trailing all-hole block (see module doc)."""
        return self.capacity // self.block_size - 1

    def num_items(self) -> jax.Array:
        return jnp.sum(self.ids >= 0)

    def max_list_blocks(self) -> int:
        """Longest list measured in blocks — the static probe-window size
        for search. Host-sync on concrete offsets (pure numpy so it stays
        usable inside an outer jit trace closing over a concrete index)."""
        lens = np.diff(np.asarray(self.list_offsets))
        return max(int(lens.max()) // self.block_size, 1)


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------


def encode(XR: jax.Array, coarse: quant.VQ,
           quantizer: quant.Quantizer) -> tuple[jax.Array, jax.Array]:
    """Assign lists and residual-encode already-rotated vectors.

    Returns (list_ids (m,), codes (m, Dp)). Pure jnp — also the "full
    rebuild" oracle that ``maintain.refresh_rotation`` is tested against.
    """
    list_ids = coarse.assign(XR)
    residuals = XR - coarse.centroids[list_ids]
    return list_ids, quantizer.encode(residuals)


def pack(R: jax.Array, coarse: quant.VQ, quantizer: quant.Quantizer,
         codes: jax.Array, list_ids: jax.Array,
         ids: jax.Array, block_size: int = 128) -> IVFPQIndex:
    """Lay encoded items out in block-aligned CSR order (host-side; numpy).

    Each list is padded to a multiple of ``block_size`` with hole rows
    (id −1, code 0) and a sentinel all-hole block is appended.
    """
    list_ids = np.asarray(list_ids)
    codes = np.asarray(codes)
    ids = np.asarray(ids, dtype=np.int32)
    L = coarse.num_centroids
    Dp = codes.shape[1]

    counts = np.bincount(list_ids, minlength=L)
    padded = -(-counts // block_size) * block_size  # per-list rounded up
    offsets = np.zeros(L + 1, dtype=np.int32)
    np.cumsum(padded, out=offsets[1:])
    cap = int(offsets[-1]) + block_size  # + sentinel hole block

    codes_out = np.zeros((cap, Dp), dtype=np.dtype(quantizer.code_dtype))
    ids_out = np.full((cap,), -1, dtype=np.int32)

    order = np.argsort(list_ids, kind="stable")
    sorted_lists = list_ids[order]
    # rank of each item within its list = position − start of its run
    run_starts = np.zeros(L, dtype=np.int64)
    np.cumsum(counts[:-1], out=run_starts[1:])
    ranks = np.arange(len(order)) - run_starts[sorted_lists]
    dest = offsets[sorted_lists] + ranks
    codes_out[dest] = codes[order]
    ids_out[dest] = ids[order]

    return IVFPQIndex(
        R=jnp.asarray(R),
        coarse=jax.tree.map(jnp.asarray, coarse),
        quantizer=jax.tree.map(jnp.asarray, quantizer),
        codes=jnp.asarray(codes_out),
        ids=jnp.asarray(ids_out),
        list_offsets=jnp.asarray(offsets),
        block_size=block_size,
    )


def build(key: jax.Array, X: jax.Array, R: jax.Array, cfg: IVFPQConfig, *,
          ids: jax.Array | None = None, coarse_iters: int = 10,
          pq_iters: int = 10, train_size: int | None = None) -> IVFPQIndex:
    """End-to-end index build from raw vectors and a learned rotation.

    ``train_size`` caps the sample used for the k-means fits (the full
    corpus is always encoded). Host-side orchestration around jit'd pieces —
    build is offline; serving (search/maintain) is the jit'd hot path.
    """
    kc, kp = jax.random.split(key)
    XR = X @ R
    XT = XR if train_size is None else XR[:train_size]
    coarse = quant.VQ.fit(kc, XT, cfg.num_lists, iters=coarse_iters)
    train_lists = coarse.assign(XT)
    quantizer, _ = quant.fit_quantizer(
        kp, XT - coarse.centroids[train_lists], cfg.pq,
        depth=cfg.depth, iters=pq_iters,
    )
    list_ids, codes = encode(XR, coarse, quantizer)
    if ids is None:
        ids = jnp.arange(X.shape[0], dtype=jnp.int32)
    return pack(R, coarse, quantizer, codes, list_ids, ids,
                block_size=cfg.block_size)


# ---------------------------------------------------------------------------
# Partitioned build: the corpus lives sharded, each shard a local CSR
# ---------------------------------------------------------------------------


def shard_split(index: IVFPQIndex, num_shards: int) -> list[IVFPQIndex]:
    """Repartition a built index into ``num_shards`` per-shard CSRs.

    Items map to shards by contiguous id-rank range (shard s owns the
    s-th of S equal slices of the sorted live ids — balanced within one
    row for any id space); every shard keeps the SHARED R / coarse /
    residual quantizer and repacks only its own rows into block-aligned
    lists — codes are carried over, never re-encoded, so a shard's row
    scores are bit-identical to the source index's. This is the parity
    path of the ``repro.search`` ``*_sharded`` backends: attach the same
    single-device build, redistributed.
    """
    ids = np.asarray(index.ids)
    codes = np.asarray(index.codes)
    offsets = np.asarray(index.list_offsets)
    live = ids >= 0
    row_list = np.searchsorted(offsets, np.arange(len(ids)), side="right") - 1
    row_list = np.clip(row_list, 0, index.num_lists - 1)
    # Partition by id RANK, not id value: ranks are dense whatever the id
    # space (sparse external ids from build(ids=...)/maintain.add would
    # otherwise collapse onto one shard), so shards stay balanced within
    # one row, and for the common dense 0..N−1 ids rank == id — contiguous
    # ranges either way.
    live_ids = ids[live]
    rank = np.empty(live_ids.size, dtype=np.int64)
    rank[np.argsort(live_ids, kind="stable")] = np.arange(live_ids.size)
    shard_of = np.full(ids.shape, -1, dtype=np.int64)
    shard_of[live] = (rank * num_shards) // max(live_ids.size, 1)
    parts = []
    for s in range(num_shards):
        m = shard_of == s
        parts.append(pack(index.R, index.coarse, index.quantizer,
                          codes[m], row_list[m], ids[m],
                          block_size=index.block_size))
    return parts


def build_sharded(key: jax.Array, chunks, R: jax.Array, cfg: IVFPQConfig, *,
                  coarse_iters: int = 10, pq_iters: int = 10,
                  train_size: int | None = None, mesh=None,
                  axis: str = "data") -> list[IVFPQIndex]:
    """Host-sharded ingest: one local index per corpus chunk.

    ``chunks`` is a sequence of (rows_s, n) arrays — one per shard — that
    are rotated and encoded one at a time, so the full corpus never
    materializes on one device: the only cross-chunk state is the training
    sample (capped at ``train_size`` rows — default 65536, NEVER the full
    corpus, or the sample concat would defeat the chunked ingest) taken
    from the chunk heads, and the O(n² + L·n + D·K·sub) quantizers it
    fits. Item ids are global (chunk-order offsets). When ``mesh`` is
    given the coarse fit runs as a sharded k-means
    (``quant.kmeans.kmeans_sharded`` — per-shard assign + psum
    accumulate); the residual fit stays on the sample either way (it is
    already capped). Returns per-shard indexes for
    ``search.attach_shards``.
    """
    chunks = [jnp.asarray(c) for c in chunks]
    n_total = sum(int(c.shape[0]) for c in chunks)
    cap = min(65536 if train_size is None else train_size, n_total)
    R = jnp.asarray(R)

    # training sample: heads of the chunks, rotated chunk by chunk
    sample, have = [], 0
    for c in chunks:
        if have >= cap:
            break
        take = min(int(c.shape[0]), cap - have)
        sample.append(c[:take] @ R.astype(c.dtype))
        have += take
    XT = jnp.concatenate(sample) if len(sample) > 1 else sample[0]

    kc, kp = jax.random.split(key)
    if mesh is not None:
        centroids = quant.kmeans.vq_kmeans_sharded(
            kc, XT, cfg.num_lists, mesh=mesh, axis=axis, iters=coarse_iters)
        coarse = quant.VQ(centroids=centroids)
    else:
        coarse = quant.VQ.fit(kc, XT, cfg.num_lists, iters=coarse_iters)
    train_lists = coarse.assign(XT)
    quantizer, _ = quant.fit_quantizer(
        kp, XT - coarse.centroids[train_lists], cfg.pq,
        depth=cfg.depth, iters=pq_iters,
    )

    parts, start = [], 0
    for c in chunks:
        XRc = c @ R.astype(c.dtype)
        list_ids, codes = encode(XRc, coarse, quantizer)
        ids = jnp.arange(start, start + c.shape[0], dtype=jnp.int32)
        start += int(c.shape[0])
        parts.append(pack(R, coarse, quantizer, codes, list_ids, ids,
                          block_size=cfg.block_size))
    return parts
