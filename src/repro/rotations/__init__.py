"""repro.rotations — the unified rotation-learner subsystem.

The paper's central claim is a *comparison of rotation-learning algorithms*
(GCD variants vs. Cayley vs. SVD/Procrustes); this package makes every
algorithm a first-class, swappable citizen behind one optax-style protocol
(see ``base``) and one string registry (see ``registry``):

  base        RotationLearner protocol, GivensDelta / DenseDelta pytrees,
              the shared ``apply(X, delta)``
  gcd         GCD (Algorithm 2: random/greedy/steepest + overlap ablations),
              SubspaceGCD (serving-aware, index-exact deltas), Frozen
  cayley      Cayley transform math (with the −1-eigenvalue guard) and the
              CayleySGD retraction learner
  procrustes  SVD learner: projected SGD ``update`` + closed-form ``solve``
  registry    ``make`` / ``names`` / ``RotationConfig`` / ``from_config``

The rotation-matrix utilities examples and benchmarks need alongside the
learners (``random_rotation``, ``orthogonality_error``,
``apply_pair_rotations``, ``project_to_so_n``) are re-exported here from
``core.givens``, so consumer code imports one package.

Consumers: ``training.optimizer`` routes every manifold leaf through the
configured learner (``OptimizerConfig.rotation``), ``quant.opq`` sweeps
learners in the alternating minimization, ``index.maintain`` consumes
GivensDeltas to refresh a live IVF index, and the fig2a/fig2bc/table1/fig4
benchmarks sweep ``names()``. ``core.rotation`` and ``core.cayley`` remain
as compatibility shims — see README.md for the migration table.
"""
from repro.core.givens import (  # noqa: F401  (canonical rotation utilities)
    apply_pair_rotations,
    orthogonality_error,
    project_to_so_n,
    random_rotation,
)
from repro.rotations import base, cayley, gcd, procrustes, registry  # noqa: F401
from repro.rotations.base import (  # noqa: F401
    DenseDelta,
    GivensDelta,
    RotationDelta,
    RotationLearner,
    apply,
    identity_delta,
)
from repro.rotations.cayley import CayleySGD  # noqa: F401
from repro.rotations.gcd import GCD, GCDState, Frozen, SubspaceGCD  # noqa: F401
from repro.rotations.procrustes import Procrustes  # noqa: F401
from repro.rotations.registry import (  # noqa: F401
    RotationConfig,
    from_config,
    make,
    names,
)
