"""Givens-coordinate-descent learners (paper Algorithm 2) as RotationLearners.

``GCD`` owns the projection-free manifold update:

    G  = ∇_R L                      (ordinary backprop gradient)
    A  = GᵀR − RᵀG                  (directional derivatives, Prop. 1)
    (pi, pj) ← select n/2 disjoint pairs   (GCD-R / GCD-G / GCD-S)
    θℓ = −λ · Â[iℓ, jℓ] / √2
    R  ← R · ∏ℓ R_{iℓ jℓ}(θℓ)       (commuting block update, O(n²))

R stays exactly orthogonal at every step (up to fp rounding) — no SVD, no
matrix exponential, no Cayley solve. The optional diagonal preconditioners
(adagrad / adam over the (n, n) directional-derivative field) implement the
paper's remark that GCD "can be easily integrated with standard neural
network training algorithms, such as Adagrad and Adam".

``SubspaceGCD`` restricts the matching to pairs inside one PQ subspace
(serving-aware GCD, extracted from the former
``index.maintain.subspace_gcd_step``): masked entries carry zero weight, so
greedy completes the matching with them only after all useful
within-subspace pairs — and their step angle θ = −λ·0/√2 is exactly 0, an
identity rotation. The resulting Δ is block-diagonal over the PQ subspaces,
so ``maintain.refresh_delta`` absorbs it EXACTLY (codes provably unchanged).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import givens, matching
from repro.rotations import base

METHODS = ("random", "greedy", "steepest", "overlap_greedy", "overlap_random")


class GCDState(NamedTuple):
    """State of a GCD-trained rotation (formerly core.rotation.RotationState)."""

    R: jax.Array              # (n, n) current rotation, in SO(n)
    step: jax.Array           # int32 step counter
    accum: jax.Array          # (n, n) preconditioner 1st accumulator (adagrad/adam-m)
    accum2: jax.Array         # (n, n) adam-v accumulator (unused for adagrad)


def _precondition(state: GCDState, A: jax.Array, preconditioner: str,
                  beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8):
    """Optionally rescale the directional-derivative field elementwise."""
    if preconditioner == "none":
        return A, state.accum, state.accum2
    t = state.step.astype(jnp.float32) + 1.0
    if preconditioner == "adagrad":
        acc = state.accum + jnp.square(A)
        Ahat = A / (jnp.sqrt(acc) + eps)
        return Ahat, acc, state.accum2
    if preconditioner == "adam":
        m = beta1 * state.accum + (1.0 - beta1) * A
        v = beta2 * state.accum2 + (1.0 - beta2) * jnp.square(A)
        mhat = m / (1.0 - beta1**t)
        vhat = v / (1.0 - beta2**t)
        Ahat = mhat / (jnp.sqrt(vhat) + eps)
        return Ahat, m, v
    raise ValueError(f"unknown preconditioner {preconditioner!r}")


@dataclasses.dataclass(frozen=True)
class GCD:
    """The paper's GCD family; ``method`` picks the pair-selection strategy."""

    method: str = "greedy"           # one of METHODS
    preconditioner: str = "none"     # none | adagrad | adam
    sweeps: int = 16                 # 2-opt sweeps for method="steepest"
    reorthonormalize_every: int = 0  # 0 = never (exact in f32)
    score_kernel_min_n: int = 256    # fused Pallas A=GᵀR−RᵀG at n ≥ this; 0 off

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"unknown GCD method {self.method!r}")

    def init(self, n: int, dtype=jnp.float32) -> GCDState:
        return self.init_from(jnp.eye(n, dtype=dtype))

    def init_from(self, R: jax.Array) -> GCDState:
        n = R.shape[-1]
        return GCDState(
            R=R,
            step=jnp.int32(0),
            accum=jnp.zeros((n, n), jnp.float32),
            accum2=jnp.zeros((n, n), jnp.float32),
        )

    def with_rotation(self, state: GCDState, R: jax.Array) -> GCDState:
        return state._replace(R=R)

    def materialize(self, state: GCDState) -> jax.Array:
        return state.R

    def select_pairs(self, Ahat: jax.Array, key: jax.Array):
        """The matching step — (pi, pj) from the preconditioned score field."""
        n = Ahat.shape[-1]
        if self.method == "random":
            return matching.random_matching(key, n)
        if self.method == "greedy":
            # exact-equivalent vectorized-rounds variant: ~12× faster at
            # n=512 than the one-edge-at-a-time scan
            return matching.greedy_matching_fast(Ahat)
        if self.method == "steepest":
            return matching.steepest_matching(Ahat, sweeps=self.sweeps)
        if self.method == "overlap_greedy":
            return matching.overlapping_topk(Ahat)
        return matching.overlapping_random(key, Ahat.shape[-1])

    def update(self, state: GCDState, grad: jax.Array, lr: float | jax.Array,
               key: jax.Array) -> tuple[GCDState, base.GivensDelta]:
        A = self._score(grad.astype(jnp.float32), state.R.astype(jnp.float32))
        Ahat, acc, acc2 = _precondition(state, self._mask(A),
                                        self.preconditioner)
        pi, pj = self.select_pairs(Ahat, key)
        theta = -jnp.asarray(lr, jnp.float32) * Ahat[pi, pj] / givens.SQRT2
        delta = base.GivensDelta(
            pi=pi, pj=pj, theta=theta,
            overlapping=self.method.startswith("overlap"))
        step = state.step + 1
        R_new = base.maybe_reorthonormalize(
            delta.apply(state.R), step, self.reorthonormalize_every)
        return GCDState(R=R_new, step=step, accum=acc, accum2=acc2), delta

    def _score(self, G: jax.Array, R: jax.Array) -> jax.Array:
        """A = GᵀR − RᵀG. Large rotations route through the fused Pallas
        kernel (one pass over G/R instead of matmul + transpose + subtract);
        bit-identical to the reference — pinned in tests/test_rotations.py.
        Below ``score_kernel_min_n`` the kernel's block padding costs more
        than it saves, so small/odd sizes keep the jnp reference."""
        n = G.shape[-1]
        if self.score_kernel_min_n and n >= self.score_kernel_min_n:
            from repro.kernels import ops as kernel_ops
            return kernel_ops.gcd_score(G, R)
        return givens.directional_derivs(G, R)

    def _mask(self, A: jax.Array) -> jax.Array:
        """Hook for SubspaceGCD; the full-matching family is unmasked."""
        return A


@dataclasses.dataclass(frozen=True)
class SubspaceGCD(GCD):
    """GCD with the matching restricted to within-subspace planes.

    ``sub`` is the PQ subspace width (n // num_subspaces). Cross-subspace
    entries of A are zeroed before the greedy matching, so every pair with
    nonzero angle stays inside one subspace slice and the delta can be
    absorbed exactly into product codebooks (``maintain.refresh_delta``).
    This restricts coordinate descent to the subgroup SO(sub)^D — strictly
    less expressive per step than a full matching, so trainers typically
    interleave: cheap exact-refresh subspace steps between queries, an
    occasional full step + ~1% approximate refresh when the descent stalls.
    """

    sub: int = 0
    method: str = "greedy"

    def __post_init__(self):
        super().__post_init__()
        if self.sub <= 0:
            raise ValueError("SubspaceGCD needs sub > 0 (the subspace width)")
        if self.method.startswith("overlap"):
            raise ValueError("SubspaceGCD requires a disjoint matching")

    def _mask(self, A: jax.Array) -> jax.Array:
        d_idx = jnp.arange(A.shape[-1]) // self.sub
        return jnp.where(d_idx[:, None] == d_idx[None, :], A, 0.0)


class FrozenState(NamedTuple):
    R: jax.Array
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class Frozen:
    """The frozen-R control: update is a no-op with an O(1) identity delta."""

    reorthonormalize_every: int = 0  # accepted for config uniformity; unused

    def init(self, n: int, dtype=jnp.float32) -> FrozenState:
        return self.init_from(jnp.eye(n, dtype=dtype))

    def init_from(self, R: jax.Array) -> FrozenState:
        return FrozenState(R=R, step=jnp.int32(0))

    def with_rotation(self, state: FrozenState, R: jax.Array) -> FrozenState:
        return state._replace(R=R)

    def materialize(self, state: FrozenState) -> jax.Array:
        return state.R

    def update(self, state: FrozenState, grad: jax.Array,
               lr: float | jax.Array, key: jax.Array):
        del grad, lr, key
        return (state._replace(step=state.step + 1),
                base.identity_delta(state.R.dtype))
