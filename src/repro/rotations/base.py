"""RotationLearner protocol + RotationDelta pytrees (the `repro.rotations` core).

Every rotation-learning algorithm in the repo — the paper's Givens coordinate
descent variants, the Cayley-SGD baseline, SVD/Procrustes, and the frozen-R
control — implements one optax-style protocol:

    learner = rotations.make("gcd", method="greedy")
    state   = learner.init(n)                       # or init_from(R)
    state, delta = learner.update(state, grad, lr, key)
    R       = learner.materialize(state)            # current rotation

``update`` consumes the plain backprop gradient ``grad = ∇_R L`` and returns
both the new state and a **RotationDelta**: a pytree describing the group
element Δ with ``R_new = R_old · Δ``. Two concrete deltas exist:

  * ``GivensDelta(pi, pj, theta)`` — a product of Givens plane rotations
    ∏ℓ R_{pi[ℓ],pj[ℓ]}(θℓ). Disjoint pairs commute (the GCD default);
    ``overlapping=True`` marks the paper's §3.1 non-commuting ablations,
    which ``apply`` composes sequentially.
  * ``DenseDelta(dR)`` — a dense factor (Cayley retraction, Procrustes).

The shared ``apply(X, delta)`` right-multiplies any (..., n) array by Δ, so
the trainer and a live IVF index can consume the *same* delta and stay
provably in sync: ``apply(R_old, delta) == materialize(new_state)`` is a
protocol invariant (checked for every registered learner in
tests/test_rotations.py), and ``index.maintain.refresh_delta`` absorbs a
GivensDelta into a serving index without re-encoding the corpus.

All learners are frozen dataclasses (hashable → usable as jit static
arguments) and all states/deltas are pytrees (vmappable over stacked
per-layer rotations (L, n, n)). Learners expose ``reorthonormalize_every``:
every that-many updates the state's R is re-projected onto SO(n)
(``givens.project_to_so_n`` in f32), bounding fp drift on long bf16 runs;
0 disables the guard (GCD needs none in f32 — that is the paper's point).
CAVEAT: on a projection step the state absorbs a correction the returned
delta does not carry — ``materialize(new_state)`` is then the *projection*
of ``apply(R_old, delta)``. A consumer syncing a live index by deltas must
keep the guard off (the default, and the right call for f32 serving loops)
or re-sync the index whenever ``state.step % every == 0``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import givens


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GivensDelta:
    """Δ = ∏ℓ R_{pi[ℓ],pj[ℓ]}(theta[ℓ]) — the GCD-family delta.

    ``pi/pj/theta`` are (p,) arrays (or (L, p) under vmap). ``overlapping``
    is static metadata: False ⇒ pairs are disjoint and commute (O(m·p)
    column mixing); True ⇒ the §3.1 ablation, applied sequentially.
    """

    pi: jax.Array
    pj: jax.Array
    theta: jax.Array
    overlapping: bool = dataclasses.field(
        default=False, metadata={"static": True})

    def apply(self, X: jax.Array) -> jax.Array:
        if self.overlapping:
            return _apply_overlapping(X, self.pi, self.pj, self.theta)
        return givens.apply_pair_rotations(X, self.pi, self.pj, self.theta)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseDelta:
    """Δ as a dense (n, n) factor — Cayley / Procrustes learners."""

    dR: jax.Array

    def apply(self, X: jax.Array) -> jax.Array:
        return X @ self.dR.astype(X.dtype)


RotationDelta = GivensDelta | DenseDelta


def apply(X: jax.Array, delta: RotationDelta) -> jax.Array:
    """Right-multiply X (..., n) by the delta's group element Δ."""
    return delta.apply(X)


def identity_delta(dtype=jnp.float32) -> GivensDelta:
    """The empty Givens product — Δ = I at O(1) cost (frozen learner)."""
    z = jnp.zeros((0,), jnp.int32)
    return GivensDelta(pi=z, pj=z, theta=jnp.zeros((0,), dtype))


def _apply_overlapping(X: jax.Array, pi: jax.Array, pj: jax.Array,
                       theta: jax.Array) -> jax.Array:
    """Sequentially compose possibly-overlapping plane rotations.

    Overlapping pairs do not commute, so this is a serial fori_loop — the
    paper's point is precisely that this is both slower and theoretically
    unsound; kept for the §3.1 ablation benchmarks.
    """

    def body(l, Xc):
        i, j, t = pi[l], pj[l], theta[l].astype(Xc.dtype)
        ci, cj = Xc[..., i], Xc[..., j]
        c, s = jnp.cos(t), jnp.sin(t)
        Xc = Xc.at[..., i].set(c * ci + s * cj)
        Xc = Xc.at[..., j].set(c * cj - s * ci)
        return Xc

    return jax.lax.fori_loop(0, pi.shape[0], body, X)


@runtime_checkable
class RotationLearner(Protocol):
    """The optax-style learner protocol (see module docstring).

    Implementations are frozen dataclasses; hyper-parameters (pair-selection
    method, preconditioner, ``reorthonormalize_every``) live on the learner,
    per-rotation quantities (R, step counter, accumulators) in the state.
    """

    def init(self, n: int, dtype=jnp.float32) -> Any:
        """Fresh state at R = I_n."""
        ...

    def init_from(self, R: jax.Array) -> Any:
        """Fresh state at an existing rotation (e.g. an OPQ warm start)."""
        ...

    def with_rotation(self, state: Any, R: jax.Array) -> Any:
        """State with its rotation replaced (re-sync from a param leaf)."""
        ...

    def update(self, state: Any, grad: jax.Array, lr: float | jax.Array,
               key: jax.Array) -> tuple[Any, RotationDelta]:
        """One manifold step from ``grad = ∇_R L``; returns (state, Δ)."""
        ...

    def materialize(self, state: Any) -> jax.Array:
        """The current rotation matrix R ∈ SO(n)."""
        ...


def maybe_reorthonormalize(R: jax.Array, step: jax.Array,
                           every: int) -> jax.Array:
    """Project R back onto SO(n) when ``step`` hits a multiple of ``every``.

    ``step`` is the post-update counter; ``every == 0`` disables the guard.
    The SVD projection runs in f32 regardless of R's dtype (bf16 SVD is both
    unsupported and pointless) and casts back. On steps where the projection
    fires, the learner's returned delta does NOT include the correction —
    see the module-docstring caveat on delta-based index sync.
    """
    if not every:
        return R

    def project(r):
        return givens.project_to_so_n(r.astype(jnp.float32)).astype(r.dtype)

    return jax.lax.cond(step % every == 0, project, lambda r: r, R)
