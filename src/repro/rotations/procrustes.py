"""SVD/Procrustes RotationLearner — the classic OPQ rotation solver.

Two entry points share one state:

  * ``update(state, grad, lr, key)`` — projected Riemannian SGD: take the
    Euclidean step R − lr·G, then SVD-project back onto SO(n)
    (``givens.project_to_so_n``). This is what "use SVD inside an SGD loop"
    costs — a full SVD per step, the paper's Fig 4 comparison point — and it
    makes Procrustes a first-class citizen of the learner conformance suite.
  * ``solve(state, X, target)`` — the closed-form Procrustes solution
    argmin_{R ∈ O(n)} ‖XR − target‖_F = UVᵀ (Schönemann 1966), used by OPQ's
    alternating minimization where the data matrix is available. Note O(n),
    not SO(n): OPQ permits reflections, matching classic behavior.

Both return a DenseDelta Δ = R_oldᵀ·R_new so downstream consumers see the
same delta algebra as every other learner.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import givens
from repro.rotations import base


def procrustes_rotation(X: jax.Array, Y: jax.Array) -> jax.Array:
    """argmin_{R ∈ O(n)} ‖XR − Y‖_F = UVᵀ with XᵀY = USVᵀ (Schönemann 1966)."""
    M = X.T @ Y
    U, _, Vt = jnp.linalg.svd(M, full_matrices=False)
    return U @ Vt


class ProcrustesState(NamedTuple):
    R: jax.Array
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class Procrustes:
    reorthonormalize_every: int = 0  # already projected every step; unused

    def init(self, n: int, dtype=jnp.float32) -> ProcrustesState:
        return self.init_from(jnp.eye(n, dtype=dtype))

    def init_from(self, R: jax.Array) -> ProcrustesState:
        return ProcrustesState(R=R, step=jnp.int32(0))

    def with_rotation(self, state: ProcrustesState,
                      R: jax.Array) -> ProcrustesState:
        return state._replace(R=R)

    def materialize(self, state: ProcrustesState) -> jax.Array:
        return state.R

    def _step_to(self, state: ProcrustesState, R_new: jax.Array):
        delta = base.DenseDelta(
            dR=state.R.astype(jnp.float32).T @ R_new.astype(jnp.float32))
        return (ProcrustesState(R=R_new.astype(state.R.dtype),
                                step=state.step + 1), delta)

    def update(self, state: ProcrustesState, grad: jax.Array,
               lr: float | jax.Array, key: jax.Array):
        del key  # deterministic
        R32 = state.R.astype(jnp.float32)
        stepped = R32 - jnp.asarray(lr, jnp.float32) * grad.astype(jnp.float32)
        return self._step_to(state, givens.project_to_so_n(stepped))

    def solve(self, state: ProcrustesState, X: jax.Array, target: jax.Array):
        """Closed-form inner solve for OPQ: R ← argmin ‖XR − target‖_F."""
        return self._step_to(state, procrustes_rotation(X, target))
