"""The rotation-learner registry — single source of truth for method names.

Before this package, four string sets drifted independently: ``METHODS`` in
core/rotation.py, ``METHODS`` in benchmarks/fig3_table1_e2e.py,
``OptimizerConfig.gcd_method`` and ``opq.rotation_solver``. Now every
consumer (trainer, OPQ, index maintenance, all four rotation benchmarks)
resolves a spec string through ``make``:

    make("gcd", method="steepest")   # kwargs override the spec's defaults
    make("gcd_greedy")               # canonical per-method names
    make("subspace_gcd", sub=8)      # serving-aware GCD (needs the subspace width)
    make("cayley_sgd")               # Cayley-retraction SGD baseline
    make("procrustes")               # SVD solver (closed-form + projected SGD)
    make("frozen")                   # frozen-R control

Legacy aliases from the pre-registry era ("svd", "cayley", the
``gcd_overlap_*`` ablations) resolve to the same learners, so old spec
strings keep working through the compat shims.

``RotationConfig`` is the trainer-facing sub-config (hashable NamedTuple —
OptimizerConfig is a jit static argument): it replaces the former
``gcd_method`` / ``gcd_lr`` / ``gcd_preconditioner`` fields and feeds
``from_config`` → a learner instance.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.rotations import base, cayley, gcd, procrustes

_REGISTRY: dict[str, type] = {
    "gcd": gcd.GCD,
    "subspace_gcd": gcd.SubspaceGCD,
    "cayley_sgd": cayley.CayleySGD,
    "procrustes": procrustes.Procrustes,
    "frozen": gcd.Frozen,
}
_REGISTRY.update({f"gcd_{m}": gcd.GCD for m in gcd.METHODS})

_ALIASES = {
    "svd": "procrustes",
    "cayley": "cayley_sgd",
}


def names() -> tuple[str, ...]:
    """Canonical registered specs — what benchmarks sweep. Aliases and the
    bare "gcd" spec (the same learner as "gcd_greedy") are excluded so a
    sweep never double-counts; both still resolve through ``make``."""
    return tuple(n for n in _REGISTRY if n != "gcd")


def canonical(spec: str) -> str:
    return _ALIASES.get(spec, spec)


def make(spec: str, **kwargs) -> base.RotationLearner:
    """Build a learner from a registry spec. ``kwargs`` go to the learner's
    constructor (e.g. ``method=``, ``preconditioner=``, ``sub=``,
    ``reorthonormalize_every=``); a ``gcd_<method>`` spec pre-binds
    ``method`` unless overridden."""
    spec = canonical(spec)
    cls = _REGISTRY.get(spec)
    if cls is None:
        raise ValueError(
            f"unknown rotation learner {spec!r}; registered: {names()}")
    if spec.startswith("gcd_"):
        kwargs.setdefault("method", spec[len("gcd_"):])
    return cls(**kwargs)


class RotationConfig(NamedTuple):
    """Trainer-facing rotation settings (sub-config of OptimizerConfig).

    ``learner`` is a registry spec; ``method``/``preconditioner``/``sweeps``
    only apply to the GCD family (a ``gcd_<method>`` spec wins over
    ``method``). ``lr`` is the manifold learning rate, passed to
    ``learner.update`` — separate from the inner optimizer's lr, as in the
    former ``gcd_lr``.
    """

    learner: str = "gcd"
    lr: float = 1e-3
    method: str = "greedy"
    preconditioner: str = "none"
    sweeps: int = 16
    reorthonormalize_every: int = 0

    @classmethod
    def from_spec(cls, spec: str, **kw) -> "RotationConfig":
        """RotationConfig from a registry spec string (CLI convenience):
        ``from_spec("gcd_steepest", lr=2e-3)``."""
        spec = canonical(spec)
        if spec.startswith("gcd_"):
            return cls(learner="gcd", method=spec[len("gcd_"):], **kw)
        return cls(learner=spec, **kw)


def from_config(cfg: RotationConfig, **extra) -> base.RotationLearner:
    """Learner instance for a RotationConfig (``extra`` for e.g. ``sub``)."""
    spec = canonical(cfg.learner)
    kw = dict(reorthonormalize_every=cfg.reorthonormalize_every, **extra)
    if spec == "gcd" or spec.startswith("gcd_") or spec == "subspace_gcd":
        kw.update(preconditioner=cfg.preconditioner, sweeps=cfg.sweeps)
        if spec == "gcd":
            kw.update(method=cfg.method)
    return make(spec, **kw)
