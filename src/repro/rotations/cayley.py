"""Cayley-transform math + the Cayley-SGD RotationLearner (paper §1.1, §3).

R(A) = (I − A)(I + A)⁻¹ with A skew-symmetric, parameterized by the strict
lower triangle of an (n, n) matrix. Differentiable end-to-end, but every
evaluation costs an n×n linear solve that does not parallelize on GPU/TPU —
the paper's (and our) motivation for GCD.

Numerical guard (the instability §1.1 notes): a rotation with an eigenvalue
at −1 makes I + R exactly singular, so ``inverse_cayley`` explodes as
eigenvalues approach −1 (a half-turn in any plane). Its solve routes through
``stable_solve``: the direct LU solution is kept when it is finite and
backward-consistent, otherwise the Tikhonov-regularized normal equations
take over — the minimum-norm-flavored solution stays finite at the
singularity instead of returning inf/nan (regression test in
tests/test_rotations.py). The forward ``cayley`` needs no guard — I + A is
provably nonsingular for skew A (its eigenvalues are 1 + iλ) — and uses a
plain solve so the per-step cost benchmarked in Fig 4 stays honest.

``CayleySGD`` is the trainable baseline: one update pulls the rotation
gradient back through the transform at A = 0 (an exact jax.vjp — this linear
solve per step is the cost the paper's Fig 4 measures) and retracts
R ← R · cayley(−lr·∇A). Re-centering at A = 0 each step keeps the transform
far from the −1-eigenvalue instability and makes the delta an explicit dense
factor the serving index can consume.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rotations import base


def skew_from_params(params: jax.Array) -> jax.Array:
    """Antisymmetrize: A = tril(params, -1) − tril(params, -1)ᵀ."""
    L = jnp.tril(params, -1)
    return L - L.T


def stable_solve(M: jax.Array, B: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Solve M X = B, surviving (near-)singular M.

    Returns the direct LU solution when it is finite and backward-consistent
    (‖M·X − B‖ small relative to ‖B‖); otherwise the Tikhonov-regularized
    normal equations  (MᵀM + (eps·‖M‖)²·I) X = MᵀB,  which are always
    nonsingular and degrade gracefully toward the least-squares solution at
    the exact singularity. Both candidates are jit-computed unconditionally
    (n is small on every call path); the selection is a jnp.where, so the
    function stays traceable and differentiable on the well-posed branch.
    """
    n = M.shape[-1]
    X = jnp.linalg.solve(M, B)
    scale = jnp.maximum(jnp.linalg.norm(M), 1.0)
    reg = jnp.linalg.solve(
        M.T @ M + (eps * scale) ** 2 * jnp.eye(n, dtype=M.dtype), M.T @ B)
    resid = jnp.linalg.norm(M @ X - B)
    ok = jnp.all(jnp.isfinite(X)) & (resid <= 1e-3 * jnp.maximum(
        jnp.linalg.norm(B), 1.0))
    return jnp.where(ok, X, reg)


def cayley(params: jax.Array) -> jax.Array:
    """R = (I − A)(I + A)⁻¹ ∈ SO(n), A = skew(params).

    Plain LU solve: I + A is provably nonsingular for skew A (eigenvalues
    1 + iλ), so the forward transform never hits the −1-eigenvalue
    singularity — the guarded ``stable_solve`` is reserved for
    ``inverse_cayley``, keeping the per-step cost this module's Fig 4
    comparison measures honest.
    """
    A = skew_from_params(params)
    n = A.shape[-1]
    I = jnp.eye(n, dtype=A.dtype)
    # solve (I + A) R = (I − A)  =>  R = (I + A)^{-1} (I − A); both orderings
    # give an orthogonal matrix since (I−A) and (I+A)^{-1} commute.
    return jnp.linalg.solve(I + A, I - A)


def inverse_cayley(R: jax.Array) -> jax.Array:
    """A with cayley(A) == R: A = (I−R)(I+R)⁻¹, returned in params form.

    I + R is singular exactly when R has a −1 eigenvalue; ``stable_solve``
    keeps the result finite there (the entries for the offending plane
    saturate instead of overflowing — see module docstring).
    """
    n = R.shape[-1]
    I = jnp.eye(n, dtype=R.dtype)
    A = stable_solve((I + R).T, (I - R).T).T
    return jnp.tril(A, -1)  # params form


def init(n: int, dtype=jnp.float32) -> jax.Array:
    """Identity rotation: A = 0 (the Cayley params array)."""
    return jnp.zeros((n, n), dtype=dtype)


class CayleyState(NamedTuple):
    R: jax.Array              # (n, n) current rotation
    step: jax.Array           # int32 step counter


@dataclasses.dataclass(frozen=True)
class CayleySGD:
    """Riemannian SGD with the Cayley retraction, re-centered every step.

    update:  gA = ∇_A L(R·cayley(A))|_{A=0}   (exact vjp through the solve)
             Δ  = cayley(−lr · gA)            (∈ SO(n) by construction)
             R  ← R · Δ

    First-order equivalent to training an accumulated Cayley parameter by
    SGD (the classic baseline), but every step pays the transform's linear
    solve — the Fig 4 runtime gap versus GCD is exactly this solve.
    """

    reorthonormalize_every: int = 0

    def init(self, n: int, dtype=jnp.float32) -> CayleyState:
        return self.init_from(jnp.eye(n, dtype=dtype))

    def init_from(self, R: jax.Array) -> CayleyState:
        return CayleyState(R=R, step=jnp.int32(0))

    def with_rotation(self, state: CayleyState, R: jax.Array) -> CayleyState:
        return state._replace(R=R)

    def materialize(self, state: CayleyState) -> jax.Array:
        return state.R

    def update(self, state: CayleyState, grad: jax.Array,
               lr: float | jax.Array, key: jax.Array):
        del key  # deterministic
        R32 = state.R.astype(jnp.float32)

        def rotated(p):
            return R32 @ cayley(p)

        zero = jnp.zeros_like(R32)
        _, vjp = jax.vjp(rotated, zero)
        (gA,) = vjp(grad.astype(jnp.float32))
        dR = cayley(-jnp.asarray(lr, jnp.float32) * gA)
        delta = base.DenseDelta(dR=dR)
        step = state.step + 1
        R_new = base.maybe_reorthonormalize(
            delta.apply(state.R), step, self.reorthonormalize_every)
        return CayleyState(R=R_new, step=step), delta
