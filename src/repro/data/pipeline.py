"""Checkpointable, sharding-aware input pipeline with async host prefetch.

Every generator in data/synthetic.py is a pure function of (seed, step), so
pipeline state is just ``{"seed", "step"}`` — restarts and elastic re-meshes
resume exactly (the batch for step k is identical no matter the mesh). The
pipeline device_puts each batch with the step function's input shardings so
pjit never reshuffles input data.

``prefetch=True`` double-buffers the host side: while the trainer runs step
k, a worker thread generates batch k+1 and ``device_put``s it with the same
shardings, so the step loop never stalls on host batch synthesis or the
host→device copy. Because batches are pure functions of (seed, step), the
prefetched stream is bit-identical to the synchronous one, and
checkpoint/restore stays trivial: ``state()`` reports the step of the next
*unconsumed* batch and ``restore()`` simply discards any in-flight prefetch
(the batch is regenerated from (seed, step) — nothing is lost).
"""
from __future__ import annotations

import concurrent.futures
from typing import Any, Callable

import jax


class Pipeline:
    """Wraps ``make_batch(key) -> pytree`` into a stateful, resumable iterator.

    ``prefetch`` enables the one-ahead background buffer (see module
    docstring). ``prefetch_hits`` / ``prefetch_misses`` count whether the
    batch for a step was already waiting when the trainer asked for it — a
    persistent miss stream means batch synthesis is slower than the train
    step and the prefetch depth (one) is the bottleneck. When a
    ``registry`` (``repro.obs.Registry``) is supplied the same counts land
    on ``pipeline.prefetch_hits`` / ``pipeline.prefetch_misses``.
    """

    def __init__(self, make_batch: Callable[[jax.Array], Any], seed: int = 0,
                 shardings: Any | None = None, prefetch: bool = False,
                 registry: Any | None = None):
        self._make = make_batch
        self._seed = seed
        self._step = 0
        self._shardings = shardings
        self._registry = registry
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self._pool = (concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="pipeline-prefetch")
            if prefetch else None)
        self._inflight: tuple[int, concurrent.futures.Future] | None = None

    @property
    def prefetch(self) -> bool:
        return self._pool is not None

    def state(self) -> dict:
        """Step of the next unconsumed batch — an in-flight prefetch is NOT
        consumed, so a restore from this state replays it exactly."""
        return {"seed": self._seed, "step": self._step}

    def restore(self, state: dict) -> None:
        self._seed = int(state["seed"])
        self._step = int(state["step"])
        # drop any in-flight prefetch: it was generated for the old cursor;
        # the batch at the restored step regenerates from (seed, step)
        self._inflight = None

    def peek_key(self) -> jax.Array:
        return jax.random.fold_in(jax.random.PRNGKey(self._seed), self._step)

    def _produce(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self._seed), step)
        batch = self._make(key)
        if self._shardings is not None:
            batch = jax.device_put(batch, self._shardings)
        return batch

    def _count(self, name: str) -> None:
        if self._registry is not None:
            self._registry.counter(f"pipeline.{name}").inc()

    def __iter__(self):
        return self

    def __next__(self):
        if self._pool is None:
            batch = self._produce(self._step)
            self._step += 1
            return batch
        if self._inflight is not None and self._inflight[0] == self._step:
            batch = self._inflight[1].result()
            self.prefetch_hits += 1
            self._count("prefetch_hits")
        else:
            # cold start, post-restore, or a stale in-flight slot: produce
            # synchronously (the miss is counted — steady state hits)
            batch = self._produce(self._step)
            self.prefetch_misses += 1
            self._count("prefetch_misses")
        self._step += 1
        self._inflight = (self._step,
                          self._pool.submit(self._produce, self._step))
        return batch

    def close(self) -> None:
        """Shut the prefetch worker down (idempotent; sync pipelines no-op)."""
        if self._pool is not None:
            self._inflight = None
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
