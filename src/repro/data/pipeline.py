"""Checkpointable, sharding-aware input pipeline.

Every generator in data/synthetic.py is a pure function of (seed, step), so
pipeline state is just ``{"seed", "step"}`` — restarts and elastic re-meshes
resume exactly (the batch for step k is identical no matter the mesh). The
pipeline device_puts each batch with the step function's input shardings so
pjit never reshuffles input data.
"""
from __future__ import annotations

from typing import Any, Callable

import jax


class Pipeline:
    """Wraps ``make_batch(key) -> pytree`` into a stateful, resumable iterator."""

    def __init__(self, make_batch: Callable[[jax.Array], Any], seed: int = 0,
                 shardings: Any | None = None):
        self._make = make_batch
        self._seed = seed
        self._step = 0
        self._shardings = shardings

    def state(self) -> dict:
        return {"seed": self._seed, "step": self._step}

    def restore(self, state: dict) -> None:
        self._seed = int(state["seed"])
        self._step = int(state["step"])

    def peek_key(self) -> jax.Array:
        return jax.random.fold_in(jax.random.PRNGKey(self._seed), self._step)

    def __iter__(self):
        return self

    def __next__(self):
        batch = self._make(self.peek_key())
        self._step += 1
        if self._shardings is not None:
            batch = jax.device_put(batch, self._shardings)
        return batch
