"""Data substrate: synthetic generators (matched to the paper's dataset
statistics), CSR graph + real neighbor sampler, checkpointable pipeline."""
