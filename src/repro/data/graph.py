"""Graph substrate: CSR storage, synthetic power-law graphs, and the REAL
neighbor sampler required by the minibatch_lg cell (GraphSAGE fanout 15-10).

The sampler is uniform-with-replacement from each node's CSR adjacency row
(exactly GraphSAGE's sampler); isolated nodes self-loop. Host-side numpy for
the data pipeline plus a pure-jax variant (padded adjacency) used inside jit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class CSRGraph(NamedTuple):
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)
    feats: np.ndarray    # (N, F)
    labels: np.ndarray   # (N,)

    @property
    def num_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.indices.shape[0]

    def edge_list(self):
        """(src, dst) arrays — src is the neighbor, dst the row node."""
        dst = np.repeat(np.arange(self.num_nodes), np.diff(self.indptr))
        return self.indices.copy(), dst


def synthetic_graph(seed: int, num_nodes: int, avg_degree: int, d_feat: int,
                    num_classes: int = 41) -> CSRGraph:
    """Power-law-ish random graph with community-correlated features/labels."""
    rng = np.random.RandomState(seed)
    # preferential-attachment-flavored degree sequence
    deg = np.minimum(
        rng.zipf(1.6, size=num_nodes), max(4 * avg_degree, 16)
    ).astype(np.int64)
    deg = np.maximum((deg * avg_degree / max(deg.mean(), 1)).astype(np.int64), 1)
    total = int(deg.sum())
    comm = rng.randint(0, num_classes, size=num_nodes)
    # endpoints biased toward same community
    dst = np.repeat(np.arange(num_nodes), deg)
    same = rng.rand(total) < 0.6
    rand_nbr = rng.randint(0, num_nodes, size=total)
    # same-community neighbor: random node with matching community via shuffle
    by_comm = {c: np.where(comm == c)[0] for c in range(num_classes)}
    comm_pick = np.array(
        [by_comm[comm[d]][rng.randint(len(by_comm[comm[d]]))] for d in dst[same]]
    ) if same.any() else np.empty(0, np.int64)
    src = rand_nbr.copy()
    src[same] = comm_pick
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    centers = rng.randn(num_classes, d_feat).astype(np.float32)
    feats = centers[comm] + 0.5 * rng.randn(num_nodes, d_feat).astype(np.float32)
    return CSRGraph(indptr=indptr, indices=src.astype(np.int32),
                    feats=feats, labels=comm.astype(np.int32))


def sample_neighbors_np(graph: CSRGraph, nodes: np.ndarray, fanout: int,
                        rng: np.random.RandomState) -> np.ndarray:
    """Uniform-with-replacement CSR sampling: (B,) -> (B, fanout) int32."""
    starts = graph.indptr[nodes]
    degs = graph.indptr[nodes + 1] - starts
    out = np.empty((len(nodes), fanout), np.int32)
    r = rng.randint(0, 1 << 30, size=(len(nodes), fanout))
    safe_deg = np.maximum(degs, 1)
    offs = r % safe_deg[:, None]
    out[:] = graph.indices[starts[:, None] + offs]
    out[degs == 0] = nodes[degs == 0, None]  # isolated → self-loop
    return out


def sample_blocks(graph: CSRGraph, seeds: np.ndarray,
                  fanouts: tuple[int, ...], seed: int):
    """GraphSAGE minibatch blocks: features at each hop, dense layout.

    Returns [ (B,F), (B,f1,F), (B,f1,f2,F), ... ] ready for
    models.gnn.minibatch_forward, plus seed labels.
    """
    rng = np.random.RandomState(seed)
    frontier = [seeds.astype(np.int64)]
    for f in fanouts:
        flat = frontier[-1].reshape(-1)
        nbrs = sample_neighbors_np(graph, flat, f, rng)
        frontier.append(nbrs.reshape(*frontier[-1].shape, f))
    feats = [jnp.asarray(graph.feats[ids]) for ids in frontier]
    labels = jnp.asarray(graph.labels[seeds])
    return feats, labels


def padded_adjacency(graph: CSRGraph, max_degree: int):
    """Dense (N, max_degree) neighbor matrix (−1 padded) + (N,) degrees —
    the device-resident form used by the pure-jax sampler."""
    N = graph.num_nodes
    adj = -np.ones((N, max_degree), np.int32)
    deg = np.minimum(np.diff(graph.indptr), max_degree).astype(np.int32)
    for v in range(N):
        s = graph.indptr[v]
        adj[v, : deg[v]] = graph.indices[s : s + deg[v]]
    return jnp.asarray(adj), jnp.asarray(deg)


def sample_neighbors_jax(key: jax.Array, adj: jax.Array, deg: jax.Array,
                         nodes: jax.Array, fanout: int) -> jax.Array:
    """Pure-jax uniform sampler over the padded adjacency (jit/pjit-safe)."""
    r = jax.random.randint(key, (*nodes.shape, fanout), 0, 1 << 30)
    d = jnp.maximum(deg[nodes], 1)[..., None]
    cols = r % d
    nbrs = jnp.take_along_axis(adj[nodes], cols, axis=-1)
    return jnp.where(nbrs >= 0, nbrs, nodes[..., None])
