"""Synthetic data generators (DESIGN.md §8: the paper's industrial click log,
MovieLens and Amazon-Books cannot ship, so every family gets a generator with
matched statistics — power-law popularity, anisotropic embeddings, etc.).

Everything is a pure function of (seed, step) so the pipeline is trivially
checkpointable and deterministic across restarts/elastic re-meshes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Fixed-embedding vectors (SIFT1M stand-in for §3.1 / Fig 2)
# ---------------------------------------------------------------------------

def sift_like(key: jax.Array, num: int, dim: int, num_clusters: int = 16,
              anisotropy: float = 8.0) -> jax.Array:
    """Gaussian mixture with per-cluster anisotropic covariance.

    Real SIFT has strongly correlated coordinates, which is exactly why OPQ
    rotations help; isotropic Gaussians would make the rotation a no-op. Each
    cluster gets a random rotation × log-spaced scales covariance.
    """
    kc, km, kr, ks, ka = jax.random.split(key, 5)
    means = 4.0 * jax.random.normal(km, (num_clusters, dim))
    scales = jnp.exp(
        jnp.log(anisotropy)
        * jax.random.uniform(ks, (num_clusters, dim), minval=-0.5, maxval=0.5)
    )
    # random orthogonal basis per cluster via QR
    zs = jax.random.normal(kr, (num_clusters, dim, dim))
    qs, _ = jnp.linalg.qr(zs)
    assign = jax.random.randint(kc, (num,), 0, num_clusters)
    z = jax.random.normal(ka, (num, dim))
    z = z * scales[assign]
    z = jnp.einsum("nd,nde->ne", z, qs[assign])
    return z + means[assign]


# ---------------------------------------------------------------------------
# LM tokens
# ---------------------------------------------------------------------------

def lm_batch(key: jax.Array, batch: int, seq: int, vocab: int):
    """Zipf-distributed token ids; labels = next-token shift."""
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    logits = -1.1 * jnp.log(ranks)  # zipf exponent ~1.1
    tokens = jax.random.categorical(key, logits, shape=(batch, seq + 1))
    return tokens[:, :-1].astype(jnp.int32), tokens[:, 1:].astype(jnp.int32)


# ---------------------------------------------------------------------------
# Retrieval click-log (two-tower / MIND) with known ground truth
# ---------------------------------------------------------------------------

class ClickLog:
    """Latent-factor click generator.

    Items/users live in a latent space with anisotropic structure; a user's
    history is sampled from items near their latent vector, the next click
    (the label) likewise. Item popularity is zipf — matching the paper's
    industrial setting where a learned index must handle skewed exposure.
    """

    def __init__(self, seed: int, num_items: int, dim: int = 32,
                 num_clusters: int = 64):
        key = jax.random.PRNGKey(seed)
        ki, kp = jax.random.split(key)
        self.num_items = num_items
        self.dim = dim
        self.item_vecs = np.array(  # np.array: writable copy (asarray of a
            # jax array is read-only)
            sift_like(ki, num_items, dim, num_clusters=num_clusters, anisotropy=4.0)
        )
        self.item_vecs /= np.linalg.norm(self.item_vecs, axis=1, keepdims=True) + 1e-9
        pop = 1.0 / np.arange(1, num_items + 1) ** 1.05
        self._pop = pop / pop.sum()

    def batch(self, seed: int, batch: int, hist_len: int, cand: int = 64):
        """Returns (hist_ids (B, L) int32 with −1 pad, pos_ids (B,))."""
        rng = np.random.RandomState(seed)
        # sample a "session anchor" item by popularity, history = its knn-ish
        anchors = rng.choice(self.num_items, size=batch, p=self._pop)
        av = self.item_vecs[anchors]  # (B, d)
        # propose candidates and keep the most similar as history + label
        props = rng.randint(0, self.num_items, size=(batch, cand))
        sims = np.einsum("bd,bcd->bc", av, self.item_vecs[props])
        order = np.argsort(-sims, axis=1)
        top = np.take_along_axis(props, order, axis=1)
        hist = top[:, 1 : hist_len + 1].astype(np.int32)
        if hist.shape[1] < hist_len:
            pad = -np.ones((batch, hist_len - hist.shape[1]), np.int32)
            hist = np.concatenate([hist, pad], axis=1)
        # random-length histories (pad tail with −1)
        lens = rng.randint(max(1, hist_len // 4), hist_len + 1, size=batch)
        mask = np.arange(hist_len)[None, :] < lens[:, None]
        hist = np.where(mask, hist, -1).astype(np.int32)
        pos = top[:, 0].astype(np.int32)
        return jnp.asarray(hist), jnp.asarray(pos)

    def eval_queries(self, seed: int, num: int, hist_len: int, k_truth: int = 100):
        """Queries + ground-truth top-k item sets (by latent similarity) for
        p@k / r@k evaluation (paper Table 1 protocol)."""
        rng = np.random.RandomState(seed)
        hist, _ = [np.asarray(a) for a in self.batch(seed, num, hist_len)]
        hv = np.zeros((num, self.dim))
        for b in range(num):
            ids = hist[b][hist[b] >= 0]
            hv[b] = self.item_vecs[ids].mean(0) if len(ids) else 0.0
        sims = hv @ self.item_vecs.T  # (num, N)
        truth = np.argsort(-sims, axis=1)[:, :k_truth]
        return jnp.asarray(hist), truth


# ---------------------------------------------------------------------------
# CTR (wide&deep / DIN)
# ---------------------------------------------------------------------------

def ctr_batch(key: jax.Array, batch: int, n_fields: int, vocab: int):
    """Sparse ids + labels from a hidden logistic model over field crosses."""
    kf, kl, kw = jax.random.split(key, 3)
    ids = jax.random.randint(kf, (batch, n_fields), 0, vocab)
    # hidden weights: hash each (field, id) to a score
    w = jax.random.normal(kw, (n_fields, 64))
    feat = jax.vmap(lambda row: jnp.take(w, jnp.arange(n_fields), axis=0)
                    * jnp.cos(row[:, None] * 0.37))(ids)
    logit = jnp.sum(feat, axis=(1, 2)) * 0.05
    labels = jax.random.bernoulli(kl, jax.nn.sigmoid(logit)).astype(jnp.float32)
    return ids.astype(jnp.int32), labels


def din_batch(key: jax.Array, batch: int, hist_len: int, vocab: int):
    kh, kt, kl = jax.random.split(key, 3)
    hist = jax.random.randint(kh, (batch, hist_len), 0, vocab).astype(jnp.int32)
    target = jax.random.randint(kt, (batch,), 0, vocab).astype(jnp.int32)
    # label: does the target "match" the history's dominant bucket
    match = (jnp.median(hist % 97, axis=1) - (target % 97)).astype(jnp.float32)
    p = jax.nn.sigmoid(1.0 - 0.1 * jnp.abs(match))
    labels = jax.random.bernoulli(kl, p).astype(jnp.float32)
    return hist, target, labels
