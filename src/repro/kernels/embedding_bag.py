"""Pallas TPU kernel: EmbeddingBag(sum) via scalar-prefetch gather.

JAX has no native EmbeddingBag; the recsys hot path is a ragged gather from a
huge HBM-resident table followed by a per-bag reduction. On TPU the idiomatic
implementation is a **scalar-prefetch** kernel: the flat index array is
prefetched into SMEM, and each grid step's BlockSpec index_map uses it to DMA
exactly one table row block HBM→VMEM — no dense one-hot, no table copy.

Bag reduction uses output-block revisiting: ``bag_ids`` must be sorted
ascending; consecutive grid steps that map to the same output row keep the
block resident in VMEM and accumulate into it, zeroing on first visit.

Grid (L,): one looked-up row per step. The jit wrapper in ops.py pads L and
handles per-sample weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET


def _kernel(idx_ref, bag_ref, w_ref, row_ref, out_ref):
    # idx_ref unused in the body (it drives the row BlockSpec index_map);
    # padded slots are neutralized by the wrapper zeroing their weight.
    del idx_ref
    l = pl.program_id(0)
    first = jnp.where(l == 0, 1, (bag_ref[l] != bag_ref[l - 1]).astype(jnp.int32))

    @pl.when(first == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[l].astype(jnp.float32)
    out_ref[...] += w * row_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_bags", "interpret"))
def embedding_bag(
    table: jax.Array,
    indices: jax.Array,
    bag_ids: jax.Array,
    num_bags: int,
    weights: jax.Array | None = None,
    *,
    interpret: bool = INTERPRET,
) -> jax.Array:
    """table (V, dim); indices (L,) int32 (−1 = padding); bag_ids (L,) int32
    sorted ascending; optional weights (L,). -> (num_bags, dim) float32."""
    L = indices.shape[0]
    V, dim = table.shape
    if weights is None:
        weights = jnp.ones((L,), jnp.float32)
    valid = indices >= 0
    safe_idx = jnp.maximum(indices, 0)  # keep DMA in-bounds for padded slots
    weights = jnp.where(valid, weights.astype(jnp.float32), 0.0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # safe_idx, bag_ids, weights
        grid=(L,),
        in_specs=[
            # one table row per step, chosen by the prefetched index
            pl.BlockSpec((1, dim), lambda l, idx, bags, w: (idx[l], 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda l, idx, bags, w: (bags[l], 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_bags, dim), jnp.float32),
        interpret=interpret,
    )(safe_idx, bag_ids, weights, table)
    # bags with no entries are never visited by the kernel: zero them.
    present = jax.ops.segment_max(
        jnp.ones_like(bag_ids, jnp.float32), bag_ids, num_segments=num_bags
    )
    return jnp.where(present[:, None] > 0, out, 0.0)
