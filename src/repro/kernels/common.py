"""Shared helpers for the Pallas TPU kernels.

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with ``interpret=True``, which executes the kernel body in
Python. ``INTERPRET`` flips automatically off-TPU so the same call sites work
in both environments.
"""
from __future__ import annotations

import jax

# interpret=True everywhere except a real TPU backend.
INTERPRET = jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pick_block(dim: int, preferred: int, align: int = 128) -> int:
    """Largest hardware-aligned block ≤ preferred that does not exceed dim
    (padded up to ``align`` when dim itself is small)."""
    if dim <= preferred:
        return round_up(dim, align) if dim % align else dim
    b = preferred - (preferred % align) or align
    return b
