"""Pallas TPU kernel: flat ADC (asymmetric distance computation) score scan.

Scores a query batch against N PQ/RQ-coded items:
out[b, n] = Σ_d LUT[b, d, c_nd]. CPU/GPU implementations use SIMD gathers
(André et al. 2015); this kernel scores each item tile with the shared
one-hot-MXU body (adc_common.adc_tile_scores) — HBM traffic stays at
O(N·Dp + N·b). Residual depth rides in the Dp column dimension.

Tombstone masking lives INSIDE the tile body: with an ``ids`` operand the
per-row id column rides the same HBM→VMEM pipeline as the codes and rows
with id < 0 (holes/deletes) score −inf before the tile is written back —
deletes are O(1) id writes that never reshape the scan.

Grid (N/bn,): each step scores one item tile against all b queries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.adc_common import adc_tile_scores
from repro.kernels.common import INTERPRET, cdiv


def _kernel(codes_ref, lut_ref, out_ref):
    scores = adc_tile_scores(codes_ref[...], lut_ref[...])  # (bn, b)
    out_ref[...] = scores.astype(out_ref.dtype)


def _kernel_q(codes_ref, lut_ref, scales_ref, out_ref):
    # quantized path: int8/uint8 LUT bytes cross HBM, dequant happens here
    scores = adc_tile_scores(codes_ref[...], lut_ref[...], scales_ref[...])
    out_ref[...] = scores.astype(out_ref.dtype)


def _kernel_m(codes_ref, lut_ref, ids_ref, out_ref):
    # masked path: the (bn, 1) id column broadcasts over the query axis
    scores = adc_tile_scores(codes_ref[...], lut_ref[...])
    scores = jnp.where(ids_ref[...] >= 0, scores, -jnp.inf)
    out_ref[...] = scores.astype(out_ref.dtype)


def _kernel_qm(codes_ref, lut_ref, scales_ref, ids_ref, out_ref):
    scores = adc_tile_scores(codes_ref[...], lut_ref[...], scales_ref[...])
    scores = jnp.where(ids_ref[...] >= 0, scores, -jnp.inf)
    out_ref[...] = scores.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def adc_lookup(
    lut: jax.Array,
    codes: jax.Array,
    scales: jax.Array | None = None,
    ids: jax.Array | None = None,
    *,
    block_n: int = 1024,
    interpret: bool = INTERPRET,
) -> jax.Array:
    """lut (b, Dp, K) float, codes (N, Dp) integer  ->  scores (b, N) float32.

    With ``scales`` (b, Dp, 2) the lut is an int8/uint8 pack from
    ``adc_common.quantize_luts``; the tile body dequantizes in VMEM so the
    per-step LUT DMA moves 4× fewer bytes. With ``ids`` (N,) the tombstone
    mask applies in VMEM: rows with id < 0 come out −inf."""
    b, Dp, K = lut.shape
    N = codes.shape[0]
    bn = min(block_n, N)
    grid = (cdiv(N, bn),)
    in_specs = [
        pl.BlockSpec((bn, Dp), lambda i: (i, 0)),
        pl.BlockSpec((b, Dp, K), lambda i: (0, 0, 0)),
    ]
    operands = [codes, lut]
    kernel = {(False, False): _kernel, (True, False): _kernel_q,
              (False, True): _kernel_m, (True, True): _kernel_qm}[
        (scales is not None, ids is not None)]
    if scales is not None:
        in_specs.append(pl.BlockSpec((b, Dp, 2), lambda i: (0, 0, 0)))
        operands.append(scales)
    if ids is not None:
        in_specs.append(pl.BlockSpec((bn, 1), lambda i: (i, 0)))
        operands.append(ids.reshape(N, 1).astype(jnp.int32))
    # codes stay in their storage dtype (uint8 for K ≤ 256) all the way to
    # VMEM — the shared tile body widens per tile; widening here would
    # materialize a 4× int32 copy of the whole corpus per call.
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, b), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out.T
