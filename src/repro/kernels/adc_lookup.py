"""Pallas TPU kernel: ADC (asymmetric distance computation) score scan.

Scores a query batch against N PQ-coded items: out[b, n] = Σ_d LUT[b, d, c_nd].
CPU/GPU implementations use SIMD gathers (André et al. 2015); gathers are
lane-hostile on TPU, so this kernel uses the **one-hot matmul trick**
(DESIGN.md §2): a (bn, D·K) one-hot expansion of the code tile is contracted
against the reshaped LUT on the MXU. The one-hot tile lives only in VMEM and
is rebuilt per grid step — HBM traffic stays at O(N·D + N·b).

Grid (N/bn,): each step scores one item tile against all b queries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv


def _kernel(codes_ref, lut_ref, out_ref, *, K: int):
    codes = codes_ref[...].astype(jnp.int32)        # (bn, D)
    lut = lut_ref[...].astype(jnp.float32)          # (b, D, K)
    b, D, _ = lut.shape
    bn = codes.shape[0]
    # one-hot over the K axis: (bn, D, K)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, D, K), 2)
    onehot = (iota == codes[:, :, None]).astype(jnp.float32)
    scores = jax.lax.dot_general(
        onehot.reshape(bn, D * K),
        lut.reshape(b, D * K),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, b)
    out_ref[...] = scores.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def adc_lookup(
    lut: jax.Array,
    codes: jax.Array,
    *,
    block_n: int = 1024,
    interpret: bool = INTERPRET,
) -> jax.Array:
    """lut (b, D, K) float, codes (N, D) integer  ->  scores (b, N) float32."""
    b, D, K = lut.shape
    N = codes.shape[0]
    bn = min(block_n, N)
    grid = (cdiv(N, bn),)
    out = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
            pl.BlockSpec((b, D, K), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, b), jnp.float32),
        interpret=interpret,
    )(codes.astype(jnp.int32), lut)
    return out.T
