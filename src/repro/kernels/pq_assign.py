"""Pallas TPU kernel: fused PQ codeword assignment (nearest-centroid search).

For each subspace d, each row x of the (m, sub) slice is assigned
argmin_k ‖x − C[d,k]‖² = argmin_k (‖C[d,k]‖² − 2⟨x, C[d,k]⟩). The kernel
fuses the MXU distance matmul with the argmin epilogue so the (bm, K) score
tile never leaves VMEM — the XLA fallback materializes all (m, D, K) scores
in HBM.

Grid (D, m/bm): one subspace × one row tile per step; the full (K, sub)
codebook slice for that subspace rides along in VMEM (K ≤ 256, sub ≤ 128 →
≤128 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv


def _kernel(x_ref, cb_ref, out_ref):
    x = x_ref[0].astype(jnp.float32)          # (bm, sub)
    cb = cb_ref[0].astype(jnp.float32)        # (K, sub)
    dots = jax.lax.dot_general(
        x, cb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bm, K)
    cn = jnp.sum(jnp.square(cb), axis=-1)[None, :]  # (1, K)
    out_ref[...] = jnp.argmin(cn - 2.0 * dots, axis=-1).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def pq_assign(
    X: jax.Array,
    codebooks: jax.Array,
    *,
    block_m: int = 512,
    interpret: bool = INTERPRET,
) -> jax.Array:
    """X (m, n), codebooks (D, K, sub) with n = D·sub  ->  codes (m, D) int32."""
    m, n = X.shape
    D, K, sub = codebooks.shape
    assert n == D * sub
    bm = min(block_m, m)
    Xs = X.reshape(m, D, sub).transpose(1, 0, 2)  # (D, m, sub): subspace-major
    grid = (D, cdiv(m, bm))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, sub), lambda d, i: (d, i, 0)),
            pl.BlockSpec((1, K, sub), lambda d, i: (d, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda d, i: (i, d)),
        out_shape=jax.ShapeDtypeStruct((m, D), jnp.int32),
        interpret=interpret,
    )(Xs, codebooks)
    return out
