"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>_ref`` is the semantic ground truth: tests sweep shapes/dtypes
and assert the kernel output is allclose to these. They are also the XLA
fallback path used on hosts where Pallas lowering is unavailable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.adc_common import dequantize_luts


def givens_rotate_ref(xe: jax.Array, xo: jax.Array, c: jax.Array, s: jax.Array):
    """Rotate paired column planes: (m, p) × 2, cos/sin (p,) -> (ye, yo).

    ye = c·xe + s·xo ;  yo = c·xo − s·xe   (column pairs already permuted
    adjacent by the caller — see core.givens.apply_pair_rotations).
    """
    c = c.astype(xe.dtype)[None, :]
    s = s.astype(xe.dtype)[None, :]
    return c * xe + s * xo, c * xo - s * xe


def gcd_score_ref(G: jax.Array, R: jax.Array) -> jax.Array:
    """A = M − Mᵀ with M = GᵀR (paper Algorithm 2 line 3)."""
    M = G.T.astype(jnp.float32) @ R.astype(jnp.float32)
    return (M - M.T).astype(R.dtype)


def pq_assign_ref(X: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Nearest codeword per subspace. X (m, n), codebooks (D, K, sub) -> (m, D)."""
    D = codebooks.shape[0]
    m, n = X.shape
    Xs = X.reshape(m, D, n // D)
    dots = jnp.einsum("mds,dks->mdk", Xs, codebooks)
    cn = jnp.sum(jnp.square(codebooks), axis=-1)
    return jnp.argmin(cn[None] - 2.0 * dots, axis=-1).astype(jnp.int32)


def adc_lookup_ref(lut: jax.Array, codes: jax.Array,
                   scales: jax.Array | None = None,
                   ids: jax.Array | None = None) -> jax.Array:
    """ADC score sum. lut (b, D, K), codes (N, D) -> (b, N).

    With ``scales`` (b, D, 2) the lut is an int8/uint8 pack from
    ``adc_common.quantize_luts`` and is dequantized first (semantic ground
    truth for the in-VMEM dequant the kernels do). With ``ids`` (N,) the
    tombstone mask is applied inside the scan: rows with id < 0 (holes and
    deletes) score −inf, so a delete is an O(1) id write and never reshapes
    the scored array."""
    if scales is not None:
        lut = dequantize_luts(lut, scales)
    D = lut.shape[1]
    g = lut[:, jnp.arange(D)[None, :], codes.astype(jnp.int32)]  # (b, N, D)
    out = jnp.sum(g, axis=-1)
    if ids is not None:
        out = jnp.where(ids[None, :] >= 0, out, -jnp.inf)
    return out


def fused_lut_ref(Q: jax.Array, qdelta: jax.Array, cb_flat: jax.Array,
                  colmap: jax.Array) -> jax.Array:
    """Rotation-fused ADC-LUT build. Q (b, n) raw queries, qdelta (n, n)
    composed query-side transform (R₀·Δ·Wᵀ — see search.flat fused refresh),
    cb_flat (Dp, K, sub) frozen flattened codebooks, colmap (Dp, D) one-hot
    mapping code column → query subspace (identity for PQ; for a depth-M RQ
    the level-major column l·D+d maps to subspace d) -> (b, Dp, K) with
    lut[b, p, k] = ⟨(Q·qdelta) subspace of column p, cb_flat[p, k]⟩.

    This is the oracle for kernels/lut_build.py: the delta is applied to the
    query block inside the tile body, so refresh never rebuilds corpus-side
    state."""
    QL = Q.astype(jnp.float32) @ qdelta.astype(jnp.float32)        # (b, n)
    b, n = QL.shape
    Dp, K, sub = cb_flat.shape
    D = colmap.shape[1]
    QLs = QL.reshape(b, D, sub)
    Qexp = jnp.einsum("pd,bds->bps", colmap.astype(jnp.float32), QLs)
    return jnp.einsum("bps,pks->bpk", Qexp, cb_flat.astype(jnp.float32))


def adc_batch_ref(lut: jax.Array, codes: jax.Array,
                  scales: jax.Array | None = None) -> jax.Array:
    """Grouped ADC score sum (KV-cache scoring). lut (g, r, Dp, K),
    codes (g, S, Dp) -> (g, r, S) with
    out[g, r, s] = Σ_d lut[g, r, d, codes[g, s, d]].

    Accumulated with a scan over the Dp columns so the peak gather buffer is
    O(g·r·S) instead of O(g·r·S·Dp) — at S=524288 decode shapes the all-Dp
    gather costs GiBs/device (the Pallas adc_batch kernel tiles a one-hot
    matmul instead; this is the XLA-safe reference path).

    ``scales`` (g, r, Dp, 2): quantized-LUT pack, dequantized up front.
    """
    if scales is not None:
        lut = dequantize_luts(lut, scales)
    g, r, Dp, K = lut.shape
    S = codes.shape[1]
    lut_d = jnp.moveaxis(lut.astype(jnp.float32), -2, 0)    # (Dp, g, r, K)
    codes_d = jnp.moveaxis(codes.astype(jnp.int32), -1, 0)  # (Dp, g, S)

    def add_one(acc, dl):
        l_d, c_d = dl  # (g, r, K), (g, S)
        return acc + jnp.take_along_axis(l_d, c_d[:, None, :], axis=-1), None

    acc0 = jnp.zeros((g, r, S), jnp.float32)
    out, _ = jax.lax.scan(add_one, acc0, (lut_d, codes_d))
    return out


def ivf_adc_ref(lut: jax.Array, codes: jax.Array, block_idx: jax.Array,
                block_query: jax.Array, *, block_size: int = 128,
                scales: jax.Array | None = None,
                ids: jax.Array | None = None) -> jax.Array:
    """Selected-block ADC scan. lut (b, D, K), codes (cap, D),
    block_idx/block_query (S,) -> (S, block_size): the scores of tile
    ``block_idx[s]`` of the CSR codes array under query ``block_query[s]``'s
    LUT (gather formulation; the Pallas kernel must match).

    ``scales`` (b, D, 2): quantized-LUT pack, dequantized up front.
    ``ids`` (cap,): tombstone mask — rows with id < 0 score −inf inside the
    scan, so holes and deletes never surface however the caller post-
    processes (the added coarse term is finite and cannot resurrect them)."""
    if scales is not None:
        lut = dequantize_luts(lut, scales)
    D = lut.shape[1]
    rows = block_idx[:, None] * block_size + jnp.arange(block_size)  # (S, bn)
    c = codes[rows].astype(jnp.int32)  # gather in storage dtype, widen after
    # (S, D, K) LUT replication below is notation, not allocation: XLA fuses
    # the gather chain into the reduction (benchmark runs 100k × nprobe=64
    # through this path without a materialized l_sel).
    l_sel = lut[block_query.astype(jnp.int32)]                       # (S, D, K)
    g = jnp.take_along_axis(
        l_sel[:, None, :, :], c[..., None], axis=-1
    )[..., 0]                                                        # (S, bn, D)
    out = jnp.sum(g, axis=-1).astype(jnp.float32)
    if ids is not None:
        out = jnp.where(ids[rows] >= 0, out, -jnp.inf)
    return out


def embedding_bag_ref(table: jax.Array, indices: jax.Array, bag_ids: jax.Array,
                      num_bags: int, weights: jax.Array | None = None) -> jax.Array:
    """EmbeddingBag(sum): table (V, dim), flat indices (L,), sorted bag_ids (L,)
    -> (num_bags, dim). JAX has no native EmbeddingBag — this is the
    take + segment_sum construction the system uses everywhere."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(rows, bag_ids, num_segments=num_bags)


def topk_merge_ref(scores: jax.Array, ids: jax.Array,
                   k: int) -> tuple[jax.Array, jax.Array]:
    """Merge concatenated per-shard top-k runs into one global top-k.

    scores/ids (b, C) — C = shards·k after the sharded searcher's
    all_gather — under the SearchResult padding contract: slots past the
    candidate pool carry score −inf, and every −inf slot gets id −1 so
    padding survives the merge. Returns (b, k), padded the same way when
    k > C.

    Tie-breaking is deterministic: equal scores rank by ascending id
    (a lexicographic two-key sort, not ``top_k``'s positional tie-break),
    so the merged top-k is a pure function of the candidate SET — invariant
    to shard order, tile order, and whichever batch composition a serving
    request landed in (the repro.serve determinism contract).
    """
    b, C = scores.shape
    kk = min(k, C)
    # ascending (−score, id): equal scores break to the smaller id. −inf
    # slots sort last regardless of id and are re-padded to −1 below.
    neg_sorted, top_ids = jax.lax.sort(
        (-scores, ids.astype(jnp.int32)), dimension=1, num_keys=2)
    top_scores = -neg_sorted[:, :kk]
    top_ids = top_ids[:, :kk]
    top_ids = jnp.where(jnp.isfinite(top_scores), top_ids, -1)
    if kk < k:
        top_scores = jnp.pad(top_scores, ((0, 0), (0, k - kk)),
                             constant_values=-jnp.inf)
        top_ids = jnp.pad(top_ids, ((0, 0), (0, k - kk)),
                          constant_values=-1)
    return top_scores, top_ids


def streaming_topk_ref(tile_scores, tile_ids,
                       k: int) -> tuple[jax.Array, jax.Array]:
    """Incremental top-k merge over a stream of corpus tiles.

    tile_scores: sequence of (b, t_i) score blocks; tile_ids: matching
    (t_i,) global row ids (−1 = padding — masked to −inf here, exactly
    like the scan's merge body). Folds each tile into a (b, k) carry via
    topk_merge_ref — the semantic ground truth for the streaming exact
    scan in search/exact.py.

    With distinct scores the result is invariant to tile order and equal to
    a one-shot top_k over the full concatenation (the tile-order-invariance
    test in tests/test_kernels.py pins exactly that).
    """
    b = tile_scores[0].shape[0]
    acc_s = jnp.full((b, k), -jnp.inf, jnp.float32)
    acc_i = jnp.full((b, k), -1, jnp.int32)
    for s, ids in zip(tile_scores, tile_ids):
        ids = ids.astype(jnp.int32)
        s = jnp.where(ids[None, :] >= 0, s.astype(jnp.float32), -jnp.inf)
        cs = jnp.concatenate([acc_s, s], axis=1)
        ci = jnp.concatenate(
            [acc_i, jnp.broadcast_to(ids[None, :], s.shape)], axis=1)
        acc_s, acc_i = topk_merge_ref(cs, ci, k)
    return acc_s, acc_i
