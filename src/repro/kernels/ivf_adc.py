"""Pallas TPU kernel: fused IVF-ADC scan over selected inverted-list blocks.

Extends the one-hot-matmul ADC trick of ``adc_lookup.py`` from "score every
item" to "score exactly the blocks the coarse probe selected". The search
layer turns (query, probed list) pairs into a flat schedule of
``block_size``-row tiles of the CSR codes array:

    block_idx[s]   — which codes tile step s scans (tile units, not rows)
    block_query[s] — which query's LUT scores it

Both ride in as **scalar-prefetch** operands (PrefetchScalarGridSpec), so the
BlockSpec index_map can steer the automatic HBM→VMEM pipeline straight at the
selected tiles: codes reach VMEM as sequential tile DMAs — gather-free, same
HBM traffic as a dense scan of the *selected* rows only. In VMEM the tile is
one-hot expanded over K and contracted against the query's (D·K) LUT row on
the MXU, exactly like the flat kernel.

Grid: one step per selected (query, block) pair; out[s] = scores of the
``block_size`` items of that tile. With an ``ids`` operand the tile's id row
is DMA'd alongside its codes (steered by the same ``block_idx`` index_map)
and rows with id < 0 — CSR padding holes and tombstoned deletes — score
−inf inside the tile body, so a delete is one id write and masked rows can
never surface downstream (the caller's added coarse term is finite).
One LUT row per step keeps the schedule fully general (any query mix); batch
efficiency comes from the ~100× fewer tiles the probe selects, not from
sharing tiles between queries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.adc_common import adc_tile_scores
from repro.kernels.common import INTERPRET


def _kernel(bi_ref, bq_ref, codes_ref, lut_ref, out_ref):
    del bi_ref, bq_ref  # consumed by the index_maps
    bn = codes_ref.shape[0]
    # shared family body with b = 1 (this step's query LUT): (bn, 1)
    scores = adc_tile_scores(codes_ref[...], lut_ref[...])
    out_ref[...] = scores.reshape(1, bn).astype(out_ref.dtype)


def _kernel_q(bi_ref, bq_ref, codes_ref, lut_ref, scales_ref, out_ref):
    del bi_ref, bq_ref  # consumed by the index_maps
    bn = codes_ref.shape[0]
    # quantized path: this step's LUT row rides in as int8/uint8 + its
    # (1, Dp, 2) scale row; dequant happens in VMEM
    scores = adc_tile_scores(codes_ref[...], lut_ref[...], scales_ref[...])
    out_ref[...] = scores.reshape(1, bn).astype(out_ref.dtype)


def _kernel_m(bi_ref, bq_ref, codes_ref, lut_ref, ids_ref, out_ref):
    del bi_ref, bq_ref  # consumed by the index_maps
    bn = codes_ref.shape[0]
    scores = adc_tile_scores(codes_ref[...], lut_ref[...]).reshape(1, bn)
    # (1, bn) id tile of this codes block: holes/tombstones → −inf
    scores = jnp.where(ids_ref[...] >= 0, scores, -jnp.inf)
    out_ref[...] = scores.astype(out_ref.dtype)


def _kernel_qm(bi_ref, bq_ref, codes_ref, lut_ref, scales_ref, ids_ref,
               out_ref):
    del bi_ref, bq_ref  # consumed by the index_maps
    bn = codes_ref.shape[0]
    scores = adc_tile_scores(
        codes_ref[...], lut_ref[...], scales_ref[...]).reshape(1, bn)
    scores = jnp.where(ids_ref[...] >= 0, scores, -jnp.inf)
    out_ref[...] = scores.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def ivf_adc(
    lut: jax.Array,
    codes: jax.Array,
    block_idx: jax.Array,
    block_query: jax.Array,
    scales: jax.Array | None = None,
    ids: jax.Array | None = None,
    *,
    block_size: int = 128,
    interpret: bool = INTERPRET,
) -> jax.Array:
    """lut (b, Dp, K) float, codes (cap, Dp) int (cap % block_size == 0),
    block_idx / block_query (S,) int32  ->  scores (S, block_size) float32.

    Residual depth rides in the Dp column dimension (Dp = M·D for RQ).
    With ``scales`` (b, Dp, 2) the lut is an int8/uint8 quantize_luts pack —
    the per-step LUT-row DMA moves 4× fewer bytes. With ``ids`` (cap,) the
    tombstone mask applies inside the tile body (rows with id < 0 → −inf)."""
    b, Dp, K = lut.shape
    S = block_idx.shape[0]
    in_specs = [
        pl.BlockSpec((block_size, Dp), lambda i, bi, bq: (bi[i], 0)),
        pl.BlockSpec((1, Dp, K), lambda i, bi, bq: (bq[i], 0, 0)),
    ]
    operands = [codes, lut]
    kernel = {(False, False): _kernel, (True, False): _kernel_q,
              (False, True): _kernel_m, (True, True): _kernel_qm}[
        (scales is not None, ids is not None)]
    if scales is not None:
        in_specs.append(pl.BlockSpec((1, Dp, 2), lambda i, bi, bq: (bq[i], 0, 0)))
        operands.append(scales)
    if ids is not None:
        # the id column folded to (cap/bs, bs) tiles so the SAME block_idx
        # prefetch steers its DMA as steers the codes tile
        in_specs.append(pl.BlockSpec((1, block_size),
                                     lambda i, bi, bq: (bi[i], 0)))
        operands.append(
            ids.reshape(codes.shape[0] // block_size, block_size)
            .astype(jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_size), lambda i, bi, bq: (i, 0)),
    )
    # codes stay in their storage dtype (uint8 for K ≤ 256) all the way to
    # VMEM — the kernel widens per tile; widening here would materialize a
    # 4× int32 copy of the whole corpus per call.
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, block_size), jnp.float32),
        interpret=interpret,
    )(block_idx.astype(jnp.int32), block_query.astype(jnp.int32), *operands)
