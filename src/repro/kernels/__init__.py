"""Pallas TPU kernels for the paper's compute hot spots.

The paper's pitch is parallel rotation learning; the hot paths it (and our
beyond-paper extensions) exercise are:

  givens_rotate   apply n/2 disjoint Givens rotations (plane combine)
  gcd_score       A = GᵀR − RᵀG fused matmul + antisymmetrize
  pq_assign       nearest-codeword search fused with argmin epilogue
  adc_lookup      ADC score scan via the one-hot MXU trick (flat corpus)
  ivf_adc         selected-block ADC scan for the IVF index — the tile
                  schedule arrives via scalar prefetch (repro.index.search)
  embedding_bag   scalar-prefetch gather + bag-sum (recsys substrate)

``ops`` holds the jit'd wrappers (public API), ``ref`` the pure-jnp oracles.
All kernels validate on CPU with interpret=True.
"""
from repro.kernels import ops, ref  # noqa: F401
