"""Pallas TPU kernels for the paper's compute hot spots.

The paper's pitch is parallel rotation learning; the hot paths it (and our
beyond-paper extensions) exercise are:

  givens_rotate   apply n/2 disjoint Givens rotations (plane combine)
  gcd_score       A = GᵀR − RᵀG fused matmul + antisymmetrize
  pq_assign       nearest-codeword search fused with argmin epilogue
  adc_lookup      flat ADC scan over the whole corpus
  ivf_adc         selected-block ADC scan for the IVF index — the tile
                  schedule arrives via scalar prefetch (repro.index.search)
  adc_batch       grouped ADC scan — per-group codes × per-group LUTs
                  (KV-cache decode scoring, core.kv_quant)
  embedding_bag   scalar-prefetch gather + bag-sum (recsys substrate)

The three ADC kernels are one family: each scores VMEM code tiles against
per-query LUTs with the shared one-hot-MXU body (``adc_common``), and all
are parameterized by residual depth through the LUT/code column dimension
(Dp = M·D for a depth-M residual quantizer — see repro.quant).

``ops`` holds the jit'd wrappers (public API), ``ref`` the pure-jnp oracles.
All kernels validate on CPU with interpret=True.
"""
from repro.kernels import ops, ref  # noqa: F401
