"""Pallas TPU kernel: fused GCD directional-derivative matrix A = M − Mᵀ,
M = GᵀR (paper Algorithm 2 line 3).

Computing M then transposing costs two n² passes over HBM; this kernel
computes, for each output tile (I, J), BOTH partial products

    acc   += G[k-block, I]ᵀ · R[k-block, J]      (tile of M)
    accT  += G[k-block, J]ᵀ · R[k-block, I]      (tile of Mᵀ, pre-transpose)

on the MXU and writes A[I, J] = acc − accTᵀ in one shot — M is never
materialized. Grid (I, J, K) with K innermost so the accumulators live in
VMEM scratch across the contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, cdiv


def _kernel(gi_ref, gj_ref, ri_ref, rj_ref, out_ref, acc_ref, accT_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        accT_ref[...] = jnp.zeros_like(accT_ref)

    gi = gi_ref[...].astype(jnp.float32)  # (bk, bi)
    gj = gj_ref[...].astype(jnp.float32)  # (bk, bj)
    ri = ri_ref[...].astype(jnp.float32)  # (bk, bi)
    rj = rj_ref[...].astype(jnp.float32)  # (bk, bj)
    acc_ref[...] += jax.lax.dot_general(
        gi, rj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    accT_ref[...] += jax.lax.dot_general(
        gj, ri, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = (acc_ref[...] - accT_ref[...].T).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "block_k", "interpret"))
def gcd_score(
    G: jax.Array,
    R: jax.Array,
    *,
    block: int = 256,
    block_k: int = 512,
    interpret: bool = INTERPRET,
):
    """A = GᵀR − RᵀG for G, R (n, n). Returns float32 (n, n) antisymmetric."""
    n = G.shape[0]
    b = min(block, n)
    bk = min(block_k, n)
    nk = cdiv(n, bk)
    grid = (cdiv(n, b), cdiv(n, b), nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, b), lambda i, j, k: (k, i)),  # G[:, I]
            pl.BlockSpec((bk, b), lambda i, j, k: (k, j)),  # G[:, J]
            pl.BlockSpec((bk, b), lambda i, j, k: (k, i)),  # R[:, I]
            pl.BlockSpec((bk, b), lambda i, j, k: (k, j)),  # R[:, J]
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((b, b), jnp.float32),  # M tile accumulator
            pltpu.VMEM((b, b), jnp.float32),  # Mᵀ tile accumulator
        ],
        interpret=interpret,
    )(G, G, R, R)
