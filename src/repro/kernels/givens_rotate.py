"""Pallas TPU kernel: apply n/2 disjoint Givens rotations to paired planes.

TPU adaptation of the paper's "sparse matmul" rotation application (DESIGN.md
§2): the caller permutes pair columns adjacent (cheap XLA gather), after which
the commuting block update is a pure elementwise combine of two column planes

    ye = c⊙xe + s⊙xo        yo = c⊙xo − s⊙xe

with cos/sin broadcast down the rows. This is memory-roofline optimal:
4 plane reads + 2 plane writes, zero matmuls, no MXU dependency.

Tiling: grid (m/bm, p/bp); each step holds a (bm, bp) tile of both planes and
a (1, bp) strip of cos/sin in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv


def _kernel(c_ref, s_ref, xe_ref, xo_ref, ye_ref, yo_ref):
    c = c_ref[...].astype(jnp.float32)  # (1, bp)
    s = s_ref[...].astype(jnp.float32)
    xe = xe_ref[...].astype(jnp.float32)
    xo = xo_ref[...].astype(jnp.float32)
    ye_ref[...] = (c * xe + s * xo).astype(ye_ref.dtype)
    yo_ref[...] = (c * xo - s * xe).astype(yo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_p", "interpret"))
def givens_rotate(
    xe: jax.Array,
    xo: jax.Array,
    c: jax.Array,
    s: jax.Array,
    *,
    block_m: int = 256,
    block_p: int = 256,
    interpret: bool = INTERPRET,
):
    """xe/xo: (m, p) paired column planes; c/s: (p,) cos/sin. -> (ye, yo)."""
    m, p = xe.shape
    bm, bp = min(block_m, m), min(block_p, p)
    grid = (cdiv(m, bm), cdiv(p, bp))
    c2 = c.reshape(1, p)
    s2 = s.reshape(1, p)
    out_shape = (
        jax.ShapeDtypeStruct((m, p), xe.dtype),
        jax.ShapeDtypeStruct((m, p), xo.dtype),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bp), lambda i, j: (0, j)),   # cos strip
            pl.BlockSpec((1, bp), lambda i, j: (0, j)),   # sin strip
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),  # xe tile
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),  # xo tile
        ],
        out_specs=(
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(c2, s2, xe, xo)
