"""Shared tile body of the ADC kernel family.

Every ADC scan in the system — the flat corpus scan (adc_lookup.py), the
IVF selected-block scan (ivf_adc.py), and the grouped KV-cache scorer
(adc_batch.py) — scores a VMEM tile of PQ/RQ codes against per-query lookup
tables with the same **one-hot matmul trick** (DESIGN.md §2): gathers are
lane-hostile on TPU, so the (bn, Dp·K) one-hot expansion of the code tile is
contracted against the reshaped LUT on the MXU. The one-hot tile lives only
in VMEM and is rebuilt per grid step.

The family is parameterized by residual depth purely through the column
dimension: a depth-M residual quantizer presents ``Dp = M·D`` code columns
and a (b, M·D, K) LUT (quant.rq flattens the level axis), so multi-level
schemes reuse these kernels unchanged.

Quantized LUTs (the FAISS/ScaNN int8 trick): the scan is bandwidth-bound at
large batch, and the LUT is the only per-query operand streamed into every
tile, so storing it int8/uint8 with per-(query, column) scales divides that
HBM traffic by 4. ``quantize_luts`` produces the (qlut, scales) pack;
``adc_tile_scores`` dequantizes in VMEM right before the MXU contraction, so
the f32 tables never exist outside the tile body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: LUT dtypes the scan kernels accept. "float32" means an unquantized plain
#: array; the integer dtypes mean a (qlut, scales) pack from quantize_luts.
LUT_DTYPES = ("float32", "int8", "uint8")


def quantize_luts(lut: jax.Array, dtype: str) -> tuple[jax.Array, jax.Array]:
    """Quantize ADC tables per (query, code-column) subspace.

    lut (..., Dp, K) float -> (qlut (..., Dp, K) int8|uint8,
    scales (..., Dp, 2) float32) where scales[..., 0] is the dequant scale
    and scales[..., 1] the offset: ``lut ≈ qlut * scale + offset``.

    int8 is symmetric (offset 0, scale = amax/127 — sign-preserving, the
    right choice for inner-product tables); uint8 is asymmetric affine over
    [min, max]. A constant column (amax or range 0) would produce scale 0
    and a divide-by-zero on the encode side, so scale is clamped to 1 there;
    the column dequantizes exactly via the offset.
    """
    lut = lut.astype(jnp.float32)
    if dtype == "int8":
        amax = jnp.max(jnp.abs(lut), axis=-1)
        scale = jnp.where(amax == 0.0, 1.0, amax / 127.0)
        offset = jnp.zeros_like(scale)
        q = jnp.clip(jnp.round(lut / scale[..., None]), -127, 127)
        qlut = q.astype(jnp.int8)
    elif dtype == "uint8":
        lo = jnp.min(lut, axis=-1)
        hi = jnp.max(lut, axis=-1)
        rng = hi - lo
        scale = jnp.where(rng == 0.0, 1.0, rng / 255.0)
        offset = lo
        q = jnp.clip(jnp.round((lut - lo[..., None]) / scale[..., None]),
                     0, 255)
        qlut = q.astype(jnp.uint8)
    else:
        raise ValueError(f"quantize_luts: dtype must be int8|uint8, "
                         f"got {dtype!r}")
    return qlut, jnp.stack([scale, offset], axis=-1)


def dequantize_luts(qlut: jax.Array, scales: jax.Array) -> jax.Array:
    """Invert quantize_luts: (..., Dp, K) int + (..., Dp, 2) -> f32 tables."""
    return (qlut.astype(jnp.float32) * scales[..., 0][..., None]
            + scales[..., 1][..., None])


def adc_tile_scores(codes: jax.Array, lut: jax.Array,
                    scales: jax.Array | None = None) -> jax.Array:
    """Score one code tile against a LUT batch inside a kernel body.

    codes (bn, Dp) integer, lut (b, Dp, K) float -> (bn, b) float32 with
    out[n, q] = Σ_d lut[q, d, codes[n, d]].

    With ``scales`` (b, Dp, 2) the lut is an integer table from
    quantize_luts and is dequantized here, in VMEM, after the cheap int
    load — the whole point: only the int8 bytes cross HBM.
    """
    codes = codes.astype(jnp.int32)
    if scales is not None:
        lut = dequantize_luts(lut, scales)
    lut = lut.astype(jnp.float32)
    b, Dp, K = lut.shape
    bn = codes.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, Dp, K), 2)
    onehot = (iota == codes[:, :, None]).astype(jnp.float32)
    return jax.lax.dot_general(
        onehot.reshape(bn, Dp * K),
        lut.reshape(b, Dp * K),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, b)
