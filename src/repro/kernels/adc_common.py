"""Shared tile body of the ADC kernel family.

Every ADC scan in the system — the flat corpus scan (adc_lookup.py), the
IVF selected-block scan (ivf_adc.py), and the grouped KV-cache scorer
(adc_batch.py) — scores a VMEM tile of PQ/RQ codes against per-query lookup
tables with the same **one-hot matmul trick** (DESIGN.md §2): gathers are
lane-hostile on TPU, so the (bn, Dp·K) one-hot expansion of the code tile is
contracted against the reshaped LUT on the MXU. The one-hot tile lives only
in VMEM and is rebuilt per grid step.

The family is parameterized by residual depth purely through the column
dimension: a depth-M residual quantizer presents ``Dp = M·D`` code columns
and a (b, M·D, K) LUT (quant.rq flattens the level axis), so multi-level
schemes reuse these kernels unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_tile_scores(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """Score one code tile against a LUT batch inside a kernel body.

    codes (bn, Dp) integer, lut (b, Dp, K) float -> (bn, b) float32 with
    out[n, q] = Σ_d lut[q, d, codes[n, d]].
    """
    codes = codes.astype(jnp.int32)
    lut = lut.astype(jnp.float32)
    b, Dp, K = lut.shape
    bn = codes.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, Dp, K), 2)
    onehot = (iota == codes[:, :, None]).astype(jnp.float32)
    return jax.lax.dot_general(
        onehot.reshape(bn, Dp * K),
        lut.reshape(b, Dp * K),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, b)
