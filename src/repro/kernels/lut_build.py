"""Pallas TPU kernel: rotation-fused ADC-LUT build.

The serving hot path rebuilds per-query LUTs on every request, and after a
live ``refresh(delta)`` the naive pipeline would *also* re-rotate corpus
state (XR on the exact path, codebooks + cached LUTs on the ADC paths).
This kernel moves the whole rotation story to the query side: the composed
query transform ``qdelta = R₀·Δ·Wᵀ`` (see search.flat fused refresh — R₀ the
frozen index rotation, Δ the accumulated delta, W its within-subspace part)
is applied to the query block *inside the tile body*, and the LUT is built
against the frozen flattened codebooks. Refresh then only swaps one (n, n)
matrix; corpus-side buffers are never touched and cached LUTs stay valid
whenever the delta is purely within-subspace.

``colmap`` (Dp, D) is a one-hot column map from code column → query
subspace: identity for PQ, and for a depth-M level-major RQ the column
l·D + d maps to subspace d. Keeping it an explicit operand lets one kernel
serve every quantizer layout — the Dp axis of the codebooks is the true
code-column axis, so per-column int8 scale groups stay correct for RQ.

Grid (b/bb,): each step rotates one query block on the MXU and contracts it
against the whole (Dp, K, sub) codebook block resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv


def _kernel(q_ref, qd_ref, cb_ref, cm_ref, out_ref):
    # rotate the query block in VMEM: (bb, n) @ (n, n)
    QL = jnp.dot(q_ref[...].astype(jnp.float32),
                 qd_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    bb = QL.shape[0]
    Dp, K, sub = cb_ref.shape
    D = cm_ref.shape[1]
    QLs = QL.reshape(bb, D, sub)
    # expand query subspaces to code columns via the one-hot map: (Dp, bb, sub)
    Qexp = jax.lax.dot_general(
        cm_ref[...].astype(jnp.float32), QLs,
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # batched contraction over sub against the codebooks: (Dp, bb, K)
    lut = jax.lax.dot_general(
        Qexp, cb_ref[...].astype(jnp.float32),
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    out_ref[...] = jnp.transpose(lut, (1, 0, 2)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_lut(
    Q: jax.Array,
    qdelta: jax.Array,
    cb_flat: jax.Array,
    colmap: jax.Array,
    *,
    block_b: int = 8,
    interpret: bool = INTERPRET,
) -> jax.Array:
    """Q (b, n) raw queries, qdelta (n, n), cb_flat (Dp, K, sub) frozen
    flattened codebooks, colmap (Dp, D) one-hot column map
    ->  lut (b, Dp, K) float32 with
    lut[b, p, k] = ⟨(Q·qdelta) subspace of column p, cb_flat[p, k]⟩."""
    b, n = Q.shape
    Dp, K, sub = cb_flat.shape
    D = colmap.shape[1]
    bb = min(block_b, b)
    bpad = cdiv(b, bb) * bb
    if bpad != b:
        Q = jnp.pad(Q, ((0, bpad - b), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        grid=(bpad // bb,),
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((Dp, K, sub), lambda i: (0, 0, 0)),
            pl.BlockSpec((Dp, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, Dp, K), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bpad, Dp, K), jnp.float32),
        interpret=interpret,
    )(Q, qdelta, cb_flat, colmap.astype(jnp.float32))
    return out[:b]
