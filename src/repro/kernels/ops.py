"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the system calls. Each wrapper:
  * reshapes/permutes into the kernel's preferred layout,
  * dispatches to the Pallas kernel (interpret=True off-TPU),
  * exposes a ``use_kernel=False`` escape hatch to the pure-jnp oracle in
    ref.py (also used by the allclose tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import adc_batch as _adcb
from repro.kernels import adc_lookup as _adc
from repro.kernels import embedding_bag as _bag
from repro.kernels import gcd_score as _score
from repro.kernels import givens_rotate as _rot
from repro.kernels import ivf_adc as _ivf
from repro.kernels import lut_build as _lut
from repro.kernels import pq_assign as _assign
from repro.kernels import ref
from repro.kernels.adc_common import (LUT_DTYPES, dequantize_luts,
                                      quantize_luts)

__all__ = [
    "apply_pair_rotations", "gcd_score", "pq_assign", "adc_lookup",
    "adc_batch", "ivf_adc", "fused_lut", "embedding_bag", "topk_merge",
    "quantize_luts", "dequantize_luts", "LUT_DTYPES",
]


def _apply_impl(pi, pj, X, theta, use_kernel: bool):
    c = jnp.cos(theta)
    s = jnp.sin(theta)
    lead = X.shape[:-1]
    n = X.shape[-1]
    Xf = X.reshape(-1, n)
    xe = jnp.take(Xf, pi, axis=1)
    xo = jnp.take(Xf, pj, axis=1)
    if use_kernel:
        ye, yo = _rot.givens_rotate(xe, xo, c, s)
    else:
        ye, yo = ref.givens_rotate_ref(xe, xo, c, s)
    Yf = Xf.at[:, pi].set(ye.astype(X.dtype)).at[:, pj].set(yo.astype(X.dtype))
    return Yf.reshape(*lead, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _apply_pair_rotations(X, theta, pi, pj, use_kernel):
    return _apply_impl(pi, pj, X, theta, use_kernel)


def _apply_fwd(X, theta, pi, pj, use_kernel):
    return _apply_impl(pi, pj, X, theta, use_kernel), (X, theta, pi, pj)


def _apply_bwd(use_kernel, res, dY):
    """Pallas calls don't autodiff; the rotation is linear & orthogonal so
    dX = dY rotated by −θ, and dθ_ℓ = Σ rows ⟨dY, ∂Y/∂θ_ℓ⟩ (plane-local)."""
    X, theta, pi, pj = res
    dX = _apply_impl(pi, pj, dY, -theta, use_kernel)
    c = jnp.cos(theta).astype(X.dtype)
    s = jnp.sin(theta).astype(X.dtype)
    xe = jnp.take(X, pi, axis=-1)
    xo = jnp.take(X, pj, axis=-1)
    dye = jnp.take(dY, pi, axis=-1)
    dyo = jnp.take(dY, pj, axis=-1)
    # y_e = c·x_e + s·x_o ; y_o = c·x_o − s·x_e
    dtheta = jnp.sum(
        (dye * (-s * xe + c * xo) + dyo * (-s * xo - c * xe)).astype(jnp.float32),
        axis=tuple(range(X.ndim - 1)),
    ).astype(theta.dtype)
    f0 = lambda a: jnp.zeros(a.shape, jax.dtypes.float0)
    return dX, dtheta, f0(pi), f0(pj)


_apply_pair_rotations.defvjp(_apply_fwd, _apply_bwd)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def apply_pair_rotations(X, pi, pj, theta, *, use_kernel: bool = True):
    """Drop-in for core.givens.apply_pair_rotations backed by the Pallas
    plane-rotation kernel: permute pair columns adjacent, rotate the even/odd
    planes in VMEM, scatter back. Differentiable via custom_vjp."""
    return _apply_pair_rotations(X, theta, pi, pj, use_kernel)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def gcd_score(G, R, *, use_kernel: bool = True):
    """A = GᵀR − RᵀG (fused; float32)."""
    if use_kernel:
        return _score.gcd_score(G, R)
    return ref.gcd_score_ref(G, R)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def pq_assign(X, codebooks, *, use_kernel: bool = True):
    """Nearest-codeword assignment (m, n) -> (m, D) int32."""
    if use_kernel:
        return _assign.pq_assign(X, codebooks)
    return ref.pq_assign_ref(X, codebooks)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def adc_lookup(lut, codes, scales=None, ids=None, *, use_kernel: bool = True):
    """Flat ADC scores (b, Dp, K) × (N, Dp) -> (b, N). Residual depth is the
    Dp column dimension (Dp = M·D for a depth-M RQ). With ``scales``
    (b, Dp, 2) the lut is an int8/uint8 ``quantize_luts`` pack, dequantized
    in the tile body. With ``ids`` (N,) rows with id < 0 (holes/tombstones)
    score −inf inside the tile body."""
    if use_kernel:
        return _adc.adc_lookup(lut, codes, scales, ids)
    return ref.adc_lookup_ref(lut, codes, scales, ids)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def adc_batch(lut, codes, scales=None, *, use_kernel: bool = True):
    """Grouped ADC scores (g, r, Dp, K) × (g, S, Dp) -> (g, r, S) — the
    KV-cache decode scorer (group = one (batch, kv-head) pair, r = GQA
    repetition). ``scales`` (g, r, Dp, 2): quantized-LUT pack."""
    if use_kernel:
        return _adcb.adc_batch(lut, codes, scales)
    return ref.adc_batch_ref(lut, codes, scales)


@functools.partial(jax.jit, static_argnames=("block_size", "use_kernel"))
def ivf_adc(lut, codes, block_idx, block_query, scales=None, ids=None, *,
            block_size: int = 128, use_kernel: bool = True):
    """Selected-block IVF-ADC scan: (b, D, K) LUTs × (cap, D) CSR codes ×
    (S,) block schedule -> (S, block_size) scores. ``scales`` (b, D, 2):
    quantized-LUT pack, the per-step LUT-row DMA shrinks 4×. ``ids`` (cap,):
    tombstone mask — rows with id < 0 score −inf inside the tile body."""
    if use_kernel:
        return _ivf.ivf_adc(lut, codes, block_idx, block_query, scales, ids,
                            block_size=block_size)
    return ref.ivf_adc_ref(lut, codes, block_idx, block_query,
                           block_size=block_size, scales=scales, ids=ids)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def fused_lut(Q, qdelta, cb_flat, colmap, *, use_kernel: bool = True):
    """Rotation-fused ADC-LUT build: raw queries (b, n) × composed query
    transform (n, n) × frozen flattened codebooks (Dp, K, sub) × one-hot
    column map (Dp, D) -> (b, Dp, K) tables. The delta is applied to the
    query block inside the tile body, so refresh never touches corpus-side
    buffers (see kernels/lut_build.py)."""
    if use_kernel:
        return _lut.fused_lut(Q, qdelta, cb_flat, colmap)
    return ref.fused_lut_ref(Q, qdelta, cb_flat, colmap)


@functools.partial(jax.jit, static_argnames=("num_bags", "use_kernel"))
def embedding_bag(table, indices, bag_ids, num_bags: int, weights=None, *,
                  use_kernel: bool = True):
    """EmbeddingBag(sum) -> (num_bags, dim) float32. bag_ids must be sorted."""
    if use_kernel:
        return _bag.embedding_bag(table, indices, bag_ids, num_bags, weights)
    return ref.embedding_bag_ref(table, indices, bag_ids, num_bags, weights)


@functools.partial(jax.jit, static_argnames=("k",))
def topk_merge(scores, ids, k: int):
    """Cross-shard local-k merge: (b, C) gathered per-shard top-k runs ->
    (b, k) global top-k under the −inf/−1 padding contract. The reduce step
    of the sharded searcher family (search/sharded.py): each shard scans
    its local CSR rows, emits a padded local top-k, and the all_gather'd
    (b, shards·k) runs merge here. Ties are deterministic — equal scores
    rank by ascending id (lexicographic two-key sort), so results are
    identical regardless of shard/tile order or which serve batch a
    request was grouped into. Pure XLA sort — already optimal at these
    widths, so there is no Pallas variant (the ref IS the
    implementation)."""
    return ref.topk_merge_ref(scores, ids, k)
