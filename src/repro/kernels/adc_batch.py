"""Pallas TPU kernel: grouped ADC scan — the KV-cache member of the family.

Decode-time attention scores every query head against its *own* code
sequence: group g (one (batch, kv-head) pair) holds S coded vectors and r
query LUTs (the GQA repetition factor). This is the flat scan of
adc_lookup.py with one extra grid axis steering both the code tile and the
LUT block at the same group, sharing the one-hot-MXU tile body
(adc_common.adc_tile_scores).

Grid (g, S/bn): step (gi, i) scores tile i of group gi's codes against that
group's r LUTs. Residual depth rides in the Dp column dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.adc_common import adc_tile_scores
from repro.kernels.common import INTERPRET, cdiv


def _kernel(codes_ref, lut_ref, out_ref):
    scores = adc_tile_scores(codes_ref[0], lut_ref[0])  # (bn, r)
    out_ref[...] = scores.T[None].astype(out_ref.dtype)


def _kernel_q(codes_ref, lut_ref, scales_ref, out_ref):
    # quantized path: the group's r LUTs ride in int8/uint8 + (r, Dp, 2)
    # scales; dequant happens in VMEM
    scores = adc_tile_scores(codes_ref[0], lut_ref[0], scales_ref[0])
    out_ref[...] = scores.T[None].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def adc_batch(
    lut: jax.Array,
    codes: jax.Array,
    scales: jax.Array | None = None,
    *,
    block_s: int = 1024,
    interpret: bool = INTERPRET,
) -> jax.Array:
    """lut (g, r, Dp, K) float, codes (g, S, Dp) integer
    ->  scores (g, r, S) float32.

    With ``scales`` (g, r, Dp, 2) the lut is an int8/uint8 quantize_luts
    pack — the per-step LUT DMA moves 4× fewer bytes."""
    g, r, Dp, K = lut.shape
    S = codes.shape[1]
    bs = min(block_s, S)
    grid = (g, cdiv(S, bs))
    in_specs = [
        pl.BlockSpec((1, bs, Dp), lambda gi, i: (gi, i, 0)),
        pl.BlockSpec((1, r, Dp, K), lambda gi, i: (gi, 0, 0, 0)),
    ]
    operands = [codes, lut]
    kernel = _kernel
    if scales is not None:
        in_specs.append(pl.BlockSpec((1, r, Dp, 2), lambda gi, i: (gi, 0, 0, 0)))
        operands.append(scales)
        kernel = _kernel_q
    # codes stay in their storage dtype (uint8 for K ≤ 256) all the way to
    # VMEM — the shared tile body widens per tile; widening here would
    # materialize a 4× int32 copy of the whole code cache per decode step.
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, r, bs), lambda gi, i: (gi, 0, i)),
        out_shape=jax.ShapeDtypeStruct((g, r, S), jnp.float32),
        interpret=interpret,
    )(*operands)
