"""repro.pipeline: the overlapped training runtime.

Closes the paper's loop at scale — rotation learning *during* training
with a live index — while keeping every slow host-side piece off the
step's critical path:

  * ``data.pipeline.Pipeline(prefetch=True)`` — double-buffered
    host→device prefetch (re-exported here); batch k+1 is generated and
    ``device_put`` while step k runs, bit-identical stream, checkpoint/
    restore carries the cursor.
  * ``LiveIndexLoop`` — consumes the trainer's per-step ``RotationDelta``s
    (``make_train_step(emit_deltas=True)``) and refreshes a live Engine
    every N steps through the zero-recompile path, tracking per-row
    staleness so only drifted rows are ever re-encoded.
  * ``churn.BackgroundCompactor`` — repacks the next index state in a
    worker thread and swaps at the Engine refresh point; the staleness
    re-encode rides inside each pass.

``benchmarks/train_e2e.py`` measures the assembled loop: in-training
recall@10 vs exact over wall-clock, step-time overhead of going live, and
the p99 win of hiding compaction.
"""
from repro.churn.compactor import BackgroundCompactor
from repro.churn.staleness import StalenessTracker
from repro.data.pipeline import Pipeline
from repro.pipeline.loop import LiveIndexLoop

__all__ = ["Pipeline", "LiveIndexLoop", "BackgroundCompactor",
           "StalenessTracker"]
