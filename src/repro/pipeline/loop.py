"""LiveIndexLoop: trainer → live index, through the zero-recompile path.

The glue between ``make_train_step(emit_deltas=True)`` and a serving
``search.Engine``. Each training step's manifold update already computes
the exact ``RotationDelta`` it applied to R; this loop buffers them and,
every ``refresh_every`` steps, replays them onto the live index via
``Engine.refresh`` — a shape-preserving state swap under the cached
executables, so keeping the index aligned with the trainer costs zero
recompiles and no rebuild.

Freshness accounting: the non-fused refresh drops cross-subspace angles
when absorbing a delta into product codebooks (``maintain.refresh_delta``),
so each applied delta leaves stored codes ~1% drifted from a fresh encode.
Every refresh round bumps the ``StalenessTracker`` epoch; the attached
``BackgroundCompactor`` re-encodes the stalest rows inside its next pass
(off-thread), so drift is repaid continuously instead of with stop-the-
world rebuilds.

Single-thread driver: call ``on_step(metrics)`` from the training loop
after each step. The only work on the training thread is the (cheap,
jit'd) refresh and a non-blocking compactor poll/submit.
"""
from __future__ import annotations

from repro import obs


class LiveIndexLoop:
    """Drive a live Engine from per-step rotation deltas (module docstring).

    ``delta_key`` names the manifold leaf in ``metrics["rotation_deltas"]``
    that rotates the index (the trainer may carry others, e.g. KV-cache
    rotations). ``compact_every`` counts refresh rounds between compaction
    submits (0 = never submit; the caller owns compaction cadence).
    """

    def __init__(self, engine, *, delta_key: str = "R",
                 refresh_every: int = 8, tracker=None, compactor=None,
                 compact_every: int = 4, registry=None):
        self.engine = engine
        self.delta_key = delta_key
        self.refresh_every = max(1, int(refresh_every))
        self.tracker = tracker
        self.compactor = compactor
        self.compact_every = int(compact_every)
        self.obs = (registry if registry is not None
                    else getattr(engine, "obs", None) or
                    obs.default_registry())
        self._buffer: list = []
        self._steps = 0
        self._rounds = 0

    def on_step(self, metrics: dict) -> None:
        """Consume one training step's metrics: buffer its delta, refresh
        on cadence, keep the background compactor moving."""
        self._steps += 1
        deltas = metrics.get("rotation_deltas")
        if deltas is not None:
            if self.delta_key not in deltas:
                # a key miss here would otherwise be a silent no-op for the
                # whole run — the trainer emits the same leaves every step
                raise KeyError(
                    f"LiveIndexLoop: delta_key {self.delta_key!r} not in "
                    f"emitted rotation deltas {sorted(deltas)} — pass "
                    f"delta_key= matching the trainer's manifold leaf")
            self._buffer.append(deltas[self.delta_key])
        if self.compactor is not None:
            self.compactor.poll()
        if self._steps % self.refresh_every == 0:
            self.flush_refresh()

    def flush_refresh(self) -> int:
        """Apply every buffered delta to the live index, in step order.
        Returns the number applied. Bumps the staleness epoch once per
        delta (each one drifts the stored codes a little further) and
        submits a background compaction every ``compact_every`` rounds."""
        applied = len(self._buffer)
        if applied:
            with self.obs.span("pipeline.refresh") as sp:
                for delta in self._buffer:
                    self.engine.refresh(delta)
                sp.sync(self.engine.state)
            self._buffer.clear()
            if self.tracker is not None:
                self.tracker.bump(applied)
            self.obs.counter("pipeline.refreshes").inc()
            self.obs.counter("pipeline.deltas_applied").inc(applied)
            self._rounds += 1
            if (self.compactor is not None and self.compact_every > 0
                    and self._rounds % self.compact_every == 0):
                self.compactor.submit()
        return applied

    def drain(self) -> None:
        """End of training: apply stragglers and land the last compaction
        pass (join → poll → swap)."""
        self.flush_refresh()
        if self.compactor is not None:
            self.compactor.join()
            self.compactor.poll()

    def stats(self) -> dict:
        return dict(
            steps=self._steps,
            refresh_rounds=self._rounds,
            buffered=len(self._buffer),
            refreshes=self.obs.counter("pipeline.refreshes").value,
            deltas_applied=self.obs.counter(
                "pipeline.deltas_applied").value,
            staleness_epoch=(self.tracker.epoch
                             if self.tracker is not None else 0),
            tracked_rows=(len(self.tracker)
                          if self.tracker is not None else 0),
        )
