"""Exporters: JSONL event sink + human-readable text snapshot report.

The JSONL log is the durable trail (one event per line, append-only,
crash-tolerant — each line flushes on write); the text report is the
at-a-glance view an operator prints between benchmark runs. Both consume
only the registry's public surface (``events``/``snapshot``), so any
registry — the global default or an ``Engine``'s private one — exports the
same way.
"""
from __future__ import annotations

import json
import math
from typing import Any

import numpy as np


def jsonable(x: Any) -> Any:
    """Recursively coerce to JSON-safe types: numpy scalars/arrays become
    Python numbers/lists, non-finite floats become None (the BENCH schema
    forbids NaN/Infinity — json would emit them as bare words that strict
    parsers, and our validator, reject), tuples/sets become lists."""
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple, set, frozenset)):
        return [jsonable(v) for v in x]
    if isinstance(x, (bool, np.bool_)):
        return bool(x)
    if isinstance(x, (int, np.integer)):
        return int(x)
    if isinstance(x, (float, np.floating)):
        v = float(x)
        return v if math.isfinite(v) else None
    if isinstance(x, np.ndarray):
        return jsonable(x.tolist())
    if x is None or isinstance(x, str):
        return x
    if hasattr(x, "tolist"):  # 0-d jax arrays and friends
        return jsonable(np.asarray(x).tolist())
    return str(x)


class JsonlSink:
    """Append-only JSONL event log: one registry event per line."""

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "a", encoding="utf-8")

    def write(self, rec: dict) -> None:
        self._fh.write(json.dumps(jsonable(rec), allow_nan=False) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()


def read_jsonl(path: str) -> list[dict]:
    """Load every event from a JSONL log (round-trip of ``JsonlSink``)."""
    out = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def text_report(registry) -> str:
    """Fixed-width snapshot of every metric in the registry — counters and
    gauges one per line, distributions with their window percentiles."""
    snap = registry.snapshot()
    lines = []
    if snap["counters"]:
        lines.append("-- counters (lifetime) --")
        for name, v in sorted(snap["counters"].items()):
            lines.append(f"{name:<48} {v}")
    if snap["gauges"]:
        lines.append("-- gauges (last value) --")
        for name, v in sorted(snap["gauges"].items()):
            lines.append(f"{name:<48} {v:.6g}")
    if snap["distributions"]:
        lines.append("-- distributions (lifetime count; window percentiles) --")
        for name, s in sorted(snap["distributions"].items()):
            lines.append(
                f"{name:<48} n={s['count']} mean={s['mean']:.4g} "
                f"p50={s['p50']:.4g} p95={s['p95']:.4g} "
                f"p99={s['p99']:.4g} max={s['max']:.4g}")
    return "\n".join(lines) if lines else "(no metrics recorded)"
