"""Sampling recall probe: live retrieval *quality* as a gauge.

Latency metrics catch a slow server; they cannot catch a server that got
fast by returning the wrong neighbors. The failure mode unique to this
paper's train-while-serving story is exactly that: a rotation refresh that
drifts the serving transform away from the stored codes degrades recall
while every latency and scan-work number stays green.

``RecallProbe`` holds a small pinned query set and its exact-MIPS ground
truth (rotation-invariant: for orthogonal R the exact backend's scores
``(QR)(XR)ᵀ = QXᵀ`` do not depend on R, so truth computed once stays valid
across every refresh). Replaying the probe set through the serving path
every ``every``-th request and publishing ``<name>.recall_at_k`` as a gauge
turns a bad refresh into a visible quality regression instead of a silent
one. ``search.Engine`` runs an attached probe automatically; probe traffic
flows through the normal serving path (bucketized, LUT-cached) and is
counted in the Engine's request metrics like any other caller.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.metrics import recall_at_k
from repro.obs import registry as reg_mod


class RecallProbe:
    """Replay a pinned query set and gauge recall@k against exact truth.

    ``registry=None`` publishes to the global default registry (so the
    gauge is a no-op until ``obs.enable()``); ``last`` always holds the
    most recent measured recall regardless, so callers can alert on it
    without enabling global metrics.
    """

    def __init__(self, queries, truth_ids, *, k: int = 10, every: int = 64,
                 name: str = "probe", registry: reg_mod.Registry | None = None):
        self.queries = np.asarray(queries)
        truth_ids = np.asarray(truth_ids)
        if truth_ids.shape[1] < k:
            raise ValueError(
                f"truth has {truth_ids.shape[1]} ids per row, need k={k}")
        self.truth = truth_ids[:, :k]
        self.k = k
        self.every = max(1, every)
        self.name = name
        self.registry = registry
        self.last: float | None = None
        self._since = 0

    @classmethod
    def from_exact(cls, corpus, R, queries, *, k: int = 10, every: int = 64,
                   tile_rows: int = 4096, name: str = "probe",
                   registry: reg_mod.Registry | None = None) -> "RecallProbe":
        """Build the ground truth by one exact-backend pass over the corpus
        (the recall oracle; done once at probe construction)."""
        from repro import search  # late: repro.search imports repro.obs

        exact = search.make("exact")
        state = exact.build(jax.random.PRNGKey(0), corpus, R,
                            search.SearchConfig(tile_rows=tile_rows))
        truth = np.asarray(exact.search(state, queries, k=k).ids)
        return cls(queries, truth, k=k, every=every, name=name,
                   registry=registry)

    def _registry(self) -> reg_mod.Registry:
        return self.registry or reg_mod.default_registry()

    def run(self, search_fn: Callable) -> float:
        """Measure now: ``search_fn(queries)`` returns a SearchResult (or a
        raw ids array); the recall lands in ``last`` + the gauge."""
        reg = self._registry()
        with reg.span(f"{self.name}.replay"):
            res = search_fn(self.queries)
        ids = np.asarray(getattr(res, "ids", res))
        recall = recall_at_k(ids, self.truth, self.k)
        self.last = recall
        reg.gauge(f"{self.name}.recall_at_k", k=self.k).set(recall)
        reg.counter(f"{self.name}.runs").inc()
        reg.event("recall_probe", name=self.name, k=self.k, recall=recall,
                  queries=int(self.queries.shape[0]))
        return recall

    def maybe_run(self, search_fn: Callable) -> float | None:
        """Sampling entry point: runs on every ``every``-th call (the first
        call measures immediately so a fresh serving loop gets a baseline)."""
        due = self._since == 0
        self._since = (self._since + 1) % self.every
        if due:
            return self.run(search_fn)
        return None
