"""repro.obs — unified observability: metrics, spans, exporters, probes.

One lightweight subsystem watches every layer of the stack:

  * **registry** (``Registry``/``counter``/``gauge``/``distribution``) —
    process-local metrics with streaming window percentiles; the global
    default registry is DISABLED until ``obs.enable()`` and disabled
    instrumentation is near-free (shared null objects, no host syncs).
  * **spans** (``span``) — nestable, exception-safe timing blocks that can
    ``sync`` on device values (block_until_ready-aware) and forward to
    ``jax.profiler.TraceAnnotation`` under ``profile=True``; use
    ``jax.named_scope`` for inside-jit stages.
  * **exporters** — JSONL event log (``enable(jsonl=...)``), text
    snapshot (``report``), and the ``BENCH_*.json`` trajectory writer +
    validator (``write_bench``/``validate_bench``) that the benchmark
    harness emits through.
  * **probes** (``RecallProbe``) — pinned-query recall@k replayed through
    the serving path, so a bad rotation refresh shows up as a quality
    regression, not just a latency blip.

Who emits what: ``search.Engine`` (request latency p50/p99, bucket/pad
waste, LUT hit rate, compile counts — via its always-on private registry
behind ``stats()``), ``search.sharded`` (per-shard rows, shard-imbalance
gauge, named-scope scan/merge spans), ``index.maintain`` (refresh spans,
delta norm, orthogonality drift), ``launch.train`` (step time, loss,
rotation health), ``quant.kmeans`` (per-iteration distortion trace), and
``benchmarks/*`` (the BENCH trajectory).
"""
from repro.obs.bench import (
    SCHEMA as BENCH_SCHEMA,
    bench_path,
    load_bench,
    validate_bench,
    write_bench,
)
from repro.obs.export import JsonlSink, jsonable, read_jsonl, text_report
from repro.obs.probe import RecallProbe
from repro.obs.registry import (
    Counter,
    Distribution,
    Gauge,
    Registry,
    Span,
    counter,
    default_registry,
    disable,
    distribution,
    enable,
    enabled,
    event,
    gauge,
    override,
    span,
)

__all__ = [
    "BENCH_SCHEMA",
    "Counter",
    "Distribution",
    "Gauge",
    "JsonlSink",
    "RecallProbe",
    "Registry",
    "Span",
    "bench_path",
    "counter",
    "default_registry",
    "disable",
    "distribution",
    "enable",
    "enabled",
    "event",
    "gauge",
    "jsonable",
    "load_bench",
    "override",
    "read_jsonl",
    "span",
    "text_report",
    "validate_bench",
    "write_bench",
]


def report(registry: Registry | None = None) -> str:
    """Text snapshot of ``registry`` (default: the global registry)."""
    return text_report(registry if registry is not None else default_registry())
