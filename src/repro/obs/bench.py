"""BENCH_*.json — the pinned benchmark trajectory (stable schema + CLI).

Benchmark numbers that only scroll past in CI are anecdotes; this module
makes them a *trajectory*: each benchmark run appends one structured run
record to ``BENCH_<name>.json``, the file is committed (or uploaded as a CI
artifact), and every future perf PR lands against the recorded history.

Schema (``repro.bench/v1``)::

    {
      "schema": "repro.bench/v1",
      "name": "fast",                      # trajectory name
      "runs": [                            # append-only, oldest first
        {
          "created": "2026-08-08T12:00:00+00:00",   # ISO-8601 UTC
          "host": {"backend": "cpu", "device_count": 1,
                   "jax": "0.4.37", "python": "3.10.12"},
          "config": {...},                 # the sweep's knobs (JSON scalars)
          "sections": {"fig4": {...}, "kernels": {...}, ...},
          "checks": {"fig4/gcd_r_faster_than_cayley_at_512": true, ...}
        }
      ]
    }

``sections`` holds each benchmark's result payload (numbers, tables);
``checks`` is the flat claim-check map — every value MUST be a bool, so a
trajectory file doubles as a pass/fail record. ``validate_bench`` enforces
the schema (CI runs it on the emitted artifact: malformed bench output
fails the build), and non-finite floats are serialized as null — a NaN can
never masquerade as a measured number.

CLI::

    python -m repro.obs.bench --validate BENCH_fast.json   # schema check
    python -m repro.obs.bench --show BENCH_fast.json       # trajectory view
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import platform
import sys
import tempfile

import jax

from repro.obs.export import jsonable

SCHEMA = "repro.bench/v1"


def host_info() -> dict:
    return dict(
        backend=jax.default_backend(),
        device_count=jax.device_count(),
        jax=jax.__version__,
        python=platform.python_version(),
    )


def make_run(sections: dict, checks: dict, config: dict | None = None) -> dict:
    """One schema-valid run record (timestamps in UTC, payloads coerced to
    JSON-safe types)."""
    return dict(
        created=datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        host=host_info(),
        config=jsonable(config or {}),
        sections={str(k): jsonable(v) for k, v in sections.items()},
        checks={str(k): bool(v) for k, v in checks.items()},
    )


def bench_path(out_dir: str, name: str) -> str:
    return os.path.join(out_dir, f"BENCH_{name}.json")


def load_bench(path: str) -> dict:
    """Strict load: bare NaN/Infinity tokens are schema violations."""
    def _reject(tok):
        raise ValueError(f"non-finite literal {tok!r} in {path}")

    with open(path, encoding="utf-8") as fh:
        return json.load(fh, parse_constant=_reject)


def write_bench(out_dir: str, name: str, sections: dict, checks: dict,
                config: dict | None = None) -> str:
    """Append one run to the ``BENCH_<name>.json`` trajectory (creating it
    on first write). The write is atomic (tmp + rename) so a crash cannot
    leave a truncated trajectory behind."""
    os.makedirs(out_dir, exist_ok=True)
    path = bench_path(out_dir, name)
    doc = {"schema": SCHEMA, "name": name, "runs": []}
    if os.path.exists(path):
        try:
            prev = load_bench(path)
            if not validate_bench(prev):
                doc = prev
        except (ValueError, json.JSONDecodeError):
            pass  # corrupt trajectory: start fresh rather than crash the run
    doc["runs"].append(make_run(sections, checks, config))
    fd, tmp = tempfile.mkstemp(dir=out_dir, suffix=".tmp")
    with os.fdopen(fd, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1, allow_nan=False)
        fh.write("\n")
    os.replace(tmp, path)
    return path


def validate_bench(doc_or_path) -> list[str]:
    """Schema check; returns the list of violations ([] == valid)."""
    if isinstance(doc_or_path, str):
        try:
            doc = load_bench(doc_or_path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            return [f"unreadable: {e}"]
    else:
        doc = doc_or_path

    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema must be {SCHEMA!r}, got {doc.get('schema')!r}")
    if not isinstance(doc.get("name"), str) or not doc.get("name"):
        errs.append("name must be a non-empty string")
    runs = doc.get("runs")
    if not isinstance(runs, list) or not runs:
        return errs + ["runs must be a non-empty list"]
    for i, run in enumerate(runs):
        where = f"runs[{i}]"
        if not isinstance(run, dict):
            errs.append(f"{where} is not an object")
            continue
        if not isinstance(run.get("created"), str):
            errs.append(f"{where}.created must be an ISO timestamp string")
        host = run.get("host")
        if not isinstance(host, dict) or "backend" not in host \
                or "device_count" not in host:
            errs.append(f"{where}.host must carry backend + device_count")
        sections = run.get("sections")
        if not isinstance(sections, dict) or not sections:
            errs.append(f"{where}.sections must be a non-empty object")
        checks = run.get("checks")
        if not isinstance(checks, dict):
            errs.append(f"{where}.checks must be an object")
        else:
            for k, v in checks.items():
                if not isinstance(v, bool):
                    errs.append(
                        f"{where}.checks[{k!r}] must be a bool, got "
                        f"{type(v).__name__}")
        try:
            json.dumps(run, allow_nan=False)
        except (TypeError, ValueError) as e:
            errs.append(f"{where} not strictly JSON-serializable: {e}")
    return errs


def _numeric_leaves(obj, prefix="") -> dict[str, float]:
    """Flatten nested dicts to dot-path → float (bools excluded: those are
    the job of ``checks``, not the delta view)."""
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_numeric_leaves(v, f"{prefix}{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def section_deltas(prev: dict, last: dict) -> dict[str, list[tuple]]:
    """Per-section numeric deltas between two run records: section →
    [(leaf, old, new, pct_change)] over the leaves both runs carry."""
    out: dict[str, list[tuple]] = {}
    for sec, payload in last.get("sections", {}).items():
        a = _numeric_leaves(prev.get("sections", {}).get(sec, {}))
        b = _numeric_leaves(payload)
        rows = []
        for leaf in sorted(set(a) & set(b)):
            old, new = a[leaf], b[leaf]
            pct = ((new - old) / abs(old) * 100.0) if old else float("inf")
            rows.append((leaf, old, new, pct))
        if rows:
            out[sec] = rows
    return out


def show(path: str) -> str:
    """Compact trajectory view: one line per run (date, backend, checks),
    then the per-section delta of the latest run vs the previous one —
    every numeric leaf both runs carry, old → new with % change, so a perf
    PR's BENCH diff reads as a table instead of two JSON blobs."""
    doc = load_bench(path)
    lines = [f"{path}: trajectory {doc['name']!r}, {len(doc['runs'])} run(s)"]
    for run in doc["runs"]:
        checks = run.get("checks", {})
        bad = [k for k, v in checks.items() if not v]
        status = "PASS" if not bad else f"FAIL({','.join(bad)})"
        lines.append(
            f"  {run.get('created', '?'):<26} "
            f"{run.get('host', {}).get('backend', '?'):<5} "
            f"sections={sorted(run.get('sections', {}))} "
            f"checks={len(checks)} {status}")
    if len(doc["runs"]) >= 2:
        prev, last = doc["runs"][-2], doc["runs"][-1]
        lines.append(f"  delta: {last.get('created', '?')} vs "
                     f"{prev.get('created', '?')}")
        for sec, rows in section_deltas(prev, last).items():
            lines.append(f"    [{sec}]")
            for leaf, old, new, pct in rows:
                lines.append(f"      {leaf:<52} {old:>14.4g} -> "
                             f"{new:>14.4g}  ({pct:+.1f}%)")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate / inspect BENCH_*.json trajectory files")
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check each file; non-zero exit on violation")
    ap.add_argument("--show", action="store_true",
                    help="print a one-line-per-run trajectory summary")
    args = ap.parse_args(argv)

    rc = 0
    for path in args.paths:
        if args.show:
            print(show(path))
        errs = validate_bench(path)
        if errs:
            rc = 1
            for e in errs:
                print(f"{path}: INVALID: {e}")
        elif args.validate:
            doc = load_bench(path)
            print(f"{path}: valid ({len(doc['runs'])} run(s))")
    return rc


if __name__ == "__main__":
    sys.exit(main())
