"""Process-local metrics registry: counters, gauges, distributions, spans.

The serving/training stack needs to SEE itself run — latency percentiles,
scan work, rotation health — without paying for it when nobody is looking.
Three design rules govern everything here:

  * **near-free when disabled** — a disabled registry hands out shared
    null singletons: no metric objects are created, no events buffered, no
    host syncs happen. Instrumented hot paths cost one attribute lookup +
    one no-op call.
  * **host-side only** — metrics never enter a ``jax.jit`` trace. Spans
    that time device work declare the arrays to wait on via ``sync`` and
    the span blocks (``jax.block_until_ready``) before stopping the clock;
    values that turn out to be tracers (the span accidentally ran under a
    trace) are skipped rather than crashed on. For *inside-jit* visibility
    use ``jax.named_scope`` at the call site (trace-time, zero runtime
    cost, shows up in XLA profiles) — the sharded searcher does exactly
    that for its scan/merge stages.
  * **windows vs lifetimes** — counters and gauges are lifetime values;
    distributions keep lifetime count/sum/min/max plus a bounded sample
    window that the streaming percentiles (p50/p95/p99) are computed over.
    Every consumer that mixes the two (``Engine.stats()``) documents which
    is which.

Spans nest: the recorded name is the dotted path of enclosing spans
(``engine.search`` inside ``serve`` records ``serve.engine.search``), the
stack is per-thread, and an exception inside the span still records the
timing (with ``error=True``) and propagates. When the registry's
``profile`` flag is on, each span also enters a
``jax.profiler.TraceAnnotation`` so host spans line up with device ops in
an XLA trace; ``trace(dir)`` wraps ``jax.profiler.trace`` the same way.

Registries are process-local. Metric CREATION (the get-or-create in
``counter``/``gauge``/``distribution``/``event``) is guarded by a lock, so
threads racing to instrument the same name always share one object — the
background-compaction worker relies on this. Concurrent WRITERS to the
same metric remain single-writer by convention (same assumption as
``search.Engine``): writers on the poll thread, workers return values; the
span stack is per-thread so concurrent readers/writers of different
metrics are fine in practice.
"""
from __future__ import annotations

import collections
import contextlib
import threading
import time
from typing import Any, Iterator

import jax

MetricKey = tuple[str, tuple[tuple[str, Any], ...]]


def _key(name: str, labels: dict[str, Any]) -> MetricKey:
    return (name, tuple(sorted(labels.items())))


def _label_str(name: str, labels: tuple[tuple[str, Any], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic lifetime count (requests served, compiles, cache hits)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple[tuple[str, Any], ...] = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (live recall, shard imbalance, orthogonality)."""

    __slots__ = ("name", "labels", "value", "updates")

    def __init__(self, name: str, labels: tuple[tuple[str, Any], ...] = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self.updates = 0

    def set(self, v: float) -> None:
        self.value = float(v)
        self.updates += 1


class Distribution:
    """Lifetime count/sum/min/max + a bounded sample window for streaming
    percentiles. ``summary()`` labels which aggregates are which."""

    __slots__ = ("name", "labels", "count", "total", "min", "max", "_window")

    def __init__(self, name: str, labels: tuple[tuple[str, Any], ...] = (),
                 window: int = 1024):
        self.name = name
        self.labels = labels
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._window: collections.deque[float] = collections.deque(
            maxlen=max(1, window))

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self._window.append(v)

    def window_values(self) -> list[float]:
        return list(self._window)

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile over the retained window."""
        w = sorted(self._window)
        if not w:
            return 0.0
        pos = (q / 100.0) * (len(w) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(w) - 1)
        frac = pos - lo
        return w[lo] * (1.0 - frac) + w[hi] * frac

    def summary(self) -> dict:
        w = list(self._window)
        return dict(
            count=self.count,                       # lifetime
            total=self.total,                       # lifetime
            min=self.min if self.count else 0.0,    # lifetime
            max=self.max if self.count else 0.0,    # lifetime
            window=len(w),
            mean=(sum(w) / len(w)) if w else 0.0,   # window-scoped ↓
            p50=self.percentile(50.0),
            p95=self.percentile(95.0),
            p99=self.percentile(99.0),
        )


class _NullMetric:
    """Shared no-op stand-in handed out by a disabled registry."""

    __slots__ = ()
    value = 0
    updates = 0
    count = 0
    total = 0.0

    def inc(self, n: int | float = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return 0.0

    def window_values(self) -> list[float]:
        return []

    def summary(self) -> dict:
        return {}


_NULL_METRIC = _NullMetric()


class _NullSpan:
    """No-op span (stateless, so one shared instance nests safely)."""

    __slots__ = ()
    elapsed_ms = 0.0
    path = ""

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def sync(self, value):
        return value


_NULL_SPAN = _NullSpan()


def _block_concrete(value) -> None:
    """block_until_ready on everything in ``value`` that is concrete —
    tracers (a span that ran under a jit trace) are skipped, not crashed
    on."""
    leaves = [x for x in jax.tree_util.tree_leaves(value)
              if not isinstance(x, jax.core.Tracer)]
    if leaves:
        jax.block_until_ready(leaves)


class Span:
    """Timing span: records a ``span.<path>.ms`` distribution + one event.

    ``sync(value)`` registers device values the span must wait on before
    stopping the clock, so async-dispatched work is charged to the span
    that launched it. Exception-safe: the timing records either way, with
    ``error=True`` on the failure path, and the exception propagates.
    """

    __slots__ = ("_registry", "name", "path", "_t0", "_pending",
                 "elapsed_ms", "_annotation")

    def __init__(self, registry: "Registry", name: str):
        self._registry = registry
        self.name = name
        self.path = name
        self._t0 = 0.0
        self._pending: list = []
        self.elapsed_ms = 0.0
        self._annotation = None

    def sync(self, value):
        """Register ``value`` (array/pytree) to block on at span exit.
        Returns it unchanged so call sites stay one-liners."""
        self._pending.append(value)
        return value

    def __enter__(self) -> "Span":
        stack = self._registry._span_stack()
        self.path = ".".join([*stack, self.name]) if stack else self.name
        stack.append(self.name)
        if self._registry.profile:
            self._annotation = jax.profiler.TraceAnnotation(self.path)
            self._annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        try:
            if self._pending:
                _block_concrete(self._pending)
        finally:
            self.elapsed_ms = (time.perf_counter() - self._t0) * 1e3
            if self._annotation is not None:
                self._annotation.__exit__(exc_type, exc, tb)
            stack = self._registry._span_stack()
            if stack and stack[-1] == self.name:
                stack.pop()
            self._registry.distribution(
                f"span.{self.path}.ms").observe(self.elapsed_ms)
            self._registry.event(
                "span", name=self.path, ms=self.elapsed_ms,
                error=exc_type is not None)
        return False


class Registry:
    """One process-local metrics namespace (see module docstring).

    ``window`` bounds both distribution sample windows and per-kind event
    windows; ``profile=True`` forwards spans to
    ``jax.profiler.TraceAnnotation``.
    """

    def __init__(self, *, enabled: bool = True, window: int = 1024,
                 profile: bool = False):
        self.enabled = enabled
        self.window = max(1, window)
        self.profile = profile
        self._metrics: dict[MetricKey, Any] = {}
        self._events: dict[str, collections.deque] = {}
        self._sinks: list = []
        self._local = threading.local()
        self._create_lock = threading.Lock()

    # -- metric accessors (get-or-create) ----------------------------------
    def _get(self, cls, name: str, labels: dict, **kw):
        if not self.enabled:
            return _NULL_METRIC
        key = _key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            # creation is locked so racing threads share ONE metric object
            # (two Counter instances under one key would tear increments)
            with self._create_lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, key[1], **kw)
                    self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def distribution(self, name: str, **labels) -> Distribution:
        return self._get(Distribution, name, labels, window=self.window)

    # -- spans --------------------------------------------------------------
    def _span_stack(self) -> list[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = []
            self._local.stack = stack
        return stack

    def span(self, name: str) -> Span | _NullSpan:
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name)

    @contextlib.contextmanager
    def trace(self, log_dir: str):
        """``jax.profiler.trace`` for the enclosed block when profiling is
        on (XLA-level device profile); a no-op otherwise."""
        if not (self.enabled and self.profile):
            yield
            return
        with jax.profiler.trace(log_dir):
            yield

    # -- events -------------------------------------------------------------
    def event(self, kind: str, **fields) -> None:
        """Append one structured event (bounded per-kind window) and fan it
        out to the attached sinks (JSONL)."""
        if not self.enabled:
            return
        rec = {"kind": kind, "t": time.time(), **fields}
        win = self._events.get(kind)
        if win is None:
            with self._create_lock:
                win = self._events.get(kind)
                if win is None:
                    win = collections.deque(maxlen=self.window)
                    self._events[kind] = win
        win.append(rec)
        for sink in self._sinks:
            sink.write(rec)

    def events(self, kind: str | None = None) -> list[dict]:
        if kind is not None:
            return list(self._events.get(kind, ()))
        return [r for win in self._events.values() for r in win]

    def add_sink(self, sink) -> None:
        self._sinks.append(sink)

    # -- inspection ---------------------------------------------------------
    def metrics(self) -> Iterator[Any]:
        return iter(self._metrics.values())

    def snapshot(self) -> dict:
        """Nested plain-dict view: counters/gauges as values, distributions
        as ``summary()`` dicts — the JSON-ready export surface."""
        out: dict[str, dict] = {"counters": {}, "gauges": {},
                                "distributions": {}}
        for m in self._metrics.values():
            label = _label_str(m.name, m.labels)
            if isinstance(m, Counter):
                out["counters"][label] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][label] = m.value
            else:
                out["distributions"][label] = m.summary()
        return out

    def reset(self) -> None:
        """Drop every metric, event window, and sink (tests; start-of-run)."""
        self._metrics.clear()
        self._events.clear()
        for sink in self._sinks:
            close = getattr(sink, "close", None)
            if close:
                close()
        self._sinks.clear()


# ---------------------------------------------------------------------------
# The global default registry: disabled until someone asks to watch.
# ---------------------------------------------------------------------------

_default = Registry(enabled=False)


def default_registry() -> Registry:
    return _default


def enabled() -> bool:
    return _default.enabled


def enable(*, jsonl: str | None = None, profile: bool = False) -> Registry:
    """Turn the global registry on (optionally attaching a JSONL event log
    and/or ``jax.profiler`` span forwarding)."""
    _default.enabled = True
    _default.profile = profile
    if jsonl is not None:
        from repro.obs.export import JsonlSink

        _default.add_sink(JsonlSink(jsonl))
    return _default


def disable() -> None:
    _default.enabled = False


@contextlib.contextmanager
def override(enabled_: bool = True):
    """Temporarily flip the global registry's enabled flag (tests)."""
    prev = _default.enabled
    _default.enabled = enabled_
    try:
        yield _default
    finally:
        _default.enabled = prev


# Module-level conveniences over the default registry — instrumented library
# code calls these so a single ``obs.enable()`` lights everything up.
def counter(name: str, **labels) -> Counter:
    return _default.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _default.gauge(name, **labels)


def distribution(name: str, **labels) -> Distribution:
    return _default.distribution(name, **labels)


def span(name: str) -> Span | _NullSpan:
    return _default.span(name)


def event(kind: str, **fields) -> None:
    _default.event(kind, **fields)
