"""Version-compat shims over JAX APIs that moved between release lines.

The repo targets both the installed 0.4.x line and current JAX:

  * ``shard_map``: ``jax.experimental.shard_map.shard_map(check_rep=...)``
    on 0.4.x became top-level ``jax.shard_map(check_vma=...)``.
  * mesh construction with ``axis_types`` lives in
    ``launch.mesh.make_mesh_compat`` (kept there because the launch layer
    owns mesh policy; it is the same guard pattern as here).

Every call site goes through these wrappers instead of feature-testing
inline.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across JAX generations.

    ``check_vma`` follows the new-API name; it is translated to the old
    ``check_rep`` kwarg on 0.4.x. ``None`` leaves the library default.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
