"""Version-compat shims over JAX APIs that moved between release lines.

The repo targets both the installed 0.4.x line and current JAX:

  * ``shard_map``: ``jax.experimental.shard_map.shard_map(check_rep=...)``
    on 0.4.x became top-level ``jax.shard_map(check_vma=...)``.
  * mesh construction with ``axis_types`` lives in
    ``launch.mesh.make_mesh_compat`` (kept there because the launch layer
    owns mesh policy; it is the same guard pattern as here).

  * ``current_mesh``: probing the ambient mesh context was only ever
    possible through the private ``jax._src.mesh.thread_resources``; newer
    JAX exposes ``jax.sharding.get_abstract_mesh``. The helper tries the
    public API first.

Every call site goes through these wrappers instead of feature-testing
inline.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across JAX generations.

    ``check_vma`` follows the new-API name; it is translated to the old
    ``check_rep`` kwarg on 0.4.x. ``None`` leaves the library default.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


def _has_manual_axes(mesh) -> bool:
    """True when any mesh axis is Manual — i.e. we are inside a shard_map
    body on new JAX, where sharding constraints over those axes are invalid
    (legacy JAX had no Manual axis type: always False there)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return False
    try:
        types = getattr(mesh, "axis_types", ())
        values = types.values() if hasattr(types, "values") else types
        return any(t == axis_type.Manual for t in values)
    except Exception:  # pragma: no cover
        return False


def current_mesh():
    """The mesh of the innermost active mesh context, or ``None``.

    Tries the public ``jax.sharding.get_abstract_mesh`` (new JAX: the
    ``use_mesh`` context) first and falls back to the legacy private
    ``thread_resources`` probe (0.4.x: the ``with mesh:`` context). Both
    probes returning nothing — i.e. no mesh context is active — yields
    ``None``, which ``sharding.rules.constrain`` treats as "do not
    constrain" (see the no-op unit test in tests/test_distributed.py).

    Inside a shard_map body on new JAX the context mesh carries Manual
    axes; that is reported as ``None`` too — constraining over manual axes
    is an error, and on legacy JAX shard_map bodies likewise saw no mesh
    (thread_resources is only set by ``with mesh:``). Callers that need
    concrete devices (e.g. ``search.sharded.resolve_mesh`` placing index
    shards) must additionally check for a non-abstract mesh — new JAX's
    ``use_mesh`` context yields an AbstractMesh with no device list.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            mesh = get_abstract()
            if mesh is not None and not mesh.empty \
                    and not _has_manual_axes(mesh):
                return mesh
        except Exception:  # pragma: no cover — fall through to the legacy probe
            pass
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh.empty or _has_manual_axes(mesh):
            return None
        return mesh
    except Exception:  # pragma: no cover
        return None
