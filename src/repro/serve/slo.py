"""SLO-adaptive nprobe: a feedback controller over measured latencies.

``nprobe`` is the serving-time quality/latency dial: more probed IVF
lists → higher recall and proportionally more scan work. A fixed nprobe
either wastes the latency budget at low load or blows p99 under bursts.
``SLOController`` closes the loop:

  * it only ever picks from a small fixed **ladder** of nprobe rungs —
    the set the front-end warms up — so adaptivity NEVER causes a
    recompile (the Engine compile cache is keyed on (bucket, k, nprobe)
    and the ladder keeps that keyspace finite and pre-compiled);
  * per (bucket, rung) it keeps an EWMA of measured batch service
    latency (seeded from warmup, updated from every served batch via
    ``observe`` — the same measurements the Engine's ``latency_ms``
    distribution sees);
  * ``choose`` picks the HIGHEST rung whose predicted latency — inflated
    by a safety margin and by the backlog still queued behind this batch
    (queued work rides in later waves, so each wave of backlog adds one
    predicted service time of queueing delay) — fits the remaining
    per-request budget. Under light load that is the top rung (spend the
    budget on recall); under a burst it sheds toward the floor and keeps
    p99 inside the SLO.

The controller is deliberately tiny and deterministic: no background
threads, no percentile estimation — an EWMA tracks the mean well enough
because batch service times at a fixed (bucket, rung) are tight (same
executable, same shapes).
"""
from __future__ import annotations

import math


class SLOController:
    """Pick an nprobe rung for each flush so requests meet their SLO.

    Parameters
    ----------
    ladder : tuple of ints, ascending nprobe rungs (the only values ever
        returned — the front-end compiles exactly these).
    safety : multiplier on the predicted latency before comparing against
        the budget (>1 biases toward meeting the SLO at some recall cost).
    ewma : smoothing factor for new observations (higher = faster
        adaptation, noisier predictions).
    """

    def __init__(self, ladder=(4, 16, 32), *, safety: float = 1.3,
                 ewma: float = 0.3):
        if not ladder:
            raise ValueError("nprobe ladder must be non-empty")
        self.ladder = tuple(sorted(int(r) for r in ladder))
        if self.ladder[0] < 1:
            raise ValueError(f"nprobe rungs must be >= 1, got {self.ladder}")
        if not 0 < ewma <= 1:
            raise ValueError(f"ewma must be in (0, 1], got {ewma}")
        self.safety = float(safety)
        self.ewma = float(ewma)
        self._lat_ms: dict[tuple[int, int], float] = {}   # (bucket, rung) → EWMA
        self.decisions = 0
        self.sheds = 0          # picked below the top rung
        self.floors = 0         # budget fit nothing — served at the floor

    def predict_ms(self, bucket: int, rung: int) -> float | None:
        """Current latency estimate for one (bucket, rung) batch, or None
        before any observation (warmup seeds every cell it compiles)."""
        return self._lat_ms.get((bucket, rung))

    def observe(self, bucket: int, rung: int, latency_ms: float) -> None:
        """Fold one measured batch service latency into the EWMA."""
        if not math.isfinite(latency_ms) or latency_ms < 0:
            return
        key = (int(bucket), int(rung))
        prev = self._lat_ms.get(key)
        if prev is None:
            self._lat_ms[key] = float(latency_ms)
        else:
            self._lat_ms[key] = (1 - self.ewma) * prev + self.ewma * latency_ms

    def choose(self, budget_ms: float, bucket: int, backlog: int = 0) -> int:
        """Highest rung predicted to fit ``budget_ms`` for a ``bucket``-row
        batch with ``backlog`` requests still queued behind it.

        The backlog inflates predictions by (1 + backlog/bucket): each full
        wave of queued work in front of a future request adds roughly one
        batch service time before it runs, so under a burst the controller
        sheds *before* the queue delay shows up in measured latencies —
        feedback plus feedforward. Unknown cells (no EWMA yet) are treated
        as not fitting, except the floor rung, which is always allowed:
        a late request still gets served, at minimum cost.
        """
        self.decisions += 1
        waves = 1.0 + max(0, int(backlog)) / max(1, int(bucket))
        for rung in reversed(self.ladder):
            pred = self._lat_ms.get((int(bucket), rung))
            if pred is not None and pred * self.safety * waves <= budget_ms:
                if rung != self.ladder[-1]:
                    self.sheds += 1
                return rung
        self.floors += 1
        self.sheds += 1
        return self.ladder[0]

    def stats(self) -> dict:
        return {
            "ladder": self.ladder,
            "decisions": self.decisions,
            "sheds": self.sheds,
            "floors": self.floors,
            "cells": {f"b{b}/np{r}": round(v, 4)
                      for (b, r), v in sorted(self._lat_ms.items())},
        }
