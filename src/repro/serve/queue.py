"""Continuous-batching admission queue: requests, tickets, deadlines.

The fixed-batch Engine answers "here is a (b, n) array"; production
traffic is a *stream* of single queries with individual latency budgets.
``BatchQueue`` turns the stream back into Engine-shaped work without
fixed-batch stalls:

  * a request is admitted into the CURRENT bucket the moment it arrives —
    there is no "wait for 32" barrier;
  * the bucket flushes when the OLDEST admitted request's admission
    deadline (``admission_ms`` after its arrival) expires, or immediately
    when the bucket is full (``max_admit`` requests — the Engine's
    ``max_bucket``). ``admission_ms = 0`` degenerates to
    flush-on-every-poll (each poll serves whatever has arrived);
  * flushed requests pad up to the next power-of-two bucket inside the
    Engine, so steady state reuses the same per-(bucket, k, nprobe)
    executables the Engine already caches — continuous batching costs
    zero new compiles.

The queue is clock-agnostic: every timestamp comes from an injected
``clock()`` (seconds, monotonic). Wall-clock serving passes
``time.monotonic``; benchmarks and tests pass a ``VirtualClock`` so
queueing dynamics are deterministic and don't need real sleeps.

One queue serves one namespace; the cross-tenant loop lives in
``serve.frontend``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable, Iterator

from repro.search.base import SearchResult

_rid = itertools.count()


class VirtualClock:
    """A manually-advanced clock for deterministic serving simulations.

    ``now()`` plugs in wherever ``time.monotonic`` would; the load
    generator advances it by measured service times (open-loop virtual
    time over real compute). ``advance`` is monotonic by construction;
    ``set`` refuses to move backwards rather than corrupting latencies.
    """

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> float:
        if seconds < 0:
            raise ValueError(f"cannot advance by {seconds}")
        self._t += seconds
        return self._t

    def set(self, t: float) -> float:
        self._t = max(self._t, float(t))
        return self._t


@dataclasses.dataclass
class Ticket:
    """One in-flight request: submit-side facts + completion slot.

    ``result`` is this request's OWN row of the batch it served in (a
    (k,)-shaped SearchResult slice). ``nprobe_served`` records what the
    SLO controller actually spent on it — the shed/boost audit trail.
    """

    rid: int
    namespace: str
    query: Any                       # (n,) host row — LUT-cache keyable
    k: int
    nprobe: int | None               # explicit override; None → SLO picks
    slo_ms: float
    arrival: float                   # clock() at submit
    completed: float | None = None   # clock() at collect
    result: SearchResult | None = None
    nprobe_served: int | None = None
    waited_ms: float = 0.0           # admission-queue wait at flush time

    @property
    def done(self) -> bool:
        return self.completed is not None

    @property
    def latency_ms(self) -> float:
        """End-to-end latency: arrival → result ready (queue wait + batch
        service), in the ticket's clock domain."""
        if self.completed is None:
            raise ValueError(f"request {self.rid} still in flight")
        return (self.completed - self.arrival) * 1e3

    def remaining_ms(self, now: float) -> float:
        """What is left of the latency budget at ``now`` (may go negative:
        the request is already late and should be served at the floor)."""
        return self.slo_ms - (now - self.arrival) * 1e3


def make_ticket(namespace: str, query, *, k: int, nprobe: int | None,
                slo_ms: float, arrival: float) -> Ticket:
    return Ticket(rid=next(_rid), namespace=namespace, query=query, k=k,
                  nprobe=nprobe, slo_ms=slo_ms, arrival=arrival)


class BatchQueue:
    """Deadline-driven admission queue for one namespace (see module doc).

    ``admission_ms`` is the batching budget — how long the oldest request
    may wait for co-riders before its bucket flushes. It trades latency
    for batch efficiency and is deliberately separate from the per-request
    SLO (which the nprobe controller spends); 0 disables batching delay
    entirely. ``max_admit`` caps a flush at the Engine's ``max_bucket`` so
    a flush is always a single ``Engine.submit``.
    """

    def __init__(self, *, admission_ms: float = 2.0, max_admit: int = 64,
                 clock: Callable[[], float] = time.monotonic):
        if admission_ms < 0:
            raise ValueError(f"admission_ms must be >= 0, got {admission_ms}")
        if max_admit < 1:
            raise ValueError(f"max_admit must be >= 1, got {max_admit}")
        self.admission_ms = float(admission_ms)
        self.max_admit = int(max_admit)
        self.clock = clock
        self._pending: deque[Ticket] = deque()

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, ticket: Ticket) -> None:
        self._pending.append(ticket)

    @property
    def depth(self) -> int:
        return len(self._pending)

    def next_deadline(self) -> float | None:
        """Clock time at which the current bucket must flush (None when
        empty). A full bucket is due immediately."""
        if not self._pending:
            return None
        if len(self._pending) >= self.max_admit:
            return self._pending[0].arrival          # already past due
        return self._pending[0].arrival + self.admission_ms * 1e-3

    def due(self, now: float | None = None) -> bool:
        deadline = self.next_deadline()
        if deadline is None:
            return False
        now = self.clock() if now is None else now
        return len(self._pending) >= self.max_admit or now >= deadline

    def take(self, now: float | None = None) -> list[Ticket]:
        """Pop the current bucket (up to ``max_admit`` tickets, FIFO) and
        stamp each ticket's queue wait. Empty list when nothing is due —
        callers can loop ``while (batch := q.take()):``."""
        now = self.clock() if now is None else now
        if not self.due(now):
            return []
        batch = [self._pending.popleft()
                 for _ in range(min(len(self._pending), self.max_admit))]
        for t in batch:
            t.waited_ms = max(0.0, (now - t.arrival) * 1e3)
        return batch

    def drain(self) -> Iterator[list[Ticket]]:
        """Yield every remaining bucket regardless of deadlines (shutdown /
        end-of-run flush)."""
        while self._pending:
            batch = [self._pending.popleft()
                     for _ in range(min(len(self._pending), self.max_admit))]
            now = self.clock()
            for t in batch:
                t.waited_ms = max(0.0, (now - t.arrival) * 1e3)
            yield batch
