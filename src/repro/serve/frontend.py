"""The serving front-end: one loop over many tenants.

``Frontend`` owns the namespace table and runs the serving loop the rest
of the package supplies parts for: callers ``submit`` single queries
(each with its own latency SLO) and ``poll`` drives everything else —

  1. **flush due buckets**: every namespace whose admission deadline
     expired (or whose bucket filled) has its tickets popped, grouped by
     (k, nprobe) into Engine-shaped batches, and *submitted without
     blocking* — device work for one group overlaps host batching of the
     next. Groups are then collected in order and each ticket gets its
     own row of the batch result.
  2. **pick nprobe**: tickets without an explicit nprobe are served at
     the rung the namespace's ``SLOController`` picks from the remaining
     per-request budget and the current backlog. Rungs come from a fixed
     pre-compiled ladder, so adaptation never recompiles.
  3. **idle maintenance**: a poll that flushed nothing instead ticks ONE
     namespace's ``ChurnController`` (round-robin) — threshold-driven
     flush/compact/rebalance runs in the gaps between buckets, sharing
     the serving loop without a second thread and without recompiles
     (churn ops are shape-preserving once staging is installed).

Construction order matters and ``create_namespace`` enforces it: the
ChurnController is attached BEFORE warmup because installing the staging
buffer changes the state pytree's structure — the one structural change
allowed, and it must land before the first executable is compiled.
Warmup then compiles every (bucket ≤ max_admit, k, ladder rung) cell and
seeds the SLO latency model from a measured steady-state run of each, so
the controller starts with calibrated predictions and serving starts at
zero pending compiles.

Clocks: pass ``clock=time.monotonic`` (default) for wall-clock serving,
or a ``VirtualClock``'s ``now``/``advance`` pair to run deterministic
simulations where queueing dynamics unfold in virtual time while service
times are real measured compute (see benchmarks/serve_load.py).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Sequence

import numpy as np

from repro import obs
from repro.search import registry as search_registry
from repro.search.base import SearchResult
from repro.search.engine import Engine
from repro.serve.namespaces import Namespace, NamespaceSet
from repro.serve.queue import BatchQueue, Ticket, make_ticket
from repro.serve.slo import SLOController

_ADAPTIVE = object()     # grouping key slot for "SLO picks the rung"


def _synth_warmup_queries(state: Any, rows: int = 8) -> np.ndarray:
    """Gaussian (rows, n) warmup queries at the state's rotation width.

    Warmup exists to compile cells and time them, and cell cost is
    query-content-independent, so synthetic rows are as good as real ones.
    Probes the serving rotation the same way Engine.refresh does
    (``state.rot`` for fused states, else ``state.R``, else
    ``state.index.R``); a state with none of these gets no default warmup.
    """
    R = getattr(state, "rot", None)
    if R is None:
        R = getattr(state, "R", None)
    if R is None:
        R = getattr(getattr(state, "index", None), "R", None)
    if R is None:
        return np.empty((0, 0), dtype=np.float32)
    n = int(np.asarray(R.shape)[-1])
    return np.random.default_rng(0).standard_normal((rows, n)).astype(
        np.float32)


class Frontend:
    """Multi-tenant continuous-batching serving loop (see module doc).

    ``lut_budget_rows`` is the global host LUT budget shared by all
    namespaces (split evenly — see ``serve.namespaces``). ``slo_ms`` is
    the default per-request latency budget; each submit may override it.
    """

    def __init__(self, *, lut_budget_rows: int = 8192, slo_ms: float = 50.0,
                 clock: Callable[[], float] = time.monotonic,
                 advance: Callable[[float], float] | None = None):
        self.namespaces = NamespaceSet(lut_budget_rows=lut_budget_rows)
        self.default_slo_ms = float(slo_ms)
        self.clock = clock
        self._advance = advance      # virtual-time hook; None = wall clock
        self._tick_order: list[str] = []   # round-robin churn cursor
        self.obs = obs.Registry(enabled=True, window=512)
        self._counters = {
            name: self.obs.counter(f"serve.{name}")
            for name in ("admitted", "flushes", "batches", "served",
                         "sheds", "maintenance_ticks")}

    # -- tenant lifecycle --------------------------------------------------
    def create_namespace(self, name: str, searcher, state: Any = None, *,
                         k: int = 10, nprobe_ladder: Sequence[int] | None = None,
                         slo_ms: float | None = None,
                         admission_ms: float = 2.0, max_admit: int = 64,
                         churn: dict | None = None,
                         warmup_queries: Any = None,
                         slo_safety: float = 1.3,
                         engine_kwargs: dict | None = None) -> Namespace:
        """Register a tenant. ``searcher`` is a registry spec string (state
        built by the caller and passed in) or an already-built Searcher.

        ``nprobe_ladder``: the fixed rung set SLO adaptation picks from
        (None → serve at the Engine's default nprobe, no adaptation —
        required for backends that don't take nprobe). ``churn``: kwargs
        for a ChurnController (e.g. ``{"staging_rows": 1024}``), attached
        before warmup; None → no churn hook. ``warmup_queries`` (m, n):
        rows tiled to pre-compile every (bucket, k, rung) cell and seed
        the SLO latency model; None synthesizes Gaussian rows at the
        state's rotation width (cell cost is query-content-independent —
        real rows only matter if you want warmup to also prime the LUT
        cache). Pass ``warmup_queries=()`` to skip warmup entirely
        (first requests then pay the compiles and the SLO controller
        floor-falls until it has observed each cell).
        """
        if isinstance(searcher, str):
            searcher = search_registry.make(searcher)
        kwargs = dict(engine_kwargs or {})
        kwargs.setdefault("max_bucket", max(max_admit, 1))
        engine = Engine(searcher, state, k=k, **kwargs)
        if nprobe_ladder is not None and not engine._takes_nprobe:
            raise ValueError(
                f"{type(searcher).__name__} does not take nprobe — "
                "nprobe_ladder requires an nprobe-capable backend")
        controller = None
        if churn is not None:
            # staging install mutates pytree STRUCTURE — must precede the
            # first compile, hence before warmup
            from repro.churn.controller import ChurnController
            controller = ChurnController(engine, **churn)
        ns = Namespace(
            name=name, engine=engine,
            queue=BatchQueue(admission_ms=admission_ms, max_admit=max_admit,
                             clock=self.clock),
            slo=SLOController(nprobe_ladder or (1,), safety=slo_safety),
            churn=controller)
        ns.slo_ms = self.default_slo_ms if slo_ms is None else float(slo_ms)
        ns.adaptive = nprobe_ladder is not None
        self.namespaces.add(ns)
        self._tick_order.append(name)
        if warmup_queries is None:
            warmup_queries = _synth_warmup_queries(state)
        Qw = np.asarray(warmup_queries)
        if Qw.size:
            self._warmup(ns, Qw)
        ns.warm_compiles = engine.stats()["compiles"]
        return ns

    def drop_namespace(self, name: str) -> None:
        self.namespaces.drop(name)
        self._tick_order.remove(name)

    def _warmup(self, ns: Namespace, Qw: np.ndarray) -> None:
        """Compile every (bucket, k, rung) cell the queue can produce and
        seed the SLO EWMA from a second, measured run of each (the first
        run pays the compile and must not poison the latency model)."""
        engine = ns.engine
        buckets, b = [], engine.min_bucket
        top = min(max(ns.queue.max_admit, 1), engine.max_bucket)
        while True:
            buckets.append(b)
            if b >= top:
                break
            b *= 2
        rungs = list(ns.slo.ladder) if ns.adaptive else [None]
        for bucket in buckets:
            reps = -(-bucket // Qw.shape[0])
            Qb = np.tile(Qw, (reps, 1))[:bucket]
            for rung in rungs:
                engine.collect(engine.submit(Qb, nprobe=rung))   # compile
                reps_ms = []
                for _ in range(3):
                    t0 = time.perf_counter()
                    engine.collect(engine.submit(Qb, nprobe=rung))
                    reps_ms.append((time.perf_counter() - t0) * 1e3)
                if rung is not None:
                    # median of 3: one noisy sample must not skew the
                    # seed the controller (and rate calibration) trusts
                    ns.slo.observe(bucket, rung, sorted(reps_ms)[1])

    # -- request path ------------------------------------------------------
    def submit(self, namespace: str, query, *, k: int | None = None,
               nprobe: int | None = None, slo_ms: float | None = None,
               arrival: float | None = None) -> Ticket:
        """Admit one query row into its namespace's current bucket and
        return the Ticket to await (serving happens in ``poll``).

        ``arrival`` backdates the ticket to its true arrival time (open-
        loop load generators submit a burst of trace arrivals the moment
        the loop regains control — their queue wait must still count from
        when they *arrived*, not from when the loop got to them)."""
        ns = self.namespaces.get(namespace)
        row = np.asarray(query)
        if row.ndim != 1:
            raise ValueError(
                f"submit takes one (n,) query row, got shape {row.shape}")
        t = make_ticket(
            ns.name, row, k=ns.engine.k if k is None else int(k),
            nprobe=nprobe,
            slo_ms=ns.slo_ms if slo_ms is None else float(slo_ms),
            arrival=self.clock() if arrival is None else float(arrival))
        ns.queue.push(t)
        self._counters["admitted"].inc()
        return t

    def next_deadline(self) -> float | None:
        """Earliest bucket-flush deadline across all namespaces (None when
        every queue is empty) — what an event loop sleeps until."""
        deadlines = [d for ns in self.namespaces
                     if (d := ns.queue.next_deadline()) is not None]
        return min(deadlines) if deadlines else None

    def poll(self) -> list[Ticket]:
        """One turn of the serving loop: flush every due bucket (tickets
        come back completed); when nothing was due, run one idle-slot
        churn maintenance tick instead. Returns the completed tickets."""
        done: list[Ticket] = []
        for ns in self.namespaces:
            while (batch := ns.queue.take(self.clock())):
                done.extend(self._serve(ns, batch))
            self.obs.gauge(f"serve.queue_depth.{ns.name}").set(ns.queue.depth)
        if not done:
            self._maintenance_tick()
        return done

    def drain(self) -> list[Ticket]:
        """Flush every namespace's remaining tickets regardless of
        deadlines (end of run / shutdown)."""
        done: list[Ticket] = []
        for ns in self.namespaces:
            for batch in ns.queue.drain():
                done.extend(self._serve(ns, batch))
        return done

    def _maintenance_tick(self) -> None:
        """Round-robin one namespace's churn step into this idle slot."""
        for _ in range(len(self._tick_order)):
            name = self._tick_order.pop(0)
            self._tick_order.append(name)
            if name in self.namespaces and \
                    self.namespaces.get(name).maintenance_tick():
                self._counters["maintenance_ticks"].inc()
                return

    # -- batch service -----------------------------------------------------
    def _serve(self, ns: Namespace, batch: list[Ticket]) -> list[Ticket]:
        """Serve one flushed bucket: group by (k, nprobe), pick rungs for
        the adaptive groups, submit all groups (device work overlaps),
        then collect in order and scatter rows back onto tickets."""
        self._counters["flushes"].inc()
        now = self.clock()
        groups: dict[tuple, list[Ticket]] = {}
        for t in batch:
            key = (t.k, t.nprobe if t.nprobe is not None
                   else (_ADAPTIVE if ns.adaptive else None))
            groups.setdefault(key, []).append(t)

        inflight = []
        for (k, npkey), tickets in groups.items():
            rung = None
            if npkey is _ADAPTIVE:
                budget = min(t.remaining_ms(now) for t in tickets)
                bucket = ns.engine._bucket(len(tickets))
                rung = ns.slo.choose(budget, bucket, backlog=ns.queue.depth)
                if rung != ns.slo.ladder[-1]:
                    self._counters["sheds"].inc()
                npb = rung
            else:
                npb = npkey
            Q = np.stack([t.query for t in tickets])
            pending = ns.engine.submit(Q, k=k, nprobe=npb)
            inflight.append((tickets, pending, rung))
            self._counters["batches"].inc()

        done = []
        for tickets, pending, rung in inflight:
            res = ns.engine.collect(pending)
            service_ms = (time.perf_counter() - pending.t0) * 1e3
            if self._advance is not None:
                # virtual time: queueing already elapsed on the virtual
                # clock; fold the real measured service time in now
                self._advance(service_ms * 1e-3)
            completed = self.clock()
            if rung is not None:
                ns.slo.observe(pending.bucket, rung, service_ms)
            for i, t in enumerate(tickets):
                t.result = SearchResult(scores=res.scores[i], ids=res.ids[i],
                                        scanned=res.scanned[i])
                t.nprobe_served = pending.nprobe
                t.completed = completed
                done.append(t)
            self._counters["served"].inc(len(tickets))
        return done

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        """Front-end counters + per-namespace engine/queue/SLO views."""
        out = {name: c.value for name, c in self._counters.items()}
        out["namespaces"] = {ns.name: ns.stats() for ns in self.namespaces}
        return out
