"""Multi-tenant namespaces: many named indexes behind one front-end.

A namespace is one tenant's complete serving unit — its own Engine (any
registry backend), its own admission queue, its own SLO controller, and
optionally its own ChurnController. Tenants share nothing that could
couple their tail latencies EXCEPT what they must share:

  * the device mesh (batches from different namespaces interleave on it —
    that is the point of a front-end);
  * the host LUT budget: ``NamespaceSet`` owns one global
    ``lut_budget_rows`` pot and splits it evenly across tenants, writing
    each Engine's ``lut_cache_rows`` on every create/drop and trimming
    immediately. A hot tenant hammering distinct queries evicts only its
    OWN cache (visible in its ``lut_evictions`` counter) — it can never
    push another tenant's warm LUTs out.

Isolation invariants (pinned in tests/test_serve.py):

  * refresh on tenant A never touches tenant B's LUT cache or epoch —
    each Engine has a private ``_luts``/``_epoch``;
  * compile caches are per-Engine, so A's shapes never evict B's
    executables;
  * obs registries are per-Engine (each Engine owns a private always-on
    Registry); the front-end aggregates views, never merges state.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.search.engine import Engine
from repro.serve.queue import BatchQueue
from repro.serve.slo import SLOController


@dataclasses.dataclass
class Namespace:
    """One tenant: engine + queue + nprobe controller (+ churn hook)."""

    name: str
    engine: Engine
    queue: BatchQueue
    slo: SLOController
    churn: Any | None = None          # ChurnController, when churn-enabled
    slo_ms: float = 50.0              # default per-request latency budget
    adaptive: bool = False            # SLO controller picks nprobe rungs
    warm_compiles: int = 0            # executables compiled by warmup

    def maintenance_tick(self) -> bool:
        """Run one idle-slot churn step (threshold-driven flush / compact /
        rebalance — a no-op when nothing crossed a threshold). Returns
        whether this namespace had a controller to tick."""
        if self.churn is None:
            return False
        self.churn.step()
        return True

    def stats(self) -> dict:
        s = self.engine.stats()
        s["queue_depth"] = self.queue.depth
        s["slo"] = self.slo.stats()
        return s


class NamespaceSet:
    """The tenant table + the shared host-LUT budget arbiter.

    ``lut_budget_rows`` is the TOTAL host cache budget across all tenants
    (same unit as ``Engine.lut_cache_rows``: cached per-query LUT rows).
    Every create/drop re-splits it evenly and re-trims each Engine, so the
    global bound holds at all times regardless of tenant count.
    """

    def __init__(self, *, lut_budget_rows: int = 8192):
        if lut_budget_rows < 0:
            raise ValueError(f"lut_budget_rows must be >= 0, "
                             f"got {lut_budget_rows}")
        self.lut_budget_rows = int(lut_budget_rows)
        self._spaces: dict[str, Namespace] = {}

    def __len__(self) -> int:
        return len(self._spaces)

    def __iter__(self):
        return iter(self._spaces.values())

    def __contains__(self, name: str) -> bool:
        return name in self._spaces

    def get(self, name: str) -> Namespace:
        try:
            return self._spaces[name]
        except KeyError:
            raise KeyError(
                f"unknown namespace {name!r}; have {sorted(self._spaces)}"
            ) from None

    def names(self) -> tuple[str, ...]:
        return tuple(self._spaces)

    def _resplit(self) -> None:
        """Even split of the global budget; each Engine trims to its new
        cap right away (evictions are counted by the Engine itself)."""
        if not self._spaces:
            return
        share = self.lut_budget_rows // len(self._spaces)
        for ns in self._spaces.values():
            ns.engine.lut_cache_rows = share
            ns.engine._evict()

    def add(self, ns: Namespace) -> Namespace:
        if ns.name in self._spaces:
            raise ValueError(f"namespace {ns.name!r} already exists")
        self._spaces[ns.name] = ns
        self._resplit()
        return ns

    def drop(self, name: str) -> None:
        self.get(name)          # raise the uniform KeyError on unknowns
        del self._spaces[name]
        self._resplit()
