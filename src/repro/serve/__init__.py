"""repro.serve — async multi-tenant serving front-end over search.Engine.

The Engine (search/engine.py) solved the single-index problems: shape
bucketing, compile caching, LUT caching, live refresh. This package adds
the request-scheduling layer production serving actually runs on:

  * ``queue``      — continuous-batching admission (deadline-driven
                     buckets, no fixed-batch stalls), plus the
                     ``VirtualClock`` used by deterministic simulations;
  * ``slo``        — per-request latency SLOs driving adaptive nprobe
                     from a fixed pre-compiled rung ladder;
  * ``namespaces`` — many named indexes behind one front-end, isolated
                     caches, one shared host LUT budget;
  * ``frontend``   — the loop tying them together: submit → poll →
                     completed tickets, with churn maintenance ticked
                     into idle slots.

Minimal serving session::

    from repro import serve
    fe = serve.Frontend(slo_ms=50.0)
    fe.create_namespace("tenant-a", "ivf", state, nprobe_ladder=(4, 16, 32),
                        warmup_queries=Qtrain[:8])
    t = fe.submit("tenant-a", q_row)
    while not t.done:
        fe.poll()
    print(t.result.ids, t.latency_ms, t.nprobe_served)

Load-generation and the SLO-adaptive-vs-fixed comparison live in
benchmarks/serve_load.py.
"""
from repro.serve.frontend import Frontend
from repro.serve.namespaces import Namespace, NamespaceSet
from repro.serve.queue import BatchQueue, Ticket, VirtualClock
from repro.serve.slo import SLOController

__all__ = [
    "Frontend", "Namespace", "NamespaceSet", "BatchQueue", "Ticket",
    "VirtualClock", "SLOController",
]
