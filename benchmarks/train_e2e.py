"""Overlapped end-to-end training: rotation learning with a live index.

The fig3/table1 story at a scale where the synchronous loop visibly
stalls. A GCD trainer minimizes PQ distortion through the rotation
(``quantizer.distortion(X @ R)``) while a live ``ivf`` Engine serves
recall probes and a balanced churn stream mutates the corpus. Three arms
run the SAME batches, deltas, and churn schedule:

  * **bare**    — trainer + prefetching pipeline only: the hardware floor.
  * **bg**      — the overlapped runtime: ``LiveIndexLoop`` replays each
    step's ``RotationDelta`` onto the Engine every ``refresh_every`` steps
    (zero-recompile path), churn rides ``ChurnController``, and a
    ``BackgroundCompactor`` repacks + staleness-re-encodes off-thread.
  * **sync**    — identical, except compaction (same re-encode batch) runs
    ON the training thread at the same cadence: the baseline whose p99 the
    background arm must beat.

A fourth, replayed arm (**rebuild**) applies the same deltas/churn to a
twin index but fully re-encodes EVERY live row each refresh round — the
expensive freshness oracle the staleness machinery must match.

Claim checks (pinned in the tracked BENCH trajectory):
  * live (bg) median step ≤ 1.15× the bare trainer median step,
  * p99 step time with background compaction strictly below the
    synchronous-compaction arm (the pause is demonstrably hidden),
  * zero steady-state Engine recompiles across refreshes/swaps,
  * in-training recall@10 vs exact within 0.01 of the full-rebuild
    baseline while re-encoding only staleness-selected rows,
  * the prefetcher reaches steady-state hits; background passes actually
    ran and re-encoded rows.

Run:  PYTHONPATH=src python benchmarks/train_e2e.py --fast
      PYTHONPATH=src python -m benchmarks.run --only train_e2e --fast
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO not in sys.path:                      # `python benchmarks/train_e2e.py`
    sys.path.insert(0, _REPO)

from benchmarks.churn import _exact_top10  # noqa: E402
from repro import churn, rotations, search
from repro.churn import ops as churn_ops
from repro.data import pipeline as pipe_lib
from repro.data import synthetic
from repro.index import ivf as index_ivf
from repro.metrics import recall_at_k
from repro.pipeline import LiveIndexLoop
from repro.training import optimizer as opt_lib
from repro.training import train_state as ts


def _schedule(X: np.ndarray, add_pool: np.ndarray, steps: int,
              churn_batch: int, churn_every: int):
    """Deterministic churn schedule shared by every arm: per step either
    ``None`` or (add_rows, add_ids, dead_ids), with removals drawn from
    the evolving live-id set."""
    rng = np.random.default_rng(0)
    live = set(range(len(X)))
    next_id = len(X)
    sched: list = []
    for s in range(steps):
        if churn_every <= 0 or (s + 1) % churn_every:
            sched.append(None)
            continue
        dead = rng.choice(sorted(live), size=churn_batch,
                          replace=False).astype(np.int32)
        pos = (s // churn_every) * churn_batch
        add = np.asarray(add_pool[pos:pos + churn_batch])
        add_ids = np.arange(next_id, next_id + churn_batch, dtype=np.int32)
        next_id += churn_batch
        live -= {int(d) for d in dead}
        live |= {int(i) for i in add_ids}
        sched.append((add, add_ids, dead))
    return sched


def _live_ids(state) -> np.ndarray:
    """Every live id of an ADC state: CSR rows + staged rows."""
    ids = np.asarray(state.index.ids)
    out = [ids[ids >= 0]]
    if state.staging is not None:
        sid = np.asarray(state.staging.ids)
        out.append(sid[sid >= 0])
    return np.concatenate(out).astype(np.int64)


def _train_arm(mode: str, *, searcher, index0, Q, vec0, sched, steps,
               batch, dim, nprobe, staging_rows, refresh_every,
               compact_every, reencode_rows, warmup, probe_every, seed,
               quantizer):
    """One full training run. Returns per-arm measurements; ``mode`` is
    'bare' | 'bg' | 'sync' (see module docstring)."""
    vecs = dict(vec0)
    vec_store = dict(vec0)
    for entry in sched:
        if entry is not None:
            vec_store.update(
                {int(i): a for i, a in zip(entry[1], entry[0])})

    def vec_lookup(ids):
        return np.stack([vec_store[int(i)] for i in np.asarray(ids)])

    def batch_fn(key):
        return (synthetic.sift_like(key, batch, dim),)

    # quantization-aware loss with a fixed encoder tower: the tower term
    # makes the step realistically compute-bound (a bare distortion on a
    # small batch is ~free, which would let ANY host-side runtime pass the
    # 1.15× overhead pin vacuously — and hide nothing)
    W1 = (jax.random.normal(jax.random.PRNGKey(seed + 2), (dim, 8 * dim))
          / np.sqrt(dim))
    W2 = (jax.random.normal(jax.random.PRNGKey(seed + 3), (8 * dim, dim))
          / np.sqrt(8 * dim))

    def loss_fn(p, x):
        xr = x @ p["R"]
        h = jnp.tanh(xr @ W1) @ W2
        return (quantizer.distortion(xr)
                + 1e-2 * jnp.mean(jnp.sum((xr - h) ** 2, -1)))

    ocfg = opt_lib.OptimizerConfig(
        lr=1e-2, total_steps=steps, warmup_steps=1, schedule="constant",
        rotation=rotations.RotationConfig.from_spec("gcd_greedy"))
    # copy: params are donated every step — aliasing the index's own R
    # buffer would delete it out from under every later attach()
    params = {"R": jnp.array(index0.R, copy=True)}
    tstate = ts.init_state(jax.random.PRNGKey(seed + 1), params, ocfg)
    step_fn = jax.jit(ts.make_train_step(loss_fn, ocfg,
                                         emit_deltas=mode != "bare"),
                      donate_argnums=(0,))
    pipe = pipe_lib.Pipeline(batch_fn, seed=seed, prefetch=True)

    eng = tracker = comp = ctl = loop = None
    compact_period = refresh_every * compact_every
    if mode != "bare":
        state = search.IVF.attach(index0, nprobe=nprobe)
        eng = search.Engine(searcher, state, k=10, nprobe=nprobe,
                            min_bucket=len(Q))
        tracker = churn.StalenessTracker()
        tracker.record(np.asarray(sorted(vecs), dtype=np.int64))
        if mode == "bg":
            comp = churn.BackgroundCompactor(
                eng, tracker=tracker, reencode_fn=vec_lookup,
                reencode_rows=reencode_rows)
        ctl = churn.ChurnController(eng, staging_rows=staging_rows,
                                    flush_at=0.5, compact_at=10.0,
                                    compactor=comp)
        loop = LiveIndexLoop(eng, refresh_every=refresh_every,
                             tracker=tracker, compactor=comp,
                             compact_every=compact_every)
        eng.search(np.asarray(Q))      # compile once, WITH staging wired

    times: list[float] = []
    deltas: list = []
    probes: list[dict] = []
    compiles_warm = None
    t_start = time.time()
    for s in range(steps):
        entry = sched[s]
        t0 = time.perf_counter()
        bdata = next(pipe)
        tstate, metrics = step_fn(tstate, *bdata)
        loss = float(metrics["loss"])          # block: step really finished
        if mode != "bare":
            if entry is not None:
                add, add_ids, dead = entry
                ctl.step(add=add, add_ids=add_ids, remove_ids=dead)
                tracker.record(add_ids)
                tracker.forget(dead)
            else:
                ctl.poll_background()
            loop.on_step(metrics)
            if mode == "sync" and (s + 1) % compact_period == 0:
                # the baseline: same repack + same staleness re-encode,
                # but ON the training thread
                rid = tracker.stalest(reencode_rows)
                re = (rid, vec_lookup(rid)) if rid.size else None
                eng.state = churn_ops.compact(
                    eng.state, include_staged=False, reencode=re)
                if rid.size:
                    tracker.record(rid)
        times.append(time.perf_counter() - t0)

        # ---- untimed bookkeeping / probes --------------------------------
        if mode != "bare":
            deltas.append(metrics["rotation_deltas"]["R"])
        if entry is not None:
            _, add_ids, dead = entry
            for d in dead:
                vecs.pop(int(d), None)
            vecs.update({int(i): vec_store[int(i)] for i in add_ids})
        if mode != "bare" and (s + 1) % probe_every == 0:
            truth = _exact_top10(np.asarray(Q), vecs)
            res = eng.search(np.asarray(Q))
            probes.append(dict(
                step=s + 1, wall_s=time.time() - t_start,
                recall=float(recall_at_k(np.asarray(res.ids), truth))))
        if mode != "bare" and s + 1 == warmup:
            compiles_warm = eng.stats()["compiles"]

    loss_final = loss
    out = dict(
        mode=mode,
        step_ms_p50=float(np.median(times[warmup:]) * 1e3),
        step_ms_p99=float(np.percentile(times[warmup:], 99) * 1e3),
        step_ms_max=float(np.max(times[warmup:]) * 1e3),
        loss_final=loss_final,
        prefetch_hits=pipe.prefetch_hits,
        prefetch_misses=pipe.prefetch_misses,
        probes=probes,
    )
    if mode != "bare":
        loop.drain()
        es = eng.stats()
        out.update(
            recompiles_steady=int(es["compiles"] - (compiles_warm
                                                    or es["compiles"])),
            lut_invalidations=int(es["lut_invalidations"]),
            churn=dict(
                bg_compactions=es["churn"]["bg_compactions"],
                bg_discarded=es["churn"]["bg_discarded"],
                flushes_deferred=es["churn"]["flushes_deferred"],
                reencoded=es["churn"]["reencoded"],
                compact_hidden_ms=es["churn"]["compact_hidden_ms_total"],
                flushes=es["churn"]["flushes"],
            ),
            staleness_hist={str(k): v
                            for k, v in sorted(tracker.histogram().items())},
            deltas=deltas,
            final_vecs=vecs,
        )
        if comp is not None:
            comp.close()
    pipe.close()
    return out


def _rebuild_arm(*, searcher, index0, Q, vec0, sched, deltas, steps,
                 nprobe, staging_rows, refresh_every, probe_every):
    """The freshness oracle: same deltas + churn, but EVERY live row is
    re-encoded against the current quantizers each refresh round (full
    rebuild every N steps). Replayed host-side — no trainer."""
    vecs = dict(vec0)
    state = search.IVF.attach(index0, nprobe=nprobe)
    state = churn_ops.with_staging(state, staging_rows)
    probes: list[dict] = []
    for s in range(steps):
        entry = sched[s]
        if entry is not None:
            add, add_ids, dead = entry
            state = churn_ops.tombstone(state, dead)
            if churn_ops.free_slots(state) < len(add_ids):
                state, _ = churn_ops.flush(state)
            if churn_ops.free_slots(state) < len(add_ids):
                state = churn_ops.compact(state)
            state = churn_ops.stage(state, jnp.asarray(add), add_ids)
            for d in dead:
                vecs.pop(int(d), None)
            vecs.update({int(i): a for i, a in zip(add_ids, add)})
        if (s + 1) % refresh_every == 0:
            for d in deltas[s + 1 - refresh_every:s + 1]:
                state = searcher.refresh(state, d)
            live = _live_ids(state)
            state = churn_ops.compact(
                state, include_staged=True,
                reencode=(live, np.stack([vecs[int(i)] for i in live])))
        if (s + 1) % probe_every == 0:
            truth = _exact_top10(np.asarray(Q), vecs)
            res = searcher.search(state, np.asarray(Q), k=10, nprobe=nprobe)
            probes.append(dict(
                step=s + 1,
                recall=float(recall_at_k(np.asarray(res.ids), truth))))
    return probes


def run(n: int = 40_000, dim: int = 64, queries: int = 128, lists: int = 64,
        subspaces: int = 16, codewords: int = 64, steps: int = 120,
        batch: int = 2048, nprobe: int = 16, refresh_every: int = 8,
        compact_every: int = 2, reencode_rows: int = 2048,
        staging_rows: int = 1024, churn_batch: int = 64,
        churn_every: int = 2, warmup: int = 34, probe_every: int = 12,
        verbose: bool = True):
    """The overlapped-training benchmark; returns (results, checks)."""
    out = print if verbose else (lambda *a, **k: None)
    total_adds = (steps // max(churn_every, 1) + 1) * churn_batch
    pool = np.asarray(synthetic.sift_like(
        jax.random.PRNGKey(0), n + total_adds, dim))
    X, add_pool = pool[:n], pool[n:]
    Q = np.asarray(synthetic.sift_like(jax.random.PRNGKey(1), queries, dim))
    R0 = rotations.random_rotation(jax.random.PRNGKey(2), dim)
    cfg = search.SearchConfig(
        num_lists=lists, subspaces=subspaces, codewords=codewords,
        nprobe=nprobe, train_size=min(n, 16384))

    t0 = time.time()
    index0 = index_ivf.build(jax.random.PRNGKey(3), jnp.asarray(X), R0,
                             cfg.ivf_config(), train_size=cfg.train_size)
    searcher = search.make("ivf")
    out(f"# built ivf index: N={n} L={lists} D={subspaces} K={codewords} "
        f"({time.time() - t0:.1f}s)")

    sched = _schedule(X, add_pool, steps, churn_batch, churn_every)
    vec0 = {i: X[i] for i in range(n)}
    kw = dict(searcher=searcher, index0=index0, Q=Q, vec0=vec0, sched=sched,
              steps=steps, batch=batch, dim=dim, nprobe=nprobe,
              staging_rows=staging_rows, refresh_every=refresh_every,
              compact_every=compact_every, reencode_rows=reencode_rows,
              warmup=warmup, probe_every=probe_every, seed=7,
              quantizer=index0.quantizer)

    bare = _train_arm("bare", **kw)
    out(f"# [bare] p50 {bare['step_ms_p50']:.1f} ms  p99 "
        f"{bare['step_ms_p99']:.1f} ms  loss {bare['loss_final']:.4f}")
    bg = _train_arm("bg", **kw)
    ch = bg["churn"]
    out(f"# [bg]   p50 {bg['step_ms_p50']:.1f} ms  p99 "
        f"{bg['step_ms_p99']:.1f} ms  compactions {ch['bg_compactions']} "
        f"(discarded {ch['bg_discarded']}) reencoded {ch['reencoded']} "
        f"hidden {ch['compact_hidden_ms']:.0f} ms")
    sync = _train_arm("sync", **kw)
    out(f"# [sync] p50 {sync['step_ms_p50']:.1f} ms  p99 "
        f"{sync['step_ms_p99']:.1f} ms")

    rebuild_probes = _rebuild_arm(
        searcher=searcher, index0=index0, Q=Q, vec0=vec0, sched=sched,
        deltas=bg["deltas"], steps=steps, nprobe=nprobe,
        staging_rows=staging_rows, refresh_every=refresh_every,
        probe_every=probe_every)

    recall_live = [p["recall"] for p in bg["probes"]]
    recall_rebuild = [p["recall"] for p in rebuild_probes]
    recall_gap = float(abs(np.mean(recall_live) - np.mean(recall_rebuild)))
    overhead = bg["step_ms_p50"] / max(bare["step_ms_p50"], 1e-9)
    out(f"# recall@10 vs exact over wall-clock: live(staleness) "
        f"mean={np.mean(recall_live):.3f} full-rebuild "
        f"mean={np.mean(recall_rebuild):.3f} gap={recall_gap:.4f}")
    out(f"# live/bare p50 overhead {overhead:.3f}x; p99 bg "
        f"{bg['step_ms_p99']:.1f} ms vs sync {sync['step_ms_p99']:.1f} ms; "
        f"steady recompiles {bg['recompiles_steady']}")

    results = dict(
        bare_step_ms_p50=bare["step_ms_p50"],
        live_step_ms_p50=bg["step_ms_p50"],
        overhead_ratio=float(overhead),
        bg_step_ms_p99=bg["step_ms_p99"],
        sync_step_ms_p99=sync["step_ms_p99"],
        bg_step_ms_max=bg["step_ms_max"],
        sync_step_ms_max=sync["step_ms_max"],
        recompiles_steady=bg["recompiles_steady"],
        recall_live_mean=float(np.mean(recall_live)),
        recall_rebuild_mean=float(np.mean(recall_rebuild)),
        recall_gap=recall_gap,
        recall_trajectory=bg["probes"],
        recall_rebuild_trajectory=rebuild_probes,
        prefetch_hits=bg["prefetch_hits"],
        prefetch_misses=bg["prefetch_misses"],
        bg_compactions=ch["bg_compactions"],
        bg_discarded=ch["bg_discarded"],
        flushes_deferred=ch["flushes_deferred"],
        reencoded=ch["reencoded"],
        compact_hidden_ms=ch["compact_hidden_ms"],
        staleness_hist=bg["staleness_hist"],
        loss_bare=bare["loss_final"], loss_live=bg["loss_final"],
    )
    checks = dict(
        live_step_overhead_ok=overhead <= 1.15,
        bg_p99_below_sync=bg["step_ms_p99"] < sync["step_ms_p99"],
        zero_steady_recompiles=bg["recompiles_steady"] == 0,
        recall_matches_rebuild=recall_gap <= 0.01,
        background_ran=(ch["bg_compactions"] >= 1
                        and ch["reencoded"] >= reencode_rows),
        prefetch_effective=bg["prefetch_hits"] > bg["prefetch_misses"],
        training_converged=bg["loss_final"] <= bare["loss_final"] * 1.001,
    )
    out(f"# ACCEPTANCE: {checks} -> "
        f"{'PASS' if all(checks.values()) else 'FAIL'}")
    return results, checks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=40_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--fast", action="store_true",
                    help="small corpus / few steps (CI train-smoke scale)")
    ap.add_argument("--out", default=None,
                    help="BENCH_train_e2e.json destination dir (default "
                         "$REPRO_BENCH_DIR; unset → print only)")
    args = ap.parse_args()
    kw = dict(n=args.n, dim=args.dim, steps=args.steps, batch=args.batch)
    if args.fast:
        kw = dict(n=32000, dim=32, queries=64, lists=32, subspaces=8,
                  codewords=32, steps=54, batch=8192, nprobe=8,
                  refresh_every=6, compact_every=3, reencode_rows=2048,
                  staging_rows=512, churn_batch=32, churn_every=3,
                  warmup=12, probe_every=6)
    res, checks = run(**kw)
    res = {k: v for k, v in res.items()}

    out_dir = args.out or os.environ.get("REPRO_BENCH_DIR")
    if out_dir:
        from repro import obs
        path = obs.write_bench(out_dir, "train_e2e",
                               sections={"train_e2e": res},
                               checks=checks, config=vars(args))
        errs = obs.validate_bench(path)
        print(f"# BENCH written: {path} "
              f"({'schema-valid' if not errs else f'INVALID: {errs}'})")
        if errs:
            sys.exit(1)
    if not all(checks.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
