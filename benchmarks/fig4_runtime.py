"""Fig 4 reproduction (App. B): per-step rotation-update runtime vs dimension.

The paper compares GCD methods against the Cayley transform per update step
(batch size 1); Cayley pays an O(n³) linear solve per step that does not
parallelize, GCD pays one matmul (the directional-derivative scores) + an
O(n) selection + an O(n²) pair-apply.

We time one full update step for n ∈ {64, 128, 256, 512} on CPU (same
"completely fair setup" as the paper's Fig 4b). Trends, not absolutes, are
the claim: GCD-R ≪ Cayley, GCD-G < Cayley, both growing more slowly.
Also timed: the SVD Procrustes solve (the OPQ inner step GCD replaces).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import cayley as cayley_mod
from repro.core import opq, rotation


def run(dims=(64, 128, 256, 512), verbose=True):
    out = {}
    for n in dims:
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (1, n))  # batch size 1, as in the paper
        w = jax.random.normal(jax.random.fold_in(key, 1), (n,))

        def loss_of_R(R):
            return jnp.sum((X @ R) * w)

        # --- GCD variants: one full update step
        state = rotation.init(n)
        G = jax.grad(loss_of_R)(state.R)

        def gcd_step(method, st, g, k):
            return rotation.update(st, g, 1e-3, k, method=method)

        res = {}
        for method in ("random", "greedy", "steepest"):
            fn = jax.jit(lambda st, g, k, m=method: rotation.update(
                st, g, 1e-3, k, method=m))
            us = time_call(fn, state, G, key)
            res[f"gcd_{method}"] = us
        # beyond-paper: serial-scan greedy vs vectorized-rounds greedy
        from repro.core import matching as match_mod
        res["match_greedy_serial"] = time_call(
            jax.jit(match_mod.greedy_matching), G - G.T)
        res["match_greedy_fast"] = time_call(
            jax.jit(match_mod.greedy_matching_fast), G - G.T)

        # --- Cayley: parameter grad + transform (the per-step work)
        A = 0.01 * jax.random.normal(key, (n, n))

        def cayley_loss(a):
            return loss_of_R(cayley_mod.cayley(a))

        cay_step = jax.jit(lambda a: a - 1e-3 * jax.grad(cayley_loss)(a))
        res["cayley"] = time_call(cay_step, A)

        # --- SVD Procrustes (OPQ inner solve)
        Y = jax.random.normal(jax.random.fold_in(key, 2), (256, n))
        Z = jax.random.normal(jax.random.fold_in(key, 3), (256, n))
        svd_fn = jax.jit(lambda y, z: opq.procrustes_rotation(y, z))
        res["svd"] = time_call(svd_fn, Y, Z)

        out[n] = res
        if verbose:
            for k, v in res.items():
                emit(f"fig4/n{n}/{k}", v)
    checks = {
        "gcd_r_faster_than_cayley_at_512": out[512]["gcd_random"]
        < out[512]["cayley"],
        "gcd_scales_better": (out[512]["gcd_random"] / out[64]["gcd_random"])
        < (out[512]["cayley"] / max(out[64]["cayley"], 1e-9)) * 2.0,
    }
    if verbose:
        for k, v in checks.items():
            emit(f"fig4/check/{k}", 0.0, str(v))
    return out, checks


if __name__ == "__main__":
    run()
