"""Fig 4 reproduction (App. B): per-step rotation-update runtime vs dimension.

The paper compares GCD methods against the Cayley transform per update step
(batch size 1); Cayley pays an O(n³) linear solve per step that does not
parallelize, GCD pays one matmul (the directional-derivative scores) + an
O(n) selection + an O(n²) pair-apply.

Every learner is timed through the same ``repro.rotations`` protocol call —
``learner.update(state, G, lr, key)`` — for n ∈ {64, 128, 256, 512} on CPU
(same "completely fair setup" as the paper's Fig 4b). The sweep list is the
registry, so a newly registered learner lands in this figure automatically.
Trends, not absolutes, are the claim: GCD-R ≪ Cayley, GCD-G < Cayley, both
growing more slowly. Also timed: the SVD Procrustes closed-form solve (the
OPQ inner step GCD replaces) and the serial-vs-vectorized greedy matching.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro import rotations

# registry learners timed per update step (subspace_gcd at sub = n // 8;
# procrustes' update is the projected-SGD step, its closed-form solve is
# timed separately below)
SWEEP = [n for n in rotations.names() if n != "frozen"]


def run(dims=(64, 128, 256, 512), verbose=True):
    out = {}
    for n in dims:
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (1, n))  # batch size 1, as in the paper
        w = jax.random.normal(jax.random.fold_in(key, 1), (n,))

        def loss_of_R(R):
            return jnp.sum((X @ R) * w)

        G = jax.grad(loss_of_R)(jnp.eye(n))

        res = {}
        for spec in SWEEP:
            kw = {"sub": n // 8} if spec == "subspace_gcd" else {}
            learner = rotations.make(spec, **kw)
            state = learner.init(n)
            fn = jax.jit(lambda st, g, k, lrn=learner: lrn.update(
                st, g, 1e-3, k)[0])
            res[spec] = time_call(fn, state, G, key)
        # beyond-paper: serial-scan greedy vs vectorized-rounds greedy
        from repro.core import matching as match_mod
        res["match_greedy_serial"] = time_call(
            jax.jit(match_mod.greedy_matching), G - G.T)
        res["match_greedy_fast"] = time_call(
            jax.jit(match_mod.greedy_matching_fast), G - G.T)

        # --- SVD Procrustes closed-form solve (OPQ inner step)
        from repro.rotations.procrustes import procrustes_rotation
        Y = jax.random.normal(jax.random.fold_in(key, 2), (256, n))
        Z = jax.random.normal(jax.random.fold_in(key, 3), (256, n))
        svd_fn = jax.jit(procrustes_rotation)
        res["procrustes_solve"] = time_call(svd_fn, Y, Z)

        out[n] = res
        if verbose:
            for k, v in res.items():
                emit(f"fig4/n{n}/{k}", v)
    top = max(dims)
    base = min(dims)
    checks = {
        f"gcd_r_faster_than_cayley_at_{top}": out[top]["gcd_random"]
        < out[top]["cayley_sgd"],
        "gcd_scales_better": (out[top]["gcd_random"] / out[base]["gcd_random"])
        < (out[top]["cayley_sgd"] / max(out[base]["cayley_sgd"], 1e-9)) * 2.0,
    }
    if verbose:
        for k, v in checks.items():
            emit(f"fig4/check/{k}", 0.0, str(v))
    return out, checks


if __name__ == "__main__":
    run()
