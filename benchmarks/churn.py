"""Live churn under query load: staged adds, tombstone deletes, compaction.

The serving story ISSUE 8 adds to the paper's train-while-serving loop:
the corpus itself now moves while the rotation is being trained. A fused
``ivf`` Engine serves a steady query stream while a ``ChurnController``
interleaves, every step,

  * ``remove`` — tombstone a batch of ids (masked to −inf inside the very
    Pallas tile scans, never filtered post-hoc),
  * ``add`` — stage a batch of new rows into the fixed-capacity append
    buffer (served by the NEXT query via the flat-ADC side pass),
  * a ``subspace_gcd`` RotationDelta absorbed through ``Engine.refresh``
    (the training loop keeps running during churn),
  * controller-paced ``flush`` (staged rows folded into CSR holes) and
    ``compact`` (holes squeezed out, shapes preserved).

Acceptance (claim checks):
  * zero Engine recompiles across the whole churn run (trace-counter
    pinned: every mutation is shape-preserving by construction),
  * zero LUT-cache invalidations (fused refresh keeps cached tables),
  * zero capacity ``grows`` — balanced churn is steady-state,
  * no tombstoned id ever surfaces in any step's results,
  * end-state recall@10 within 0.01 of a from-scratch ``ivf.build`` on
    the live rows (and exactly matching a same-quantizer repack).

``--devices N`` appends a sharded cell (forced host devices, subprocess):
the same controller loop over ``ivf_sharded``, with deletes concentrated
on the lowest id ranks so shard 0 drains and the controller's imbalance
trigger fires a ``shard_rebalance`` — recall must survive the migration.

Run:  PYTHONPATH=src python benchmarks/churn.py --fast [--devices 2]
      PYTHONPATH=src python -m benchmarks.run --only churn --fast
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import churn, rotations, search
from repro.data import synthetic
from repro.index import ivf as index_ivf
from repro.metrics import recall_at_k

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _exact_top10(Q: np.ndarray, vecs: dict) -> np.ndarray:
    """Brute-force MIPS oracle over the live id → vector map."""
    live_ids = np.asarray(sorted(vecs), dtype=np.int32)
    live_X = np.stack([vecs[int(i)] for i in live_ids])
    order = np.argsort(-(Q @ live_X.T), axis=1)[:, :10]
    return live_ids[order]


def _delta(R, dim, sub, key):
    G = jax.random.normal(jax.random.PRNGKey(1000 + key), (dim, dim))
    learner = rotations.make("subspace_gcd", sub=sub)
    _, delta = learner.update(learner.init_from(R), G, 1e-3,
                              jax.random.PRNGKey(key))
    return delta


def churn_loop(engine, ctl, Q, vecs, add_pool, *, steps, batch, dim, rng,
               refresh=True, low_end_removes=False):
    """Drive balanced add/remove churn + refresh under query load.

    ``add_pool`` is the in-distribution add stream — drawn from the SAME
    mixture as the corpus (one ``sift_like`` call split in two), the
    realistic churn model. Out-of-mixture adds are a quantizer-drift
    problem (retrain), not an index-mutation problem.

    Returns (per-step dicts, cumulative removed-id set). Asserts nothing —
    callers turn the records into claim checks.
    """
    sub = getattr(ctl.state, "index", ctl.state).quantizer.sub
    removed: set = set()
    next_id = max(vecs) + 1
    records = []
    for step in range(steps):
        live_sorted = sorted(vecs)
        if low_end_removes:
            dead = np.asarray(live_sorted[:batch], dtype=np.int32)
        else:
            dead = rng.choice(live_sorted, size=batch,
                              replace=False).astype(np.int32)
        add = add_pool[step * batch:(step + 1) * batch]
        add_ids = np.arange(next_id, next_id + batch, dtype=np.int32)
        next_id += batch

        t0 = time.time()
        ctl.step(add=add, add_ids=add_ids, remove_ids=dead)
        mut_ms = (time.time() - t0) * 1e3
        for i in dead:
            removed.add(int(i))
            vecs.pop(int(i))
        vecs.update({int(i): x for i, x in zip(add_ids, add)})

        if refresh:
            engine.refresh(_delta(ctl.state.index.R, dim, sub, step))
        res = engine.search(Q)
        ids = np.asarray(res.ids)
        records.append(dict(
            step=step, mutate_ms=mut_ms,
            surfaced_tombstone=bool(np.isin(ids[ids >= 0],
                                            list(removed)).any()),
            ids_live=bool(set(ids[ids >= 0].ravel().tolist())
                          <= set(vecs)),
        ))
    return records, removed


def run(n: int = 50_000, dim: int = 64, queries: int = 128, lists: int = 64,
        subspaces: int = 16, codewords: int = 64, steps: int = 20,
        batch: int = 128, nprobe: int = 16, staging_rows: int = 1024,
        verbose: bool = True, devices: int = 1):
    """The single-device churn benchmark; returns (results, checks)."""
    out = print if verbose else (lambda *a, **k: None)
    pool = np.asarray(synthetic.sift_like(
        jax.random.PRNGKey(0), n + steps * batch, dim))
    X, add_pool = pool[:n], pool[n:]
    Q = np.asarray(synthetic.sift_like(jax.random.PRNGKey(1), queries, dim))
    R = rotations.random_rotation(jax.random.PRNGKey(2), dim)
    cfg = search.SearchConfig(
        num_lists=lists, subspaces=subspaces, codewords=codewords,
        nprobe=nprobe, train_size=min(n, 16384), fused_refresh=True)

    ivf_s = search.make("ivf")
    t0 = time.time()
    state = ivf_s.build(jax.random.PRNGKey(3), jnp.asarray(X), R, cfg)
    out(f"# built fused ivf index: N={n} L={lists} D={subspaces} "
        f"K={codewords} ({time.time() - t0:.1f}s)")

    engine = search.Engine(ivf_s, state, k=10, nprobe=nprobe, min_bucket=32)
    ctl = churn.ChurnController(engine, staging_rows=staging_rows,
                                flush_at=0.5, compact_at=0.03)
    engine.search(Q)                      # compile once, WITH staging wired
    compiles0 = engine.stats()["compiles"]

    vecs = {i: X[i] for i in range(n)}
    rng = np.random.default_rng(0)
    t0 = time.time()
    records, removed = churn_loop(engine, ctl, Q, vecs, add_pool,
                                  steps=steps, batch=batch, dim=dim, rng=rng)
    churn_s = time.time() - t0

    es = engine.stats()
    ch = es["churn"]
    recompiles = es["compiles"] - compiles0
    tombstone_clean = not any(r["surfaced_tombstone"] for r in records)
    ids_live = all(r["ids_live"] for r in records)

    # --- end-state recall vs the two rebuild oracles -----------------------
    truth = _exact_top10(Q, vecs)
    final = engine.search(Q)
    recall_churn = float(recall_at_k(np.asarray(final.ids), truth))

    live_ids = np.asarray(sorted(vecs), dtype=np.int32)
    live_X = np.stack([vecs[int(i)] for i in live_ids])
    idx = ctl.state.index
    # (a) same-quantizer repack: the bit-parity oracle — compaction and
    # staging must cost exactly nothing relative to a clean CSR
    list_ids, codes = index_ivf.encode(
        np.asarray(live_X) @ np.asarray(idx.R), idx.coarse, idx.quantizer)
    repacked = index_ivf.pack(idx.R, idx.coarse, idx.quantizer, codes,
                              list_ids, live_ids,
                              block_size=cfg.block_size)
    res_repack = ivf_s.search(search.IVF.attach(repacked, nprobe=nprobe),
                              np.asarray(Q), k=10, nprobe=nprobe)
    recall_repack = float(recall_at_k(np.asarray(res_repack.ids), truth))
    # (b) from-scratch build: fresh k-means on the live rows under the
    # CURRENT (GCD-trained) rotation — the expensive path churn avoids
    rebuilt = ivf_s.build(jax.random.PRNGKey(3), np.asarray(live_X),
                          idx.R, cfg)
    rebuilt = search.IVF.attach(  # re-key ids: build numbers rows 0..m
        index_ivf.IVFPQIndex(
            R=rebuilt.index.R, coarse=rebuilt.index.coarse,
            quantizer=rebuilt.index.quantizer, codes=rebuilt.index.codes,
            ids=np.where(np.asarray(rebuilt.index.ids) >= 0,
                         live_ids[np.maximum(
                             np.asarray(rebuilt.index.ids), 0)],
                         -1).astype(np.int32),
            list_offsets=rebuilt.index.list_offsets,
            block_size=rebuilt.index.block_size),
        nprobe=nprobe)
    res_build = ivf_s.search(rebuilt, np.asarray(Q), k=10, nprobe=nprobe)
    recall_build = float(recall_at_k(np.asarray(res_build.ids), truth))

    results = dict(
        steps=steps, batch=batch, churn_qps=queries * steps / churn_s,
        mutate_ms_p50=float(np.median([r["mutate_ms"] for r in records])),
        latency_ms_p50=es["latency_ms_p50"],
        recompiles=recompiles, lut_invalidations=es["lut_invalidations"],
        recall_churn=recall_churn, recall_repack=recall_repack,
        recall_build=recall_build,
        staged=ch["staged"], flushed=ch["flushed"],
        tombstoned=ch["tombstoned"], flushes=ch["flushes"],
        compactions=ch["compactions"], grows=ch["grows"],
        flush_ms_p95=ch["flush_ms_p95"],
    )
    checks = dict(
        zero_recompiles=recompiles == 0,
        zero_lut_invalidations=es["lut_invalidations"] == 0,
        zero_grows=ch["grows"] == 0,
        no_tombstoned_id_surfaced=tombstone_clean and ids_live,
        all_mutations_exercised=(ch["flushes"] >= 1
                                 and ch["compactions"] >= 1
                                 and ch["staged"] == steps * batch
                                 and ch["tombstoned"] == steps * batch),
        recall_matches_repack=abs(recall_churn - recall_repack) <= 0.01,
        recall_within_rebuild=recall_churn >= recall_build - 0.01,
    )
    out(f"# [churn] {steps} steps x {batch} add/{batch} remove + refresh "
        f"under load: recompiles {recompiles}, lut_invalidations "
        f"{es['lut_invalidations']}, grows {ch['grows']}, flushes "
        f"{ch['flushes']}, compactions {ch['compactions']}, flush p95 "
        f"{ch['flush_ms_p95']:.1f} ms")
    out(f"# [churn] recall@10 vs live-set exact: churn={recall_churn:.3f} "
        f"repack={recall_repack:.3f} fresh-build={recall_build:.3f}")

    if devices > 1:
        cell = _run_sharded_cell(
            devices, n=n, dim=dim, queries=queries, lists=lists,
            subspaces=subspaces, codewords=codewords, steps=steps,
            batch=batch, nprobe=nprobe, staging_rows=staging_rows)
        results["sharded"] = cell
        out(f"# [churn --devices {devices}] recompiles "
            f"{cell['recompiles']}, rebalances {cell['rebalances']}, "
            f"shard rows {cell['shard_rows_before']} -> "
            f"{cell['shard_rows_after']}, recall {cell['recall']:.3f} "
            f"(repack {cell['recall_repack']:.3f})")
        checks["sharded_zero_recompiles"] = cell["recompiles"] == 0
        checks["sharded_rebalanced"] = cell["rebalances"] >= 1
        checks["sharded_no_tombstones"] = cell["tombstone_clean"]
        checks["sharded_recall_matches_repack"] = (
            abs(cell["recall"] - cell["recall_repack"]) <= 0.01)

    out(f"# ACCEPTANCE: {checks} -> "
        f"{'PASS' if all(checks.values()) else 'FAIL'}")
    return results, checks


def churn_sharded_cell(n: int, dim: int, queries: int, lists: int,
                       subspaces: int, codewords: int, steps: int,
                       batch: int, nprobe: int, staging_rows: int,
                       devices: int) -> dict:
    """The --devices cell: controller churn over ``ivf_sharded``, with
    low-end deletes draining shard 0 (the id-rank partition puts the lowest
    ids there) until the imbalance trigger rebalances. Runs inside the
    forced-host-device subprocess ``_run_sharded_cell`` spawns."""
    assert jax.device_count() >= devices
    from repro.launch.mesh import make_data_mesh
    mesh = make_data_mesh(devices)

    pool = np.asarray(synthetic.sift_like(
        jax.random.PRNGKey(0), n + steps * batch, dim))
    X, add_pool = pool[:n], pool[n:]
    Q = np.asarray(synthetic.sift_like(jax.random.PRNGKey(1), queries, dim))
    R = rotations.random_rotation(jax.random.PRNGKey(2), dim)
    cfg = search.SearchConfig(
        num_lists=lists, subspaces=subspaces, codewords=codewords,
        nprobe=nprobe, train_size=min(n, 16384))
    index = index_ivf.build(jax.random.PRNGKey(3), jnp.asarray(X), R,
                            cfg.ivf_config(), train_size=cfg.train_size)

    sh_s = search.make("ivf_sharded", mesh=mesh)
    state = search.IVFSharded.attach(index, mesh=mesh, nprobe=nprobe)
    engine = search.Engine(sh_s, state, k=10, nprobe=nprobe, min_bucket=32)
    # low-end removes drain shard 0 by ~batch rows/step; the tight
    # threshold makes the imbalance trigger fire within the short run
    ctl = churn.ChurnController(engine, staging_rows=staging_rows,
                                flush_at=0.5, compact_at=0.05,
                                imbalance_threshold=1.03)

    def shard_rows(st):
        ids = np.asarray(st.ids)
        return [int((ids[s] >= 0).sum()) for s in range(ids.shape[0])]

    rows_before = shard_rows(ctl.state)
    engine.search(Q)
    compiles0 = engine.stats()["compiles"]

    vecs = {i: X[i] for i in range(n)}
    records, removed = churn_loop(
        engine, ctl, Q, vecs, add_pool, steps=steps, batch=batch, dim=dim,
        rng=np.random.default_rng(0), refresh=False, low_end_removes=True)

    es = engine.stats()
    truth = _exact_top10(Q, vecs)
    final = engine.search(Q)
    recall = float(recall_at_k(np.asarray(final.ids), truth))

    # same-quantizer repack oracle, served through the same sharded backend
    live_ids = np.asarray(sorted(vecs), dtype=np.int32)
    live_X = np.stack([vecs[int(i)] for i in live_ids])
    idx0 = index
    list_ids, codes = index_ivf.encode(
        np.asarray(live_X) @ np.asarray(idx0.R), idx0.coarse, idx0.quantizer)
    repacked = index_ivf.pack(idx0.R, idx0.coarse, idx0.quantizer, codes,
                              list_ids, live_ids, block_size=cfg.block_size)
    res_repack = sh_s.search(
        search.IVFSharded.attach(repacked, mesh=mesh, nprobe=nprobe),
        np.asarray(Q), k=10, nprobe=nprobe)
    recall_repack = float(recall_at_k(np.asarray(res_repack.ids), truth))

    return dict(
        devices=devices,
        recompiles=int(es["compiles"] - compiles0),
        rebalances=int(es["churn"]["rebalances"]),
        grows=int(es["churn"]["grows"]),
        shard_rows_before=rows_before,
        shard_rows_after=shard_rows(ctl.state),
        tombstone_clean=not any(r["surfaced_tombstone"] for r in records)
        and all(r["ids_live"] for r in records),
        recall=recall, recall_repack=recall_repack,
    )


def _run_sharded_cell(devices: int, **kw) -> dict:
    """Spawn ``churn_sharded_cell`` under a forced host-device count (the
    XLA flag must be set before jax initializes, hence the subprocess)."""
    code = (
        "import os, json\n"
        "os.environ['XLA_FLAGS'] = (os.environ.get('XLA_FLAGS', '') + "
        f"' --xla_force_host_platform_device_count={devices}').strip()\n"
        "from benchmarks.churn import churn_sharded_cell\n"
        f"print('CELL=' + json.dumps(churn_sharded_cell(devices={devices}, "
        + ", ".join(f"{k}={v!r}" for k, v in kw.items()) + ")))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, os.path.join(_REPO, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(
            f"churn sharded cell failed:\n{out.stdout}\n{out.stderr}")
    line = [l for l in out.stdout.splitlines() if l.startswith("CELL=")][-1]
    return json.loads(line[len("CELL="):])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--lists", type=int, default=64)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--fast", action="store_true",
                    help="small corpus / few steps (CI churn-smoke scale)")
    ap.add_argument("--devices", type=int, default=1,
                    help="append the sharded churn cell on N forced host "
                         "devices (subprocess)")
    ap.add_argument("--out", default=None,
                    help="BENCH_churn.json destination dir (default "
                         "$REPRO_BENCH_DIR; unset → print only)")
    args = ap.parse_args()
    kw = dict(n=args.n, dim=args.dim, queries=args.queries,
              lists=args.lists, steps=args.steps, batch=args.batch)
    if args.fast:
        kw = dict(n=8000, dim=32, queries=64, lists=32, subspaces=8,
                  codewords=32, steps=6, batch=64, nprobe=8,
                  staging_rows=512)
    res, checks = run(devices=args.devices, **kw)

    out_dir = args.out or os.environ.get("REPRO_BENCH_DIR")
    if out_dir:
        from repro import obs
        path = obs.write_bench(out_dir, "churn", sections={"churn": res},
                               checks=checks, config=vars(args))
        errs = obs.validate_bench(path)
        print(f"# BENCH written: {path} "
              f"({'schema-valid' if not errs else f'INVALID: {errs}'})")
        if errs:
            sys.exit(1)
    if not all(checks.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
