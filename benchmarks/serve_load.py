"""Open-loop Poisson load over the repro.serve front-end.

The serving claim this benchmark pins (ISSUE 9 acceptance): under Poisson
arrivals near the top rung's capacity, **SLO-adaptive nprobe beats every
fixed-nprobe baseline of equal-or-better recall on p99 latency**, with
zero steady-state recompiles and zero cross-namespace LUT invalidations.

Method
------
Two namespaces (``alpha``, ``beta`` — separate corpora, fused-refresh IVF
backends, alpha also carries a ChurnController for idle-slot maintenance
ticks) are served by one Frontend per cell. Cells: one fixed-nprobe cell
per ladder rung plus the adaptive cell; every cell replays the SAME
arrival trace (seeded Poisson inter-arrivals over a finite query pool —
pools model real traffic repeats and keep the LUT cache meaningful).

Time is virtual (``serve.VirtualClock``): queueing dynamics unfold on the
virtual axis while batch service times are REAL measured compute folded
in via ``advance`` — deterministic arrivals, honest service. The arrival
rate is calibrated from the adaptive cell's warmup-seeded latency model:
``load`` × the top rung's full-bucket throughput, so "near capacity"
means the same thing on any host.

During the run alpha absorbs periodic **cross-subspace** rotation deltas
(the kind fused refresh can NOT keep LUTs through) — alpha's cache
invalidates, and the isolation check pins that beta's never does.

Per cell, the first ``warm_frac`` of completed tickets are discarded
(small host-side jits — LUT builds at novel miss widths — warm up there),
then p50/p99/QPS/SLO-attainment/recall@10 come from the rest.

CLI: ``--fast`` is the CI smoke preset; ``--out`` (or $REPRO_BENCH_DIR)
writes schema-validated BENCH_serve.json; exit 1 on any failed check.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import numpy as np

from repro import rotations, search, serve
from repro.data import synthetic
from repro.metrics import recall_at_k

NAMESPACES = ("alpha", "beta")


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def _cross_subspace_delta(R, dim, step):
    """A full-width GCD delta — deliberately NOT within-subspace, so the
    fused backends must invalidate cached LUTs (isolation stressor)."""
    G = jax.random.normal(jax.random.PRNGKey(7000 + step), (dim, dim))
    learner = rotations.make("gcd")
    _, delta = learner.update(learner.init_from(R), G, 1e-3,
                              jax.random.PRNGKey(step))
    return delta


def _build_states(*, n, dim, lists, subspaces, codewords, nprobe, seed=0):
    """One fused-refresh IVF state per namespace, plus its query pool and
    exact ground truth (MIPS is rotation-invariant: truth from raw X/Q)."""
    s = search.make("ivf")
    cfg = search.SearchConfig(
        num_lists=lists, subspaces=subspaces, codewords=codewords,
        nprobe=nprobe, train_size=min(n, 16384), fused_refresh=True)
    out = {}
    for i, name in enumerate(NAMESPACES):
        X = np.asarray(synthetic.sift_like(
            jax.random.PRNGKey(seed + 10 * i), n, dim))
        R = rotations.random_rotation(jax.random.PRNGKey(seed + 10 * i + 1),
                                      dim)
        state = s.build(jax.random.PRNGKey(seed + 10 * i + 2), X, R, cfg)
        pool = np.asarray(synthetic.sift_like(
            jax.random.PRNGKey(seed + 10 * i + 3), 64, dim))
        truth = np.argsort(-(pool @ X.T), axis=1)[:, :10]
        out[name] = dict(state=state, pool=pool, truth=truth)
    return s, out


def _make_frontend(searcher, corpora, *, ladder, fixed_nprobe, slo_ms,
                   admission_ms, max_admit, clock):
    """One cell's Frontend: adaptive (``ladder``) or fixed
    (``fixed_nprobe`` as each Engine's default, no adaptation)."""
    fe = serve.Frontend(slo_ms=slo_ms, clock=clock.now, advance=clock.advance,
                        lut_budget_rows=4096)
    for name in NAMESPACES:
        c = corpora[name]
        ekw = {"min_bucket": max_admit}
        if fixed_nprobe is not None:
            ekw["nprobe"] = fixed_nprobe
        churn = {"staging_rows": 256} if name == "alpha" else None
        fe.create_namespace(
            name, searcher, c["state"], k=10,
            nprobe_ladder=ladder if fixed_nprobe is None else None,
            admission_ms=admission_ms, max_admit=max_admit, churn=churn,
            warmup_queries=c["pool"][:4], engine_kwargs=ekw)
    return fe


def _arrival_trace(rng, *, requests, rate_qps, pool_size):
    """(t, namespace, pool index) triples — one seeded trace replayed by
    every cell so fixed vs adaptive see identical load."""
    gaps = rng.exponential(1.0 / rate_qps, size=requests)
    ts = np.cumsum(gaps)
    names = rng.integers(0, len(NAMESPACES), size=requests)
    qis = rng.integers(0, pool_size, size=requests)
    return [(float(ts[i]), NAMESPACES[int(names[i])], int(qis[i]))
            for i in range(requests)]


def _run_cell(searcher, corpora, trace, *, ladder, fixed_nprobe, slo_ms,
              admission_ms, max_admit, dim, refresh_every, warm_frac):
    """Replay one trace through one Frontend configuration; returns the
    cell's measured-phase metrics."""
    clock = serve.VirtualClock()
    fe = _make_frontend(searcher, corpora, ladder=ladder,
                        fixed_nprobe=fixed_nprobe, slo_ms=slo_ms,
                        admission_ms=admission_ms, max_admit=max_admit,
                        clock=clock)
    spaces = {name: fe.namespaces.get(name) for name in NAMESPACES}
    warm_at = int(len(trace) * warm_frac)
    measured_from = {name: None for name in NAMESPACES}  # compiles at cutoff

    done, i, refreshes = [], 0, 0
    while i < len(trace) or fe.next_deadline() is not None:
        nd = fe.next_deadline()
        na = trace[i][0] if i < len(trace) else None
        if nd is None and na is None:
            break
        clock.set(min(t for t in (nd, na) if t is not None))
        while i < len(trace) and trace[i][0] <= clock.now():
            t_arr, name, qi = trace[i]
            fe.submit(name, corpora[name]["pool"][qi], arrival=t_arr)
            i += 1
            if i == warm_at:
                for nm, ns in spaces.items():
                    measured_from[nm] = ns.engine.stats()["compiles"]
            if refresh_every and i % refresh_every == 0:
                # between-batch rotation absorption on alpha only
                eng = spaces["alpha"].engine
                eng.refresh(_cross_subspace_delta(
                    eng.state.index.R, dim, step=i))
                refreshes += 1
        done.extend(fe.poll())
    done.extend(fe.drain())
    assert len(done) == len(trace), (len(done), len(trace))

    done.sort(key=lambda t: t.arrival)
    meas = done[warm_at:]
    lats = [t.latency_ms for t in meas]
    rung_mix = {}
    for t in meas:
        rung_mix[t.nprobe_served] = rung_mix.get(t.nprobe_served, 0) + 1
    recs = [float(recall_at_k(
        np.asarray(t.result.ids)[None, :],
        corpora[t.namespace]["truth"][_pool_index(t, corpora)][None, :]))
        for t in meas]
    span_s = max(t.completed for t in meas) - min(t.arrival for t in meas)
    stats = fe.stats()
    steady_recompiles = sum(
        stats["namespaces"][nm]["compiles"] - measured_from[nm]
        for nm in NAMESPACES if measured_from[nm] is not None)
    return dict(
        requests=len(meas),
        qps=len(meas) / span_s if span_s > 0 else 0.0,
        p50_ms=_percentile(lats, 50), p99_ms=_percentile(lats, 99),
        slo_attainment=float(np.mean([t.latency_ms <= t.slo_ms
                                      for t in meas])),
        recall=float(np.mean(recs)),
        rung_mix={str(r): c for r, c in sorted(rung_mix.items())},
        sheds=stats["sheds"], flushes=stats["flushes"],
        maintenance_ticks=stats["maintenance_ticks"],
        steady_recompiles=steady_recompiles,
        alpha_refreshes=refreshes,
        alpha_lut_invalidations=(
            stats["namespaces"]["alpha"]["lut_invalidations"]),
        beta_lut_invalidations=(
            stats["namespaces"]["beta"]["lut_invalidations"]),
        beta_lut_epoch=stats["namespaces"]["beta"]["lut_epoch"],
        lut_evictions={nm: stats["namespaces"][nm]["lut_evictions"]
                       for nm in NAMESPACES},
    )


def _pool_index(ticket, corpora):
    """Recover which pool row a ticket served (pools are small; row bytes
    are unique per pool with overwhelming probability)."""
    pool = corpora[ticket.namespace]["pool"]
    hit = np.flatnonzero((pool == ticket.query).all(axis=1))
    return int(hit[0])


def run(*, n=50_000, dim=64, lists=256, subspaces=32, codewords=64,
        ladder=(2, 8, 32), requests=1500, load=1.1, slo_factor=4.0,
        admission_ms=2.0, max_admit=16, refresh_every=200, warm_frac=0.3,
        verbose=True):
    """All cells on one trace; returns (results, checks)."""
    out = print if verbose else (lambda *a, **k: None)
    ladder = tuple(sorted(ladder))
    searcher, corpora = _build_states(
        n=n, dim=dim, lists=lists, subspaces=subspaces,
        codewords=codewords, nprobe=ladder[-1])
    out(f"# built {len(NAMESPACES)} fused ivf namespaces: N={n} "
        f"L={lists} D={subspaces} K={codewords} ladder={ladder}")

    # calibrate the arrival rate from a throwaway adaptive frontend's
    # warmup-seeded latency model: load × top-rung full-bucket throughput
    cal_clock = serve.VirtualClock()
    cal = _make_frontend(searcher, corpora, ladder=ladder, fixed_nprobe=None,
                         slo_ms=1e9, admission_ms=admission_ms,
                         max_admit=max_admit, clock=cal_clock)
    top_ms = max(cal.namespaces.get(nm).slo.predict_ms(max_admit, ladder[-1])
                 for nm in NAMESPACES)
    rate_qps = load * max_admit / (top_ms * 1e-3)
    slo_ms = slo_factor * top_ms
    out(f"# calibration: top-rung bucket {top_ms:.2f} ms -> "
        f"rate {rate_qps:.0f} q/s (load {load}), slo {slo_ms:.1f} ms")

    trace = _arrival_trace(np.random.default_rng(42), requests=requests,
                           rate_qps=rate_qps,
                           pool_size=corpora["alpha"]["pool"].shape[0])
    kw = dict(slo_ms=slo_ms, admission_ms=admission_ms,
              max_admit=max_admit, dim=dim, refresh_every=refresh_every,
              warm_frac=warm_frac)
    cells = {}
    for rung in ladder:
        cells[f"fixed_np{rung}"] = _run_cell(
            searcher, corpora, trace, ladder=ladder, fixed_nprobe=rung, **kw)
        c = cells[f"fixed_np{rung}"]
        out(f"# [serve] fixed np={rung:>3}: p50 {c['p50_ms']:7.2f}  "
            f"p99 {c['p99_ms']:8.2f}  recall {c['recall']:.3f}  "
            f"slo-att {c['slo_attainment']:.3f}  qps {c['qps']:.0f}")
    cells["adaptive"] = _run_cell(
        searcher, corpora, trace, ladder=ladder, fixed_nprobe=None, **kw)
    a = cells["adaptive"]
    out(f"# [serve] adaptive    : p50 {a['p50_ms']:7.2f}  "
        f"p99 {a['p99_ms']:8.2f}  recall {a['recall']:.3f}  "
        f"slo-att {a['slo_attainment']:.3f}  qps {a['qps']:.0f}  "
        f"sheds {a['sheds']}/{a['flushes']}  mix {a['rung_mix']}")

    # comparable fixed baselines: equal-or-better recall (±0.01)
    comparable = {name: c for name, c in cells.items()
                  if name != "adaptive" and c["recall"] >= a["recall"] - 0.01}
    best_fixed_p99 = (min(c["p99_ms"] for c in comparable.values())
                      if comparable else float("inf"))
    out(f"# [serve] comparable fixed cells at recall >= "
        f"{a['recall'] - 0.01:.3f}: {sorted(comparable)} "
        f"(best p99 {best_fixed_p99:.2f} ms vs adaptive {a['p99_ms']:.2f})")

    results = dict(
        rate_qps=rate_qps, slo_ms=slo_ms, ladder=list(ladder),
        requests=requests, cells=cells,
        comparable_fixed=sorted(comparable),
        best_fixed_p99_ms=best_fixed_p99,
    )
    checks = dict(
        adaptive_beats_best_fixed_p99_at_equal_recall=(
            bool(comparable) and a["p99_ms"] < best_fixed_p99),
        zero_steady_state_recompiles=all(
            c["steady_recompiles"] == 0 for c in cells.values()),
        zero_cross_namespace_lut_invalidations=all(
            c["beta_lut_invalidations"] == 0 and c["beta_lut_epoch"] == 0
            for c in cells.values()),
        refresh_isolation_exercised=(
            a["alpha_refreshes"] >= 1 and a["alpha_lut_invalidations"] >= 1),
        adaptive_sheds_under_load=a["sheds"] >= 1,
        maintenance_ticks_in_idle_slots=a["maintenance_ticks"] >= 1,
    )
    return results, checks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--lists", type=int, default=64)
    ap.add_argument("--requests", type=int, default=1500)
    ap.add_argument("--load", type=float, default=1.1,
                    help="arrival rate as a fraction of top-rung capacity "
                         "(>1 = the top rung alone cannot keep up)")
    ap.add_argument("--slo-factor", type=float, default=4.0,
                    help="per-request SLO as a multiple of the top rung's "
                         "full-bucket service time")
    ap.add_argument("--fast", action="store_true",
                    help="small corpus / short trace (CI serve-smoke scale)")
    ap.add_argument("--out", default=None,
                    help="BENCH_serve.json destination dir (default "
                         "$REPRO_BENCH_DIR; unset → print only)")
    args = ap.parse_args()
    kw = dict(n=args.n, dim=args.dim, lists=args.lists,
              requests=args.requests, load=args.load,
              slo_factor=args.slo_factor)
    if args.fast:
        kw = dict(n=8000, dim=32, lists=128, subspaces=16, codewords=64,
                  ladder=(2, 4, 16), requests=600, load=args.load,
                  slo_factor=args.slo_factor, max_admit=8,
                  refresh_every=150)
    res, checks = run(**kw)

    out_dir = args.out or os.environ.get("REPRO_BENCH_DIR")
    if out_dir:
        from repro import obs
        path = obs.write_bench(out_dir, "serve", sections={"serve": res},
                               checks=checks, config=vars(args))
        errs = obs.validate_bench(path)
        print(f"# BENCH written: {path} "
              f"({'schema-valid' if not errs else f'INVALID: {errs}'})")
        if errs:
            sys.exit(1)
    if not all(checks.values()):
        print("# FAILED checks:",
              sorted(k for k, v in checks.items() if not v))
        sys.exit(1)


if __name__ == "__main__":
    main()
